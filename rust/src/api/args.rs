//! Typed task-argument extraction.
//!
//! The wire format of a spawned task is the paper's Fig 4 surface: a
//! function-table index plus a flat `[TaskArg]` list of flagged
//! `(node, value)` pairs. Task bodies, however, should not be indexing
//! that list positionally (`val_arg(3)`) and keeping the spawn-site order
//! in sync by hand — that is the closed-world, error-prone part of the
//! original API. Instead a body unpacks its arguments once, as a typed
//! tuple:
//!
//! ```ignore
//! let (r, halo, iter): (RegionArg, ObjArg, u64) = ctx.args();
//! ```
//!
//! Each tuple element consumes one (or more, for [`Rest`]) wire
//! arguments, in order. In debug builds every element checks the
//! argument's `TYPE_*` flag bits (an `ObjArg` must be a non-SAFE object
//! argument, a `u64` must be SAFE, …) and the tuple as a whole checks
//! arity — a spawn site and a body that disagree about the argument list
//! panic at the first execution instead of silently mis-reading ids. In
//! release builds extraction compiles down to plain indexed reads — with
//! one carve-out: [`Rest`] collects its tail into a `Vec`, so it
//! allocates once per body invocation that uses it (task bodies run once
//! per dispatch and already allocate freely; the no-allocation invariant
//! covers the simulator's per-event paths and the spawn path, not body
//! internals).
//!
//! Element types:
//!
//! * [`ObjArg`] (= [`ObjectId`]) — a non-SAFE object argument, any access
//!   mode.
//! * [`RegionArg`] (= [`RegionId`]) — a `TYPE_REGION_ARG` argument.
//! * `u64` / `usize` — a SAFE by-value scalar.
//! * [`OptObj`] — either an object argument or the SAFE sentinel `0`
//!   ("no object"), and also tolerates the argument being absent
//!   entirely (a trailing optional). Used for e.g. a stencil neighbour
//!   that the first/last band does not have.
//! * `Option<T>` — `None` if the argument list ended, otherwise `T`.
//! * [`Rest<T>`] — all remaining arguments, each extracted as `T`. Must
//!   be the last tuple element.

use crate::ids::{ObjectId, RegionId};
use crate::task::descriptor::TaskArg;

/// Typed view of a non-SAFE object argument.
pub type ObjArg = ObjectId;
/// Typed view of a region argument.
pub type RegionArg = RegionId;

/// One tuple element: consume argument(s) at `*cursor`, advancing it.
pub trait FromArg: Sized {
    fn from_arg(args: &[TaskArg], cursor: &mut usize) -> Self;
}

fn take<'a>(args: &'a [TaskArg], cursor: &mut usize) -> &'a TaskArg {
    let a = &args[*cursor];
    *cursor += 1;
    a
}

impl FromArg for ObjectId {
    fn from_arg(args: &[TaskArg], cursor: &mut usize) -> Self {
        let i = *cursor;
        let a = take(args, cursor);
        debug_assert!(
            !a.is_safe() && !a.is_region() && a.node.is_some(),
            "arg {i} is not an object argument (flags {:#x})",
            a.flags
        );
        ObjectId(a.value)
    }
}

impl FromArg for RegionId {
    fn from_arg(args: &[TaskArg], cursor: &mut usize) -> Self {
        let i = *cursor;
        let a = take(args, cursor);
        debug_assert!(a.is_region(), "arg {i} is not a region argument (flags {:#x})", a.flags);
        RegionId(a.value)
    }
}

impl FromArg for u64 {
    fn from_arg(args: &[TaskArg], cursor: &mut usize) -> Self {
        let i = *cursor;
        let a = take(args, cursor);
        debug_assert!(
            a.is_safe(),
            "arg {i} is not a SAFE by-value argument (flags {:#x})",
            a.flags
        );
        a.value
    }
}

impl FromArg for usize {
    fn from_arg(args: &[TaskArg], cursor: &mut usize) -> Self {
        u64::from_arg(args, cursor) as usize
    }
}

/// An object argument that may be "none": the spawn site passed either a
/// real object or the SAFE sentinel `0` (see
/// [`SpawnBuilder::obj_opt`](crate::api::spawn::SpawnBuilder::obj_opt)),
/// or omitted the trailing argument entirely.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct OptObj(pub Option<ObjectId>);

impl OptObj {
    pub fn get(self) -> Option<ObjectId> {
        self.0
    }

    pub fn is_none(self) -> bool {
        self.0.is_none()
    }
}

impl FromArg for OptObj {
    fn from_arg(args: &[TaskArg], cursor: &mut usize) -> Self {
        if *cursor >= args.len() {
            return OptObj(None);
        }
        let i = *cursor;
        let a = take(args, cursor);
        if a.is_safe() {
            debug_assert_eq!(a.value, 0, "arg {i}: SAFE optional-object sentinel must be 0");
            OptObj(None)
        } else {
            debug_assert!(
                !a.is_region() && a.node.is_some(),
                "arg {i} is neither an object nor the SAFE 0 sentinel (flags {:#x})",
                a.flags
            );
            OptObj(Some(ObjectId(a.value)))
        }
    }
}

impl<T: FromArg> FromArg for Option<T> {
    fn from_arg(args: &[TaskArg], cursor: &mut usize) -> Self {
        if *cursor >= args.len() {
            None
        } else {
            Some(T::from_arg(args, cursor))
        }
    }
}

/// All remaining arguments, each extracted as `T`. Must be the last
/// tuple element (anything after it fails the arity check).
#[derive(Clone, Debug)]
pub struct Rest<T>(pub Vec<T>);

impl<T> std::ops::Deref for Rest<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        &self.0
    }
}

impl<T: FromArg> FromArg for Rest<T> {
    fn from_arg(args: &[TaskArg], cursor: &mut usize) -> Self {
        let mut out = Vec::with_capacity(args.len() - *cursor);
        while *cursor < args.len() {
            out.push(T::from_arg(args, cursor));
        }
        Rest(out)
    }
}

/// A full argument tuple. Implemented for tuples of [`FromArg`] elements
/// up to arity 10; extraction is positional and, in debug builds, checks
/// that the tuple consumed the argument list exactly.
pub trait FromTaskArgs: Sized {
    fn from_task_args(args: &[TaskArg]) -> Self;
}

macro_rules! impl_from_task_args {
    ($($t:ident),+) => {
        impl<$($t: FromArg),+> FromTaskArgs for ($($t,)+) {
            fn from_task_args(args: &[TaskArg]) -> Self {
                let mut cursor = 0usize;
                let out = ($($t::from_arg(args, &mut cursor),)+);
                debug_assert_eq!(
                    cursor,
                    args.len(),
                    "task body extracted {cursor} of {} wire arguments",
                    args.len()
                );
                out
            }
        }
    };
}

impl_from_task_args!(A);
impl_from_task_args!(A, B);
impl_from_task_args!(A, B, C);
impl_from_task_args!(A, B, C, D);
impl_from_task_args!(A, B, C, D, E);
impl_from_task_args!(A, B, C, D, E, F);
impl_from_task_args!(A, B, C, D, E, F, G);
impl_from_task_args!(A, B, C, D, E, F, G, H);
impl_from_task_args!(A, B, C, D, E, F, G, H, I);
impl_from_task_args!(A, B, C, D, E, F, G, H, I, J);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extracts_mixed_tuple() {
        let args = vec![
            TaskArg::region_inout(RegionId(3)),
            TaskArg::obj_in(ObjectId(7)),
            TaskArg::val(42),
        ];
        let (r, o, v): (RegionArg, ObjArg, u64) = FromTaskArgs::from_task_args(&args);
        assert_eq!(r, RegionId(3));
        assert_eq!(o, ObjectId(7));
        assert_eq!(v, 42);
    }

    #[test]
    fn opt_obj_accepts_object_sentinel_and_absent() {
        let args = vec![TaskArg::obj_in(ObjectId(9)), TaskArg::val(0)];
        let (a, b, c): (OptObj, OptObj, OptObj) = FromTaskArgs::from_task_args(&args);
        assert_eq!(a.get(), Some(ObjectId(9)));
        assert_eq!(b.get(), None);
        assert_eq!(c.get(), None);
    }

    #[test]
    fn rest_collects_tail() {
        let args = vec![
            TaskArg::val(1),
            TaskArg::obj_in(ObjectId(4)),
            TaskArg::obj_in(ObjectId(5)),
            TaskArg::obj_in(ObjectId(6)),
        ];
        let (v, rest): (u64, Rest<ObjArg>) = FromTaskArgs::from_task_args(&args);
        assert_eq!(v, 1);
        assert_eq!(rest.0, vec![ObjectId(4), ObjectId(5), ObjectId(6)]);
    }

    #[test]
    fn trailing_option_is_none_when_absent() {
        let args = vec![TaskArg::val(8)];
        let (v, tail): (u64, Option<ObjArg>) = FromTaskArgs::from_task_args(&args);
        assert_eq!(v, 8);
        assert!(tail.is_none());
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "debug-only check")]
    #[should_panic(expected = "wire arguments")]
    fn arity_mismatch_panics_in_debug() {
        let args = vec![TaskArg::val(1), TaskArg::val(2), TaskArg::val(3)];
        let _: (u64, u64) = FromTaskArgs::from_task_args(&args);
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "debug-only check")]
    #[should_panic(expected = "not a region argument")]
    fn flag_mismatch_panics_in_debug() {
        let args = vec![TaskArg::obj_in(ObjectId(1))];
        let _: (RegionArg,) = FromTaskArgs::from_task_args(&args);
    }
}

//! The Myrmics application API (paper Fig 4): the wire-faithful task
//! context plus the typed spawn/args layer that lowers to it.
pub mod args;
pub mod ctx;
pub mod spawn;

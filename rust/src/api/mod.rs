//! The Myrmics application API (paper Fig 4).
pub mod ctx;

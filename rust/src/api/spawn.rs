//! Typed spawn and wait builders.
//!
//! `sys_spawn` on the wire is untyped (paper Fig 4: function-table index
//! plus flagged `(args, types)` arrays); the builder is the typed layer
//! that *lowers to* that format without exposing it. A task body spawns a
//! child through a chained builder:
//!
//! ```ignore
//! ctx.spawn_task(band_task)
//!     .reg_inout(group).notransfer()
//!     .obj_in(halo)
//!     .val(iter)
//!     .submit();
//! ```
//!
//! Every builder method appends exactly the [`TaskArg`] the corresponding
//! wire constructor would have produced (`obj_in(o)` ==
//! `TaskArg::obj_in(o)`, bit for bit — pinned by `tests/api_roundtrip.rs`),
//! so the resulting `TaskDesc` is byte-identical to a hand-assembled one.
//! Arguments are staged in a scratch buffer pooled inside the
//! [`TaskCtx`], so a body spawning many children reallocates nothing in
//! steady state; `submit` performs the single exact-sized allocation the
//! wire `TaskDesc` itself owns.
//!
//! [`WaitBuilder`] is the `sys_wait` counterpart. Its contract differs
//! from the raw `TaskCtx::wait` slice API in one important way: SAFE
//! by-value arguments have no dependency node and therefore *cannot be
//! waited on* — the builder only offers object/region methods, making the
//! mistake unrepresentable (the slice API debug-asserts instead).

use crate::ids::{NodeId, ObjectId, RegionId};
use crate::task::descriptor::{Access, TaskArg, TaskDesc};
use crate::task::registry::TaskRef;

use super::ctx::TaskCtx;

/// Chained builder for one `sys_spawn`. Created by
/// [`TaskCtx::spawn_task`]; dropped without [`submit`](Self::submit), it
/// spawns nothing.
pub struct SpawnBuilder<'c, 'w> {
    ctx: &'c mut TaskCtx<'w>,
    func: usize,
}

impl<'c, 'w> SpawnBuilder<'c, 'w> {
    pub(crate) fn new(ctx: &'c mut TaskCtx<'w>, func: TaskRef) -> Self {
        // A previous builder may have been abandoned mid-chain; its staged
        // args must not leak into this spawn.
        ctx.spawn_scratch.clear();
        SpawnBuilder { ctx, func: func.index() }
    }

    fn push(self, arg: TaskArg) -> Self {
        self.ctx.spawn_scratch.push(arg);
        self
    }

    /// Object argument, read-only access.
    pub fn obj_in(self, o: ObjectId) -> Self {
        self.push(TaskArg::obj_in(o))
    }

    /// Object argument, write-only access.
    pub fn obj_out(self, o: ObjectId) -> Self {
        self.push(TaskArg::obj_out(o))
    }

    /// Object argument, read-write access.
    pub fn obj_inout(self, o: ObjectId) -> Self {
        self.push(TaskArg::obj_inout(o))
    }

    /// Optional object argument: `Some(o)` is `obj_in(o)`, `None` is the
    /// SAFE sentinel `0`. The body-side counterpart is
    /// [`OptObj`](crate::api::args::OptObj).
    pub fn obj_opt(self, o: Option<ObjectId>) -> Self {
        match o {
            Some(o) => self.obj_in(o),
            None => self.val(0),
        }
    }

    /// Region argument, read-only access.
    pub fn reg_in(self, r: RegionId) -> Self {
        self.push(TaskArg::region_in(r))
    }

    /// Region argument, read-write access.
    pub fn reg_inout(self, r: RegionId) -> Self {
        self.push(TaskArg::region_inout(r))
    }

    /// SAFE by-value scalar (no dependency analysis, no transfer).
    pub fn val(self, v: u64) -> Self {
        self.push(TaskArg::val(v))
    }

    /// Mark the *most recently added* argument NOTRANSFER: dependency
    /// semantics apply but no DMA is performed (paper V-A — tasks that
    /// only spawn subtasks over a region).
    pub fn notransfer(self) -> Self {
        let last = self
            .ctx
            .spawn_scratch
            .last_mut()
            .expect("notransfer() before any argument");
        debug_assert!(!last.is_safe(), "notransfer() on a SAFE by-value argument");
        last.flags |= crate::task::descriptor::TYPE_NOTRANSFER_ARG;
        self
    }

    /// Wire-level escape hatch: append a pre-built [`TaskArg`] verbatim.
    pub fn arg(self, a: TaskArg) -> Self {
        self.push(a)
    }

    /// Lower to the Fig-4 wire format and record the spawn. The staged
    /// arguments become the `TaskDesc`'s exact-sized `args` vector; the
    /// pooled scratch buffer is retained for the body's next spawn.
    pub fn submit(self) {
        let args: Vec<TaskArg> = self.ctx.spawn_scratch.as_slice().to_vec();
        self.ctx.spawn_scratch.clear();
        let desc = TaskDesc::new(self.func, args);
        self.ctx.push_spawn(desc);
    }
}

/// Chained builder for one `sys_wait`. Created by [`TaskCtx::wait_on`].
///
/// Contract: a wait list names *dependency nodes* (objects or regions)
/// the suspended task wants exclusive/shared access to again. SAFE
/// by-value arguments have no node and are not expressible here. The
/// body should return right after [`wait`](Self::wait); it is re-invoked
/// with `phase() + 1` once the waited subtrees quiesce.
pub struct WaitBuilder<'c, 'w> {
    ctx: &'c mut TaskCtx<'w>,
    nodes: Vec<(NodeId, Access)>,
}

impl<'c, 'w> WaitBuilder<'c, 'w> {
    pub(crate) fn new(ctx: &'c mut TaskCtx<'w>) -> Self {
        WaitBuilder { ctx, nodes: Vec::new() }
    }

    fn push(mut self, node: NodeId, access: Access) -> Self {
        self.nodes.push((node, access));
        self
    }

    /// Wait to re-acquire `o` read-write.
    pub fn obj_inout(self, o: ObjectId) -> Self {
        self.push(NodeId::Object(o), Access::Write)
    }

    /// Wait to re-acquire `o` read-only.
    pub fn obj_in(self, o: ObjectId) -> Self {
        self.push(NodeId::Object(o), Access::Read)
    }

    /// Wait to re-acquire region `r` read-write.
    pub fn reg_inout(self, r: RegionId) -> Self {
        self.push(NodeId::Region(r), Access::Write)
    }

    /// Wait to re-acquire region `r` read-only.
    pub fn reg_in(self, r: RegionId) -> Self {
        self.push(NodeId::Region(r), Access::Read)
    }

    /// Record the `sys_wait`. The body should return immediately after.
    pub fn wait(self) {
        debug_assert!(!self.nodes.is_empty(), "sys_wait with an empty wait list");
        self.ctx.push_wait(self.nodes);
    }
}

//! The Myrmics application API (paper Fig 4) as seen by task bodies.
//!
//! # Execution model: eager functional, replayed timing
//!
//! A task body is plain Rust. When a worker starts a task, the body runs
//! *eagerly* against the shared [`World`] — allocations return real ids,
//! data reads see what producers wrote (dependency grants guarantee the
//! producers completed earlier in virtual time). While running, the body
//! records an **op list**: compute charges, memory-API round trips, spawns
//! and waits. The worker then *replays* the ops in virtual time — each RPC
//! becomes a real worker->scheduler(s) message chain that charges the
//! schedulers on the route and suspends the replay until the reply — so
//! contention, saturation and message traffic are all modeled faithfully
//! while application code stays straight-line.
//!
//! `sys_wait` splits a body into phases: the body is re-invoked with
//! `phase() + 1` once the waited subtrees quiesce, so code after a wait
//! sees data its children produced.

use crate::ids::{Cycles, NodeId, ObjectId, RegionId, TaskId};
use crate::noc::msg::MemOpKind;
use crate::platform::World;
use crate::task::descriptor::{Access, TaskArg, TaskDesc};

/// One step of a task's timing replay.
#[derive(Clone, Debug)]
pub enum TaskOp {
    /// Busy compute for this many (MicroBlaze) cycles.
    Compute(Cycles),
    /// Memory-API round trip to the owner scheduler (functional result
    /// already applied; this replays the message chain + service costs).
    Rpc { owner: usize, op: MemOpKind },
    /// Spawn a child task (synchronous: replay waits for the ack).
    Spawn(TaskDesc),
    /// `sys_wait` on the given nodes; replay resumes at the next phase.
    Wait(Vec<(NodeId, Access)>),
}

/// Handle given to task bodies.
pub struct TaskCtx<'w> {
    pub world: &'w mut World,
    pub task: TaskId,
    pub worker: crate::ids::CoreId,
    phase: u32,
    args: Vec<TaskArg>,
    ops: Vec<TaskOp>,
}

impl<'w> TaskCtx<'w> {
    pub fn new(
        world: &'w mut World,
        task: TaskId,
        worker: crate::ids::CoreId,
        phase: u32,
        args: Vec<TaskArg>,
    ) -> Self {
        TaskCtx { world, task, worker, phase, args, ops: Vec::new() }
    }

    pub fn into_ops(self) -> Vec<TaskOp> {
        self.ops
    }

    /// Which `sys_wait` phase this invocation is (0 = first).
    pub fn phase(&self) -> u32 {
        self.phase
    }

    // ------------------------------------------------------------ arguments

    pub fn n_args(&self) -> usize {
        self.args.len()
    }

    pub fn arg(&self, i: usize) -> &TaskArg {
        &self.args[i]
    }

    /// Value of a SAFE by-value argument.
    pub fn val_arg(&self, i: usize) -> u64 {
        self.args[i].value
    }

    pub fn region_arg(&self, i: usize) -> RegionId {
        debug_assert!(self.args[i].is_region(), "arg {i} is not a region");
        RegionId(self.args[i].value)
    }

    pub fn obj_arg(&self, i: usize) -> ObjectId {
        debug_assert!(
            !self.args[i].is_region() && self.args[i].node.is_some(),
            "arg {i} is not an object"
        );
        ObjectId(self.args[i].value)
    }

    // ---------------------------------------------------- memory management

    /// `sys_ralloc(parent, lvl)`.
    pub fn ralloc(&mut self, parent: RegionId, lvl: i32) -> RegionId {
        let w = &mut *self.world;
        let owner = w.mem.owner(NodeId::Region(parent));
        let r = w.mem.ralloc(parent, lvl, &w.hier);
        self.world.gstats.regions_created += 1;
        self.ops.push(TaskOp::Rpc { owner, op: MemOpKind::Ralloc });
        r
    }

    /// `sys_rfree(r)`: recursively destroy a region.
    pub fn rfree(&mut self, r: RegionId) {
        let owner = self.world.mem.owner(NodeId::Region(r));
        let destroyed = self.world.mem.rfree(r);
        for n in &destroyed {
            self.world.dep.retire(*n);
            if let NodeId::Object(o) = n {
                self.world.store.remove(*o);
            }
        }
        self.ops.push(TaskOp::Rpc { owner, op: MemOpKind::Rfree { nodes: destroyed.len() as u32 } });
    }

    /// `sys_alloc(size, r)`.
    pub fn alloc(&mut self, size: u64, r: RegionId) -> ObjectId {
        let owner = self.world.mem.owner(NodeId::Region(r));
        let o = self.world.mem.alloc(size, r);
        self.world.gstats.objects_created += 1;
        self.ops.push(TaskOp::Rpc { owner, op: MemOpKind::Alloc });
        o
    }

    /// `sys_balloc(size, r, num)`: bulk allocation, one round trip.
    pub fn balloc(&mut self, size: u64, r: RegionId, num: usize) -> Vec<ObjectId> {
        let owner = self.world.mem.owner(NodeId::Region(r));
        let objs = self.world.mem.balloc(size, r, num);
        self.world.gstats.objects_created += num as u64;
        self.ops.push(TaskOp::Rpc { owner, op: MemOpKind::Balloc { n: num as u32 } });
        objs
    }

    /// `sys_free(o)`.
    pub fn free(&mut self, o: ObjectId) {
        let owner = self.world.mem.owner(NodeId::Object(o));
        self.world.dep.retire(NodeId::Object(o));
        self.world.store.remove(o);
        let ok = self.world.mem.free(o);
        debug_assert!(ok, "double free of {o}");
        self.ops.push(TaskOp::Rpc { owner, op: MemOpKind::Free });
    }

    /// `sys_realloc(o, size, new_r)`.
    pub fn realloc(&mut self, o: ObjectId, size: u64, new_r: RegionId) {
        let owner = self.world.mem.owner(NodeId::Object(o));
        self.world.mem.realloc(o, size, new_r);
        self.ops.push(TaskOp::Rpc { owner, op: MemOpKind::Realloc });
    }

    // ------------------------------------------------------ task management

    /// `sys_spawn(idx, args, types)`.
    pub fn spawn(&mut self, func: usize, args: Vec<TaskArg>) {
        self.ops.push(TaskOp::Spawn(TaskDesc::new(func, args)));
    }

    /// `sys_wait(args, types)`: suspend until the listed arguments are
    /// again exclusively available to this task. The body should return
    /// right after calling this; it will be re-invoked with `phase()+1`.
    pub fn wait(&mut self, args: &[TaskArg]) {
        let nodes: Vec<(NodeId, Access)> = args
            .iter()
            .filter(|a| !a.is_safe())
            .map(|a| (a.node.expect("wait arg without node"), a.access()))
            .collect();
        self.ops.push(TaskOp::Wait(nodes));
    }

    // ------------------------------------------------------------- compute

    /// Model `cycles` of task computation.
    pub fn compute(&mut self, cycles: Cycles) {
        self.ops.push(TaskOp::Compute(cycles));
    }

    // ------------------------------------------------------------ real data

    pub fn write_f32(&mut self, o: ObjectId, data: &[f32]) {
        self.world.store.put_f32(o, data);
    }

    pub fn read_f32(&self, o: ObjectId) -> Vec<f32> {
        self.world.store.get_f32(o).unwrap_or_else(|| panic!("no data for {o}"))
    }

    pub fn try_read_f32(&self, o: ObjectId) -> Option<Vec<f32>> {
        self.world.store.get_f32(o)
    }

    pub fn write_u32(&mut self, o: ObjectId, data: &[u32]) {
        self.world.store.put_u32(o, data);
    }

    pub fn read_u32(&self, o: ObjectId) -> Vec<u32> {
        self.world.store.get_u32(o).unwrap_or_else(|| panic!("no data for {o}"))
    }

    /// Is the platform running with real (PJRT) kernels attached?
    pub fn real_compute(&self) -> bool {
        self.world.kernels.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::task::descriptor::TaskDesc;

    fn world() -> World {
        World::new(PlatformConfig::hierarchical(32))
    }

    fn mkctx(w: &mut World) -> TaskCtx<'_> {
        let t = w.tasks.create(TaskDesc::new(0, vec![]), None, 0, 0);
        TaskCtx::new(w, t, crate::ids::CoreId(1), 0, vec![])
    }

    #[test]
    fn api_calls_record_rpcs() {
        let mut w = world();
        let mut ctx = mkctx(&mut w);
        let r = ctx.ralloc(RegionId::ROOT, 1);
        let o = ctx.alloc(256, r);
        let objs = ctx.balloc(64, r, 10);
        ctx.free(o);
        ctx.compute(1000);
        ctx.spawn(0, vec![TaskArg::obj_in(objs[0])]);
        let ops = ctx.into_ops();
        assert_eq!(ops.len(), 6);
        assert!(matches!(ops[0], TaskOp::Rpc { op: MemOpKind::Ralloc, .. }));
        assert!(matches!(ops[1], TaskOp::Rpc { op: MemOpKind::Alloc, .. }));
        assert!(matches!(ops[2], TaskOp::Rpc { op: MemOpKind::Balloc { n: 10 }, .. }));
        assert!(matches!(ops[3], TaskOp::Rpc { op: MemOpKind::Free, .. }));
        assert!(matches!(ops[4], TaskOp::Compute(1000)));
        assert!(matches!(ops[5], TaskOp::Spawn(_)));
        assert_eq!(w.mem.n_objects(), 10);
    }

    #[test]
    fn rfree_retires_dep_nodes_and_data() {
        let mut w = world();
        let mut ctx = mkctx(&mut w);
        let r = ctx.ralloc(RegionId::ROOT, 1);
        let o = ctx.alloc(64, r);
        ctx.write_f32(o, &[1.0, 2.0]);
        assert_eq!(ctx.read_f32(o), vec![1.0, 2.0]);
        ctx.rfree(r);
        let ops = ctx.into_ops();
        assert!(matches!(ops.last(), Some(TaskOp::Rpc { op: MemOpKind::Rfree { nodes: 2 }, .. })));
        assert!(!w.mem.exists(NodeId::Region(r)));
        assert!(w.store.get(o).is_none());
    }

    #[test]
    fn wait_collects_dep_nodes_only() {
        let mut w = world();
        let mut ctx = mkctx(&mut w);
        let r = ctx.ralloc(RegionId::ROOT, 0);
        ctx.wait(&[TaskArg::region_inout(r), TaskArg::val(7)]);
        let ops = ctx.into_ops();
        match &ops[1] {
            TaskOp::Wait(nodes) => {
                assert_eq!(nodes.len(), 1);
                assert_eq!(nodes[0], (NodeId::Region(r), Access::Write));
            }
            other => panic!("expected Wait, got {other:?}"),
        }
    }

    #[test]
    fn ralloc_rpc_targets_parent_owner() {
        let mut w = world();
        let mut ctx = mkctx(&mut w);
        // Parent is the root region, owned by scheduler 0.
        ctx.ralloc(RegionId::ROOT, 1);
        let ops = ctx.into_ops();
        assert!(matches!(ops[0], TaskOp::Rpc { owner: 0, .. }));
    }
}

//! The Myrmics application API (paper Fig 4) as seen by task bodies.
//!
//! # Execution model: eager functional, replayed timing
//!
//! A task body is plain Rust. When a worker starts a task, the body runs
//! *eagerly* against the shared [`World`] — allocations return real ids,
//! data reads see what producers wrote (dependency grants guarantee the
//! producers completed earlier in virtual time). While running, the body
//! records an **op list**: compute charges, memory-API round trips, spawns
//! and waits. The worker then *replays* the ops in virtual time — each RPC
//! becomes a real worker->scheduler(s) message chain that charges the
//! schedulers on the route and suspends the replay until the reply — so
//! contention, saturation and message traffic are all modeled faithfully
//! while application code stays straight-line.
//!
//! `sys_wait` splits a body into phases: the body is re-invoked with
//! `phase() + 1` once the waited subtrees quiesce, so code after a wait
//! sees data its children produced.
//!
//! # Typed layer over the Fig-4 wire format
//!
//! The paper's `sys_spawn(idx, args, types)` names tasks by a raw
//! function-table index and passes untyped flagged argument arrays. That
//! wire format is preserved unchanged ([`TaskDesc`] `{func, args}`), but
//! application code never touches it directly:
//!
//! * spawning goes through the chained [`SpawnBuilder`] —
//!   `ctx.spawn_task(f).reg_inout(r).notransfer().val(i).submit()` —
//!   which stages arguments in a pooled scratch buffer and lowers to a
//!   byte-identical `TaskDesc` on submit;
//! * bodies unpack their arguments with the typed extractor —
//!   `let (r, o, i): (RegionArg, ObjArg, u64) = ctx.args();` — which
//!   flag/arity-checks in debug builds (see [`crate::api::args`]);
//! * waiting goes through [`WaitBuilder`] (`ctx.wait_on()`), which only
//!   admits dependency nodes, never SAFE by-value scalars.
//!
//! See `docs/app-api.md` for the full tour and how to add a workload.

use std::sync::Arc;

use crate::api::args::FromTaskArgs;
use crate::api::spawn::{SpawnBuilder, WaitBuilder};
use crate::ids::{Cycles, NodeId, ObjectId, RegionId, TaskId};
use crate::noc::msg::MemOpKind;
use crate::platform::World;
use crate::task::descriptor::{Access, TaskArg, TaskDesc};
use crate::task::registry::TaskRef;

/// One step of a task's timing replay.
#[derive(Clone, Debug)]
pub enum TaskOp {
    /// Busy compute for this many (MicroBlaze) cycles.
    Compute(Cycles),
    /// Memory-API round trip to the owner scheduler (functional result
    /// already applied; this replays the message chain + service costs).
    Rpc { owner: usize, op: MemOpKind },
    /// Spawn a child task (synchronous: replay waits for the ack).
    Spawn(TaskDesc),
    /// `sys_wait` on the given nodes; replay resumes at the next phase.
    Wait(Vec<(NodeId, Access)>),
}

/// Handle given to task bodies.
pub struct TaskCtx<'w> {
    pub world: &'w mut World,
    pub task: TaskId,
    pub worker: crate::ids::CoreId,
    phase: u32,
    /// The task's own descriptor (shared with the task table — no copy).
    desc: Arc<TaskDesc>,
    ops: Vec<TaskOp>,
    /// Pooled assembly buffer for [`SpawnBuilder`]: grows to the widest
    /// argument list once, then spawning is allocation-free up to the
    /// final exact-sized `TaskDesc` vector.
    pub(crate) spawn_scratch: Vec<TaskArg>,
}

impl<'w> TaskCtx<'w> {
    pub fn new(
        world: &'w mut World,
        task: TaskId,
        worker: crate::ids::CoreId,
        phase: u32,
        desc: Arc<TaskDesc>,
    ) -> Self {
        TaskCtx { world, task, worker, phase, desc, ops: Vec::new(), spawn_scratch: Vec::new() }
    }

    pub fn into_ops(self) -> Vec<TaskOp> {
        self.ops
    }

    /// Which `sys_wait` phase this invocation is (0 = first).
    pub fn phase(&self) -> u32 {
        self.phase
    }

    // ------------------------------------------------------------ arguments

    /// Unpack the task's arguments as a typed tuple (see
    /// [`crate::api::args`]). Debug builds check flags and arity against
    /// the wire descriptor; release builds are plain reads.
    pub fn args<T: FromTaskArgs>(&self) -> T {
        T::from_task_args(&self.desc.args)
    }

    /// Wire-level argument count (typed bodies rarely need this).
    pub fn n_args(&self) -> usize {
        self.desc.args.len()
    }

    /// Wire-level view of one argument (typed bodies rarely need this).
    pub fn arg(&self, i: usize) -> &TaskArg {
        &self.desc.args[i]
    }

    // ---------------------------------------------------- memory management

    /// `sys_ralloc(parent, lvl)`.
    pub fn ralloc(&mut self, parent: RegionId, lvl: i32) -> RegionId {
        let w = &mut *self.world;
        let owner = w.mem.owner(NodeId::Region(parent));
        let r = w.mem.ralloc(parent, lvl, &w.hier);
        self.world.gstats.regions_created += 1;
        self.ops.push(TaskOp::Rpc { owner, op: MemOpKind::Ralloc });
        r
    }

    /// `sys_rfree(r)`: recursively destroy a region.
    pub fn rfree(&mut self, r: RegionId) {
        let owner = self.world.mem.owner(NodeId::Region(r));
        let destroyed = self.world.mem.rfree(r);
        for n in &destroyed {
            self.world.dep.retire(*n);
            if let NodeId::Object(o) = n {
                self.world.store.remove(*o);
            }
        }
        self.ops.push(TaskOp::Rpc { owner, op: MemOpKind::Rfree { nodes: destroyed.len() as u32 } });
    }

    /// `sys_alloc(size, r)`.
    pub fn alloc(&mut self, size: u64, r: RegionId) -> ObjectId {
        let owner = self.world.mem.owner(NodeId::Region(r));
        let o = self.world.mem.alloc(size, r);
        self.world.gstats.objects_created += 1;
        self.ops.push(TaskOp::Rpc { owner, op: MemOpKind::Alloc });
        o
    }

    /// `sys_balloc(size, r, num)`: bulk allocation, one round trip.
    pub fn balloc(&mut self, size: u64, r: RegionId, num: usize) -> Vec<ObjectId> {
        let owner = self.world.mem.owner(NodeId::Region(r));
        let objs = self.world.mem.balloc(size, r, num);
        self.world.gstats.objects_created += num as u64;
        self.ops.push(TaskOp::Rpc { owner, op: MemOpKind::Balloc { n: num as u32 } });
        objs
    }

    /// `sys_free(o)`.
    pub fn free(&mut self, o: ObjectId) {
        let owner = self.world.mem.owner(NodeId::Object(o));
        self.world.dep.retire(NodeId::Object(o));
        self.world.store.remove(o);
        let ok = self.world.mem.free(o);
        debug_assert!(ok, "double free of {o}");
        self.ops.push(TaskOp::Rpc { owner, op: MemOpKind::Free });
    }

    /// `sys_realloc(o, size, new_r)`.
    pub fn realloc(&mut self, o: ObjectId, size: u64, new_r: RegionId) {
        let owner = self.world.mem.owner(NodeId::Object(o));
        self.world.mem.realloc(o, size, new_r);
        self.ops.push(TaskOp::Rpc { owner, op: MemOpKind::Realloc });
    }

    // ------------------------------------------------------ task management

    /// `sys_spawn`, typed: start a chained [`SpawnBuilder`] for task `f`.
    /// Chain argument methods in wire order, then call `submit()`.
    pub fn spawn_task(&mut self, f: TaskRef) -> SpawnBuilder<'_, 'w> {
        SpawnBuilder::new(self, f)
    }

    /// `sys_wait`, typed: start a chained [`WaitBuilder`]. The body should
    /// return right after the builder's `wait()`; it will be re-invoked
    /// with `phase() + 1`.
    pub fn wait_on(&mut self) -> WaitBuilder<'_, 'w> {
        WaitBuilder::new(self)
    }

    /// Wire-level `sys_wait(args, types)`: suspend until the listed
    /// arguments are again exclusively available to this task.
    ///
    /// Contract: every entry must be a dependency-carrying argument. SAFE
    /// by-value arguments have no dependency node and cannot be waited on
    /// — passing one is a bug (debug builds assert; release builds skip
    /// it). Prefer [`TaskCtx::wait_on`], which makes the mistake
    /// unrepresentable.
    pub fn wait(&mut self, args: &[TaskArg]) {
        debug_assert!(
            args.iter().all(|a| !a.is_safe()),
            "SAFE by-value argument in a sys_wait list (no dependency node to wait on)"
        );
        let nodes: Vec<(NodeId, Access)> = args
            .iter()
            .filter(|a| !a.is_safe())
            .map(|a| (a.node.expect("wait arg without node"), a.access()))
            .collect();
        self.ops.push(TaskOp::Wait(nodes));
    }

    pub(crate) fn push_spawn(&mut self, desc: TaskDesc) {
        self.ops.push(TaskOp::Spawn(desc));
    }

    pub(crate) fn push_wait(&mut self, nodes: Vec<(NodeId, Access)>) {
        self.ops.push(TaskOp::Wait(nodes));
    }

    // ------------------------------------------------------------- compute

    /// Model `cycles` of task computation.
    pub fn compute(&mut self, cycles: Cycles) {
        self.ops.push(TaskOp::Compute(cycles));
    }

    // ------------------------------------------------------------ real data

    pub fn write_f32(&mut self, o: ObjectId, data: &[f32]) {
        self.world.store.put_f32(o, data);
    }

    pub fn read_f32(&self, o: ObjectId) -> Vec<f32> {
        self.world.store.get_f32(o).unwrap_or_else(|| panic!("no data for {o}"))
    }

    pub fn try_read_f32(&self, o: ObjectId) -> Option<Vec<f32>> {
        self.world.store.get_f32(o)
    }

    pub fn write_u32(&mut self, o: ObjectId, data: &[u32]) {
        self.world.store.put_u32(o, data);
    }

    pub fn read_u32(&self, o: ObjectId) -> Vec<u32> {
        self.world.store.get_u32(o).unwrap_or_else(|| panic!("no data for {o}"))
    }

    /// Is the platform running with real (PJRT) kernels attached?
    pub fn real_compute(&self) -> bool {
        self.world.kernels.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::task::descriptor::TaskDesc;
    use crate::task::registry::TaskRef;

    fn world() -> World {
        World::new(PlatformConfig::hierarchical(32))
    }

    fn mkctx(w: &mut World) -> TaskCtx<'_> {
        let t = w.tasks.create(TaskDesc::new(0, vec![]), None, 0, 0);
        let desc = w.tasks.get(t).desc.clone();
        TaskCtx::new(w, t, crate::ids::CoreId(1), 0, desc)
    }

    #[test]
    fn api_calls_record_rpcs() {
        let mut w = world();
        let mut ctx = mkctx(&mut w);
        let r = ctx.ralloc(RegionId::ROOT, 1);
        let o = ctx.alloc(256, r);
        let objs = ctx.balloc(64, r, 10);
        ctx.free(o);
        ctx.compute(1000);
        ctx.spawn_task(TaskRef::from_index(0)).obj_in(objs[0]).submit();
        let ops = ctx.into_ops();
        assert_eq!(ops.len(), 6);
        assert!(matches!(ops[0], TaskOp::Rpc { op: MemOpKind::Ralloc, .. }));
        assert!(matches!(ops[1], TaskOp::Rpc { op: MemOpKind::Alloc, .. }));
        assert!(matches!(ops[2], TaskOp::Rpc { op: MemOpKind::Balloc { n: 10 }, .. }));
        assert!(matches!(ops[3], TaskOp::Rpc { op: MemOpKind::Free, .. }));
        assert!(matches!(ops[4], TaskOp::Compute(1000)));
        assert!(matches!(ops[5], TaskOp::Spawn(_)));
        assert_eq!(w.mem.n_objects(), 10);
    }

    #[test]
    fn rfree_retires_dep_nodes_and_data() {
        let mut w = world();
        let mut ctx = mkctx(&mut w);
        let r = ctx.ralloc(RegionId::ROOT, 1);
        let o = ctx.alloc(64, r);
        ctx.write_f32(o, &[1.0, 2.0]);
        assert_eq!(ctx.read_f32(o), vec![1.0, 2.0]);
        ctx.rfree(r);
        let ops = ctx.into_ops();
        assert!(matches!(ops.last(), Some(TaskOp::Rpc { op: MemOpKind::Rfree { nodes: 2 }, .. })));
        assert!(!w.mem.exists(NodeId::Region(r)));
        assert!(w.store.get(o).is_none());
    }

    #[test]
    fn wait_builder_collects_dep_nodes() {
        let mut w = world();
        let mut ctx = mkctx(&mut w);
        let r = ctx.ralloc(RegionId::ROOT, 0);
        let o = ctx.alloc(64, r);
        ctx.wait_on().reg_inout(r).obj_in(o).wait();
        let ops = ctx.into_ops();
        match &ops[2] {
            TaskOp::Wait(nodes) => {
                assert_eq!(nodes.len(), 2);
                assert_eq!(nodes[0], (NodeId::Region(r), Access::Write));
                assert_eq!(nodes[1], (NodeId::Object(o), Access::Read));
            }
            other => panic!("expected Wait, got {other:?}"),
        }
    }

    #[test]
    #[cfg_attr(not(debug_assertions), ignore = "debug-only check")]
    #[should_panic(expected = "SAFE by-value argument in a sys_wait list")]
    fn slice_wait_with_safe_arg_panics_in_debug() {
        let mut w = world();
        let mut ctx = mkctx(&mut w);
        let r = ctx.ralloc(RegionId::ROOT, 0);
        ctx.wait(&[TaskArg::region_inout(r), TaskArg::val(7)]);
    }

    #[test]
    fn ralloc_rpc_targets_parent_owner() {
        let mut w = world();
        let mut ctx = mkctx(&mut w);
        // Parent is the root region, owned by scheduler 0.
        ctx.ralloc(RegionId::ROOT, 1);
        let ops = ctx.into_ops();
        assert!(matches!(ops[0], TaskOp::Rpc { owner: 0, .. }));
    }
}

//! Dependency queues, child counters and parent (race-avoidance) counters
//! — the per-node state of paper V-D / Fig 5.
//!
//! Every object and region with dependency activity has a [`DepNode`]:
//!
//! * an in-order *dependency queue* of tasks waiting for (or currently
//!   granted) access at this node;
//! * *child counters* `cr`/`cw`: how many live argument instances are
//!   enqueued or granted somewhere strictly below this region (split by
//!   read/write so concurrent readers can be optimized, as the paper
//!   notes);
//! * *parent counters* `pr_recv`/`pw_recv`: cumulative enqueues that ever
//!   crossed into this node from its parent — the race-avoidance protocol:
//!   a quiescence report carries them, and the parent ignores the report
//!   unless they match its own cumulative send counts.
//!
//! The grant rule (serial-equivalence preserving): an entry may be granted
//! (or a traversal may pass through) when every entry ahead of it is a
//! granted entry of an *ancestor task* (a parent delegating a subset to a
//! child) or a compatible granted reader; region grants additionally
//! require the child counters to be compatible (writers need `cr == cw ==
//! 0`, readers need `cw == 0`).

use std::collections::{BTreeMap, VecDeque};

use crate::ids::{Cycles, NodeId, TaskId};
use crate::task::descriptor::Access;

/// One queued argument instance. `Copy`: five words, no heap — the queue
/// re-scan copies entries out instead of cloning.
#[derive(Clone, Copy, Debug)]
pub struct DepEntry {
    pub task: TaskId,
    /// Argument index within the task's descriptor.
    pub arg: usize,
    pub mode: Access,
    /// The node this instance ultimately wants (== the node it is queued
    /// on once it arrives; an earlier node while it is blocked mid-path).
    pub target: NodeId,
    pub granted: bool,
}

/// What a queue re-evaluation decided (the caller owns messaging/IO).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ReadyAction {
    /// Entry (task, arg) reached its target here and is now granted.
    Grant { task: TaskId, arg: usize },
    /// Entry was unblocked and must resume its downward traversal from
    /// this node towards `target`.
    Resume { task: TaskId, arg: usize, mode: Access, target: NodeId },
}

#[derive(Debug)]
pub struct DepNode {
    pub id: NodeId,
    /// Region-tree parent at creation time (kept here so teardown works
    /// even after the memory metadata is freed).
    pub parent: Option<NodeId>,
    /// Owning scheduler index (owners never migrate).
    pub owner: usize,
    pub queue: VecDeque<DepEntry>,
    /// Live descendant readers/writers (regions only).
    pub cr: u64,
    pub cw: u64,
    /// Cumulative enqueues received from the parent link.
    pub pr_recv: u64,
    pub pw_recv: u64,
    /// Cumulative enqueues sent down each child link.
    pub sent_r: BTreeMap<NodeId, u64>,
    pub sent_w: BTreeMap<NodeId, u64>,
    /// Cumulative enqueues already acknowledged per child link (via
    /// matched quiescence reports).
    pub acked_r: BTreeMap<NodeId, u64>,
    pub acked_w: BTreeMap<NodeId, u64>,
    /// `sys_wait` registrations: tasks waiting for this subtree to drain.
    pub waiters: Vec<(TaskId, Access)>,
    /// Last pr / pw included in a quiescence report, to avoid duplicate
    /// decrements at the parent (separate channels per access mode: the
    /// paper's "separate child counters ... so we can optimize for
    /// multiple tasks to have access to read-only arguments").
    pub last_quiesce_r: Option<u64>,
    pub last_quiesce_w: Option<u64>,
    /// Region was freed while entries were still draining; remove this
    /// node once it quiesces.
    pub dying: bool,
    /// Timestamp of the last grant (profiling aid).
    pub last_grant_at: Cycles,
}

impl DepNode {
    pub fn new(id: NodeId, parent: Option<NodeId>, owner: usize) -> Self {
        DepNode {
            id,
            parent,
            owner,
            queue: VecDeque::new(),
            cr: 0,
            cw: 0,
            pr_recv: 0,
            pw_recv: 0,
            sent_r: BTreeMap::new(),
            sent_w: BTreeMap::new(),
            acked_r: BTreeMap::new(),
            acked_w: BTreeMap::new(),
            waiters: Vec::new(),
            last_quiesce_r: None,
            last_quiesce_w: None,
            dying: false,
            last_grant_at: 0,
        }
    }

    /// Counter compatibility for granting `mode` at this node.
    pub fn counters_ok(&self, mode: Access) -> bool {
        match mode {
            Access::Write => self.cr == 0 && self.cw == 0,
            Access::Read => self.cw == 0,
        }
    }

    /// Queue position preserving *serial program order*: a descendant of a
    /// granted holder belongs inside that ancestor's subtree window (right
    /// after the last entry of the same subtree), ahead of unrelated
    /// entries that were appended later but come after the whole subtree
    /// in serial order. Unrelated tasks append at the tail.
    pub fn insertion_point(
        &self,
        task: TaskId,
        is_ancestor: &dyn Fn(TaskId, TaskId) -> bool,
    ) -> usize {
        let Some(i) = self
            .queue
            .iter()
            .rposition(|x| x.granted && is_ancestor(x.task, task))
        else {
            return self.queue.len();
        };
        let a = self.queue[i].task;
        let mut j = i + 1;
        while j < self.queue.len()
            && (self.queue[j].task == a || is_ancestor(a, self.queue[j].task))
        {
            j += 1;
        }
        j
    }

    /// May a traversal of (`task`, `mode`) pass through this node without
    /// enqueueing? True iff every entry *ahead of its serial position* is
    /// granted and either an ancestor of `task` (delegation) or a
    /// compatible reader.
    pub fn can_pass(
        &self,
        task: TaskId,
        mode: Access,
        is_ancestor: &dyn Fn(TaskId, TaskId) -> bool,
    ) -> bool {
        let j = self.insertion_point(task, is_ancestor);
        self.queue.iter().take(j).all(|e| {
            e.granted && (is_ancestor(e.task, task) || e.mode.compatible(mode))
        })
    }

    /// Record an instance crossing from this node down the `child` link.
    pub fn note_descent(&mut self, child: NodeId, mode: Access) {
        match mode {
            Access::Read => {
                self.cr += 1;
                *self.sent_r.entry(child).or_insert(0) += 1;
            }
            Access::Write => {
                self.cw += 1;
                *self.sent_w.entry(child).or_insert(0) += 1;
            }
        }
    }

    /// Record an instance arriving from the parent link.
    pub fn note_arrival(&mut self, mode: Access) {
        match mode {
            Access::Read => self.pr_recv += 1,
            Access::Write => self.pw_recv += 1,
        }
    }

    /// Enqueue a (non-granted) entry at its serial-order position (see
    /// [`DepNode::insertion_point`]).
    pub fn enqueue(
        &mut self,
        task: TaskId,
        arg: usize,
        mode: Access,
        target: NodeId,
        is_ancestor: &dyn Fn(TaskId, TaskId) -> bool,
    ) {
        let j = self.insertion_point(task, is_ancestor);
        self.queue.insert(j, DepEntry { task, arg, mode, target, granted: false });
    }

    /// Push an already-granted entry (used to bootstrap the main task).
    pub fn enqueue_granted(&mut self, task: TaskId, arg: usize, mode: Access) {
        let target = self.id;
        self.queue.push_back(DepEntry { task, arg, mode, target, granted: true });
    }

    /// Remove `task`'s entry (granted or not). Returns true if found.
    pub fn pop_task(&mut self, task: TaskId, arg: usize) -> bool {
        if let Some(pos) = self.queue.iter().position(|e| e.task == task && e.arg == arg) {
            self.queue.remove(pos);
            true
        } else {
            false
        }
    }

    /// Re-scan the queue in order, granting / resuming everything that is
    /// no longer blocked. Stops at the first entry that must keep waiting.
    /// Allocating wrapper around [`DepNode::collect_ready_into`].
    pub fn collect_ready(
        &mut self,
        is_ancestor: &dyn Fn(TaskId, TaskId) -> bool,
    ) -> Vec<ReadyAction> {
        let mut out = Vec::new();
        self.collect_ready_into(is_ancestor, &mut out);
        out
    }

    /// Like [`DepNode::collect_ready`] but appends into a caller-owned
    /// buffer (the scheduler keeps a small pool of these so the per-event
    /// re-evaluation path allocates nothing in the steady state).
    pub fn collect_ready_into(
        &mut self,
        is_ancestor: &dyn Fn(TaskId, TaskId) -> bool,
        out: &mut Vec<ReadyAction>,
    ) {
        let mut i = 0;
        while i < self.queue.len() {
            if self.queue[i].granted {
                i += 1;
                continue;
            }
            // Blocked by anything ahead?
            let e = self.queue[i];
            let blocked = self.queue.iter().take(i).any(|ahead| {
                !(ahead.granted
                    && (is_ancestor(ahead.task, e.task) || ahead.mode.compatible(e.mode)))
            });
            if blocked {
                break;
            }
            if e.target == self.id {
                if self.counters_ok(e.mode) {
                    self.queue[i].granted = true;
                    out.push(ReadyAction::Grant { task: e.task, arg: e.arg });
                    i += 1;
                } else {
                    break;
                }
            } else {
                // Resume the downward traversal; the instance leaves this
                // queue and moves below (the caller bumps counters).
                self.queue.remove(i);
                out.push(ReadyAction::Resume {
                    task: e.task,
                    arg: e.arg,
                    mode: e.mode,
                    target: e.target,
                });
            }
        }
    }

    /// Queue empty and no live descendants: the subtree is quiescent.
    pub fn is_quiescent(&self) -> bool {
        self.queue.is_empty() && self.cr == 0 && self.cw == 0
    }

    /// No read activity at or below this node: every read instance that
    /// entered has finished (long-lived writers may remain).
    pub fn read_quiescent(&self) -> bool {
        self.cr == 0 && !self.queue.iter().any(|e| e.mode == Access::Read)
    }

    /// No write activity at or below this node (long-lived readers may
    /// remain — this is what lets a region's write counter drain at the
    /// parent while granted readers still hold objects below it).
    pub fn write_quiescent(&self) -> bool {
        self.cw == 0 && !self.queue.iter().any(|e| e.mode == Access::Write)
    }

    /// Is `task`'s `sys_wait` on this node satisfied? All descendants
    /// drained and nothing queued except the task's own granted entries.
    pub fn wait_satisfied(&self, task: TaskId, mode: Access) -> bool {
        self.counters_ok(mode) && self.queue.iter().all(|e| e.task == task && e.granted)
    }

    /// Handle a quiescence report from `child`. Each mode is an
    /// independent channel carrying the child's cumulative arrival count
    /// for that mode (`None` = that mode not quiescent); a channel is
    /// applied only when the count matches this node's cumulative sends
    /// (the race-avoidance parent-counter check). Returns true if any
    /// channel matched (counters changed).
    pub fn apply_quiesce(&mut self, child: NodeId, pr: Option<u64>, pw: Option<u64>) -> bool {
        let mut matched = false;
        if let Some(pr) = pr {
            let sent_r = self.sent_r.get(&child).copied().unwrap_or(0);
            if pr == sent_r {
                let ar = self.acked_r.entry(child).or_insert(0);
                self.cr -= pr - *ar;
                *ar = pr;
                matched = true;
            }
        }
        if let Some(pw) = pw {
            let sent_w = self.sent_w.get(&child).copied().unwrap_or(0);
            if pw == sent_w {
                let aw = self.acked_w.entry(child).or_insert(0);
                self.cw -= pw - *aw;
                *aw = pw;
                matched = true;
            }
        }
        matched
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::{ObjectId, RegionId};

    fn node(id: u64) -> DepNode {
        DepNode::new(NodeId::Region(RegionId(id)), None, 0)
    }

    /// Ancestry oracle: t1 is parent of everything else.
    fn anc(a: TaskId, t: TaskId) -> bool {
        a == TaskId(1) && t != TaskId(1)
    }

    #[test]
    fn empty_node_grants_writer_at_target() {
        let mut n = node(1);
        n.enqueue(TaskId(2), 0, Access::Write, n.id, &anc);
        let acts = n.collect_ready(&anc);
        assert_eq!(acts, vec![ReadyAction::Grant { task: TaskId(2), arg: 0 }]);
        assert!(n.queue[0].granted);
    }

    #[test]
    fn busy_counters_block_grant() {
        let mut n = node(1);
        n.cw = 1;
        n.enqueue(TaskId(2), 0, Access::Write, n.id, &anc);
        assert!(n.collect_ready(&anc).is_empty());
        n.cw = 0;
        n.cr = 2;
        // A reader can be granted with readers below; a writer cannot.
        assert!(!n.counters_ok(Access::Write));
        assert!(n.counters_ok(Access::Read));
    }

    #[test]
    fn reader_prefix_grants_together() {
        let mut n = node(1);
        n.enqueue(TaskId(2), 0, Access::Read, n.id, &anc);
        n.enqueue(TaskId(3), 0, Access::Read, n.id, &anc);
        n.enqueue(TaskId(4), 0, Access::Write, n.id, &anc);
        let acts = n.collect_ready(&anc);
        assert_eq!(acts.len(), 2, "both readers grant, writer waits");
        assert!(n.queue[0].granted && n.queue[1].granted && !n.queue[2].granted);
        // Writer grants only after both readers pop.
        assert!(n.pop_task(TaskId(2), 0));
        assert!(n.collect_ready(&anc).is_empty());
        assert!(n.pop_task(TaskId(3), 0));
        let acts = n.collect_ready(&anc);
        assert_eq!(acts, vec![ReadyAction::Grant { task: TaskId(4), arg: 0 }]);
    }

    #[test]
    fn granted_ancestor_does_not_block_child() {
        let mut n = node(1);
        n.enqueue_granted(TaskId(1), 0, Access::Write); // parent holds the region
        n.enqueue(TaskId(2), 0, Access::Write, n.id, &anc); // child wants the whole thing
        let acts = n.collect_ready(&anc);
        assert_eq!(acts, vec![ReadyAction::Grant { task: TaskId(2), arg: 0 }]);
    }

    #[test]
    fn non_ancestor_writer_blocks() {
        let mut n = node(1);
        n.enqueue_granted(TaskId(5), 0, Access::Write); // unrelated granted writer
        n.enqueue(TaskId(2), 0, Access::Write, n.id, &anc);
        assert!(n.collect_ready(&anc).is_empty());
        assert!(n.pop_task(TaskId(5), 0));
        assert_eq!(n.collect_ready(&anc).len(), 1);
    }

    #[test]
    fn mid_path_entry_resumes_not_grants() {
        let mut n = node(1);
        let deeper = NodeId::Object(ObjectId(7));
        n.enqueue_granted(TaskId(5), 0, Access::Write);
        n.enqueue(TaskId(2), 0, Access::Write, deeper, &anc); // stopped here mid-path
        assert!(n.collect_ready(&anc).is_empty());
        n.pop_task(TaskId(5), 0);
        let acts = n.collect_ready(&anc);
        assert_eq!(
            acts,
            vec![ReadyAction::Resume { task: TaskId(2), arg: 0, mode: Access::Write, target: deeper }]
        );
        assert!(n.queue.is_empty(), "resumed entry leaves the queue");
    }

    #[test]
    fn can_pass_rules() {
        let mut n = node(1);
        assert!(n.can_pass(TaskId(2), Access::Write, &anc));
        n.enqueue_granted(TaskId(1), 0, Access::Write);
        // Ancestor grant: children may pass.
        assert!(n.can_pass(TaskId(2), Access::Write, &anc));
        // Unrelated task may not pass a granted writer.
        n.queue.clear();
        n.enqueue_granted(TaskId(5), 0, Access::Write);
        assert!(!n.can_pass(TaskId(2), Access::Write, &anc));
        // Readers pass granted readers.
        n.queue.clear();
        n.enqueue_granted(TaskId(5), 0, Access::Read);
        assert!(n.can_pass(TaskId(2), Access::Read, &anc));
        assert!(!n.can_pass(TaskId(2), Access::Write, &anc));
        // Waiting (non-granted) entries block everyone.
        n.queue.clear();
        n.enqueue(TaskId(5), 0, Access::Read, n.id, &anc);
        assert!(!n.can_pass(TaskId(2), Access::Read, &anc));
    }

    #[test]
    fn descent_and_arrival_counters() {
        let mut n = node(1);
        let c1 = NodeId::Region(RegionId(2));
        let c2 = NodeId::Region(RegionId(3));
        n.note_descent(c1, Access::Write);
        n.note_descent(c2, Access::Write);
        n.note_descent(c1, Access::Read);
        assert_eq!((n.cr, n.cw), (1, 2));
        assert_eq!(n.sent_w.get(&c1), Some(&1));
        assert_eq!(n.sent_w.get(&c2), Some(&1));
        assert_eq!(n.sent_r.get(&c1), Some(&1));
        n.note_arrival(Access::Write);
        assert_eq!((n.pr_recv, n.pw_recv), (0, 1));
    }

    #[test]
    fn quiesce_protocol_matches_and_races() {
        // Mirrors Fig 5b: region B with two children C and D.
        let mut b = node(10);
        let c = NodeId::Region(RegionId(11));
        let d = NodeId::Region(RegionId(12));
        b.note_descent(c, Access::Write);
        b.note_descent(d, Access::Write);
        assert_eq!(b.cw, 2);
        // D quiesces having received 1 write enqueue: matched, cw drops.
        assert!(b.apply_quiesce(d, Some(0), Some(1)));
        assert_eq!(b.cw, 1);
        // A racing (stale) report from C claiming 0 enqueues is ignored.
        assert!(!b.apply_quiesce(c, None, Some(0)));
        assert_eq!(b.cw, 1);
        // The real report matches.
        assert!(b.apply_quiesce(c, None, Some(1)));
        assert_eq!(b.cw, 0);
        assert!(b.is_quiescent());
        // Re-activation: another descent, another quiesce, cumulative.
        b.note_descent(c, Access::Write);
        assert_eq!(b.cw, 1);
        assert!(!b.apply_quiesce(c, None, Some(1)), "old count must not match");
        assert!(b.apply_quiesce(c, None, Some(2)));
        assert_eq!(b.cw, 0);
    }

    #[test]
    fn double_quiesce_is_idempotent_via_ack() {
        let mut b = node(10);
        let c = NodeId::Region(RegionId(11));
        b.note_descent(c, Access::Read);
        assert!(b.apply_quiesce(c, Some(1), None));
        assert_eq!(b.cr, 0);
        // Same report again: matches but the ack makes the delta zero.
        assert!(b.apply_quiesce(c, Some(1), None));
        assert_eq!(b.cr, 0);

        // Per-mode independence: a granted reader below must not block a
        // write-quiescence report from draining the parent's cw.
        let mut n = node(20);
        n.note_descent(c, Access::Read);
        n.note_descent(c, Access::Write);
        assert!(n.apply_quiesce(c, None, Some(1)), "write channel drains alone");
        assert_eq!((n.cr, n.cw), (1, 0));
        assert!(n.apply_quiesce(c, Some(1), None));
        assert_eq!((n.cr, n.cw), (0, 0));
    }

    #[test]
    fn wait_satisfaction() {
        let mut n = node(1);
        n.enqueue_granted(TaskId(1), 0, Access::Write);
        assert!(n.wait_satisfied(TaskId(1), Access::Write));
        n.cw = 1;
        assert!(!n.wait_satisfied(TaskId(1), Access::Write));
        n.cw = 0;
        n.enqueue(TaskId(2), 0, Access::Write, n.id, &anc);
        assert!(!n.wait_satisfied(TaskId(1), Access::Write));
    }

    #[test]
    fn fig5a_scenario_traversal_stops_at_busy_queue() {
        // parent() holds region A; child() wants object 1 under F; another
        // task child2 is granted on F. child's descent must stop at F.
        let mut f = node(6);
        let obj1 = NodeId::Object(ObjectId(1));
        f.enqueue_granted(TaskId(9), 0, Access::Write); // child2 (unrelated)
        assert!(!f.can_pass(TaskId(2), Access::Write, &anc));
        f.enqueue(TaskId(2), 0, Access::Write, obj1, &anc);
        // child2 finishes:
        f.pop_task(TaskId(9), 0);
        let acts = f.collect_ready(&anc);
        assert_eq!(
            acts,
            vec![ReadyAction::Resume { task: TaskId(2), arg: 0, mode: Access::Write, target: obj1 }]
        );
    }
}

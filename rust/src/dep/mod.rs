//! Dependency analysis: queues, counters, traversal (paper V-D).
pub mod analysis;
pub mod node;

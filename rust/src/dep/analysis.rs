//! Dependency-forest state and path/anchor computations (paper V-D).

use crate::arena::{SlotArena, SlotId};
use crate::ids::NodeId;
use crate::memory::region::Memory;
use crate::task::descriptor::{Access, TaskArg};

use super::node::DepNode;

/// All live dependency nodes. Each node is *owned* by one scheduler
/// (`DepNode::owner`); scheduler logic only mutates nodes it owns —
/// cross-owner steps travel as NoC messages.
///
/// Storage is a generational [`SlotArena`] addressed through two dense
/// side tables (region id -> slot, object id -> slot). Region and object
/// ids are handed out by [`Memory`] from incrementing counters, so the
/// side tables are flat `Vec`s and a lookup on the grant/re-evaluation
/// path is two array indexes — no hashing (the `FxHashMap` this replaces
/// was the hottest map in whole-run profiles).
#[derive(Default)]
pub struct DepState {
    nodes: SlotArena<DepNode>,
    /// RegionId.0 -> arena slot (SlotId::NONE when absent).
    region_slot: Vec<SlotId>,
    /// ObjectId.0 -> arena slot (SlotId::NONE when absent).
    object_slot: Vec<SlotId>,
}

impl DepState {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn slot_of(&self, id: NodeId) -> SlotId {
        let (table, key) = match id {
            NodeId::Region(r) => (&self.region_slot, r.0),
            NodeId::Object(o) => (&self.object_slot, o.0),
        };
        table.get(key as usize).copied().unwrap_or(SlotId::NONE)
    }

    #[inline]
    fn slot_entry(&mut self, id: NodeId) -> &mut SlotId {
        let (table, key) = match id {
            NodeId::Region(r) => (&mut self.region_slot, r.0),
            NodeId::Object(o) => (&mut self.object_slot, o.0),
        };
        let key = key as usize;
        if key >= table.len() {
            table.resize(key + 1, SlotId::NONE);
        }
        &mut table[key]
    }

    #[inline]
    pub fn get(&self, id: NodeId) -> Option<&DepNode> {
        self.nodes.get(self.slot_of(id))
    }

    #[inline]
    pub fn get_mut(&mut self, id: NodeId) -> Option<&mut DepNode> {
        let slot = self.slot_of(id);
        self.nodes.get_mut(slot)
    }

    #[inline]
    pub fn contains(&self, id: NodeId) -> bool {
        self.nodes.get(self.slot_of(id)).is_some()
    }

    /// Get or lazily create the node, deriving parent/owner from the
    /// memory metadata (both are frozen into the node so teardown works
    /// after the region is freed).
    pub fn node_mut(&mut self, id: NodeId, mem: &Memory) -> &mut DepNode {
        let slot = self.slot_of(id);
        if self.nodes.get(slot).is_none() {
            let parent = mem.parent_of(id);
            let owner = mem.owner(id);
            let slot = self.nodes.insert(DepNode::new(id, parent, owner));
            *self.slot_entry(id) = slot;
            return self.nodes.get_mut(slot).expect("freshly inserted node");
        }
        self.nodes.get_mut(slot).expect("checked live above")
    }

    pub fn remove(&mut self, id: NodeId) -> Option<DepNode> {
        let slot = self.slot_of(id);
        let node = self.nodes.remove(slot)?;
        *self.slot_entry(id) = SlotId::NONE;
        Some(node)
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// All live nodes, in slot order (diagnostics and the quiescence
    /// oracles: at full drain every surviving node must be idle).
    pub fn iter_nodes(&self) -> impl Iterator<Item = &DepNode> {
        self.nodes.iter()
    }

    /// Mark a node dying (region freed while draining) or remove it
    /// immediately if it is already idle.
    pub fn retire(&mut self, id: NodeId) {
        let remove = match self.get_mut(id) {
            None => false,
            Some(n) => {
                if n.queue.is_empty() && n.cr == 0 && n.cw == 0 && n.waiters.is_empty() {
                    true
                } else {
                    n.dying = true;
                    false
                }
            }
        };
        if remove {
            self.remove(id);
        }
    }
}

/// Find the *anchor* for a child task argument: the parent task's argument
/// node that is an ancestor-or-self of `target` (nearest one wins). The
/// programming model guarantees child footprints are subsets of the
/// parent's (paper [6]); `None` here means the application violated that.
pub fn find_anchor(
    parent_args: &[TaskArg],
    mem: &Memory,
    target: NodeId,
    mode: Access,
) -> Option<NodeId> {
    let mut cur = Some(target);
    while let Some(n) = cur {
        for a in parent_args {
            if a.is_safe() {
                continue;
            }
            if a.node == Some(n) {
                // The parent must hold at least the access the child wants.
                if mode == Access::Write && a.access() == Access::Read {
                    return None;
                }
                return Some(n);
            }
        }
        cur = mem.parent_of(n);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HierarchySpec;
    use crate::ids::{RegionId, TaskId};
    use crate::sched::hierarchy::HierarchyMap;

    fn setup() -> (Memory, HierarchyMap) {
        let h = HierarchyMap::build(8, &HierarchySpec::flat());
        (Memory::new(1), h)
    }

    #[test]
    fn anchor_is_nearest_parent_arg() {
        let (mut m, h) = setup();
        let a = m.ralloc(RegionId::ROOT, 0, &h);
        let b = m.ralloc(a, 0, &h);
        let o = m.alloc(64, b);
        // Parent holds both A (inout) and B (inout): nearest is B.
        let args = vec![TaskArg::region_inout(a), TaskArg::region_inout(b)];
        assert_eq!(
            find_anchor(&args, &m, NodeId::Object(o), Access::Write),
            Some(NodeId::Region(b))
        );
        // Parent holds only A.
        let args = vec![TaskArg::region_inout(a)];
        assert_eq!(
            find_anchor(&args, &m, NodeId::Object(o), Access::Write),
            Some(NodeId::Region(a))
        );
    }

    #[test]
    fn anchor_respects_access_mode() {
        let (mut m, h) = setup();
        let a = m.ralloc(RegionId::ROOT, 0, &h);
        let o = m.alloc(64, a);
        // Parent holds A read-only: child may read but not write.
        let args = vec![TaskArg::region_in(a)];
        assert_eq!(
            find_anchor(&args, &m, NodeId::Object(o), Access::Read),
            Some(NodeId::Region(a))
        );
        assert_eq!(find_anchor(&args, &m, NodeId::Object(o), Access::Write), None);
    }

    #[test]
    fn anchor_missing_for_foreign_target() {
        let (mut m, h) = setup();
        let a = m.ralloc(RegionId::ROOT, 0, &h);
        let c = m.ralloc(RegionId::ROOT, 0, &h);
        let o = m.alloc(64, c);
        let args = vec![TaskArg::region_inout(a)];
        assert_eq!(find_anchor(&args, &m, NodeId::Object(o), Access::Write), None);
    }

    #[test]
    fn anchor_can_equal_target() {
        let (mut m, h) = setup();
        let a = m.ralloc(RegionId::ROOT, 0, &h);
        let args = vec![TaskArg::region_inout(a)];
        assert_eq!(
            find_anchor(&args, &m, NodeId::Region(a), Access::Write),
            Some(NodeId::Region(a))
        );
    }

    #[test]
    fn retire_defers_busy_nodes() {
        let (mut m, h) = setup();
        let a = m.ralloc(RegionId::ROOT, 0, &h);
        let mut ds = DepState::new();
        let n = ds.node_mut(NodeId::Region(a), &m);
        n.enqueue_granted(TaskId(1), 0, Access::Write);
        ds.retire(NodeId::Region(a));
        assert!(ds.contains(NodeId::Region(a)), "busy node only marked dying");
        assert!(ds.get(NodeId::Region(a)).unwrap().dying);
        // Idle node removes immediately.
        let b = m.ralloc(RegionId::ROOT, 0, &h);
        ds.node_mut(NodeId::Region(b), &m);
        ds.retire(NodeId::Region(b));
        assert!(!ds.contains(NodeId::Region(b)));
        let _ = h;
    }

    #[test]
    fn node_mut_freezes_parent_and_owner() {
        let (mut m, h) = setup();
        let a = m.ralloc(RegionId::ROOT, 0, &h);
        let mut ds = DepState::new();
        let n = ds.node_mut(NodeId::Region(a), &m);
        assert_eq!(n.parent, Some(NodeId::Region(RegionId::ROOT)));
        assert_eq!(n.owner, 0);
    }
}

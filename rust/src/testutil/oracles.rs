//! Invariant oracles for the protocol fuzz/soak harness.
//!
//! Each oracle inspects a quiesced engine (run with
//! [`crate::sim::engine::Engine::run_to_quiescence`], i.e. the event
//! queue fully drained — not merely cut off at `world.done`) and returns
//! human-readable violations. At true quiescence the distributed
//! scheduler state must have collapsed back to its ground state:
//!
//! * every spawned task completed exactly once (no lost or duplicated
//!   `TaskId`s),
//! * every `LoadTracker` book drained (exactly zero when load reports are
//!   disabled; near-zero otherwise),
//! * every ready queue empty and no steal request left outstanding,
//! * every surviving dependency node idle (queues and waiters empty,
//!   child activity counters zero) and no dying node leaked,
//! * every channel credit restored and no send left parked,
//! * the global steal counters self-consistent (reqs == grants + denies,
//!   stolen tasks imply grants).
//!
//! The individual checks are public so a debug build can interleave the
//! cheap ones (e.g. [`check_gstats`]) mid-run; [`check_all`] is the
//! quiesce-time entry point the fuzz harness uses. Violations are
//! returned, not asserted, so the harness can record them per seed and
//! emit a reproducer line instead of dying on the first bad run.

use crate::sched::scheduler::SchedLogic;
use crate::sim::engine::Engine;
use crate::sim::traffic::JobPhase;
use crate::task::table::TaskState;

/// Non-strict bound for per-scheduler load-estimate residue. With load
/// reports enabled the run cuts off with authoritative reports possibly
/// still queued behind the final decay, so books may legitimately hold a
/// few units at `world.done`; full drain delivers them, but the bound
/// stays lenient to keep the oracle free of false positives.
const LOOSE_BOOK_BOUND: u64 = 16;

/// Run every oracle; returns all violations (empty = healthy).
/// `strict_books` should be true when the run disabled load reports
/// (`load_report_threshold == u64::MAX`): then the decay path alone must
/// balance every book to exactly zero (pinned by `tests/load_drift.rs`).
pub fn check_all(eng: &Engine, strict_books: bool) -> Vec<String> {
    let mut v = Vec::new();
    check_drained(eng, &mut v);
    check_tasks(eng, &mut v);
    check_schedulers(eng, strict_books, &mut v);
    check_dep(eng, &mut v);
    check_channels(eng, &mut v);
    check_gstats(eng, &mut v);
    check_journal(eng, &mut v);
    check_recovery(eng, &mut v);
    check_jobs(eng, &mut v);
    v
}

/// The engine must actually be quiescent for the other oracles to apply.
pub fn check_drained(eng: &Engine, out: &mut Vec<String>) {
    if !eng.world.done {
        out.push("run did not complete: world.done is false".into());
    }
    if !eng.sim.queue_is_empty() {
        out.push("event queue not drained: oracle state is not final".into());
    }
}

/// Every spawned task completes exactly once.
pub fn check_tasks(eng: &Engine, out: &mut Vec<String>) {
    let g = &eng.world.gstats;
    let table = eng.world.tasks.len() as u64;
    if g.tasks_spawned != table {
        out.push(format!(
            "task oracle: {} spawned but {} table entries",
            g.tasks_spawned, table
        ));
    }
    if g.tasks_completed != g.tasks_spawned {
        out.push(format!(
            "task oracle: {} spawned, {} completed — lost or duplicated tasks",
            g.tasks_spawned, g.tasks_completed
        ));
    }
    for e in eng.world.tasks.iter() {
        if e.state != TaskState::Done {
            out.push(format!(
                "task oracle: task {} finished the run in state {:?}",
                e.id, e.state
            ));
        }
    }
}

/// Per-scheduler state: books drained, ready queues empty, steal latch
/// clear.
pub fn check_schedulers(eng: &Engine, strict_books: bool, out: &mut Vec<String>) {
    for s in 0..eng.world.hier.n_scheds {
        let core = eng.world.hier.sched_core(s);
        let Some(logic) = eng.logic_of(core) else {
            out.push(format!("scheduler {s}: core has no logic"));
            continue;
        };
        let Some(sched) = logic.as_any().and_then(|a| a.downcast_ref::<SchedLogic>()) else {
            out.push(format!("scheduler {s}: logic is not SchedLogic"));
            continue;
        };
        if sched.ready_depth() != 0 {
            out.push(format!(
                "ready oracle: scheduler {s} holds {} queued tasks at quiescence",
                sched.ready_depth()
            ));
        }
        if sched.steal_in_flight() {
            out.push(format!(
                "steal oracle: scheduler {s} still has a StealReq outstanding"
            ));
        }
        let loads = &sched.placer().loads;
        let total = loads.total();
        let bound = if strict_books { 0 } else { LOOSE_BOOK_BOUND };
        if total > bound {
            out.push(format!(
                "book oracle: scheduler {s} leaked load estimates: total {total} \
                 (bound {bound}), children {:?}, workers {:?}",
                loads.child_loads(),
                loads.worker_loads()
            ));
        }
    }
}

/// Dependency forest: every surviving node must be idle (queue and
/// waiters empty, child-activity counters drained by the quiescence
/// protocol) and no dying node may outlive its drain.
pub fn check_dep(eng: &Engine, out: &mut Vec<String>) {
    for n in eng.world.dep.iter_nodes() {
        if !n.queue.is_empty() {
            out.push(format!(
                "dep oracle: node {} still queues {} entries",
                n.id,
                n.queue.len()
            ));
        }
        if !n.waiters.is_empty() {
            out.push(format!(
                "dep oracle: node {} still holds {} waiters",
                n.id,
                n.waiters.len()
            ));
        }
        if n.cr != 0 || n.cw != 0 {
            out.push(format!(
                "dep oracle: node {} child counters not drained (cr {}, cw {})",
                n.id, n.cr, n.cw
            ));
        }
        if n.dying {
            out.push(format!("dep oracle: dying node {} leaked past quiescence", n.id));
        }
    }
}

/// Channel credits: at quiescence every in-flight message was processed
/// (its credit returned) and no send remains parked.
pub fn check_channels(eng: &Engine, out: &mut Vec<String>) {
    // `channel_views` covers both engine modes: the legacy table (always
    // present, so test-only injections stay visible) plus one table per
    // shard when the run was sharded. The slot counter is global across
    // tables so a violation message still names a unique slot.
    let mut slot = 0usize;
    for table in eng.sim.channel_views() {
        for ch in table.iter() {
            if ch.in_flight != 0 {
                out.push(format!(
                    "channel oracle: channel slot {slot} still holds {} credits",
                    ch.in_flight
                ));
            }
            if !ch.blocked.is_empty() {
                out.push(format!(
                    "channel oracle: channel slot {slot} still parks {} sends",
                    ch.blocked.len()
                ));
            }
            slot += 1;
        }
    }
}

/// Global steal-counter consistency.
pub fn check_gstats(eng: &Engine, out: &mut Vec<String>) {
    let g = &eng.world.gstats;
    if g.steal_reqs != g.steal_grants + g.steal_denies {
        out.push(format!(
            "gstats oracle: steal_reqs {} != grants {} + denies {}",
            g.steal_reqs, g.steal_grants, g.steal_denies
        ));
    }
    if g.tasks_stolen < g.steal_grants {
        out.push(format!(
            "gstats oracle: {} grants but only {} stolen tasks (every grant \
             carries at least one)",
            g.steal_grants, g.tasks_stolen
        ));
    }
    if g.tasks_stolen > 0 && g.steal_grants == 0 {
        out.push(format!(
            "gstats oracle: {} stolen tasks with zero grants",
            g.tasks_stolen
        ));
    }
}

/// Durable request journal: every reentrant rendezvous (pack aggregation,
/// spawn settle, wait count) must have been served by quiescence — a
/// leaked entry means a requester is suspended forever.
pub fn check_journal(eng: &Engine, out: &mut Vec<String>) {
    let j = &eng.world.journal;
    if !j.is_empty() {
        out.push(format!(
            "journal oracle: {} reentrant requests (pack/spawn/wait) still pending",
            j.outstanding()
        ));
    }
}

/// Crash-recovery counter consistency: at most the one installable crash
/// fired, every restart matches a crash, synthesized denies are a subset
/// of all denies, re-issued tasks imply a re-adoption, and no recovery
/// machinery moved in a crash-free run. (Exactly-once completion itself is
/// covered by [`check_tasks`]: spawned == completed and every entry Done.)
pub fn check_recovery(eng: &Engine, out: &mut Vec<String>) {
    let g = &eng.world.gstats;
    if g.crashes > 1 {
        out.push(format!(
            "recovery oracle: {} crashes fired but at most one is installable",
            g.crashes
        ));
    }
    if g.restarts > g.crashes {
        out.push(format!(
            "recovery oracle: {} restarts exceed {} crashes",
            g.restarts, g.crashes
        ));
    }
    if g.crash_denies_synth > g.steal_denies {
        out.push(format!(
            "recovery oracle: {} synthesized denies exceed {} total denies",
            g.crash_denies_synth, g.steal_denies
        ));
    }
    if g.tasks_reissued > 0 && g.re_adoptions == 0 {
        out.push(format!(
            "recovery oracle: {} tasks re-issued without any re-adoption",
            g.tasks_reissued
        ));
    }
    if g.crashes == 0
        && (g.re_adoptions > 0 || g.tasks_reissued > 0 || g.crash_denies_synth > 0)
    {
        out.push(format!(
            "recovery oracle: recovery counters moved without a crash \
             (re_adoptions {}, reissued {}, synth denies {})",
            g.re_adoptions, g.tasks_reissued, g.crash_denies_synth
        ));
    }
}

/// Traffic books: every arrival fired, every job — including every
/// deferred one — was eventually admitted and completed exactly once,
/// per-job task counts balance, and the tenant books drained to zero live
/// jobs. A traffic-free run (`world.traffic == None`) passes vacuously.
pub fn check_jobs(eng: &Engine, out: &mut Vec<String>) {
    let Some(tr) = eng.world.traffic.as_ref() else { return };
    if tr.arrivals_pending != 0 {
        out.push(format!("job oracle: {} arrivals never fired", tr.arrivals_pending));
    }
    if tr.unfinished != 0 {
        out.push(format!("job oracle: {} jobs unfinished at quiescence", tr.unfinished));
    }
    if tr.admitted as usize != tr.jobs.len() {
        out.push(format!(
            "job oracle: {} of {} jobs admitted — deferred jobs must eventually \
             be admitted",
            tr.admitted,
            tr.jobs.len()
        ));
    }
    for (i, j) in tr.jobs.iter().enumerate() {
        if j.phase != JobPhase::Done {
            out.push(format!(
                "job oracle: job {i} finished the run in phase {:?}",
                j.phase
            ));
            continue;
        }
        if j.live != 0 || j.spawned != j.completed {
            out.push(format!(
                "job oracle: job {i} books unbalanced (live {}, spawned {}, \
                 completed {})",
                j.live, j.spawned, j.completed
            ));
        }
        if j.attempts == 0 || j.root_task.is_none() {
            out.push(format!("job oracle: done job {i} has no admission record"));
        }
    }
    for (t, tb) in tr.tenants.iter().enumerate() {
        if tb.live_jobs != 0 {
            out.push(format!(
                "job oracle: tenant {t} still holds {} live jobs",
                tb.live_jobs
            ));
        }
        if tb.finished != tb.submitted {
            out.push(format!(
                "job oracle: tenant {t} finished {} of {} submitted jobs",
                tb.finished, tb.submitted
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    //! Oracle self-tests: each oracle must fail loudly on a seeded
    //! corruption, so the suite can't rot into always-green.

    use super::*;
    use crate::apps::synthetic::{independent, SynthParams};
    use crate::config::{HierarchySpec, PlatformConfig, StealCfg};
    use crate::ids::{CoreId, NodeId, RegionId};
    use crate::platform::Platform;

    /// A small finished run in the strict (reports-off) regime, fully
    /// drained so every oracle should pass before corruption.
    fn healthy_engine() -> Engine {
        let (reg, main) = independent();
        let mut cfg = PlatformConfig::new(16, HierarchySpec::two_level(4));
        cfg.load_report_threshold = u64::MAX;
        cfg.policy.steal = StealCfg::on();
        let mut plat = Platform::build_with(cfg, reg, main, |w| {
            w.app = Some(Box::new(SynthParams {
                n_tasks: 24,
                task_cycles: 50_000,
                ..Default::default()
            }));
        });
        plat.run_to_quiescence(Some(1 << 44));
        plat.eng
    }

    fn sched_mut(eng: &mut Engine, idx: usize) -> &mut SchedLogic {
        let core = eng.world.hier.sched_core(idx);
        eng.logic_of_mut(core)
            .and_then(|l| l.as_any_mut())
            .and_then(|a| a.downcast_mut::<SchedLogic>())
            .expect("scheduler core logic is SchedLogic")
    }

    fn assert_caught(violations: &[String], needle: &str) {
        assert!(
            violations.iter().any(|v| v.contains(needle)),
            "expected a violation containing {needle:?}, got {violations:?}"
        );
    }

    #[test]
    fn healthy_run_passes_all_oracles() {
        let eng = healthy_engine();
        let v = check_all(&eng, true);
        assert!(v.is_empty(), "healthy quiesced run must pass: {v:?}");
    }

    #[test]
    fn task_oracle_catches_state_corruption() {
        let mut eng = healthy_engine();
        let id = eng.world.tasks.iter().next().expect("tasks exist").id;
        eng.world.tasks.get_mut(id).state = TaskState::Running;
        assert_caught(&check_all(&eng, true), "finished the run in state");
    }

    #[test]
    fn task_oracle_catches_lost_completion() {
        let mut eng = healthy_engine();
        eng.world.gstats.tasks_completed -= 1;
        assert_caught(&check_all(&eng, true), "lost or duplicated tasks");
    }

    #[test]
    fn book_oracle_catches_skewed_loads() {
        let mut eng = healthy_engine();
        let loads = &mut sched_mut(&mut eng, 0).placer_mut().loads;
        for _ in 0..LOOSE_BOOK_BOUND + 1 {
            loads.bump_child(0);
        }
        assert_caught(&check_all(&eng, true), "leaked load estimates");
    }

    #[test]
    fn ready_oracle_catches_leaked_queue_entry() {
        let mut eng = healthy_engine();
        let id = eng.world.tasks.iter().next().expect("tasks exist").id;
        sched_mut(&mut eng, 1).ready_inject(id);
        assert_caught(&check_all(&eng, true), "queued tasks at quiescence");
    }

    #[test]
    fn dep_oracle_catches_undrained_counters() {
        let mut eng = healthy_engine();
        let crate::platform::World { dep, mem, .. } = &mut eng.world;
        dep.node_mut(NodeId::Region(RegionId::ROOT), mem).cr += 1;
        assert_caught(&check_all(&eng, true), "child counters not drained");
    }

    #[test]
    fn channel_oracle_catches_leaked_credit() {
        let mut eng = healthy_engine();
        eng.sim
            .channels_mut()
            .entry(CoreId(0), CoreId(1))
            .try_acquire(8);
        assert_caught(&check_all(&eng, true), "still holds");
    }

    #[test]
    fn gstats_oracle_catches_inconsistent_steal_counters() {
        let mut eng = healthy_engine();
        eng.world.gstats.steal_reqs += 1;
        assert_caught(&check_all(&eng, true), "steal_reqs");
    }

    #[test]
    fn journal_oracle_catches_leaked_rendezvous() {
        use crate::ids::ReqId;
        let mut eng = healthy_engine();
        eng.world.journal.inject_spawn(ReqId(0xDEAD), CoreId(17), 2);
        assert_caught(&check_all(&eng, true), "still pending");
    }

    #[test]
    fn recovery_oracle_catches_restart_without_crash() {
        let mut eng = healthy_engine();
        eng.world.gstats.restarts += 1;
        assert_caught(&check_all(&eng, true), "restarts exceed");
    }

    #[test]
    fn recovery_oracle_catches_reissue_without_adoption() {
        let mut eng = healthy_engine();
        eng.world.gstats.crashes = 1;
        eng.world.gstats.restarts = 1;
        eng.world.gstats.tasks_reissued = 3;
        assert_caught(&check_all(&eng, true), "without any re-adoption");
    }

    #[test]
    fn recovery_oracle_catches_machinery_moving_crash_free() {
        let mut eng = healthy_engine();
        eng.world.gstats.re_adoptions = 1;
        assert_caught(&check_all(&eng, true), "without a crash");
    }

    #[test]
    fn recovery_oracle_catches_synth_deny_overflow() {
        let mut eng = healthy_engine();
        eng.world.gstats.crashes = 1;
        eng.world.gstats.crash_denies_synth = eng.world.gstats.steal_denies + 1;
        assert_caught(&check_all(&eng, true), "synthesized denies exceed");
    }

    /// A small finished traffic run, fully drained (reports on, so the
    /// loose book bound applies).
    fn healthy_traffic_engine() -> Engine {
        use crate::apps::jobs::traffic_boot;
        use crate::config::TrafficCfg;
        use crate::sim::traffic::{JobShape, JobTemplate, TrafficState};
        let (reg, refs) = traffic_boot();
        let main_fn = refs.job_main.index();
        let mut cfg = PlatformConfig::new(16, HierarchySpec::two_level(4));
        cfg.traffic = TrafficCfg::on(6, 2);
        let tcfg = cfg.traffic.clone();
        let seed = cfg.seed;
        let mut plat = Platform::build_with(cfg, reg, refs.boot, move |w| {
            let tpl = [JobTemplate {
                name: "t",
                shape: JobShape { tasks: 4, task_cycles: 200_000, fanout: 2, hot_pct: 50 },
            }];
            let tr = TrafficState::generate(&tcfg, seed, &w.hier, main_fn, &tpl);
            w.traffic = Some(tr);
        });
        plat.run_to_quiescence(Some(1 << 44));
        plat.eng
    }

    #[test]
    fn traffic_run_passes_all_oracles() {
        let eng = healthy_traffic_engine();
        let v = check_all(&eng, false);
        assert!(v.is_empty(), "healthy quiesced traffic run must pass: {v:?}");
    }

    #[test]
    fn job_oracle_catches_unfinished_job() {
        let mut eng = healthy_traffic_engine();
        eng.world.traffic.as_mut().unwrap().unfinished += 1;
        assert_caught(&check_all(&eng, false), "jobs unfinished");
    }

    #[test]
    fn job_oracle_catches_missed_admission() {
        let mut eng = healthy_traffic_engine();
        eng.world.traffic.as_mut().unwrap().admitted -= 1;
        assert_caught(&check_all(&eng, false), "eventually");
    }

    #[test]
    fn job_oracle_catches_unbalanced_books() {
        let mut eng = healthy_traffic_engine();
        eng.world.traffic.as_mut().unwrap().jobs[0].spawned += 1;
        assert_caught(&check_all(&eng, false), "books unbalanced");
    }

    #[test]
    fn job_oracle_catches_stranded_tenant() {
        let mut eng = healthy_traffic_engine();
        eng.world.traffic.as_mut().unwrap().tenants[0].live_jobs += 1;
        assert_caught(&check_all(&eng, false), "live jobs");
    }
}

//! Test utilities (mini property-testing harness + invariant oracles).
pub mod oracles;
pub mod prop;

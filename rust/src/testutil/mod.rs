//! Test utilities (mini property-testing harness).
pub mod prop;

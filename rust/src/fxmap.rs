//! Tiny multiply-xor hasher for small integer keys (ids).
//!
//! The simulator's hottest maps (dependency nodes, region/object tables,
//! NoC channels) are keyed by small newtype integers; std's SipHash shows
//! up at ~9% of the whole-run profile (EXPERIMENTS.md Perf). This is the
//! classic FxHash construction: one wrapping multiply + rotate per word.

use std::hash::{BuildHasherDefault, Hasher};

#[derive(Default)]
pub struct FxHasher {
    state: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn mix(&mut self, word: u64) {
        self.state = (self.state.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.mix(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.mix(n);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.mix(n as u64);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.mix(n as u64);
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.mix(n as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distinct_keys_hash_distinctly() {
        use std::hash::{BuildHasher, Hash};
        let b = FxBuildHasher::default();
        let hash = |x: u64| {
            let mut h = b.build_hasher();
            x.hash(&mut h);
            h.finish()
        };
        let mut seen = std::collections::HashSet::new();
        for k in 0..10_000u64 {
            assert!(seen.insert(hash(k)), "collision at {k}");
        }
    }

    #[test]
    fn map_roundtrip() {
        let mut m: FxHashMap<crate::ids::NodeId, u64> = FxHashMap::default();
        for i in 0..1000 {
            m.insert(crate::ids::NodeId::Object(crate::ids::ObjectId(i)), i * 3);
        }
        for i in 0..1000 {
            assert_eq!(m[&crate::ids::NodeId::Object(crate::ids::ObjectId(i))], i * 3);
        }
    }
}

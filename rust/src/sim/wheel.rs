//! Deterministic hierarchical timing wheel — the simulator's event queue.
//!
//! The paper's schedulers are "event-based servers" with nothing hashed or
//! logarithmic on the critical path; the simulator that models them should
//! hold itself to the same bar. This queue replaces the old global
//! `BinaryHeap<Queued>` (O(log n) per push/pop) with:
//!
//! * **Three wheel levels** of 256 power-of-two time buckets each
//!   (8 bits/level, [`SPAN_BITS`] = 24 bits ≈ 16.7 M cycles of horizon).
//!   A push files the event by the highest differing bit block between its
//!   time and the cursor; a pop pulls the head of the current tick's
//!   bucket. Both are O(1); an event cascades to a lower level at most
//!   twice over its lifetime.
//! * **A far heap** for events beyond the wheel span (multi-million-cycle
//!   task timers, DMA completions of huge transfers). It holds only
//!   `(t, seq, node)` keys — payloads stay in the node slab — and refills
//!   the wheel lazily when the cursor enters a new 2^24-cycle epoch.
//! * **A wake side-heap** for the engine's busy-core drain markers. A
//!   deferred event parks in the core's local FIFO and its waker lives
//!   here, so draining a busy core never round-trips through the global
//!   wheel at all. Wakes still consume global sequence numbers, so the
//!   merged pop order is bit-identical to the single-heap engine.
//! * **A node slab with an intrusive free list**: bucket membership is a
//!   `next` index chain through the slab, so steady-state push/pop
//!   performs no heap allocation (the slab grows to the high-water mark of
//!   outstanding events and is then reused forever) — the hot-path
//!   invariant of ROADMAP.md's Performance section.
//!
//! # Determinism contract
//!
//! Pops are globally ordered by `(t, seq)` exactly like the old binary
//! heap: `seq` is unique and monotone, buckets are FIFO chains, and
//! cascades/refills preserve relative order of equal-time events (they
//! re-append in the order the chain or heap yields, which is seq order for
//! equal `t`). `tests/determinism.rs` and `tests/wheel_determinism.rs`
//! pin this. See `docs/sim-engine.md` for the full contract.
//!
//! # Invariants (established in `advance`, relied on everywhere)
//!
//! 1. Every wheel-resident event shares the cursor's epoch
//!    (`t >> SPAN_BITS == cur >> SPAN_BITS`); far-heap events are in
//!    strictly later epochs, hence strictly later than all wheel events.
//! 2. Level-0 events share the cursor's 256-tick block, so a level-0
//!    bucket holds exactly one tick and its FIFO chain is already in
//!    `(t, seq)` order.
//! 3. The cursor only enters a block by cascading that block's bucket
//!    first, so equal-time events always land in the same chain in seq
//!    order (a later push can never file "below" an earlier equal-time
//!    event).
//! 4. `push` times never precede the cursor: the engine only pushes at or
//!    after the time of the event it is processing, and the cursor is
//!    bounded by the pending wake minimum while one exists.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::ids::{CoreId, Cycles};
use crate::sim::event::{Event, Queued};

/// log2 of the bucket count per level.
const BITS: u32 = 8;
/// Buckets per level.
const SLOTS: usize = 1 << BITS;
/// Mask selecting a level-0 bucket index.
const SLOT_MASK: u64 = (SLOTS as u64) - 1;
/// Wheel levels; beyond them events overflow to the far heap.
const LEVELS: usize = 3;
/// Total wheel span in bits: events within `2^SPAN_BITS` cycles of the
/// cursor's epoch base live in the wheel.
const SPAN_BITS: u32 = BITS * LEVELS as u32;

/// Null link in the node slab.
const NIL: u32 = u32::MAX;

/// One queued event in the slab. `next` chains bucket membership (or the
/// free list once popped). Roughly two cache lines: `Queued`'s fields
/// (budgeted by the const asserts in `sim::event`) plus the `u32` link.
struct Node {
    t: Cycles,
    seq: u64,
    core: CoreId,
    ev: Event,
    next: u32,
}

/// Head/tail of one bucket's FIFO chain.
#[derive(Clone, Copy)]
struct Slot {
    head: u32,
    tail: u32,
}

impl Slot {
    const EMPTY: Slot = Slot { head: NIL, tail: NIL };
}

/// 256-bit occupancy bitmap: which buckets of a level are non-empty.
/// `next_from` is a couple of word scans — this is what makes "find the
/// next event tick" O(1) instead of a 256-slot walk.
#[derive(Clone, Copy, Default)]
struct Occupancy {
    words: [u64; SLOTS / 64],
}

impl Occupancy {
    #[inline]
    fn set(&mut self, i: usize) {
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    fn clear(&mut self, i: usize) {
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }

    /// Smallest set bit with index >= `from`, if any.
    #[inline]
    fn next_from(&self, from: usize) -> Option<usize> {
        let mut wi = from >> 6;
        if wi >= self.words.len() {
            return None;
        }
        let mut word = self.words[wi] & (!0u64 << (from & 63));
        loop {
            if word != 0 {
                return Some((wi << 6) + word.trailing_zeros() as usize);
            }
            wi += 1;
            if wi == self.words.len() {
                return None;
            }
            word = self.words[wi];
        }
    }
}

/// Far-future event key: `(t, seq, node index)`; the payload stays in the
/// node slab. Wrapped in [`Reverse`] so `BinaryHeap` (a max-heap) pops
/// the earliest `(t, seq)`.
type FarEntry = (Cycles, u64, u32);

/// Busy-core drain marker key: `(t, seq, core)` (see `Engine::run`).
type WakeEntry = (Cycles, u64, CoreId);

/// What a [`EventQ::pop`] yielded: a real event, or a busy-core drain
/// marker (the engine turns the latter into one deferred-event delivery).
pub enum Popped {
    Ev(Queued),
    Wake { t: Cycles, seq: u64, core: CoreId },
}

/// The simulator's event queue: hierarchical timing wheel + far heap +
/// wake side-heap. See the module docs for the determinism contract.
pub struct EventQ {
    nodes: Vec<Node>,
    /// Free-list head into `nodes`.
    free: u32,
    /// Cursor: lower bound on every queued event's time (and exactly the
    /// tick of the level-0 bucket about to be popped after `advance`).
    cur: Cycles,
    /// Events currently resident in wheel buckets (not far, not wakes).
    in_wheel: usize,
    /// `LEVELS * SLOTS` bucket chains, level-major.
    slots: Vec<Slot>,
    occ: [Occupancy; LEVELS],
    far: BinaryHeap<Reverse<FarEntry>>,
    wakes: BinaryHeap<Reverse<WakeEntry>>,
}

/// Which wheel level `t` files under, relative to cursor `cur`
/// (`None` = beyond the span, go to the far heap).
#[inline]
fn level_for(cur: Cycles, t: Cycles) -> Option<usize> {
    let x = cur ^ t;
    if x >> SPAN_BITS != 0 {
        None
    } else if x >> (2 * BITS) != 0 {
        Some(2)
    } else if x >> BITS != 0 {
        Some(1)
    } else {
        Some(0)
    }
}

impl Default for EventQ {
    fn default() -> Self {
        Self::new()
    }
}

impl EventQ {
    pub fn new() -> Self {
        EventQ {
            nodes: Vec::new(),
            free: NIL,
            cur: 0,
            in_wheel: 0,
            slots: vec![Slot::EMPTY; LEVELS * SLOTS],
            occ: [Occupancy::default(); LEVELS],
            far: BinaryHeap::new(),
            wakes: BinaryHeap::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.in_wheel + self.far.len() + self.wakes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue an event. `seq` must be globally unique and monotone (the
    /// engine's single counter) — it is the determinism tie-breaker.
    pub fn push(&mut self, t: Cycles, seq: u64, core: CoreId, ev: Event) {
        debug_assert!(t >= self.cur, "push at {t} behind cursor {}", self.cur);
        let node = self.alloc(t, seq, core, ev);
        match level_for(self.cur, t) {
            Some(level) => self.link(level, node),
            None => self.far.push(Reverse((t, seq, node))),
        }
    }

    /// Enqueue a busy-core drain marker. Never touches the wheel or the
    /// slab — wakes live in their own (tiny) heap, keyed like events so
    /// the merged pop order is the old single-queue order.
    pub fn push_wake(&mut self, t: Cycles, seq: u64, core: CoreId) {
        self.wakes.push(Reverse((t, seq, core)));
    }

    /// Dequeue the globally earliest `(t, seq)` item.
    pub fn pop(&mut self) -> Option<Popped> {
        let bound = self.wakes.peek().map(|Reverse(w)| w.0);
        let ev_key = if self.advance(bound) {
            let head = self.slots[(self.cur & SLOT_MASK) as usize].head;
            let n = &self.nodes[head as usize];
            debug_assert_eq!(n.t, self.cur);
            Some((n.t, n.seq))
        } else {
            None
        };
        let wake_key = self.wakes.peek().map(|Reverse(w)| (w.0, w.1));
        match (ev_key, wake_key) {
            (None, None) => None,
            (Some(_), None) => Some(self.pop_event()),
            (None, Some(_)) => Some(self.pop_wake()),
            (Some(e), Some(w)) => {
                if e < w {
                    Some(self.pop_event())
                } else {
                    Some(self.pop_wake())
                }
            }
        }
    }

    // ---------------------------------------------------------- internals

    fn alloc(&mut self, t: Cycles, seq: u64, core: CoreId, ev: Event) -> u32 {
        if self.free != NIL {
            let i = self.free;
            let n = &mut self.nodes[i as usize];
            self.free = n.next;
            n.t = t;
            n.seq = seq;
            n.core = core;
            n.ev = ev;
            n.next = NIL;
            i
        } else {
            assert!(self.nodes.len() < NIL as usize, "event queue slab overflow");
            self.nodes.push(Node { t, seq, core, ev, next: NIL });
            (self.nodes.len() - 1) as u32
        }
    }

    /// Unlink a node's payload and return it to the free list. The parked
    /// `Event::Wake` placeholder keeps freed slots from pinning message
    /// payloads (descriptors, range lists) alive.
    fn release(&mut self, i: u32) -> Queued {
        let n = &mut self.nodes[i as usize];
        let ev = std::mem::replace(&mut n.ev, Event::Wake);
        let q = Queued { t: n.t, seq: n.seq, core: n.core, ev };
        n.next = self.free;
        self.free = i;
        q
    }

    /// Append `node` to its bucket at `level` (bucket index = the level's
    /// bit-block of the node's time).
    fn link(&mut self, level: usize, node: u32) {
        let t = self.nodes[node as usize].t;
        let s = ((t >> (BITS * level as u32)) & SLOT_MASK) as usize;
        let slot = &mut self.slots[level * SLOTS + s];
        if slot.head == NIL {
            slot.head = node;
            slot.tail = node;
            self.occ[level].set(s);
        } else {
            let tail = slot.tail;
            slot.tail = node;
            self.nodes[tail as usize].next = node;
        }
        self.in_wheel += 1;
    }

    /// Re-file every event of bucket `(level, s)` one or two levels down,
    /// preserving chain (= seq) order. Called with the cursor already set
    /// to the bucket's block start.
    fn cascade(&mut self, level: usize, s: usize) {
        let idx = level * SLOTS + s;
        let mut node = self.slots[idx].head;
        self.slots[idx] = Slot::EMPTY;
        self.occ[level].clear(s);
        while node != NIL {
            let next = self.nodes[node as usize].next;
            self.nodes[node as usize].next = NIL;
            self.in_wheel -= 1;
            let t = self.nodes[node as usize].t;
            let l = level_for(self.cur, t).expect("cascaded event within span");
            debug_assert!(l < level);
            self.link(l, node);
            node = next;
        }
    }

    /// Position the cursor on the earliest event tick, cascading and
    /// refilling as needed. Returns false if there is no event at all, or
    /// none at or before `bound` (the pending-wake minimum). Every step
    /// checks its candidate time against `bound` *before* moving the
    /// cursor, so while cascades along the way may advance it, the cursor
    /// never passes `bound` — a wake due first is never overtaken, and a
    /// push at the drained wake's time stays legal (invariant 4).
    fn advance(&mut self, bound: Option<Cycles>) -> bool {
        let beyond = |t: Cycles| bound.is_some_and(|b| t > b);
        loop {
            if self.in_wheel > 0 {
                // Level 0: buckets are single ticks of the cursor's block.
                let base = (self.cur & SLOT_MASK) as usize;
                if let Some(s) = self.occ[0].next_from(base) {
                    let t0 = (self.cur & !SLOT_MASK) | s as u64;
                    if beyond(t0) {
                        return false;
                    }
                    self.cur = t0;
                    return true;
                }
                // Level 1: every occupied bucket is strictly ahead of the
                // cursor's level-1 block; the smallest index is earliest.
                if let Some(s1) = self.occ[1].next_from(0) {
                    let block = (self.cur & !((1u64 << (2 * BITS)) - 1)) | ((s1 as u64) << BITS);
                    if beyond(block) {
                        return false;
                    }
                    self.cur = block;
                    self.cascade(1, s1);
                    continue;
                }
                // Level 2 likewise.
                if let Some(s2) = self.occ[2].next_from(0) {
                    let block =
                        (self.cur & !((1u64 << SPAN_BITS) - 1)) | ((s2 as u64) << (2 * BITS));
                    if beyond(block) {
                        return false;
                    }
                    self.cur = block;
                    self.cascade(2, s2);
                    continue;
                }
                unreachable!("wheel count positive but no occupied bucket");
            }
            // Wheel empty: jump the cursor to the far heap's minimum and
            // pull its whole epoch in (each far event re-files exactly
            // once — this is the lazy refill).
            let Some(far_t) = self.far.peek().map(|Reverse(e)| e.0) else {
                return false;
            };
            if beyond(far_t) {
                return false;
            }
            self.cur = far_t;
            while let Some(&Reverse((t, _, _))) = self.far.peek() {
                if (t ^ self.cur) >> SPAN_BITS != 0 {
                    break;
                }
                let Reverse((t, _, node)) = self.far.pop().expect("peeked entry");
                let level = level_for(self.cur, t).expect("same epoch");
                self.link(level, node);
            }
        }
    }

    /// Pop the head of the cursor's level-0 bucket (valid directly after
    /// `advance` returned true).
    fn pop_event(&mut self) -> Popped {
        let s = (self.cur & SLOT_MASK) as usize;
        let slot = &mut self.slots[s];
        let i = slot.head;
        debug_assert_ne!(i, NIL);
        let next = self.nodes[i as usize].next;
        slot.head = next;
        if next == NIL {
            slot.tail = NIL;
            self.occ[0].clear(s);
        }
        self.in_wheel -= 1;
        Popped::Ev(self.release(i))
    }

    fn pop_wake(&mut self) -> Popped {
        let Reverse((t, seq, core)) = self.wakes.pop().expect("wake heap non-empty");
        Popped::Wake { t, seq, core }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(p: Popped) -> (Cycles, u64, bool) {
        match p {
            Popped::Ev(q) => (q.t, q.seq, false),
            Popped::Wake { t, seq, .. } => (t, seq, true),
        }
    }

    fn push_ev(q: &mut EventQ, t: Cycles, seq: u64) {
        q.push(t, seq, CoreId(0), Event::Boot);
    }

    #[test]
    fn pops_in_time_order_across_levels() {
        let mut q = EventQ::new();
        // One event per level of the wheel plus one far-heap event.
        for (seq, t) in [(0u64, 300_000u64), (1, 3), (2, 70_000), (3, 40_000_000), (4, 260)] {
            push_ev(&mut q, t, seq);
        }
        let order: Vec<Cycles> = std::iter::from_fn(|| q.pop().map(|p| key(p).0)).collect();
        assert_eq!(order, vec![3, 260, 70_000, 300_000, 40_000_000]);
        assert!(q.is_empty());
    }

    #[test]
    fn same_tick_ties_pop_in_seq_order() {
        let mut q = EventQ::new();
        // Same tick pushed out of nothing — seq order must hold, including
        // for ties that start out at an upper level and cascade down.
        for seq in 0..5u64 {
            push_ev(&mut q, 100_000, seq);
        }
        for seq in 5..8u64 {
            push_ev(&mut q, 0, seq);
        }
        let keys: Vec<(Cycles, u64, bool)> =
            std::iter::from_fn(|| q.pop().map(key)).collect();
        assert_eq!(
            keys,
            vec![
                (0, 5, false),
                (0, 6, false),
                (0, 7, false),
                (100_000, 0, false),
                (100_000, 1, false),
                (100_000, 2, false),
                (100_000, 3, false),
                (100_000, 4, false),
            ]
        );
    }

    #[test]
    fn far_heap_refills_lazily() {
        let mut q = EventQ::new();
        // Two epochs beyond the span, interleaved pushes.
        push_ev(&mut q, 50_000_000, 0);
        push_ev(&mut q, 10, 1);
        push_ev(&mut q, 50_000_001, 2);
        push_ev(&mut q, 34_000_000, 3);
        let order: Vec<(Cycles, u64, bool)> =
            std::iter::from_fn(|| q.pop().map(key)).collect();
        assert_eq!(
            order,
            vec![(10, 1, false), (34_000_000, 3, false), (50_000_000, 0, false), (50_000_001, 2, false)]
        );
    }

    #[test]
    fn wakes_merge_by_seq_and_never_stall_cursor() {
        let mut q = EventQ::new();
        push_ev(&mut q, 100, 0);
        q.push_wake(50, 1, CoreId(7));
        // Wake at t=50 must come out before the event at t=100, and the
        // cursor must not have run past 50: a push at 60 afterwards (as the
        // engine does from the drained handler) must still be accepted and
        // ordered correctly.
        assert_eq!(key(q.pop().unwrap()), (50, 1, true));
        push_ev(&mut q, 60, 2);
        assert_eq!(key(q.pop().unwrap()), (60, 2, false));
        assert_eq!(key(q.pop().unwrap()), (100, 0, false));
        assert!(q.pop().is_none());
    }

    #[test]
    fn wake_ties_with_event_resolve_by_seq() {
        let mut q = EventQ::new();
        push_ev(&mut q, 10, 0);
        q.push_wake(10, 1, CoreId(1));
        push_ev(&mut q, 10, 2);
        assert_eq!(key(q.pop().unwrap()), (10, 0, false));
        assert_eq!(key(q.pop().unwrap()), (10, 1, true));
        assert_eq!(key(q.pop().unwrap()), (10, 2, false));
    }

    #[test]
    fn slab_is_reused_after_drain() {
        let mut q = EventQ::new();
        for round in 0..4u64 {
            for i in 0..64u64 {
                push_ev(&mut q, round * 1000 + i, round * 64 + i);
            }
            for _ in 0..64 {
                assert!(q.pop().is_some());
            }
        }
        // All four rounds fit in the slab allocated for the first.
        assert_eq!(q.nodes.len(), 64);
        assert!(q.is_empty());
    }

    #[test]
    fn interleaved_push_pop_keeps_global_order() {
        // Engine-like usage: every pop may push new events at or after the
        // popped time.
        let mut q = EventQ::new();
        let mut seq = 0u64;
        for i in 0..8u64 {
            push_ev(&mut q, i * 17, seq);
            seq += 1;
        }
        let mut last = 0;
        let mut popped = 0;
        while let Some(p) = q.pop() {
            let (t, _, _) = key(p);
            assert!(t >= last);
            last = t;
            popped += 1;
            if seq < 40 {
                push_ev(&mut q, t + 1 + (seq % 3) * 90_000, seq);
                seq += 1;
            }
        }
        assert_eq!(popped, 40);
        assert!(last > 0, "time advanced over the run");
    }

    #[test]
    fn occupancy_next_from() {
        let mut o = Occupancy::default();
        assert_eq!(o.next_from(0), None);
        o.set(3);
        o.set(64);
        o.set(255);
        assert_eq!(o.next_from(0), Some(3));
        assert_eq!(o.next_from(3), Some(3));
        assert_eq!(o.next_from(4), Some(64));
        assert_eq!(o.next_from(65), Some(255));
        assert_eq!(o.next_from(255), Some(255));
        o.clear(255);
        assert_eq!(o.next_from(65), None);
    }
}

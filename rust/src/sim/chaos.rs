//! Deterministic fault injection (robustness harness substrate).
//!
//! A [`FaultPlan`] perturbs a run *within legal bounds*: message latency
//! jitter, transient credit starvation, bounded scheduler stalls and
//! forced steal denies. Every perturbation flows through the existing
//! event/cost seams — faults never invent, drop or corrupt messages, they
//! only shift when things happen — so a faulted run must still satisfy
//! every protocol invariant (`testutil/oracles.rs`) and must replay
//! bit-identically from `(seed, plan)`.
//!
//! Determinism contract (same as `sched/policy.rs`): all randomness
//! derives from `PlatformConfig::seed` and the plan seed through
//! [`crate::sim::rng::Rng`] on a dedicated stream mixer — never host
//! entropy, never time. [`FaultPlan::none()`] keeps the engine on the
//! exact pre-fault code paths (zero extra RNG draws, zero extra events),
//! so disabled runs stay byte-identical to a build without this module —
//! pinned by the untouched fingerprints in `tests/determinism.rs`.
//!
//! Hot-path invariant: fault state lives in dense per-link tables sized
//! once at install; the steady state allocates nothing.

use crate::ids::{CoreId, Cycles};
use crate::sim::rng::Rng;

/// Stream mixer for the chaos RNG — a third odd constant, distinct from
/// the placement (p2c) and victim-selection streams in `sched/policy.rs`,
/// so fault draws never correlate with policy draws.
pub const CHAOS_STREAM: u64 = 0xC2B2_AE3D_27D4_EB4F;

/// Stream mixer for the crash-schedule RNG — decorrelated from
/// [`CHAOS_STREAM`] so adding crash faults to a plan never shifts the
/// jitter/starve/stall/deny draws of the same `(seed, plan)` pair.
pub const CRASH_STREAM: u64 = 0x9E37_79B9_7F4A_7C15;

/// Per-shard lane mixer: with the engine sharded, shard `k` draws from
/// `Rng::new(base ^ (k+1) * SHARD_STREAM)` where `base` is the run's
/// chaos stream. Fault draws then depend only on `(run seed, plan seed,
/// shard, shard-local event order)` — never on the global pop
/// interleaving — which is what makes chaos schedules invariant across
/// *thread* counts at a fixed shard count (each worker replays its
/// shard's event order exactly, so it replays its lane's draw order
/// exactly).
pub const SHARD_STREAM: u64 = 0xD6E8_FEB8_6659_FD93;

/// Message class seen by the class-targeted delay knobs. Classification
/// happens in the engine (which owns the `Msg`); chaos only draws.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MsgClass {
    /// `LoadReport` / `QuiesceUp`: books and region-teardown traffic.
    Report,
    /// `StealGrant`: migration payloads, racing fresh spawns.
    Grant,
    Other,
}

/// A deterministic scheduler crash derived from `(run seed, plan seed)`:
/// which scheduler dies, when, and whether/when it comes back.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CrashSchedule {
    /// Scheduler index (into the hierarchy's eligible-victim list).
    pub victim: usize,
    /// Cycle at which the scheduler goes dark.
    pub at: Cycles,
    /// Cycle at which it restarts with fresh volatile state; `None`
    /// means permanent death.
    pub up_at: Option<Cycles>,
}

/// A bounded, seed-derived fault schedule. All knobs are rates (percent)
/// or cycle caps; `enabled == false` (the [`FaultPlan::none`] default)
/// short-circuits every hook before any RNG draw.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    /// Master switch. False = the engine behaves byte-identically to a
    /// build without fault injection.
    pub enabled: bool,
    /// Identifies the plan (for reproducer lines and the RNG stream).
    pub plan_seed: u64,
    /// Percent of message deliveries that gain extra latency.
    pub jitter_pct: u32,
    /// Max extra delivery latency, cycles (each jitter draws `1..=max`).
    pub jitter_max: Cycles,
    /// Percent of credited sends forcibly starved (parked in the blocked
    /// queue) even when a credit is available. Only applied while the
    /// channel has messages in flight, so a future release always
    /// unblocks the parked send — starvation is transient by design.
    pub starve_pct: u32,
    /// Percent of scheduler events preceded by a bounded stall.
    pub stall_pct: u32,
    /// Max stall length, cycles.
    pub stall_max: Cycles,
    /// Percent of steal requests denied even when the victim has work.
    pub deny_pct: u32,
    /// Unconditionally deny this many steal requests before `deny_pct`
    /// takes over — pins the "first victim always denies" retry path.
    pub deny_first: u32,
    /// Percent chance the run schedules a scheduler crash. Crashes only
    /// fire when `RecoveryCfg::enabled` is also set — without the
    /// recovery protocol a dead scheduler would simply orphan its
    /// subtree, which is a feature gap, not a fault to fuzz.
    pub crash_pct: u32,
    /// Upper bound on the crash time, cycles (drawn `1..=max`).
    pub crash_max: Cycles,
    /// Upper bound on the down window before restart, cycles.
    pub crash_down: Cycles,
    /// Percent chance the crash is permanent (no restart; the parent
    /// keeps the re-adopted subtree forever).
    pub crash_perm_pct: u32,
    /// Percent of `LoadReport`/`QuiesceUp` deliveries given extra delay
    /// beyond generic jitter — races quiescence against region teardown.
    pub report_delay_pct: u32,
    pub report_delay_max: Cycles,
    /// Percent of `StealGrant` deliveries given extra delay — widens the
    /// window in which adversarial spawns land while a grant is in
    /// flight.
    pub grant_delay_pct: u32,
    pub grant_delay_max: Cycles,
}

impl FaultPlan {
    /// No faults; runs are byte-identical to the pre-chaos engine.
    pub fn none() -> Self {
        FaultPlan {
            enabled: false,
            plan_seed: 0,
            jitter_pct: 0,
            jitter_max: 0,
            starve_pct: 0,
            stall_pct: 0,
            stall_max: 0,
            deny_pct: 0,
            deny_first: 0,
            crash_pct: 0,
            crash_max: 0,
            crash_down: 0,
            crash_perm_pct: 0,
            report_delay_pct: 0,
            report_delay_max: 0,
            grant_delay_pct: 0,
            grant_delay_max: 0,
        }
    }

    /// Derive a legal-bounds plan from a plan seed. Plan 0 is reserved
    /// for "no faults" so `--plan 0` reproduces the clean baseline; any
    /// other value yields an enabled plan whose knobs are a pure function
    /// of the seed. Jitter is always on (≥ 10%) so every derived plan
    /// genuinely perturbs the schedule; the other fault classes may be
    /// individually absent.
    pub fn from_seed(plan_seed: u64) -> Self {
        if plan_seed == 0 {
            return Self::none();
        }
        let mut r = Rng::new(plan_seed.wrapping_mul(CHAOS_STREAM) | 1);
        FaultPlan {
            enabled: true,
            plan_seed,
            jitter_pct: 10 + r.below(41) as u32,
            jitter_max: 1 + r.below(5_000),
            starve_pct: r.below(26) as u32,
            stall_pct: r.below(31) as u32,
            stall_max: 1 + r.below(20_000),
            deny_pct: r.below(51) as u32,
            deny_first: r.below(3) as u32,
            // Drawn after the original knobs so pre-crash plans keep the
            // exact values they had when their reproducer lines were
            // recorded.
            crash_pct: r.below(61) as u32,
            crash_max: 50_000 + r.below(1_450_001),
            crash_down: 100_000 + r.below(900_001),
            crash_perm_pct: r.below(26) as u32,
            report_delay_pct: r.below(41) as u32,
            report_delay_max: 1 + r.below(50_000),
            grant_delay_pct: r.below(41) as u32,
            grant_delay_max: 1 + r.below(50_000),
        }
    }

    /// Derive the run's crash schedule, or `None` when the dice say no
    /// crash or there is no eligible victim. `eligible` is the list of
    /// crash-eligible scheduler indices (leaf schedulers whose parent has
    /// a surviving sibling to re-place orphans onto); the platform only
    /// calls this when recovery is enabled. A separate RNG stream keeps
    /// the jitter/stall/deny draws of the same plan untouched.
    pub fn crash_schedule(&self, run_seed: u64, eligible: &[usize]) -> Option<CrashSchedule> {
        if !self.enabled || self.crash_pct == 0 || eligible.is_empty() {
            return None;
        }
        let stream =
            run_seed ^ self.plan_seed.wrapping_add(1).wrapping_mul(CRASH_STREAM);
        let mut r = Rng::new(stream | 1);
        if r.below(100) >= self.crash_pct as u64 {
            return None;
        }
        let victim = eligible[r.below(eligible.len() as u64) as usize];
        let at = 1 + r.below(self.crash_max.max(1));
        let up_at = if r.below(100) < self.crash_perm_pct as u64 {
            None
        } else {
            Some(at + 1 + r.below(self.crash_down.max(1)))
        };
        Some(CrashSchedule { victim, at, up_at })
    }
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self::none()
    }
}

/// One shard's draw lane: its own RNG stream, deny countdown and
/// injection counters. Unsharded runs have exactly one lane (index 0)
/// seeded from the legacy stream, so their draw sequence is
/// byte-identical to the pre-lane code. Sharded runs get one lane per
/// shard (see [`SHARD_STREAM`]); in the threaded executor each worker
/// only ever touches its own shard's lane, so lanes are also the unit of
/// thread disjointness.
#[derive(Debug)]
struct Lane {
    rng: Rng,
    denies_left: u32,
    jitters: u64,
    starves: u64,
    stalls: u64,
    forced_denies: u64,
    report_delays: u64,
    grant_delays: u64,
}

impl Lane {
    fn new(rng: Rng, denies_left: u32) -> Self {
        Lane {
            rng,
            denies_left,
            jitters: 0,
            starves: 0,
            stalls: 0,
            forced_denies: 0,
            report_delays: 0,
            grant_delays: 0,
        }
    }
}

/// Per-run fault state: the plan, its RNG lanes and the dense per-link
/// delivery-floor table that preserves per-link FIFO order under jitter.
/// Sized once at install; no steady-state allocation.
///
/// Every draw method takes the *shard lane* of the core on whose behalf
/// the draw is made (the sender for send-side draws, the event's core
/// for stalls/denies); unsharded runs pass 0. The `link_last` floor
/// table stays global: a directed (from, hop) row is only ever touched
/// by one shard inline (same-shard link) or by the single-threaded
/// barrier walk (cross-shard link), so rows are disjoint by discipline.
#[derive(Debug)]
pub struct ChaosState {
    plan: FaultPlan,
    /// Base RNG stream (run seed x plan seed); lanes derive from it.
    stream: u64,
    n: usize,
    /// Last delivery time pushed per directed (from, hop) link. Jittered
    /// deliveries clamp to this floor so same-link messages never
    /// reorder — per-link FIFO is load-bearing (decay-then-overwrite
    /// load accounting, dependency-protocol ordering).
    link_last: Vec<Cycles>,
    lanes: Vec<Lane>,
    msgs_requeued: u64,
}

impl ChaosState {
    /// Inert state: `active()` is false and no table is allocated.
    pub fn disabled() -> Self {
        ChaosState {
            plan: FaultPlan::none(),
            stream: 1,
            n: 0,
            link_last: Vec::new(),
            lanes: vec![Lane::new(Rng::new(1), 0)],
            msgs_requeued: 0,
        }
    }

    /// Build the fault state for a run: the RNG stream mixes the run
    /// seed with the plan seed so `(seed, plan)` fully determines every
    /// draw.
    pub fn new(plan: FaultPlan, run_seed: u64, n_cores: usize) -> Self {
        let stream =
            run_seed ^ plan.plan_seed.wrapping_add(1).wrapping_mul(CHAOS_STREAM);
        let denies_left = plan.deny_first;
        ChaosState {
            stream,
            n: n_cores,
            link_last: vec![0; n_cores * n_cores],
            lanes: vec![Lane::new(Rng::new(stream), denies_left)],
            msgs_requeued: 0,
            plan,
        }
    }

    /// Split the single draw stream into one decorrelated lane per shard
    /// (no-op for `shards <= 1`, keeping unsharded runs on the legacy
    /// stream). Called at platform build when the engine is sharded, so
    /// draws depend only on shard-local event order — the chaos half of
    /// the thread-invariance contract. Note `deny_first` becomes a
    /// *per-shard* countdown in this regime (each lane denies its first
    /// `deny_first` requests); the `steal_reqs == grants + denies` books
    /// are unaffected.
    pub fn set_shards(&mut self, shards: usize) {
        if shards <= 1 || !self.plan.enabled {
            return;
        }
        let (stream, denies) = (self.stream, self.plan.deny_first);
        self.lanes = (0..shards)
            .map(|k| {
                Lane::new(
                    Rng::new(stream ^ (k as u64 + 1).wrapping_mul(SHARD_STREAM)),
                    denies,
                )
            })
            .collect();
    }

    #[inline]
    fn lane(&mut self, shard: usize) -> &mut Lane {
        let i = shard.min(self.lanes.len() - 1);
        &mut self.lanes[i]
    }

    /// Whether any fault hook should run. The engine gates every chaos
    /// call on this, keeping disabled runs on the exact pre-fault paths.
    #[inline]
    pub fn active(&self) -> bool {
        self.plan.enabled
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Draw-only half of the generic delivery jitter: the extra latency
    /// for one delivery, 0 when the dice say no jitter. Split from the
    /// FIFO clamp so the threaded executor can draw at *send* time on
    /// the sender's lane and apply the (draw-free) floor later, at the
    /// canonical merge point. Must only be called when `active()`.
    pub fn jitter_extra(&mut self, shard: usize) -> Cycles {
        let pct = self.plan.jitter_pct;
        let max = self.plan.jitter_max.max(1);
        let lane = self.lane(shard);
        if pct > 0 && lane.rng.below(100) < pct as u64 {
            lane.jitters += 1;
            1 + lane.rng.below(max)
        } else {
            0
        }
    }

    /// Draw-free half: clamp arrival `t` on link (from → hop) to the
    /// link's delivery floor (per-link FIFO) and advance the floor.
    pub fn fifo_floor(&mut self, from: CoreId, hop: CoreId, mut t: Cycles) -> Cycles {
        let key = from.idx() * self.n + hop.idx();
        if t < self.link_last[key] {
            t = self.link_last[key];
        }
        self.link_last[key] = t;
        t
    }

    /// Final delivery time for a message on link (from → hop), given the
    /// undisturbed arrival `at`. Applies jitter, then clamps to the
    /// link's delivery floor so per-link FIFO order is preserved.
    /// Must only be called when `active()`.
    pub fn delivery_time(&mut self, from: CoreId, hop: CoreId, at: Cycles, shard: usize) -> Cycles {
        let t = at + self.jitter_extra(shard);
        self.fifo_floor(from, hop, t)
    }

    /// Extra class-targeted delivery delay for a message of `class`,
    /// applied *before* the generic jitter + FIFO clamp in
    /// [`Self::delivery_time`] (so per-link order still holds). Draws
    /// only when the matching knob is armed, keeping plans without these
    /// knobs on their original draw sequence. Must only be called when
    /// `active()`.
    pub fn class_delay(&mut self, class: MsgClass, shard: usize) -> Cycles {
        let plan = self.plan.clone();
        let lane = self.lane(shard);
        match class {
            MsgClass::Report if plan.report_delay_pct > 0 => {
                if lane.rng.below(100) < plan.report_delay_pct as u64 {
                    lane.report_delays += 1;
                    1 + lane.rng.below(plan.report_delay_max.max(1))
                } else {
                    0
                }
            }
            MsgClass::Grant if plan.grant_delay_pct > 0 => {
                if lane.rng.below(100) < plan.grant_delay_pct as u64 {
                    lane.grant_delays += 1;
                    1 + lane.rng.below(plan.grant_delay_max.max(1))
                } else {
                    0
                }
            }
            _ => 0,
        }
    }

    /// Record a message re-parked in a dead scheduler's mailbox (engine
    /// crash path).
    pub fn note_requeued(&mut self) {
        self.msgs_requeued += 1;
    }

    /// Draw the transient-starvation decision for a credited send. The
    /// caller applies it only when the channel has in-flight messages
    /// (so a release is guaranteed to unpark the send later).
    pub fn draw_starve(&mut self, shard: usize) -> bool {
        let pct = self.plan.starve_pct;
        pct > 0 && self.lane(shard).rng.below(100) < pct as u64
    }

    /// Record that a send was actually parked by a starvation fault.
    pub fn note_starved(&mut self, shard: usize) {
        self.lane(shard).starves += 1;
    }

    /// Bounded scheduler stall for the current event: 0 = no stall.
    pub fn stall(&mut self, shard: usize) -> Cycles {
        let pct = self.plan.stall_pct;
        let max = self.plan.stall_max.max(1);
        let lane = self.lane(shard);
        if pct == 0 || lane.rng.below(100) >= pct as u64 {
            return 0;
        }
        lane.stalls += 1;
        1 + lane.rng.below(max)
    }

    /// Whether the victim must deny this steal request regardless of its
    /// queue depth: the first `deny_first` requests always deny, then
    /// `deny_pct` applies.
    pub fn force_deny(&mut self, shard: usize) -> bool {
        let pct = self.plan.deny_pct;
        let lane = self.lane(shard);
        if lane.denies_left > 0 {
            lane.denies_left -= 1;
            lane.forced_denies += 1;
            return true;
        }
        if pct > 0 && lane.rng.below(100) < pct as u64 {
            lane.forced_denies += 1;
            return true;
        }
        false
    }

    pub fn jitters(&self) -> u64 {
        self.lanes.iter().map(|l| l.jitters).sum()
    }
    pub fn starves(&self) -> u64 {
        self.lanes.iter().map(|l| l.starves).sum()
    }
    pub fn stalls(&self) -> u64 {
        self.lanes.iter().map(|l| l.stalls).sum()
    }
    pub fn forced_denies(&self) -> u64 {
        self.lanes.iter().map(|l| l.forced_denies).sum()
    }
    pub fn report_delays(&self) -> u64 {
        self.lanes.iter().map(|l| l.report_delays).sum()
    }
    pub fn grant_delays(&self) -> u64 {
        self.lanes.iter().map(|l| l.grant_delays).sum()
    }
    pub fn msgs_requeued(&self) -> u64 {
        self.msgs_requeued
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_zero_is_none_and_default_is_inert() {
        assert_eq!(FaultPlan::from_seed(0), FaultPlan::none());
        assert_eq!(FaultPlan::default(), FaultPlan::none());
        assert!(!FaultPlan::none().enabled);
        assert!(!ChaosState::disabled().active());
    }

    #[test]
    fn from_seed_is_deterministic_and_bounded() {
        for s in 1..64u64 {
            let a = FaultPlan::from_seed(s);
            let b = FaultPlan::from_seed(s);
            assert_eq!(a, b, "plan derivation must be pure");
            assert!(a.enabled);
            assert!((10..=50).contains(&a.jitter_pct), "{a:?}");
            assert!((1..=5_000).contains(&a.jitter_max), "{a:?}");
            assert!(a.starve_pct <= 25, "{a:?}");
            assert!(a.stall_pct <= 30, "{a:?}");
            assert!((1..=20_000).contains(&a.stall_max), "{a:?}");
            assert!(a.deny_pct <= 50, "{a:?}");
            assert!(a.deny_first <= 2, "{a:?}");
            assert!(a.crash_pct <= 60, "{a:?}");
            assert!((50_000..=1_500_000).contains(&a.crash_max), "{a:?}");
            assert!((100_000..=1_000_000).contains(&a.crash_down), "{a:?}");
            assert!(a.crash_perm_pct <= 25, "{a:?}");
            assert!(a.report_delay_pct <= 40, "{a:?}");
            assert!((1..=50_000).contains(&a.report_delay_max), "{a:?}");
            assert!(a.grant_delay_pct <= 40, "{a:?}");
            assert!((1..=50_000).contains(&a.grant_delay_max), "{a:?}");
        }
        assert_ne!(
            FaultPlan::from_seed(1),
            FaultPlan::from_seed(2),
            "different seeds should generally differ"
        );
    }

    #[test]
    fn jitter_preserves_per_link_fifo() {
        let plan = FaultPlan { jitter_pct: 100, ..FaultPlan::from_seed(7) };
        let mut st = ChaosState::new(plan, 0xB5EED, 4);
        let (a, b) = (CoreId(0), CoreId(1));
        let mut last = 0;
        for t in (0..400).step_by(3) {
            let d = st.delivery_time(a, b, t, 0);
            assert!(d >= t, "jitter only delays");
            assert!(d >= last, "same-link deliveries must never reorder");
            last = d;
        }
        assert!(st.jitters() > 0);
        // An independent link has its own floor.
        let d = st.delivery_time(b, a, 1, 0);
        assert!(d >= 1);
    }

    #[test]
    fn deny_first_counts_down_then_rate_applies() {
        let plan = FaultPlan {
            deny_first: 2,
            deny_pct: 0,
            ..FaultPlan::from_seed(3)
        };
        let mut st = ChaosState::new(plan, 0xB5EED, 2);
        assert!(st.force_deny(0));
        assert!(st.force_deny(0));
        assert!(!st.force_deny(0), "deny_pct 0: no denies after the countdown");
        assert_eq!(st.forced_denies(), 2);
    }

    #[test]
    fn crash_schedule_is_pure_and_bounded() {
        let plan = FaultPlan { crash_pct: 100, ..FaultPlan::from_seed(11) };
        let eligible = [1usize, 2, 3];
        let a = plan.crash_schedule(0xFEED, &eligible);
        let b = plan.crash_schedule(0xFEED, &eligible);
        assert_eq!(a, b, "crash schedule must be pure in (seed, plan)");
        let s = a.expect("crash_pct 100 must schedule a crash");
        assert!(eligible.contains(&s.victim));
        assert!(s.at >= 1 && s.at <= plan.crash_max);
        if let Some(u) = s.up_at {
            assert!(u > s.at && u <= s.at + 1 + plan.crash_down);
        }
        // No crash without a victim pool, without the knob, or disabled.
        assert_eq!(plan.crash_schedule(0xFEED, &[]), None);
        let off = FaultPlan { crash_pct: 0, ..plan.clone() };
        assert_eq!(off.crash_schedule(0xFEED, &eligible), None);
        assert_eq!(FaultPlan::none().crash_schedule(0xFEED, &eligible), None);
        // Different run seeds move the schedule (decorrelated stream).
        let c = plan.crash_schedule(0xFEED ^ 1, &eligible);
        assert!(c.is_some());
    }

    #[test]
    fn permanent_death_follows_perm_pct() {
        let perm = FaultPlan {
            crash_pct: 100,
            crash_perm_pct: 100,
            ..FaultPlan::from_seed(11)
        };
        let s = perm.crash_schedule(0xFEED, &[1, 2]).unwrap();
        assert_eq!(s.up_at, None, "perm_pct 100 must never restart");
        let transient = FaultPlan { crash_perm_pct: 0, ..perm };
        let s = transient.crash_schedule(0xFEED, &[1, 2]).unwrap();
        assert!(s.up_at.is_some(), "perm_pct 0 must always restart");
    }

    #[test]
    fn class_delays_only_hit_their_class() {
        let plan = FaultPlan {
            report_delay_pct: 100,
            grant_delay_pct: 0,
            ..FaultPlan::from_seed(5)
        };
        let mut st = ChaosState::new(plan, 0xB5EED, 4);
        assert!(st.class_delay(MsgClass::Report, 0) > 0);
        assert_eq!(st.class_delay(MsgClass::Grant, 0), 0);
        assert_eq!(st.class_delay(MsgClass::Other, 0), 0);
        assert_eq!(st.report_delays(), 1);
        assert_eq!(st.grant_delays(), 0);
        let bound = st.plan().report_delay_max;
        for _ in 0..100 {
            let d = st.class_delay(MsgClass::Report, 0);
            assert!(d >= 1 && d <= 1 + bound);
        }
    }

    #[test]
    fn replay_is_bit_identical_from_seed_and_plan() {
        let mk = || ChaosState::new(FaultPlan::from_seed(42), 0xFEED, 8);
        let (mut x, mut y) = (mk(), mk());
        for i in 0..200u64 {
            let (f, h) = (CoreId((i % 8) as u32), CoreId(((i + 1) % 8) as u32));
            assert_eq!(
                x.delivery_time(f, h, i * 10, 0),
                y.delivery_time(f, h, i * 10, 0)
            );
            assert_eq!(x.draw_starve(0), y.draw_starve(0));
            assert_eq!(x.stall(0), y.stall(0));
            assert_eq!(x.force_deny(0), y.force_deny(0));
        }
    }

    #[test]
    fn shard_lanes_are_decorrelated_and_independent() {
        let mk = || {
            let mut st = ChaosState::new(FaultPlan::from_seed(42), 0xFEED, 8);
            st.set_shards(4);
            st
        };
        let (mut x, mut y) = (mk(), mk());
        // Each lane replays its own subsequence regardless of how draws
        // interleave with other lanes: x draws lanes round-robin, y
        // drains lane-by-lane, and per-lane sequences must agree.
        let mut xs: Vec<Vec<Cycles>> = vec![Vec::new(); 4];
        for i in 0..160usize {
            let k = i % 4;
            xs[k].push(x.stall(k));
        }
        for (k, want) in xs.iter().enumerate() {
            for w in want {
                assert_eq!(y.stall(k), *w, "lane {k} must be order-independent");
            }
        }
        // Lanes are genuinely decorrelated: at least one pair differs in
        // its first few draws.
        let mut z = mk();
        let a: Vec<bool> = (0..32).map(|_| z.draw_starve(0)).collect();
        let b: Vec<bool> = (0..32).map(|_| z.draw_starve(1)).collect();
        let c: Vec<Cycles> = (0..32).map(|_| z.stall(2)).collect();
        let d: Vec<Cycles> = (0..32).map(|_| z.stall(3)).collect();
        assert!(a != b || c != d, "shard lanes should not mirror each other");
        // set_shards on a single shard or a disabled plan is a no-op.
        let mut single = ChaosState::new(FaultPlan::from_seed(42), 0xFEED, 8);
        single.set_shards(1);
        let mut legacy = ChaosState::new(FaultPlan::from_seed(42), 0xFEED, 8);
        for _ in 0..50 {
            assert_eq!(single.stall(0), legacy.stall(0));
        }
        let mut off = ChaosState::disabled();
        off.set_shards(4);
        assert!(!off.active());
    }
}

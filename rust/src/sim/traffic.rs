//! Multi-tenant traffic layer: open-loop job arrivals + per-tenant books.
//!
//! Converts the simulator from one-shot benchmark runs into a
//! traffic-serving system: many concurrent *jobs* — instances of workload
//! templates with mixed sizes, tenants and priorities — arrive over
//! virtual time on an **open-loop, seed-deterministic schedule** computed
//! entirely at build time from `PlatformConfig::seed`. Each arrival is a
//! pre-pushed timer event on a deterministically chosen *entry scheduler*
//! (a top-level subtree root), where a decentralized admission decision is
//! taken at the `sched::policy` seam: admit (inject the job's root task,
//! pre-granted on a fresh per-job region owned by the entry scheduler) or
//! defer (re-arm a retry timer with capped exponential backoff). There is
//! no front-door dispatcher; the hierarchy root never serializes
//! admissions (cf. the distributed-manager designs in PAPERS.md).
//!
//! Determinism contract: the whole arrival schedule (submit times,
//! tenants, templates, priorities, entry schedulers) is drawn from one
//! RNG stream derived from the run seed before the first event executes,
//! so it is identical across shard counts and replay runs. Retry timers
//! are armed from deterministic state only (attempt counters). With
//! `world.traffic == None` (the default) no timer exists, no branch in
//! the scheduler hot path is taken, and every pre-traffic fingerprint
//! stays byte-identical.
//!
//! The functional books here are world-level state (like `Memory` and
//! `TaskTable`); ownership discipline still holds because only the entry
//! scheduler of a job mutates its admission state, and task-level counts
//! are bumped at the same exactly-once sites as
//! `GlobalStats::tasks_spawned` / `tasks_completed`.

use crate::ids::{Cycles, JobId, TaskId};
use crate::sched::hierarchy::HierarchyMap;
use crate::sim::rng::Rng;

/// Stream-mixer for the traffic RNG: arrivals draw from
/// `Rng::new(seed ^ TRAFFIC_STREAM)` so the schedule never perturbs the
/// workload/placement streams derived from the same run seed.
pub const TRAFFIC_STREAM: u64 = 0x7AFF_1C5E_ED00_0001;

// --- job timer tags -------------------------------------------------------
//
// Custom timer tags on scheduler cores. The steal-retry (0x57EA_17) and
// heartbeat (0xB_EA7) tags are both < 2^32; job tags keep the kind in the
// top nibble and the job index in the low 32 bits, so the spaces can
// never collide.
const TAG_KIND_SHIFT: u32 = 60;
const ARRIVE_KIND: u64 = 0xA;
const RETRY_KIND: u64 = 0xB;

/// Timer tag for job `j`'s (single) open-loop arrival.
pub fn arrive_tag(j: JobId) -> u64 {
    (ARRIVE_KIND << TAG_KIND_SHIFT) | j.0 as u64
}

/// Timer tag for a deferred job `j`'s admission retry.
pub fn retry_tag(j: JobId) -> u64 {
    (RETRY_KIND << TAG_KIND_SHIFT) | j.0 as u64
}

/// A decoded job timer.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum JobTimer {
    Arrive(JobId),
    Retry(JobId),
}

/// Decode a custom timer tag; `None` for non-traffic tags (steal retry,
/// heartbeat), which all live below 2^32.
pub fn decode_tag(tag: u64) -> Option<JobTimer> {
    let j = JobId((tag & 0xFFFF_FFFF) as u32);
    match tag >> TAG_KIND_SHIFT {
        ARRIVE_KIND => Some(JobTimer::Arrive(j)),
        RETRY_KIND => Some(JobTimer::Retry(j)),
        _ => None,
    }
}

// --- job templates --------------------------------------------------------

/// Size/shape of one job instance: the generic job body (`apps::jobs`)
/// turns this into `tasks` independent compute tasks of `task_cycles`
/// each, allocated over `fanout` subregions of the job's root region,
/// with `hot_pct` percent of them skewed into subregion 0.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct JobShape {
    pub tasks: u32,
    pub task_cycles: u64,
    pub fanout: u32,
    pub hot_pct: u32,
}

impl JobShape {
    /// Tasks a job of this shape contributes, root task included.
    pub fn total_tasks(&self) -> u64 {
        1 + self.tasks as u64
    }
}

/// A workload's instantiation as a traffic job template (see
/// `Workload::job_shape`): the template name keyed into reports plus the
/// shape the generic job body realizes.
#[derive(Clone, Copy, Debug)]
pub struct JobTemplate {
    pub name: &'static str,
    pub shape: JobShape,
}

// --- per-job / per-tenant books -------------------------------------------

/// Admission lifecycle of a job.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum JobPhase {
    /// Arrival timer pre-pushed, not fired yet.
    Scheduled,
    /// Arrived but deferred by admission control; a retry timer is armed.
    Deferred,
    /// Admitted; tasks in flight.
    Live,
    /// All of the job's tasks completed.
    Done,
}

/// Everything recorded about one job.
#[derive(Clone, Debug)]
pub struct JobBook {
    pub tenant: u32,
    pub template: &'static str,
    pub shape: JobShape,
    /// Accounting priority class (0 = highest), drawn per job. Recorded
    /// in reports; admission policies may consume it in the future.
    pub priority: u8,
    /// Entry scheduler index (a top-level subtree root) that owns this
    /// job's admission and root region.
    pub entry: usize,
    pub submit_at: Cycles,
    pub phase: JobPhase,
    /// Admission attempts so far (0 = not yet arrived; 1 = admitted or
    /// deferred on first try).
    pub attempts: u32,
    pub admit_at: Cycles,
    pub finish_at: Cycles,
    /// The injected root task, once admitted.
    pub root_task: Option<TaskId>,
    /// Tasks of this job currently alive (spawned, not completed).
    pub live: u64,
    pub spawned: u64,
    pub completed: u64,
}

impl JobBook {
    /// Submit-to-finish job latency (valid once `phase == Done`).
    pub fn latency(&self) -> Cycles {
        self.finish_at.saturating_sub(self.submit_at)
    }
}

/// Per-tenant aggregate books. Drain to zero live jobs at quiescence —
/// the `check_jobs` oracle pins this.
#[derive(Clone, Copy, Default, Debug)]
pub struct TenantBook {
    pub submitted: u32,
    pub live_jobs: u32,
    pub finished: u32,
    pub deferrals: u64,
}

/// World-level traffic state: the arrival schedule plus all books.
/// `None` in `World::traffic` means the traffic layer does not exist —
/// the byte-identity contract for every single-job fingerprint.
#[derive(Clone, Debug)]
pub struct TrafficState {
    pub jobs: Vec<JobBook>,
    pub tenants: Vec<TenantBook>,
    /// Registry index of the generic job root body (`apps::jobs`).
    pub main_fn: usize,
    /// Deferred-retry backoff base, cycles (shifted by attempt count,
    /// capped — see [`TrafficState::note_deferred`]).
    pub retry_backoff: Cycles,
    /// Arrival timers not yet fired.
    pub arrivals_pending: u32,
    /// Jobs not yet `Done` (scheduled + deferred + live).
    pub unfinished: u32,
    pub admitted: u32,
    pub total_deferrals: u64,
}

impl TrafficState {
    /// Build the full seed-deterministic arrival schedule. Inter-arrival
    /// gaps are uniform-jittered around `mean_gap` (integer arithmetic
    /// only — no libm calls whose rounding could vary across hosts);
    /// tenants are drawn weighted by `tenant_weights` (uniform when
    /// empty); templates round through `templates` by RNG draw.
    pub fn generate(
        cfg: &crate::config::TrafficCfg,
        seed: u64,
        hier: &HierarchyMap,
        main_fn: usize,
        templates: &[JobTemplate],
    ) -> TrafficState {
        assert!(cfg.enabled, "generating traffic with traffic disabled");
        assert!(!templates.is_empty(), "traffic needs at least one job template");
        assert!(cfg.tenants >= 1 && cfg.jobs >= 1);
        let mut rng = Rng::new(seed ^ TRAFFIC_STREAM);
        let entries: Vec<usize> =
            if hier.children[0].is_empty() { vec![0] } else { hier.children[0].clone() };
        let weights: Vec<u64> = if cfg.tenant_weights.is_empty() {
            vec![1; cfg.tenants as usize]
        } else {
            assert_eq!(cfg.tenant_weights.len(), cfg.tenants as usize);
            cfg.tenant_weights.clone()
        };
        let wsum: u64 = weights.iter().sum::<u64>().max(1);
        let mean = cfg.mean_gap.max(2);
        let mut t: Cycles = 0;
        let mut jobs = Vec::with_capacity(cfg.jobs as usize);
        for _ in 0..cfg.jobs {
            // Open loop: the next submit time never waits on completions.
            t += rng.range(mean / 2, mean + mean / 2);
            let mut pick = rng.below(wsum);
            let mut tenant = 0u32;
            for (i, &w) in weights.iter().enumerate() {
                if pick < w {
                    tenant = i as u32;
                    break;
                }
                pick -= w;
            }
            let tpl = templates[rng.below(templates.len() as u64) as usize];
            let priority = rng.below(3) as u8;
            let entry = entries[rng.below(entries.len() as u64) as usize];
            jobs.push(JobBook {
                tenant,
                template: tpl.name,
                shape: tpl.shape,
                priority,
                entry,
                submit_at: t,
                phase: JobPhase::Scheduled,
                attempts: 0,
                admit_at: 0,
                finish_at: 0,
                root_task: None,
                live: 0,
                spawned: 0,
                completed: 0,
            });
        }
        let mut tenants = vec![TenantBook::default(); cfg.tenants as usize];
        for j in &jobs {
            tenants[j.tenant as usize].submitted += 1;
        }
        TrafficState {
            arrivals_pending: jobs.len() as u32,
            unfinished: jobs.len() as u32,
            jobs,
            tenants,
            main_fn,
            retry_backoff: cfg.retry_backoff.max(1),
            admitted: 0,
            total_deferrals: 0,
        }
    }

    /// Quiescence condition the engine gate consults: every arrival has
    /// fired and every job has drained. While this is false, completed
    /// task counts matching spawned counts does *not* end the run.
    pub fn all_done(&self) -> bool {
        self.arrivals_pending == 0 && self.unfinished == 0
    }

    pub fn job(&self, j: JobId) -> &JobBook {
        &self.jobs[j.idx()]
    }

    /// Live jobs of a tenant right now — the `TenantCap` admission input.
    pub fn tenant_live(&self, tenant: u32) -> u32 {
        self.tenants[tenant as usize].live_jobs
    }

    /// The arrival timer for `j` fired (first admission attempt).
    pub fn note_arrived(&mut self, j: JobId) {
        let b = &mut self.jobs[j.idx()];
        debug_assert_eq!(b.phase, JobPhase::Scheduled);
        self.arrivals_pending -= 1;
    }

    /// Admission deferred `j`; returns the backoff delay for the retry
    /// timer (base shifted by attempt count, capped so the delay cannot
    /// overflow or grow unbounded).
    pub fn note_deferred(&mut self, j: JobId) -> Cycles {
        let b = &mut self.jobs[j.idx()];
        b.phase = JobPhase::Deferred;
        b.attempts += 1;
        self.tenants[b.tenant as usize].deferrals += 1;
        self.total_deferrals += 1;
        self.retry_backoff << (b.attempts - 1).min(6)
    }

    /// Admission accepted `j`: its root task is injected at the entry
    /// scheduler. Counts the root task as spawned-and-live.
    pub fn note_admitted(&mut self, j: JobId, root: TaskId, now: Cycles) {
        let b = &mut self.jobs[j.idx()];
        debug_assert!(b.phase == JobPhase::Scheduled || b.phase == JobPhase::Deferred);
        b.phase = JobPhase::Live;
        b.attempts += 1;
        b.admit_at = now;
        b.root_task = Some(root);
        b.live = 1;
        b.spawned = 1;
        self.tenants[b.tenant as usize].live_jobs += 1;
        self.admitted += 1;
    }

    /// A task belonging to `j` was spawned (same exactly-once site as
    /// `GlobalStats::tasks_spawned`).
    pub fn on_task_spawned(&mut self, j: JobId) {
        let b = &mut self.jobs[j.idx()];
        b.live += 1;
        b.spawned += 1;
    }

    /// A task belonging to `j` completed (same exactly-once site as
    /// `GlobalStats::tasks_completed`). Returns `true` when this drained
    /// the job — per-channel FIFO ordering guarantees every spawn of the
    /// job was already counted before its parent's completion is
    /// processed, so a zero live count really is the job's end.
    pub fn on_task_completed(&mut self, j: JobId, now: Cycles) -> bool {
        let b = &mut self.jobs[j.idx()];
        b.completed += 1;
        debug_assert!(b.live > 0, "completion underflow on {j}");
        b.live -= 1;
        if b.live == 0 && b.phase == JobPhase::Live {
            b.phase = JobPhase::Done;
            b.finish_at = now;
            let tb = &mut self.tenants[b.tenant as usize];
            tb.live_jobs -= 1;
            tb.finished += 1;
            self.unfinished -= 1;
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HierarchySpec, TrafficCfg};

    fn hier() -> HierarchyMap {
        HierarchyMap::build(32, &HierarchySpec::two_level(4))
    }

    fn templates() -> Vec<JobTemplate> {
        vec![
            JobTemplate {
                name: "a",
                shape: JobShape { tasks: 4, task_cycles: 1000, fanout: 2, hot_pct: 0 },
            },
            JobTemplate {
                name: "b",
                shape: JobShape { tasks: 8, task_cycles: 500, fanout: 4, hot_pct: 90 },
            },
        ]
    }

    #[test]
    fn tag_codec_round_trips_and_avoids_legacy_tags() {
        let j = JobId(77);
        assert_eq!(decode_tag(arrive_tag(j)), Some(JobTimer::Arrive(j)));
        assert_eq!(decode_tag(retry_tag(j)), Some(JobTimer::Retry(j)));
        // Legacy custom tags (steal retry, heartbeat) are below 2^32 and
        // must never decode as job timers.
        assert_eq!(decode_tag(0x57EA_17), None);
        assert_eq!(decode_tag(0xB_EA7), None);
        assert_ne!(arrive_tag(j), retry_tag(j));
    }

    #[test]
    fn generation_is_seed_deterministic() {
        let cfg = TrafficCfg::on(12, 3);
        let h = hier();
        let a = TrafficState::generate(&cfg, 42, &h, 7, &templates());
        let b = TrafficState::generate(&cfg, 42, &h, 7, &templates());
        assert_eq!(a.jobs.len(), 12);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.submit_at, y.submit_at);
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.template, y.template);
            assert_eq!(x.entry, y.entry);
            assert_eq!(x.priority, y.priority);
        }
        let c = TrafficState::generate(&cfg, 43, &h, 7, &templates());
        assert!(
            a.jobs.iter().zip(&c.jobs).any(|(x, y)| x.submit_at != y.submit_at),
            "different seeds must draw different schedules"
        );
    }

    #[test]
    fn arrivals_are_open_loop_and_entries_are_subtree_roots() {
        let cfg = TrafficCfg::on(32, 2);
        let h = hier();
        let t = TrafficState::generate(&cfg, 7, &h, 0, &templates());
        let mut prev = 0;
        for j in &t.jobs {
            assert!(j.submit_at > prev, "submit times strictly increase");
            assert!(
                j.submit_at - prev <= cfg.mean_gap + cfg.mean_gap / 2,
                "gap bounded by the jitter window"
            );
            prev = j.submit_at;
            assert!(h.children[0].contains(&j.entry));
        }
        // Tenant books account for every submission.
        let total: u32 = t.tenants.iter().map(|b| b.submitted).sum();
        assert_eq!(total, 32);
    }

    #[test]
    fn book_lifecycle_drains() {
        let cfg = TrafficCfg::on(2, 1);
        let h = hier();
        let mut t = TrafficState::generate(&cfg, 1, &h, 0, &templates());
        assert!(!t.all_done());
        // Job 0: deferred once, then admitted with a 2-task tree.
        t.note_arrived(JobId(0));
        let d0 = t.note_deferred(JobId(0));
        assert_eq!(d0, t.retry_backoff);
        let d1 = t.note_deferred(JobId(0));
        assert_eq!(d1, t.retry_backoff << 1);
        t.note_admitted(JobId(0), TaskId(5), 100);
        assert_eq!(t.tenant_live(0), 1);
        t.on_task_spawned(JobId(0));
        assert!(!t.on_task_completed(JobId(0), 200), "root still live");
        assert!(t.on_task_completed(JobId(0), 300), "last completion drains the job");
        assert_eq!(t.job(JobId(0)).latency(), 300 - t.job(JobId(0)).submit_at);
        assert_eq!(t.tenant_live(0), 0);
        assert!(!t.all_done(), "job 1 still scheduled");
        // Job 1: admitted first try, drains immediately.
        t.note_arrived(JobId(1));
        t.note_admitted(JobId(1), TaskId(9), 400);
        assert!(t.on_task_completed(JobId(1), 500));
        assert!(t.all_done());
        assert_eq!(t.admitted, 2);
        assert_eq!(t.total_deferrals, 2);
        assert_eq!(t.tenants[0].finished, 2);
    }

    #[test]
    fn flat_hierarchy_enters_at_the_root() {
        let cfg = TrafficCfg::on(4, 1);
        let h = HierarchyMap::build(4, &HierarchySpec::flat());
        let t = TrafficState::generate(&cfg, 3, &h, 0, &templates());
        assert!(t.jobs.iter().all(|j| j.entry == 0));
    }

    #[test]
    fn weighted_tenants_skew_the_draw() {
        let mut cfg = TrafficCfg::on(64, 2);
        cfg.tenant_weights = vec![7, 1];
        let t = TrafficState::generate(&cfg, 11, &hier(), 0, &templates());
        assert!(
            t.tenants[0].submitted > t.tenants[1].submitted,
            "7:1 weights must skew submissions: {:?}",
            t.tenants
        );
        assert_eq!(t.tenants[0].submitted + t.tenants[1].submitted, 64);
    }
}

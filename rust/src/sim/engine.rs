//! The discrete-event simulation engine.
//!
//! Every core of the simulated platform is an event-driven state machine
//! (exactly how the paper describes Myrmics scheduler cores: "Each
//! scheduler is organized as an event-based server ... in a continuous
//! loop, waiting for new messages"). The engine delivers events in virtual
//! time, models core occupancy (a busy core defers incoming events — this
//! is what makes saturated schedulers slow the system down, Fig 9/12),
//! charges per-operation cycle costs from the [`CostModel`], and models the
//! NoC: wire latencies, per-peer credit-flow buffers and DMA groups.
//!
//! The per-event loop is constant-time end to end: events come off a
//! hierarchical timing wheel ([`crate::sim::wheel`]) instead of a binary
//! heap, channel credits live in a flat `(src, dst)`-indexed table
//! instead of a hashed map, busy-core drains are side-heap markers that
//! never re-enter the global queue, and the run horizon is maintained
//! incrementally instead of scanned. See `docs/sim-engine.md` for the
//! event core's layout and the determinism contract.
//!
//! With `ShardCfg::shards > 1` the engine runs *sharded*: cores are
//! partitioned by top-level scheduler subtree
//! ([`crate::sched::hierarchy::ShardPartition`]), each shard owns its own
//! timing wheel, channel table and busy horizon, and cross-shard events
//! travel through per-shard mailboxes under a conservative-PDES lookahead
//! derived from the minimum cross-shard NoC link latency. The shard heads
//! are merged back into the canonical global `(t, seq)` order at pop
//! time, so a run is bit-identical regardless of shard count. With
//! `ShardCfg::threads > 1` (and an eligible, `World::par_safe`
//! workload) the shards additionally step on real host threads between
//! conservative barriers — see [`par`] and `docs/sim-engine.md`
//! "Sharded engine" for the window contract, the provisional-stamp
//! residue scheme and the barrier walk that reassigns canonical order.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::{CoreKind, CostModel};
use crate::ids::{CoreId, Cycles};
use crate::noc::channel::{Channel, ChannelTables};
use crate::noc::dma::{group_completion, Transfer};
use crate::noc::msg::Msg;
use crate::noc::topology::Topology;
use crate::platform::World;
use crate::sched::hierarchy::ShardPartition;
use crate::sim::chaos::{ChaosState, FaultPlan, MsgClass};
use crate::sim::event::{Event, Queued, TimerKind};
use crate::sim::wheel::{EventQ, Popped};
use crate::stats::metrics::CoreStats;
use crate::task::registry::Registry;

#[path = "par.rs"]
mod par;

/// How long a message sits in a dead scheduler's hardware mailbox before
/// the engine re-checks whether the core is back (or its mailbox has been
/// re-adopted). Purely a polling granularity: a fixed constant so replays
/// stay bit-identical and per-link FIFO order is preserved (equal delays
/// cannot reorder a link).
pub const CRASH_MAILBOX_RETRY: Cycles = 1_024;

/// An installed scheduler crash (engine-level view of
/// [`crate::sim::chaos::CrashSchedule`], resolved to a core id).
///
/// Crash semantics: between `at` and `up_at` the core processes nothing.
/// Its *software* state (ready queue, load books, request latches) is lost
/// at restart — [`CoreLogic::on_crash_restart`] wipes it — but the
/// *hardware* mailbox survives: messages delivered while the core is down
/// are re-parked (see [`CRASH_MAILBOX_RETRY`]), never dropped, so channel
/// credits stay balanced. Once the parent re-adopts the subtree it
/// installs a redirect and the engine drains the dead mailbox toward it.
#[derive(Clone, Copy, Debug)]
pub struct CrashState {
    pub core: CoreId,
    pub at: Cycles,
    /// Restart time; `None` = permanent death (the core stays dark until
    /// the post-completion teardown re-bootstrap).
    pub up_at: Option<Cycles>,
    /// The crash intercepted at least one event (counted in gstats).
    pub fired: bool,
    /// The restart transition has run (volatile state wiped, `Boot`
    /// delivered to the fresh incarnation).
    pub restarted: bool,
}

/// An event exchanged between shards through a mailbox: it left the
/// executing shard but cannot enter the destination wheel directly (the
/// wheel's cursor may already be ahead of it), so it is merged back into
/// the canonical global `(t, seq)` order at pop time. Wake markers travel
/// as `Event::Wake` payloads and are rehydrated into [`Popped::Wake`].
#[derive(Debug)]
struct MailItem {
    t: Cycles,
    seq: u64,
    core: CoreId,
    ev: Event,
}

impl PartialEq for MailItem {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for MailItem {}
impl PartialOrd for MailItem {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for MailItem {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.t, self.seq).cmp(&(other.t, other.seq))
    }
}

/// Sharded-engine state (`ShardCfg::shards > 1` only — the unsharded
/// engine never allocates this and takes the exact legacy paths). Each
/// shard owns a timing wheel, a channel table and a busy horizon; events
/// crossing shards go through the destination shard's mailbox; the pop
/// loop merges every shard's head back into the canonical global
/// `(t, seq)` order, which is what makes a sharded run bit-identical to
/// the single-wheel run.
struct ShardState {
    n: usize,
    /// Host threads stepping the shards (`1` = the sequential merge; set
    /// by [`SimState::set_shard_threads`], clamped to the shard count).
    /// Only the [`par`] executor reads values above 1.
    threads: usize,
    /// Core id -> shard id (from [`ShardPartition`]).
    shard_of: Vec<u32>,
    /// Conservative-PDES lookahead: minimum one-way latency over the
    /// cross-shard tree links (or the config override). Any cross-shard
    /// send issued at `t` arrives no earlier than `t + lookahead`, which
    /// bounds how far shards could free-run apart — see the docs.
    lookahead: Cycles,
    wheels: Vec<EventQ>,
    /// One-slot wheel lookahead per shard: the wheel has no peek, so the
    /// merge pops each wheel's head into this slot and consumes it only
    /// when it is the global minimum.
    held: Vec<Option<Popped>>,
    /// Mirror of each wheel's cursor (the `t` of its last wheel pop).
    /// Pushes behind it must go through the mailbox — the wheel itself
    /// would assert on a push behind its cursor.
    cursor: Vec<Cycles>,
    /// Per-destination-shard mailboxes, min-heaps on `(t, seq)`: the
    /// merged view of all per-pair cross-shard streams, plus same-shard
    /// events that landed behind their own wheel cursor.
    inbox: Vec<BinaryHeap<Reverse<MailItem>>>,
    /// Per-shard channel tables. A cross-shard link is owned by the lower
    /// shard id; debug builds assert no third shard ever touches a link.
    channels: Vec<ChannelTables>,
    /// Per-shard incrementally-maintained busy horizon;
    /// [`SimState::horizon`] max-reduces over these.
    max_busy: Vec<Cycles>,
    /// Shard whose event is currently executing (`None` outside the run
    /// loop): decides mailbox-vs-wheel routing and backs the channel
    /// ownership asserts.
    exec: Option<u32>,
    /// Bounded-lag window accounting: the current window is
    /// `[window_end - lookahead, window_end)`.
    window_end: Cycles,
    windows: u64,
    /// Events that travelled through a mailbox.
    mail_events: u64,
}

impl ShardState {
    /// Route a freshly stamped event to its shard: the destination wheel
    /// when the push comes from the same shard and is not behind the
    /// wheel cursor, the destination mailbox otherwise.
    fn route(&mut self, t: Cycles, seq: u64, core: CoreId, ev: Event) {
        let d = self.shard_of[core.idx()] as usize;
        let cross = self.exec.is_some_and(|e| e as usize != d);
        if !cross && t >= self.cursor[d] {
            match ev {
                Event::Wake => self.wheels[d].push_wake(t, seq, core),
                ev => self.wheels[d].push(t, seq, core, ev),
            }
        } else {
            self.mail_events += 1;
            self.inbox[d].push(Reverse(MailItem { t, seq, core, ev }));
        }
    }

    /// Channel-table index owning the `src -> dst` link: the lower shard
    /// id of the two endpoints. Debug builds enforce the shard-safety
    /// rule that only an endpoint shard may touch a link.
    fn chan_owner(&self, src: CoreId, dst: CoreId) -> usize {
        let a = self.shard_of[src.idx()] as usize;
        let b = self.shard_of[dst.idx()] as usize;
        debug_assert!(
            self.exec
                .is_none_or(|e| (e as usize) == a || (e as usize) == b),
            "channel {src}->{dst} touched from shard {:?} (endpoints {a}/{b})",
            self.exec
        );
        a.min(b)
    }
}

/// Per-core engine metadata.
#[derive(Clone, Debug)]
pub struct CoreMeta {
    pub kind: CoreKind,
    /// The core is executing (task or runtime code) until this time;
    /// events arriving earlier are deferred ("workers do not interrupt
    /// running tasks", paper V-E).
    pub busy_until: Cycles,
    /// Events deferred while the core was busy, in arrival order. Drained
    /// one per wake marker ([`crate::sim::wheel::Popped::Wake`]) — O(1)
    /// per deferral, and the drain never re-enters the global wheel.
    pending: std::collections::VecDeque<Event>,
    /// A wake marker is already scheduled for this core.
    wake_scheduled: bool,
}

/// Mutable simulation state shared with handlers through [`Ctx`].
pub struct SimState {
    pub now: Cycles,
    seq: u64,
    queue: EventQ,
    pub metas: Vec<CoreMeta>,
    pub stats: Vec<CoreStats>,
    pub topo: Topology,
    pub cost: CostModel,
    pub channel_capacity: usize,
    channels: ChannelTables,
    /// Largest `busy_until` ever reached, maintained incrementally so
    /// [`SimState::horizon`] is O(1) instead of a scan over all cores.
    /// Valid because a core's `busy_until` never moves backwards: handlers
    /// only run once the core is idle, at `t >= busy_until`.
    max_busy: Cycles,
    /// DMA group id allocator. Atomic (relaxed) because threaded-window
    /// workers allocate concurrently; the ids are inert labels — each is
    /// matched per-core against its own `DmaDone`, so allocation order
    /// never feeds back into the schedule.
    dma_seq: AtomicU64,
    /// Print an event trace (debugging aid).
    pub trace: bool,
    /// Deterministic fault injection ([`crate::sim::chaos`]). Inert by
    /// default: every hook below is gated on `chaos.active()`, so runs
    /// without an installed plan stay byte-identical to the pre-chaos
    /// engine (no extra RNG draws, events or charges).
    pub chaos: ChaosState,
    /// Installed scheduler crash, if any (`None` keeps the pop loop on
    /// the exact pre-crash paths — the check is a single `Option` test).
    crash: Option<CrashState>,
    /// Per-core mailbox redirect installed by re-adoption: events for a
    /// dead core are forwarded (uncredited) to the adoptive parent.
    /// Allocated only when a crash is installed.
    redirect: Vec<Option<CoreId>>,
    /// Sharded-engine state (`None` = the legacy single-wheel engine;
    /// installed by [`SimState::install_sharding`] when
    /// `ShardCfg::shards > 1` and the hierarchy has enough top-level
    /// subtrees).
    shard: Option<Box<ShardState>>,
}

impl SimState {
    pub fn new(
        kinds: Vec<CoreKind>,
        topo: Topology,
        cost: CostModel,
        channel_capacity: usize,
    ) -> Self {
        let n = kinds.len();
        let channels = ChannelTables::new(n, ChannelTables::degree_hint(&topo));
        SimState {
            now: 0,
            seq: 0,
            queue: EventQ::new(),
            metas: kinds
                .into_iter()
                .map(|kind| CoreMeta {
                    kind,
                    busy_until: 0,
                    pending: std::collections::VecDeque::new(),
                    wake_scheduled: false,
                })
                .collect(),
            stats: vec![CoreStats::default(); n],
            topo,
            cost,
            channel_capacity,
            channels,
            max_busy: 0,
            dma_seq: AtomicU64::new(0),
            trace: false,
            chaos: ChaosState::disabled(),
            crash: None,
            redirect: Vec::new(),
            shard: None,
        }
    }

    /// Install the sharded engine for this run: per-shard wheels, channel
    /// tables, busy horizons and mailboxes, with the conservative
    /// lookahead derived from the minimum cross-shard link latency (or
    /// taken from the config override). A one-shard partition is a no-op:
    /// the legacy single-wheel path stays byte-identical to the
    /// pre-sharding engine. Must run before any event is pushed or any
    /// channel pre-seeded.
    pub fn install_sharding(&mut self, part: &ShardPartition, lookahead_override: Option<Cycles>) {
        if part.n_shards <= 1 {
            return;
        }
        assert!(
            self.seq == 0 && self.queue.is_empty(),
            "install_sharding must precede the first push"
        );
        debug_assert_eq!(part.shard_of.len(), self.n_cores());
        let derived = part
            .cross_links
            .iter()
            .map(|&(a, b)| self.cost.msg_latency(self.topo.hops(a, b)))
            .min();
        let lookahead = lookahead_override.or(derived).unwrap_or(1).max(1);
        let n = part.n_shards;
        let hint = ChannelTables::degree_hint_sharded(&self.topo, n);
        let n_cores = self.n_cores();
        self.shard = Some(Box::new(ShardState {
            n,
            threads: 1,
            shard_of: part.shard_of.clone(),
            lookahead,
            wheels: (0..n).map(|_| EventQ::new()).collect(),
            held: (0..n).map(|_| None).collect(),
            cursor: vec![0; n],
            inbox: (0..n).map(|_| BinaryHeap::new()).collect(),
            channels: (0..n).map(|_| ChannelTables::new(n_cores, hint)).collect(),
            max_busy: vec![0; n],
            exec: None,
            window_end: 0,
            windows: 0,
            mail_events: 0,
        }));
    }

    /// Number of engine shards (1 = the legacy single-wheel engine).
    pub fn n_shards(&self) -> usize {
        self.shard.as_ref().map_or(1, |sh| sh.n)
    }

    /// Conservative lookahead of the sharded engine (`None` unsharded).
    pub fn shard_lookahead(&self) -> Option<Cycles> {
        self.shard.as_ref().map(|sh| sh.lookahead)
    }

    /// Bounded-lag windows opened so far (0 when unsharded).
    pub fn shard_windows(&self) -> u64 {
        self.shard.as_ref().map_or(0, |sh| sh.windows)
    }

    /// Events that travelled through a cross-shard mailbox (0 unsharded).
    pub fn shard_mail_events(&self) -> u64 {
        self.shard.as_ref().map_or(0, |sh| sh.mail_events)
    }

    /// Request host threads for the sharded executor, clamped to
    /// `1..=n_shards`. A no-op when unsharded; `threads = 1` keeps the
    /// byte-identical sequential merge. Must run before the first event
    /// is processed (the choice is per-run, not per-window).
    pub fn set_shard_threads(&mut self, threads: usize) {
        if let Some(sh) = &mut self.shard {
            sh.threads = threads.clamp(1, sh.n);
        }
    }

    /// Host threads the sharded executor will use (1 = sequential).
    pub fn shard_threads(&self) -> usize {
        self.shard.as_ref().map_or(1, |sh| sh.threads)
    }

    /// Chaos lane of `core`: its shard id when sharded, lane 0 otherwise.
    /// Every chaos draw is routed through the drawing core's lane so the
    /// draw schedule is a function of per-shard execution order alone —
    /// identical for any thread count (see `sim::chaos`).
    pub fn shard_ix(&self, core: CoreId) -> usize {
        self.shard.as_ref().map_or(0, |sh| sh.shard_of[core.idx()] as usize)
    }

    /// Install a fault plan for this run. A disabled plan is a no-op so
    /// the default config never allocates fault tables.
    pub fn install_chaos(&mut self, plan: &FaultPlan, run_seed: u64) {
        if plan.enabled {
            self.chaos = ChaosState::new(plan.clone(), run_seed, self.n_cores());
        }
    }

    /// Install a scheduler crash for this run (platform-side, only when
    /// recovery is enabled). Schedules the restart `Boot` so the fresh
    /// incarnation announces itself even if no traffic wakes it.
    pub fn install_crash(&mut self, core: CoreId, at: Cycles, up_at: Option<Cycles>) {
        self.crash = Some(CrashState { core, at, up_at, fired: false, restarted: false });
        if self.redirect.is_empty() {
            self.redirect = vec![None; self.n_cores()];
        }
        if let Some(u) = up_at {
            self.push(u, core, Event::Boot);
        }
    }

    /// The installed crash, if any (oracles/tests).
    pub fn crash(&self) -> Option<&CrashState> {
        self.crash.as_ref()
    }

    /// Point a dead core's mailbox at `to` (re-adoption), or clear the
    /// redirect with `None` (re-integration after restart).
    pub fn set_redirect(&mut self, dead: CoreId, to: Option<CoreId>) {
        if self.redirect.is_empty() {
            self.redirect = vec![None; self.n_cores()];
        }
        self.redirect[dead.idx()] = to;
    }

    /// Current mailbox redirect for `core`, if any.
    pub fn redirect_of(&self, core: CoreId) -> Option<CoreId> {
        self.redirect.get(core.idx()).copied().flatten()
    }

    pub fn n_cores(&self) -> usize {
        self.metas.len()
    }

    /// Enqueue an event for `core` at absolute time `t`. Sequentially the
    /// stamp comes from the single global counter: pushes are totally
    /// ordered by the merge loop, so the stamp order is shard-count
    /// invariant. Inside a threaded window (a worker thread has a
    /// [`par::ShardLog`] bound) the push instead takes a *provisional*
    /// per-shard residue stamp and is logged; the barrier walk replays
    /// the log in canonical order and reassigns the exact stamps the
    /// sequential merge would have drawn.
    pub fn push(&mut self, t: Cycles, core: CoreId, ev: Event) {
        match &mut self.shard {
            None => {
                let seq = self.seq;
                self.seq += 1;
                self.queue.push(t, seq, core, ev);
            }
            Some(sh) => {
                if sh.threads > 1 {
                    if let Some(log) = par::tl_log() {
                        par::window_push(sh, log, t, core, ev);
                        return;
                    }
                }
                let seq = self.seq;
                self.seq += 1;
                sh.route(t, seq, core, ev);
            }
        }
    }

    /// Enqueue a busy-core drain marker. Consumes a sequence number like
    /// any event so the merged pop order (and hence every downstream
    /// tie-break) is identical to the old single-queue engine.
    fn push_wake(&mut self, t: Cycles, core: CoreId) {
        match &mut self.shard {
            None => {
                let seq = self.seq;
                self.seq += 1;
                self.queue.push_wake(t, seq, core);
            }
            Some(sh) => {
                if sh.threads > 1 {
                    if let Some(log) = par::tl_log() {
                        par::window_push(sh, log, t, core, Event::Wake);
                        return;
                    }
                }
                let seq = self.seq;
                self.seq += 1;
                sh.route(t, seq, core, Event::Wake);
            }
        }
    }

    /// Dequeue the globally earliest `(t, seq)` item across all shards
    /// (the plain wheel pop when unsharded).
    fn pop_next(&mut self) -> Option<Popped> {
        if self.shard.is_some() {
            self.sharded_pop()
        } else {
            self.queue.pop()
        }
    }

    /// The sharded merge: refill each shard's held wheel head, then take
    /// the global `(t, seq)` minimum over held heads and mailbox heads.
    /// This *is* the conservative barrier in sequential form — no shard
    /// ever advances past an earlier event of another shard, and the
    /// bounded-lag window accounting tracks where thread-parallel shards
    /// would synchronize (see docs).
    fn sharded_pop(&mut self) -> Option<Popped> {
        let sh = self.shard.as_mut().expect("sharded engine");
        for s in 0..sh.n {
            if sh.held[s].is_none() {
                if let Some(p) = sh.wheels[s].pop() {
                    sh.cursor[s] = match &p {
                        Popped::Ev(q) => q.t,
                        Popped::Wake { t, .. } => *t,
                    };
                    sh.held[s] = Some(p);
                }
            }
        }
        let mut best: Option<(Cycles, u64, usize, bool)> = None;
        for s in 0..sh.n {
            if let Some(p) = &sh.held[s] {
                let key = match p {
                    Popped::Ev(q) => (q.t, q.seq),
                    Popped::Wake { t, seq, .. } => (*t, *seq),
                };
                if best.is_none_or(|(bt, bs, ..)| key < (bt, bs)) {
                    best = Some((key.0, key.1, s, false));
                }
            }
            if let Some(Reverse(m)) = sh.inbox[s].peek() {
                if best.is_none_or(|(bt, bs, ..)| (m.t, m.seq) < (bt, bs)) {
                    best = Some((m.t, m.seq, s, true));
                }
            }
        }
        let (t, _, s, from_inbox) = best?;
        if t >= sh.window_end {
            sh.window_end = t + sh.lookahead;
            sh.windows += 1;
        }
        sh.exec = Some(s as u32);
        if from_inbox {
            let Reverse(m) = sh.inbox[s].pop().expect("peeked above");
            Some(match m.ev {
                Event::Wake => Popped::Wake { t: m.t, seq: m.seq, core: m.core },
                ev => Popped::Ev(Queued { t: m.t, seq: m.seq, core: m.core, ev }),
            })
        } else {
            sh.held[s].take()
        }
    }

    /// Latest point in virtual time any core is busy until (>= `now`).
    /// O(1) unsharded (maintained as events complete); a max-reduce over
    /// the per-shard busy horizons when sharded.
    pub fn horizon(&self) -> Cycles {
        let mb = match &self.shard {
            None => self.max_busy,
            Some(sh) => sh.max_busy.iter().copied().max().unwrap_or(0),
        };
        mb.max(self.now)
    }

    /// Record a core's new `busy_until` in the (per-shard) busy horizon.
    fn note_busy(&mut self, core: CoreId, busy: Cycles) {
        match &mut self.shard {
            None => {
                if busy > self.max_busy {
                    self.max_busy = busy;
                }
            }
            Some(sh) => {
                let s = sh.shard_of[core.idx()] as usize;
                if busy > sh.max_busy[s] {
                    sh.max_busy[s] = busy;
                }
            }
        }
    }

    /// The `src -> dst` credit channel, created on first use, in whichever
    /// table owns the link (the global table unsharded; the lower
    /// endpoint shard's table sharded).
    fn chan_entry(&mut self, src: CoreId, dst: CoreId) -> &mut Channel {
        match &mut self.shard {
            None => self.channels.entry(src, dst),
            Some(sh) => {
                let o = sh.chan_owner(src, dst);
                sh.channels[o].entry(src, dst)
            }
        }
    }

    /// The `src -> dst` channel if it exists (release path: never creates).
    fn chan_get_mut(&mut self, src: CoreId, dst: CoreId) -> Option<&mut Channel> {
        match &mut self.shard {
            None => self.channels.get_mut(src, dst),
            Some(sh) => {
                let o = sh.chan_owner(src, dst);
                sh.channels[o].get_mut(src, dst)
            }
        }
    }

    /// Materialize the `src -> dst` credit channel up front so a known-hot
    /// link (scheduler tree edge) sits first in the sender's peer table.
    pub fn preseed_channel(&mut self, src: CoreId, dst: CoreId) {
        let _ = self.chan_entry(src, dst);
    }

    /// Mark the `src -> dst` link as legitimately uncredited: messages on
    /// it may be pushed directly (boot bootstrap) so a release finding
    /// zero in-flight credits there is expected, not a double release.
    /// See [`crate::noc::channel::Channel::allow_uncredited`].
    pub fn expect_uncredited(&mut self, src: CoreId, dst: CoreId) {
        self.chan_entry(src, dst).allow_uncredited();
    }

    /// Read-only view of the legacy credit-channel table. Sharded runs
    /// keep their channels in per-shard tables — invariant oracles must
    /// use [`SimState::channel_views`] to see every table in both modes.
    pub fn channels(&self) -> &ChannelTables {
        &self.channels
    }

    /// Every channel table of the run: the legacy table (always included,
    /// so test-only injections through [`SimState::channels_mut`] stay
    /// visible) plus one table per shard when sharded.
    pub fn channel_views(&self) -> Vec<&ChannelTables> {
        let mut v = vec![&self.channels];
        if let Some(sh) = &self.shard {
            v.extend(sh.channels.iter());
        }
        v
    }

    /// Mutable channel access for seeded-corruption tests only.
    #[cfg(test)]
    pub fn channels_mut(&mut self) -> &mut ChannelTables {
        &mut self.channels
    }

    /// True once every event (including wake markers and mailbox items)
    /// has been consumed.
    pub fn queue_is_empty(&self) -> bool {
        match &self.shard {
            None => self.queue.is_empty(),
            Some(sh) => {
                sh.wheels.iter().all(|w| w.is_empty())
                    && sh.held.iter().all(|h| h.is_none())
                    && sh.inbox.iter().all(|i| i.is_empty())
            }
        }
    }

    /// Schedule delivery of a message whose chaos delay `extra` was
    /// already drawn at send time (see [`Ctx::send_via`]): wire latency,
    /// the carried delay, then the per-link FIFO clamp. Draw-free, so a
    /// parked send delivered later (credit release, crash re-adoption)
    /// consumes no randomness — the chaos schedule is a pure function of
    /// the send order, never of when credits freed up.
    fn deliver_msg(
        &mut self,
        t_send: Cycles,
        from: CoreId,
        hop: CoreId,
        dst: CoreId,
        msg: Msg,
        extra: Cycles,
    ) {
        let lat = self.cost.msg_latency(self.topo.hops(from, hop));
        let mut at = t_send + lat + extra;
        if self.chaos.active() {
            // Clamped so same-link deliveries never reorder (per-link
            // FIFO is load-bearing for load accounting and the dep
            // protocol).
            at = self.chaos.fifo_floor(from, hop, at);
        }
        self.push(at, hop, Event::Msg { from, dst, msg });
    }
}

/// Handler context: everything a core's logic may touch while processing
/// one event. Time charged through `charge`/`charge_task` advances the
/// core's cursor; messages and DMA orders are stamped at the cursor.
pub struct Ctx<'a> {
    pub sim: &'a mut SimState,
    pub world: &'a mut World,
    pub registry: &'a Registry,
    pub core: CoreId,
    start: Cycles,
    charged_rt: Cycles,
    charged_task: Cycles,
}

impl<'a> Ctx<'a> {
    /// Current cursor: event start time plus everything charged so far.
    pub fn now(&self) -> Cycles {
        self.start + self.charged_rt + self.charged_task
    }

    pub fn kind(&self) -> CoreKind {
        self.sim.metas[self.core.idx()].kind
    }

    /// Charge `mb_cycles` of *runtime* work, scaled by this core's speed.
    pub fn charge(&mut self, mb_cycles: Cycles) {
        if mb_cycles == 0 {
            return;
        }
        let kind = self.kind();
        self.charged_rt += self.sim.cost.charge_on(kind, mb_cycles);
    }

    /// Charge `mb_cycles` of *application task* work, scaled by core speed.
    pub fn charge_task(&mut self, mb_cycles: Cycles) {
        if mb_cycles == 0 {
            return;
        }
        let kind = self.kind();
        self.charged_task += self.sim.cost.charge_on(kind, mb_cycles);
    }

    /// Send a control message directly to `to`. Charges sender-side push
    /// cost, consumes a channel credit (or queues the send if the peer's
    /// buffer is full) and schedules delivery after the wire latency.
    pub fn send(&mut self, to: CoreId, msg: Msg) {
        self.send_via(to, to, msg);
    }

    /// Send a control message whose final destination is `dst`, delivered
    /// to the adjacent tree hop `next` (which forwards it on if it is not
    /// the destination). This is the allocation-free replacement for the
    /// old boxed `Msg::Route` envelope: the payload is moved, never
    /// re-heaped, across hops.
    pub fn send_via(&mut self, next: CoreId, dst: CoreId, msg: Msg) {
        let wires = msg.wire_msgs();
        self.charge(self.sim.cost.msg_send * wires);
        let st = &mut self.sim.stats[self.core.idx()];
        st.msgs_sent += wires;
        st.msg_bytes_sent += wires * self.sim.cost.msg_bytes;
        let t_send = self.start + self.charged_rt + self.charged_task;
        let cap = self.sim.channel_capacity;
        let shard = self.sim.shard_ix(self.core);
        // Fault injection: every chaos draw happens at *send* time, on
        // the sender's shard lane — transient credit starvation (only
        // legal while the channel has messages in flight: the matching
        // release is what unparks blocked sends, so starving an idle
        // channel would strand the message forever), then the
        // class-targeted delay, then bounded generic jitter. A parked
        // send carries its drawn delay with it (`Channel::blocked`), so
        // the draw schedule depends only on the per-lane send order —
        // which is what keeps it identical across thread counts.
        let starve = self.sim.chaos.active() && self.sim.chaos.draw_starve(shard);
        let extra = if self.sim.chaos.active() {
            let class = match &msg {
                Msg::LoadReport { .. } | Msg::QuiesceUp { .. } => MsgClass::Report,
                Msg::StealGrant { .. } => MsgClass::Grant,
                _ => MsgClass::Other,
            };
            self.sim.chaos.class_delay(class, shard) + self.sim.chaos.jitter_extra(shard)
        } else {
            0
        };
        // Threaded window: a cross-shard send must not touch the link's
        // credit channel mid-window (the canonical interleaving with the
        // other endpoint's traffic is not known yet). The charge, wire
        // stats and chaos draws above are all sender-local and already
        // done; defer the credit decision itself to the barrier walk,
        // which replays attempts in canonical order.
        if let Some(sh) = &self.sim.shard {
            if sh.threads > 1 && sh.shard_of[next.idx()] as usize != shard {
                if let Some(log) = par::tl_log() {
                    par::defer_send(
                        log,
                        par::SendAttempt { t_send, from: self.core, hop: next, dst, msg, extra, starve },
                    );
                    return;
                }
            }
        }
        let (acquired, starved) = {
            let ch = self.sim.chan_entry(self.core, next);
            if !ch.blocked.is_empty() {
                // Preserve send order behind already-parked messages.
                (false, false)
            } else if starve && ch.in_flight > 0 {
                (false, true)
            } else {
                (ch.try_acquire(cap), false)
            }
        };
        if starved {
            self.sim.chaos.note_starved(shard);
        }
        if acquired {
            self.sim.deliver_msg(t_send, self.core, next, dst, msg, extra);
        } else {
            // Cold path: out of credits (or starved); re-find the channel
            // (the borrow cannot span `deliver_msg` above) and park the
            // send with its pre-drawn delay.
            self.sim.chan_entry(self.core, next).blocked.push_back((t_send, dst, msg, extra));
        }
    }

    /// Order a group of DMA transfers into this core. Returns the group id;
    /// an [`Event::DmaDone`] fires when the whole group completes. An empty
    /// group completes after just the issue cost.
    pub fn dma_group(&mut self, transfers: Vec<Transfer>) -> u64 {
        let id = self.sim.dma_seq.fetch_add(1, Ordering::Relaxed);
        // Issue cost: one DMA start charge per transfer.
        self.charge(self.sim.cost.dma_start * transfers.len() as Cycles);
        for t in &transfers {
            self.dma_stat(t.src, t.bytes, true);
            self.dma_stat(t.dst, t.bytes, false);
        }
        self.world.gstats.dma_transfers += transfers.len() as u64;
        let done = group_completion(&self.sim.cost, &transfers);
        let at = self.now() + done;
        let core = self.core;
        self.sim.push(at, core, Event::DmaDone { group: id });
        id
    }

    /// Charge a DMA byte counter on `core`'s [`CoreStats`]. Inside a
    /// threaded window a transfer endpoint may live on another shard —
    /// bump it through the shard log instead (applied at the barrier) so
    /// no two threads ever write the same `CoreStats` slot.
    fn dma_stat(&mut self, core: CoreId, bytes: u64, out: bool) {
        if let (Some(sh), Some(log)) = (&self.sim.shard, par::tl_log()) {
            if sh.threads > 1 && sh.shard_of[core.idx()] as usize != log.shard {
                log.remote_dma.push((core, bytes, out));
                return;
            }
        }
        let st = &mut self.sim.stats[core.idx()];
        if out {
            st.dma_bytes_out += bytes;
        } else {
            st.dma_bytes_in += bytes;
        }
    }

    /// Schedule a timer event for this core `delay` cycles from the cursor.
    pub fn after(&mut self, delay: Cycles, kind: TimerKind) {
        let at = self.now() + delay;
        let core = self.core;
        self.sim.push(at, core, Event::Timer(kind));
    }

    /// Schedule a timer for another core (used by experiment drivers).
    pub fn timer_for(&mut self, core: CoreId, delay: Cycles, kind: TimerKind) {
        let at = self.now() + delay;
        self.sim.push(at, core, Event::Timer(kind));
    }

    /// Mesh hop distance from this core.
    pub fn hops_to(&self, to: CoreId) -> u32 {
        self.sim.topo.hops(self.core, to)
    }

    /// Fault injection: bounded stall (cycles) to charge before handling
    /// the current event. Always 0 when no fault plan is active — the
    /// inactive path draws no randomness and charges nothing.
    pub fn chaos_stall(&mut self) -> Cycles {
        if !self.sim.chaos.active() {
            return 0;
        }
        let shard = self.sim.shard_ix(self.core);
        self.sim.chaos.stall(shard)
    }

    /// Fault injection: must this steal request be denied regardless of
    /// queue depth? Always false when no fault plan is active.
    pub fn chaos_force_deny(&mut self) -> bool {
        if !self.sim.chaos.active() {
            return false;
        }
        let shard = self.sim.shard_ix(self.core);
        self.sim.chaos.force_deny(shard)
    }

    /// Recovery: re-adopt a dead scheduler's mailbox — future events for
    /// `dead` are drained toward `to` (uncredited forwards).
    pub fn adopt_mailbox(&mut self, dead: CoreId, to: CoreId) {
        self.sim.set_redirect(dead, Some(to));
    }

    /// Recovery: give a restarted scheduler its mailbox back.
    pub fn restore_mailbox(&mut self, core: CoreId) {
        self.sim.set_redirect(core, None);
    }
}

/// Logic driving one simulated core.
pub trait CoreLogic {
    fn on_event(&mut self, ctx: &mut Ctx<'_>, ev: Event);

    /// Crash-recovery hook: wipe volatile state after a restart. Called
    /// by the engine exactly once, immediately before the first event the
    /// fresh incarnation processes. Default: no-op (workers never crash).
    fn on_crash_restart(&mut self) {}

    /// Downcast hook for diagnostics and tests (e.g. inspecting a
    /// scheduler's load estimates after a run). Default: not downcastable.
    fn as_any(&self) -> Option<&dyn std::any::Any> {
        None
    }

    /// Mutable downcast hook (seeded-corruption tests for the invariant
    /// oracles). Default: not downcastable.
    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        None
    }
}

/// The assembled simulator: state + world + per-core logic.
pub struct Engine {
    pub sim: SimState,
    pub world: World,
    pub registry: Registry,
    logic: Vec<Option<Box<dyn CoreLogic>>>,
}

impl Engine {
    pub fn new(sim: SimState, world: World, registry: Registry) -> Self {
        let n = sim.n_cores();
        let mut logic = Vec::with_capacity(n);
        logic.resize_with(n, || None);
        Engine { sim, world, registry, logic }
    }

    pub fn set_logic(&mut self, core: CoreId, l: Box<dyn CoreLogic>) {
        self.logic[core.idx()] = Some(l);
    }

    /// Borrow a core's logic, if any (diagnostics/tests; see
    /// [`CoreLogic::as_any`] for downcasting to a concrete logic type).
    pub fn logic_of(&self, core: CoreId) -> Option<&dyn CoreLogic> {
        self.logic.get(core.idx()).and_then(|l| l.as_deref())
    }

    /// Mutable logic borrow (see [`CoreLogic::as_any_mut`]).
    pub fn logic_of_mut(&mut self, core: CoreId) -> Option<&mut dyn CoreLogic> {
        self.logic.get_mut(core.idx()).and_then(|l| l.as_deref_mut())
    }

    /// Schedule [`Event::Boot`] for every core with logic at t=0.
    pub fn boot(&mut self) {
        for i in 0..self.logic.len() {
            if self.logic[i].is_some() {
                self.sim.push(0, CoreId(i as u32), Event::Boot);
            }
        }
    }

    /// Run until the event queue drains, `world.done` is set, or the
    /// optional time limit is exceeded. Returns the final virtual time.
    pub fn run(&mut self, limit: Option<Cycles>) -> Cycles {
        self.run_inner(limit, true)
    }

    /// Like [`Engine::run`], but keeps processing past `world.done` until
    /// the event queue fully drains (or the limit cuts the run off).
    /// `run` discards whatever was still queued at the completion cutoff;
    /// the fuzz harness needs true quiescence, where strict invariants
    /// (channel credits restored, books exactly zero) are checkable.
    pub fn run_to_quiescence(&mut self, limit: Option<Cycles>) -> Cycles {
        self.run_inner(limit, false)
    }

    /// The threaded executor may run this configuration: more than one
    /// shard and thread requested, no event tracing, and none of the
    /// layers that mutate cross-shard global state outside the message
    /// seam (crash redirects, recovery heartbeats, traffic books, MPI
    /// rendezvous, real kernels) — on a workload whose prime closure
    /// opted in to the single-spawner contract ([`World::par_safe`]).
    /// Everything else falls back to the sequential merge, which is
    /// byte-identical by construction.
    fn par_eligible(&self) -> bool {
        let Some(sh) = &self.sim.shard else { return false };
        sh.n > 1
            && sh.threads > 1
            && self.world.par_safe
            && !self.sim.trace
            && self.sim.crash.is_none()
            && !self.world.cfg.recovery.enabled
            && self.world.traffic.is_none()
            && self.world.mpi.is_none()
            && self.world.kernels.is_none()
    }

    fn run_inner(&mut self, limit: Option<Cycles>, stop_on_done: bool) -> Cycles {
        if self.par_eligible() {
            return par::run_windows(self, limit, stop_on_done);
        }
        while let Some(popped) = self.sim.pop_next() {
            if stop_on_done && self.world.done {
                break;
            }
            let (p_t, core) = match &popped {
                Popped::Ev(q) => (q.t, q.core),
                Popped::Wake { t, core, .. } => (*t, *core),
            };
            if let Some(lim) = limit {
                if p_t > lim {
                    self.sim.now = lim;
                    break;
                }
            }
            let ci = core.idx();
            let (t, ev) = match popped {
                Popped::Ev(q) => {
                    let meta = &mut self.sim.metas[ci];
                    if meta.busy_until > q.t || !meta.pending.is_empty() {
                        // Core occupied (or draining earlier deferrals):
                        // park the event in arrival order behind a single
                        // drain marker ("workers do not interrupt running
                        // tasks", paper V-E). The marker goes to the wake
                        // side-heap, not back into the wheel.
                        meta.pending.push_back(q.ev);
                        let arm = if meta.wake_scheduled {
                            None
                        } else {
                            meta.wake_scheduled = true;
                            Some(meta.busy_until.max(q.t))
                        };
                        if let Some(at) = arm {
                            self.sim.push_wake(at, core);
                        }
                        continue;
                    }
                    (q.t, q.ev)
                }
                Popped::Wake { t, .. } => {
                    let meta = &mut self.sim.metas[ci];
                    meta.wake_scheduled = false;
                    if meta.busy_until > t {
                        // Re-extended meanwhile: re-arm.
                        let arm = if meta.pending.is_empty() {
                            None
                        } else {
                            meta.wake_scheduled = true;
                            Some(meta.busy_until)
                        };
                        if let Some(at) = arm {
                            self.sim.push_wake(at, core);
                        }
                        continue;
                    }
                    match meta.pending.pop_front() {
                        Some(ev) => (t, ev),
                        None => continue,
                    }
                }
            };
            // Crash interception (single `Option` test when no crash is
            // installed — the default path is untouched).
            if let Some(c) = self.sim.crash {
                if c.core == core && !c.restarted {
                    let down = t >= c.at && c.up_at.is_none_or(|u| t < u);
                    if down && !self.world.done {
                        if !self.sim.crash.as_mut().expect("checked").fired {
                            self.sim.crash.as_mut().expect("checked").fired = true;
                            self.world.gstats.crashes += 1;
                        }
                        match ev {
                            Event::Msg { from, dst, msg } => {
                                if let Some(target) = self.sim.redirect[ci] {
                                    // Re-adopted: drain the dead mailbox
                                    // toward the adoptive parent. Return
                                    // the sender's credit (the message
                                    // left the buffer) and forward
                                    // uncredited — the link is marked so
                                    // the release at processing time is
                                    // expected, not a double release.
                                    let released = self
                                        .sim
                                        .chan_get_mut(from, core)
                                        .and_then(|ch| ch.release());
                                    if let Some((t_blk, b_dst, b_msg, b_extra)) = released {
                                        let stall = t.saturating_sub(t_blk);
                                        self.sim.stats[from.idx()].credit_stall += stall;
                                        self.sim.deliver_msg(t, from, core, b_dst, b_msg, b_extra);
                                    }
                                    // Destination rewrite: traffic for the
                                    // dead core itself goes to the adopter;
                                    // traffic merely routed *through* it
                                    // (worker <-> ancestors) skips the dead
                                    // hop straight to its destination. The
                                    // adopter owns the dead switch's
                                    // routing table — bouncing transit off
                                    // the adopter would loop forever, since
                                    // its tree route back towards the
                                    // destination passes through this very
                                    // core.
                                    let fwd = if dst == core { target } else { dst };
                                    self.sim.expect_uncredited(core, fwd);
                                    self.sim.push(
                                        t,
                                        fwd,
                                        Event::Msg { from: core, dst: fwd, msg },
                                    );
                                } else {
                                    // Not yet re-adopted: the hardware
                                    // mailbox holds the message; re-check
                                    // after a fixed poll interval (equal
                                    // delays preserve per-link FIFO).
                                    self.sim.chaos.note_requeued();
                                    self.sim.push(
                                        t + CRASH_MAILBOX_RETRY,
                                        core,
                                        Event::Msg { from, dst, msg },
                                    );
                                }
                            }
                            // Timers and markers of the dead incarnation
                            // die with it; the fresh one re-arms its own.
                            _ => {}
                        }
                        // Keep draining whatever was parked behind the
                        // busy cursor pre-crash: the normal re-arm runs
                        // after the handler, which we just skipped.
                        let rearm = {
                            let meta = &mut self.sim.metas[ci];
                            if !meta.pending.is_empty() && !meta.wake_scheduled {
                                meta.wake_scheduled = true;
                                true
                            } else {
                                false
                            }
                        };
                        if rearm {
                            self.sim.push_wake(t, core);
                        }
                        continue;
                    }
                    if t >= c.at {
                        // Restart transition: past the down window (or a
                        // crash surfacing after completion, too late for
                        // the liveness protocol — re-bootstrap so the
                        // teardown drain cannot wedge on a dark mailbox).
                        let cs = self.sim.crash.as_mut().expect("checked");
                        cs.restarted = true;
                        if !cs.fired {
                            cs.fired = true;
                            self.world.gstats.crashes += 1;
                        }
                        self.world.gstats.restarts += 1;
                        // The reboot clears the pipeline: whatever the
                        // dead incarnation was "executing" is gone.
                        self.sim.metas[ci].busy_until = t;
                        if let Some(l) = self.logic[ci].as_deref_mut() {
                            l.on_crash_restart();
                        }
                    }
                }
            }

            debug_assert!(t >= self.sim.now, "time went backwards");
            self.sim.now = t;
            self.world.gstats.events_processed += 1;

            // Message bookkeeping the handler should not have to repeat:
            // credit return, receive stats, receiver processing cost.
            let mut init_charge = 0;
            if let Event::Msg { from, msg, .. } = &ev {
                let wires = msg.wire_msgs();
                let st = &mut self.sim.stats[ci];
                st.msgs_recv += wires;
                st.msg_bytes_recv += wires * self.sim.cost.msg_bytes;
                self.world.gstats.msgs_total += wires;
                let hops = self.sim.topo.hops(*from, core);
                let proc = self.sim.cost.msg_proc(hops, self.sim.topo.max_hops()) * wires;
                init_charge = self.sim.cost.charge_on(self.sim.metas[ci].kind, proc);
                // Return the credit; a blocked send may claim it.
                let released =
                    self.sim.chan_get_mut(*from, core).and_then(|ch| ch.release());
                if let Some((t_blocked, blocked_dst, blocked_msg, blocked_extra)) = released {
                    let stall = t.saturating_sub(t_blocked);
                    self.sim.stats[from.idx()].credit_stall += stall;
                    self.sim.deliver_msg(t, *from, core, blocked_dst, blocked_msg, blocked_extra);
                }
            }

            if self.sim.trace {
                let tag = match &ev {
                    Event::Boot => "Boot".to_string(),
                    Event::Msg { from, msg, .. } => format!("Msg({}) from {from}", msg.tag()),
                    Event::DmaDone { group } => format!("DmaDone({group})"),
                    Event::Timer(k) => format!("Timer({k:?})"),
                    Event::Wake => "Wake".to_string(),
                };
                eprintln!("[{t:>12}] {core} <- {tag}");
            }

            let mut logic = self.logic[ci].take().expect("event for core without logic");
            let mut ctx = Ctx {
                sim: &mut self.sim,
                world: &mut self.world,
                registry: &self.registry,
                core,
                start: t,
                charged_rt: init_charge,
                charged_task: 0,
            };
            logic.on_event(&mut ctx, ev);
            let (rt, tk) = (ctx.charged_rt, ctx.charged_task);
            self.logic[ci] = Some(logic);
            let busy = t + rt + tk;
            self.sim.metas[ci].busy_until = busy;
            self.sim.note_busy(core, busy);
            // More deferred work waiting: re-arm the drain marker.
            let rearm = {
                let meta = &mut self.sim.metas[ci];
                if !meta.pending.is_empty() && !meta.wake_scheduled {
                    meta.wake_scheduled = true;
                    true
                } else {
                    false
                }
            };
            if rearm {
                self.sim.push_wake(busy, core);
            }
            let st = &mut self.sim.stats[ci];
            st.busy_task += tk;
            st.busy_runtime += rt;
        }
        // No shard is executing between runs: pushes from test scaffolding
        // (or a later `run_to_quiescence` continuation) must not be
        // misclassified as cross-shard traffic.
        if let Some(sh) = &mut self.sim.shard {
            sh.exec = None;
        }
        self.sim.now
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::ids::ReqId;
    use crate::platform::World;

    /// Echo logic: replies to every message; counts events.
    struct Echo {
        seen: u64,
        work: Cycles,
    }

    impl CoreLogic for Echo {
        fn on_event(&mut self, ctx: &mut Ctx<'_>, ev: Event) {
            self.seen += 1;
            ctx.charge(self.work);
            if let Event::Msg { from, msg: Msg::SpawnAck { req }, .. } = ev {
                if req.0 < 5 {
                    ctx.send(from, Msg::SpawnAck { req: ReqId(req.0 + 1) });
                }
            }
        }
    }

    fn tiny_engine(n: usize, work: Cycles) -> Engine {
        let cfg = PlatformConfig::flat(1);
        let sim = SimState::new(
            vec![CoreKind::MicroBlaze; n],
            Topology::new(n),
            cfg.cost.clone(),
            cfg.channel_capacity,
        );
        let world = World::for_tests(cfg);
        let mut eng = Engine::new(sim, world, Registry::new());
        for i in 0..n {
            eng.set_logic(CoreId(i as u32), Box::new(Echo { seen: 0, work }));
        }
        eng
    }

    #[test]
    fn ping_pong_advances_time() {
        let mut eng = tiny_engine(2, 100);
        eng.sim.push(0, CoreId(0), Event::Msg { from: CoreId(1), dst: CoreId(0), msg: Msg::SpawnAck { req: ReqId(0) } });
        let end = eng.run(None);
        // 6 messages processed (req 0..=5), each with latency + processing.
        assert!(end > 6 * 100);
        assert_eq!(eng.world.gstats.msgs_total, 6);
    }

    #[test]
    fn busy_core_defers_events() {
        let mut eng = tiny_engine(1, 1000);
        // Two boot events can't exist, so use timers close together.
        eng.sim.push(0, CoreId(0), Event::Timer(TimerKind::Custom(0)));
        eng.sim.push(10, CoreId(0), Event::Timer(TimerKind::Custom(1)));
        let end = eng.run(None);
        // Second event deferred until t=1000, finishes at 2000.
        assert_eq!(end, 1000);
        assert_eq!(eng.sim.metas[0].busy_until, 2000);
        assert_eq!(eng.sim.stats[0].busy_runtime, 2000);
        // The incrementally maintained horizon matches.
        assert_eq!(eng.sim.horizon(), 2000);
    }

    #[test]
    fn far_future_timer_exercises_overflow_heap() {
        // 40 M cycles is beyond the wheel span (2^24): the second timer
        // parks in the far heap and refills the wheel lazily.
        let mut eng = tiny_engine(1, 10);
        eng.sim.push(0, CoreId(0), Event::Timer(TimerKind::Custom(0)));
        eng.sim.push(40_000_000, CoreId(0), Event::Timer(TimerKind::Custom(1)));
        let end = eng.run(None);
        assert_eq!(end, 40_000_000);
        assert_eq!(eng.sim.stats[0].busy_runtime, 20);
        assert_eq!(eng.sim.horizon(), 40_000_010);
    }

    #[test]
    fn deferred_drain_matches_wake_timing_with_later_traffic() {
        // A busy core with a parked event plus later traffic: the drain
        // marker (t=1000) must deliver the parked event before the t=1500
        // one, and both must run back-to-back off the busy cursor.
        let mut eng = tiny_engine(1, 1000);
        eng.sim.push(0, CoreId(0), Event::Timer(TimerKind::Custom(0)));
        eng.sim.push(10, CoreId(0), Event::Timer(TimerKind::Custom(1)));
        eng.sim.push(1500, CoreId(0), Event::Timer(TimerKind::Custom(2)));
        eng.run(None);
        // t=0 runs to 1000; drain at 1000 runs deferral to 2000; the
        // t=1500 event is deferred behind it and runs 2000..3000.
        assert_eq!(eng.sim.metas[0].busy_until, 3000);
        assert_eq!(eng.sim.stats[0].busy_runtime, 3000);
    }

    #[test]
    fn time_limit_stops_run() {
        let mut eng = tiny_engine(2, 100);
        eng.sim.push(0, CoreId(0), Event::Msg { from: CoreId(1), dst: CoreId(0), msg: Msg::SpawnAck { req: ReqId(0) } });
        let end = eng.run(Some(250));
        assert!(end <= 250);
    }

    #[test]
    fn credit_exhaustion_blocks_and_recovers() {
        let mut eng = tiny_engine(2, 50);
        eng.sim.channel_capacity = 1;
        // Core 0 sends 3 messages back-to-back to core 1 from one handler.
        struct Burst;
        impl CoreLogic for Burst {
            fn on_event(&mut self, ctx: &mut Ctx<'_>, ev: Event) {
                if matches!(ev, Event::Boot) {
                    for i in 0..3 {
                        ctx.send(CoreId(1), Msg::SpawnAck { req: ReqId(i) });
                    }
                }
            }
        }
        eng.set_logic(CoreId(0), Box::new(Burst));
        eng.sim.push(0, CoreId(0), Event::Boot);
        eng.run(None);
        // All three messages eventually processed by core 1.
        assert_eq!(eng.sim.stats[1].msgs_recv, 3);
        // Sender observed stall time from the blocked sends.
        assert!(eng.sim.stats[0].credit_stall > 0);
    }

    #[test]
    fn dma_group_completion_fires() {
        let mut eng = tiny_engine(3, 10);
        struct Fetch {
            done: bool,
        }
        impl CoreLogic for Fetch {
            fn on_event(&mut self, ctx: &mut Ctx<'_>, ev: Event) {
                match ev {
                    Event::Boot => {
                        ctx.dma_group(vec![
                            Transfer { src: CoreId(1), dst: CoreId(0), bytes: 4096, hops: 1 },
                            Transfer { src: CoreId(2), dst: CoreId(0), bytes: 1024, hops: 2 },
                        ]);
                    }
                    Event::DmaDone { .. } => self.done = true,
                    _ => {}
                }
            }
        }
        eng.set_logic(CoreId(0), Box::new(Fetch { done: false }));
        eng.sim.push(0, CoreId(0), Event::Boot);
        eng.run(None);
        assert_eq!(eng.sim.stats[0].dma_bytes_in, 5120);
        assert_eq!(eng.sim.stats[1].dma_bytes_out, 4096);
        assert_eq!(eng.world.gstats.dma_transfers, 2);
    }

    #[test]
    fn deterministic_replay() {
        let run = || {
            let mut eng = tiny_engine(2, 100);
            eng.sim
                .push(0, CoreId(0), Event::Msg { from: CoreId(1), dst: CoreId(0), msg: Msg::SpawnAck { req: ReqId(0) } });
            let t = eng.run(None);
            (t, eng.world.gstats.msgs_total, eng.sim.stats[0].busy_runtime)
        };
        assert_eq!(run(), run());
    }

    fn ping_pong_with(plan: &FaultPlan) -> (Cycles, u64) {
        let mut eng = tiny_engine(2, 100);
        eng.sim.install_chaos(plan, 0xB5EED);
        eng.sim
            .push(0, CoreId(0), Event::Msg { from: CoreId(1), dst: CoreId(0), msg: Msg::SpawnAck { req: ReqId(0) } });
        let t = eng.run(None);
        (t, eng.world.gstats.msgs_total)
    }

    #[test]
    fn disabled_fault_plan_is_inert() {
        // Installing FaultPlan::none() must leave the engine on the
        // baseline schedule (full byte-identity is pinned by the platform
        // fingerprints in tests/determinism.rs).
        assert_eq!(ping_pong_with(&FaultPlan::none()), ping_pong_with(&FaultPlan::none()));
        let base = {
            let mut eng = tiny_engine(2, 100);
            eng.sim
                .push(0, CoreId(0), Event::Msg { from: CoreId(1), dst: CoreId(0), msg: Msg::SpawnAck { req: ReqId(0) } });
            let t = eng.run(None);
            (t, eng.world.gstats.msgs_total)
        };
        assert_eq!(ping_pong_with(&FaultPlan::none()), base);
    }

    #[test]
    fn chaos_run_replays_and_never_drops_messages() {
        let plan = FaultPlan::from_seed(9);
        let a = ping_pong_with(&plan);
        let b = ping_pong_with(&plan);
        assert_eq!(a, b, "(seed, plan) must replay bit-identically");
        assert_eq!(a.1, 6, "faults delay but never drop messages");
    }

    #[test]
    fn crashed_core_parks_messages_until_restart() {
        let mut eng = tiny_engine(2, 10);
        eng.sim.install_crash(CoreId(1), 5, Some(50_000));
        // Three messages land during the down window (req >= 5 so the
        // echo logic does not reply). The mailbox must hold them — none
        // processed before the restart, all processed after it.
        for (i, t) in [10u64, 20, 30].into_iter().enumerate() {
            eng.sim.push(
                t,
                CoreId(1),
                Event::Msg {
                    from: CoreId(0),
                    dst: CoreId(1),
                    msg: Msg::SpawnAck { req: ReqId(7 + i as u64) },
                },
            );
        }
        let end = eng.run(None);
        assert!(end >= 50_000, "messages must wait out the down window");
        assert_eq!(eng.sim.stats[1].msgs_recv, 3, "mailbox holds, never drops");
        assert_eq!(eng.world.gstats.crashes, 1);
        assert_eq!(eng.world.gstats.restarts, 1);
        assert!(eng.sim.chaos.msgs_requeued() > 0);
        assert!(eng.sim.crash().expect("installed").restarted);
    }

    #[test]
    fn readopted_mailbox_forwards_to_redirect_target() {
        let mut eng = tiny_engine(3, 10);
        // Permanent death of core 1; its mailbox is re-adopted by core 2.
        eng.sim.install_crash(CoreId(1), 5, None);
        eng.sim.set_redirect(CoreId(1), Some(CoreId(2)));
        eng.sim.push(
            10,
            CoreId(1),
            Event::Msg { from: CoreId(0), dst: CoreId(1), msg: Msg::SpawnAck { req: ReqId(9) } },
        );
        eng.run(None);
        assert_eq!(eng.sim.stats[1].msgs_recv, 0, "dead core processes nothing");
        assert_eq!(eng.sim.stats[2].msgs_recv, 1, "forwarded to the adopter");
        assert_eq!(eng.world.gstats.crashes, 1);
        assert_eq!(eng.world.gstats.restarts, 0, "permanent death never restarts");
        assert_eq!(eng.sim.redirect_of(CoreId(1)), Some(CoreId(2)));
    }

    #[test]
    fn crash_replays_bit_identically() {
        let run = || {
            let mut eng = tiny_engine(2, 100);
            eng.sim.install_crash(CoreId(0), 300, Some(9_000));
            eng.sim.push(
                0,
                CoreId(0),
                Event::Msg {
                    from: CoreId(1),
                    dst: CoreId(0),
                    msg: Msg::SpawnAck { req: ReqId(0) },
                },
            );
            let t = eng.run(None);
            (t, eng.world.gstats.msgs_total, eng.sim.stats[0].busy_runtime)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn forced_starvation_parks_but_never_loses_messages() {
        let mut eng = tiny_engine(2, 50);
        let plan = FaultPlan {
            enabled: true,
            plan_seed: 1,
            starve_pct: 100,
            ..FaultPlan::none()
        };
        eng.sim.install_chaos(&plan, 0xB5EED);
        struct Burst;
        impl CoreLogic for Burst {
            fn on_event(&mut self, ctx: &mut Ctx<'_>, ev: Event) {
                if matches!(ev, Event::Boot) {
                    for i in 0..3 {
                        ctx.send(CoreId(1), Msg::SpawnAck { req: ReqId(i) });
                    }
                }
            }
        }
        eng.set_logic(CoreId(0), Box::new(Burst));
        eng.sim.push(0, CoreId(0), Event::Boot);
        eng.run(None);
        // Starvation parks sends behind in-flight messages, and each
        // release unparks the next one — nothing may be lost.
        assert_eq!(eng.sim.stats[1].msgs_recv, 3);
        assert!(eng.sim.chaos.starves() > 0, "100% starvation must park some send");
    }

    fn install_two_shards(eng: &mut Engine, lookahead: Option<Cycles>) {
        let part = ShardPartition {
            n_shards: 2,
            shard_of: vec![0, 1],
            cross_links: vec![(CoreId(0), CoreId(1))],
        };
        eng.sim.install_sharding(&part, lookahead);
    }

    /// Cross-shard ping-pong with a slow far-side core and a far-future
    /// timer parked in its wheel. This forces every sharded-only path:
    /// cross-shard mailbox delivery, the held-slot merge against a later
    /// wheel head, and a drain-marker wake routed through the inbox
    /// because it lands *behind* the shard's held cursor (t=10_000 wake
    /// vs a t=50_000 held timer).
    fn cross_shard_ping_pong(sharded: bool) -> (Cycles, u64, Cycles, Cycles, Cycles) {
        let mut eng = tiny_engine(2, 10);
        eng.set_logic(CoreId(1), Box::new(Echo { seen: 0, work: 10_000 }));
        if sharded {
            install_two_shards(&mut eng, None);
        }
        eng.sim.push(0, CoreId(1), Event::Timer(TimerKind::Custom(0)));
        eng.sim.push(50_000, CoreId(1), Event::Timer(TimerKind::Custom(1)));
        eng.sim.push(
            0,
            CoreId(0),
            Event::Msg { from: CoreId(1), dst: CoreId(0), msg: Msg::SpawnAck { req: ReqId(0) } },
        );
        let t = eng.run(None);
        assert!(eng.sim.queue_is_empty(), "both modes must drain fully");
        (
            t,
            eng.world.gstats.msgs_total,
            eng.sim.stats[0].busy_runtime,
            eng.sim.stats[1].busy_runtime,
            eng.sim.horizon(),
        )
    }

    #[test]
    fn sharded_run_is_bit_identical_to_legacy() {
        assert_eq!(cross_shard_ping_pong(true), cross_shard_ping_pong(false));
    }

    #[test]
    fn sharded_run_uses_mailboxes_and_windows() {
        let mut eng = tiny_engine(2, 10);
        eng.set_logic(CoreId(1), Box::new(Echo { seen: 0, work: 10_000 }));
        install_two_shards(&mut eng, None);
        assert_eq!(eng.sim.n_shards(), 2);
        let la = eng.sim.shard_lookahead().expect("sharded");
        assert!(la >= 1, "lookahead derives from the cross link latency");
        eng.sim.push(0, CoreId(1), Event::Timer(TimerKind::Custom(0)));
        eng.sim.push(50_000, CoreId(1), Event::Timer(TimerKind::Custom(1)));
        eng.sim.push(
            0,
            CoreId(0),
            Event::Msg { from: CoreId(1), dst: CoreId(0), msg: Msg::SpawnAck { req: ReqId(0) } },
        );
        eng.run(None);
        assert_eq!(eng.world.gstats.msgs_total, 6, "full ping-pong ran");
        assert!(eng.sim.shard_mail_events() > 0, "replies crossed via the mailbox");
        assert!(eng.sim.shard_windows() > 1, "run spans several lookahead windows");
        // Channels live in the per-shard tables now; the merged view sees
        // them while the legacy table stays empty.
        let views = eng.sim.channel_views();
        assert_eq!(views.len(), 3, "legacy + one per shard");
        assert_eq!(views[0].iter().count(), 0, "legacy table unused when sharded");
        assert!(views[1].iter().count() + views[2].iter().count() > 0);
    }

    #[test]
    fn single_shard_install_is_a_no_op() {
        let mut eng = tiny_engine(2, 100);
        let part =
            ShardPartition { n_shards: 1, shard_of: vec![0, 0], cross_links: Vec::new() };
        eng.sim.install_sharding(&part, None);
        assert_eq!(eng.sim.n_shards(), 1);
        assert!(eng.sim.shard_lookahead().is_none());
        assert_eq!(eng.sim.shard_windows(), 0);
    }

    #[test]
    fn lookahead_override_wins_over_derived() {
        let mut eng = tiny_engine(2, 100);
        install_two_shards(&mut eng, Some(5));
        assert_eq!(eng.sim.shard_lookahead(), Some(5));
    }
}

//! Discrete-event simulation substrate.
pub mod engine;
pub mod event;
pub mod rng;

//! Discrete-event simulation substrate.
pub mod chaos;
pub mod engine;
pub mod event;
pub mod rng;
pub mod traffic;
pub mod wheel;

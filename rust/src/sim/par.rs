//! The thread-parallel sharded executor: real host threads stepping
//! shards between conservative-PDES barriers, bit-identical to the
//! sequential merge.
//!
//! # Window protocol
//!
//! Per window the main thread computes `W` (the global minimum event
//! time over the shard heads) and `wend = W + lookahead`, then releases
//! one worker per `threads` through a [`Barrier`]. Worker `w` steps
//! every shard `k ≡ w (mod threads)`: it drains the shard's own wheel up
//! to `bound = min(wend, limit+1)`, running the exact per-event body of
//! `Engine::run_inner` (kept in sync by hand — see the comment there).
//! The conservative lookahead (minimum cross-shard link latency)
//! guarantees any cross-shard effect of an in-window event lands at or
//! after `wend`, so shards never need each other mid-window.
//!
//! # Provisional stamps (per-shard seq residue blocks)
//!
//! The sequential engine stamps every push from one global counter;
//! threads cannot share it without racing or diverging. Instead, shard
//! `k`'s `j`-th in-window push takes the *provisional* stamp
//! `PROV_BIT | (j·n + k)` — a residue-`k` block with the top bit set so
//! any provisional stamp sorts after every canonical stamp at equal
//! time, exactly where the sequential engine would have placed it (all
//! canonical stamps in a wheel predate the window; in-window pushes
//! would have drawn strictly larger stamps). Within a shard,
//! provisional order is push order, which is the canonical push order
//! restricted to that shard. Together these give the key invariant:
//! *a shard's local execution order equals the canonical global order
//! restricted to that shard.*
//!
//! # The barrier walk
//!
//! Each worker logs one [`Rec`] per pop (including deferral and
//! drain-marker iterations — they push wake markers, which consume
//! stamps) plus the ordered list of its pushes, staged events, and
//! deferred cross-shard send attempts. After the window, the main
//! thread merges all logs by `(t, canonical stamp)` — a provisional
//! stamp's canonical value is always known by the time it can surface
//! as a head, because its pushing record precedes it in the same
//! shard's log — and replays, in canonical order: deferred cross-link
//! credit releases, stamp assignment for direct pushes, routing of
//! staged events, and the credit decision of every deferred send. The
//! result is byte-identical stamp assignment, channel state, chaos
//! `link_last` floors and delivery times to the sequential merge.
//!
//! # Shared-state discipline (why `&mut Engine` per worker is sound)
//!
//! Workers formally alias `&mut Engine` but are *disjoint by
//! discipline*, which `Engine::par_eligible` enforces by construction:
//!
//! - Engine slices (`wheels`, `held`, `cursor`, `max_busy`, per-shard
//!   channel tables, `metas`/`stats` of own-shard cores) are indexed by
//!   shard — no two workers touch the same index.
//! - Cross-shard channels, cross-shard credit releases and off-shard
//!   `CoreStats` (DMA endpoints) are never touched mid-window — they
//!   are logged and applied by the main thread at the barrier.
//! - `World.gstats` is a [`GStats`] facade routing each thread to its
//!   own `WorldShard` slot; slots are reduced at quiescence.
//! - Chaos draws go through per-shard lanes (`sim::chaos`), so the RNG
//!   schedule is a function of per-shard execution order alone.
//! - Functional `World` state follows the ownership discipline (every
//!   region/node/task has one owning scheduler, cross-owner steps are
//!   messages) plus the [`World::par_safe`] single-spawner contract;
//!   cross-shard *reads* (task descriptors at dispatch) are of entries
//!   created at least one window earlier — the barrier provides the
//!   happens-before edge, and `SlotArena`'s chunked storage keeps the
//!   addresses stable under concurrent appends by the owner.
//! - DMA group ids come from an atomic counter; the ids are inert.
//!
//! Known, documented slack: in a `stop_on_done` run the workers of the
//! final window deterministically process events past the completion
//! cut; the walk restores every *global* counter and the busy horizon
//! exactly, but per-core `CoreStats` and channel occupancy keep those
//! extra (deterministic, thread-count-invariant fingerprints never read
//! them post-cut) contributions.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Barrier;

use crate::ids::{CoreId, Cycles};
use crate::noc::msg::Msg;
use crate::sim::event::Event;
use crate::sim::wheel::Popped;
use crate::stats::metrics::{GStats, GlobalStats};

use super::{Ctx, Engine, ShardState, SimState};

/// Top bit of a provisional stamp: sorts after every canonical stamp at
/// equal `t`, which is exactly the canonical position of an in-window
/// push relative to the pre-window wheel contents.
const PROV_BIT: u64 = 1 << 63;

/// A cross-shard send whose credit decision is deferred to the barrier
/// walk. Charge, wire stats and every chaos draw already happened at
/// send time on the sender's thread.
pub(super) struct SendAttempt {
    pub(super) t_send: Cycles,
    pub(super) from: CoreId,
    pub(super) hop: CoreId,
    pub(super) dst: CoreId,
    pub(super) msg: Msg,
    pub(super) extra: Cycles,
    pub(super) starve: bool,
}

/// An in-window push that could not enter a wheel directly: cross-shard,
/// or at/past the processing bound (it would survive the window with a
/// provisional stamp otherwise). Restamped and routed at the walk.
struct StagedEv {
    t: Cycles,
    core: CoreId,
    ev: Event,
}

/// One intra-handler action, in exact occurrence order. The walk replays
/// these to reassign canonical stamps: `Direct` consumes one stamp (the
/// event already sits in the shard's own wheel, provisionally stamped
/// and consumed in-window), `Staged`/`Send` route their payloads.
#[derive(Clone, Copy)]
enum Act {
    Direct,
    Staged(u32),
    Send(u32),
}

/// One pop-equivalent iteration of a shard's window loop.
struct Rec {
    t: Cycles,
    /// Raw stamp of the popped item: canonical (pre-window) or
    /// provisional (pushed earlier in this window by this shard).
    stamp: u64,
    /// Range into [`ShardLog::acts`].
    acts: (u32, u32),
    /// Deferred cross-link credit release `(from, to)`: the popped event
    /// was a message from another shard, so returning the credit (and
    /// possibly unparking a blocked send) must happen in canonical order
    /// at the walk.
    rel: Option<(CoreId, CoreId)>,
    d_spawned: u64,
    d_completed: u64,
    /// This shard's `WorldShard` stats slot *before* the iteration
    /// (cloned only in `stop_on_done` runs): the completion cut restores
    /// the slot to the snapshot of the first unwalked record.
    snap: GlobalStats,
    /// `ShardState::max_busy[shard]` before the iteration (same cut).
    pre_max_busy: Cycles,
}

/// Everything one shard logs during one window.
pub(super) struct ShardLog {
    pub(super) shard: usize,
    /// Shard count: the provisional-stamp residue modulus.
    n: u64,
    /// Process events with `t < bound` (= `min(wend, limit+1)`).
    bound: Cycles,
    /// Window end `W + lookahead`: cross-shard staged events must land
    /// at or after it (the conservative guarantee).
    wend: Cycles,
    snap_stats: bool,
    direct_j: u64,
    acts: Vec<Act>,
    staged: Vec<Option<StagedEv>>,
    sends: Vec<Option<SendAttempt>>,
    recs: Vec<Rec>,
    /// `canon_of[j]` = canonical stamp assigned to this shard's `j`-th
    /// direct push, filled by the walk in replay order.
    canon_of: Vec<u64>,
    /// Off-shard DMA endpoint stat bumps `(core, bytes, outbound)`,
    /// applied by the main thread at the barrier.
    pub(super) remote_dma: Vec<(CoreId, u64, bool)>,
    cur_acts0: u32,
    cur_rel: Option<(CoreId, CoreId)>,
}

impl ShardLog {
    fn new(shard: usize, n: usize) -> Self {
        ShardLog {
            shard,
            n: n as u64,
            bound: 0,
            wend: 0,
            snap_stats: false,
            direct_j: 0,
            acts: Vec::new(),
            staged: Vec::new(),
            sends: Vec::new(),
            recs: Vec::new(),
            canon_of: Vec::new(),
            remote_dma: Vec::new(),
            cur_acts0: 0,
            cur_rel: None,
        }
    }

    /// Reset for a new window (buffers keep their capacity).
    fn open(&mut self, bound: Cycles, wend: Cycles, snap_stats: bool) {
        self.bound = bound;
        self.wend = wend;
        self.snap_stats = snap_stats;
        self.direct_j = 0;
        self.acts.clear();
        self.staged.clear();
        self.sends.clear();
        self.recs.clear();
        self.canon_of.clear();
        self.remote_dma.clear();
        self.cur_acts0 = 0;
        self.cur_rel = None;
    }
}

thread_local! {
    /// The stepping thread's active window log (null = not inside a
    /// threaded window; every sequential path sees null and is
    /// untouched).
    static TL: Cell<*mut ShardLog> = const { Cell::new(std::ptr::null_mut()) };
}

/// The calling thread's window log, if it is stepping a shard.
pub(super) fn tl_log<'a>() -> Option<&'a mut ShardLog> {
    let p = TL.with(|c| c.get());
    if p.is_null() {
        None
    } else {
        // SAFETY: set by the owning worker around `step_shard`; the log
        // outlives the window and only this thread holds the pointer.
        Some(unsafe { &mut *p })
    }
}

fn set_tl(p: *mut ShardLog) {
    TL.with(|c| c.set(p));
}

fn log_of<'a>(p: *mut ShardLog) -> &'a mut ShardLog {
    // SAFETY: only the owning worker dereferences its log mid-window.
    unsafe { &mut *p }
}

/// Record an in-window push (called from `SimState::push`/`push_wake`
/// when a window log is bound): same-shard pushes inside the bound go
/// straight into the shard's own wheel under a provisional stamp (they
/// will be consumed before the window closes); everything else is
/// staged for canonical restamping at the walk.
pub(super) fn window_push(
    sh: &mut ShardState,
    log: &mut ShardLog,
    t: Cycles,
    core: CoreId,
    ev: Event,
) {
    let d = sh.shard_of[core.idx()] as usize;
    if d == log.shard && t < log.bound {
        let prov = PROV_BIT | (log.direct_j * log.n + log.shard as u64);
        log.direct_j += 1;
        log.acts.push(Act::Direct);
        match ev {
            Event::Wake => sh.wheels[d].push_wake(t, prov, core),
            ev => sh.wheels[d].push(t, prov, core, ev),
        }
    } else {
        let i = log.staged.len() as u32;
        log.staged.push(Some(StagedEv { t, core, ev }));
        log.acts.push(Act::Staged(i));
    }
}

/// Log a deferred cross-shard send attempt (called from `Ctx::send_via`).
pub(super) fn defer_send(log: &mut ShardLog, a: SendAttempt) {
    let i = log.sends.len() as u32;
    log.sends.push(Some(a));
    log.acts.push(Act::Send(i));
}

/// Canonical sort key of a logged record's stamp. A provisional stamp's
/// canonical value is already assigned: its pushing record precedes it
/// in the same shard's log, and the walk consumes a shard's records in
/// order.
fn canon_key(log: &ShardLog, stamp: u64) -> u64 {
    if stamp & PROV_BIT != 0 {
        log.canon_of[((stamp & !PROV_BIT) / log.n) as usize]
    } else {
        stamp
    }
}

fn pkey(p: &Popped) -> (Cycles, u64) {
    match p {
        Popped::Ev(q) => (q.t, q.seq),
        Popped::Wake { t, seq, .. } => (*t, *seq),
    }
}

/// Refill every shard's held head and return the window base `W` (the
/// global minimum event time), or `None` when everything has drained.
/// At window boundaries all stamps are canonical, so the keys compare
/// directly.
fn refill(sim: &mut SimState) -> Option<Cycles> {
    let sh = sim.shard.as_mut().expect("threaded executor is sharded");
    let mut w: Option<(Cycles, u64)> = None;
    for s in 0..sh.n {
        if sh.held[s].is_none() {
            if let Some(p) = sh.wheels[s].pop() {
                sh.cursor[s] = pkey(&p).0;
                sh.held[s] = Some(p);
            }
        }
        if let Some(p) = &sh.held[s] {
            let k = pkey(p);
            debug_assert_eq!(k.1 & PROV_BIT, 0, "provisional stamp survived a window");
            if w.is_none_or(|b| k < b) {
                w = Some(k);
            }
        }
        debug_assert!(sh.inbox[s].is_empty(), "threaded windows never use mailboxes");
    }
    w.map(|(t, _)| t)
}

/// Drop the single globally-earliest held event — the exact shape of the
/// sequential limit break, which pops one event past the limit and
/// discards it.
fn discard_global_min(sim: &mut SimState) {
    let sh = sim.shard.as_mut().expect("threaded executor is sharded");
    let mut best: Option<((Cycles, u64), usize)> = None;
    for s in 0..sh.n {
        if let Some(p) = &sh.held[s] {
            let k = pkey(p);
            if best.is_none_or(|(bk, _)| k < bk) {
                best = Some((k, s));
            }
        }
    }
    if let Some((_, s)) = best {
        sh.held[s] = None;
    }
}

/// Raw pointers shared with the worker threads. Access is partitioned
/// by the barrier protocol: workers touch the engine and their own logs
/// strictly between the window-open and window-close barriers; the main
/// thread strictly outside them.
struct Shared {
    eng: *mut Engine,
    logs: *mut ShardLog,
}
// SAFETY: see the struct docs and the module-level discipline notes.
unsafe impl Sync for Shared {}

/// Step shard `k` to the window bound. This is the per-event body of
/// `Engine::run_inner` minus the paths the eligibility gate excludes
/// (crash interception, tracing, the done break) — KEEP IN SYNC with it.
/// The caller bound this thread's stats slot and window log.
fn step_shard(eng: &mut Engine, k: usize, logp: *mut ShardLog) {
    let snap_stats = log_of(logp).snap_stats;
    loop {
        let bound = log_of(logp).bound;
        let popped = {
            let sh = eng.sim.shard.as_mut().expect("sharded");
            match sh.held[k].take() {
                Some(p) => {
                    if pkey(&p).0 >= bound {
                        sh.held[k] = Some(p);
                        break;
                    }
                    p
                }
                None => match sh.wheels[k].pop() {
                    Some(p) => {
                        let (t, _) = pkey(&p);
                        sh.cursor[k] = t;
                        if t >= bound {
                            sh.held[k] = Some(p);
                            break;
                        }
                        p
                    }
                    None => break,
                },
            }
        };
        let (p_t, p_seq, core) = match &popped {
            Popped::Ev(q) => (q.t, q.seq, q.core),
            Popped::Wake { t, seq, core } => (*t, *seq, *core),
        };
        let ci = core.idx();
        // Open the record: every pop is one walk slot, even deferral and
        // drain-marker iterations (their wake pushes consume stamps).
        {
            let lg = log_of(logp);
            lg.cur_acts0 = lg.acts.len() as u32;
            lg.cur_rel = None;
        }
        let snap =
            if snap_stats { eng.world.gstats.slot(k).clone() } else { GlobalStats::default() };
        let pre_max_busy = eng.sim.shard.as_ref().expect("sharded").max_busy[k];
        let (pre_sp, pre_co) = {
            let sl = eng.world.gstats.slot(k);
            (sl.tasks_spawned, sl.tasks_completed)
        };

        let processed: Option<(Cycles, Event)> = match popped {
            Popped::Ev(q) => {
                let meta = &mut eng.sim.metas[ci];
                if meta.busy_until > q.t || !meta.pending.is_empty() {
                    meta.pending.push_back(q.ev);
                    let arm = if meta.wake_scheduled {
                        None
                    } else {
                        meta.wake_scheduled = true;
                        Some(meta.busy_until.max(q.t))
                    };
                    if let Some(at) = arm {
                        eng.sim.push_wake(at, core);
                    }
                    None
                } else {
                    Some((q.t, q.ev))
                }
            }
            Popped::Wake { t, .. } => {
                let meta = &mut eng.sim.metas[ci];
                meta.wake_scheduled = false;
                if meta.busy_until > t {
                    let arm = if meta.pending.is_empty() {
                        None
                    } else {
                        meta.wake_scheduled = true;
                        Some(meta.busy_until)
                    };
                    if let Some(at) = arm {
                        eng.sim.push_wake(at, core);
                    }
                    None
                } else {
                    meta.pending.pop_front().map(|ev| (t, ev))
                }
            }
        };
        if let Some((t, ev)) = processed {
            eng.world.gstats.events_processed += 1;
            let mut init_charge = 0;
            if let Event::Msg { from, msg, .. } = &ev {
                let wires = msg.wire_msgs();
                let st = &mut eng.sim.stats[ci];
                st.msgs_recv += wires;
                st.msg_bytes_recv += wires * eng.sim.cost.msg_bytes;
                eng.world.gstats.msgs_total += wires;
                let hops = eng.sim.topo.hops(*from, core);
                let proc = eng.sim.cost.msg_proc(hops, eng.sim.topo.max_hops()) * wires;
                init_charge = eng.sim.cost.charge_on(eng.sim.metas[ci].kind, proc);
                let same_shard = eng.sim.shard.as_ref().expect("sharded").shard_of
                    [from.idx()] as usize
                    == k;
                if same_shard {
                    // Own link: the credit return is shard-local, run it
                    // inline exactly like the sequential engine.
                    let released =
                        eng.sim.chan_get_mut(*from, core).and_then(|ch| ch.release());
                    if let Some((t_blk, b_dst, b_msg, b_extra)) = released {
                        eng.sim.stats[from.idx()].credit_stall += t.saturating_sub(t_blk);
                        eng.sim.deliver_msg(t, *from, core, b_dst, b_msg, b_extra);
                    }
                } else {
                    // Cross link: defer to the walk (canonical order).
                    log_of(logp).cur_rel = Some((*from, core));
                }
            }
            let mut logic = eng.logic[ci].take().expect("event for core without logic");
            let mut ctx = Ctx {
                sim: &mut eng.sim,
                world: &mut eng.world,
                registry: &eng.registry,
                core,
                start: t,
                charged_rt: init_charge,
                charged_task: 0,
            };
            logic.on_event(&mut ctx, ev);
            let (rt, tk) = (ctx.charged_rt, ctx.charged_task);
            eng.logic[ci] = Some(logic);
            let busy = t + rt + tk;
            eng.sim.metas[ci].busy_until = busy;
            eng.sim.note_busy(core, busy);
            let rearm = {
                let meta = &mut eng.sim.metas[ci];
                if !meta.pending.is_empty() && !meta.wake_scheduled {
                    meta.wake_scheduled = true;
                    true
                } else {
                    false
                }
            };
            if rearm {
                eng.sim.push_wake(busy, core);
            }
            let st = &mut eng.sim.stats[ci];
            st.busy_task += tk;
            st.busy_runtime += rt;
        }
        let (post_sp, post_co) = {
            let sl = eng.world.gstats.slot(k);
            (sl.tasks_spawned, sl.tasks_completed)
        };
        let lg = log_of(logp);
        let acts1 = lg.acts.len() as u32;
        lg.recs.push(Rec {
            t: p_t,
            stamp: p_seq,
            acts: (lg.cur_acts0, acts1),
            rel: lg.cur_rel.take(),
            d_spawned: post_sp - pre_sp,
            d_completed: post_co - pre_co,
            snap,
            pre_max_busy,
        });
    }
}

/// The barrier walk: merge every shard's window log in canonical
/// `(t, stamp)` order and replay the stamp assignments, staged routings,
/// credit releases and deferred sends the sequential engine would have
/// interleaved. Returns `true` when the completion gate fired (the run
/// is cut at that record, exactly like the sequential `run` break).
fn walk(eng: &mut Engine, logs: &mut [ShardLog], stop_on_done: bool) -> bool {
    let n = logs.len();
    // Off-shard DMA endpoint stats: plain counters, order-free.
    for log in logs.iter() {
        for &(c, bytes, out) in &log.remote_dma {
            let st = &mut eng.sim.stats[c.idx()];
            if out {
                st.dma_bytes_out += bytes;
            } else {
                st.dma_bytes_in += bytes;
            }
        }
    }
    // Running completion totals as of the window start: a shard's first
    // record snapshot is its slot before the window; a shard without
    // records left its slot untouched. (Gate evaluation is exact because
    // spawn bumps and completion bumps never share an event.)
    let (mut completed, mut spawned) = if stop_on_done {
        let g = &eng.world.gstats;
        let mut c = g.tasks_completed; // main-thread deref = the main slot
        let mut s = g.tasks_spawned;
        for (k, log) in logs.iter().enumerate() {
            let (kc, ks) = match log.recs.first() {
                Some(r0) => (r0.snap.tasks_completed, r0.snap.tasks_spawned),
                None => {
                    let sl = g.slot(k);
                    (sl.tasks_completed, sl.tasks_spawned)
                }
            };
            c += kc;
            s += ks;
        }
        (c, s)
    } else {
        (0, 0)
    };
    let mut ptr = vec![0usize; n];
    let mut last_t = eng.sim.now;
    loop {
        let mut best: Option<(Cycles, u64, usize)> = None;
        for (k, log) in logs.iter().enumerate() {
            if ptr[k] < log.recs.len() {
                let r = &log.recs[ptr[k]];
                let key = canon_key(log, r.stamp);
                if best.is_none_or(|(bt, bs, _)| (r.t, key) < (bt, bs)) {
                    best = Some((r.t, key, k));
                }
            }
        }
        let Some((t, _, k)) = best else { break };
        last_t = t;
        let (a0, a1, rel, d_sp, d_co) = {
            let r = &logs[k].recs[ptr[k]];
            (r.acts.0, r.acts.1, r.rel, r.d_spawned, r.d_completed)
        };
        debug_assert!(!(d_sp > 0 && d_co > 0), "spawn and completion share an event");
        // Credit return for a cross-shard message, before the handler's
        // own pushes — the sequential bookkeeping order.
        if let Some((from, to)) = rel {
            let released = eng.sim.chan_get_mut(from, to).and_then(|ch| ch.release());
            if let Some((t_blk, b_dst, b_msg, b_extra)) = released {
                eng.sim.stats[from.idx()].credit_stall += t.saturating_sub(t_blk);
                eng.sim.deliver_msg(t, from, to, b_dst, b_msg, b_extra);
                eng.sim.shard.as_mut().expect("sharded").mail_events += 1;
            }
        }
        for a in a0..a1 {
            match logs[k].acts[a as usize] {
                Act::Direct => {
                    let s = eng.sim.seq;
                    eng.sim.seq += 1;
                    logs[k].canon_of.push(s);
                }
                Act::Staged(i) => {
                    let sev = logs[k].staged[i as usize].take().expect("staged routed once");
                    let s = eng.sim.seq;
                    eng.sim.seq += 1;
                    let sh = eng.sim.shard.as_mut().expect("sharded");
                    let d = sh.shard_of[sev.core.idx()] as usize;
                    if d != k {
                        debug_assert!(
                            sev.t >= logs[k].wend,
                            "cross-shard event inside the conservative window"
                        );
                        sh.mail_events += 1;
                    }
                    match sev.ev {
                        Event::Wake => sh.wheels[d].push_wake(sev.t, s, sev.core),
                        ev => sh.wheels[d].push(sev.t, s, sev.core, ev),
                    }
                }
                Act::Send(i) => {
                    let at = logs[k].sends[i as usize].take().expect("send replayed once");
                    let cap = eng.sim.channel_capacity;
                    let (acquired, starved) = {
                        let ch = eng.sim.chan_entry(at.from, at.hop);
                        if !ch.blocked.is_empty() {
                            (false, false)
                        } else if at.starve && ch.in_flight > 0 {
                            (false, true)
                        } else {
                            (ch.try_acquire(cap), false)
                        }
                    };
                    if starved {
                        let lane = eng.sim.shard_ix(at.from);
                        eng.sim.chaos.note_starved(lane);
                    }
                    if acquired {
                        eng.sim.shard.as_mut().expect("sharded").mail_events += 1;
                        eng.sim.deliver_msg(at.t_send, at.from, at.hop, at.dst, at.msg, at.extra);
                    } else {
                        eng.sim
                            .chan_entry(at.from, at.hop)
                            .blocked
                            .push_back((at.t_send, at.dst, at.msg, at.extra));
                    }
                }
            }
        }
        ptr[k] += 1;
        if stop_on_done {
            completed += d_co;
            spawned += d_sp;
            if d_co > 0 && completed == spawned {
                // Completion cut: this record is the last one the
                // sequential engine would process. Its own effects are
                // fully applied (above); everything canonically after it
                // is discarded, and each shard's stats slot and busy
                // horizon roll back to the state before its first
                // unwalked record.
                for (j, log) in logs.iter().enumerate() {
                    if ptr[j] < log.recs.len() {
                        let r = &log.recs[ptr[j]];
                        *eng.world.gstats.slot_mut(j) = r.snap.clone();
                        eng.sim.shard.as_mut().expect("sharded").max_busy[j] = r.pre_max_busy;
                    }
                }
                eng.world.done = true;
                eng.sim.now = t;
                return true;
            }
        }
    }
    eng.sim.now = last_t;
    if stop_on_done {
        // Workers may have written a spurious `done` from shard-local
        // counters; the walk's totals are authoritative.
        eng.world.done = false;
    }
    false
}

/// The threaded run loop. Entered from `Engine::run_inner` when
/// `Engine::par_eligible` holds; everything else takes the sequential
/// merge. Persistent workers park on the barrier between windows.
pub(super) fn run_windows(eng: &mut Engine, limit: Option<Cycles>, stop_on_done: bool) -> Cycles {
    let (n, threads, lookahead) = {
        let sh = eng.sim.shard.as_ref().expect("par_eligible checked");
        (sh.n, sh.threads.clamp(1, sh.n), sh.lookahead)
    };
    eng.world.gstats.install_shards(n);
    if stop_on_done && eng.world.done {
        // The sequential loop pops one event, sees `done`, and breaks.
        let _ = eng.sim.pop_next();
        if let Some(sh) = &mut eng.sim.shard {
            sh.exec = None;
        }
        return eng.sim.now;
    }
    let mut logs: Vec<ShardLog> = (0..n).map(|k| ShardLog::new(k, n)).collect();
    let shared = Shared { eng: eng as *mut Engine, logs: logs.as_mut_ptr() };
    let barrier = Barrier::new(threads + 1);
    let stop = AtomicBool::new(false);

    std::thread::scope(|scope| {
        for w in 0..threads {
            let shared = &shared;
            let barrier = &barrier;
            let stop = &stop;
            scope.spawn(move || loop {
                barrier.wait();
                if stop.load(Ordering::Acquire) {
                    break;
                }
                for k in (w..n).step_by(threads) {
                    // SAFETY: disjoint-by-discipline access between the
                    // two barriers — see the module docs.
                    let eng = unsafe { &mut *shared.eng };
                    let logp = unsafe { shared.logs.add(k) };
                    GStats::set_slot(k);
                    set_tl(logp);
                    step_shard(eng, k, logp);
                    set_tl(std::ptr::null_mut());
                    GStats::clear_slot();
                }
                barrier.wait();
            });
        }

        loop {
            let w = match refill(&mut eng.sim) {
                Some(w) => w,
                None => break,
            };
            if let Some(lim) = limit {
                if w > lim {
                    discard_global_min(&mut eng.sim);
                    eng.sim.now = lim;
                    break;
                }
            }
            let wend = w + lookahead;
            let bound = match limit {
                Some(lim) => wend.min(lim + 1),
                None => wend,
            };
            {
                let sh = eng.sim.shard.as_mut().expect("sharded");
                sh.window_end = wend;
                sh.windows += 1;
            }
            for log in logs.iter_mut() {
                log.open(bound, wend, stop_on_done);
            }
            barrier.wait(); // open: workers step their shards
            barrier.wait(); // close: logs are ours again
            if walk(eng, &mut logs, stop_on_done) {
                break;
            }
        }
        stop.store(true, Ordering::Release);
        barrier.wait(); // release the parked workers into their exit
    });

    if !stop_on_done {
        // True quiescence: every queue drained (or the limit cut us
        // off). The gate the schedulers evaluate per-completion reduces
        // to final-count equality — evaluate it once on true totals,
        // overwriting any spurious shard-local verdict.
        let tot = eng.world.gstats.totals();
        eng.world.done = tot.tasks_completed > 0 && tot.tasks_completed == tot.tasks_spawned;
    }
    // Fold the per-shard stats slots into the main struct so every
    // post-run reader sees legacy totals.
    eng.world.gstats.reduce();
    eng.sim.now
}

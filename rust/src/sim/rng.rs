//! Deterministic xorshift64* RNG.
//!
//! All randomized decisions in the simulator (workload generation,
//! tie-breaking) flow through this generator so that every run is exactly
//! reproducible from `PlatformConfig::seed`.

/// xorshift64* — tiny, fast, good enough for workload generation.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        Rng { state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`. `n` must be non-zero.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Multiply-shift range reduction (Lemire); bias is negligible for
        // simulation workloads.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
            let x = r.range(5, 9);
            assert!((5..=9).contains(&x));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut acc = 0.0;
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            acc += x;
        }
        // Mean should be near 0.5.
        assert!((acc / 1000.0 - 0.5).abs() < 0.05);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn zero_seed_is_usable() {
        let mut r = Rng::new(0);
        // Must not get stuck at zero.
        assert_ne!(r.next_u64(), 0);
        assert_ne!(r.next_u64(), r.next_u64());
    }
}

//! Events delivered to simulated cores.

use crate::ids::{CoreId, Cycles, TaskId};
use crate::noc::msg::Msg;

/// Self-scheduled continuation kinds.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TimerKind {
    /// Continue replaying the op list of a running task on a worker.
    TaskStep(TaskId),
    /// Advance a mini-MPI rank program.
    MpiStep,
    /// Free-form continuation for app/experiment logic.
    Custom(u64),
}

/// An event delivered to a core at a point in virtual time.
#[derive(Clone, Debug)]
pub enum Event {
    /// Delivered once to every core at t=0 (initialize, kick off work).
    Boot,
    /// An incoming control message (after wire latency). The engine
    /// auto-charges receiver-side processing cost and handles the channel
    /// credit return before the handler runs. `dst` is the final
    /// destination: when it differs from the receiving core, the receiver
    /// is an intermediate hop on the scheduler tree and must forward the
    /// message (this replaces the old boxed `Msg::Route` envelope — the
    /// payload moves hop to hop without touching the heap).
    Msg { from: CoreId, dst: CoreId, msg: Msg },
    /// A previously ordered DMA group completed.
    DmaDone { group: u64 },
    /// Self-scheduled timer.
    Timer(TimerKind),
    /// Engine-internal: a busy core's deferred-event queue should drain
    /// (see `Engine::run`). Never delivered to core logic.
    Wake,
}

/// Queue entry: ordered by (time, sequence number) for determinism.
#[derive(Debug)]
pub struct Queued {
    pub t: Cycles,
    pub seq: u64,
    pub core: CoreId,
    pub ev: Event,
}

// Hot-path size budgets. Every queued event occupies a timing-wheel slab
// slot (`sim::wheel`) that is copied on push/cascade/pop, millions of
// times per run; `Queued` must stay within 2 cache lines (128 B) or every
// queue operation pays extra memory traffic. The budgets compose: Event's
// 104 B plus Queued's 24 B key header (t, seq, core + padding) is exactly
// the 128-B ceiling. The usual offender is a new `Msg` variant with
// inline payload — box or index large payloads instead (`ProducerRange`
// lists already do this via `Vec`). If a legitimate change needs more,
// re-budget BOTH asserts here WITH a hotpath-bench measurement
// (ROADMAP.md Performance section).
const _: () = assert!(std::mem::size_of::<Event>() <= 104, "Event grew past its hot-path budget");
const _: () = assert!(
    std::mem::size_of::<Queued>() <= 128,
    "Queued must stay within two cache lines"
);

impl PartialEq for Queued {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl Eq for Queued {}
impl PartialOrd for Queued {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Queued {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest first.
        (other.t, other.seq).cmp(&(self.t, self.seq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BinaryHeap;

    fn q(t: Cycles, seq: u64) -> Queued {
        Queued { t, seq, core: CoreId(0), ev: Event::Boot }
    }

    #[test]
    fn heap_pops_earliest_first() {
        let mut h = BinaryHeap::new();
        h.push(q(30, 0));
        h.push(q(10, 1));
        h.push(q(20, 2));
        assert_eq!(h.pop().unwrap().t, 10);
        assert_eq!(h.pop().unwrap().t, 20);
        assert_eq!(h.pop().unwrap().t, 30);
    }

    #[test]
    fn ties_break_by_sequence() {
        let mut h = BinaryHeap::new();
        h.push(q(10, 5));
        h.push(q(10, 2));
        h.push(q(10, 9));
        assert_eq!(h.pop().unwrap().seq, 2);
        assert_eq!(h.pop().unwrap().seq, 5);
        assert_eq!(h.pop().unwrap().seq, 9);
    }
}

//! 3D-mesh topology of the simulated 520-core platform.
//!
//! The Formic prototype arranges 64 octo-core boards in a 4x4x4 cube
//! (8x8x8 cores) with the two ARM boards attached to it. We model the
//! whole platform as a near-cubic 3D mesh; message and DMA latencies are a
//! function of the Manhattan hop distance between cores, matching the
//! prototype's 38-cycle (nearest) to 131-cycle (farthest) round-trip
//! message range.

use crate::ids::CoreId;

/// Coordinates of a core in the mesh.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Coord {
    pub x: u16,
    pub y: u16,
    pub z: u16,
}

/// Static mesh geometry: maps core ids to coordinates and computes hop
/// distances. Core ids are assigned by `sched::hierarchy` so that a leaf
/// scheduler and its workers occupy consecutive slots, which makes each
/// scheduling domain spatially contiguous — the same hand-placement the
/// paper applies to both MPI ranks and Myrmics workers ("we hand-select
/// the assignment ... so that they map as well as possible to the physical
/// topology of the 3D hardware platform").
#[derive(Clone, Debug)]
pub struct Topology {
    dims: (u16, u16, u16),
    coords: Vec<Coord>,
    max_hops: u32,
}

impl Topology {
    /// Build a near-cubic mesh with at least `n_cores` slots.
    pub fn new(n_cores: usize) -> Self {
        let n = n_cores.max(1);
        let dx = (n as f64).cbrt().ceil() as u16;
        let dy = ((n as f64 / dx as f64).sqrt().ceil() as u16).max(1);
        let dz = (n as f64 / (dx as f64 * dy as f64)).ceil().max(1.0) as u16;
        let mut coords = Vec::with_capacity(n);
        'fill: for z in 0..dz {
            for y in 0..dy {
                for x in 0..dx {
                    coords.push(Coord { x, y, z });
                    if coords.len() == n {
                        break 'fill;
                    }
                }
            }
        }
        let max_hops = (dx - 1) as u32 + (dy - 1) as u32 + (dz - 1) as u32;
        Topology { dims: (dx, dy, dz), coords, max_hops: max_hops.max(1) }
    }

    pub fn n_cores(&self) -> usize {
        self.coords.len()
    }

    pub fn dims(&self) -> (u16, u16, u16) {
        self.dims
    }

    pub fn coord(&self, c: CoreId) -> Coord {
        self.coords[c.idx()]
    }

    /// Manhattan hop distance between two cores (0 for the same core).
    pub fn hops(&self, a: CoreId, b: CoreId) -> u32 {
        let ca = self.coords[a.idx()];
        let cb = self.coords[b.idx()];
        ca.x.abs_diff(cb.x) as u32 + ca.y.abs_diff(cb.y) as u32 + ca.z.abs_diff(cb.z) as u32
    }

    /// Largest possible hop distance in this mesh (>= 1).
    pub fn max_hops(&self) -> u32 {
        self.max_hops
    }

    /// Maximum number of directly adjacent mesh slots any core can have
    /// (up to 2 per axis, fewer on degenerate dimensions). Sizes the
    /// per-core channel tables ([`crate::noc::channel::ChannelTables`]).
    pub fn max_degree(&self) -> usize {
        let (dx, dy, dz) = self.dims;
        [dx, dy, dz]
            .iter()
            .map(|&d| match d {
                0 | 1 => 0,
                2 => 1,
                _ => 2,
            })
            .sum()
    }

    /// The slot nearest the mesh center — used to place the top-level
    /// scheduler so its average distance to everyone is minimal.
    pub fn center_slot(&self) -> usize {
        let (dx, dy, dz) = self.dims;
        let target = Coord { x: dx / 2, y: dy / 2, z: dz / 2 };
        let mut best = 0;
        let mut best_d = u32::MAX;
        for (i, c) in self.coords.iter().enumerate() {
            let d = c.x.abs_diff(target.x) as u32
                + c.y.abs_diff(target.y) as u32
                + c.z.abs_diff(target.z) as u32;
            if d < best_d {
                best_d = d;
                best = i;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mesh_512_is_8x8x8() {
        let t = Topology::new(512);
        assert_eq!(t.dims(), (8, 8, 8));
        assert_eq!(t.n_cores(), 512);
        assert_eq!(t.max_hops(), 21);
    }

    #[test]
    fn mesh_520_fits() {
        let t = Topology::new(520);
        assert_eq!(t.n_cores(), 520);
        let (dx, dy, dz) = t.dims();
        assert!(dx as usize * dy as usize * dz as usize >= 520);
    }

    #[test]
    fn hops_symmetric_and_zero_on_self() {
        let t = Topology::new(64);
        let a = CoreId(0);
        let b = CoreId(63);
        assert_eq!(t.hops(a, a), 0);
        assert_eq!(t.hops(a, b), t.hops(b, a));
        assert!(t.hops(a, b) <= t.max_hops());
    }

    #[test]
    fn adjacent_slots_are_one_hop() {
        let t = Topology::new(512);
        assert_eq!(t.hops(CoreId(0), CoreId(1)), 1);
        // Slot 8 wraps to the next row in an 8-wide mesh.
        assert_eq!(t.hops(CoreId(0), CoreId(8)), 1);
        // Slot 64 is the next z-plane.
        assert_eq!(t.hops(CoreId(0), CoreId(64)), 1);
    }

    #[test]
    fn triangle_inequality_samples() {
        let t = Topology::new(100);
        for (a, b, c) in [(0u32, 42, 99), (5, 50, 77), (1, 2, 3)] {
            let (a, b, c) = (CoreId(a), CoreId(b), CoreId(c));
            assert!(t.hops(a, c) <= t.hops(a, b) + t.hops(b, c));
        }
    }

    #[test]
    fn center_slot_is_central() {
        let t = Topology::new(512);
        let center = CoreId(t.center_slot() as u32);
        // Every core is within max_hops/2 + 2 of the center.
        for i in 0..512 {
            assert!(t.hops(center, CoreId(i)) <= t.max_hops() / 2 + 2);
        }
    }

    #[test]
    fn max_degree_by_shape() {
        assert_eq!(Topology::new(512).max_degree(), 6); // 8x8x8
        assert_eq!(Topology::new(1).max_degree(), 0);
        assert_eq!(Topology::new(2).max_degree(), 1); // 2x1x1
    }

    #[test]
    fn tiny_meshes() {
        let t = Topology::new(1);
        assert_eq!(t.n_cores(), 1);
        assert_eq!(t.max_hops(), 1); // clamped to avoid div-by-zero
        let t2 = Topology::new(2);
        assert_eq!(t2.hops(CoreId(0), CoreId(1)), 1);
    }
}

//! The scheduler/worker wire protocol.
//!
//! Cores exchange fixed-size (64-B) control messages strictly along the
//! scheduler/worker tree (paper IV-b). Messages that must reach a
//! non-adjacent core carry their final destination in the delivery event
//! (`Event::Msg::dst`) and are forwarded hop by hop — each intermediate
//! scheduler charges message-processing time, which is how the paper's
//! "requests are forwarded to parent or child schedulers" cost
//! materializes in the simulation. (Earlier versions wrapped forwarded
//! messages in a boxed `Msg::Route` envelope; the destination field moves
//! the payload hop to hop with no heap traffic.)
//!
//! Payloads that would not fit 64 bytes on real hardware (task descriptors,
//! pack range lists) model multi-message transfers: their `wire_msgs()`
//! count is charged as additional message-processing time and counted in
//! the traffic statistics.

use crate::ids::{CoreId, NodeId, ReqId, TaskId};
use crate::task::descriptor::{Access, TaskDesc};

/// A coalesced address range grouped by last producer — the output of the
/// packing operation (paper V-E).
#[derive(Clone, Copy, Debug)]
pub struct ProducerRange {
    /// Worker core that last produced this range (data lives in its DRAM).
    pub producer: CoreId,
    /// Base address in the global address space.
    pub addr: u64,
    pub bytes: u64,
}

/// Memory-management operation kinds, for cost accounting during replay.
/// The functional result is computed eagerly when the task body runs; the
/// message chain replays the *timing* of the worker -> scheduler(s) round
/// trip (see `api::ctx`).
#[derive(Clone, Copy, Debug)]
pub enum MemOpKind {
    Alloc,
    /// Bulk allocation of `n` objects (`sys_balloc`).
    Balloc { n: u32 },
    Ralloc,
    Free,
    /// Recursive region free touching `nodes` regions/objects.
    Rfree { nodes: u32 },
    Realloc,
}

#[derive(Clone, Debug)]
pub enum Msg {
    // ------------------------------------------------------ worker -> sched
    /// `sys_spawn`: synchronous RPC — the worker blocks until `SpawnAck`
    /// (rendezvous over the credit-flow buffers; this serialization is
    /// what produces the paper's 16.2 K / 37.4 K intrinsic spawn costs).
    SpawnReq { req: ReqId, origin: CoreId, parent: Option<TaskId>, desc: TaskDesc },
    /// Task finished executing on a worker; routed to the task's
    /// responsible scheduler.
    TaskDone { task: TaskId },
    /// Memory-API round trip; `owner` is the scheduler that owns the
    /// target region. Replies with `MemResp`.
    MemReq { req: ReqId, origin: CoreId, owner: CoreId, op: MemOpKind },
    /// `sys_wait`: suspend until the listed argument subtrees quiesce.
    WaitReq { task: TaskId, origin: CoreId, nodes: Vec<(NodeId, Access)> },
    /// Load report (ready-queue depth), sent on threshold change.
    LoadReport { from: CoreId, load: u64 },

    // ------------------------------------------------------ sched -> worker
    SpawnAck { req: ReqId },
    MemResp { req: ReqId },
    /// Dispatch a dependency-free, packed, placed task for execution.
    Dispatch { task: TaskId },
    WaitGranted { task: TaskId },

    // ------------------------------------------------------ sched <-> sched
    /// Delegate responsibility for a freshly spawned task one level down
    /// (paper V-E: "only when all its arguments are handled by this single
    /// child scheduler or its children"). Carries the spawn-rendezvous
    /// token so the final responsible scheduler can ack the spawner.
    Delegate { task: TaskId, req: ReqId, origin: CoreId },
    /// Continue the downward dependency traversal of `task`'s argument
    /// `arg` at node `cur`, owned by the receiving scheduler. `entered` is
    /// true when the step crosses a parent->child region link (the
    /// receiver bumps the race-avoidance parent counter); it is false when
    /// the traversal starts at the anchor.
    /// `settle` names the scheduler (+ request id) to notify once this
    /// argument's traversal stops (enqueued or granted); the spawn is
    /// acked only after *all* its arguments settle, which closes the
    /// enqueue-vs-completion race on the spawn side (the quiesce side is
    /// closed by the parent counters).
    DepDescend {
        task: TaskId,
        arg: usize,
        mode: Access,
        target: NodeId,
        cur: NodeId,
        entered: bool,
        settle: Option<(CoreId, ReqId)>,
    },
    /// One argument traversal of the spawn identified by `req` stopped.
    DepSettled { req: ReqId },
    /// Argument `arg` of `task` reached the head of its target queue.
    DepGranted { task: TaskId, arg: usize },
    /// Pop `task`'s (granted) entry for argument `arg` from `node` at task
    /// completion.
    PopEntry { node: NodeId, task: TaskId, arg: usize },
    /// Register a `sys_wait` waiter on `node`.
    RegisterWait { task: TaskId, node: NodeId, mode: Access },
    /// `node`'s subtree drained for the waiting `task`.
    WaitNodeOk { task: TaskId, node: NodeId },
    /// Part of `child`'s subtree activity drained. `pr`/`pw` carry the
    /// cumulative read/write enqueues the child observed from this parent
    /// link for each mode that is quiescent (`None` = still active) — the
    /// race-avoidance "parent counters" of paper V-D, split per access
    /// mode so read-only holders don't pin write counters.
    QuiesceUp { child: NodeId, parent: NodeId, pr: Option<u64>, pw: Option<u64> },
    /// Ask `node`'s owner to pack its local portion and recurse.
    PackReq { req: ReqId, node: NodeId, reply_to: CoreId },
    PackResp { req: ReqId, ranges: Vec<ProducerRange> },
    /// Hierarchical placement descent: the receiving scheduler picks one
    /// of its children subtrees (or a worker, at leaf level) for `task`.
    /// `epoch` is the task's placement generation (see
    /// `task::table::TaskEntry::epoch`): crash recovery bumps it when it
    /// re-issues an orphaned task, so a late duplicate `ScheduleDown`
    /// that surfaces from a dead scheduler's drained mailbox is dropped
    /// by the epoch dedup rule instead of double-placing the task.
    ScheduleDown { task: TaskId, epoch: u32 },
    /// Inform `node`'s owner that `worker` is now the last producer.
    ProducerUpdate { node: NodeId, worker: CoreId },
    /// Idle-driven rebalance (parent -> child): request up to `batch`
    /// queued-ready tasks from the child's [`ReadyQ`] for migration
    /// towards an idle sibling subtree. Sent only when stealing is
    /// enabled (`StealCfg`); at most one is in flight per scheduler.
    ///
    /// [`ReadyQ`]: crate::sched::readyq::ReadyQ
    StealReq { batch: u32 },
    /// Rebalance grant (child -> parent): the migrated task ids, popped
    /// from the back of the victim's ready queue. Wire cost scales with
    /// the batch (descriptors re-marshal onto the NoC).
    StealGrant { tasks: Vec<TaskId> },
    /// Rebalance refusal (child -> parent): the victim's ready queue was
    /// empty — its load is already committed to workers/subtrees.
    StealDeny,

    // ----------------------------------------------- crash & recovery
    /// Heartbeat probe (parent -> scheduler child). Only exists when
    /// `RecoveryCfg::enabled`; a child that misses the pong window is
    /// declared dead and its subtree re-adopted.
    Ping,
    /// Heartbeat reply (child -> parent).
    Pong,
    /// Re-point a worker's uplink at `leaf` (re-adoption hands the
    /// workers of a dead leaf scheduler to its parent; re-integration
    /// hands them back to the restarted leaf).
    Adopt { leaf: CoreId },
    /// A restarted scheduler announces itself to its parent (carries its
    /// own core id because the message may be processed after further
    /// topology churn). The parent clears the dead mark and routing
    /// redirect; the child's follow-up full `LoadReport` rebuilds books.
    Rejoin { from: CoreId },

    // ------------------------------------------------------ mini-MPI
    /// Point-to-point MPI message (baseline runtime). `bytes` is payload;
    /// matching is by (src, tag) on the receiver.
    MpiSend { src: CoreId, tag: u64, bytes: u64 },
}

impl Msg {
    /// How many 64-B wire messages this logical message occupies. Variable
    /// payloads (descriptors, pack lists) cost proportionally more.
    pub fn wire_msgs(&self) -> u64 {
        match self {
            Msg::SpawnReq { desc, .. } => 1 + desc.args.len() as u64 / 4,
            Msg::PackResp { ranges, .. } => 1 + ranges.len() as u64 / 4,
            Msg::WaitReq { nodes, .. } => 1 + nodes.len() as u64 / 8,
            // 8 task ids per 64-B frame.
            Msg::StealGrant { tasks } => 1 + tasks.len() as u64 / 8,
            // MPI payloads move over DMA; the message is the header.
            _ => 1,
        }
    }

    /// Short tag for tracing/debugging.
    pub fn tag(&self) -> &'static str {
        match self {
            Msg::SpawnReq { .. } => "SpawnReq",
            Msg::TaskDone { .. } => "TaskDone",
            Msg::MemReq { .. } => "MemReq",
            Msg::WaitReq { .. } => "WaitReq",
            Msg::LoadReport { .. } => "LoadReport",
            Msg::SpawnAck { .. } => "SpawnAck",
            Msg::MemResp { .. } => "MemResp",
            Msg::Dispatch { .. } => "Dispatch",
            Msg::WaitGranted { .. } => "WaitGranted",
            Msg::Delegate { .. } => "Delegate",
            Msg::DepDescend { .. } => "DepDescend",
            Msg::DepSettled { .. } => "DepSettled",
            Msg::DepGranted { .. } => "DepGranted",
            Msg::PopEntry { .. } => "PopEntry",
            Msg::RegisterWait { .. } => "RegisterWait",
            Msg::WaitNodeOk { .. } => "WaitNodeOk",
            Msg::QuiesceUp { .. } => "QuiesceUp",
            Msg::PackReq { .. } => "PackReq",
            Msg::PackResp { .. } => "PackResp",
            Msg::ScheduleDown { .. } => "ScheduleDown",
            Msg::ProducerUpdate { .. } => "ProducerUpdate",
            Msg::StealReq { .. } => "StealReq",
            Msg::StealGrant { .. } => "StealGrant",
            Msg::StealDeny => "StealDeny",
            Msg::Ping => "Ping",
            Msg::Pong => "Pong",
            Msg::Adopt { .. } => "Adopt",
            Msg::Rejoin { .. } => "Rejoin",
            Msg::MpiSend { .. } => "MpiSend",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ids::ObjectId;
    use crate::task::descriptor::TaskArg;

    #[test]
    fn wire_msgs_scale_with_payload() {
        let small = Msg::SpawnReq {
            req: ReqId(0),
            origin: CoreId(5),
            parent: None,
            desc: TaskDesc::new(0, vec![TaskArg::obj_in(ObjectId(1))]),
        };
        assert_eq!(small.wire_msgs(), 1);
        let big = Msg::SpawnReq {
            req: ReqId(0),
            origin: CoreId(5),
            parent: None,
            desc: TaskDesc::new(0, (0..16).map(|i| TaskArg::obj_in(ObjectId(i))).collect()),
        };
        assert_eq!(big.wire_msgs(), 5);
    }

    #[test]
    fn pack_resp_wire_cost_scales() {
        let resp = Msg::PackResp {
            req: ReqId(1),
            ranges: (0..8)
                .map(|i| ProducerRange { producer: CoreId(0), addr: i * 64, bytes: 64 })
                .collect(),
        };
        // 8 ranges over 64-B frames: header + 2 continuation messages.
        assert_eq!(resp.wire_msgs(), 3);
        assert_eq!(resp.tag(), "PackResp");
    }

    #[test]
    fn recovery_messages_are_single_frame() {
        assert_eq!(Msg::Ping.wire_msgs(), 1);
        assert_eq!(Msg::Ping.tag(), "Ping");
        assert_eq!(Msg::Pong.wire_msgs(), 1);
        assert_eq!(Msg::Pong.tag(), "Pong");
        assert_eq!(Msg::Adopt { leaf: CoreId(3) }.wire_msgs(), 1);
        assert_eq!(Msg::Adopt { leaf: CoreId(3) }.tag(), "Adopt");
        assert_eq!(Msg::Rejoin { from: CoreId(1) }.wire_msgs(), 1);
        assert_eq!(Msg::Rejoin { from: CoreId(1) }.tag(), "Rejoin");
        assert_eq!(Msg::ScheduleDown { task: TaskId(1), epoch: 0 }.wire_msgs(), 1);
    }

    #[test]
    fn steal_messages_wire_cost_and_tags() {
        assert_eq!(Msg::StealReq { batch: 4 }.wire_msgs(), 1);
        assert_eq!(Msg::StealReq { batch: 4 }.tag(), "StealReq");
        assert_eq!(Msg::StealDeny.wire_msgs(), 1);
        assert_eq!(Msg::StealDeny.tag(), "StealDeny");
        let small = Msg::StealGrant { tasks: (0..4).map(TaskId).collect() };
        assert_eq!(small.wire_msgs(), 1);
        assert_eq!(small.tag(), "StealGrant");
        // 16 ids over 64-B frames: header + 2 continuation messages.
        let big = Msg::StealGrant { tasks: (0..16).map(TaskId).collect() };
        assert_eq!(big.wire_msgs(), 3);
    }
}

//! Per-peer message buffers with credit flow.
//!
//! The prototype's NoC layer assigns "a number of per-peer software
//! buffers, where a peer can push messages using one-way hardware DMA
//! primitives ... and a credit-flow system for the software buffers, so no
//! overflow can occur under system load" (paper V-B).
//!
//! We model each directed (sender, receiver) pair as a [`Channel`] with a
//! fixed credit capacity. A send consumes a credit; the credit returns when
//! the receiver *processes* (not merely receives) the message. Sends issued
//! without credits queue at the sender and are delivered in FIFO order as
//! credits free up — this is the backpressure that slows workers down when
//! their scheduler saturates (paper Fig 9/12).

use std::collections::VecDeque;

use crate::ids::{CoreId, Cycles};
use crate::noc::msg::Msg;

/// One directed sender->receiver message channel.
#[derive(Debug, Default)]
pub struct Channel {
    /// Messages currently occupying receiver buffer slots (sent but not
    /// yet processed).
    pub in_flight: usize,
    /// Sends blocked waiting for a credit: (enqueue time, final
    /// destination, message). The destination rides along so tree-routed
    /// messages resume forwarding when the credit frees up.
    pub blocked: VecDeque<(Cycles, CoreId, Msg)>,
}

impl Channel {
    /// Try to consume a credit. Returns true if the send may proceed.
    pub fn try_acquire(&mut self, capacity: usize) -> bool {
        if self.in_flight < capacity {
            self.in_flight += 1;
            true
        } else {
            false
        }
    }

    /// Return a credit after the receiver processed a message. If a
    /// blocked send is waiting, it immediately claims the credit and is
    /// returned for delivery.
    pub fn release(&mut self) -> Option<(Cycles, CoreId, Msg)> {
        debug_assert!(self.in_flight > 0, "credit release without in-flight message");
        self.in_flight = self.in_flight.saturating_sub(1);
        if let Some(queued) = self.blocked.pop_front() {
            self.in_flight += 1;
            Some(queued)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg() -> Msg {
        Msg::SpawnAck { req: crate::ids::ReqId(0) }
    }

    #[test]
    fn credits_respect_capacity() {
        let mut ch = Channel::default();
        assert!(ch.try_acquire(2));
        assert!(ch.try_acquire(2));
        assert!(!ch.try_acquire(2));
        assert_eq!(ch.in_flight, 2);
    }

    #[test]
    fn release_unblocks_fifo() {
        let mut ch = Channel::default();
        assert!(ch.try_acquire(1));
        assert!(!ch.try_acquire(1));
        ch.blocked.push_back((10, CoreId(1), msg()));
        ch.blocked.push_back((20, CoreId(1), msg()));
        let (t, _, _) = ch.release().expect("first blocked send should be released");
        assert_eq!(t, 10);
        // Credit was immediately re-consumed by the blocked send.
        assert_eq!(ch.in_flight, 1);
        let (t2, _, _) = ch.release().expect("second blocked send");
        assert_eq!(t2, 20);
        assert!(ch.release().is_none());
        assert_eq!(ch.in_flight, 0);
    }
}

//! Per-peer message buffers with credit flow.
//!
//! The prototype's NoC layer assigns "a number of per-peer software
//! buffers, where a peer can push messages using one-way hardware DMA
//! primitives ... and a credit-flow system for the software buffers, so no
//! overflow can occur under system load" (paper V-B).
//!
//! We model each directed (sender, receiver) pair as a [`Channel`] with a
//! fixed credit capacity. A send consumes a credit; the credit returns when
//! the receiver *processes* (not merely receives) the message. Sends issued
//! without credits queue at the sender and are delivered in FIFO order as
//! credits free up — this is the backpressure that slows workers down when
//! their scheduler saturates (paper Fig 9/12).

use std::collections::VecDeque;

use crate::ids::{CoreId, Cycles};
use crate::noc::msg::Msg;
use crate::noc::topology::Topology;

/// One directed sender->receiver message channel.
#[derive(Debug, Default)]
pub struct Channel {
    /// Messages currently occupying receiver buffer slots (sent but not
    /// yet processed).
    pub in_flight: usize,
    /// Sends blocked waiting for a credit: (enqueue time, final
    /// destination, message, chaos delay extra). The destination rides
    /// along so tree-routed messages resume forwarding when the credit
    /// frees up; the delay extra is the fault-injection jitter/class
    /// delay drawn *at send time* (uniformly for delivered and parked
    /// sends, so chaos draw order never depends on credit state or on
    /// which thread performs the unpark) and applied on delivery.
    pub blocked: VecDeque<(Cycles, CoreId, Msg, Cycles)>,
    /// Debug-build audit: how often `release` found no in-flight credit.
    /// Legal only on links marked [`Channel::allow_uncredited`]; anywhere
    /// else an idle release is a double credit return being masked.
    #[cfg(debug_assertions)]
    idle_releases: u64,
    /// Uncredited pushes (boot bootstrap) are expected on this link.
    #[cfg(debug_assertions)]
    uncredited_ok: bool,
}

impl Channel {
    /// Mark this link as legitimately carrying uncredited direct pushes
    /// (the platform-boot Dispatch). Debug builds then count idle
    /// releases instead of flagging them as double credit returns.
    /// No-op in release builds.
    pub fn allow_uncredited(&mut self) {
        #[cfg(debug_assertions)]
        {
            self.uncredited_ok = true;
        }
    }

    /// How many idle releases this channel absorbed (debug builds only).
    #[cfg(debug_assertions)]
    pub fn idle_releases(&self) -> u64 {
        self.idle_releases
    }

    /// Try to consume a credit. Returns true if the send may proceed.
    pub fn try_acquire(&mut self, capacity: usize) -> bool {
        if self.in_flight < capacity {
            self.in_flight += 1;
            true
        } else {
            false
        }
    }

    /// Return a credit after the receiver processed a message. If a
    /// blocked send is waiting, it immediately claims the credit and is
    /// returned for delivery.
    ///
    /// A release with no in-flight message is a no-op in release builds:
    /// a few paths (platform boot, mini-MPI data delivery) inject
    /// `Event::Msg` directly without consuming a credit. Debug builds
    /// audit the path: the link must have been marked
    /// [`Channel::allow_uncredited`], otherwise the idle release is a
    /// double credit return that the no-op would silently mask.
    pub fn release(&mut self) -> Option<(Cycles, CoreId, Msg, Cycles)> {
        if self.in_flight == 0 {
            debug_assert!(self.blocked.is_empty(), "blocked sends on an idle channel");
            #[cfg(debug_assertions)]
            {
                self.idle_releases += 1;
                debug_assert!(
                    self.uncredited_ok,
                    "idle release on a credited link: double credit return"
                );
            }
            return None;
        }
        self.in_flight -= 1;
        if let Some(queued) = self.blocked.pop_front() {
            self.in_flight += 1;
            Some(queued)
        } else {
            None
        }
    }
}

/// "No channel" sentinel in [`ChannelTables::index`].
const NO_CHANNEL: u32 = u32::MAX;

/// All directed channels of the platform — the replacement for the old
/// global `FxHashMap<(u32, u32), Channel>`, which put a hash + probe on
/// every message send *and* every receive.
///
/// Layout: a flat `n x n` index of `u32` slot numbers into one pooled
/// `Vec<Channel>`, so both the send path (`entry`) and the credit-return
/// path (`get_mut`) are a single multiply-add and one load — strictly
/// O(1) even for the flat-512 configuration where one scheduler core
/// exchanges messages with every worker (a per-sender peer *list* would
/// make that bottleneck core scan hundreds of entries per message).
/// Channels themselves are allocated on first use, densely, in
/// first-touch order — `Platform::build` pre-seeds the scheduler-tree
/// links so the hot edges sit contiguously at the front of the pool.
///
/// The index costs 4 bytes per core pair (~1 MB for the 520-core
/// prototype platform). If core counts ever grow past a few thousand,
/// revisit with a per-sender dense sub-index allocated on first send.
#[derive(Debug, Default)]
pub struct ChannelTables {
    n: usize,
    index: Vec<u32>,
    chans: Vec<Channel>,
}

impl ChannelTables {
    /// Table for `n_cores` senders. `degree_hint` (typically
    /// [`Topology::max_degree`] plus tree-link headroom) pre-sizes the
    /// channel pool so steady state never reallocates.
    pub fn new(n_cores: usize, degree_hint: usize) -> Self {
        ChannelTables {
            n: n_cores,
            index: vec![NO_CHANNEL; n_cores * n_cores],
            chans: Vec::with_capacity(n_cores.saturating_mul(degree_hint).min(1 << 16)),
        }
    }

    /// The `src -> dst` channel, created empty on first use.
    pub fn entry(&mut self, src: CoreId, dst: CoreId) -> &mut Channel {
        let key = src.idx() * self.n + dst.idx();
        let mut i = self.index[key];
        if i == NO_CHANNEL {
            i = self.chans.len() as u32;
            assert!(i < NO_CHANNEL, "channel pool overflow");
            self.index[key] = i;
            self.chans.push(Channel::default());
        }
        &mut self.chans[i as usize]
    }

    /// The `src -> dst` channel if it exists (release path: never creates).
    pub fn get_mut(&mut self, src: CoreId, dst: CoreId) -> Option<&mut Channel> {
        let i = self.index[src.idx() * self.n + dst.idx()];
        if i == NO_CHANNEL {
            None
        } else {
            Some(&mut self.chans[i as usize])
        }
    }

    /// Materialize the `src -> dst` channel up front so a known-hot link
    /// (a scheduler tree edge) gets a slot near the front of the pool,
    /// keeping the hot working set contiguous.
    pub fn preseed(&mut self, src: CoreId, dst: CoreId) {
        let _ = self.entry(src, dst);
    }

    /// Channel-pool sizing hint for a platform on `topo`: mesh degree
    /// plus headroom for the tree links (parent + children/workers beyond
    /// the mesh neighbors).
    pub fn degree_hint(topo: &Topology) -> usize {
        topo.max_degree() + 2
    }

    /// Pool-sizing hint for one of `n_shards` per-shard tables: each
    /// shard owns roughly `1/n_shards` of the links (cross-shard channels
    /// go to the lower endpoint's table), so scale the global hint down
    /// while keeping the tree-link headroom so a skewed partition never
    /// reallocates on the hot path.
    pub fn degree_hint_sharded(topo: &Topology, n_shards: usize) -> usize {
        topo.max_degree() / n_shards.max(1) + 2
    }

    /// All materialized channels (invariant oracles: at quiescence every
    /// credit must be restored and no send may remain parked).
    pub fn iter(&self) -> impl Iterator<Item = &Channel> {
        self.chans.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg() -> Msg {
        Msg::SpawnAck { req: crate::ids::ReqId(0) }
    }

    #[test]
    fn credits_respect_capacity() {
        let mut ch = Channel::default();
        assert!(ch.try_acquire(2));
        assert!(ch.try_acquire(2));
        assert!(!ch.try_acquire(2));
        assert_eq!(ch.in_flight, 2);
    }

    #[test]
    fn idle_release_is_noop() {
        let mut ch = Channel::default();
        // Links that receive uncredited direct pushes (platform boot) are
        // marked; an idle release there is the legal no-op path.
        ch.allow_uncredited();
        assert!(ch.release().is_none());
        assert_eq!(ch.in_flight, 0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "double credit return")]
    fn double_release_is_caught_in_debug() {
        let mut ch = Channel::default();
        assert!(ch.try_acquire(1));
        assert!(ch.release().is_none());
        // One release too many on a credited link: must not be masked.
        let _ = ch.release();
    }

    #[test]
    #[cfg(debug_assertions)]
    fn idle_releases_are_counted_on_uncredited_links() {
        let mut ch = Channel::default();
        ch.allow_uncredited();
        assert!(ch.release().is_none());
        assert!(ch.release().is_none());
        assert_eq!(ch.idle_releases(), 2);
        // A properly credited release is not an idle release.
        assert!(ch.try_acquire(1));
        assert!(ch.release().is_none());
        assert_eq!(ch.idle_releases(), 2);
    }

    #[test]
    fn sharded_degree_hint_scales_down_but_keeps_headroom() {
        let topo = Topology::new(64);
        let full = ChannelTables::degree_hint(&topo);
        let quarter = ChannelTables::degree_hint_sharded(&topo, 4);
        assert!(quarter <= full);
        assert!(quarter >= 2, "tree-link headroom survives any shard count");
        // Degenerate inputs must not divide by zero or underflow.
        assert_eq!(ChannelTables::degree_hint_sharded(&topo, 1), full);
        let _ = ChannelTables::degree_hint_sharded(&topo, 1000);
    }

    #[test]
    fn tables_isolate_directed_pairs() {
        let mut t = ChannelTables::new(4, 2);
        assert!(t.entry(CoreId(0), CoreId(1)).try_acquire(1));
        // Reverse direction is a distinct channel with its own credits.
        assert!(t.entry(CoreId(1), CoreId(0)).try_acquire(1));
        // Same direction again: out of credits.
        assert!(!t.entry(CoreId(0), CoreId(1)).try_acquire(1));
        // Release path never creates channels.
        assert!(t.get_mut(CoreId(2), CoreId(3)).is_none());
        assert!(t.get_mut(CoreId(0), CoreId(1)).is_some());
    }

    #[test]
    fn preseed_materializes_link_without_credits() {
        let mut t = ChannelTables::new(2, 4);
        t.preseed(CoreId(0), CoreId(1));
        let ch = t.get_mut(CoreId(0), CoreId(1)).expect("preseeded");
        assert_eq!(ch.in_flight, 0);
        // A release on the pre-seeded, never-used link is a no-op — but
        // only uncredited-marked links may absorb it (see
        // `double_release_is_caught_in_debug`).
        ch.allow_uncredited();
        assert!(ch.release().is_none());
        assert_eq!(t.iter().count(), 1);
    }

    #[test]
    fn high_degree_sender_stays_o1() {
        // Flat-512 shape: one scheduler talking to hundreds of workers.
        let mut t = ChannelTables::new(513, 8);
        for w in 1..513u32 {
            assert!(t.entry(CoreId(0), CoreId(w)).try_acquire(8));
        }
        for w in 1..513u32 {
            let ch = t.get_mut(CoreId(0), CoreId(w)).expect("created above");
            assert_eq!(ch.in_flight, 1);
            assert!(ch.release().is_none());
        }
    }

    #[test]
    fn release_unblocks_fifo() {
        let mut ch = Channel::default();
        assert!(ch.try_acquire(1));
        assert!(!ch.try_acquire(1));
        ch.blocked.push_back((10, CoreId(1), msg(), 0));
        ch.blocked.push_back((20, CoreId(1), msg(), 3));
        let (t, _, _, d) = ch.release().expect("first blocked send should be released");
        assert_eq!(t, 10);
        assert_eq!(d, 0);
        // Credit was immediately re-consumed by the blocked send.
        assert_eq!(ch.in_flight, 1);
        let (t2, _, _, d2) = ch.release().expect("second blocked send");
        assert_eq!(t2, 20);
        assert_eq!(d2, 3);
        assert!(ch.release().is_none());
        assert_eq!(ch.in_flight, 0);
    }
}

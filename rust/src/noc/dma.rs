//! DMA transfer groups.
//!
//! Workers order grouped DMA transfers for all remote task arguments; the
//! NoC layer notifies the upper layer when the whole group completes
//! (paper V-B). Transfers from distinct source cores stream in parallel
//! (each source has its own hardware DMA engine); transfers from the same
//! source serialize on that engine.

use std::collections::BTreeMap;

use crate::config::CostModel;
use crate::ids::{CoreId, Cycles};

/// One transfer of a DMA group.
#[derive(Clone, Copy, Debug)]
pub struct Transfer {
    pub src: CoreId,
    pub dst: CoreId,
    pub bytes: u64,
    /// Mesh hop distance between src and dst (precomputed by the caller).
    pub hops: u32,
}

/// Completion time (relative to issue) of a group of transfers:
/// per-source engines serialize their own transfers and run in parallel
/// with other sources; the group completes when the slowest engine drains.
pub fn group_completion(cost: &CostModel, transfers: &[Transfer]) -> Cycles {
    let mut per_src: BTreeMap<CoreId, Cycles> = BTreeMap::new();
    for t in transfers {
        *per_src.entry(t.src).or_insert(0) += cost.dma_time(t.bytes, t.hops);
    }
    per_src.values().copied().max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm() -> CostModel {
        CostModel::default()
    }

    #[test]
    fn empty_group_completes_instantly() {
        assert_eq!(group_completion(&cm(), &[]), 0);
    }

    #[test]
    fn parallel_sources_take_max() {
        let c = cm();
        let a = Transfer { src: CoreId(0), dst: CoreId(2), bytes: 4096, hops: 1 };
        let b = Transfer { src: CoreId(1), dst: CoreId(2), bytes: 1024, hops: 1 };
        let t = group_completion(&c, &[a, b]);
        assert_eq!(t, c.dma_time(4096, 1));
    }

    #[test]
    fn same_source_serializes() {
        let c = cm();
        let a = Transfer { src: CoreId(0), dst: CoreId(2), bytes: 4096, hops: 1 };
        let t = group_completion(&c, &[a, a]);
        assert_eq!(t, 2 * c.dma_time(4096, 1));
    }
}

//! Network-on-chip model: topology, messages, credit flow, DMA.
pub mod channel;
pub mod dma;
pub mod msg;
pub mod topology;

//! Myrmics: scalable, dependency-aware task scheduling on heterogeneous
//! manycores — a full-system reproduction.
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for the
//! paper-vs-measured results. Top-level layout:
//!
//! * [`sim`], [`noc`] — the discrete-event simulator of the 520-core
//!   prototype platform (mesh, messages, credits, DMA).
//! * [`memory`], [`dep`], [`sched`], [`task`], [`api`] — the Myrmics
//!   runtime itself (regions, slab allocation, dependency analysis,
//!   hierarchical scheduling, the Fig-4 API).
//! * [`mpi`] — the hand-tuned message-passing baseline on the same NoC.
//! * [`apps`] — the paper's six benchmarks for both runtimes plus the
//!   synthetic microbenchmarks.
//! * [`runtime`] — the PJRT bridge executing AOT-compiled JAX/Pallas
//!   kernels (real compute mode).
//! * [`experiments`] — one harness per paper figure/table.

pub mod api;
pub mod apps;
pub mod arena;
pub mod config;
pub mod dep;
pub mod experiments;
pub mod fxmap;
pub mod ids;
pub mod memory;
pub mod mpi;
pub mod noc;
pub mod platform;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod stats;
pub mod task;
pub mod testutil;

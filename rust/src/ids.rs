//! Core identifier newtypes shared across all subsystems.
//!
//! Everything in the simulated platform is addressed by small integer ids:
//! cores (schedulers + workers), tasks, regions, objects and dependency
//! nodes. Newtypes keep them from being mixed up and make the message
//! protocol self-documenting.

use std::fmt;

/// Virtual time, measured in MicroBlaze clock cycles (the slow cores of the
/// paper's 520-core prototype). ARM Cortex-A9 cores charge
/// `cycles / arm_speedup` for the same work (Fig 7a: 7-8x difference).
pub type Cycles = u64;

/// A physical core in the simulated platform (0-based, schedulers and
/// workers share the same namespace).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct CoreId(pub u32);

impl CoreId {
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl fmt::Display for CoreId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "c{}", self.0)
    }
}

/// A task instance. Ids are handed out by the platform in spawn order,
/// which makes task-related logs and tie-breaking deterministic.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct TaskId(pub u64);

impl fmt::Display for TaskId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "t{}", self.0)
    }
}

/// A memory region (`rid_t` in the paper's API, Fig 4). Region 0 is the
/// default top-level root region.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct RegionId(pub u64);

impl RegionId {
    pub const ROOT: RegionId = RegionId(0);
}

impl fmt::Display for RegionId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "r{}", self.0)
    }
}

/// A heap object allocated by `sys_alloc`. The id doubles as the key into
/// the backing store; its *address* in the global address space is separate
/// (see `memory::addr`).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct ObjectId(pub u64);

impl fmt::Display for ObjectId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "o{}", self.0)
    }
}

/// A node in the dependency forest: either a region or an object.
/// Dependency queues, child counters and last-producer metadata hang off
/// these (paper 5a/5b).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub enum NodeId {
    Region(RegionId),
    Object(ObjectId),
}

impl NodeId {
    pub fn as_region(self) -> Option<RegionId> {
        match self {
            NodeId::Region(r) => Some(r),
            NodeId::Object(_) => None,
        }
    }

    pub fn as_object(self) -> Option<ObjectId> {
        match self {
            NodeId::Object(o) => Some(o),
            NodeId::Region(_) => None,
        }
    }

    pub fn is_region(self) -> bool {
        matches!(self, NodeId::Region(_))
    }
}

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NodeId::Region(r) => write!(f, "{r}"),
            NodeId::Object(o) => write!(f, "{o}"),
        }
    }
}

impl From<RegionId> for NodeId {
    fn from(r: RegionId) -> Self {
        NodeId::Region(r)
    }
}

impl From<ObjectId> for NodeId {
    fn from(o: ObjectId) -> Self {
        NodeId::Object(o)
    }
}

/// A traffic job: one instance of a workload template admitted into the
/// platform by the multi-tenant traffic layer (`sim::traffic`). Ids index
/// the arrival schedule densely, so per-job books are array lookups.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct JobId(pub u32);

impl JobId {
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

impl fmt::Display for JobId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "j{}", self.0)
    }
}

/// Request id used to match replies to reentrant pending operations inside
/// a scheduler (the paper's "reentrant events with saved local state").
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub struct ReqId(pub u64);

impl fmt::Display for ReqId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "q{}", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_id_conversions() {
        let r: NodeId = RegionId(3).into();
        let o: NodeId = ObjectId(7).into();
        assert!(r.is_region());
        assert!(!o.is_region());
        assert_eq!(r.as_region(), Some(RegionId(3)));
        assert_eq!(r.as_object(), None);
        assert_eq!(o.as_object(), Some(ObjectId(7)));
        assert_eq!(o.as_region(), None);
    }

    #[test]
    fn display_forms() {
        assert_eq!(CoreId(4).to_string(), "c4");
        assert_eq!(TaskId(9).to_string(), "t9");
        assert_eq!(NodeId::Region(RegionId(1)).to_string(), "r1");
        assert_eq!(NodeId::Object(ObjectId(2)).to_string(), "o2");
    }

    #[test]
    fn root_region_is_zero() {
        assert_eq!(RegionId::ROOT, RegionId(0));
    }
}

//! Fig 7: intrinsic overheads (a) and task-granularity impact (b).

use crate::apps::synthetic::{empty_chain, independent, SynthParams};
use crate::config::PlatformConfig;
use crate::ids::{Cycles, TaskId};
use crate::platform::Platform;

/// One Fig 7a bar group: per-task spawn and execute cost.
#[derive(Clone, Debug)]
pub struct OverheadRow {
    pub mode: &'static str,
    pub spawn_cycles: f64,
    pub exec_cycles: f64,
}

/// Fig 7a: 1,000 empty tasks on one object, 1 scheduler + 1 worker, in
/// the three core-flavour modes (MicroBlaze/MicroBlaze, A9/MicroBlaze,
/// A9/A9). Times in MicroBlaze cycles like the paper.
pub fn fig7a(n: usize) -> Vec<OverheadRow> {
    let run = |hetero: bool, fast_worker: bool| -> (f64, f64) {
        let (reg, main) = empty_chain();
        let mut cfg = PlatformConfig::flat(1);
        cfg.hetero = hetero;
        let mut plat = Platform::build_with(cfg, reg, main, |w| {
            w.app = Some(Box::new(SynthParams { n_tasks: n, ..Default::default() }));
        });
        if fast_worker {
            // ARM/ARM mode: the worker core is a Cortex-A9 too.
            for m in plat.eng.sim.metas.iter_mut() {
                m.kind = crate::config::CoreKind::CortexA9;
            }
        }
        let end = plat.run(Some(1 << 46));
        let main_e = plat.world().tasks.get(TaskId(0));
        let spawn = (main_e.done_at - main_e.started_at) as f64 / n as f64;
        let exec = (end - main_e.done_at) as f64 / n as f64;
        (spawn, exec)
    };
    let (s_mb, e_mb) = run(false, false);
    let (s_het, e_het) = run(true, false);
    let (s_arm, e_arm) = run(true, true);
    vec![
        OverheadRow { mode: "MB sched / MB worker", spawn_cycles: s_mb, exec_cycles: e_mb },
        OverheadRow { mode: "A9 sched / MB worker", spawn_cycles: s_het, exec_cycles: e_het },
        OverheadRow { mode: "A9 sched / A9 worker", spawn_cycles: s_arm, exec_cycles: e_arm },
    ]
}

/// One point of the Fig 7b surface.
#[derive(Clone, Debug)]
pub struct GranularityPoint {
    pub workers: usize,
    pub task_cycles: Cycles,
    pub speedup: f64,
}

/// Fig 7b (hetero scheduler) / Fig 12a (MicroBlaze scheduler): 512
/// independent tasks, single scheduler, sweep workers x task size.
pub fn granularity(
    n_tasks: usize,
    worker_counts: &[usize],
    task_sizes: &[Cycles],
    hetero: bool,
) -> Vec<GranularityPoint> {
    let mut base: Vec<(Cycles, Cycles)> = Vec::new(); // (size, t1)
    for &size in task_sizes {
        let t1 = run_once(n_tasks, 1, size, hetero);
        base.push((size, t1));
    }
    let mut out = Vec::new();
    for &w in worker_counts {
        for &(size, t1) in &base {
            let tw = run_once(n_tasks, w, size, hetero);
            out.push(GranularityPoint {
                workers: w,
                task_cycles: size,
                speedup: t1 as f64 / tw as f64,
            });
        }
    }
    out
}

fn run_once(n_tasks: usize, workers: usize, task_cycles: Cycles, hetero: bool) -> Cycles {
    let (reg, main) = independent();
    let mut cfg = PlatformConfig::flat(workers);
    cfg.hetero = hetero;
    let mut plat = Platform::build_with(cfg, reg, main, |w| {
        w.app = Some(Box::new(SynthParams { n_tasks, task_cycles, ..Default::default() }));
    });
    plat.run(Some(1 << 46))
}

/// Optimal worker count for a task size: the paper approximates it as
/// task size / intrinsic spawn overhead (e.g. 1 M / 16.2 K ~= 64).
pub fn optimal_workers(points: &[GranularityPoint], task_cycles: Cycles) -> usize {
    points
        .iter()
        .filter(|p| p.task_cycles == task_cycles)
        .max_by(|a, b| a.speedup.partial_cmp(&b.speedup).unwrap())
        .map(|p| p.workers)
        .unwrap_or(1)
}

pub fn print_fig7a(rows: &[OverheadRow]) {
    println!("Fig 7a — time to spawn / execute an empty task (MB cycles)");
    println!("{:<24} {:>12} {:>12}", "mode", "spawn", "execute");
    for r in rows {
        println!("{:<24} {:>12.0} {:>12.0}", r.mode, r.spawn_cycles, r.exec_cycles);
    }
    println!("paper: hetero 16.2K spawn / 13.3K exec; MB-only 37.4K spawn\n");
}

pub fn print_granularity(points: &[GranularityPoint], label: &str) {
    println!("{label} — speedup vs single worker (rows: task size)");
    let mut sizes: Vec<Cycles> = points.iter().map(|p| p.task_cycles).collect();
    sizes.sort_unstable();
    sizes.dedup();
    let mut workers: Vec<usize> = points.iter().map(|p| p.workers).collect();
    workers.sort_unstable();
    workers.dedup();
    print!("{:>10}", "task\\wrk");
    for w in &workers {
        print!("{w:>8}");
    }
    println!();
    for s in &sizes {
        print!("{:>10}", super::fmt_cycles(*s));
        for w in &workers {
            let p = points
                .iter()
                .find(|p| p.task_cycles == *s && p.workers == *w)
                .expect("grid point");
            print!("{:>8.1}", p.speedup);
        }
        let opt = optimal_workers(points, *s);
        println!("   (opt {opt})");
    }
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7a_reproduces_paper_overheads() {
        let rows = fig7a(300);
        assert!((rows[1].spawn_cycles - 16_200.0).abs() / 16_200.0 < 0.12);
        assert!((rows[1].exec_cycles - 13_300.0).abs() / 13_300.0 < 0.12);
        assert!((rows[0].spawn_cycles - 37_400.0).abs() / 37_400.0 < 0.12);
        // ARM/ARM is the cheapest mode.
        assert!(rows[2].spawn_cycles < rows[1].spawn_cycles);
        assert!(rows[2].exec_cycles < rows[1].exec_cycles);
    }

    #[test]
    fn granularity_has_an_optimum() {
        // Small grid: 64 tasks of 1M cycles; optimum should be well below
        // 64 workers but above 8 (paper: ~64 for 512 tasks at 1M).
        let pts = granularity(64, &[1, 8, 16, 32, 64], &[1_000_000], true);
        let opt = optimal_workers(&pts, 1_000_000);
        assert!(opt >= 8, "optimum {opt}");
        // Bigger tasks always speed up better at high worker counts.
        let pts2 = granularity(64, &[32], &[100_000, 4_000_000], true);
        assert!(pts2[1].speedup > pts2[0].speedup);
    }
}

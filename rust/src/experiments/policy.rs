//! Placement-policy sweep: every pluggable policy × locality weight over
//! fig7/fig8-shaped workloads, with a machine-readable JSON report.
//!
//! Where fig11 reproduces the paper's VI-D locality/balance trade-off on
//! the application benchmarks, this experiment exercises the *policy seam*
//! itself (`sched::policy`): the same synthetic workloads the hotpath
//! bench drives — `independent` (fig7b: one spawner fans out over a
//! hierarchy) and `hier_empty` (fig8/12b: nested regions over a deep
//! tree) — are run under every [`PolicyCfg`] variant, so a new policy
//! only needs a config constructor to show up in the comparison.
//!
//! Output: paper-style rows on stdout plus `POLICY_sweep.json`
//! (`[{workload, workers, policy, p_locality, time, balance_pct,
//! dma_bytes, msg_bytes, events, tasks}]`) so the policy trajectory is
//! machine-comparable across PRs. CI smoke-runs the emitter (1 policy ×
//! 1 tiny workload) so it cannot rot.

use crate::apps::synthetic::{hier_empty, independent, SynthParams};
use crate::config::{HierarchySpec, PlatformConfig, PolicyCfg};
use crate::ids::Cycles;
use crate::platform::Platform;

use super::summarize;

/// One (workload, policy) measurement.
#[derive(Clone, Debug)]
pub struct PolicyRow {
    pub workload: &'static str,
    pub workers: usize,
    /// Engine shards / executor threads the row ran under (picked up from
    /// `MYRMICS_SHARDS`/`MYRMICS_THREADS` or `--threads`; both 1 by
    /// default). Recorded so sweep JSON from a sharded or threaded run is
    /// never compared against a sequential baseline unawares.
    pub shards: usize,
    pub threads: usize,
    pub policy: &'static str,
    pub p_locality: u32,
    pub time: Cycles,
    pub balance_pct: f64,
    pub dma_bytes: u64,
    pub msg_bytes: u64,
    pub events: u64,
    pub tasks: u64,
}

/// Workload shapes the sweep runs (≥ 2 per the experiment contract).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Shape {
    /// fig7b: independent tasks fanned out over a two-level hierarchy —
    /// placement quality shows up as load balance.
    Fig7Independent,
    /// fig8/12b: nested regions over a deep (3-level) tree — placement
    /// interacts with delegation and tree routing.
    Fig8Deep,
}

impl Shape {
    pub fn name(&self) -> &'static str {
        match self {
            Shape::Fig7Independent => "fig7-independent",
            Shape::Fig8Deep => "fig8-deep",
        }
    }
}

/// Run one workload shape under one policy.
pub fn run_one(shape: Shape, workers: usize, tasks: usize, policy: PolicyCfg) -> PolicyRow {
    let (mut cfg, reg, main, params) = match shape {
        Shape::Fig7Independent => {
            let (reg, main) = independent();
            // Explicit two-level tree (not `hierarchical`, which
            // degenerates to flat under 32 workers): child-level placement
            // must be exercised at every sweep size.
            let leaves = 4.min(workers.max(2));
            (
                PlatformConfig::new(workers, HierarchySpec::two_level(leaves)),
                reg,
                main,
                SynthParams { n_tasks: tasks, task_cycles: 200_000, ..Default::default() },
            )
        }
        Shape::Fig8Deep => {
            let (reg, main) = hier_empty();
            let cfg = PlatformConfig::new(
                workers,
                HierarchySpec { scheds_per_level: vec![1, 2, 4] },
            );
            (
                cfg,
                reg,
                main,
                SynthParams {
                    domains: 4,
                    per_domain: tasks.div_ceil(4),
                    domain_level: 2,
                    task_cycles: 50_000,
                    ..Default::default()
                },
            )
        }
    };
    cfg.policy = policy;
    let shard = cfg.shard;
    let mut plat = Platform::build_with(cfg, reg, main, |w| {
        w.app = Some(Box::new(params));
    });
    let t = plat.run(Some(1 << 44));
    let s = summarize(&plat.eng, t);
    let g = &plat.eng.world.gstats;
    PolicyRow {
        workload: shape.name(),
        workers,
        shards: shard.shards.max(1),
        threads: shard.threads.max(1),
        policy: policy.name(),
        p_locality: policy.p_locality,
        time: t,
        balance_pct: s.balance,
        dma_bytes: s.total_dma_bytes,
        msg_bytes: g.msgs_total * plat.eng.sim.cost.msg_bytes,
        events: g.events_processed,
        tasks: g.tasks_completed,
    }
}

/// The policy set a full sweep compares: the paper blend at several
/// locality weights, plus the rotating and randomized baselines.
pub fn sweep_policies() -> Vec<PolicyCfg> {
    vec![
        PolicyCfg::locality_balance(0),
        PolicyCfg::locality_balance(10),
        PolicyCfg::locality_balance(30),
        PolicyCfg::locality_balance(100),
        PolicyCfg::round_robin(),
        PolicyCfg::power_of_two(),
    ]
}

/// Run the sweep. `quick` shrinks the workloads; `smoke` runs exactly one
/// policy on one tiny workload (CI: exercises the emitter in seconds).
pub fn run(quick: bool, smoke: bool) -> Vec<PolicyRow> {
    let mut rows = Vec::new();
    if smoke {
        rows.push(run_one(Shape::Fig7Independent, 8, 32, PolicyCfg::default()));
    } else {
        let (workers, tasks) = if quick { (16, 64) } else { (64, 512) };
        for shape in [Shape::Fig7Independent, Shape::Fig8Deep] {
            for policy in sweep_policies() {
                rows.push(run_one(shape, workers, tasks, policy));
            }
        }
    }
    print_rows(&rows);
    match emit_json(&rows, "POLICY_sweep.json") {
        Ok(()) => println!("wrote POLICY_sweep.json ({} rows)", rows.len()),
        Err(e) => eprintln!("failed to write POLICY_sweep.json: {e}"),
    }
    rows
}

pub fn print_rows(rows: &[PolicyRow]) {
    println!("Policy sweep — placement policies over fig7/fig8 workload shapes");
    println!(
        "{:<18} {:>4} {:<18} {:>6} {:>12} {:>9} {:>12} {:>8}",
        "workload", "w", "policy", "p_loc", "time", "balance%", "DMA bytes", "tasks"
    );
    for r in rows {
        // Only the blend policy is parameterized by the locality weight.
        let p = if r.policy == "locality-balance" { r.p_locality.to_string() } else { "-".into() };
        println!(
            "{:<18} {:>4} {:<18} {:>6} {:>12} {:>9.1} {:>12} {:>8}",
            r.workload, r.workers, r.policy, p, r.time, r.balance_pct, r.dma_bytes, r.tasks
        );
    }
    println!();
}

/// Serialize rows as a JSON array (no external deps — field values are
/// numbers and fixed identifier strings, so no escaping is needed).
pub fn to_json(rows: &[PolicyRow]) -> String {
    let objs: Vec<String> = rows
        .iter()
        .map(|r| {
            // Only the blend policy is parameterized by the locality
            // weight; for the others the field is inert — emit null so
            // consumers cannot mistake it for a real sweep coordinate.
            let p_loc = if r.policy == "locality-balance" {
                r.p_locality.to_string()
            } else {
                "null".to_string()
            };
            format!(
                "{{\"workload\": \"{}\", \"workers\": {}, \"shards\": {}, \
                 \"threads\": {}, \"policy\": \"{}\", \
                 \"p_locality\": {}, \"time\": {}, \"balance_pct\": {:.2}, \
                 \"dma_bytes\": {}, \"msg_bytes\": {}, \"events\": {}, \"tasks\": {}}}",
                r.workload,
                r.workers,
                r.shards,
                r.threads,
                r.policy,
                p_loc,
                r.time,
                r.balance_pct,
                r.dma_bytes,
                r.msg_bytes,
                r.events,
                r.tasks,
            )
        })
        .collect();
    super::json_array(&objs)
}

pub fn emit_json(rows: &[PolicyRow], path: &str) -> std::io::Result<()> {
    std::fs::write(path, to_json(rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_policy_completes_both_shapes() {
        for shape in [Shape::Fig7Independent, Shape::Fig8Deep] {
            for policy in sweep_policies() {
                let r = run_one(shape, 8, 16, policy);
                assert!(r.tasks > 0, "{}/{} completed no tasks", r.workload, r.policy);
                assert!(r.time > 0);
                assert!(r.events > 0);
            }
        }
    }

    #[test]
    fn round_robin_balances_independent_tasks() {
        // Equal-size independent tasks: strict rotation spreads them at
        // least as evenly as anything else on a tiny run.
        let rr = run_one(Shape::Fig7Independent, 8, 64, PolicyCfg::round_robin());
        assert!(rr.balance_pct > 50.0, "round-robin balance {:.1}%", rr.balance_pct);
    }

    #[test]
    fn p2c_replays_bit_identically() {
        let a = run_one(Shape::Fig7Independent, 8, 32, PolicyCfg::power_of_two());
        let b = run_one(Shape::Fig7Independent, 8, 32, PolicyCfg::power_of_two());
        assert_eq!(a.time, b.time, "randomized policy must be seed-deterministic");
        assert_eq!(a.events, b.events);
        assert_eq!(a.msg_bytes, b.msg_bytes);
    }

    #[test]
    fn json_shape_is_stable() {
        let rows = vec![run_one(Shape::Fig7Independent, 8, 8, PolicyCfg::default())];
        let j = to_json(&rows);
        assert!(j.starts_with("[\n"));
        assert!(j.trim_end().ends_with(']'));
        for key in [
            "\"workload\"",
            "\"shards\"",
            "\"threads\"",
            "\"policy\"",
            "\"p_locality\"",
            "\"time\"",
            "\"balance_pct\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        // Exactly one row, no trailing comma.
        assert_eq!(j.matches("{\"workload\"").count(), 1);
    }
}

//! Fig 12: MicroBlaze-scheduler granularity (a) and deeper scheduler
//! hierarchies (b), on the homogeneous 512-core system (paper VI-E).

use crate::apps::synthetic::{hier_empty, SynthParams};
use crate::config::{HierarchySpec, PlatformConfig};
use crate::ids::Cycles;
use crate::platform::Platform;

pub use super::fig7::{granularity, print_granularity, GranularityPoint};

/// Fig 12a: the Fig 7b sweep with a MicroBlaze scheduler (hetero=false) —
/// the intrinsic spawn cost rises to ~37.4 K cycles and the achievable
/// speedup drops.
pub fn fig12a(
    n_tasks: usize,
    worker_counts: &[usize],
    task_sizes: &[Cycles],
) -> Vec<GranularityPoint> {
    granularity(n_tasks, worker_counts, task_sizes, false)
}

#[derive(Clone, Debug)]
pub struct HierPoint {
    pub levels: usize,
    pub workers: usize,
    pub time: Cycles,
    /// Weak-scaling slowdown vs the same config's smallest run.
    pub slowdown: f64,
}

/// Fig 12b: empty-task hierarchy benchmark, weak scaling, scheduler
/// fanout 6, on the homogeneous (all-MicroBlaze) system. One domain
/// region per ~6 workers, `tasks_per_domain` empty tasks each.
pub fn fig12b(worker_counts: &[usize], levels_list: &[usize], tasks_per_domain: usize) -> Vec<HierPoint> {
    let mut out = Vec::new();
    for &levels in levels_list {
        let mut base: Option<f64> = None;
        for &w in worker_counts {
            let t = run_hier(w, levels, tasks_per_domain);
            // Weak scaling: work per worker is constant, so the slowdown
            // is the plain time ratio to the curve's first point.
            let b = *base.get_or_insert(t as f64);
            out.push(HierPoint { levels, workers: w, time: t, slowdown: t as f64 / b });
        }
    }
    out
}

fn spec_for(levels: usize, workers: usize) -> HierarchySpec {
    // Scheduler fanout 6 (paper VI-E): leaves = ceil(w/6); mid = ceil(l/6).
    match levels {
        1 => HierarchySpec::flat(),
        2 => {
            let leaves = workers.div_ceil(6).max(1);
            HierarchySpec { scheds_per_level: vec![1, leaves] }
        }
        3 => {
            let leaves = workers.div_ceil(6).max(1);
            let mids = leaves.div_ceil(6).max(1);
            HierarchySpec { scheds_per_level: vec![1, mids, leaves] }
        }
        _ => panic!("unsupported level count {levels}"),
    }
}

fn run_hier(workers: usize, levels: usize, tasks_per_domain: usize) -> Cycles {
    let (reg, main) = hier_empty();
    let mut cfg = PlatformConfig::new(workers, spec_for(levels, workers));
    cfg.hetero = false; // homogeneous 512-core MicroBlaze system
    let domains = workers.div_ceil(6).max(1);
    let levels_i = levels as i32;
    let mut plat = Platform::build_with(cfg, reg, main, move |w| {
        w.app = Some(Box::new(SynthParams {
            domains,
            per_domain: tasks_per_domain,
            domain_level: levels_i - 1,
            task_cycles: 0,
            ..Default::default()
        }));
    });
    plat.run(Some(1 << 46))
}

/// Weak-scaling slowdown normalized to each curve's first point: the
/// paper's Fig 12b Y axis.
pub fn normalized(points: &[HierPoint], worker_counts: &[usize]) -> Vec<(usize, Vec<f64>)> {
    let mut rows = Vec::new();
    let mut levels: Vec<usize> = points.iter().map(|p| p.levels).collect();
    levels.sort_unstable();
    levels.dedup();
    for l in levels {
        let curve: Vec<&HierPoint> = points.iter().filter(|p| p.levels == l).collect();
        let base = curve
            .iter()
            .find(|p| p.workers == worker_counts[0])
            .map(|p| p.time as f64)
            .unwrap_or(1.0);
        rows.push((l, curve.iter().map(|p| p.time as f64 / base).collect()));
    }
    rows
}

pub fn print_fig12b(points: &[HierPoint], worker_counts: &[usize]) {
    println!("Fig 12b — multi-level weak scaling (empty tasks, fanout 6, MB-only)");
    print!("{:<10}", "levels\\wrk");
    for w in worker_counts {
        print!("{w:>8}");
    }
    println!("   (slowdown normalized to first point)");
    for (l, row) in normalized(points, worker_counts) {
        print!("{l:<10}");
        for v in row {
            print!("{v:>8.2}");
        }
        println!();
    }
    println!("paper: 2-level >> 1-level; 3-level ~15% better than 2-level at scale\n");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hierarchy_beats_single_scheduler_at_scale() {
        let workers = [12, 72];
        let pts = fig12b(&workers, &[1, 2], 6);
        let rows = normalized(&pts, &workers);
        let one = &rows[0].1;
        let two = &rows[1].1;
        // At 72 workers, the single scheduler slows down much more.
        assert!(
            one[1] > two[1] * 1.2,
            "1-level {:.2} vs 2-level {:.2} at 72 workers",
            one[1],
            two[1]
        );
    }

    #[test]
    fn three_levels_work() {
        let pts = fig12b(&[36], &[3], 4);
        assert_eq!(pts.len(), 1);
        assert!(pts[0].time > 0);
    }

    #[test]
    fn three_levels_beat_two_at_scale() {
        // Paper VI-E: deeper hierarchies relieve the saturated top-level
        // scheduler once enough leaf schedulers exist.
        let workers = [12, 216];
        let pts = fig12b(&workers, &[2, 3], 8);
        let rows = normalized(&pts, &workers);
        let two = rows.iter().find(|r| r.0 == 2).unwrap().1[1];
        let three = rows.iter().find(|r| r.0 == 3).unwrap().1[1];
        assert!(three < two, "3-level {three:.2} should beat 2-level {two:.2}");
    }
}

//! Protocol fuzz/soak harness: randomized fault plans x adversarial
//! spawn patterns, every run checked against the quiescence oracles and
//! a double-run replay pin.
//!
//! Each case is fully determined by two integers:
//!
//! * `seed` — the run seed (`PlatformConfig::seed`) *and* the source of
//!   the case parameters (workload shape, hierarchy, steal config,
//!   strictness), drawn from a decorrelated RNG stream;
//! * `plan` — the fault-plan seed ([`FaultPlan::from_seed`]); `0` means
//!   no faults, so every 5th case doubles as a plain-engine regression.
//!
//! That makes every verdict reproducible from one line:
//! `myrmics exp fuzz --seed X --plan Y`. The harness runs each case
//! twice and compares full fingerprints (the `tests/steal_determinism.rs`
//! tuple), so a nondeterministic schedule is a failure even when every
//! oracle passes. On failure with faults enabled the case is re-run with
//! `plan = 0` as a one-step shrink: `clean_fails` in the report says
//! whether the bug needs the fault plan at all.
//!
//! Output: verdict rows on stdout plus `FUZZ_report.json` (per-case
//! verdicts, violations, reproducer lines). CI smoke-runs the harness on
//! every PR; the nightly workflow runs wide (`--seeds 200`) and soaks.

use std::time::Instant;

use crate::apps::jobs::traffic_boot;
use crate::apps::skew::{myrmics as skew_myrmics, SkewParams};
use crate::apps::synthetic::{empty_chain, hier_empty, independent, SynthParams};
use crate::apps::workload_api::job_templates;
use crate::config::{
    AdmissionKind, HierarchySpec, PlatformConfig, RecoveryCfg, ShardCfg, StealCfg, TrafficCfg,
};
use crate::ids::Cycles;
use crate::platform::Platform;
use crate::sim::chaos::FaultPlan;
use crate::sim::engine::Engine;
use crate::sim::rng::Rng;
use crate::sim::traffic::TrafficState;
use crate::testutil::oracles;

/// Decorrelates case-parameter draws from the engine RNG streams (which
/// also start from `seed`).
const CASE_STREAM: u64 = 0xAD5E_11E5_0DDB_A11D;
/// Seed of the meta-RNG that generates the (seed, plan) case list.
const META_SEED: u64 = 0xF0CC_5EED;
/// Cycle budget per run; a case still undrained here is a hang.
const CASE_LIMIT: Cycles = 1 << 44;

/// Harness options (parsed by `experiments::cli`).
#[derive(Clone, Copy, Debug)]
pub struct FuzzOpts {
    /// Number of generated cases (ignored when `fixed` is set).
    pub cases: usize,
    /// Keep generating fresh cases until this much wall-clock has passed
    /// (0 = no soak phase).
    pub soak_secs: u64,
    /// Reproduce exactly one `(seed, plan)` case.
    pub fixed: Option<(u64, u64)>,
}

impl FuzzOpts {
    pub fn smoke() -> Self {
        FuzzOpts { cases: 8, soak_secs: 0, fixed: None }
    }
}

/// Everything that must replay bit-identically (the
/// `tests/steal_determinism.rs` fingerprint tuple).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct CaseFp {
    pub time: Cycles,
    pub events: u64,
    pub msgs: u64,
    pub spawned: u64,
    pub completed: u64,
    pub dep_boundary: u64,
    pub steal_reqs: u64,
    pub steal_grants: u64,
    pub steal_denies: u64,
    pub tasks_stolen: u64,
    pub ready_hwm: u64,
    pub crashes: u64,
    pub restarts: u64,
    pub tasks_reissued: u64,
    pub crash_dups_dropped: u64,
    /// Traffic books (0 on non-traffic cases): the replay pin covers the
    /// admission schedule, not just the task schedule.
    pub jobs_admitted: u32,
    pub job_deferrals: u64,
}

/// One case verdict.
#[derive(Clone, Debug)]
pub struct FuzzRow {
    pub seed: u64,
    pub plan: u64,
    pub shape: &'static str,
    pub hier: &'static str,
    pub steal: &'static str,
    pub recovery: &'static str,
    /// Requested engine shard count (the partition may clamp it lower on
    /// small trees; clamped runs are still bit-identical by contract).
    pub shards: usize,
    /// Requested executor thread count (clamped to the shard count; runs
    /// outside the eligibility gate fall back to the sequential merge —
    /// either way the fingerprint is thread-count invariant by contract).
    pub threads: usize,
    pub strict: bool,
    /// Traffic mode ("off" | "steady" | "burst") and its parameters —
    /// `jobs`/`tenants` are 0 and `admission` is "-" on non-traffic cases.
    pub traffic: &'static str,
    pub admission: &'static str,
    pub jobs: u32,
    pub tenants: u32,
    pub fp: CaseFp,
    /// "ok" | "oracle" | "replay" | "hang".
    pub verdict: &'static str,
    pub violations: Vec<String>,
    /// Shrink result for failures with faults on: does the same seed
    /// fail with `plan = 0` too? `None` when not applicable.
    pub clean_fails: Option<bool>,
}

impl FuzzRow {
    pub fn ok(&self) -> bool {
        self.verdict == "ok"
    }

    /// The one-line reproducer recorded in the report.
    pub fn repro(&self) -> String {
        format!("myrmics exp fuzz --seed {} --plan {}", self.seed, self.plan)
    }
}

/// Case parameters, derived from the seed alone so the reproducer line
/// needs no extra state.
struct CaseParams {
    shape: u64,
    hier: u64,
    steal: u64,
    strict: bool,
    /// 0 = recovery off (pre-crash engine), 1 = protocol armed (the plan's
    /// own crash knobs decide if anything dies), 2 = forced crash (the
    /// plan's `crash_pct` is pinned to 100 so the full outage/re-adoption
    /// path runs whenever the tree has an eligible victim).
    recovery: u64,
    /// Engine shard count draw: 0 -> 1 shard (legacy), 1 -> 2, 2 -> 4.
    shard: u64,
    /// Executor thread count draw: 0 -> 1 (sequential merge), 1 -> 2,
    /// 2 -> 4, clamped to the drawn shard count.
    threads: u64,
    /// Traffic mode: 0..=1 = off (the single-job shapes above run as
    /// before), 2 = steady open-loop arrivals, 3 = burst (tight gaps +
    /// backpressure-heavy admission knobs).
    traffic: u64,
    /// Arrival-mix parameters for traffic cases, drawn unconditionally so
    /// the stream position never depends on earlier values.
    traffic_jobs: u64,
    traffic_tenants: u64,
    traffic_adm: u64,
}

impl CaseParams {
    fn derive(seed: u64) -> Self {
        let mut r = Rng::new(seed ^ CASE_STREAM);
        CaseParams {
            shape: r.below(5),
            hier: r.below(3),
            steal: r.below(4),
            // Mostly strict (load reports off => books must hit exactly
            // zero); the rest exercise the report path under the loose
            // bound.
            strict: r.below(4) < 3,
            // Trailing draw: earlier knobs for a given seed are unchanged,
            // so pre-crash reproducer lines keep their meaning.
            recovery: r.below(3),
            // Trailing again (same reasoning): the sharded engine joins
            // the sweep without renaming any pre-shard reproducer.
            shard: r.below(3),
            // Trailing again: chaos + crash + steal now also run under
            // concurrent multi-tenant jobs, without renaming any
            // pre-traffic reproducer.
            traffic: r.below(4),
            traffic_jobs: r.range(6, 14),
            traffic_tenants: r.range(2, 4),
            traffic_adm: r.below(3),
            // Trailing again: the thread-parallel executor joins the
            // sweep without renaming any pre-thread reproducer.
            threads: r.below(3),
        }
    }

    fn traffic_on(&self) -> bool {
        self.traffic >= 2
    }

    /// The traffic configuration of this case (`None` = single-job case).
    /// Burst mode crams arrivals an order of magnitude tighter than a
    /// job's service time and pins the backpressure knobs low, so the
    /// deferral/retry machinery actually runs under chaos.
    fn traffic_cfg(&self) -> Option<TrafficCfg> {
        if !self.traffic_on() {
            return None;
        }
        let mut t = TrafficCfg::on(self.traffic_jobs as u32, self.traffic_tenants as u32);
        t.admission = [
            AdmissionKind::AdmitAll,
            AdmissionKind::TenantCap,
            AdmissionKind::LoadThreshold,
        ][self.traffic_adm as usize];
        if self.traffic == 3 {
            t.mean_gap = 100_000;
            t.tenant_cap = 1;
            t.load_threshold = 8;
            t.retry_backoff = 50_000;
        }
        Some(t)
    }

    fn traffic_name(&self) -> &'static str {
        match self.traffic {
            0 | 1 => "off",
            2 => "steady",
            _ => "burst",
        }
    }

    fn admission_name(&self) -> &'static str {
        if !self.traffic_on() {
            return "-";
        }
        ["admit-all", "tenant-cap", "load-threshold"][self.traffic_adm as usize]
    }

    /// What actually executed: traffic cases replace the drawn single-job
    /// shape with the multi-job traffic body.
    fn effective_shape_name(&self) -> &'static str {
        if self.traffic_on() {
            "traffic-jobs"
        } else {
            self.shape_name()
        }
    }

    /// Requested shard count (the hierarchy partition clamps it to the
    /// number of top-level subtrees; fixed by the seed, so the
    /// reproducer line is environment-independent).
    fn shard_count(&self) -> usize {
        [1, 2, 4][self.shard as usize]
    }

    /// Requested executor thread count. Clamped to the drawn shard count
    /// (threads beyond shards would idle); the engine clamps again after
    /// the partition, so the reproducer line stays environment-free.
    fn thread_count(&self) -> usize {
        [1, 2, 4][self.threads as usize].min(self.shard_count())
    }

    fn shape_name(&self) -> &'static str {
        ["chain", "independent", "skew-hot", "skew-90", "hier-empty"][self.shape as usize]
    }

    fn hier_name(&self) -> &'static str {
        ["flat4", "two-level16", "three-level16"][self.hier as usize]
    }

    fn steal_name(&self) -> &'static str {
        ["off", "on", "rnd-victim", "on-retry"][self.steal as usize]
    }

    fn recovery_name(&self) -> &'static str {
        ["off", "armed", "crash"][self.recovery as usize]
    }
}

/// Build and fully drain one run. Shapes are the known adversaries: a
/// deep serial chain (all `inout` on one object), a wide independent fan,
/// the skewed-spawn hot spot (100% = everything into one subtree), and
/// the nested-region hierarchy that spawns during delegation.
fn exec(seed: u64, plan: u64) -> (Cycles, Engine) {
    let p = CaseParams::derive(seed);
    let mut cfg = match p.hier {
        0 => PlatformConfig::new(4, HierarchySpec::flat()),
        1 => PlatformConfig::new(16, HierarchySpec::two_level(4)),
        _ => PlatformConfig::new(16, HierarchySpec::multi_level(3, 2)),
    };
    cfg.seed = seed;
    cfg.chaos = FaultPlan::from_seed(plan);
    match p.recovery {
        0 => {}
        1 => cfg.recovery = RecoveryCfg::on(),
        _ => {
            cfg.recovery = RecoveryCfg::on();
            // Forced crash: with a live plan, guarantee the schedule rolls
            // a victim (plan 0 still means a clean engine — recovery armed
            // but nothing to recover from).
            if cfg.chaos.enabled {
                cfg.chaos.crash_pct = 100;
            }
        }
    }
    cfg.policy.steal = match p.steal {
        0 => StealCfg::default(),
        1 => StealCfg::on(),
        2 => StealCfg::random_victim(),
        _ => StealCfg::on().with_retry(5_000, 3),
    };
    if p.strict {
        cfg.load_report_threshold = u64::MAX;
    }
    // Shard and thread counts come from the case stream, not the
    // environment, so a reproducer line means the same thing everywhere.
    cfg.shard = ShardCfg::with_threads(p.shard_count(), p.thread_count());
    // Traffic cases swap the single-job shape for an open-loop multi-job
    // arrival mix: chaos, crashes and steal faults all run under
    // concurrent admissions, checked by the `check_jobs` oracle.
    if let Some(tcfg) = p.traffic_cfg() {
        cfg.traffic = tcfg.clone();
        let (reg, refs) = traffic_boot();
        let main_fn = refs.job_main.index();
        let mut plat = Platform::build_with(cfg, reg, refs.boot, move |w| {
            let tr = TrafficState::generate(&tcfg, seed, &w.hier, main_fn, &job_templates(1));
            w.traffic = Some(tr);
        });
        let t = plat.run_to_quiescence(Some(CASE_LIMIT));
        return (t, plat.eng);
    }
    let mut plat = match p.shape {
        0 => {
            let (reg, main) = empty_chain();
            Platform::build_with(cfg, reg, main, |w| {
                // Single-spawner contract holds: every spawn comes from
                // the chain's one live task. Threaded draws engage the
                // windowed executor (when the gate's other conditions
                // hold); ineligible combos fall back, bit-identically.
                w.par_safe = true;
                w.app = Some(Box::new(SynthParams {
                    n_tasks: 60,
                    task_cycles: 20_000,
                    ..Default::default()
                }));
            })
        }
        1 => {
            let (reg, main) = independent();
            Platform::build_with(cfg, reg, main, |w| {
                w.par_safe = true;
                w.app = Some(Box::new(SynthParams {
                    n_tasks: 48,
                    task_cycles: 50_000,
                    ..Default::default()
                }));
            })
        }
        2 | 3 => {
            let hot_pct = if p.shape == 2 { 100 } else { 90 };
            let (reg, main) = skew_myrmics();
            Platform::build_with(cfg, reg, main, move |w| {
                w.app = Some(Box::new(SkewParams {
                    tasks: 48,
                    task_cycles: 100_000,
                    hot_pct,
                    groups: 4,
                }));
            })
        }
        _ => {
            let (reg, main) = hier_empty();
            Platform::build_with(cfg, reg, main, |w| {
                w.app = Some(Box::new(SynthParams {
                    domains: 4,
                    per_domain: 8,
                    task_cycles: 20_000,
                    // On shallower trees ralloc clamps at the leaves.
                    domain_level: 2,
                    ..Default::default()
                }));
            })
        }
    };
    let t = plat.run_to_quiescence(Some(CASE_LIMIT));
    (t, plat.eng)
}

fn fingerprint(t: Cycles, eng: &Engine) -> CaseFp {
    let g = &eng.world.gstats;
    let (jobs_admitted, job_deferrals) =
        eng.world.traffic.as_ref().map_or((0, 0), |tr| (tr.admitted, tr.total_deferrals));
    CaseFp {
        time: t,
        events: g.events_processed,
        msgs: g.msgs_total,
        spawned: g.tasks_spawned,
        completed: g.tasks_completed,
        dep_boundary: g.dep_boundary_msgs,
        steal_reqs: g.steal_reqs,
        steal_grants: g.steal_grants,
        steal_denies: g.steal_denies,
        tasks_stolen: g.tasks_stolen,
        ready_hwm: g.ready_queue_hwm,
        crashes: g.crashes,
        restarts: g.restarts,
        tasks_reissued: g.tasks_reissued,
        crash_dups_dropped: g.crash_dups_dropped,
        jobs_admitted,
        job_deferrals,
    }
}

/// Run one `(seed, plan)` case: execute, check oracles, replay, shrink.
pub fn run_case(seed: u64, plan: u64) -> FuzzRow {
    run_case_with(seed, plan, None)
}

/// Like [`run_case`] but lets a test corrupt the quiesced engine before
/// the oracles see it — how the "a seeded corruption is caught and gets a
/// reproducer line" acceptance test drives the real reporting path. The
/// fingerprint is taken *before* corruption, so the replay pin still
/// compares honest runs.
pub fn run_case_with(
    seed: u64,
    plan: u64,
    corrupt: Option<&dyn Fn(&mut Engine)>,
) -> FuzzRow {
    let p = CaseParams::derive(seed);
    let (t, mut eng) = exec(seed, plan);
    let fp = fingerprint(t, &eng);
    let hang = !eng.world.done;
    if let Some(f) = corrupt {
        f(&mut eng);
    }
    let violations = oracles::check_all(&eng, p.strict);
    let (t2, eng2) = exec(seed, plan);
    let replay_ok = fp == fingerprint(t2, &eng2);
    let verdict = if hang {
        "hang"
    } else if !violations.is_empty() {
        "oracle"
    } else if !replay_ok {
        "replay"
    } else {
        "ok"
    };
    let clean_fails = if verdict != "ok" && plan != 0 {
        let (_tc, engc) = exec(seed, 0);
        Some(!engc.world.done || !oracles::check_all(&engc, p.strict).is_empty())
    } else {
        None
    };
    FuzzRow {
        seed,
        plan,
        shape: p.effective_shape_name(),
        hier: p.hier_name(),
        steal: p.steal_name(),
        recovery: p.recovery_name(),
        shards: p.shard_count(),
        threads: p.thread_count(),
        strict: p.strict,
        traffic: p.traffic_name(),
        admission: p.admission_name(),
        jobs: if p.traffic_on() { p.traffic_jobs as u32 } else { 0 },
        tenants: if p.traffic_on() { p.traffic_tenants as u32 } else { 0 },
        fp,
        verdict,
        violations,
        clean_fails,
    }
}

/// Run the harness. Returns `true` when every case passed (the CLI exits
/// nonzero otherwise, which is what makes the CI step blocking).
pub fn run(opts: &FuzzOpts) -> bool {
    let mut rows = Vec::new();
    if let Some((seed, plan)) = opts.fixed {
        rows.push(run_case(seed, plan));
    } else {
        let mut meta = Rng::new(META_SEED);
        for i in 0..opts.cases {
            let seed = meta.next_u64();
            let drawn = meta.next_u64();
            // Every 5th case runs fault-free: the oracles must also hold
            // on the unperturbed engine.
            let plan = if i % 5 == 4 { 0 } else { drawn };
            rows.push(run_case(seed, plan));
        }
        if opts.soak_secs > 0 {
            let start = Instant::now();
            while start.elapsed().as_secs() < opts.soak_secs {
                let seed = meta.next_u64();
                let plan = meta.next_u64();
                rows.push(run_case(seed, plan));
            }
        }
    }
    print_rows(&rows);
    match emit_json(&rows, "FUZZ_report.json") {
        Ok(()) => println!("wrote FUZZ_report.json ({} cases)", rows.len()),
        Err(e) => eprintln!("failed to write FUZZ_report.json: {e}"),
    }
    let failures: Vec<&FuzzRow> = rows.iter().filter(|r| !r.ok()).collect();
    for r in &failures {
        eprintln!(
            "FAIL [{}] {}  # shape {} hier {} steal {} recovery {} traffic {}",
            r.verdict, r.repro(), r.shape, r.hier, r.steal, r.recovery, r.traffic
        );
    }
    failures.is_empty()
}

pub fn print_rows(rows: &[FuzzRow]) {
    println!("Protocol fuzz — fault plans x adversarial spawns, oracle + replay checked");
    println!(
        "{:<22} {:<22} {:<12} {:<12} {:<10} {:<8} {:<8} {:>6} {:>4} {:>6} {:>12} {:>6} {:>7} {:>7} {:>5} {:>8}",
        "seed", "plan", "shape", "hier", "steal", "recov", "traffic", "shards", "thr", "strict", "time", "tasks", "stolen", "crashes", "jobs", "verdict"
    );
    for r in rows {
        println!(
            "{:<22} {:<22} {:<12} {:<12} {:<10} {:<8} {:<8} {:>6} {:>4} {:>6} {:>12} {:>6} {:>7} {:>7} {:>5} {:>8}",
            r.seed,
            r.plan,
            r.shape,
            r.hier,
            r.steal,
            r.recovery,
            r.traffic,
            r.shards,
            r.threads,
            if r.strict { "yes" } else { "no" },
            r.fp.time,
            r.fp.completed,
            r.fp.tasks_stolen,
            r.fp.crashes,
            r.fp.jobs_admitted,
            r.verdict
        );
    }
    println!();
}

/// Minimal JSON string escaping (violation text can contain quotes from
/// `{:?}` formatting).
fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

pub fn to_json(rows: &[FuzzRow]) -> String {
    let objs: Vec<String> = rows
        .iter()
        .map(|r| {
            let detail = esc(&r.violations.join("; "));
            let clean = match r.clean_fails {
                None => "null".to_string(),
                Some(b) => b.to_string(),
            };
            format!(
                "{{\"seed\": {}, \"plan\": {}, \"shape\": \"{}\", \"hier\": \"{}\", \
                 \"steal\": \"{}\", \"recovery\": \"{}\", \"shards\": {}, \"threads\": {}, \
                 \"strict\": {}, \
                 \"traffic\": \"{}\", \"admission\": \"{}\", \"jobs\": {}, \"tenants\": {}, \
                 \"admitted\": {}, \"deferrals\": {}, \"time\": {}, \
                 \"events\": {}, \"tasks\": {}, \"tasks_stolen\": {}, \"steal_denies\": {}, \
                 \"crashes\": {}, \"tasks_reissued\": {}, \
                 \"verdict\": \"{}\", \"violations\": {}, \"detail\": \"{}\", \
                 \"clean_fails\": {}, \"repro\": \"{}\"}}",
                r.seed,
                r.plan,
                r.shape,
                r.hier,
                r.steal,
                r.recovery,
                r.shards,
                r.threads,
                r.strict,
                r.traffic,
                r.admission,
                r.jobs,
                r.tenants,
                r.fp.jobs_admitted,
                r.fp.job_deferrals,
                r.fp.time,
                r.fp.events,
                r.fp.completed,
                r.fp.tasks_stolen,
                r.fp.steal_denies,
                r.fp.crashes,
                r.fp.tasks_reissued,
                r.verdict,
                r.violations.len(),
                detail,
                clean,
                r.repro(),
            )
        })
        .collect();
    super::json_array(&objs)
}

pub fn emit_json(rows: &[FuzzRow], path: &str) -> std::io::Result<()> {
    std::fs::write(path, to_json(rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// First cases of the real meta stream (what `--smoke` runs) must
    /// pass every oracle and the replay pin.
    #[test]
    fn leading_smoke_cases_are_green() {
        let mut meta = Rng::new(META_SEED);
        for i in 0..3 {
            let seed = meta.next_u64();
            let drawn = meta.next_u64();
            let plan = if i % 5 == 4 { 0 } else { drawn };
            let r = run_case(seed, plan);
            assert!(
                r.ok(),
                "case {i} (seed {seed}, plan {plan}) failed: {} {:?}",
                r.verdict,
                r.violations
            );
        }
    }

    /// The acceptance criterion: a deliberately corrupted run is caught
    /// by an oracle and the row carries a reproducer line.
    #[test]
    fn seeded_corruption_is_caught_with_a_reproducer() {
        let mut meta = Rng::new(META_SEED);
        let seed = meta.next_u64();
        let plan = meta.next_u64();
        let r = run_case_with(seed, plan, Some(&|eng: &mut Engine| {
            eng.world.gstats.tasks_completed -= 1;
        }));
        assert_eq!(r.verdict, "oracle");
        assert!(!r.violations.is_empty());
        assert!(r.repro().contains("--seed"), "repro line: {}", r.repro());
        let j = to_json(&[r]);
        assert!(j.contains("\"verdict\": \"oracle\""));
        assert!(j.contains("myrmics exp fuzz --seed"));
    }

    /// Nonzero plans must actually perturb: across a handful of cases the
    /// chaos layer has to have injected something (every generated plan
    /// draws jitter_pct >= 10, so an all-quiet sweep means the hooks came
    /// unwired).
    #[test]
    fn fault_plans_actually_inject() {
        let mut meta = Rng::new(META_SEED);
        let mut injected = 0u64;
        for _ in 0..3 {
            let seed = meta.next_u64();
            let plan = meta.next_u64();
            let (_t, eng) = exec(seed, plan);
            assert!(eng.world.done, "chaos run must still complete");
            let c = &eng.sim.chaos;
            injected += c.jitters()
                + c.starves()
                + c.stalls()
                + c.forced_denies()
                + c.report_delays()
                + c.grant_delays();
        }
        assert!(injected > 0, "no faults injected across 3 chaos cases");
    }

    /// The meta stream's forced-crash cases (recovery mode "crash" on a
    /// tree with an eligible victim) must lose a scheduler mid-run, run
    /// the re-adoption protocol, and still come out green on every oracle
    /// plus the replay pin — the crash-and-restart acceptance criterion,
    /// exercised on the exact cases CI's smoke/nightly sweeps draw.
    #[test]
    fn crash_cases_recover_and_stay_green() {
        let mut meta = Rng::new(META_SEED);
        let mut ran = 0u32;
        let mut crashed = 0u64;
        for i in 0..64 {
            let seed = meta.next_u64();
            let drawn = meta.next_u64();
            let plan = if i % 5 == 4 { 0 } else { drawn };
            let p = CaseParams::derive(seed);
            // flat4 has a single scheduler: no eligible victim, so the
            // forced-crash knob is inert there by design.
            if plan == 0 || p.recovery != 2 || p.hier == 0 {
                continue;
            }
            let r = run_case(seed, plan);
            assert!(
                r.ok(),
                "crash case (seed {seed}, plan {plan}) failed: {} {:?}",
                r.verdict,
                r.violations
            );
            crashed += r.fp.crashes;
            ran += 1;
            if ran == 3 {
                break;
            }
        }
        assert!(ran > 0, "meta stream produced no forced-crash case in 64 draws");
        assert!(crashed > 0, "no forced-crash case actually lost a scheduler");
    }

    /// A fixed-case reproduction (`--seed X --plan Y`) runs exactly one
    /// row and replays.
    #[test]
    fn fixed_case_reproduces_and_replays() {
        let a = run_case(12345, 678);
        let b = run_case(12345, 678);
        assert_eq!(a.fp, b.fp, "same (seed, plan) must fingerprint identically");
        assert!(a.ok(), "fixed case failed: {} {:?}", a.verdict, a.violations);
    }

    #[test]
    fn json_shape_is_stable() {
        let rows = vec![run_case(42, 0)];
        let j = to_json(&rows);
        assert!(j.starts_with("[\n"));
        assert!(j.trim_end().ends_with(']'));
        for key in [
            "\"seed\"",
            "\"plan\"",
            "\"recovery\"",
            "\"shards\"",
            "\"threads\"",
            "\"traffic\"",
            "\"admission\"",
            "\"jobs\"",
            "\"tenants\"",
            "\"admitted\"",
            "\"deferrals\"",
            "\"crashes\"",
            "\"tasks_reissued\"",
            "\"verdict\"",
            "\"repro\"",
            "\"clean_fails\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        assert_eq!(j.matches("{\"seed\"").count(), 1);
    }

    /// Traffic cases from the real meta stream run green (oracles +
    /// replay pin, which now covers the admission books) and their rows
    /// carry the drawn traffic parameters into the report.
    #[test]
    fn traffic_cases_run_green_and_report_their_params() {
        let mut meta = Rng::new(META_SEED);
        let mut ran = 0u32;
        for i in 0..64 {
            let seed = meta.next_u64();
            let drawn = meta.next_u64();
            let plan = if i % 5 == 4 { 0 } else { drawn };
            let p = CaseParams::derive(seed);
            if !p.traffic_on() {
                continue;
            }
            let r = run_case(seed, plan);
            assert!(
                r.ok(),
                "traffic case (seed {seed}, plan {plan}) failed: {} {:?}",
                r.verdict,
                r.violations
            );
            assert_eq!(r.shape, "traffic-jobs");
            assert_ne!(r.traffic, "off");
            assert_ne!(r.admission, "-");
            assert!(r.jobs > 0 && r.tenants > 0);
            // The oracle already pins "every job admitted"; the row must
            // agree with the books.
            assert_eq!(r.fp.jobs_admitted, r.jobs);
            let j = to_json(&[r]);
            assert!(j.contains("\"traffic\": \"steady\"") || j.contains("\"traffic\": \"burst\""));
            ran += 1;
            if ran == 2 {
                break;
            }
        }
        assert!(ran > 0, "meta stream produced no traffic case in 64 draws");
    }

    /// The headline satellite: chaos + a forced scheduler crash under
    /// concurrent multi-tenant jobs. The run must lose a scheduler,
    /// recover (re-adoption re-arms the dead entry's job timers), drain
    /// every admitted job, and replay bit-identically.
    #[test]
    fn traffic_crash_cases_recover_and_drain_every_job() {
        let mut meta = Rng::new(META_SEED);
        let mut ran = 0u32;
        let mut crashed = 0u64;
        for i in 0..128 {
            let seed = meta.next_u64();
            let drawn = meta.next_u64();
            let plan = if i % 5 == 4 { 0 } else { drawn };
            let p = CaseParams::derive(seed);
            // flat4 has no eligible crash victim; plan 0 is fault-free.
            if plan == 0 || !p.traffic_on() || p.recovery != 2 || p.hier == 0 {
                continue;
            }
            let r = run_case(seed, plan);
            assert!(
                r.ok(),
                "traffic crash case (seed {seed}, plan {plan}) failed: {} {:?}",
                r.verdict,
                r.violations
            );
            assert_eq!(r.fp.jobs_admitted, r.jobs, "every job must still be admitted");
            crashed += r.fp.crashes;
            ran += 1;
            if ran == 2 {
                break;
            }
        }
        assert!(ran > 0, "meta stream produced no traffic+crash case in 128 draws");
        assert!(crashed > 0, "no traffic crash case actually lost a scheduler");
    }
}

//! Unified benchmark driver: pick a kernel, a worker count, a scaling
//! mode and a runtime (Myrmics flat / hierarchical / MPI) and get a
//! [`Summary`] back. This backs Figs 8, 9, 10 and 11.
//!
//! Sizing follows paper VI-B: strong scaling fixes the problem and
//! decomposes into 2 tasks per worker per step with >= ~1 M-cycle minimum
//! tasks at 512 workers; weak scaling fixes per-task size at the ~1 M
//! minimum and grows the problem with the worker count.

use crate::apps::{barnes_hut, bitonic, jacobi, kmeans, matmul, raytrace};
use crate::config::{HierarchySpec, PlatformConfig, PolicyCfg};
use crate::ids::Cycles;
use crate::mpi::runner::run_mpi;
use crate::platform::Platform;
use crate::sim::engine::Engine;

use super::{summarize, Summary};

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BenchKind {
    Jacobi,
    Raytrace,
    Bitonic,
    Kmeans,
    Matmul,
    BarnesHut,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Scaling {
    Strong,
    Weak,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum System {
    Mpi,
    MyrmicsFlat,
    MyrmicsHier,
}

impl BenchKind {
    pub fn all() -> [BenchKind; 6] {
        [
            BenchKind::Jacobi,
            BenchKind::Raytrace,
            BenchKind::Bitonic,
            BenchKind::Kmeans,
            BenchKind::Matmul,
            BenchKind::BarnesHut,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            BenchKind::Jacobi => "jacobi",
            BenchKind::Raytrace => "raytrace",
            BenchKind::Bitonic => "bitonic",
            BenchKind::Kmeans => "kmeans",
            BenchKind::Matmul => "matmul",
            BenchKind::BarnesHut => "barnes-hut",
        }
    }

    /// Worker counts each benchmark supports (matmul needs power-of-4
    /// grids; bitonic power-of-2 blocks; Barnes-Hut stops at 128 in the
    /// paper "due to memory constraints").
    pub fn valid_workers(&self, w: usize) -> bool {
        match self {
            BenchKind::Matmul => {
                let p = (w as f64).sqrt().round() as usize;
                p * p == w
            }
            BenchKind::Bitonic => w.is_power_of_two(),
            BenchKind::BarnesHut => w <= 128,
            _ => true,
        }
    }

    /// Benchmark iterations/steps (kept small: scaling shape, not length).
    fn iters(&self) -> usize {
        match self {
            BenchKind::Jacobi => 6,
            BenchKind::Raytrace => 1,
            BenchKind::Bitonic => 1,
            BenchKind::Kmeans => 4,
            BenchKind::Matmul => 1,
            BenchKind::BarnesHut => 3,
        }
    }
}

/// Groups used by the app decomposition — the paper's leaf-scheduler
/// count, so each leaf scheduler gets its own region subtree.
fn groups_for(workers: usize) -> usize {
    HierarchySpec::paper_leaves(workers).max(1)
}

/// Build + run the Myrmics variant; returns (time, engine). `policy`
/// overrides the default placement policy (`None` = paper default).
pub fn run_myrmics(
    bench: BenchKind,
    workers: usize,
    scaling: Scaling,
    hier: bool,
    policy: Option<PolicyCfg>,
) -> (Cycles, Engine) {
    let mut cfg = if hier {
        PlatformConfig::hierarchical(workers)
    } else {
        PlatformConfig::flat(workers)
    };
    if let Some(p) = policy {
        cfg.policy = p;
    }
    let g = groups_for(workers);
    let weak = scaling == Scaling::Weak;
    let iters = bench.iters();
    let w = workers;
    match bench {
        BenchKind::Jacobi => {
            let bands = (2 * w).max(2);
            let n = if weak { bands * 10 } else { 8192.max(bands * 3) };
            let p = jacobi::JacobiParams::modeled(n, iters, bands, g.min(bands));
            let (reg, main) = jacobi::myrmics();
            let mut plat = Platform::build_with(cfg, reg, main, |world| {
                world.app = Some(Box::new(p));
            });
            let t = plat.run(Some(1 << 46));
            (t, plat.eng)
        }
        BenchKind::Raytrace => {
            let tasks = (2 * w).max(2);
            let height = if weak { tasks * 2 } else { 2048.max(tasks * 2) };
            let p = raytrace::RayParams {
                width: 4096,
                height,
                tasks,
                groups: g.min(tasks),
                scene_bytes: 64 * 1024,
            };
            let (reg, main) = raytrace::myrmics();
            let mut plat = Platform::build_with(cfg, reg, main, |world| {
                world.app = Some(Box::new(p));
            });
            let t = plat.run(Some(1 << 46));
            (t, plat.eng)
        }
        BenchKind::Bitonic => {
            let blocks = (2 * w).next_power_of_two();
            let m = if weak { 4096 } else { (1usize << 22) / blocks };
            let p = bitonic::BitonicParams {
                blocks,
                m: m.max(64),
                groups: g.next_power_of_two().min(blocks),
                real_data: false,
            };
            let (reg, main) = bitonic::myrmics();
            let mut plat = Platform::build_with(cfg, reg, main, |world| {
                world.app = Some(Box::new(p));
            });
            let t = plat.run(Some(1 << 46));
            (t, plat.eng)
        }
        BenchKind::Kmeans => {
            let bands = (2 * w).max(2);
            let points = if weak { bands * 8192 } else { 1 << 23 };
            let p = kmeans::KmParams {
                points,
                k: 16,
                iters,
                bands,
                groups: g.min(bands),
                real_data: false,
            };
            let (reg, main) = kmeans::myrmics();
            let mut plat = Platform::build_with(cfg, reg, main, |world| {
                world.app = Some(Box::new(p));
            });
            let t = plat.run(Some(1 << 46));
            (t, plat.eng)
        }
        BenchKind::Matmul => {
            let p_grid = ((w as f64).sqrt().round() as usize).max(1);
            let n = if weak { 64 * p_grid } else { 1024 };
            let p = matmul::MatmulParams { n, p: p_grid, real_data: false };
            let (reg, main) = matmul::myrmics();
            let mut plat = Platform::build_with(cfg, reg, main, |world| {
                world.app = Some(Box::new(p));
            });
            let t = plat.run(Some(1 << 46));
            (t, plat.eng)
        }
        BenchKind::BarnesHut => {
            let bands = (2 * w).max(2);
            let bodies = if weak { bands * 4096 } else { 1 << 20 };
            let p = barnes_hut::BhParams { bodies, bands, groups: g.min(bands), iters };
            let (reg, main) = barnes_hut::myrmics();
            let mut plat = Platform::build_with(cfg, reg, main, |world| {
                world.app = Some(Box::new(p));
            });
            let t = plat.run(Some(1 << 46));
            (t, plat.eng)
        }
    }
}

/// Build + run the MPI baseline; returns (time, engine).
pub fn run_mpi_bench(bench: BenchKind, ranks: usize, scaling: Scaling) -> (Cycles, Engine) {
    let cfg = PlatformConfig::flat(1);
    let weak = scaling == Scaling::Weak;
    let iters = bench.iters();
    let progs = match bench {
        BenchKind::Jacobi => {
            let bands = (2 * ranks).max(2);
            let n = if weak { bands * 10 } else { 8192.max(bands * 3) };
            jacobi::mpi_programs(&jacobi::JacobiParams::modeled(n, iters, bands, 1), ranks)
        }
        BenchKind::Raytrace => {
            let tasks = (2 * ranks).max(2);
            let height = if weak { tasks * 2 } else { 2048.max(tasks * 2) };
            raytrace::mpi_programs(
                &raytrace::RayParams {
                    width: 4096,
                    height,
                    tasks,
                    groups: 1,
                    scene_bytes: 64 * 1024,
                },
                ranks,
            )
        }
        BenchKind::Bitonic => {
            let blocks = (2 * ranks).next_power_of_two();
            let m = if weak { 4096 } else { (1usize << 22) / blocks };
            bitonic::mpi_programs(
                &bitonic::BitonicParams { blocks, m: m.max(64), groups: 1, real_data: false },
                ranks,
            )
        }
        BenchKind::Kmeans => {
            let bands = (2 * ranks).max(2);
            let points = if weak { bands * 8192 } else { 1 << 23 };
            kmeans::mpi_programs(
                &kmeans::KmParams { points, k: 16, iters, bands, groups: 1, real_data: false },
                ranks,
            )
        }
        BenchKind::Matmul => {
            let p_grid = ((ranks as f64).sqrt().round() as usize).max(1);
            let n = if weak { 64 * p_grid } else { 1024 };
            matmul::mpi_programs(&matmul::MatmulParams { n, p: p_grid, real_data: false }, ranks)
        }
        BenchKind::BarnesHut => {
            let bands = (2 * ranks).max(2);
            let bodies = if weak { bands * 4096 } else { 1 << 20 };
            barnes_hut::mpi_programs(
                &barnes_hut::BhParams { bodies, bands, groups: 1, iters },
                ranks,
            )
        }
    };
    let eng = run_mpi(progs, &cfg);
    (eng.sim.now, eng)
}

/// Run any system and summarize.
pub fn run_system(
    bench: BenchKind,
    system: System,
    workers: usize,
    scaling: Scaling,
) -> Summary {
    let (t, eng) = match system {
        System::Mpi => run_mpi_bench(bench, workers, scaling),
        System::MyrmicsFlat => run_myrmics(bench, workers, scaling, false, None),
        System::MyrmicsHier => run_myrmics(bench, workers, scaling, true, None),
    };
    summarize(&eng, t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_bench_runs_on_every_system_small() {
        for bench in BenchKind::all() {
            let w = if bench == BenchKind::Matmul { 4 } else { 4 };
            for sys in [System::Mpi, System::MyrmicsFlat, System::MyrmicsHier] {
                let s = run_system(bench, sys, w, Scaling::Weak);
                assert!(s.time > 0, "{:?}/{:?}", bench, sys);
                if sys != System::Mpi {
                    assert!(s.tasks_completed > 0, "{:?}/{:?}", bench, sys);
                }
            }
        }
    }

    #[test]
    fn valid_worker_filters() {
        assert!(BenchKind::Matmul.valid_workers(16));
        assert!(!BenchKind::Matmul.valid_workers(32));
        assert!(BenchKind::Bitonic.valid_workers(64));
        assert!(!BenchKind::Bitonic.valid_workers(48));
        assert!(!BenchKind::BarnesHut.valid_workers(256));
    }
}

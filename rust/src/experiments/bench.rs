//! Unified benchmark driver: pick a workload, a worker count, a scaling
//! mode and a runtime (Myrmics flat / hierarchical / MPI) and get a
//! [`Summary`] back. This backs Figs 8, 9, 10 and 11.
//!
//! Workloads are trait objects from [`all_workloads`] — this driver holds
//! **no per-benchmark knowledge**: sizing, registration, MPI baselines
//! and validity filters all live behind the [`Workload`] seam in each
//! app's own file (`apps/workload_api.rs`). Adding a scenario does not
//! touch this module.

use crate::config::{PlatformConfig, PolicyCfg};
use crate::ids::Cycles;
use crate::mpi::runner::run_mpi;
use crate::platform::Platform;
use crate::sim::engine::Engine;
use crate::task::registry::Registry;

pub use crate::apps::workload_api::{all_workloads, workload, Scaling, Workload, WorkloadRef};

use super::{summarize, Summary};

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum System {
    Mpi,
    MyrmicsFlat,
    MyrmicsHier,
}

/// Build + run the Myrmics variant; returns (time, engine). `policy`
/// overrides the default placement policy (`None` = paper default).
pub fn run_myrmics(
    bench: WorkloadRef,
    workers: usize,
    scaling: Scaling,
    hier: bool,
    policy: Option<PolicyCfg>,
) -> (Cycles, Engine) {
    let mut cfg = if hier {
        PlatformConfig::hierarchical(workers)
    } else {
        PlatformConfig::flat(workers)
    };
    if let Some(p) = policy {
        cfg.policy = p;
    }
    let mut reg = Registry::new();
    let main = bench.register(&mut reg);
    let params = bench.params_for(workers, scaling);
    let mut plat = Platform::build_with(cfg, reg, main, move |world| {
        world.app = Some(params);
    });
    let t = plat.run(Some(1 << 46));
    (t, plat.eng)
}

/// Build + run the MPI baseline; returns (time, engine).
pub fn run_mpi_bench(bench: WorkloadRef, ranks: usize, scaling: Scaling) -> (Cycles, Engine) {
    let cfg = PlatformConfig::flat(1);
    let eng = run_mpi(bench.mpi_programs(ranks, scaling), &cfg);
    (eng.sim.now, eng)
}

/// Run any system and summarize.
pub fn run_system(
    bench: WorkloadRef,
    system: System,
    workers: usize,
    scaling: Scaling,
) -> Summary {
    let (t, eng) = match system {
        System::Mpi => run_mpi_bench(bench, workers, scaling),
        System::MyrmicsFlat => run_myrmics(bench, workers, scaling, false, None),
        System::MyrmicsHier => run_myrmics(bench, workers, scaling, true, None),
    };
    summarize(&eng, t)
}

//! Fig 11: locality vs load-balance policy sweep (paper VI-D).
//!
//! Sweeps the policy bias `p` from pure locality (p=100) to pure load
//! balance (p=0) for the paper's three configurations: MatMul flat/32w,
//! Jacobi hier/128w, K-Means hier/512w; reports running time, system-wide
//! load balance and total DMA traffic, normalized to each experiment's
//! maximum (percent, as in the figure).

use super::bench::{run_myrmics, workload, Scaling, WorkloadRef};
use super::summarize;
use crate::config::PolicyCfg;

#[derive(Clone, Debug)]
pub struct PolicyPoint {
    pub p_locality: u32,
    pub time_pct: f64,
    pub balance_pct: f64,
    pub dma_pct: f64,
}

#[derive(Clone, Debug)]
pub struct PolicySweep {
    pub bench: WorkloadRef,
    pub workers: usize,
    pub hier: bool,
    pub points: Vec<PolicyPoint>,
}

/// The paper's three VI-D configurations, resolved from the workload
/// table.
pub fn paper_configs() -> [(WorkloadRef, usize, bool); 3] {
    [
        (workload("matmul"), 16, false), // paper uses 32; 16 keeps the square grid
        (workload("jacobi"), 128, true),
        (workload("kmeans"), 512, true),
    ]
}

pub fn sweep(bench: WorkloadRef, workers: usize, hier: bool, ps: &[u32]) -> PolicySweep {
    let mut raw = Vec::new();
    for &p in ps {
        let (t, eng) =
            run_myrmics(bench, workers, Scaling::Strong, hier, Some(PolicyCfg::locality_balance(p)));
        let s = summarize(&eng, t);
        raw.push((p, t as f64, s.balance, s.total_dma_bytes as f64));
    }
    let t_max = raw.iter().map(|r| r.1).fold(0.0, f64::max).max(1.0);
    let d_max = raw.iter().map(|r| r.3).fold(0.0, f64::max).max(1.0);
    PolicySweep {
        bench,
        workers,
        hier,
        points: raw
            .into_iter()
            .map(|(p, t, b, d)| PolicyPoint {
                p_locality: p,
                time_pct: 100.0 * t / t_max,
                balance_pct: b,
                dma_pct: 100.0 * d / d_max,
            })
            .collect(),
    }
}

pub fn print_sweep(s: &PolicySweep) {
    println!(
        "Fig 11 — {} / {} workers / {} scheduling",
        s.bench.name(),
        s.workers,
        if s.hier { "hierarchical" } else { "flat" }
    );
    println!(
        "{:>10} {:>10} {:>10} {:>10}",
        "p(local%)", "time%", "balance%", "DMA%"
    );
    for p in &s.points {
        println!(
            "{:>10} {:>10.1} {:>10.1} {:>10.1}",
            p.p_locality, p.time_pct, p.balance_pct, p.dma_pct
        );
    }
    println!("paper: best trade-off at 0.1-0.3 locality weight\n");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locality_extreme_hurts_balance_and_time() {
        let s = sweep(workload("kmeans"), 16, true, &[100, 20, 0]);
        let p100 = &s.points[0];
        let p20 = &s.points[1];
        // Pure locality: worse balance than the balanced policy.
        assert!(p100.balance_pct <= p20.balance_pct + 1e-9);
        // Balanced policy runs at least as fast as pure locality.
        assert!(p20.time_pct <= p100.time_pct + 1e-9);
    }

    #[test]
    fn balance_extreme_moves_more_data() {
        let s = sweep(workload("jacobi"), 16, true, &[100, 0]);
        let p100 = &s.points[0];
        let p0 = &s.points[1];
        assert!(
            p0.dma_pct >= p100.dma_pct,
            "pure balance should move at least as much data: {} vs {}",
            p0.dma_pct,
            p100.dma_pct
        );
    }
}

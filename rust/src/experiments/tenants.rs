//! Multi-tenant traffic sweep: admission policies x hierarchy depths.
//!
//! Each point drives the open-loop arrival process (`sim::traffic`) with
//! the full heterogeneous template mix (`apps::workload_api::job_templates`)
//! and measures what the admission layer trades: makespan and utilization
//! against per-tenant job-latency percentiles, deferral counts and Jain's
//! fairness index (`stats::tenants`). Trees go up to 4096 workers under a
//! 4-level scheduler hierarchy — the scale argument for decentralized
//! admission: every decision is taken at a top-level subtree root from
//! local load books, so adding subtrees adds admission capacity.
//!
//! Output: rows on stdout plus `TENANTS_sweep.json`. CI smoke-runs the
//! emitter (`myrmics exp tenants --smoke`, blocking) so it cannot rot;
//! the nightly workflow runs the full depth ladder.

use crate::apps::jobs::traffic_boot;
use crate::apps::workload_api::job_templates;
use crate::config::{AdmissionKind, HierarchySpec, PlatformConfig, ShardCfg, TrafficCfg};
use crate::ids::Cycles;
use crate::platform::Platform;
use crate::sim::traffic::TrafficState;
use crate::stats::tenants::tenant_report;

use super::summarize;

/// One (tree, admission policy) measurement.
#[derive(Clone, Debug)]
pub struct TenantRow {
    pub policy: &'static str,
    pub tree: &'static str,
    pub workers: usize,
    pub levels: usize,
    /// Engine shards / executor threads the row ran under (from
    /// `MYRMICS_SHARDS`/`MYRMICS_THREADS` or `--threads`; both 1 by
    /// default). Traffic runs always fall back to the sequential merge
    /// today, but the row still records the requested engine mode.
    pub shards: usize,
    pub threads: usize,
    pub jobs: u32,
    pub tenants: u32,
    pub admitted: u32,
    pub deferrals: u64,
    pub makespan: Cycles,
    pub p50_latency: Cycles,
    pub p99_latency: Cycles,
    pub jain: f64,
    /// Mean fraction of worker time spent in task bodies.
    pub util_pct: f64,
    pub tenant_p50: Vec<Cycles>,
    pub tenant_p99: Vec<Cycles>,
    pub events: u64,
}

/// One hierarchy point of the depth ladder.
#[derive(Clone, Debug)]
pub struct TreePoint {
    pub name: &'static str,
    pub workers: usize,
    pub spec: HierarchySpec,
}

impl TreePoint {
    pub fn levels(&self) -> usize {
        self.spec.scheds_per_level.len()
    }
}

/// The depth ladder the full sweep climbs (levels 2..=4, up to 4096
/// workers). Leaf counts keep ~64 workers per leaf subtree at the top
/// end, matching the paper's 512-core chapter scaled up.
pub fn depth_ladder() -> Vec<TreePoint> {
    vec![
        TreePoint { name: "two-level-64", workers: 64, spec: HierarchySpec::two_level(8) },
        TreePoint {
            name: "three-level-512",
            workers: 512,
            spec: HierarchySpec { scheds_per_level: vec![1, 4, 16] },
        },
        TreePoint {
            name: "four-level-4096",
            workers: 4096,
            spec: HierarchySpec { scheds_per_level: vec![1, 4, 16, 64] },
        },
    ]
}

/// Run one point: `tcfg` jobs arrive over `tree`, templates at `scale`.
pub fn run_one(tree: &TreePoint, tcfg: &TrafficCfg, scale: u32) -> TenantRow {
    let mut cfg = PlatformConfig::new(tree.workers, tree.spec.clone());
    cfg.traffic = tcfg.clone();
    let levels = tree.levels();
    let (reg, refs) = traffic_boot();
    let main_fn = refs.job_main.index();
    let seed = cfg.seed;
    let prime_cfg = tcfg.clone();
    let mut plat = Platform::build_with(cfg, reg, refs.boot, move |w| {
        let tr =
            TrafficState::generate(&prime_cfg, seed, &w.hier, main_fn, &job_templates(scale));
        w.traffic = Some(tr);
    });
    let t = plat.run(Some(1 << 44));
    let s = summarize(&plat.eng, t);
    let tr = plat.world().traffic.as_ref().expect("traffic installed");
    assert!(tr.all_done(), "sweep points must drain: {} {:?}", tree.name, tcfg.admission);
    let rep = tenant_report(tr);
    let shard = ShardCfg::from_env();
    TenantRow {
        policy: tcfg.admission.name(),
        tree: tree.name,
        workers: tree.workers,
        levels,
        shards: shard.shards.max(1),
        threads: shard.threads.max(1),
        jobs: tcfg.jobs,
        tenants: tcfg.tenants,
        admitted: rep.admitted,
        deferrals: rep.total_deferrals,
        makespan: t,
        p50_latency: rep.p50_latency,
        p99_latency: rep.p99_latency,
        jain: rep.jain_index,
        util_pct: 100.0 * s.worker_task_frac,
        tenant_p50: rep.tenants.iter().map(|x| x.p50_latency).collect(),
        tenant_p99: rep.tenants.iter().map(|x| x.p99_latency).collect(),
        events: plat.world().gstats.events_processed,
    }
}

/// The three admission policies every sweep mode covers.
pub fn policies() -> [AdmissionKind; 3] {
    [AdmissionKind::AdmitAll, AdmissionKind::TenantCap, AdmissionKind::LoadThreshold]
}

fn traffic_for(jobs: u32, tenants: u32, admission: AdmissionKind) -> TrafficCfg {
    let mut t = TrafficCfg::on(jobs, tenants).with_admission(admission);
    // Arrivals well inside a job's runtime so admission actually has
    // concurrent load to push back on.
    t.mean_gap = 400_000;
    t
}

/// Run the sweep. `smoke` = one small tree, all three policies (CI,
/// seconds); `quick` = two trees; full = the whole depth ladder to 4096
/// workers with job counts scaled to the tree.
pub fn run(quick: bool, smoke: bool) -> Vec<TenantRow> {
    let mut rows = Vec::new();
    if smoke {
        let tree =
            TreePoint { name: "two-level-16", workers: 16, spec: HierarchySpec::two_level(4) };
        for p in policies() {
            rows.push(run_one(&tree, &traffic_for(12, 3, p), 1));
        }
    } else {
        let ladder = depth_ladder();
        let trees: &[TreePoint] = if quick { &ladder[..2] } else { &ladder };
        for tree in trees {
            let jobs = ((tree.workers / 16) as u32).clamp(24, 128);
            let scale = if tree.workers >= 512 { 2 } else { 1 };
            for p in policies() {
                rows.push(run_one(tree, &traffic_for(jobs, 4, p), scale));
            }
        }
    }
    print_rows(&rows);
    match emit_json(&rows, "TENANTS_sweep.json") {
        Ok(()) => println!("wrote TENANTS_sweep.json ({} rows)", rows.len()),
        Err(e) => eprintln!("failed to write TENANTS_sweep.json: {e}"),
    }
    rows
}

pub fn print_rows(rows: &[TenantRow]) {
    println!("Tenants sweep — admission policies over the hierarchy depth ladder");
    println!(
        "{:<16} {:<16} {:>5} {:>3} {:>5} {:>6} {:>6} {:>12} {:>10} {:>10} {:>6} {:>6}",
        "tree", "policy", "w", "lvl", "jobs", "admit", "defer", "makespan", "p50", "p99",
        "jain", "util%"
    );
    for r in rows {
        println!(
            "{:<16} {:<16} {:>5} {:>3} {:>5} {:>6} {:>6} {:>12} {:>10} {:>10} {:>6.3} {:>6.1}",
            r.tree,
            r.policy,
            r.workers,
            r.levels,
            r.jobs,
            r.admitted,
            r.deferrals,
            r.makespan,
            super::fmt_cycles(r.p50_latency),
            super::fmt_cycles(r.p99_latency),
            r.jain,
            r.util_pct,
        );
    }
    println!();
}

fn json_cycles_array(xs: &[Cycles]) -> String {
    let items: Vec<String> = xs.iter().map(|x| x.to_string()).collect();
    format!("[{}]", items.join(", "))
}

/// Serialize rows as a JSON array (no external deps — values are numbers
/// and fixed identifier strings).
pub fn to_json(rows: &[TenantRow]) -> String {
    let objs: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"tree\": \"{}\", \"policy\": \"{}\", \"workers\": {}, \
                 \"levels\": {}, \"shards\": {}, \"threads\": {}, \
                 \"jobs\": {}, \"tenants\": {}, \"admitted\": {}, \
                 \"deferrals\": {}, \"makespan\": {}, \"p50_latency\": {}, \
                 \"p99_latency\": {}, \"jain\": {:.4}, \"util_pct\": {:.2}, \
                 \"tenant_p50\": {}, \"tenant_p99\": {}, \"events\": {}}}",
                r.tree,
                r.policy,
                r.workers,
                r.levels,
                r.shards,
                r.threads,
                r.jobs,
                r.tenants,
                r.admitted,
                r.deferrals,
                r.makespan,
                r.p50_latency,
                r.p99_latency,
                r.jain,
                r.util_pct,
                json_cycles_array(&r.tenant_p50),
                json_cycles_array(&r.tenant_p99),
                r.events,
            )
        })
        .collect();
    super::json_array(&objs)
}

pub fn emit_json(rows: &[TenantRow], path: &str) -> std::io::Result<()> {
    std::fs::write(path, to_json(rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_tree() -> TreePoint {
        TreePoint { name: "two-level-16", workers: 16, spec: HierarchySpec::two_level(4) }
    }

    #[test]
    fn every_policy_admits_and_drains_everything() {
        for p in policies() {
            let r = run_one(&small_tree(), &traffic_for(8, 2, p), 1);
            assert_eq!(r.admitted, 8, "{}: all jobs eventually admitted", r.policy);
            assert!(r.p99_latency >= r.p50_latency);
            assert!(r.jain > 0.0 && r.jain <= 1.0 + 1e-9);
            assert_eq!(r.tenant_p50.len(), 2);
        }
    }

    #[test]
    fn admit_all_never_defers_and_caps_do() {
        let all = run_one(&small_tree(), &traffic_for(10, 1, AdmissionKind::AdmitAll), 1);
        assert_eq!(all.deferrals, 0);
        let mut t = traffic_for(10, 1, AdmissionKind::TenantCap);
        t.tenant_cap = 1;
        t.mean_gap = 50_000;
        let cap = run_one(&small_tree(), &t, 1);
        assert!(cap.deferrals > 0, "cap 1 with crammed arrivals must defer");
        assert!(
            cap.p99_latency >= all.p99_latency,
            "backpressure trades tail latency: cap {} vs all {}",
            cap.p99_latency,
            all.p99_latency
        );
    }

    /// The acceptance replay pin: two identically configured sweeps are
    /// identical in every measured field.
    #[test]
    fn double_run_replays_identically() {
        let t = traffic_for(8, 3, AdmissionKind::LoadThreshold);
        let a = run_one(&small_tree(), &t, 1);
        let b = run_one(&small_tree(), &t, 1);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.p50_latency, b.p50_latency);
        assert_eq!(a.p99_latency, b.p99_latency);
        assert_eq!(a.admitted, b.admitted);
        assert_eq!(a.deferrals, b.deferrals);
        assert_eq!(a.events, b.events);
        assert_eq!(a.tenant_p50, b.tenant_p50);
    }

    #[test]
    fn json_shape_is_stable() {
        let rows = vec![run_one(&small_tree(), &traffic_for(6, 2, AdmissionKind::AdmitAll), 1)];
        let j = to_json(&rows);
        assert!(j.starts_with("[\n"));
        assert!(j.trim_end().ends_with(']'));
        for key in [
            "\"policy\"",
            "\"levels\"",
            "\"shards\"",
            "\"threads\"",
            "\"p99_latency\"",
            "\"jain\"",
            "\"util_pct\"",
            "\"tenant_p50\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        assert_eq!(j.matches("{\"tree\"").count(), 1);
    }

    #[test]
    fn depth_ladder_reaches_4096_workers_at_4_levels() {
        let l = depth_ladder();
        let top = l.last().unwrap();
        assert_eq!(top.workers, 4096);
        assert_eq!(top.levels(), 4);
        assert!(l.iter().all(|t| t.levels() >= 2));
    }
}

//! Work-stealing sweep: stealing on/off over fig7/fig8 workload shapes
//! and the skewed-spawn workload, with a machine-readable JSON report.
//!
//! The sweep answers the two questions the rebalance subsystem must get
//! right at once:
//!
//! * **it wins where it should** — on the `skew` workload (a hot-spot
//!   fraction of tasks delegated into one subtree) enabling stealing must
//!   strictly reduce the makespan and raise the load-balance percentage;
//! * **it costs ~nothing where it can't win** — on the already-balanced
//!   fig7/fig8 shapes the steal-enabled run must stay within a few
//!   percent (the protocol's only activity there is occasional
//!   request/deny chatter near the tail).
//!
//! Output: rows on stdout (time, balance, queue-depth high-water, steal
//! request/grant/deny/migration counts) plus `STEAL_sweep.json`. CI
//! smoke-runs the emitter (1 shape x on/off) so it cannot rot.

use crate::apps::skew::{myrmics as skew_myrmics, SkewParams};
use crate::apps::synthetic::{hier_empty, independent, SynthParams};
use crate::config::{HierarchySpec, PlatformConfig, ShardCfg, StealCfg};
use crate::ids::Cycles;
use crate::platform::Platform;

use super::summarize;

/// One (workload, steal on/off) measurement.
#[derive(Clone, Debug)]
pub struct StealRow {
    pub workload: &'static str,
    pub workers: usize,
    /// Engine shards / executor threads the row ran under (from
    /// `MYRMICS_SHARDS`/`MYRMICS_THREADS` or `--threads`; both 1 by
    /// default) — keeps sweep JSON self-describing across engine modes.
    pub shards: usize,
    pub threads: usize,
    pub steal: bool,
    pub threshold: u64,
    pub batch: u32,
    pub time: Cycles,
    pub tasks: u64,
    pub balance_pct: f64,
    pub steal_reqs: u64,
    pub steal_grants: u64,
    pub steal_denies: u64,
    pub tasks_stolen: u64,
    pub ready_hwm: u64,
    pub events: u64,
}

/// Workload shapes the sweep runs.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Shape {
    /// fig7b: independent tasks fanned out over a two-level hierarchy —
    /// already balanced, so stealing must be (near-)free here.
    Fig7Independent,
    /// fig8/12b: nested regions over a deep (3-level) tree — delegation
    /// plus tree routing, still balanced.
    Fig8Deep,
    /// The skewed-spawn adversary: a hot-spot fraction of tasks delegated
    /// into one leaf subtree — what stealing exists to fix.
    Skew,
}

impl Shape {
    pub fn name(&self) -> &'static str {
        match self {
            Shape::Fig7Independent => "fig7-independent",
            Shape::Fig8Deep => "fig8-deep",
            Shape::Skew => "skew",
        }
    }
}

/// Run one workload shape with the given stealing configuration.
pub fn run_one(shape: Shape, workers: usize, tasks: usize, steal: StealCfg) -> StealRow {
    let mut plat = match shape {
        Shape::Fig7Independent => {
            let (reg, main) = independent();
            let leaves = 4.min(workers.max(2));
            let mut cfg = PlatformConfig::new(workers, HierarchySpec::two_level(leaves));
            cfg.policy.steal = steal;
            Platform::build_with(cfg, reg, main, move |w| {
                w.app = Some(Box::new(SynthParams {
                    n_tasks: tasks,
                    task_cycles: 200_000,
                    ..Default::default()
                }));
            })
        }
        Shape::Fig8Deep => {
            let (reg, main) = hier_empty();
            let mut cfg =
                PlatformConfig::new(workers, HierarchySpec { scheds_per_level: vec![1, 2, 4] });
            cfg.policy.steal = steal;
            Platform::build_with(cfg, reg, main, move |w| {
                w.app = Some(Box::new(SynthParams {
                    domains: 4,
                    per_domain: tasks.div_ceil(4),
                    domain_level: 2,
                    task_cycles: 50_000,
                    ..Default::default()
                }));
            })
        }
        Shape::Skew => {
            let (reg, main) = skew_myrmics();
            // Explicit two-level tree with 4 leaf subtrees: `hierarchical`
            // degenerates to flat under 32 workers, and stealing needs
            // siblings to rebalance between.
            let mut cfg = PlatformConfig::new(workers, HierarchySpec::two_level(4));
            cfg.policy.steal = steal;
            Platform::build_with(cfg, reg, main, move |w| {
                w.app = Some(Box::new(SkewParams {
                    tasks,
                    task_cycles: 200_000,
                    hot_pct: 90,
                    groups: 4,
                }));
            })
        }
    };
    let t = plat.run(Some(1 << 44));
    let s = summarize(&plat.eng, t);
    let g = &plat.eng.world.gstats;
    // Same env seam PlatformConfig::new read when the platform above was
    // built — the row records the engine mode it actually ran under.
    let shard = ShardCfg::from_env();
    StealRow {
        workload: shape.name(),
        workers,
        shards: shard.shards.max(1),
        threads: shard.threads.max(1),
        steal: steal.enabled,
        threshold: steal.threshold,
        batch: steal.batch,
        time: t,
        tasks: g.tasks_completed,
        balance_pct: s.balance,
        steal_reqs: g.steal_reqs,
        steal_grants: g.steal_grants,
        steal_denies: g.steal_denies,
        tasks_stolen: g.tasks_stolen,
        ready_hwm: g.ready_queue_hwm,
        events: g.events_processed,
    }
}

/// Run the sweep. `quick` shrinks the workloads; `smoke` runs exactly one
/// shape on/off (CI: exercises the emitter in seconds).
pub fn run(quick: bool, smoke: bool) -> Vec<StealRow> {
    let mut rows = Vec::new();
    let configs = [StealCfg::default(), StealCfg::on()];
    if smoke {
        for steal in configs {
            rows.push(run_one(Shape::Skew, 8, 32, steal));
        }
    } else {
        let (workers, tasks) = if quick { (16, 64) } else { (64, 512) };
        for shape in [Shape::Fig7Independent, Shape::Fig8Deep, Shape::Skew] {
            for steal in configs {
                rows.push(run_one(shape, workers, tasks, steal));
            }
        }
    }
    print_rows(&rows);
    match emit_json(&rows, "STEAL_sweep.json") {
        Ok(()) => println!("wrote STEAL_sweep.json ({} rows)", rows.len()),
        Err(e) => eprintln!("failed to write STEAL_sweep.json: {e}"),
    }
    rows
}

pub fn print_rows(rows: &[StealRow]) {
    println!("Steal sweep — idle-driven rebalance on/off over workload shapes");
    println!(
        "{:<18} {:>4} {:>6} {:>12} {:>9} {:>6} {:>7} {:>7} {:>7} {:>7} {:>8}",
        "workload", "w", "steal", "time", "balance%", "qhwm", "reqs", "grants", "denies",
        "stolen", "tasks"
    );
    for r in rows {
        println!(
            "{:<18} {:>4} {:>6} {:>12} {:>9.1} {:>6} {:>7} {:>7} {:>7} {:>7} {:>8}",
            r.workload,
            r.workers,
            if r.steal { "on" } else { "off" },
            r.time,
            r.balance_pct,
            r.ready_hwm,
            r.steal_reqs,
            r.steal_grants,
            r.steal_denies,
            r.tasks_stolen,
            r.tasks
        );
    }
    println!();
}

/// Serialize rows as a JSON array (no external deps — field values are
/// numbers, booleans and fixed identifier strings).
pub fn to_json(rows: &[StealRow]) -> String {
    let objs: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"workload\": \"{}\", \"workers\": {}, \"shards\": {}, \
                 \"threads\": {}, \"steal\": {}, \
                 \"threshold\": {}, \"batch\": {}, \"time\": {}, \"tasks\": {}, \
                 \"balance_pct\": {:.2}, \"steal_reqs\": {}, \"steal_grants\": {}, \
                 \"steal_denies\": {}, \"tasks_stolen\": {}, \"ready_hwm\": {}, \
                 \"events\": {}}}",
                r.workload,
                r.workers,
                r.shards,
                r.threads,
                r.steal,
                r.threshold,
                r.batch,
                r.time,
                r.tasks,
                r.balance_pct,
                r.steal_reqs,
                r.steal_grants,
                r.steal_denies,
                r.tasks_stolen,
                r.ready_hwm,
                r.events,
            )
        })
        .collect();
    super::json_array(&objs)
}

pub fn emit_json(rows: &[StealRow], path: &str) -> std::io::Result<()> {
    std::fs::write(path, to_json(rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The acceptance criterion, pinned: on the skewed workload stealing
    /// strictly reduces the makespan (and actually migrates tasks).
    #[test]
    fn stealing_strictly_improves_the_skew_workload() {
        let off = run_one(Shape::Skew, 16, 64, StealCfg::default());
        let on = run_one(Shape::Skew, 16, 64, StealCfg::on());
        assert_eq!(off.tasks, on.tasks, "both runs must complete everything");
        assert_eq!(off.tasks_stolen, 0);
        assert!(on.tasks_stolen > 0, "the skew workload must trigger migrations");
        assert!(
            on.time < off.time,
            "stealing must strictly reduce the skew makespan: on {} vs off {}",
            on.time,
            off.time
        );
        assert!(
            on.balance_pct > off.balance_pct,
            "migrations must improve load balance: {:.1}% vs {:.1}%",
            on.balance_pct,
            off.balance_pct
        );
    }

    /// On the already-balanced fig7 shape the steal-enabled run must stay
    /// within 2% of the baseline makespan (the other acceptance bound).
    #[test]
    fn stealing_is_nearly_free_on_balanced_fig7() {
        let off = run_one(Shape::Fig7Independent, 16, 64, StealCfg::default());
        let on = run_one(Shape::Fig7Independent, 16, 64, StealCfg::on());
        assert_eq!(off.tasks, on.tasks);
        let delta = (on.time as f64 - off.time as f64).abs() / off.time as f64;
        assert!(
            delta < 0.02,
            "steal-enabled fig7 drifted {:.2}% (on {} vs off {})",
            100.0 * delta,
            on.time,
            off.time
        );
    }

    /// Disabled stealing is the do-nothing path: no protocol traffic, and
    /// the queue never holds more than the task being dispatched.
    #[test]
    fn disabled_stealing_has_no_protocol_footprint() {
        for shape in [Shape::Fig7Independent, Shape::Fig8Deep, Shape::Skew] {
            let r = run_one(shape, 8, 32, StealCfg::default());
            assert_eq!(r.steal_reqs, 0, "{}: requests with stealing off", r.workload);
            assert_eq!(r.tasks_stolen, 0);
            assert!(r.ready_hwm <= 1, "{}: queue depth {} with stealing off", r.workload, r.ready_hwm);
        }
    }

    #[test]
    fn deep_tree_completes_with_stealing_on() {
        let r = run_one(Shape::Fig8Deep, 16, 32, StealCfg::on());
        assert!(r.tasks > 0);
        assert!(r.time > 0);
    }

    #[test]
    fn json_shape_is_stable() {
        let rows = vec![run_one(Shape::Skew, 8, 16, StealCfg::on())];
        let j = to_json(&rows);
        assert!(j.starts_with("[\n"));
        assert!(j.trim_end().ends_with(']'));
        for key in [
            "\"workload\"",
            "\"shards\"",
            "\"threads\"",
            "\"steal\"",
            "\"time\"",
            "\"tasks_stolen\"",
            "\"ready_hwm\"",
        ] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        assert_eq!(j.matches("{\"workload\"").count(), 1);
    }
}

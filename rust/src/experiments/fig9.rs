//! Fig 9 (time breakdown) and Fig 10 (traffic analysis) for the three
//! qualitative-study kernels: Bitonic (worst), K-Means (medium),
//! Raytrace (best), on their strong-scaling hierarchical runs.

use super::bench::{run_system, workload, Scaling, System, WorkloadRef};
use super::Summary;

#[derive(Clone, Debug)]
pub struct BreakdownRow {
    pub bench: WorkloadRef,
    pub workers: usize,
    pub n_scheds: usize,
    pub summary: Summary,
}

/// The paper's qualitative-study kernels, resolved from the workload
/// table.
pub fn qualitative_benches() -> [WorkloadRef; 3] {
    [workload("bitonic"), workload("kmeans"), workload("raytrace")]
}

pub fn breakdown(bench: WorkloadRef, worker_counts: &[usize]) -> Vec<BreakdownRow> {
    worker_counts
        .iter()
        .filter(|&&w| bench.valid_workers(w))
        .map(|&w| {
            let s = run_system(bench, System::MyrmicsHier, w, Scaling::Strong);
            BreakdownRow { bench, workers: w, n_scheds: s.n_scheds, summary: s }
        })
        .collect()
}

pub fn print_breakdown(rows: &[BreakdownRow]) {
    let mut benches: Vec<WorkloadRef> = rows.iter().map(|r| r.bench).collect();
    benches.dedup();
    for bench in benches {
        println!("Fig 9 — time breakdown: {}", bench.name());
        println!(
            "{:>8} {:>8} | {:>9} {:>9} {:>9} | {:>10}",
            "workers", "(scheds)", "wrk task%", "wrk rt%", "wrk idle%", "sched busy%"
        );
        for r in rows.iter().filter(|r| r.bench == bench) {
            let s = &r.summary;
            println!(
                "{:>8} {:>8} | {:>8.1}% {:>8.1}% {:>8.1}% | {:>9.1}%",
                r.workers,
                format!("({})", r.n_scheds),
                100.0 * s.worker_task_frac,
                100.0 * s.worker_runtime_frac,
                100.0 * s.worker_idle_frac,
                100.0 * s.sched_busy_frac,
            );
        }
        println!();
    }
}

pub fn print_traffic(rows: &[BreakdownRow]) {
    let mut benches: Vec<WorkloadRef> = rows.iter().map(|r| r.bench).collect();
    benches.dedup();
    for bench in benches {
        println!("Fig 10 — traffic per core: {}", bench.name());
        println!(
            "{:>8} {:>8} | {:>12} {:>12} {:>12}",
            "workers", "(scheds)", "wrk msgs", "wrk DMA", "sched msgs"
        );
        for r in rows.iter().filter(|r| r.bench == bench) {
            let s = &r.summary;
            println!(
                "{:>8} {:>8} | {:>12} {:>12} {:>12}",
                r.workers,
                format!("({})", r.n_scheds),
                super::fmt_bytes(s.per_worker_msg_bytes),
                super::fmt_bytes(s.per_worker_dma_bytes),
                super::fmt_bytes(s.per_sched_msg_bytes),
            );
        }
        println!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raytrace_keeps_schedulers_idle() {
        // Paper: raytrace scheduler load is at worst ~6%.
        let rows = breakdown(workload("raytrace"), &[16]);
        assert!(rows[0].summary.sched_busy_frac < 0.25);
        // Workers actually do task work.
        assert!(rows[0].summary.worker_task_frac > 0.3);
    }

    #[test]
    fn bitonic_loads_schedulers_more_than_raytrace() {
        let bt = breakdown(workload("bitonic"), &[16]);
        let rt = breakdown(workload("raytrace"), &[16]);
        assert!(
            bt[0].summary.sched_busy_frac > rt[0].summary.sched_busy_frac,
            "bitonic {:.3} vs raytrace {:.3}",
            bt[0].summary.sched_busy_frac,
            rt[0].summary.sched_busy_frac
        );
    }

    #[test]
    fn scheduler_traffic_grows_with_workers() {
        let rows = breakdown(workload("kmeans"), &[4, 32]);
        assert!(rows[1].summary.per_sched_msg_bytes > rows[0].summary.per_sched_msg_bytes);
    }
}

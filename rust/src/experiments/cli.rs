//! Experiment driver shared by the `figures` bench and the `myrmics`
//! CLI binary: runs the selected experiments and prints paper-style rows.

use super::bench::{all_workloads, workload, Scaling};
use super::{fig11, fig12, fig7, fig8, fig9, fuzz, policy, steal, tenants};

/// `args`: experiment names (empty = all paper figures) plus optional
/// `--quick` / `--smoke` (smoke applies to the `policy`/`steal`/`tenants`
/// sweeps and the `fuzz` harness: tiny configurations for CI checks). The
/// `fuzz` harness additionally takes value flags — `--seeds N`,
/// `--soak MINUTES`, and `--seed X [--plan Y]` to reproduce one case —
/// which are consumed here so their values never masquerade as
/// experiment names.
pub fn run(args: &[String]) {
    let quick = args.iter().any(|a| a == "--quick");
    let smoke = args.iter().any(|a| a == "--smoke");
    let mut picks: Vec<&str> = Vec::new();
    let mut fuzz_cases: Option<usize> = None;
    let mut fuzz_soak_secs: u64 = 0;
    let mut fuzz_seed: Option<u64> = None;
    let mut fuzz_plan: Option<u64> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--seeds" => fuzz_cases = it.next().and_then(|v| v.parse().ok()),
            "--soak" => {
                let mins: u64 = it.next().and_then(|v| v.parse().ok()).unwrap_or(0);
                fuzz_soak_secs = mins * 60;
            }
            "--seed" => fuzz_seed = it.next().and_then(|v| v.parse().ok()),
            "--plan" => fuzz_plan = it.next().and_then(|v| v.parse().ok()),
            s if s.starts_with("--") => {}
            s => picks.push(s),
        }
    }
    let want = |name: &str| picks.is_empty() || picks.contains(&name);


    let workers_full: &[usize] = &[1, 4, 16, 64, 128, 256, 512];
    let workers_quick: &[usize] = &[1, 4, 16, 64];
    let workers = if quick { workers_quick } else { workers_full };

    if want("fig7a") {
        fig7::print_fig7a(&fig7::fig7a(1000));
    }
    if want("fig7b") {
        let wc: &[usize] = if quick { &[1, 8, 32, 64] } else { &[1, 8, 32, 64, 128, 256, 512] };
        let sizes: &[u64] = if quick {
            &[100_000, 1_000_000]
        } else {
            &[100_000, 400_000, 1_000_000, 4_000_000, 16_000_000]
        };
        let n = if quick { 128 } else { 512 };
        let pts = fig7::granularity(n, wc, sizes, true);
        fig7::print_granularity(&pts, "Fig 7b — task granularity (A9 scheduler)");
    }
    for (scaling, tag) in [(Scaling::Strong, "fig8-strong"), (Scaling::Weak, "fig8-weak")] {
        if !(want(tag) || (scaling == Scaling::Strong && want("overhead"))) {
            continue;
        }
        let mut all = Vec::new();
        for bench in all_workloads() {
            let pts = fig8::scaling_curves(bench, scaling, workers);
            fig8::print_curves(&pts, scaling);
            all.extend(pts);
        }
        if scaling == Scaling::Strong {
            fig8::print_overheads(&fig8::overhead_table(&all));
        }
    }
    if want("fig9") || want("fig10") {
        let wc: &[usize] = if quick { &[4, 16, 64] } else { &[4, 16, 64, 128, 256, 512] };
        for bench in fig9::qualitative_benches() {
            let rows = fig9::breakdown(bench, wc);
            if want("fig9") {
                fig9::print_breakdown(&rows);
            }
            if want("fig10") {
                fig9::print_traffic(&rows);
            }
        }
    }
    if want("fig11") {
        let ps: &[u32] = if quick { &[100, 50, 20, 0] } else { &[100, 80, 60, 40, 20, 10, 0] };
        let configs = if quick {
            vec![(workload("matmul"), 16usize, false)]
        } else {
            fig11::paper_configs().to_vec()
        };
        for (bench, w, hier) in configs {
            fig11::print_sweep(&fig11::sweep(bench, w, hier, ps));
        }
    }
    if want("fig12a") {
        let wc: &[usize] = if quick { &[1, 8, 32] } else { &[1, 8, 32, 64, 128, 256] };
        let sizes: &[u64] =
            if quick { &[400_000] } else { &[100_000, 400_000, 1_000_000, 4_000_000] };
        let n = if quick { 128 } else { 512 };
        let pts = fig12::fig12a(n, wc, sizes);
        fig12::print_granularity(&pts, "Fig 12a — task granularity (MicroBlaze scheduler)");
    }
    if want("fig12b") {
        let wc: &[usize] = if quick { &[12, 36, 72] } else { &[12, 36, 72, 144, 216, 438] };
        let pts = fig12::fig12b(wc, &[1, 2, 3], 8);
        fig12::print_fig12b(&pts, wc);
    }
    if want("policy") {
        policy::run(quick, smoke);
    }
    if want("steal") {
        steal::run(quick, smoke);
    }
    if want("tenants") {
        tenants::run(quick, smoke);
    }
    // The fuzz harness only runs when explicitly picked: it is a
    // robustness gate, not a paper figure, so the bare `myrmics exp`
    // figure regeneration skips it. A failing case makes the whole
    // invocation exit nonzero (the blocking CI contract).
    if picks.contains(&"fuzz") {
        let opts = fuzz::FuzzOpts {
            cases: fuzz_cases.unwrap_or(if smoke {
                8
            } else if quick {
                24
            } else {
                64
            }),
            soak_secs: fuzz_soak_secs,
            fixed: fuzz_seed.map(|s| (s, fuzz_plan.unwrap_or(0))),
        };
        if !fuzz::run(&opts) {
            std::process::exit(1);
        }
    }
}

pub const EXPERIMENTS: &[&str] = &[
    "fig7a", "fig7b", "fig8-strong", "fig8-weak", "overhead", "fig9", "fig10", "fig11",
    "fig12a", "fig12b", "policy", "steal", "tenants", "fuzz",
];

//! One harness per paper figure/table (see DESIGN.md 6).
//!
//! Every harness returns plain row structs and provides a `print_*`
//! function emitting the same series the paper plots; `cargo bench
//! --bench figures` regenerates everything.

pub mod bench;
pub mod cli;
pub mod fig11;
pub mod fig12;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod fuzz;
pub mod policy;
pub mod steal;
pub mod tenants;

use crate::ids::Cycles;
use crate::sim::engine::Engine;

/// Aggregated per-run metrics backing Figs 8-11.
#[derive(Clone, Debug)]
pub struct Summary {
    pub time: Cycles,
    pub n_workers: usize,
    pub n_scheds: usize,
    /// Average worker time fractions (Fig 9 left bars).
    pub worker_task_frac: f64,
    pub worker_runtime_frac: f64,
    pub worker_idle_frac: f64,
    /// Average scheduler busy fraction (Fig 9 right bars).
    pub sched_busy_frac: f64,
    /// Average traffic per core (Fig 10): message and DMA bytes.
    pub per_worker_msg_bytes: f64,
    pub per_worker_dma_bytes: f64,
    pub per_sched_msg_bytes: f64,
    pub tasks_completed: u64,
    /// Load balance % (Fig 11): 100 = perfectly even task counts,
    /// 0 = one worker ran everything.
    pub balance: f64,
    pub total_dma_bytes: u64,
}

/// Extract a [`Summary`] from a finished Myrmics engine.
pub fn summarize(eng: &Engine, time: Cycles) -> Summary {
    let hier = &eng.world.hier;
    let n_workers = hier.n_workers;
    let n_scheds = hier.n_scheds;
    let mut wt = 0.0;
    let mut wr = 0.0;
    let mut wmsg = 0.0;
    let mut wdma = 0.0;
    let mut tasks: Vec<u64> = Vec::new();
    let mut total_dma = 0u64;
    let mut smsg = 0.0;
    let mut sbusy = 0.0;
    for (i, st) in eng.sim.stats.iter().enumerate() {
        let core = crate::ids::CoreId(i as u32);
        total_dma += st.dma_bytes_in;
        if i >= hier.n_cores() {
            continue;
        }
        if hier.is_sched(core) {
            sbusy += (st.busy().min(time)) as f64 / time.max(1) as f64;
            smsg += (st.msg_bytes_sent + st.msg_bytes_recv) as f64;
        } else {
            wt += st.task_frac(time);
            wr += st.runtime_frac(time);
            wmsg += (st.msg_bytes_sent + st.msg_bytes_recv) as f64;
            wdma += (st.dma_bytes_in + st.dma_bytes_out) as f64;
            tasks.push(st.tasks_run);
        }
    }
    let w = n_workers.max(1) as f64;
    let s = n_scheds.max(1) as f64;
    let total_tasks: u64 = tasks.iter().sum();
    let mean = total_tasks as f64 / w;
    let dev: f64 = tasks.iter().map(|&t| (t as f64 - mean).abs()).sum();
    let worst = 2.0 * total_tasks as f64 * (1.0 - 1.0 / w);
    let balance = if worst > 0.0 { 100.0 * (1.0 - dev / worst) } else { 100.0 };
    Summary {
        time,
        n_workers,
        n_scheds,
        worker_task_frac: wt / w,
        worker_runtime_frac: wr / w,
        worker_idle_frac: (1.0 - wt / w - wr / w).max(0.0),
        sched_busy_frac: sbusy / s,
        per_worker_msg_bytes: wmsg / w,
        per_worker_dma_bytes: wdma / w,
        per_sched_msg_bytes: smsg / s,
        tasks_completed: eng.world.gstats.tasks_completed,
        balance,
        total_dma_bytes: total_dma,
    }
}

/// Render pre-formatted JSON object strings as one JSON array document
/// (two-space indent, no trailing comma, trailing newline). Shared by the
/// machine-readable report emitters (`experiments::policy`, the hotpath
/// bench) so the array framing cannot drift between them; callers remain
/// responsible for their rows containing no characters needing escaping.
pub fn json_array(rows: &[String]) -> String {
    let mut s = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str("  ");
        s.push_str(r);
        s.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    s.push_str("]\n");
    s
}

/// Format cycles as M/K for table output.
pub fn fmt_cycles(c: Cycles) -> String {
    if c >= 10_000_000 {
        format!("{:.1}M", c as f64 / 1e6)
    } else if c >= 10_000 {
        format!("{:.1}K", c as f64 / 1e3)
    } else {
        format!("{c}")
    }
}

/// Format bytes with units (Fig 10 is plotted in bytes, log scale).
pub fn fmt_bytes(b: f64) -> String {
    if b >= 1e6 {
        format!("{:.1}MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1}KB", b / 1e3)
    } else {
        format!("{b:.0}B")
    }
}

//! Fig 8: strong (a-f) and weak (g-l) scaling of the six benchmarks,
//! MPI vs Myrmics-flat vs Myrmics-hierarchical; plus the VI-B headline
//! overhead table (Myrmics 10-30% over MPI at well-scaling points).

use super::bench::{run_system, Scaling, System, WorkloadRef};
use crate::ids::Cycles;

#[derive(Clone, Debug)]
pub struct ScalePoint {
    pub bench: WorkloadRef,
    pub system: System,
    pub workers: usize,
    pub time: Cycles,
    /// Strong: speedup vs this system's 1-worker run.
    /// Weak: slowdown vs this system's 1-worker run.
    pub rel: f64,
}

pub const PAPER_WORKER_COUNTS: [usize; 7] = [1, 4, 16, 64, 128, 256, 512];

/// Run one benchmark's scaling curves for all three systems.
pub fn scaling_curves(
    bench: WorkloadRef,
    scaling: Scaling,
    worker_counts: &[usize],
) -> Vec<ScalePoint> {
    let mut out = Vec::new();
    for system in [System::Mpi, System::MyrmicsFlat, System::MyrmicsHier] {
        let mut t1: Option<Cycles> = None;
        for &w in worker_counts {
            if !bench.valid_workers(w) {
                continue;
            }
            let s = run_system(bench, system, w, scaling);
            let base = *t1.get_or_insert(s.time);
            let rel = match scaling {
                Scaling::Strong => base as f64 / s.time as f64,
                Scaling::Weak => s.time as f64 / base as f64,
            };
            out.push(ScalePoint { bench, system, workers: w, time: s.time, rel });
        }
    }
    out
}

/// The VI-B headline: Myrmics-vs-MPI overhead at each worker count.
#[derive(Clone, Debug)]
pub struct OverheadPoint {
    pub bench: WorkloadRef,
    pub workers: usize,
    pub overhead_pct: f64,
}

pub fn overhead_table(points: &[ScalePoint]) -> Vec<OverheadPoint> {
    let mut out = Vec::new();
    for p in points.iter().filter(|p| p.system == System::MyrmicsHier) {
        if let Some(mpi) = points
            .iter()
            .find(|q| q.system == System::Mpi && q.workers == p.workers && q.bench == p.bench)
        {
            out.push(OverheadPoint {
                bench: p.bench,
                workers: p.workers,
                overhead_pct: 100.0 * (p.time as f64 / mpi.time as f64 - 1.0),
            });
        }
    }
    out
}

fn sys_name(s: System) -> &'static str {
    match s {
        System::Mpi => "MPI",
        System::MyrmicsFlat => "myrmics-flat",
        System::MyrmicsHier => "myrmics-hier",
    }
}

pub fn print_curves(points: &[ScalePoint], scaling: Scaling) {
    let label = match scaling {
        Scaling::Strong => "speedup",
        Scaling::Weak => "slowdown",
    };
    let mut benches: Vec<WorkloadRef> = points.iter().map(|p| p.bench).collect();
    benches.dedup();
    for bench in benches {
        println!("Fig 8 ({label}) — {}", bench.name());
        let mut workers: Vec<usize> = points
            .iter()
            .filter(|p| p.bench == bench)
            .map(|p| p.workers)
            .collect();
        workers.sort_unstable();
        workers.dedup();
        print!("{:<14}", "system");
        for w in &workers {
            print!("{w:>8}");
        }
        println!();
        for system in [System::Mpi, System::MyrmicsFlat, System::MyrmicsHier] {
            print!("{:<14}", sys_name(system));
            for w in &workers {
                match points.iter().find(|p| {
                    p.bench == bench && p.system == system && p.workers == *w
                }) {
                    Some(p) => print!("{:>8.2}", p.rel),
                    None => print!("{:>8}", "-"),
                }
            }
            println!();
        }
        println!();
    }
}

pub fn print_overheads(rows: &[OverheadPoint]) {
    println!("VI-B headline — Myrmics(hier) execution-time overhead vs MPI (%)");
    println!("{:<12} {:>8} {:>10}", "bench", "workers", "overhead");
    for r in rows {
        println!("{:<12} {:>8} {:>9.1}%", r.bench.name(), r.workers, r.overhead_pct);
    }
    println!("paper: typically 10-30% at points that scale well\n");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::workload_api::workload;

    #[test]
    fn strong_scaling_shape_jacobi() {
        let pts = scaling_curves(workload("jacobi"), Scaling::Strong, &[1, 8, 32]);
        // MPI scales near-perfectly.
        let mpi32 = pts
            .iter()
            .find(|p| p.system == System::Mpi && p.workers == 32)
            .unwrap();
        assert!(mpi32.rel > 24.0, "MPI speedup at 32: {:.1}", mpi32.rel);
        // Hierarchical Myrmics scales too, within the overhead budget.
        let hier32 = pts
            .iter()
            .find(|p| p.system == System::MyrmicsHier && p.workers == 32)
            .unwrap();
        assert!(hier32.rel > 12.0, "Myrmics-hier speedup at 32: {:.1}", hier32.rel);
    }

    #[test]
    fn overhead_in_paper_band_at_moderate_scale() {
        let pts = scaling_curves(workload("raytrace"), Scaling::Strong, &[1, 16]);
        let over = overhead_table(&pts);
        let at16 = over.iter().find(|o| o.workers == 16).unwrap();
        assert!(
            at16.overhead_pct > -5.0 && at16.overhead_pct < 60.0,
            "overhead {:.1}%",
            at16.overhead_pct
        );
    }
}

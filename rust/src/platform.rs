//! Platform assembly: the shared functional world + the builder that wires
//! cores, logic, topology and the boot task together.
//!
//! The `World` is the single-process home of all *functional* state
//! (memory metadata, dependency forest, task table, data store). Ownership
//! discipline replaces physical distribution: every region, dependency
//! node and task entry has exactly one owning scheduler, and scheduler
//! logic only mutates what it owns — all cross-owner steps are explicit
//! NoC messages whose latency and processing costs the simulation charges.
//! This keeps the *algorithms* (the paper's contribution) faithful while
//! the silicon is simulated (see DESIGN.md 1).

use std::any::Any;

use crate::api::ctx::TaskCtx;
use crate::config::{CoreKind, PlatformConfig};
use crate::dep::analysis::DepState;
use crate::ids::{CoreId, Cycles, JobId, NodeId, RegionId, TaskId};
use crate::memory::region::Memory;
use crate::memory::store::DataStore;
use crate::noc::msg::Msg;
use crate::noc::topology::Topology;
use crate::sched::hierarchy::HierarchyMap;
use crate::sched::scheduler::{Journal, SchedLogic};
use crate::sched::worker::WorkerLogic;
use crate::sim::engine::{Engine, SimState};
use crate::sim::event::{Event, TimerKind};
use crate::sim::traffic::TrafficState;
use crate::sim::rng::Rng;
use crate::stats::metrics::GStats;
use crate::task::descriptor::{TaskArg, TaskDesc};
use crate::task::registry::{Registry, TaskRef};
use crate::task::table::{TaskState, TaskTable};

/// Shared functional state of a run.
pub struct World {
    pub cfg: PlatformConfig,
    pub hier: HierarchyMap,
    pub mem: Memory,
    pub dep: DepState,
    pub tasks: TaskTable,
    pub store: DataStore,
    /// Durable reentrant-request tables (pack aggregations, spawn
    /// rendezvous, wait counts), keyed by globally unique ids. World-level
    /// so crash recovery can serve a reply that surfaces from a dead
    /// scheduler's re-adopted mailbox — see [`Journal`].
    pub journal: Journal,
    /// Run-wide counters behind a sharding facade: plain
    /// `GlobalStats` field access everywhere (auto-deref), but under the
    /// threaded executor each worker thread is routed to its own
    /// `WorldShard` accumulator slot, reduced into the main struct at
    /// every quiescence point.
    pub gstats: GStats,
    pub rng: Rng,
    /// Loaded PJRT kernels for `Real` compute mode (`None` = modeled).
    pub kernels: Option<crate::runtime::engine::KernelEngine>,
    /// Benchmark-specific shared state (downcast by task bodies).
    pub app: Option<Box<dyn Any>>,
    /// Mini-MPI collective rendezvous state (baseline runs only).
    pub mpi: Option<crate::mpi::rank::MpiShared>,
    /// Multi-tenant traffic layer: the seed-deterministic job arrival
    /// schedule plus per-job/per-tenant books. `None` (the default) means
    /// the layer does not exist — single-job runs stay byte-identical.
    /// Installed by the `prime` closure (see `experiments::tenants`).
    pub traffic: Option<TrafficState>,
    /// The workload's prime closure asserts the *single-spawner
    /// contract*: all world-level growth (task spawns, region creation)
    /// is driven from one scheduler subtree per object, so shard-local
    /// mutation plus the ownership discipline's message seam covers every
    /// cross-shard effect. Required (with an eligible configuration — see
    /// `Engine::par_eligible`) before the threaded sharded executor may
    /// run; `false` (the default) always takes the sequential merge.
    pub par_safe: bool,
    pub done: bool,
}

impl World {
    pub fn new(cfg: PlatformConfig) -> Self {
        let hier = HierarchyMap::build(cfg.n_workers, &cfg.hierarchy);
        let mem = Memory::new(hier.n_scheds);
        World {
            rng: Rng::new(cfg.seed),
            cfg,
            hier,
            mem,
            dep: DepState::new(),
            tasks: TaskTable::new(),
            store: DataStore::new(),
            journal: Journal::default(),
            gstats: GStats::default(),
            kernels: None,
            app: None,
            mpi: None,
            traffic: None,
            par_safe: false,
            done: false,
        }
    }

    /// Minimal world for engine-level unit tests.
    pub fn for_tests(cfg: PlatformConfig) -> Self {
        Self::new(cfg)
    }

    /// Downcast the app state.
    pub fn app_mut<T: 'static>(&mut self) -> &mut T {
        self.app
            .as_mut()
            .expect("no app state installed")
            .downcast_mut::<T>()
            .expect("app state type mismatch")
    }

    pub fn app_ref<T: 'static>(&self) -> &T {
        self.app
            .as_ref()
            .expect("no app state installed")
            .downcast_ref::<T>()
            .expect("app state type mismatch")
    }
}

/// A fully wired simulation ready to run.
pub struct Platform {
    pub eng: Engine,
    pub main_task: TaskId,
}

impl Platform {
    /// Build a platform: schedulers and workers in their tree, the main
    /// task pre-granted on the root region and dispatched to worker 0.
    pub fn build(cfg: PlatformConfig, registry: Registry, main_fn: TaskRef) -> Self {
        Self::build_with(cfg, registry, main_fn, |_| {})
    }

    /// Like [`Platform::build`] but lets the caller prime the world
    /// (install app state, seed real data, attach kernels) before boot.
    pub fn build_with(
        cfg: PlatformConfig,
        registry: Registry,
        main_fn: TaskRef,
        prime: impl FnOnce(&mut World),
    ) -> Self {
        let mut world = World::new(cfg.clone());
        prime(&mut world);
        let n_cores = world.hier.n_cores();
        let kinds: Vec<CoreKind> = (0..n_cores)
            .map(|i| {
                if world.hier.is_sched(CoreId(i as u32)) {
                    if cfg.hetero {
                        CoreKind::CortexA9
                    } else {
                        CoreKind::MicroBlaze
                    }
                } else {
                    CoreKind::MicroBlaze
                }
            })
            .collect();
        let mut sim = SimState::new(
            kinds,
            Topology::new(n_cores),
            cfg.cost.clone(),
            cfg.channel_capacity,
        );
        // Sharding must be installed before the first push or preseed so
        // every event and channel lands in its shard-local structure from
        // the start. `shards=1` (the default) leaves the legacy
        // single-queue engine untouched.
        let part = world.hier.shard_partition(cfg.shard.shards);
        sim.install_sharding(&part, cfg.shard.lookahead_override);
        sim.set_shard_threads(cfg.shard.threads);
        // Pre-seed the channel table with the scheduler-tree links
        // (parent <-> child, leaf <-> worker): messages flow strictly
        // along the tree, so these hot edges get contiguous slots at the
        // front of the channel pool before any dynamic peer appears.
        for s in 0..world.hier.n_scheds {
            let sc = world.hier.sched_core(s);
            if let Some(p) = world.hier.parent[s] {
                let pc = world.hier.sched_core(p);
                sim.preseed_channel(sc, pc);
                sim.preseed_channel(pc, sc);
            }
            for &w in &world.hier.leaf_workers[s] {
                sim.preseed_channel(sc, w);
                sim.preseed_channel(w, sc);
            }
        }
        // Deterministic fault injection: a disabled plan (the default) is
        // a no-op and keeps the engine byte-identical to the pre-chaos
        // schedule.
        sim.install_chaos(&cfg.chaos, cfg.seed);
        // Decorrelated per-shard chaos lanes: each shard draws from its
        // own stream (run seed, plan seed, shard id) so threaded workers
        // never contend on one RNG. Installed even at `threads=1` — the
        // sharded sequential merge uses the same lanes, which is what
        // keeps `threads` out of the RNG schedule entirely.
        sim.chaos.set_shards(sim.n_shards());
        // Deterministic scheduler crash: derived from (run seed, plan),
        // leaf victims only, and only when both the plan and the recovery
        // protocol are on — a crash without the protocol would simply
        // wedge the run, which is not an interesting configuration.
        if cfg.recovery.enabled && cfg.chaos.enabled {
            let eligible = world.hier.crash_eligible();
            if let Some(cs) = cfg.chaos.crash_schedule(cfg.seed, &eligible) {
                sim.install_crash(world.hier.sched_core(cs.victim), cs.at, cs.up_at);
            }
        }

        // Main task: holds the root region read-write, responsible
        // scheduler = top level, dispatched to worker 0.
        let main_desc = TaskDesc::new(main_fn.index(), vec![TaskArg::region_inout(RegionId::ROOT)]);
        let main_task = world.tasks.create(main_desc, None, 0, 0);
        world.gstats.tasks_spawned += 1;
        {
            let mem = &world.mem;
            let root = world.dep.node_mut(NodeId::Region(RegionId::ROOT), mem);
            root.enqueue_granted(main_task, 0, crate::task::descriptor::Access::Write);
        }
        let e = world.tasks.get_mut(main_task);
        e.deps_pending = 0;
        e.state = TaskState::Dispatched;
        let first_worker = world
            .hier
            .leaf_workers
            .iter()
            .find(|ws| !ws.is_empty())
            .expect("platform has no workers")[0];
        world.tasks.get_mut(main_task).worker = Some(first_worker);

        let mut eng = Engine::new(sim, world, registry);
        // Wire logic.
        for s in 0..eng.world.hier.n_scheds {
            let core = eng.world.hier.sched_core(s);
            let logic = Box::new(SchedLogic::new(s, core, &eng.world.hier, &eng.world.cfg));
            eng.set_logic(core, logic);
        }
        for s in 0..eng.world.hier.n_scheds {
            for w in eng.world.hier.leaf_workers[s].clone() {
                let leaf_core = eng.world.hier.sched_core(s);
                eng.set_logic(w, Box::new(WorkerLogic::new(w, leaf_core)));
            }
        }
        // Boot: deliver the main-task dispatch to the first worker. The
        // push bypasses the credit channel, so the receiver-side release
        // on that link legitimately finds no in-flight credit — mark it
        // so debug builds don't flag the no-op as a double release.
        let top = eng.world.hier.top_core();
        eng.sim.expect_uncredited(top, first_worker);
        eng.sim.push(
            0,
            first_worker,
            Event::Msg { from: top, dst: first_worker, msg: Msg::Dispatch { task: main_task } },
        );
        // Recovery on: seed a Boot on every probing (non-leaf) scheduler
        // so the heartbeat chains arm at t=0. Recovery off: zero extra
        // events — the pre-recovery schedule stays byte-identical.
        if eng.world.cfg.recovery.enabled {
            for s in 0..eng.world.hier.n_scheds {
                if !eng.world.hier.children[s].is_empty() {
                    let core = eng.world.hier.sched_core(s);
                    eng.sim.push(0, core, Event::Boot);
                }
            }
        }
        // Traffic: pre-push every job's open-loop arrival timer on its
        // entry scheduler. The schedule (installed by `prime`) was drawn
        // entirely at build time, so the pushes are identical across
        // shard counts and replay runs; `traffic == None` (the default)
        // pushes nothing and keeps the event schedule byte-identical.
        if let Some(tr) = eng.world.traffic.as_ref() {
            for (i, j) in tr.jobs.iter().enumerate() {
                let tag = crate::sim::traffic::arrive_tag(JobId(i as u32));
                eng.sim.push(
                    j.submit_at,
                    eng.world.hier.sched_core(j.entry),
                    Event::Timer(TimerKind::Custom(tag)),
                );
            }
        }
        Platform { eng, main_task }
    }

    /// Run to completion (or the optional cycle limit). Returns the final
    /// virtual time.
    pub fn run(&mut self, limit: Option<Cycles>) -> Cycles {
        self.eng.run(limit);
        self.eng.sim.now = self.eng.sim.horizon();
        self.eng.sim.now
    }

    /// Run past completion until the event queue fully drains, so strict
    /// quiescence invariants (credits restored, books exactly zero) hold
    /// — the mode the fuzz harness checks its oracles in. See
    /// [`Engine::run_to_quiescence`].
    pub fn run_to_quiescence(&mut self, limit: Option<Cycles>) -> Cycles {
        self.eng.run_to_quiescence(limit);
        self.eng.sim.now = self.eng.sim.horizon();
        self.eng.sim.now
    }

    pub fn world(&self) -> &World {
        &self.eng.world
    }

    /// Convenience: register everything, build, run, return (time, world).
    pub fn run_app(
        cfg: PlatformConfig,
        registry: Registry,
        main_fn: TaskRef,
        prime: impl FnOnce(&mut World),
    ) -> (Cycles, Engine) {
        let mut p = Platform::build_with(cfg, registry, main_fn, prime);
        let t = p.run(Some(1_u64 << 42));
        (t, p.eng)
    }
}

/// Helper used by scheduler/worker logic to run a task body eagerly and
/// collect its op list (see `api::ctx` for the replay model).
pub fn run_task_body(
    world: &mut World,
    registry: &Registry,
    task: TaskId,
    worker: CoreId,
    phase: u32,
) -> Vec<crate::api::ctx::TaskOp> {
    // Share the descriptor with the task table (Arc bump) and borrow the
    // body from the registry: the dispatch path allocates nothing.
    let desc = world.tasks.get(task).desc.clone();
    let f = registry.get(desc.func);
    let mut tctx = TaskCtx::new(world, task, worker, phase, desc);
    f(&mut tctx);
    tctx.into_ops()
}

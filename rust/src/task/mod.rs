//! Task descriptors, the function registry and the task table.
pub mod descriptor;
pub mod registry;
pub mod table;

//! The task function table.
//!
//! `sys_spawn` names tasks by "an index to a table of function pointers"
//! (paper V-A). Applications register their task bodies here before the
//! platform boots; workers look bodies up by index when a dispatch
//! arrives.

use std::rc::Rc;

use crate::api::ctx::TaskCtx;

pub type TaskFn = Rc<dyn Fn(&mut TaskCtx<'_>)>;

#[derive(Default)]
pub struct Registry {
    fns: Vec<(String, TaskFn)>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a task body; returns its function-table index.
    pub fn register(&mut self, name: &str, f: impl Fn(&mut TaskCtx<'_>) + 'static) -> usize {
        self.fns.push((name.to_string(), Rc::new(f)));
        self.fns.len() - 1
    }

    pub fn get(&self, idx: usize) -> TaskFn {
        self.fns[idx].1.clone()
    }

    pub fn name(&self, idx: usize) -> &str {
        &self.fns[idx].0
    }

    pub fn len(&self) -> usize {
        self.fns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fns.is_empty()
    }
}

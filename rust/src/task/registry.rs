//! The task function table.
//!
//! `sys_spawn` names tasks by "an index to a table of function pointers"
//! (paper V-A) — that raw index remains the wire format inside
//! [`TaskDesc`](crate::task::descriptor::TaskDesc). Application code,
//! however, only ever sees the typed [`TaskRef`] handle returned by
//! [`Registry::register`]: spawn sites pass it to
//! `TaskCtx::spawn_task`, which lowers it back to the index. Workers look
//! bodies up by index when a dispatch arrives.

use crate::api::ctx::TaskCtx;

pub type TaskFn = Box<dyn Fn(&mut TaskCtx<'_>)>;

/// Typed handle to a registered task body. This is what spawn sites name
/// tasks by; the underlying function-table index is the Fig-4 wire
/// representation and stays out of application code.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct TaskRef(usize);

impl TaskRef {
    /// The wire-format function-table index (`TaskDesc::func`).
    pub fn index(self) -> usize {
        self.0
    }

    /// Wire-level escape hatch (dispatch internals and tests). Normal
    /// code receives `TaskRef`s from [`Registry::register`].
    pub fn from_index(idx: usize) -> Self {
        TaskRef(idx)
    }
}

#[derive(Default)]
pub struct Registry {
    fns: Vec<(String, TaskFn)>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a task body; returns its typed handle.
    pub fn register(&mut self, name: &str, f: impl Fn(&mut TaskCtx<'_>) + 'static) -> TaskRef {
        self.fns.push((name.to_string(), Box::new(f)));
        TaskRef(self.fns.len() - 1)
    }

    /// Borrow a body by wire index. Dispatch-path accessor: no clone, no
    /// refcount traffic.
    pub fn get(&self, idx: usize) -> &TaskFn {
        &self.fns[idx].1
    }

    pub fn name(&self, idx: usize) -> &str {
        &self.fns[idx].0
    }

    pub fn len(&self) -> usize {
        self.fns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fns.is_empty()
    }
}

//! The task table: every task's lifecycle state.
//!
//! "Each task in Myrmics is assigned to one of the schedulers, which is
//! responsible to monitor it until it retires" (paper V-E). Entries live
//! in one arena; each is *owned* by its responsible scheduler, which is
//! the only core that mutates it (the worker running the task mutates only
//! through messages to that scheduler).

use std::sync::Arc;

use crate::arena::SlotArena;
use crate::ids::{CoreId, Cycles, JobId, TaskId};
use crate::noc::msg::ProducerRange;
use crate::task::descriptor::TaskDesc;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TaskState {
    /// Created; dependency analysis in flight.
    DepWait,
    /// All arguments granted; packing in flight.
    Packing,
    /// Packed; parked in a scheduler's ready queue awaiting dispatch —
    /// the only state in which a task is migratable by work stealing.
    Queued,
    /// Packed; placement descent in flight.
    Placing,
    /// Sent to a worker; queued or fetching arguments there.
    Dispatched,
    /// Body executing on the worker.
    Running,
    /// Suspended in `sys_wait`.
    Waiting,
    Done,
}

#[derive(Debug)]
pub struct TaskEntry {
    pub id: TaskId,
    /// Shared descriptor: the scheduler lifecycle (spawn -> ready -> place
    /// -> done) reads it from several borrow scopes, so it is reference-
    /// counted — "cloning" it to escape a borrow is a pointer bump, not a
    /// deep copy of the argument vector.
    pub desc: Arc<TaskDesc>,
    pub parent: Option<TaskId>,
    /// Responsible scheduler index.
    pub resp: usize,
    /// Scheduler index whose `ReadyQ` currently holds this task (valid
    /// only while `state == Queued`). Distinct from `resp`: a task placed
    /// down the tree queues at a descendant while dependency
    /// responsibility stays put. Crash recovery scans on it to find tasks
    /// stranded in a dead scheduler's volatile queue, and dispatch
    /// validates it before placing — a queue entry whose task was
    /// re-adopted elsewhere is a stale lease and is dropped.
    pub queued_at: usize,
    pub state: TaskState,
    /// Dependency-pending argument count (granted when it hits zero).
    pub deps_pending: usize,
    /// Packing result: coalesced ranges grouped by last producer.
    pub pack: Vec<ProducerRange>,
    /// Worker the task was dispatched to.
    pub worker: Option<CoreId>,
    /// Current `sys_wait` phase (0 = first run of the body).
    pub phase: u32,
    /// Placement generation. Bumped when crash recovery re-issues the
    /// task toward a surviving sibling; a `ScheduleDown` carrying an
    /// older epoch is a stale duplicate (it surfaced from a dead
    /// scheduler's drained mailbox) and is dropped, which is what makes
    /// re-issue exactly-once. 0 for the entire life of a task that never
    /// met a crash.
    pub epoch: u32,
    /// Traffic job this task belongs to (`None` for single-job runs and
    /// the boot task). Inherited from the parent at creation, so a whole
    /// job's task tree carries its job id without any per-spawn lookup
    /// beyond the parent entry already in hand.
    pub job: Option<JobId>,
    // --- timeline, for profiling/reports ---
    pub spawned_at: Cycles,
    pub ready_at: Cycles,
    pub started_at: Cycles,
    pub done_at: Cycles,
}

/// Arena of all tasks ever created in a run. The table is insert-only, so
/// the [`SlotArena`] hands out dense slot indices in spawn order and the
/// slot index *is* the task id — `get`/`get_mut` on the grant path are a
/// bounds check and an array index.
#[derive(Default)]
pub struct TaskTable {
    tasks: SlotArena<TaskEntry>,
}

impl TaskTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn create(
        &mut self,
        desc: TaskDesc,
        parent: Option<TaskId>,
        resp: usize,
        now: Cycles,
    ) -> TaskId {
        let id = TaskId(self.tasks.capacity_used() as u64);
        let deps_pending = desc.n_dep_args();
        let job = parent.and_then(|p| self.get(p).job);
        let slot = self.tasks.insert(TaskEntry {
            id,
            desc: Arc::new(desc),
            parent,
            resp,
            queued_at: resp,
            state: TaskState::DepWait,
            deps_pending,
            pack: Vec::new(),
            worker: None,
            phase: 0,
            epoch: 0,
            job,
            spawned_at: now,
            ready_at: 0,
            started_at: 0,
            done_at: 0,
        });
        debug_assert_eq!(slot.idx as u64, id.0, "insert-only table stays dense");
        id
    }

    #[inline]
    pub fn get(&self, t: TaskId) -> &TaskEntry {
        self.tasks.get_dense(t.0 as usize).unwrap_or_else(|| panic!("no task {t}"))
    }

    #[inline]
    pub fn get_mut(&mut self, t: TaskId) -> &mut TaskEntry {
        self.tasks.get_dense_mut(t.0 as usize).unwrap_or_else(|| panic!("no task {t}"))
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Is `a` an ancestor task of `t` (walking the parent chain)?
    pub fn is_ancestor(&self, a: TaskId, t: TaskId) -> bool {
        if a == t {
            return false;
        }
        let mut cur = self.get(t).parent;
        while let Some(p) = cur {
            if p == a {
                return true;
            }
            cur = self.get(p).parent;
        }
        false
    }

    pub fn iter(&self) -> impl Iterator<Item = &TaskEntry> {
        self.tasks.iter()
    }

    /// Mutable sweep over every entry — the crash-recovery scan uses it
    /// to reassign responsibility for a dead scheduler's tasks.
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut TaskEntry> {
        self.tasks.iter_mut()
    }

    pub fn n_done(&self) -> usize {
        self.tasks.iter().filter(|t| t.state == TaskState::Done).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn desc() -> TaskDesc {
        TaskDesc::new(0, vec![])
    }

    #[test]
    fn ids_are_dense() {
        let mut t = TaskTable::new();
        let a = t.create(desc(), None, 0, 0);
        let b = t.create(desc(), Some(a), 0, 10);
        assert_eq!(a, TaskId(0));
        assert_eq!(b, TaskId(1));
        assert_eq!(t.len(), 2);
        assert_eq!(t.get(b).parent, Some(a));
        assert_eq!(t.get(b).spawned_at, 10);
    }

    #[test]
    fn ancestry_chain() {
        let mut t = TaskTable::new();
        let a = t.create(desc(), None, 0, 0);
        let b = t.create(desc(), Some(a), 0, 0);
        let c = t.create(desc(), Some(b), 0, 0);
        let d = t.create(desc(), Some(a), 0, 0);
        assert!(t.is_ancestor(a, c));
        assert!(t.is_ancestor(b, c));
        assert!(t.is_ancestor(a, d));
        assert!(!t.is_ancestor(c, a));
        assert!(!t.is_ancestor(b, d));
        assert!(!t.is_ancestor(a, a), "a task is not its own ancestor");
    }

    #[test]
    fn job_id_is_inherited_down_the_spawn_tree() {
        use crate::ids::JobId;
        let mut t = TaskTable::new();
        let root = t.create(desc(), None, 0, 0);
        assert_eq!(t.get(root).job, None, "boot tasks carry no job");
        t.get_mut(root).job = Some(JobId(3));
        let child = t.create(desc(), Some(root), 0, 0);
        let grandchild = t.create(desc(), Some(child), 0, 0);
        assert_eq!(t.get(child).job, Some(JobId(3)));
        assert_eq!(t.get(grandchild).job, Some(JobId(3)));
        let other = t.create(desc(), None, 0, 0);
        assert_eq!(t.get(other).job, None);
    }

    #[test]
    fn deps_pending_counts_non_safe_args() {
        use crate::ids::{ObjectId, RegionId};
        use crate::task::descriptor::TaskArg;
        let mut t = TaskTable::new();
        let d = TaskDesc::new(
            0,
            vec![
                TaskArg::val(1),
                TaskArg::obj_in(ObjectId(1)),
                TaskArg::region_inout(RegionId(1)),
            ],
        );
        let id = t.create(d, None, 0, 0);
        assert_eq!(t.get(id).deps_pending, 2);
    }
}

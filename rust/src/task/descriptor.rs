//! Task descriptors and argument annotations (paper Fig 4).
//!
//! A spawned task is a function-table index plus an argument list. Each
//! argument carries the dependency flags of the Myrmics API:
//! `TYPE_IN_ARG`, `TYPE_OUT_ARG`, `TYPE_NOTRANSFER_ARG`, `TYPE_SAFE_ARG`,
//! `TYPE_REGION_ARG`.
//!
//! This is the **wire format**: what travels in `SpawnReq` messages, what
//! the dependency analysis walks, and what the paper's `sys_spawn(idx,
//! args, types)` signature carries. Application code does not build it by
//! hand — the typed layer (`api::spawn::SpawnBuilder` at spawn sites,
//! `api::args` extraction in bodies, `TaskRef` instead of the raw `func`
//! index) lowers to exactly these structs, byte for byte (pinned by
//! `tests/api_roundtrip.rs`).

use crate::ids::{NodeId, ObjectId, RegionId};

pub const TYPE_IN_ARG: u8 = 1 << 0;
pub const TYPE_OUT_ARG: u8 = 1 << 1;
pub const TYPE_NOTRANSFER_ARG: u8 = 1 << 2;
pub const TYPE_SAFE_ARG: u8 = 1 << 3;
pub const TYPE_REGION_ARG: u8 = 1 << 4;

/// Dependency access mode derived from the IN/OUT flags.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Access {
    /// Read-only: multiple readers may be granted concurrently.
    Read,
    /// Write or read-write: exclusive.
    Write,
}

impl Access {
    pub fn compatible(self, other: Access) -> bool {
        self == Access::Read && other == Access::Read
    }
}

/// One task argument.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct TaskArg {
    /// The dependency node (object or region) — `None` for SAFE by-value
    /// arguments, which skip dependency analysis entirely.
    pub node: Option<NodeId>,
    /// By-value payload (scalar arguments, or the raw pointer/rid the task
    /// body receives).
    pub value: u64,
    /// OR of the `TYPE_*` flag bits.
    pub flags: u8,
}

impl TaskArg {
    /// An object argument with read-only access.
    pub fn obj_in(o: ObjectId) -> Self {
        TaskArg { node: Some(o.into()), value: o.0, flags: TYPE_IN_ARG }
    }

    /// An object argument with read-write access.
    pub fn obj_inout(o: ObjectId) -> Self {
        TaskArg { node: Some(o.into()), value: o.0, flags: TYPE_IN_ARG | TYPE_OUT_ARG }
    }

    /// An object argument with write-only access.
    pub fn obj_out(o: ObjectId) -> Self {
        TaskArg { node: Some(o.into()), value: o.0, flags: TYPE_OUT_ARG }
    }

    /// A region argument with read-only access.
    pub fn region_in(r: RegionId) -> Self {
        TaskArg { node: Some(r.into()), value: r.0, flags: TYPE_IN_ARG | TYPE_REGION_ARG }
    }

    /// A region argument with read-write access.
    pub fn region_inout(r: RegionId) -> Self {
        TaskArg {
            node: Some(r.into()),
            value: r.0,
            flags: TYPE_IN_ARG | TYPE_OUT_ARG | TYPE_REGION_ARG,
        }
    }

    /// A by-value scalar argument (no dependency analysis, no transfer).
    pub fn val(v: u64) -> Self {
        TaskArg { node: None, value: v, flags: TYPE_SAFE_ARG }
    }

    /// Mark this argument NOTRANSFER: dependency semantics apply but no
    /// DMA transfer is performed (used by tasks that only spawn subtasks).
    pub fn notransfer(mut self) -> Self {
        self.flags |= TYPE_NOTRANSFER_ARG;
        self
    }

    pub fn is_safe(&self) -> bool {
        self.flags & TYPE_SAFE_ARG != 0 || self.node.is_none()
    }

    pub fn is_region(&self) -> bool {
        self.flags & TYPE_REGION_ARG != 0
    }

    pub fn is_notransfer(&self) -> bool {
        self.flags & TYPE_NOTRANSFER_ARG != 0
    }

    pub fn access(&self) -> Access {
        if self.flags & TYPE_OUT_ARG != 0 {
            Access::Write
        } else {
            Access::Read
        }
    }
}

/// A task to be spawned: function-table index + arguments.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct TaskDesc {
    /// Index into the [`crate::task::registry::Registry`] function table
    /// (the `idx` parameter of `sys_spawn`). Application code names tasks
    /// by [`crate::task::registry::TaskRef`]; this raw index is the wire
    /// lowering.
    pub func: usize,
    pub args: Vec<TaskArg>,
}

impl TaskDesc {
    pub fn new(func: usize, args: Vec<TaskArg>) -> Self {
        TaskDesc { func, args }
    }

    /// Arguments that participate in dependency analysis (non-SAFE).
    pub fn dep_args(&self) -> impl Iterator<Item = (usize, &TaskArg)> {
        self.args.iter().enumerate().filter(|(_, a)| !a.is_safe())
    }

    pub fn n_dep_args(&self) -> usize {
        self.dep_args().count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn access_modes() {
        assert_eq!(TaskArg::obj_in(ObjectId(1)).access(), Access::Read);
        assert_eq!(TaskArg::obj_inout(ObjectId(1)).access(), Access::Write);
        assert_eq!(TaskArg::obj_out(ObjectId(1)).access(), Access::Write);
        assert_eq!(TaskArg::region_in(RegionId(1)).access(), Access::Read);
        assert_eq!(TaskArg::region_inout(RegionId(1)).access(), Access::Write);
    }

    #[test]
    fn compatibility() {
        assert!(Access::Read.compatible(Access::Read));
        assert!(!Access::Read.compatible(Access::Write));
        assert!(!Access::Write.compatible(Access::Write));
    }

    #[test]
    fn safe_args_skip_deps() {
        let d = TaskDesc::new(
            0,
            vec![TaskArg::val(42), TaskArg::obj_in(ObjectId(1)), TaskArg::region_inout(RegionId(2))],
        );
        assert_eq!(d.n_dep_args(), 2);
        assert!(d.args[0].is_safe());
        assert!(!d.args[1].is_region());
        assert!(d.args[2].is_region());
    }

    #[test]
    fn notransfer_flag() {
        let a = TaskArg::region_inout(RegionId(1)).notransfer();
        assert!(a.is_notransfer());
        assert_eq!(a.access(), Access::Write);
        assert!(!a.is_safe());
    }

    #[test]
    fn flag_bits_match_paper() {
        assert_eq!(TYPE_IN_ARG, 1);
        assert_eq!(TYPE_OUT_ARG, 2);
        assert_eq!(TYPE_NOTRANSFER_ARG, 4);
        assert_eq!(TYPE_SAFE_ARG, 8);
        assert_eq!(TYPE_REGION_ARG, 16);
    }
}

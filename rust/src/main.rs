//! The `myrmics` launcher: run paper experiments or individual benchmark
//! simulations from the command line. The benchmark list is enumerated
//! from `all_workloads()` — there is no hand-kept name table to drift.

use myrmics::apps::workload_api::all_workloads;
use myrmics::experiments::bench::{run_mpi_bench, run_myrmics, Scaling};
use myrmics::experiments::{cli, summarize};

fn bench_names() -> String {
    all_workloads()
        .iter()
        .map(|w| w.name())
        .collect::<Vec<_>>()
        .join(" ")
}

fn usage() -> ! {
    eprintln!("myrmics — Myrmics runtime-system reproduction");
    eprintln!();
    eprintln!("USAGE:");
    eprintln!("  myrmics exp [NAMES...] [--quick|--smoke]  regenerate paper figures/tables");
    eprintln!("  myrmics exp policy [--quick|--smoke]      placement-policy sweep -> POLICY_sweep.json");
    eprintln!("  myrmics exp steal [--quick|--smoke]       work-stealing sweep -> STEAL_sweep.json");
    eprintln!("  myrmics exp tenants [--quick|--smoke]     multi-tenant traffic sweep -> TENANTS_sweep.json");
    eprintln!("  myrmics exp fuzz [FUZZ OPTS]              protocol fuzz + invariant oracles");
    eprintln!("  myrmics run <bench> [OPTS]                run one benchmark simulation");
    eprintln!("  myrmics bench --list                      list the registered workloads");
    eprintln!();
    eprintln!("EXPERIMENTS: {}", cli::EXPERIMENTS.join(" "));
    eprintln!("BENCHES:     {}", bench_names());
    eprintln!();
    eprintln!("exp FLAGS: --quick (small sweep)  --smoke (tiny CI configuration)");
    eprintln!("run OPTS:  --workers N (default 64)  --flat  --mpi  --weak");
    eprintln!("fuzz OPTS: --smoke | --seeds N | --soak MINUTES | --seed X [--plan Y]");
    eprintln!();
    eprintln!(
        "GLOBAL:    --threads N  executor threads per sharded engine (requires\n\
         \x20          MYRMICS_SHARDS >= N; equivalent to MYRMICS_THREADS=N)"
    );
    std::process::exit(2)
}

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    // Global `--threads N`, valid on every subcommand: routed through the
    // MYRMICS_THREADS environment seam (PlatformConfig::new reads it via
    // ShardCfg::from_env), exactly like CI's threaded lane. Validated
    // here against the shard count so a silent engine-side clamp never
    // masquerades as a threaded measurement.
    if let Some(i) = args.iter().position(|a| a == "--threads") {
        let n: usize =
            args.get(i + 1).and_then(|v| v.parse().ok()).unwrap_or_else(|| usage());
        let shards: usize = std::env::var("MYRMICS_SHARDS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1);
        if n == 0 || n > shards {
            eprintln!(
                "--threads {n} must be between 1 and the engine shard count \
                 ({shards}; set MYRMICS_SHARDS)"
            );
            std::process::exit(2);
        }
        std::env::set_var("MYRMICS_THREADS", n.to_string());
        args.drain(i..=i + 1);
    }
    match args.first().map(|s| s.as_str()) {
        Some("exp") => cli::run(&args[1..]),
        Some("bench") => {
            if args.get(1).map(|s| s.as_str()) != Some("--list") {
                usage();
            }
            for w in all_workloads() {
                println!("{}", w.name());
            }
        }
        Some("run") => {
            let name = args.get(1).cloned().unwrap_or_else(|| usage());
            let bench = all_workloads()
                .into_iter()
                .find(|b| b.name() == name)
                .unwrap_or_else(|| usage());
            let mut workers = 64usize;
            let mut flat = false;
            let mut mpi = false;
            let mut weak = false;
            let mut it = args[2..].iter();
            while let Some(a) = it.next() {
                match a.as_str() {
                    "--workers" => {
                        workers = it.next().and_then(|v| v.parse().ok()).unwrap_or_else(|| usage())
                    }
                    "--flat" => flat = true,
                    "--mpi" => mpi = true,
                    "--weak" => weak = true,
                    _ => usage(),
                }
            }
            if !bench.valid_workers(workers) {
                eprintln!("{} does not support {} workers", bench.name(), workers);
                std::process::exit(1);
            }
            let scaling = if weak { Scaling::Weak } else { Scaling::Strong };
            let (t, eng) = if mpi {
                run_mpi_bench(bench, workers, scaling)
            } else {
                run_myrmics(bench, workers, scaling, !flat, None)
            };
            let s = summarize(&eng, t);
            println!(
                "{} | {} workers ({} scheds) | {} cycles | tasks {} | worker task/rt/idle \
                 {:.0}%/{:.0}%/{:.0}% | sched busy {:.1}% | balance {:.0}%",
                bench.name(),
                s.n_workers,
                s.n_scheds,
                t,
                s.tasks_completed,
                100.0 * s.worker_task_frac,
                100.0 * s.worker_runtime_frac,
                100.0 * s.worker_idle_frac,
                100.0 * s.sched_busy_frac,
                s.balance,
            );
        }
        _ => usage(),
    }
}

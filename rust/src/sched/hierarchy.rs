//! Scheduler/worker tree construction and routing (paper Fig 3a).
//!
//! Workers form the leaves; each exchanges messages only with its leaf
//! scheduler. Mid-level schedulers talk to their parent and children; the
//! root is the single top-level scheduler.
//!
//! Core-id assignment places each leaf scheduler immediately before its
//! block of workers, so consecutive ids are spatially adjacent in the 3D
//! mesh ([`crate::noc::topology::Topology`]) and every scheduling domain
//! is physically contiguous — mirroring the hand-placement the paper
//! applies on the prototype. Non-leaf schedulers are placed after all
//! worker blocks.

use crate::config::HierarchySpec;
use crate::ids::CoreId;

/// Core-to-shard assignment for the sharded engine, computed from the
/// scheduler tree: each *top-level subtree* (a child of the root and
/// everything under it) is an indivisible unit, distributed round-robin
/// over the shards; the top-level scheduler itself lives on shard 0. The
/// only tree links that can cross shards are therefore root <-> top-level
/// child links — enumerated in `cross_links` so the engine can derive its
/// conservative lookahead from the slowest-free (minimum-latency) one.
#[derive(Clone, Debug)]
pub struct ShardPartition {
    /// Effective shard count after clamping the request to the number of
    /// top-level subtrees (1 for flat hierarchies).
    pub n_shards: usize,
    /// Core id -> shard id, dense over all cores.
    pub shard_of: Vec<u32>,
    /// Tree links whose endpoints land on different shards, as
    /// `(parent_core, child_core)` pairs in child-index order. Empty when
    /// `n_shards == 1`.
    pub cross_links: Vec<(CoreId, CoreId)>,
}

impl ShardPartition {
    pub fn shard(&self, c: CoreId) -> usize {
        self.shard_of[c.idx()] as usize
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Role {
    /// Scheduler with the given scheduler index (0 = top level).
    Sched(usize),
    /// Worker with the given worker index (0..n_workers).
    Worker(usize),
}

/// Immutable map of the whole core hierarchy.
#[derive(Clone, Debug)]
pub struct HierarchyMap {
    pub n_workers: usize,
    pub n_scheds: usize,
    /// Scheduler index -> core id (index 0 is the top-level scheduler).
    pub sched_cores: Vec<CoreId>,
    /// Scheduler index -> tree level (0 = top).
    pub level_of: Vec<usize>,
    /// Scheduler index -> parent scheduler index.
    pub parent: Vec<Option<usize>>,
    /// Scheduler index -> child scheduler indices.
    pub children: Vec<Vec<usize>>,
    /// Scheduler index -> directly attached workers (leaf schedulers only).
    pub leaf_workers: Vec<Vec<CoreId>>,
    /// All workers in a scheduler's subtree (sorted by core id).
    subtree_workers: Vec<Vec<CoreId>>,
    /// Core id -> role.
    role: Vec<Role>,
    /// Core id -> leaf scheduler index serving it (`usize::MAX` for
    /// scheduler cores). Dense: `route_next` probes this per forwarded
    /// hop, so it must stay an index, not a hash lookup.
    worker_leaf: Vec<usize>,
}

impl HierarchyMap {
    pub fn build(n_workers: usize, spec: &HierarchySpec) -> Self {
        assert!(n_workers >= 1);
        assert_eq!(spec.scheds_per_level[0], 1, "exactly one top-level scheduler");
        let n_scheds = spec.n_schedulers();
        let n_levels = spec.n_levels();

        // Scheduler indices level by level (BFS order).
        let mut levels: Vec<Vec<usize>> = Vec::new();
        let mut next = 0usize;
        for &n in &spec.scheds_per_level {
            levels.push((next..next + n).collect());
            next += n;
        }

        let mut parent = vec![None; n_scheds];
        let mut children: Vec<Vec<usize>> = vec![Vec::new(); n_scheds];
        let mut level_of = vec![0usize; n_scheds];
        for (lvl, idxs) in levels.iter().enumerate() {
            for &s in idxs {
                level_of[s] = lvl;
            }
            if lvl == 0 {
                continue;
            }
            // Distribute this level's schedulers among the previous
            // level's, in contiguous chunks.
            let ups = &levels[lvl - 1];
            for (i, &s) in idxs.iter().enumerate() {
                let p = ups[i * ups.len() / idxs.len()];
                parent[s] = Some(p);
                children[p].push(s);
            }
        }

        let leaves = levels[n_levels - 1].clone();
        // Distribute workers among leaves in contiguous chunks.
        let mut leaf_worker_counts = vec![0usize; n_scheds];
        for w in 0..n_workers {
            let l = leaves[w * leaves.len() / n_workers.max(1)];
            leaf_worker_counts[l] += 1;
        }

        // Core-id layout: for each leaf (in index order): leaf scheduler,
        // then its workers; then all non-leaf schedulers in index order.
        let n_cores = n_workers + n_scheds;
        let mut role = Vec::with_capacity(n_cores);
        let mut sched_cores = vec![CoreId(0); n_scheds];
        let mut leaf_workers: Vec<Vec<CoreId>> = vec![Vec::new(); n_scheds];
        let mut worker_leaf = vec![usize::MAX; n_cores];
        let mut wi = 0usize;
        for &l in &leaves {
            sched_cores[l] = CoreId(role.len() as u32);
            role.push(Role::Sched(l));
            for _ in 0..leaf_worker_counts[l] {
                let c = CoreId(role.len() as u32);
                worker_leaf[c.idx()] = l;
                leaf_workers[l].push(c);
                role.push(Role::Worker(wi));
                wi += 1;
            }
        }
        for s in 0..n_scheds {
            if !leaves.contains(&s) {
                sched_cores[s] = CoreId(role.len() as u32);
                role.push(Role::Sched(s));
            }
        }
        debug_assert_eq!(role.len(), n_cores);

        // Subtree worker sets, bottom-up.
        let mut subtree_workers: Vec<Vec<CoreId>> = leaf_workers.clone();
        for lvl in (0..n_levels - 1).rev() {
            for &s in &levels[lvl] {
                let mut acc: Vec<CoreId> = Vec::new();
                for &c in &children[s] {
                    acc.extend_from_slice(&subtree_workers[c]);
                }
                acc.sort_unstable();
                subtree_workers[s] = acc;
            }
        }
        for v in &mut subtree_workers {
            v.sort_unstable();
        }

        HierarchyMap {
            n_workers,
            n_scheds,
            sched_cores,
            level_of,
            parent,
            children,
            leaf_workers,
            subtree_workers,
            role,
            worker_leaf,
        }
    }

    pub fn n_cores(&self) -> usize {
        self.role.len()
    }

    pub fn role(&self, c: CoreId) -> Role {
        self.role[c.idx()]
    }

    pub fn is_sched(&self, c: CoreId) -> bool {
        matches!(self.role(c), Role::Sched(_))
    }

    pub fn sched_idx(&self, c: CoreId) -> Option<usize> {
        match self.role(c) {
            Role::Sched(i) => Some(i),
            Role::Worker(_) => None,
        }
    }

    pub fn sched_core(&self, idx: usize) -> CoreId {
        self.sched_cores[idx]
    }

    pub fn top_core(&self) -> CoreId {
        self.sched_cores[0]
    }

    /// The leaf scheduler index serving a worker core. O(1) dense index —
    /// on the per-hop routing path.
    pub fn leaf_of_worker(&self, c: CoreId) -> usize {
        let l = self.worker_leaf[c.idx()];
        assert!(l != usize::MAX, "not a worker core");
        l
    }

    pub fn is_leaf(&self, idx: usize) -> bool {
        self.children[idx].is_empty()
    }

    /// All workers under scheduler `idx` (its whole subtree).
    pub fn subtree_workers(&self, idx: usize) -> &[CoreId] {
        &self.subtree_workers[idx]
    }

    /// True if scheduler `anc`'s subtree contains scheduler `idx`.
    pub fn sched_subtree_contains(&self, anc: usize, mut idx: usize) -> bool {
        loop {
            if idx == anc {
                return true;
            }
            match self.parent[idx] {
                Some(p) => idx = p,
                None => return false,
            }
        }
    }

    /// True if scheduler `idx`'s subtree contains `core` (scheduler or
    /// worker core).
    pub fn subtree_contains_core(&self, idx: usize, core: CoreId) -> bool {
        match self.role(core) {
            Role::Sched(s) => self.sched_subtree_contains(idx, s),
            Role::Worker(_) => self.sched_subtree_contains(idx, self.leaf_of_worker(core)),
        }
    }

    /// Next hop from scheduler `from_idx` towards `target` along the tree.
    /// Returns the core to forward to (a child scheduler core, a worker of
    /// this leaf, or the parent scheduler core).
    pub fn route_next(&self, from_idx: usize, target: CoreId) -> CoreId {
        if self.sched_cores[from_idx] == target {
            return target;
        }
        // A worker directly attached to this (leaf) scheduler?
        if let Role::Worker(_) = self.role(target) {
            if self.leaf_of_worker(target) == from_idx {
                return target;
            }
        }
        for &c in &self.children[from_idx] {
            if self.subtree_contains_core(c, target) {
                return self.sched_cores[c];
            }
        }
        let p = self.parent[from_idx].expect("target not in tree and no parent");
        self.sched_cores[p]
    }

    /// The child of `anc` on the ancestry path to scheduler `idx`
    /// (`Some(idx)` when `idx` is a direct child). `None` when `idx == anc`
    /// or `idx` is outside `anc`'s subtree. O(depth), allocation-free —
    /// the load tracker uses this to attribute a completion to the child
    /// subtree it was placed into.
    pub fn child_towards(&self, anc: usize, mut idx: usize) -> Option<usize> {
        if idx == anc {
            return None;
        }
        loop {
            match self.parent[idx] {
                Some(p) if p == anc => return Some(idx),
                Some(p) => idx = p,
                None => return None,
            }
        }
    }

    /// For delegation: the child of `idx` whose subtree contains all of
    /// `owners` (scheduler indices), if exactly such a child exists.
    pub fn child_covering(&self, idx: usize, owners: &[usize]) -> Option<usize> {
        if owners.is_empty() {
            return None;
        }
        'child: for &c in &self.children[idx] {
            for &o in owners {
                if !self.sched_subtree_contains(c, o) {
                    continue 'child;
                }
            }
            return Some(c);
        }
        None
    }

    /// Depth (number of levels) of the scheduler tree.
    pub fn n_levels(&self) -> usize {
        self.level_of.iter().copied().max().unwrap_or(0) + 1
    }

    /// The top-level subtree a scheduler belongs to, as an index into
    /// `children[0]` (`None` for the root itself).
    fn top_subtree_of(&self, mut s: usize) -> Option<usize> {
        loop {
            match self.parent[s] {
                None => return None,
                Some(0) => return self.children[0].iter().position(|&c| c == s),
                Some(p) => s = p,
            }
        }
    }

    /// Compute the shard partition for a requested shard count. The
    /// request is clamped to the number of top-level subtrees (a shard
    /// must own whole subtrees; flat hierarchies always get one shard),
    /// so `requested = 4` on a two-subtree tree silently runs with 2 —
    /// determinism is unaffected since the merged order is shard-count
    /// invariant by construction.
    pub fn shard_partition(&self, requested: usize) -> ShardPartition {
        let n_subtrees = self.children[0].len();
        let n_shards = requested.clamp(1, n_subtrees.max(1));
        let mut shard_of = vec![0u32; self.n_cores()];
        if n_shards > 1 {
            for s in 0..self.n_scheds {
                if let Some(i) = self.top_subtree_of(s) {
                    let shard = (i % n_shards) as u32;
                    shard_of[self.sched_cores[s].idx()] = shard;
                    for &w in &self.leaf_workers[s] {
                        shard_of[w.idx()] = shard;
                    }
                }
            }
        }
        let mut cross_links = Vec::new();
        for s in 0..self.n_scheds {
            if let Some(p) = self.parent[s] {
                let (pc, sc) = (self.sched_cores[p], self.sched_cores[s]);
                if shard_of[pc.idx()] != shard_of[sc.idx()] {
                    cross_links.push((pc, sc));
                }
            }
        }
        ShardPartition { n_shards, shard_of, cross_links }
    }

    /// Scheduler indices eligible to be crash victims: leaf schedulers
    /// whose parent has at least two children. Leaf-only keeps the blast
    /// radius to one scheduling domain; the >= 2 siblings rule guarantees
    /// the re-adopting parent always has a *surviving* child subtree to
    /// re-place orphaned work into. Deterministic (index order) so the
    /// chaos plan's victim draw replays bit-identically.
    pub fn crash_eligible(&self) -> Vec<usize> {
        (0..self.n_scheds)
            .filter(|&s| {
                self.is_leaf(s)
                    && self.parent[s].is_some_and(|p| self.children[p].len() >= 2)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_hierarchy() {
        let h = HierarchyMap::build(4, &HierarchySpec::flat());
        assert_eq!(h.n_scheds, 1);
        assert_eq!(h.n_cores(), 5);
        // Layout: [sched0, w0, w1, w2, w3]
        assert_eq!(h.sched_core(0), CoreId(0));
        assert!(h.is_leaf(0));
        assert_eq!(h.leaf_workers[0].len(), 4);
        assert_eq!(h.leaf_of_worker(CoreId(3)), 0);
        assert_eq!(h.subtree_workers(0).len(), 4);
    }

    #[test]
    fn two_level_paper_config() {
        // 128 workers, 1 top + 7 leaves (paper Fig 8 caption).
        let h = HierarchyMap::build(128, &HierarchySpec::two_level(7));
        assert_eq!(h.n_scheds, 8);
        assert_eq!(h.n_cores(), 136);
        assert_eq!(h.children[0].len(), 7);
        for l in 1..8 {
            assert_eq!(h.parent[l], Some(0));
            assert!(h.is_leaf(l));
            // 128/7 = 18.3: leaves hold 18 or 19 workers.
            let n = h.leaf_workers[l].len();
            assert!((18..=19).contains(&n), "leaf {l} has {n}");
        }
        assert_eq!(h.subtree_workers(0).len(), 128);
        // Leaf blocks are contiguous: each leaf's workers follow its core.
        for l in 1..8 {
            let sc = h.sched_core(l);
            for (i, w) in h.leaf_workers[l].iter().enumerate() {
                assert_eq!(w.0, sc.0 + 1 + i as u32);
            }
        }
    }

    #[test]
    fn three_level_fanout6() {
        let h = HierarchyMap::build(216, &HierarchySpec::multi_level(3, 6));
        assert_eq!(h.n_scheds, 1 + 6 + 36);
        assert_eq!(h.n_levels(), 3);
        // Every mid scheduler has 6 leaf children.
        for s in 1..7 {
            assert_eq!(h.children[s].len(), 6);
            assert_eq!(h.level_of[s], 1);
        }
        // 216 workers over 36 leaves = 6 each.
        for s in 7..43 {
            assert_eq!(h.leaf_workers[s].len(), 6);
        }
    }

    #[test]
    fn routing_goes_through_tree() {
        let h = HierarchyMap::build(32, &HierarchySpec::two_level(2));
        let top = 0usize;
        let leaf_a = 1usize;
        let leaf_b = 2usize;
        let w_b = h.leaf_workers[leaf_b][0];
        // From leaf A to a worker of leaf B: up to the top first.
        assert_eq!(h.route_next(leaf_a, w_b), h.sched_core(top));
        // From the top towards that worker: down to leaf B.
        assert_eq!(h.route_next(top, w_b), h.sched_core(leaf_b));
        // From leaf B: direct.
        assert_eq!(h.route_next(leaf_b, w_b), w_b);
    }

    #[test]
    fn child_covering_for_delegation() {
        let h = HierarchyMap::build(36, &HierarchySpec::multi_level(3, 2));
        // Tree: 0 -> (1,2); 1 -> (3,4); 2 -> (5,6).
        assert_eq!(h.child_covering(0, &[3]), Some(1));
        assert_eq!(h.child_covering(0, &[3, 4]), Some(1));
        assert_eq!(h.child_covering(0, &[3, 5]), None);
        assert_eq!(h.child_covering(1, &[3]), Some(3));
        assert_eq!(h.child_covering(0, &[0]), None);
        assert_eq!(h.child_covering(0, &[]), None);
    }

    #[test]
    fn routing_outside_the_subtree_goes_up() {
        let h = HierarchyMap::build(36, &HierarchySpec::multi_level(3, 2));
        // Tree: 0 -> (1,2); 1 -> (3,4); 2 -> (5,6).
        let w_far = h.leaf_workers[6][0];
        // From leaf 3, a worker under leaf 6 is outside the whole level-1
        // subtree: the next hop is leaf 3's parent (mid 1), not a child.
        assert_eq!(h.route_next(3, w_far), h.sched_core(1));
        // From mid 1 it is still outside: up again to the top.
        assert_eq!(h.route_next(1, w_far), h.sched_core(0));
        // From the top the route descends the covering child chain.
        assert_eq!(h.route_next(0, w_far), h.sched_core(2));
        assert_eq!(h.route_next(2, w_far), h.sched_core(6));
        assert_eq!(h.route_next(6, w_far), w_far);
        // A foreign *scheduler core* target routes the same way.
        assert_eq!(h.route_next(3, h.sched_core(5)), h.sched_core(1));
    }

    #[test]
    fn routing_single_child_chain() {
        // Degenerate 3-level chain: every level has exactly one scheduler.
        let h = HierarchyMap::build(4, &HierarchySpec { scheds_per_level: vec![1, 1, 1] });
        assert_eq!(h.n_scheds, 3);
        assert_eq!(h.children[0], vec![1]);
        assert_eq!(h.children[1], vec![2]);
        let w = h.leaf_workers[2][0];
        // Downward: each hop is the single child.
        assert_eq!(h.route_next(0, w), h.sched_core(1));
        assert_eq!(h.route_next(1, w), h.sched_core(2));
        assert_eq!(h.route_next(2, w), w);
        // Upward from the bottom towards the top core.
        assert_eq!(h.route_next(2, h.top_core()), h.sched_core(1));
        assert_eq!(h.route_next(1, h.top_core()), h.sched_core(0));
    }

    #[test]
    fn routing_top_core_targets() {
        let h = HierarchyMap::build(32, &HierarchySpec::two_level(2));
        // Self-target: route_next returns the target itself.
        assert_eq!(h.route_next(0, h.top_core()), h.top_core());
        assert_eq!(h.route_next(1, h.sched_core(1)), h.sched_core(1));
        // From a leaf, the top core is the parent hop.
        assert_eq!(h.route_next(1, h.top_core()), h.top_core());
        assert_eq!(h.route_next(2, h.top_core()), h.top_core());
    }

    #[test]
    fn child_towards_walks_the_ancestry() {
        let h = HierarchyMap::build(36, &HierarchySpec::multi_level(3, 2));
        // Tree: 0 -> (1,2); 1 -> (3,4); 2 -> (5,6).
        assert_eq!(h.child_towards(0, 3), Some(1));
        assert_eq!(h.child_towards(0, 6), Some(2));
        assert_eq!(h.child_towards(0, 1), Some(1));
        assert_eq!(h.child_towards(1, 4), Some(4));
        // Not in the subtree / self: no child to attribute.
        assert_eq!(h.child_towards(1, 5), None);
        assert_eq!(h.child_towards(0, 0), None);
        assert_eq!(h.child_towards(3, 0), None);
    }

    #[test]
    fn child_covering_edge_cases() {
        let h = HierarchyMap::build(36, &HierarchySpec::multi_level(3, 2));
        // Owners spanning two level-1 subtrees: no single cover.
        assert_eq!(h.child_covering(0, &[4, 6]), None);
        // Deep owner: the level-1 child covering a leaf two levels down.
        assert_eq!(h.child_covering(0, &[6]), Some(2));
        // A leaf has no children: never a cover.
        assert_eq!(h.child_covering(3, &[3]), None);
        // The parent itself among the owners can never be covered.
        assert_eq!(h.child_covering(0, &[0, 3]), None);
        // Single-child chain: the only child covers everything below it.
        let c = HierarchyMap::build(4, &HierarchySpec { scheds_per_level: vec![1, 1, 1] });
        assert_eq!(c.child_covering(0, &[2]), Some(1));
        assert_eq!(c.child_covering(1, &[2]), Some(2));
        assert_eq!(c.child_covering(2, &[2]), None);
    }

    #[test]
    fn subtree_containment() {
        let h = HierarchyMap::build(36, &HierarchySpec::multi_level(3, 2));
        assert!(h.sched_subtree_contains(0, 6));
        assert!(h.sched_subtree_contains(1, 4));
        assert!(!h.sched_subtree_contains(1, 5));
        let w = h.leaf_workers[3][0];
        assert!(h.subtree_contains_core(1, w));
        assert!(!h.subtree_contains_core(2, w));
        assert!(h.subtree_contains_core(0, w));
    }

    #[test]
    fn crash_eligible_needs_a_surviving_sibling() {
        // Flat: the single scheduler has no parent — nothing eligible.
        let flat = HierarchyMap::build(4, &HierarchySpec::flat());
        assert!(flat.crash_eligible().is_empty());
        // Single-child chain: leaf 2's parent has one child — ineligible.
        let chain = HierarchyMap::build(4, &HierarchySpec { scheds_per_level: vec![1, 1, 1] });
        assert!(chain.crash_eligible().is_empty());
        // Two-level with 7 leaves: all 7 eligible, never the top.
        let two = HierarchyMap::build(128, &HierarchySpec::two_level(7));
        assert_eq!(two.crash_eligible(), (1..8).collect::<Vec<_>>());
        // Three-level: only the 36 leaves, not the mid tier.
        let three = HierarchyMap::build(216, &HierarchySpec::multi_level(3, 6));
        let elig = three.crash_eligible();
        assert_eq!(elig.len(), 36);
        assert!(elig.iter().all(|&s| three.is_leaf(s)));
    }

    #[test]
    fn shard_partition_is_by_top_level_subtree() {
        let h = HierarchyMap::build(128, &HierarchySpec::two_level(7));
        let p = h.shard_partition(4);
        assert_eq!(p.n_shards, 4);
        // The root lives on shard 0.
        assert_eq!(p.shard(h.top_core()), 0);
        // Each leaf subtree is whole: the leaf scheduler and all its
        // workers share one shard, and subtrees round-robin over shards.
        for (i, &l) in h.children[0].iter().enumerate() {
            let want = i % 4;
            assert_eq!(p.shard(h.sched_core(l)), want, "leaf {l}");
            for &w in &h.leaf_workers[l] {
                assert_eq!(p.shard(w), want);
            }
        }
        // Cross links are exactly the root <-> off-shard-0 child links.
        assert_eq!(p.cross_links.len(), 5); // subtrees 1,2,3,5,6
        for &(a, b) in &p.cross_links {
            assert_eq!(a, h.top_core());
            assert_ne!(p.shard(a), p.shard(b));
        }
    }

    #[test]
    fn shard_partition_clamps_and_degenerates() {
        // Flat: no subtrees, always one shard, no cross links.
        let flat = HierarchyMap::build(4, &HierarchySpec::flat());
        let p = flat.shard_partition(8);
        assert_eq!(p.n_shards, 1);
        assert!(p.cross_links.is_empty());
        assert!(p.shard_of.iter().all(|&s| s == 0));
        // Two subtrees: a request for 4 clamps to 2.
        let two = HierarchyMap::build(32, &HierarchySpec::two_level(2));
        let p = two.shard_partition(4);
        assert_eq!(p.n_shards, 2);
        assert_eq!(p.cross_links.len(), 1);
        // Requesting 1 shard never computes a partition.
        let p1 = two.shard_partition(1);
        assert_eq!(p1.n_shards, 1);
        assert!(p1.cross_links.is_empty());
    }

    #[test]
    fn shard_partition_keeps_deep_subtrees_whole() {
        // 3 levels, fanout 2: subtrees under mids 1 and 2 must each land
        // whole (mid + its leaves + their workers) on one shard.
        let h = HierarchyMap::build(36, &HierarchySpec::multi_level(3, 2));
        let p = h.shard_partition(2);
        assert_eq!(p.n_shards, 2);
        for (i, &mid) in h.children[0].iter().enumerate() {
            let want = i % 2;
            assert_eq!(p.shard(h.sched_core(mid)), want);
            for &leaf in &h.children[mid] {
                assert_eq!(p.shard(h.sched_core(leaf)), want);
                for &w in &h.leaf_workers[leaf] {
                    assert_eq!(p.shard(w), want);
                }
            }
        }
        // Only root<->mid links can cross; leaf<->mid links never do.
        for &(a, _) in &p.cross_links {
            assert_eq!(a, h.top_core());
        }
    }

    #[test]
    fn all_workers_covered_once() {
        for (nw, spec) in
            [(100, HierarchySpec::two_level(7)), (57, HierarchySpec::multi_level(3, 3))]
        {
            let h = HierarchyMap::build(nw, &spec);
            let total: usize = (0..h.n_scheds).map(|s| h.leaf_workers[s].len()).sum();
            assert_eq!(total, nw);
            assert_eq!(h.subtree_workers(0).len(), nw);
        }
    }
}

//! Locality / load-balance scoring primitives (paper V-E, evaluated in
//! VI-D) — the arithmetic behind the `LocalityBalance` placement policy
//! in [`crate::sched::policy`].
//!
//! When a dependency-free task is placed, each candidate subtree (child
//! scheduler, or worker at leaf level) gets a locality score `L` — how many
//! of the task's packed bytes were last produced inside the candidate —
//! and a load-balance score `B` — how idle the candidate is. Both are
//! normalized to 0..=1024 and combined as `T = p*L + (100-p)*B` with the
//! policy bias percentage `p`.

use crate::ids::CoreId;
use crate::noc::msg::ProducerRange;

pub const SCORE_MAX: u64 = 1024;

/// Locality score: fraction of `pack` bytes produced by `members`
/// (a sorted slice of worker core ids), scaled to 0..=1024.
pub fn locality_score(pack: &[ProducerRange], members: &[CoreId]) -> u64 {
    let total: u64 = pack.iter().map(|r| r.bytes).sum();
    if total == 0 {
        return 0;
    }
    let inside: u64 = pack
        .iter()
        .filter(|r| members.binary_search(&r.producer).is_ok())
        .map(|r| r.bytes)
        .sum();
    SCORE_MAX * inside / total
}

/// Load-balance score: 1024 when idle, halved when the candidate holds
/// `capacity` outstanding tasks (2x its worker count — the paper's "ready
/// tasks twice the number of cores" operating point), falling smoothly
/// towards 0 beyond. The hyperbolic shape keeps two properties the
/// placement needs: small (+-1 task) imbalances do not swamp the locality
/// score (sticky placement among equally-loaded candidates), and the
/// score keeps discriminating at any overload level (no saturation ties).
pub fn balance_score(load: u64, capacity: u64) -> u64 {
    let cap = capacity.max(1) as u128;
    (SCORE_MAX as u128 * cap / (cap + load as u128)) as u64
}

/// Combined score with policy bias `p` (percent weight of locality).
pub fn total_score(p_locality: u32, l: u64, b: u64) -> u64 {
    let p = p_locality.min(100) as u64;
    (p * l + (100 - p) * b) / 100
}

/// Pick the candidate with the best combined score; ties break to the
/// lowest index (determinism).
pub fn pick_best(p_locality: u32, cands: &[(u64, u64)]) -> usize {
    let mut best = 0;
    let mut best_t = 0;
    for (i, &(l, b)) in cands.iter().enumerate() {
        let t = total_score(p_locality, l, b);
        if i == 0 || t > best_t {
            best = i;
            best_t = t;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pr(producer: u32, bytes: u64) -> ProducerRange {
        ProducerRange { producer: CoreId(producer), addr: 0, bytes }
    }

    #[test]
    fn locality_fractions() {
        let members = vec![CoreId(1), CoreId(2)];
        let pack = vec![pr(1, 300), pr(2, 100), pr(9, 600)];
        assert_eq!(locality_score(&pack, &members), 1024 * 400 / 1000);
        assert_eq!(locality_score(&[], &members), 0);
        assert_eq!(locality_score(&pack, &[]), 0);
        let all = vec![CoreId(1), CoreId(2), CoreId(9)];
        assert_eq!(locality_score(&pack, &all), 1024);
    }

    #[test]
    fn balance_extremes() {
        assert_eq!(balance_score(0, 1), 1024);
        assert_eq!(balance_score(0, 10), 1024);
        assert_eq!(balance_score(10, 10), 512);
        // Keeps discriminating past capacity (no saturation ties).
        assert!(balance_score(20, 10) < balance_score(19, 10));
        assert!(balance_score(1000, 10) > 0 || balance_score(1000, 10) == 0);
        let b = balance_score(30, 10);
        assert_eq!(b, 1024 * 10 / 40);
    }

    #[test]
    fn policy_bias_blends() {
        // Pure locality.
        assert_eq!(total_score(100, 1024, 0), 1024);
        // Pure load balance.
        assert_eq!(total_score(0, 1024, 0), 0);
        assert_eq!(total_score(0, 0, 1024), 1024);
        // Even split.
        assert_eq!(total_score(50, 1024, 0), 512);
        // The paper's default favors balance.
        assert!(total_score(20, 1024, 0) < total_score(20, 0, 1024));
    }

    #[test]
    fn pick_best_deterministic_ties() {
        // Identical candidates: lowest index wins.
        assert_eq!(pick_best(20, &[(100, 100), (100, 100)]), 0);
        assert_eq!(pick_best(20, &[(0, 0), (1024, 1024)]), 1);
        // Locality-heavy bias flips the winner.
        let cands = [(1024, 0), (0, 1000)];
        assert_eq!(pick_best(100, &cands), 0);
        assert_eq!(pick_best(0, &cands), 1);
    }
}

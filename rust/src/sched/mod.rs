//! Hierarchical scheduling: the tree, scheduler/worker logic, scoring.
pub mod hierarchy;
pub mod scheduler;
pub mod scoring;
pub mod worker;

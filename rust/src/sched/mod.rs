//! Hierarchical scheduling: the tree, scheduler/worker logic, the
//! pluggable placement-policy layer and its scoring primitives.
pub mod hierarchy;
pub mod policy;
pub mod readyq;
pub mod scheduler;
pub mod scoring;
pub mod worker;

//! Pluggable placement policies + dense load tracking (paper V-E, VI-D).
//!
//! This module is the *policy seam* carved out of the scheduler: everything
//! that decides **where** a dependency-free task goes — candidate scoring,
//! the locality/load-balance blend, eager load estimates and their
//! refresh/decay — lives here, while `sched::scheduler` keeps only the
//! protocol (messages, traversal, packing). The split is what lets the
//! `policy` experiment sweep placement strategies without touching the
//! protocol code, and what future work-stealing / admission-control PRs
//! plug into.
//!
//! # Hot-path discipline
//!
//! Placement runs once per task on the per-event path, so the same PR-1
//! invariant applies: **no steady-state heap allocation, no hash or tree
//! lookups, enum dispatch only** (no `dyn`). Concretely:
//!
//! * [`PlacePolicy`] is an enum; `match` dispatch keeps the choice branch
//!   predictable and inlinable.
//! * [`LoadTracker`] replaces the scheduler's old `BTreeMap<usize, u64>` /
//!   `BTreeMap<u32, u64>` child/worker load maps with dense `Vec`-indexed
//!   tables. Child scheduler indices and worker core ids are assigned in
//!   contiguous blocks by [`HierarchyMap::build`], so a slot is a subtract
//!   and an index — the last hashing/tree probe on the placement path is
//!   gone. The tracker also maintains the load total incrementally, making
//!   the upstream load report O(1) instead of a map scan.
//! * Scoring scratch lives in the [`Placer`], reused across placements.
//!
//! # Determinism contract
//!
//! The simulator must stay a pure function of its configuration:
//!
//! * [`PolicyKind::LocalityBalance`] and [`PolicyKind::RoundRobin`] draw no
//!   random numbers at all: the policy layer itself adds no entropy, and a
//!   given build replays bit-identically from its configuration. (Note:
//!   schedules are *not* bit-identical across this PR — the same PR fixes
//!   eager load-estimate decay, which deterministically shifts default-
//!   policy placement relative to the pre-refactor scheduler. The choice
//!   *logic* of `LocalityBalance` is unchanged; the load inputs are more
//!   accurate.)
//! * [`PolicyKind::PowerOfTwoChoices`] uses a private [`Rng`] seeded from
//!   `PlatformConfig::seed` mixed with the scheduler index — never host
//!   entropy, and never the shared workload RNG (so enabling it does not
//!   perturb workload generation, and each scheduler's stream is
//!   independent of event interleaving).

use crate::config::{PolicyCfg, PolicyKind};
use crate::ids::CoreId;
use crate::noc::msg::ProducerRange;
use crate::sched::hierarchy::HierarchyMap;
use crate::sched::scoring::{balance_score, locality_score, pick_best};
use crate::sim::rng::Rng;

/// Enum-dispatched placement policy. Variants own their state (rotation
/// cursor, RNG) so a scheduler's policy is self-contained.
pub enum PlacePolicy {
    /// Paper V-E/VI-D: score every candidate on locality + load balance.
    LocalityBalance { p_locality: u32 },
    /// Rotate through candidates; loads and packs are ignored.
    RoundRobin { next: u64 },
    /// Sample two distinct candidates, keep the lighter-loaded one.
    PowerOfTwoChoices { rng: Rng },
}

impl PlacePolicy {
    /// Instantiate the policy a scheduler runs, deriving any RNG from the
    /// run seed and the scheduler index (see the determinism contract).
    pub fn new(cfg: &PolicyCfg, sched_idx: usize, seed: u64) -> Self {
        match cfg.kind {
            PolicyKind::LocalityBalance => {
                PlacePolicy::LocalityBalance { p_locality: cfg.p_locality }
            }
            PolicyKind::RoundRobin => PlacePolicy::RoundRobin { next: 0 },
            // The +1 keeps the mix non-degenerate for scheduler 0: a bare
            // `seed ^ 0` would clone the shared workload RNG's stream.
            PolicyKind::PowerOfTwoChoices => PlacePolicy::PowerOfTwoChoices {
                rng: Rng::new(seed ^ (sched_idx as u64 + 1).wrapping_mul(0xA24B_AED4_963E_E407)),
            },
        }
    }

    /// How many candidates this policy examines on an `n`-way choice —
    /// the multiplier for the `sc_score_per_child` cycle charge.
    pub fn scored(&self, n: usize) -> u64 {
        match self {
            PlacePolicy::LocalityBalance { .. } => n as u64,
            PlacePolicy::RoundRobin { .. } => 0,
            PlacePolicy::PowerOfTwoChoices { .. } => n.min(2) as u64,
        }
    }

    /// Choose among `n > 0` candidates. `members(i)` is candidate `i`'s
    /// worker set (for locality scoring; capacity is twice its size — the
    /// paper's "ready tasks twice the number of cores" operating point),
    /// `load(i)` its current load estimate. `scratch` is the reusable
    /// scoring buffer. Ties break to the lowest index (determinism).
    pub fn choose<'a>(
        &mut self,
        pack: &[ProducerRange],
        n: usize,
        members: impl Fn(usize) -> &'a [CoreId],
        load: impl Fn(usize) -> u64,
        scratch: &mut Vec<(u64, u64)>,
    ) -> usize {
        debug_assert!(n > 0);
        match self {
            PlacePolicy::LocalityBalance { p_locality } => {
                scratch.clear();
                for i in 0..n {
                    let m = members(i);
                    let l = locality_score(pack, m);
                    let b = balance_score(load(i), 2 * m.len() as u64);
                    scratch.push((l, b));
                }
                pick_best(*p_locality, scratch)
            }
            PlacePolicy::RoundRobin { next } => {
                let i = (*next % n as u64) as usize;
                *next += 1;
                i
            }
            PlacePolicy::PowerOfTwoChoices { rng } => {
                if n == 1 {
                    return 0;
                }
                let a = rng.below(n as u64) as usize;
                let mut b = rng.below(n as u64 - 1) as usize;
                if b >= a {
                    b += 1;
                }
                let (la, lb) = (load(a), load(b));
                if lb < la || (lb == la && b < a) {
                    b
                } else {
                    a
                }
            }
        }
    }
}

/// Dense load-estimate tables for one scheduler: one slot per child
/// scheduler and one per directly attached worker, plus an incrementally
/// maintained total. Estimates combine eager increments at placement,
/// decays at task completion, and authoritative overwrites from upstream
/// load reports (paper V-C).
pub struct LoadTracker {
    /// First child scheduler index (children are contiguous by
    /// construction — see `HierarchyMap::build`).
    child_base: usize,
    child: Vec<u64>,
    /// First attached worker core id (a leaf's workers directly follow its
    /// own core id).
    worker_base: u32,
    worker: Vec<u64>,
    total: u64,
}

impl LoadTracker {
    pub fn new(hier: &HierarchyMap, idx: usize) -> Self {
        let children = &hier.children[idx];
        let child_base = children.first().copied().unwrap_or(0);
        debug_assert!(
            children.iter().enumerate().all(|(i, &c)| c == child_base + i),
            "child scheduler indices must be contiguous"
        );
        let workers = &hier.leaf_workers[idx];
        let worker_base = workers.first().map(|w| w.0).unwrap_or(0);
        debug_assert!(
            workers.iter().enumerate().all(|(i, &w)| w.0 == worker_base + i as u32),
            "attached worker core ids must be contiguous"
        );
        LoadTracker {
            child_base,
            child: vec![0; children.len()],
            worker_base,
            worker: vec![0; workers.len()],
            total: 0,
        }
    }

    /// Slot of a child by its global scheduler index.
    #[inline]
    pub fn child_slot(&self, global: usize) -> usize {
        debug_assert!((global - self.child_base) < self.child.len());
        global - self.child_base
    }

    /// Slot of a directly attached worker by its core id.
    #[inline]
    pub fn worker_slot(&self, w: CoreId) -> usize {
        let s = (w.0 - self.worker_base) as usize;
        debug_assert!(s < self.worker.len());
        s
    }

    #[inline]
    pub fn child(&self, slot: usize) -> u64 {
        self.child[slot]
    }

    #[inline]
    pub fn worker(&self, slot: usize) -> u64 {
        self.worker[slot]
    }

    /// Eager estimate: a task was just sent down to this child.
    #[inline]
    pub fn bump_child(&mut self, slot: usize) {
        self.child[slot] += 1;
        self.total += 1;
    }

    /// Eager estimate: a task was just dispatched to this worker.
    #[inline]
    pub fn bump_worker(&mut self, slot: usize) {
        self.worker[slot] += 1;
        self.total += 1;
    }

    /// A task placed through this child completed: undo one eager unit.
    /// Saturating — an authoritative report may already have absorbed it.
    #[inline]
    pub fn decay_child(&mut self, slot: usize) {
        if self.child[slot] > 0 {
            self.child[slot] -= 1;
            self.total -= 1;
        }
    }

    #[inline]
    pub fn decay_worker(&mut self, slot: usize) {
        if self.worker[slot] > 0 {
            self.worker[slot] -= 1;
            self.total -= 1;
        }
    }

    /// Authoritative load report from a child scheduler.
    #[inline]
    pub fn set_child(&mut self, slot: usize, load: u64) {
        self.total = self.total - self.child[slot] + load;
        self.child[slot] = load;
    }

    /// Authoritative load report from an attached worker.
    #[inline]
    pub fn set_worker(&mut self, slot: usize, load: u64) {
        self.total = self.total - self.worker[slot] + load;
        self.worker[slot] = load;
    }

    /// Aggregate load (what this scheduler reports upstream). O(1).
    #[inline]
    pub fn total(&self) -> u64 {
        self.total
    }

    /// All child-slot estimates (diagnostics/tests).
    pub fn child_loads(&self) -> &[u64] {
        &self.child
    }

    /// All worker-slot estimates (diagnostics/tests).
    pub fn worker_loads(&self) -> &[u64] {
        &self.worker
    }
}

/// A scheduler's complete placement state: the policy, its load tables and
/// the reusable scoring scratch. This is the only object the protocol layer
/// talks to for placement and load accounting.
pub struct Placer {
    pub policy: PlacePolicy,
    pub loads: LoadTracker,
    scratch: Vec<(u64, u64)>,
}

impl Placer {
    pub fn new(cfg: &PolicyCfg, hier: &HierarchyMap, idx: usize, seed: u64) -> Self {
        Placer {
            policy: PlacePolicy::new(cfg, idx, seed),
            loads: LoadTracker::new(hier, idx),
            scratch: Vec::new(),
        }
    }

    /// Pick the child subtree for a task descending from scheduler `idx`
    /// and bump its eager load estimate. Returns the chosen child's global
    /// scheduler index plus the number of candidates scored (for cycle
    /// accounting).
    pub fn choose_child(
        &mut self,
        hier: &HierarchyMap,
        idx: usize,
        pack: &[ProducerRange],
    ) -> (usize, u64) {
        let children = &hier.children[idx];
        let n = children.len();
        let loads = &self.loads;
        let slot = self.policy.choose(
            pack,
            n,
            |i| hier.subtree_workers(children[i]),
            |i| loads.child(i),
            &mut self.scratch,
        );
        let scored = self.policy.scored(n);
        self.loads.bump_child(slot);
        (children[slot], scored)
    }

    /// Pick the worker for a task at leaf scheduler `idx` and bump its
    /// eager load estimate. Returns the worker core plus the number of
    /// candidates scored.
    pub fn choose_worker(
        &mut self,
        hier: &HierarchyMap,
        idx: usize,
        pack: &[ProducerRange],
    ) -> (CoreId, u64) {
        let workers = &hier.leaf_workers[idx];
        let n = workers.len();
        let loads = &self.loads;
        let slot = self.policy.choose(
            pack,
            n,
            |i| std::slice::from_ref(&workers[i]),
            |i| loads.worker(i),
            &mut self.scratch,
        );
        let scored = self.policy.scored(n);
        self.loads.bump_worker(slot);
        (workers[slot], scored)
    }

    /// Upstream load report from child scheduler `global`.
    pub fn child_report(&mut self, global: usize, load: u64) {
        let slot = self.loads.child_slot(global);
        self.loads.set_child(slot, load);
    }

    /// Load report from directly attached worker `w`.
    pub fn worker_report(&mut self, w: CoreId, load: u64) {
        let slot = self.loads.worker_slot(w);
        self.loads.set_worker(slot, load);
    }

    /// A task dispatched to attached worker `w` completed.
    pub fn worker_done(&mut self, w: CoreId) {
        let slot = self.loads.worker_slot(w);
        self.loads.decay_worker(slot);
    }

    /// A task this (non-leaf) scheduler placed down completed on worker
    /// `w`: decay the estimate of the child subtree containing it. This
    /// mirrors the worker-level refresh — without it the eager increments
    /// from `choose_child` are only ever corrected by child reports, and
    /// drift upward whenever reports are throttled.
    pub fn child_done(&mut self, hier: &HierarchyMap, idx: usize, w: CoreId) {
        if let Some(c) = hier.child_towards(idx, hier.leaf_of_worker(w)) {
            let slot = self.loads.child_slot(c);
            self.loads.decay_child(slot);
        }
    }

    /// Aggregate load estimate (reported upstream). O(1).
    pub fn total(&self) -> u64 {
        self.loads.total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HierarchySpec;

    fn pr(producer: u32, bytes: u64) -> ProducerRange {
        ProducerRange { producer: CoreId(producer), addr: 0, bytes }
    }

    fn two_level() -> HierarchyMap {
        // 1 top + 4 leaves, 16 workers (4 per leaf).
        HierarchyMap::build(16, &HierarchySpec::two_level(4))
    }

    #[test]
    fn locality_balance_matches_legacy_scoring() {
        let hier = two_level();
        // Pack produced entirely by the third leaf's workers: with a
        // locality-heavy blend that child must win.
        let mut placer_loc = Placer::new(&PolicyCfg::locality_balance(100), &hier, 0, 1);
        let third = hier.children[0][2];
        let w = hier.subtree_workers(third)[0];
        let pack = vec![pr(w.0, 4096)];
        let (chosen, scored) = placer_loc.choose_child(&hier, 0, &pack);
        assert_eq!(chosen, third);
        assert_eq!(scored, 4);
        // Balance-only blend with a loaded first child: avoid it.
        let mut placer_bal = Placer::new(&PolicyCfg::locality_balance(0), &hier, 0, 1);
        for _ in 0..8 {
            let slot = placer_bal.loads.child_slot(hier.children[0][0]);
            placer_bal.loads.bump_child(slot);
        }
        let (chosen, _) = placer_bal.choose_child(&hier, 0, &pack);
        assert_ne!(chosen, hier.children[0][0]);
    }

    #[test]
    fn round_robin_rotates() {
        let hier = two_level();
        let mut placer = Placer::new(&PolicyCfg::round_robin(), &hier, 0, 1);
        let picks: Vec<usize> = (0..6).map(|_| placer.choose_child(&hier, 0, &[]).0).collect();
        let c = &hier.children[0];
        assert_eq!(picks, vec![c[0], c[1], c[2], c[3], c[0], c[1]]);
        // No candidates are scored: the per-child cycle charge is zero.
        assert_eq!(placer.policy.scored(4), 0);
    }

    #[test]
    fn round_robin_workers_at_leaf() {
        let hier = two_level();
        let leaf = hier.children[0][0];
        let mut placer = Placer::new(&PolicyCfg::round_robin(), &hier, leaf, 1);
        let a = placer.choose_worker(&hier, leaf, &[]).0;
        let b = placer.choose_worker(&hier, leaf, &[]).0;
        assert_ne!(a, b);
        assert_eq!(placer.total(), 2);
    }

    #[test]
    fn p2c_is_deterministic_and_prefers_lighter() {
        let hier = two_level();
        let run = || {
            let mut placer = Placer::new(&PolicyCfg::power_of_two(), &hier, 0, 0xB5EED);
            (0..32).map(|_| placer.choose_child(&hier, 0, &[]).0).collect::<Vec<_>>()
        };
        assert_eq!(run(), run(), "p2c must replay bit-identically from the seed");
        // With one candidate massively loaded, p2c must essentially never
        // pick it (only when both samples land on it — impossible, the two
        // samples are distinct).
        let mut placer = Placer::new(&PolicyCfg::power_of_two(), &hier, 0, 7);
        let heavy = hier.children[0][1];
        let slot = placer.loads.child_slot(heavy);
        for _ in 0..1000 {
            placer.loads.bump_child(slot);
        }
        for _ in 0..64 {
            let (c, scored) = placer.choose_child(&hier, 0, &[]);
            assert_ne!(c, heavy, "two-choice must dodge the overloaded child");
            assert_eq!(scored, 2);
        }
    }

    #[test]
    fn p2c_single_candidate_needs_no_rng() {
        let hier = HierarchyMap::build(4, &HierarchySpec::two_level(1));
        let mut placer = Placer::new(&PolicyCfg::power_of_two(), &hier, 0, 3);
        let only = hier.children[0][0];
        assert_eq!(placer.choose_child(&hier, 0, &[]).0, only);
    }

    #[test]
    fn tracker_total_tracks_all_mutations() {
        let hier = two_level();
        let leaf = hier.children[0][0];
        let mut t = LoadTracker::new(&hier, leaf);
        assert_eq!(t.total(), 0);
        t.bump_worker(0);
        t.bump_worker(1);
        t.bump_worker(1);
        assert_eq!(t.total(), 3);
        assert_eq!(t.worker(1), 2);
        t.decay_worker(1);
        assert_eq!(t.total(), 2);
        // Saturating decay: an already-drained slot is a no-op.
        t.decay_worker(3);
        assert_eq!(t.total(), 2);
        // Authoritative report overwrites, total follows.
        t.set_worker(0, 5);
        assert_eq!(t.total(), 6);
        t.set_worker(0, 0);
        t.set_worker(1, 0);
        assert_eq!(t.total(), 0);
    }

    #[test]
    fn tracker_maps_globals_to_dense_slots() {
        let hier = two_level();
        let t = LoadTracker::new(&hier, 0);
        for (i, &c) in hier.children[0].iter().enumerate() {
            assert_eq!(t.child_slot(c), i);
        }
        let leaf = hier.children[0][2];
        let tl = LoadTracker::new(&hier, leaf);
        for (i, &w) in hier.leaf_workers[leaf].iter().enumerate() {
            assert_eq!(tl.worker_slot(w), i);
        }
        assert_eq!(tl.child_loads().len(), 0);
        assert_eq!(tl.worker_loads().len(), 4);
    }

    #[test]
    fn child_done_decays_the_covering_subtree() {
        let hier = HierarchyMap::build(36, &HierarchySpec::multi_level(3, 2));
        // Tree: 0 -> (1,2); 1 -> (3,4); 2 -> (5,6).
        let mut placer = Placer::new(&PolicyCfg::default(), &hier, 0, 1);
        let slot1 = placer.loads.child_slot(1);
        placer.loads.bump_child(slot1);
        assert_eq!(placer.total(), 1);
        let w = hier.leaf_workers[3][0]; // under child 1
        placer.child_done(&hier, 0, w);
        assert_eq!(placer.total(), 0);
        // A completion under child 2 with a drained slot stays saturated.
        let w2 = hier.leaf_workers[5][0];
        placer.child_done(&hier, 0, w2);
        assert_eq!(placer.total(), 0);
    }
}

//! Pluggable placement policies + dense load tracking (paper V-E, VI-D).
//!
//! This module is the *policy seam* carved out of the scheduler: everything
//! that decides **where** a dependency-free task goes — candidate scoring,
//! the locality/load-balance blend, eager load estimates and their
//! refresh/decay — lives here, while `sched::scheduler` keeps only the
//! protocol (messages, traversal, packing). The split is what lets the
//! `policy` experiment sweep placement strategies without touching the
//! protocol code, and what future work-stealing / admission-control PRs
//! plug into.
//!
//! # Hot-path discipline
//!
//! Placement runs once per task on the per-event path, so the same PR-1
//! invariant applies: **no steady-state heap allocation, no hash or tree
//! lookups, enum dispatch only** (no `dyn`). Concretely:
//!
//! * [`PlacePolicy`] is an enum; `match` dispatch keeps the choice branch
//!   predictable and inlinable.
//! * [`LoadTracker`] replaces the scheduler's old `BTreeMap<usize, u64>` /
//!   `BTreeMap<u32, u64>` child/worker load maps with dense `Vec`-indexed
//!   tables. Child scheduler indices and worker core ids are assigned in
//!   contiguous blocks by [`HierarchyMap::build`], so a slot is a subtract
//!   and an index — the last hashing/tree probe on the placement path is
//!   gone. The tracker also maintains the load total incrementally, making
//!   the upstream load report O(1) instead of a map scan.
//! * Scoring scratch lives in the [`Placer`], reused across placements.
//!
//! # Determinism contract
//!
//! The simulator must stay a pure function of its configuration:
//!
//! * [`PolicyKind::LocalityBalance`] and [`PolicyKind::RoundRobin`] draw no
//!   random numbers at all: the policy layer itself adds no entropy, and a
//!   given build replays bit-identically from its configuration. (Note:
//!   schedules are *not* bit-identical across this PR — the same PR fixes
//!   eager load-estimate decay, which deterministically shifts default-
//!   policy placement relative to the pre-refactor scheduler. The choice
//!   *logic* of `LocalityBalance` is unchanged; the load inputs are more
//!   accurate.)
//! * [`PolicyKind::PowerOfTwoChoices`] uses a private [`Rng`] seeded from
//!   `PlatformConfig::seed` mixed with the scheduler index — never host
//!   entropy, and never the shared workload RNG (so enabling it does not
//!   perturb workload generation, and each scheduler's stream is
//!   independent of event interleaving).

use crate::config::{AdmissionKind, PolicyCfg, PolicyKind, StealCfg, TrafficCfg, VictimKind};
use crate::ids::CoreId;
use crate::noc::msg::ProducerRange;
use crate::sched::hierarchy::HierarchyMap;
use crate::sched::scoring::{balance_score, locality_score, pick_best};
use crate::sim::rng::Rng;

/// Per-worker ready-queue capacity the dispatch throttle targets when
/// stealing is enabled: a worker double-buffers (one running + one
/// prefetching, paper V-E), so two outstanding tasks keep it fed and
/// anything deeper is better held where it can still migrate. This is the
/// same "twice the number of cores" operating point the balance score
/// uses as subtree capacity.
pub const WORKER_QUEUE_CAP: u64 = 2;

/// Enum-dispatched placement policy. Variants own their state (rotation
/// cursor, RNG) so a scheduler's policy is self-contained.
pub enum PlacePolicy {
    /// Paper V-E/VI-D: score every candidate on locality + load balance.
    LocalityBalance { p_locality: u32 },
    /// Rotate through candidates; loads and packs are ignored.
    RoundRobin { next: u64 },
    /// Sample two distinct candidates, keep the lighter-loaded one.
    PowerOfTwoChoices { rng: Rng },
}

impl PlacePolicy {
    /// Instantiate the policy a scheduler runs, deriving any RNG from the
    /// run seed and the scheduler index (see the determinism contract).
    pub fn new(cfg: &PolicyCfg, sched_idx: usize, seed: u64) -> Self {
        match cfg.kind {
            PolicyKind::LocalityBalance => {
                PlacePolicy::LocalityBalance { p_locality: cfg.p_locality }
            }
            PolicyKind::RoundRobin => PlacePolicy::RoundRobin { next: 0 },
            // The +1 keeps the mix non-degenerate for scheduler 0: a bare
            // `seed ^ 0` would clone the shared workload RNG's stream.
            PolicyKind::PowerOfTwoChoices => PlacePolicy::PowerOfTwoChoices {
                rng: Rng::new(seed ^ (sched_idx as u64 + 1).wrapping_mul(0xA24B_AED4_963E_E407)),
            },
        }
    }

    /// How many candidates this policy examines on an `n`-way choice —
    /// the multiplier for the `sc_score_per_child` cycle charge.
    pub fn scored(&self, n: usize) -> u64 {
        match self {
            PlacePolicy::LocalityBalance { .. } => n as u64,
            PlacePolicy::RoundRobin { .. } => 0,
            PlacePolicy::PowerOfTwoChoices { .. } => n.min(2) as u64,
        }
    }

    /// Choose among `n > 0` candidates. `members(i)` is candidate `i`'s
    /// worker set (for locality scoring; capacity is twice its size — the
    /// paper's "ready tasks twice the number of cores" operating point),
    /// `load(i)` its current load estimate. `scratch` is the reusable
    /// scoring buffer. Ties break to the lowest index (determinism).
    pub fn choose<'a>(
        &mut self,
        pack: &[ProducerRange],
        n: usize,
        members: impl Fn(usize) -> &'a [CoreId],
        load: impl Fn(usize) -> u64,
        scratch: &mut Vec<(u64, u64)>,
    ) -> usize {
        debug_assert!(n > 0);
        match self {
            PlacePolicy::LocalityBalance { p_locality } => {
                scratch.clear();
                for i in 0..n {
                    let m = members(i);
                    let l = locality_score(pack, m);
                    let b = balance_score(load(i), 2 * m.len() as u64);
                    scratch.push((l, b));
                }
                pick_best(*p_locality, scratch)
            }
            PlacePolicy::RoundRobin { next } => {
                let i = (*next % n as u64) as usize;
                *next += 1;
                i
            }
            PlacePolicy::PowerOfTwoChoices { rng } => {
                if n == 1 {
                    return 0;
                }
                let a = rng.below(n as u64) as usize;
                let mut b = rng.below(n as u64 - 1) as usize;
                if b >= a {
                    b += 1;
                }
                let (la, lb) = (load(a), load(b));
                if lb < la || (lb == la && b < a) {
                    b
                } else {
                    a
                }
            }
        }
    }
}

/// Victim selection for the idle-driven rebalance protocol: which loaded
/// child subtree a scheduler asks for queued-ready tasks when a sibling
/// idles. Lives here (not in the scheduler) per the policy-seam contract —
/// and obeys the same determinism rules as [`PlacePolicy`]: the default is
/// draw-free, the randomized variant uses only the per-scheduler RNG
/// derived from the run seed.
pub enum VictimPolicy {
    /// The most loaded eligible child; ties break to the lowest index.
    MaxLoad,
    /// Uniform among eligible children (load >= threshold).
    Random { rng: Rng },
}

impl VictimPolicy {
    pub fn new(cfg: &StealCfg, sched_idx: usize, seed: u64) -> Self {
        match cfg.victim {
            VictimKind::MaxLoad => VictimPolicy::MaxLoad,
            // A different odd mixer than PowerOfTwoChoices, so a scheduler
            // running both randomized policies has two independent streams.
            VictimKind::Random => VictimPolicy::Random {
                rng: Rng::new(
                    seed ^ (sched_idx as u64 + 1).wrapping_mul(0xD6E8_FEB8_6659_FD93),
                ),
            },
        }
    }

    /// Pick a victim slot among `n` children whose `load(i) >= threshold`,
    /// or `None` when no child is eligible.
    pub fn choose(
        &mut self,
        n: usize,
        load: impl Fn(usize) -> u64,
        threshold: u64,
    ) -> Option<usize> {
        match self {
            VictimPolicy::MaxLoad => {
                let mut best: Option<(usize, u64)> = None;
                for i in 0..n {
                    let l = load(i);
                    let better = match best {
                        None => true,
                        Some((_, bl)) => l > bl,
                    };
                    if l >= threshold && better {
                        best = Some((i, l));
                    }
                }
                best.map(|(i, _)| i)
            }
            VictimPolicy::Random { rng } => {
                let eligible = (0..n).filter(|&i| load(i) >= threshold).count();
                if eligible == 0 {
                    return None;
                }
                let k = rng.below(eligible as u64) as usize;
                (0..n).filter(|&i| load(i) >= threshold).nth(k)
            }
        }
    }
}

/// Dense load-estimate tables for one scheduler: one slot per child
/// scheduler and one per directly attached worker, plus an incrementally
/// maintained total. Estimates combine eager increments at placement,
/// decays at task completion, and authoritative overwrites from upstream
/// load reports (paper V-C).
pub struct LoadTracker {
    /// First child scheduler index (children are contiguous by
    /// construction — see `HierarchyMap::build`).
    child_base: usize,
    child: Vec<u64>,
    /// First attached worker core id (a leaf's workers directly follow its
    /// own core id).
    worker_base: u32,
    worker: Vec<u64>,
    total: u64,
    /// Crash recovery: children currently marked dead (scheduler down,
    /// subtree re-adopted). Dead slots are pinned at load 0 and excluded
    /// from every placement / victim / headroom decision until a `Rejoin`
    /// clears the mark.
    dead: Vec<bool>,
    n_dead: usize,
}

impl LoadTracker {
    pub fn new(hier: &HierarchyMap, idx: usize) -> Self {
        let children = &hier.children[idx];
        let child_base = children.first().copied().unwrap_or(0);
        debug_assert!(
            children.iter().enumerate().all(|(i, &c)| c == child_base + i),
            "child scheduler indices must be contiguous"
        );
        let workers = &hier.leaf_workers[idx];
        let worker_base = workers.first().map(|w| w.0).unwrap_or(0);
        debug_assert!(
            workers.iter().enumerate().all(|(i, &w)| w.0 == worker_base + i as u32),
            "attached worker core ids must be contiguous"
        );
        LoadTracker {
            child_base,
            child: vec![0; children.len()],
            worker_base,
            worker: vec![0; workers.len()],
            total: 0,
            dead: vec![false; children.len()],
            n_dead: 0,
        }
    }

    /// Slot of a child by its global scheduler index.
    #[inline]
    pub fn child_slot(&self, global: usize) -> usize {
        debug_assert!((global - self.child_base) < self.child.len());
        global - self.child_base
    }

    /// Slot of a directly attached worker by its core id.
    #[inline]
    pub fn worker_slot(&self, w: CoreId) -> usize {
        let s = (w.0 - self.worker_base) as usize;
        debug_assert!(s < self.worker.len());
        s
    }

    #[inline]
    pub fn child(&self, slot: usize) -> u64 {
        self.child[slot]
    }

    #[inline]
    pub fn worker(&self, slot: usize) -> u64 {
        self.worker[slot]
    }

    /// Eager estimate: a task was just sent down to this child.
    #[inline]
    pub fn bump_child(&mut self, slot: usize) {
        self.child[slot] += 1;
        self.total += 1;
    }

    /// Eager estimate: a task was just dispatched to this worker.
    #[inline]
    pub fn bump_worker(&mut self, slot: usize) {
        self.worker[slot] += 1;
        self.total += 1;
    }

    /// A task placed through this child completed: undo one eager unit.
    /// Saturating — an authoritative report may already have absorbed it.
    #[inline]
    pub fn decay_child(&mut self, slot: usize) {
        if self.child[slot] > 0 {
            self.child[slot] -= 1;
            self.total -= 1;
        }
    }

    #[inline]
    pub fn decay_worker(&mut self, slot: usize) {
        if self.worker[slot] > 0 {
            self.worker[slot] -= 1;
            self.total -= 1;
        }
    }

    /// Authoritative load report from a child scheduler.
    #[inline]
    pub fn set_child(&mut self, slot: usize, load: u64) {
        self.total = self.total - self.child[slot] + load;
        self.child[slot] = load;
    }

    /// Authoritative load report from an attached worker.
    #[inline]
    pub fn set_worker(&mut self, slot: usize, load: u64) {
        self.total = self.total - self.worker[slot] + load;
        self.worker[slot] = load;
    }

    /// Aggregate load (what this scheduler reports upstream). O(1).
    #[inline]
    pub fn total(&self) -> u64 {
        self.total
    }

    /// All child-slot estimates (diagnostics/tests).
    pub fn child_loads(&self) -> &[u64] {
        &self.child
    }

    /// All worker-slot estimates (diagnostics/tests).
    pub fn worker_loads(&self) -> &[u64] {
        &self.worker
    }

    // -------------------------------------------------- crash-recovery marks

    /// Mark a child dead: its book zeroes (the work it held is being
    /// re-placed elsewhere by the recovery scan) and the slot drops out of
    /// every decision until cleared. Idempotent.
    pub fn set_child_dead(&mut self, slot: usize) {
        if !self.dead[slot] {
            self.dead[slot] = true;
            self.n_dead += 1;
        }
        self.set_child(slot, 0);
    }

    /// A restarted child rejoined: it is placeable again. Its book stays
    /// at whatever the fresh post-restart `LoadReport` set it to.
    pub fn clear_child_dead(&mut self, slot: usize) {
        if self.dead[slot] {
            self.dead[slot] = false;
            self.n_dead -= 1;
        }
    }

    #[inline]
    pub fn child_dead(&self, slot: usize) -> bool {
        self.dead[slot]
    }

    #[inline]
    pub fn any_dead(&self) -> bool {
        self.n_dead > 0
    }
}

/// A scheduler's complete placement state: the policy, its load tables and
/// the reusable scoring scratch. This is the only object the protocol layer
/// talks to for placement and load accounting.
pub struct Placer {
    pub policy: PlacePolicy,
    pub loads: LoadTracker,
    /// Work-stealing knobs + victim selection (policy side of the
    /// rebalance protocol; the scheduler owns only the messages).
    steal: StealCfg,
    victim: VictimPolicy,
    scratch: Vec<(u64, u64)>,
}

impl Placer {
    pub fn new(cfg: &PolicyCfg, hier: &HierarchyMap, idx: usize, seed: u64) -> Self {
        Placer {
            policy: PlacePolicy::new(cfg, idx, seed),
            loads: LoadTracker::new(hier, idx),
            steal: cfg.steal,
            victim: VictimPolicy::new(&cfg.steal, idx, seed),
            scratch: Vec::new(),
        }
    }

    /// The run's stealing configuration (copied from `PolicyCfg`).
    pub fn steal_cfg(&self) -> StealCfg {
        self.steal
    }

    /// Pick the child subtree for a task descending from scheduler `idx`
    /// and bump its eager load estimate. Returns the chosen child's global
    /// scheduler index plus the number of candidates scored (for cycle
    /// accounting).
    pub fn choose_child(
        &mut self,
        hier: &HierarchyMap,
        idx: usize,
        pack: &[ProducerRange],
    ) -> (usize, u64) {
        let children = &hier.children[idx];
        let n = children.len();
        if self.loads.any_dead() {
            // Crash recovery in progress: score only surviving children.
            // This path runs at most for one outage window per run, so a
            // local candidate list (allocation) is fine here — the common
            // no-dead path below stays allocation-free.
            let live: Vec<usize> =
                (0..n).filter(|&i| !self.loads.child_dead(i)).collect();
            debug_assert!(!live.is_empty(), "placement with every child dead");
            let loads = &self.loads;
            let k = self.policy.choose(
                pack,
                live.len(),
                |i| hier.subtree_workers(children[live[i]]),
                |i| loads.child(live[i]),
                &mut self.scratch,
            );
            let scored = self.policy.scored(live.len());
            let slot = live[k];
            self.loads.bump_child(slot);
            return (children[slot], scored);
        }
        let loads = &self.loads;
        let slot = self.policy.choose(
            pack,
            n,
            |i| hier.subtree_workers(children[i]),
            |i| loads.child(i),
            &mut self.scratch,
        );
        let scored = self.policy.scored(n);
        self.loads.bump_child(slot);
        (children[slot], scored)
    }

    /// Pick the worker for a task at leaf scheduler `idx` and bump its
    /// eager load estimate. Returns the worker core plus the number of
    /// candidates scored.
    pub fn choose_worker(
        &mut self,
        hier: &HierarchyMap,
        idx: usize,
        pack: &[ProducerRange],
    ) -> (CoreId, u64) {
        let workers = &hier.leaf_workers[idx];
        let n = workers.len();
        let loads = &self.loads;
        let slot = self.policy.choose(
            pack,
            n,
            |i| std::slice::from_ref(&workers[i]),
            |i| loads.worker(i),
            &mut self.scratch,
        );
        let scored = self.policy.scored(n);
        self.loads.bump_worker(slot);
        (workers[slot], scored)
    }

    /// Upstream load report from child scheduler `global`.
    pub fn child_report(&mut self, global: usize, load: u64) {
        let slot = self.loads.child_slot(global);
        self.loads.set_child(slot, load);
    }

    /// Load report from directly attached worker `w`.
    pub fn worker_report(&mut self, w: CoreId, load: u64) {
        let slot = self.loads.worker_slot(w);
        self.loads.set_worker(slot, load);
    }

    /// A task dispatched to attached worker `w` completed.
    pub fn worker_done(&mut self, w: CoreId) {
        let slot = self.loads.worker_slot(w);
        self.loads.decay_worker(slot);
    }

    /// A task this (non-leaf) scheduler placed down completed on worker
    /// `w`: decay the estimate of the child subtree containing it. This
    /// mirrors the worker-level refresh — without it the eager increments
    /// from `choose_child` are only ever corrected by child reports, and
    /// drift upward whenever reports are throttled.
    pub fn child_done(&mut self, hier: &HierarchyMap, idx: usize, w: CoreId) {
        if let Some(c) = hier.child_towards(idx, hier.leaf_of_worker(w)) {
            let slot = self.loads.child_slot(c);
            self.loads.decay_child(slot);
        }
    }

    /// Aggregate load estimate (reported upstream). O(1).
    pub fn total(&self) -> u64 {
        self.loads.total()
    }

    // --------------------------------------------------- admission control

    /// Decentralized traffic-admission decision (`sim::traffic`): should
    /// this scheduler admit an arriving job of a tenant that currently
    /// has `tenant_live` live jobs? Consumes only state already at hand —
    /// the O(1) aggregate load estimate and the tenant book — so the
    /// decision costs one branch and never messages another scheduler.
    /// `false` means defer: the caller re-arms a deterministic backoff
    /// retry timer.
    pub fn admit_job(&self, t: &TrafficCfg, tenant_live: u32) -> bool {
        match t.admission {
            AdmissionKind::AdmitAll => true,
            AdmissionKind::TenantCap => tenant_live < t.tenant_cap.max(1),
            AdmissionKind::LoadThreshold => self.total() < t.load_threshold.max(1),
        }
    }

    // ------------------------------------------------- work-stealing hooks

    /// Dispatch throttle (stealing enabled only): is any placement target
    /// below its capacity? Children cap at twice their subtree's worker
    /// count (the balance score's operating point); attached workers cap
    /// at [`WORKER_QUEUE_CAP`]. While false, ready tasks stay in the
    /// scheduler's `ReadyQ`, where they remain migratable.
    pub fn has_headroom(&self, hier: &HierarchyMap, idx: usize) -> bool {
        let children = &hier.children[idx];
        if children.is_empty() {
            let n = hier.leaf_workers[idx].len();
            (0..n).any(|i| self.loads.worker(i) < WORKER_QUEUE_CAP)
        } else {
            (0..children.len()).any(|i| {
                !self.loads.child_dead(i)
                    && self.loads.child(i) < 2 * hier.subtree_workers(children[i]).len() as u64
            })
        }
    }

    /// Steal trigger: when some child subtree sits at load 0 while a
    /// sibling is at/above the configured threshold, pick the victim
    /// (policy-dependent) and return its *global* scheduler index.
    pub fn choose_victim(&mut self, hier: &HierarchyMap, idx: usize) -> Option<usize> {
        let children = &hier.children[idx];
        let n = children.len();
        if n < 2 {
            return None;
        }
        let loads = &self.loads;
        // The trigger needs a *live* idle child — a dead subtree sits at
        // load 0 but cannot absorb work, so it must not look like a
        // starving sibling.
        if !(0..n).any(|i| !loads.child_dead(i) && loads.child(i) == 0) {
            return None;
        }
        let thr = self.steal.threshold.max(1);
        // Dead slots are pinned at load 0 < threshold, so they can never
        // be chosen as victims; the explicit map keeps that true even if a
        // stale estimate ever leaked in.
        let slot =
            self.victim.choose(n, |i| if loads.child_dead(i) { 0 } else { loads.child(i) }, thr)?;
        Some(children[slot])
    }

    /// Destination for one stolen task: the least-loaded child subtree
    /// *other than the victim* (ties to the lowest index —
    /// deterministic), bumped eagerly like any placement. Excluding the
    /// victim is load-bearing: after `victim_stolen` decays its estimate,
    /// a load tie could otherwise route the task straight back where it
    /// was stolen from (wasted messages, and with `batch >= threshold` a
    /// potential thief->victim->thief ping-pong). `choose_victim`
    /// requires >= 2 children, so a non-victim candidate always exists.
    /// Returns (global child index, candidates scored).
    pub fn steal_dest(
        &mut self,
        hier: &HierarchyMap,
        idx: usize,
        victim_global: usize,
    ) -> (usize, u64) {
        let children = &hier.children[idx];
        debug_assert!(children.len() >= 2, "steal_dest needs a sibling to route to");
        let vslot = self.loads.child_slot(victim_global);
        let mut best: Option<usize> = None;
        for i in 0..children.len() {
            if i == vslot || self.loads.child_dead(i) {
                continue;
            }
            let better = match best {
                None => true,
                Some(b) => self.loads.child(i) < self.loads.child(b),
            };
            if better {
                best = Some(i);
            }
        }
        // Every sibling dead (a grant that was already in flight when the
        // outage hit): keep the tasks where they are — the victim is the
        // only live subtree left.
        let best = best.unwrap_or(vslot);
        self.loads.bump_child(best);
        (children[best], children.len() as u64)
    }

    // ------------------------------------------------ crash-recovery hooks

    /// Mark child scheduler `global` dead (zero book, excluded from every
    /// decision) after a missed-heartbeat detection.
    pub fn mark_child_dead(&mut self, global: usize) {
        let slot = self.loads.child_slot(global);
        self.loads.set_child_dead(slot);
    }

    /// A restarted child rejoined: make it placeable again.
    pub fn mark_child_alive(&mut self, global: usize) {
        let slot = self.loads.child_slot(global);
        self.loads.clear_child_dead(slot);
    }

    /// Is child scheduler `global` currently marked dead?
    pub fn child_is_dead(&self, global: usize) -> bool {
        self.loads.child_dead(self.loads.child_slot(global))
    }

    /// Rebuild the load books from scratch. A restarted scheduler lost its
    /// volatile placement state; authoritative reports (a fresh
    /// unconditional one is requested from every attached worker on
    /// `Adopt`) repopulate the zeroed slots.
    pub fn reset_loads(&mut self, hier: &HierarchyMap, idx: usize) {
        self.loads = LoadTracker::new(hier, idx);
    }

    /// `n` queued-ready tasks just migrated out of child `victim_global`:
    /// undo their share of its load estimate (saturating, like every
    /// decay — an authoritative report may already have absorbed them).
    pub fn victim_stolen(&mut self, victim_global: usize, n: u64) {
        let slot = self.loads.child_slot(victim_global);
        for _ in 0..n {
            self.loads.decay_child(slot);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HierarchySpec;

    fn pr(producer: u32, bytes: u64) -> ProducerRange {
        ProducerRange { producer: CoreId(producer), addr: 0, bytes }
    }

    fn two_level() -> HierarchyMap {
        // 1 top + 4 leaves, 16 workers (4 per leaf).
        HierarchyMap::build(16, &HierarchySpec::two_level(4))
    }

    #[test]
    fn locality_balance_matches_legacy_scoring() {
        let hier = two_level();
        // Pack produced entirely by the third leaf's workers: with a
        // locality-heavy blend that child must win.
        let mut placer_loc = Placer::new(&PolicyCfg::locality_balance(100), &hier, 0, 1);
        let third = hier.children[0][2];
        let w = hier.subtree_workers(third)[0];
        let pack = vec![pr(w.0, 4096)];
        let (chosen, scored) = placer_loc.choose_child(&hier, 0, &pack);
        assert_eq!(chosen, third);
        assert_eq!(scored, 4);
        // Balance-only blend with a loaded first child: avoid it.
        let mut placer_bal = Placer::new(&PolicyCfg::locality_balance(0), &hier, 0, 1);
        for _ in 0..8 {
            let slot = placer_bal.loads.child_slot(hier.children[0][0]);
            placer_bal.loads.bump_child(slot);
        }
        let (chosen, _) = placer_bal.choose_child(&hier, 0, &pack);
        assert_ne!(chosen, hier.children[0][0]);
    }

    #[test]
    fn admission_policies_read_local_state_only() {
        let hier = two_level();
        let mut placer = Placer::new(&PolicyCfg::default(), &hier, 0, 1);
        // Admit-all: always yes, whatever the books say.
        let t = TrafficCfg::on(8, 2);
        assert!(placer.admit_job(&t, 0));
        assert!(placer.admit_job(&t, 1_000));
        // Tenant cap: defers exactly at the cap.
        let t = TrafficCfg::on(8, 2).with_admission(AdmissionKind::TenantCap);
        assert!(placer.admit_job(&t, t.tenant_cap - 1));
        assert!(!placer.admit_job(&t, t.tenant_cap));
        // A zero cap clamps to one so a tenant can never be starved
        // forever.
        let mut z = t.clone();
        z.tenant_cap = 0;
        assert!(placer.admit_job(&z, 0));
        assert!(!placer.admit_job(&z, 1));
        // Load threshold: keys off the placer's aggregate estimate.
        let mut t = TrafficCfg::on(8, 2).with_admission(AdmissionKind::LoadThreshold);
        t.load_threshold = 3;
        assert!(placer.admit_job(&t, 0));
        let slot = placer.loads.child_slot(hier.children[0][0]);
        for _ in 0..3 {
            placer.loads.bump_child(slot);
        }
        assert!(!placer.admit_job(&t, 0), "at the threshold the job defers");
        // An idle subtree always admits even with threshold 0 (clamped).
        let idle = Placer::new(&PolicyCfg::default(), &hier, 0, 1);
        t.load_threshold = 0;
        assert!(idle.admit_job(&t, 0));
    }

    #[test]
    fn round_robin_rotates() {
        let hier = two_level();
        let mut placer = Placer::new(&PolicyCfg::round_robin(), &hier, 0, 1);
        let picks: Vec<usize> = (0..6).map(|_| placer.choose_child(&hier, 0, &[]).0).collect();
        let c = &hier.children[0];
        assert_eq!(picks, vec![c[0], c[1], c[2], c[3], c[0], c[1]]);
        // No candidates are scored: the per-child cycle charge is zero.
        assert_eq!(placer.policy.scored(4), 0);
    }

    #[test]
    fn round_robin_workers_at_leaf() {
        let hier = two_level();
        let leaf = hier.children[0][0];
        let mut placer = Placer::new(&PolicyCfg::round_robin(), &hier, leaf, 1);
        let a = placer.choose_worker(&hier, leaf, &[]).0;
        let b = placer.choose_worker(&hier, leaf, &[]).0;
        assert_ne!(a, b);
        assert_eq!(placer.total(), 2);
    }

    #[test]
    fn p2c_is_deterministic_and_prefers_lighter() {
        let hier = two_level();
        let run = || {
            let mut placer = Placer::new(&PolicyCfg::power_of_two(), &hier, 0, 0xB5EED);
            (0..32).map(|_| placer.choose_child(&hier, 0, &[]).0).collect::<Vec<_>>()
        };
        assert_eq!(run(), run(), "p2c must replay bit-identically from the seed");
        // With one candidate massively loaded, p2c must essentially never
        // pick it (only when both samples land on it — impossible, the two
        // samples are distinct).
        let mut placer = Placer::new(&PolicyCfg::power_of_two(), &hier, 0, 7);
        let heavy = hier.children[0][1];
        let slot = placer.loads.child_slot(heavy);
        for _ in 0..1000 {
            placer.loads.bump_child(slot);
        }
        for _ in 0..64 {
            let (c, scored) = placer.choose_child(&hier, 0, &[]);
            assert_ne!(c, heavy, "two-choice must dodge the overloaded child");
            assert_eq!(scored, 2);
        }
    }

    #[test]
    fn p2c_single_candidate_needs_no_rng() {
        let hier = HierarchyMap::build(4, &HierarchySpec::two_level(1));
        let mut placer = Placer::new(&PolicyCfg::power_of_two(), &hier, 0, 3);
        let only = hier.children[0][0];
        assert_eq!(placer.choose_child(&hier, 0, &[]).0, only);
    }

    #[test]
    fn tracker_total_tracks_all_mutations() {
        let hier = two_level();
        let leaf = hier.children[0][0];
        let mut t = LoadTracker::new(&hier, leaf);
        assert_eq!(t.total(), 0);
        t.bump_worker(0);
        t.bump_worker(1);
        t.bump_worker(1);
        assert_eq!(t.total(), 3);
        assert_eq!(t.worker(1), 2);
        t.decay_worker(1);
        assert_eq!(t.total(), 2);
        // Saturating decay: an already-drained slot is a no-op.
        t.decay_worker(3);
        assert_eq!(t.total(), 2);
        // Authoritative report overwrites, total follows.
        t.set_worker(0, 5);
        assert_eq!(t.total(), 6);
        t.set_worker(0, 0);
        t.set_worker(1, 0);
        assert_eq!(t.total(), 0);
    }

    #[test]
    fn tracker_maps_globals_to_dense_slots() {
        let hier = two_level();
        let t = LoadTracker::new(&hier, 0);
        for (i, &c) in hier.children[0].iter().enumerate() {
            assert_eq!(t.child_slot(c), i);
        }
        let leaf = hier.children[0][2];
        let tl = LoadTracker::new(&hier, leaf);
        for (i, &w) in hier.leaf_workers[leaf].iter().enumerate() {
            assert_eq!(tl.worker_slot(w), i);
        }
        assert_eq!(tl.child_loads().len(), 0);
        assert_eq!(tl.worker_loads().len(), 4);
    }

    #[test]
    fn headroom_tracks_capacity_at_both_levels() {
        let hier = two_level();
        // Top: 4 children x 4 workers => per-child cap 8.
        let mut top = Placer::new(&PolicyCfg::default(), &hier, 0, 1);
        assert!(top.has_headroom(&hier, 0));
        for c in &hier.children[0] {
            let slot = top.loads.child_slot(*c);
            for _ in 0..8 {
                top.loads.bump_child(slot);
            }
        }
        assert!(!top.has_headroom(&hier, 0), "all children at 2x capacity");
        top.loads.decay_child(0);
        assert!(top.has_headroom(&hier, 0));
        // Leaf: 4 workers, cap WORKER_QUEUE_CAP each.
        let leaf = hier.children[0][1];
        let mut lp = Placer::new(&PolicyCfg::default(), &hier, leaf, 1);
        for slot in 0..4 {
            for _ in 0..WORKER_QUEUE_CAP {
                lp.loads.bump_worker(slot as usize);
            }
        }
        assert!(!lp.has_headroom(&hier, leaf));
        lp.loads.decay_worker(2);
        assert!(lp.has_headroom(&hier, leaf));
    }

    #[test]
    fn victim_needs_an_idle_sibling_and_a_loaded_one() {
        let hier = two_level();
        let cfg = PolicyCfg::default().with_steal(StealCfg::on());
        let mut p = Placer::new(&cfg, &hier, 0, 1);
        // All idle: nothing worth stealing.
        assert_eq!(p.choose_victim(&hier, 0), None);
        // One loaded child above threshold + idle siblings: it is chosen.
        let heavy = hier.children[0][2];
        let slot = p.loads.child_slot(heavy);
        for _ in 0..p.steal_cfg().threshold.max(1) {
            p.loads.bump_child(slot);
        }
        assert_eq!(p.choose_victim(&hier, 0), Some(heavy));
        // No idle child (everyone has a unit): trigger condition fails.
        for c in &hier.children[0] {
            let s = p.loads.child_slot(*c);
            if p.loads.child(s) == 0 {
                p.loads.bump_child(s);
            }
        }
        assert_eq!(p.choose_victim(&hier, 0), None);
    }

    #[test]
    fn max_load_victim_breaks_ties_low_and_tracks_max() {
        let mut v = VictimPolicy::MaxLoad;
        let loads = [3u64, 9, 9, 0];
        assert_eq!(v.choose(4, |i| loads[i], 4), Some(1));
        assert_eq!(v.choose(4, |i| loads[i], 10), None);
        let one = [0u64, 0, 5, 0];
        assert_eq!(v.choose(4, |i| one[i], 5), Some(2));
    }

    #[test]
    fn random_victim_is_seeded_and_eligible_only() {
        let cfg = StealCfg::random_victim();
        let loads = [9u64, 0, 7, 12];
        let run = |seed: u64| {
            let mut v = VictimPolicy::new(&cfg, 3, seed);
            (0..32).map(|_| v.choose(4, |i| loads[i], 4).unwrap()).collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7), "victim stream must replay from the seed");
        // Only eligible slots (load >= 4) are ever chosen.
        assert!(run(7).iter().all(|&i| [0usize, 2, 3].contains(&i)));
        // Ineligible-everything yields None without drawing forever.
        let mut v = VictimPolicy::new(&cfg, 3, 7);
        assert_eq!(v.choose(4, |i| loads[i], 100), None);
    }

    #[test]
    fn steal_dest_and_victim_stolen_balance_the_books() {
        let hier = two_level();
        let cfg = PolicyCfg::default().with_steal(StealCfg::on());
        let mut p = Placer::new(&cfg, &hier, 0, 1);
        // Simulate: 4 tasks placed into child 0 (the future victim).
        let victim = hier.children[0][0];
        let vslot = p.loads.child_slot(victim);
        for _ in 0..4 {
            p.loads.bump_child(vslot);
        }
        assert_eq!(p.total(), 4);
        // Steal 2: decay the victim, re-place each to the least-loaded
        // non-victim child (never back to the victim, even on load ties).
        p.victim_stolen(victim, 2);
        assert_eq!(p.total(), 2);
        let (d1, scored) = p.steal_dest(&hier, 0, victim);
        assert_ne!(d1, victim);
        assert_eq!(scored, 4);
        let (d2, _) = p.steal_dest(&hier, 0, victim);
        assert_ne!(d2, victim);
        assert_ne!(d2, d1, "second task goes to the next idle subtree");
        assert_eq!(p.total(), 4, "thief charged for every re-placed task");
        // Completions drain everything back to zero.
        p.victim_stolen(victim, 2);
        p.victim_stolen(d1, 1);
        p.victim_stolen(d2, 1);
        assert_eq!(p.total(), 0);
        // Full load tie (everything at zero): the victim is still never
        // the destination — a tie must not undo the migration.
        let (d3, _) = p.steal_dest(&hier, 0, victim);
        assert_ne!(d3, victim);
    }

    #[test]
    fn dead_children_drop_out_of_every_decision() {
        let hier = two_level();
        let cfg = PolicyCfg::default().with_steal(StealCfg::on());
        let mut p = Placer::new(&cfg, &hier, 0, 1);
        let dead = hier.children[0][0];
        let slot = p.loads.child_slot(dead);
        for _ in 0..3 {
            p.loads.bump_child(slot);
        }
        assert_eq!(p.total(), 3);
        p.mark_child_dead(dead);
        assert!(p.child_is_dead(dead));
        assert_eq!(p.total(), 0, "dead book zeroes");
        // Placement never picks the dead child, even though it now has
        // the lowest (zero) load.
        for _ in 0..16 {
            let (c, _) = p.choose_child(&hier, 0, &[]);
            assert_ne!(c, dead);
        }
        // A dead subtree at load 0 is not a starving sibling: with every
        // live child loaded and only the dead one idle, no steal fires.
        let mut q = Placer::new(&cfg, &hier, 0, 1);
        q.mark_child_dead(dead);
        for &c in &hier.children[0][1..] {
            let s = q.loads.child_slot(c);
            for _ in 0..q.steal_cfg().threshold.max(1) {
                q.loads.bump_child(s);
            }
        }
        assert_eq!(q.choose_victim(&hier, 0), None);
        // Steal destination skips the dead slot too.
        let victim = hier.children[0][1];
        let (d, _) = q.steal_dest(&hier, 0, victim);
        assert_ne!(d, dead);
        assert_ne!(d, victim);
        // Headroom ignores dead capacity: kill everything but one child,
        // fill it to its cap, and the parent must report no headroom.
        let mut r = Placer::new(&cfg, &hier, 0, 1);
        for &c in &hier.children[0][..3] {
            r.mark_child_dead(c);
        }
        let last = hier.children[0][3];
        let ls = r.loads.child_slot(last);
        for _ in 0..8 {
            r.loads.bump_child(ls);
        }
        assert!(!r.has_headroom(&hier, 0));
        // Rejoin restores the slot to service.
        r.mark_child_alive(dead);
        assert!(!r.child_is_dead(dead));
        assert!(r.has_headroom(&hier, 0));
    }

    #[test]
    fn child_done_decays_the_covering_subtree() {
        let hier = HierarchyMap::build(36, &HierarchySpec::multi_level(3, 2));
        // Tree: 0 -> (1,2); 1 -> (3,4); 2 -> (5,6).
        let mut placer = Placer::new(&PolicyCfg::default(), &hier, 0, 1);
        let slot1 = placer.loads.child_slot(1);
        placer.loads.bump_child(slot1);
        assert_eq!(placer.total(), 1);
        let w = hier.leaf_workers[3][0]; // under child 1
        placer.child_done(&hier, 0, w);
        assert_eq!(placer.total(), 0);
        // A completion under child 2 with a drained slot stays saturated.
        let w2 = hier.leaf_workers[5][0];
        placer.child_done(&hier, 0, w2);
        assert_eq!(placer.total(), 0);
    }
}

//! Worker core logic (paper V-E, last paragraphs).
//!
//! "Worker cores run a very small portion of the Myrmics runtime system.
//! They await messages from their parent scheduler which dispatch tasks to
//! be executed. Workers implement ready-task queues ... The worker orders
//! a group of DMA transfers for all remaining remote arguments ... Whenever
//! two or more task descriptors exist in the queue, the worker optimizes
//! the DMA transfers by ordering the DMA group for the second task ...
//! before starting to execute the first task [double-buffering]. Workers
//! do not interrupt running tasks."
//!
//! Task bodies run eagerly on arrival at the execution slot (functional
//! effects) and are *replayed* as a timed op list: compute charges pass
//! time, API calls become real message round trips that suspend the
//! replay, spawns rendezvous with the scheduler (`SpawnAck`), `sys_wait`
//! suspends until the scheduler re-grants the arguments.

use std::collections::{HashMap, VecDeque};

use crate::api::ctx::TaskOp;
use crate::ids::{CoreId, ReqId, TaskId};
use crate::noc::dma::Transfer;
use crate::noc::msg::Msg;
use crate::platform::run_task_body;
use crate::sim::engine::{CoreLogic, Ctx};
use crate::sim::event::Event;
use crate::task::table::TaskState;

/// DMA readiness of a queued task.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Fetch {
    Prepping,
    Ready,
}

/// What the replay is suspended on.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Waiting {
    None,
    Rpc(ReqId),
    SpawnAck(ReqId),
    WaitGrant,
}

struct Run {
    task: TaskId,
    ops: Vec<TaskOp>,
    idx: usize,
    phase: u32,
    waiting: Waiting,
}

pub struct WorkerLogic {
    pub core: CoreId,
    leaf: CoreId,
    ready: VecDeque<TaskId>,
    fetch: HashMap<TaskId, Fetch>,
    groups: HashMap<u64, TaskId>,
    running: Option<Run>,
    /// Tasks parked in `sys_wait` (they yield the core; paper V-A).
    suspended: HashMap<TaskId, Run>,
    /// Suspended tasks whose wait was granted, ready to resume.
    resumable: VecDeque<TaskId>,
    next_req: u64,
    last_load: u64,
}

impl WorkerLogic {
    pub fn new(core: CoreId, leaf: CoreId) -> Self {
        WorkerLogic {
            core,
            leaf,
            ready: VecDeque::new(),
            fetch: HashMap::new(),
            groups: HashMap::new(),
            running: None,
            suspended: HashMap::new(),
            resumable: VecDeque::new(),
            next_req: 1,
            last_load: 0,
        }
    }

    fn fresh_req(&mut self) -> ReqId {
        let r = ReqId((self.core.0 as u64) << 32 | self.next_req);
        self.next_req += 1;
        r
    }

    fn load(&self) -> u64 {
        self.ready.len() as u64 + self.running.is_some() as u64
    }

    fn report_load(&mut self, ctx: &mut Ctx<'_>) {
        let load = self.load();
        if load.abs_diff(self.last_load) >= ctx.world.cfg.load_report_threshold {
            self.last_load = load;
            ctx.send(self.leaf, Msg::LoadReport { from: self.core, load });
        }
    }

    /// Order DMA groups for the first (up to) two unprepped queued tasks —
    /// the paper's double-buffering window.
    fn maybe_prep(&mut self, ctx: &mut Ctx<'_>) {
        for wi in 0..2 {
            let Some(&t) = self.ready.get(wi) else { break };
            if self.fetch.contains_key(&t) {
                continue;
            }
            // Borrow the pack list in place (shared borrows of disjoint
            // Ctx fields) instead of cloning it per prep.
            let transfers: Vec<Transfer> = ctx
                .world
                .tasks
                .get(t)
                .pack
                .iter()
                .filter(|r| r.producer != self.core)
                .map(|r| Transfer {
                    src: r.producer,
                    dst: self.core,
                    bytes: r.bytes,
                    hops: ctx.hops_to(r.producer),
                })
                .collect();
            let group = ctx.dma_group(transfers);
            self.fetch.insert(t, Fetch::Prepping);
            self.groups.insert(group, t);
        }
    }

    /// Start the queue-head task if its DMA group completed. Resumed
    /// `sys_wait` tasks take priority over fresh dispatches.
    fn maybe_start(&mut self, ctx: &mut Ctx<'_>) {
        if self.running.is_some() {
            return;
        }
        if let Some(t) = self.resumable.pop_front() {
            let run = self.suspended.remove(&t).expect("resumable task is suspended");
            ctx.charge(ctx.sim.cost.wk_dispatch_handle);
            self.running = Some(run);
            self.continue_run(ctx);
            return;
        }
        let Some(&t) = self.ready.front() else { return };
        if self.fetch.get(&t) != Some(&Fetch::Ready) {
            return;
        }
        self.ready.pop_front();
        self.fetch.remove(&t);
        ctx.charge(ctx.sim.cost.wk_task_setup);
        let phase = ctx.world.tasks.get(t).phase;
        {
            let now = ctx.now();
            let entry = ctx.world.tasks.get_mut(t);
            entry.state = TaskState::Running;
            entry.started_at = now;
        }
        let ops = run_task_body(ctx.world, ctx.registry, t, self.core, phase);
        self.running = Some(Run { task: t, ops, idx: 0, phase, waiting: Waiting::None });
        self.continue_run(ctx);
    }

    /// Replay ops until the list ends or an RPC/wait suspends it.
    fn continue_run(&mut self, ctx: &mut Ctx<'_>) {
        loop {
            let Some(run) = self.running.as_mut() else { return };
            debug_assert_eq!(run.waiting, Waiting::None);
            if run.idx >= run.ops.len() {
                let task = run.task;
                self.running = None;
                self.finish_task(ctx, task);
                return;
            }
            let op = run.ops[run.idx].clone();
            run.idx += 1;
            match op {
                TaskOp::Compute(c) => {
                    ctx.charge_task(c);
                }
                TaskOp::Rpc { owner, op } => {
                    let req = self.fresh_req();
                    let owner_core = ctx.world.hier.sched_core(owner);
                    ctx.charge(ctx.sim.cost.wk_api_call);
                    let origin = self.core;
                    ctx.send(self.leaf, Msg::MemReq { req, origin, owner: owner_core, op });
                    self.running.as_mut().unwrap().waiting = Waiting::Rpc(req);
                    return;
                }
                TaskOp::Spawn(desc) => {
                    let req = self.fresh_req();
                    ctx.charge(ctx.sim.cost.wk_spawn_call);
                    let parent = Some(self.running.as_ref().unwrap().task);
                    let origin = self.core;
                    ctx.send(self.leaf, Msg::SpawnReq { req, origin, parent, desc });
                    self.running.as_mut().unwrap().waiting = Waiting::SpawnAck(req);
                    return;
                }
                TaskOp::Wait(nodes) => {
                    let task = self.running.as_ref().unwrap().task;
                    let origin = self.core;
                    ctx.charge(ctx.sim.cost.wk_api_call);
                    ctx.send(self.leaf, Msg::WaitReq { task, origin, nodes });
                    // Park the task: the core is free to run other ready
                    // tasks while this one waits for its subtrees.
                    let mut run = self.running.take().unwrap();
                    run.waiting = Waiting::WaitGrant;
                    self.suspended.insert(task, run);
                    self.maybe_prep(ctx);
                    self.maybe_start(ctx);
                    return;
                }
            }
        }
    }

    fn finish_task(&mut self, ctx: &mut Ctx<'_>, task: TaskId) {
        ctx.charge(ctx.sim.cost.wk_task_teardown);
        ctx.sim.stats[self.core.idx()].tasks_run += 1;
        ctx.send(self.leaf, Msg::TaskDone { task });
        self.maybe_prep(ctx);
        self.maybe_start(ctx);
        self.report_load(ctx);
    }

    fn resume(&mut self, ctx: &mut Ctx<'_>, expect: Waiting) {
        let Some(run) = self.running.as_mut() else { return };
        if run.waiting != expect {
            return;
        }
        run.waiting = Waiting::None;
        self.continue_run(ctx);
    }
}

impl CoreLogic for WorkerLogic {
    fn on_event(&mut self, ctx: &mut Ctx<'_>, ev: Event) {
        match ev {
            Event::Boot => {}
            // Workers are always the final destination — the tree never
            // routes *through* a worker.
            Event::Msg { dst, msg, .. } => {
                debug_assert_eq!(dst, self.core, "through-traffic delivered to a worker");
                match msg {
                    Msg::Dispatch { task } => {
                        ctx.charge(ctx.sim.cost.wk_dispatch_handle);
                        self.ready.push_back(task);
                        self.maybe_prep(ctx);
                        self.maybe_start(ctx);
                        self.report_load(ctx);
                    }
                    Msg::Adopt { leaf } => {
                        // Crash recovery re-homed this worker under a new
                        // (or restarted) scheduler. All future uplink
                        // traffic goes there; send an unconditional load
                        // report so the adopter's book starts from truth
                        // instead of the dead child's stale view.
                        ctx.charge(ctx.sim.cost.wk_msg_proc);
                        self.leaf = leaf;
                        let load = self.load();
                        self.last_load = load;
                        ctx.send(self.leaf, Msg::LoadReport { from: self.core, load });
                    }
                    Msg::SpawnAck { req } => self.resume(ctx, Waiting::SpawnAck(req)),
                    Msg::MemResp { req } => self.resume(ctx, Waiting::Rpc(req)),
                    Msg::WaitGranted { task } => {
                        // Re-run the body at the next phase; its new ops
                        // replace the old list. The task resumes once the
                        // core is free.
                        let Some(run) = self.suspended.get_mut(&task) else { return };
                        if run.waiting != Waiting::WaitGrant {
                            return;
                        }
                        run.phase += 1;
                        let phase = run.phase;
                        ctx.world.tasks.get_mut(task).phase = phase;
                        ctx.charge(ctx.sim.cost.wk_dispatch_handle);
                        let ops = run_task_body(ctx.world, ctx.registry, task, self.core, phase);
                        let run = self.suspended.get_mut(&task).unwrap();
                        run.ops = ops;
                        run.idx = 0;
                        run.waiting = Waiting::None;
                        self.resumable.push_back(task);
                        self.maybe_start(ctx);
                    }
                    other => {
                        panic!("worker {} got unexpected message {}", self.core, other.tag())
                    }
                }
            }
            Event::DmaDone { group } => {
                ctx.charge(ctx.sim.cost.wk_msg_proc);
                if let Some(t) = self.groups.remove(&group) {
                    self.fetch.insert(t, Fetch::Ready);
                }
                self.maybe_start(ctx);
            }
            Event::Timer(_) | Event::Wake => {}
        }
    }
}

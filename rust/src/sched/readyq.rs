//! Per-scheduler ready-task queue: the migratable staging area between
//! "all dependencies granted + packed" and "committed to a subtree/worker".
//!
//! Before the work-stealing refactor a ready task was placed and sent in
//! the same breath — once `place()` ran, the decision was irrevocable. Now
//! every ready task passes through its scheduler's [`ReadyQ`]; dispatch is
//! "pop + place + send". Tasks sitting in the queue are *not yet bound* to
//! any child subtree or worker, which is exactly what makes them stealable:
//! the rebalance protocol (`Msg::StealReq`/`StealGrant`) migrates queued
//! entries without unwinding any placement state.
//!
//! # Hot-path discipline
//!
//! Push/pop/migrate sit on the per-event path, so the PR-1 invariant
//! applies: the queue is an **intrusive doubly-linked FIFO over its own
//! slot slab** (one contiguous `Vec`, links by dense `u32` index, freed
//! slots recycled through an intrusive free list). Steady state performs
//! no heap allocation and no hashing; the slab grows once to the
//! high-water mark of simultaneously queued tasks and is then reused.
//!
//! Dispatch pops from the **front** (FIFO — oldest ready task first, the
//! order the pre-refactor scheduler produced); steals pop from the
//! **back** (the tasks the local scheduler would reach last, so migration
//! costs are paid by work that would otherwise wait the longest).

use crate::ids::TaskId;

const NIL: u32 = u32::MAX;

struct Node {
    task: TaskId,
    prev: u32,
    next: u32,
}

/// Intrusive, arena-backed FIFO of ready task ids with O(1) push-back,
/// pop-front (dispatch) and pop-back (steal).
pub struct ReadyQ {
    nodes: Vec<Node>,
    /// Head of the intrusive free list (`next`-linked), `NIL` when empty.
    free: u32,
    head: u32,
    tail: u32,
    len: usize,
}

impl Default for ReadyQ {
    fn default() -> Self {
        Self::new()
    }
}

impl ReadyQ {
    pub fn new() -> Self {
        ReadyQ { nodes: Vec::new(), free: NIL, head: NIL, tail: NIL, len: 0 }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total slab slots ever allocated — the queue-depth high-water mark.
    /// Steady state never grows this (tests pin slot reuse).
    pub fn slots(&self) -> usize {
        self.nodes.len()
    }

    pub fn push_back(&mut self, task: TaskId) {
        let prev = self.tail;
        let slot = if self.free != NIL {
            let s = self.free;
            let n = &mut self.nodes[s as usize];
            self.free = n.next;
            n.task = task;
            n.prev = prev;
            n.next = NIL;
            s
        } else {
            let s = self.nodes.len() as u32;
            self.nodes.push(Node { task, prev, next: NIL });
            s
        };
        if prev != NIL {
            self.nodes[prev as usize].next = slot;
        } else {
            self.head = slot;
        }
        self.tail = slot;
        self.len += 1;
    }

    /// Dispatch order: oldest ready task.
    pub fn pop_front(&mut self) -> Option<TaskId> {
        if self.head == NIL {
            return None;
        }
        let s = self.head;
        let (task, next) = {
            let n = &self.nodes[s as usize];
            (n.task, n.next)
        };
        self.head = next;
        if next != NIL {
            self.nodes[next as usize].prev = NIL;
        } else {
            self.tail = NIL;
        }
        self.release(s);
        Some(task)
    }

    /// Migration order: the task the local scheduler would reach last.
    pub fn pop_back(&mut self) -> Option<TaskId> {
        if self.tail == NIL {
            return None;
        }
        let s = self.tail;
        let (task, prev) = {
            let n = &self.nodes[s as usize];
            (n.task, n.prev)
        };
        self.tail = prev;
        if prev != NIL {
            self.nodes[prev as usize].next = NIL;
        } else {
            self.head = NIL;
        }
        self.release(s);
        Some(task)
    }

    #[inline]
    fn release(&mut self, s: u32) {
        self.nodes[s as usize].next = self.free;
        self.free = s;
        self.len -= 1;
    }

    /// Front-to-back walk (diagnostics/tests only — not on the hot path).
    pub fn iter(&self) -> ReadyIter<'_> {
        ReadyIter { q: self, at: self.head }
    }

    /// Drain the queue front-to-back into a `Vec`, leaving it empty.
    /// Crash recovery uses this: a restarted scheduler's volatile queue is
    /// wiped wholesale, and a re-adopting parent drains what it can see.
    /// Off the hot path — called at most once per crash.
    pub fn take_all(&mut self) -> Vec<TaskId> {
        let mut out = Vec::with_capacity(self.len);
        while let Some(t) = self.pop_front() {
            out.push(t);
        }
        out
    }
}

pub struct ReadyIter<'a> {
    q: &'a ReadyQ,
    at: u32,
}

impl Iterator for ReadyIter<'_> {
    type Item = TaskId;

    fn next(&mut self) -> Option<TaskId> {
        if self.at == NIL {
            return None;
        }
        let n = &self.q.nodes[self.at as usize];
        self.at = n.next;
        Some(n.task)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(q: &ReadyQ) -> Vec<u64> {
        q.iter().map(|t| t.0).collect()
    }

    #[test]
    fn fifo_dispatch_order() {
        let mut q = ReadyQ::new();
        for i in 0..5 {
            q.push_back(TaskId(i));
        }
        assert_eq!(q.len(), 5);
        assert_eq!(ids(&q), vec![0, 1, 2, 3, 4]);
        for i in 0..5 {
            assert_eq!(q.pop_front(), Some(TaskId(i)));
        }
        assert_eq!(q.pop_front(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn steals_come_off_the_back() {
        let mut q = ReadyQ::new();
        for i in 0..4 {
            q.push_back(TaskId(i));
        }
        assert_eq!(q.pop_back(), Some(TaskId(3)));
        assert_eq!(q.pop_back(), Some(TaskId(2)));
        // Dispatch still sees the oldest first.
        assert_eq!(q.pop_front(), Some(TaskId(0)));
        assert_eq!(q.pop_back(), Some(TaskId(1)));
        assert_eq!(q.pop_back(), None);
        assert_eq!(q.pop_front(), None);
    }

    #[test]
    fn single_element_from_either_end() {
        let mut q = ReadyQ::new();
        q.push_back(TaskId(7));
        assert_eq!(q.pop_back(), Some(TaskId(7)));
        q.push_back(TaskId(8));
        assert_eq!(q.pop_front(), Some(TaskId(8)));
        assert!(q.is_empty());
        // Links fully reset: the queue keeps working after draining.
        q.push_back(TaskId(9));
        q.push_back(TaskId(10));
        assert_eq!(ids(&q), vec![9, 10]);
    }

    #[test]
    fn slots_are_recycled_not_grown() {
        let mut q = ReadyQ::new();
        for i in 0..8 {
            q.push_back(TaskId(i));
        }
        let hwm = q.slots();
        assert_eq!(hwm, 8);
        // Steady-state churn at depth <= 8 must reuse the same slab.
        for round in 0..100u64 {
            q.pop_front();
            q.pop_back();
            q.push_back(TaskId(100 + round));
            q.push_back(TaskId(200 + round));
            assert_eq!(q.len(), 8);
        }
        assert_eq!(q.slots(), hwm, "steady-state churn must not allocate");
    }

    #[test]
    fn take_all_drains_in_fifo_order() {
        let mut q = ReadyQ::new();
        for i in 0..6 {
            q.push_back(TaskId(i));
        }
        q.pop_back();
        let drained: Vec<u64> = q.take_all().iter().map(|t| t.0).collect();
        assert_eq!(drained, vec![0, 1, 2, 3, 4]);
        assert!(q.is_empty());
        // The queue survives a wholesale drain.
        q.push_back(TaskId(9));
        assert_eq!(q.pop_front(), Some(TaskId(9)));
    }

    #[test]
    fn interleaved_ops_preserve_order() {
        let mut q = ReadyQ::new();
        q.push_back(TaskId(1));
        q.push_back(TaskId(2));
        assert_eq!(q.pop_front(), Some(TaskId(1)));
        q.push_back(TaskId(3));
        q.push_back(TaskId(4));
        assert_eq!(q.pop_back(), Some(TaskId(4)));
        q.push_back(TaskId(5));
        assert_eq!(ids(&q), vec![2, 3, 5]);
        assert_eq!(q.len(), 3);
    }
}

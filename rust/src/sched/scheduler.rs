//! Scheduler core logic: the event-based server of paper V.
//!
//! One instance drives each scheduler core. It implements, against the
//! nodes/tasks it *owns*:
//!
//! * spawn handling + downward delegation (V-E),
//! * the dependency traversal, grants, quiescence propagation and the
//!   parent-counter race protocol (V-D),
//! * packing with reentrant pending state (V-E),
//! * the memory-API service path and load-report aggregation (V-C).
//!
//! The placement *decision* — which child subtree or worker a ready task
//! goes to, and the load estimates that inform it — is not made here: it
//! lives behind the [`Placer`] seam in [`crate::sched::policy`]. This
//! module only speaks the protocol (what messages to send once the policy
//! has chosen), so placement strategies can be swept and extended without
//! touching the traversal or packing state machines.
//!
//! Ready tasks pass through a per-scheduler [`ReadyQ`] before placement
//! (dispatch = pop + place + send), which is what makes them migratable:
//! the idle-driven rebalance protocol (`StealReq`/`StealGrant`/
//! `StealDeny`, configured by `StealCfg` and **off by default**) moves
//! queued-ready tasks from a loaded child subtree towards an idle sibling.
//! See the "Work stealing" section of `docs/sim-engine.md` for the
//! protocol, accounting and determinism contract.
//!
//! Everything that touches state owned by another scheduler leaves this
//! core as a routed NoC message and is charged accordingly.
//!
//! # Hot-path discipline
//!
//! The per-event path (grant, traversal step, re-evaluation, pack,
//! placement) performs **no steady-state heap allocation**: task
//! descriptors are shared `Arc`s (escaping a borrow is a pointer bump,
//! not an argument-vector copy), queue re-evaluation and pack walks run
//! over pooled scratch buffers owned by this scheduler, placement runs
//! over the policy layer's dense load tables and reusable scoring scratch
//! (no hash/tree probes, enum dispatch only — see `sched::policy`),
//! and tree-forwarded messages move hop to hop without boxing (see
//! `Event::Msg::dst`). Keep it that way — the simulator's throughput
//! (events per host second, `cargo bench --bench hotpath`) is the
//! regression gate.

use crate::config::PlatformConfig;
use crate::dep::node::ReadyAction;
use crate::fxmap::FxHashMap;
use crate::ids::{CoreId, Cycles, JobId, NodeId, RegionId, ReqId, TaskId};
use crate::noc::msg::{MemOpKind, Msg, ProducerRange};
use crate::memory::region::PackScratch;
use crate::sched::hierarchy::HierarchyMap;
use crate::sched::policy::Placer;
use crate::sched::readyq::ReadyQ;
use crate::sim::engine::{CoreLogic, Ctx};
use crate::sim::event::{Event, TimerKind};
use crate::sim::traffic::{self, JobPhase, JobTimer};
use crate::task::descriptor::{Access, TaskArg, TaskDesc};
use crate::task::table::TaskState;

/// Custom-timer tag for the deny-retry backoff rearm (see
/// [`crate::config::StealCfg::retry_backoff`]). Workers never schedule
/// custom timers, so the tag only needs to be unique among scheduler
/// timers.
const STEAL_RETRY_TIMER: u64 = 0x57EA_17;

/// Custom-timer tag for the recovery heartbeat tick (must stay distinct
/// from [`STEAL_RETRY_TIMER`] — both arrive as `Timer(Custom(..))` on the
/// same scheduler cores).
const HEARTBEAT_TIMER: u64 = 0xB_EA7;

/// Reentrant pending packing operation ("reentrant events with saved local
/// state", paper V-C).
pub struct PackPending {
    /// Root pend: drives `task`'s scheduling when complete.
    task: Option<TaskId>,
    /// Aggregation pend: reply to (original req, requester) when complete.
    reply: Option<(ReqId, CoreId)>,
    outstanding: usize,
    acc: Vec<ProducerRange>,
}

/// Durable reentrant-request tables, shared by all schedulers and keyed by
/// globally unique ids (`ReqId` embeds the issuing scheduler's index).
///
/// Pre-crash these lived inside each `SchedLogic`; they moved to the
/// [`World`](crate::platform::World) so crash recovery stays tractable:
/// the model is that a scheduler *journals* its request tables (pack
/// aggregations, spawn rendezvous, wait counts) to memory that survives a
/// crash, so a reply surfacing from a dead scheduler's re-adopted mailbox
/// can be served — by the re-adopting parent during the outage or by the
/// restarted incarnation after it — instead of wedging its requester
/// forever. Functionally nothing changed for healthy runs: ids never
/// collide across schedulers, and each entry is still only touched by the
/// core currently serving it.
#[derive(Default)]
pub struct Journal {
    packs: FxHashMap<ReqId, PackPending>,
    /// Spawn rendezvous: req -> (spawner core, unsettled traversals).
    spawns: FxHashMap<ReqId, (CoreId, usize)>,
    /// task -> outstanding wait-node count.
    waits: FxHashMap<TaskId, usize>,
}

impl Journal {
    /// All request tables drained (quiescence oracle: nothing reentrant
    /// may be pending once the platform is idle).
    pub fn is_empty(&self) -> bool {
        self.packs.is_empty() && self.spawns.is_empty() && self.waits.is_empty()
    }

    /// Outstanding entries (diagnostics/oracle reporting).
    pub fn outstanding(&self) -> usize {
        self.packs.len() + self.spawns.len() + self.waits.len()
    }

    /// Seeded-corruption hook for oracle self-tests: leak a rendezvous.
    #[cfg(test)]
    pub fn inject_spawn(&mut self, req: ReqId, origin: CoreId, left: usize) {
        self.spawns.insert(req, (origin, left));
    }
}

pub struct SchedLogic {
    pub idx: usize,
    pub core: CoreId,
    /// Monotone request counter. Survives a crash (part of the journal
    /// fiction — see [`Journal`]): resetting it would mint `ReqId`s that
    /// collide with pre-crash journal entries.
    next_req: u64,
    /// Placement policy + dense load estimates (the policy seam; see
    /// [`crate::sched::policy`]).
    placer: Placer,
    /// Ready tasks not yet committed to a subtree/worker. Dispatch is
    /// "pop front + place + send"; the rebalance protocol migrates from
    /// the back. With stealing disabled the queue drains inside the
    /// handler that fills it (`pump` never throttles), so the pre-stealing
    /// event schedule is reproduced byte for byte.
    ready: ReadyQ,
    /// The child an outstanding `StealReq` went to (its estimate is
    /// decayed when the grant lands). `Some` doubles as the "one request
    /// in flight at a time" latch.
    steal_victim: Option<usize>,
    /// Consecutive denied steal attempts (deny-retry backoff state; only
    /// advances when `StealCfg::retry_backoff > 0`).
    steal_retries: u32,
    last_reported: u64,
    // --- crash recovery (all inert while `RecoveryCfg::enabled` is off:
    // --- no timers armed, no probes sent, no draws, no charges).
    /// Per-child-slot time of the last heard `Pong` (or `Rejoin`).
    last_pong: Vec<Cycles>,
    /// Incarnation number: bumped by each crash restart (diagnostics —
    /// the functional dedup rides on task epochs and the task table).
    generation: u32,
    /// Set by the engine's restart transition, consumed by the next
    /// `Boot`: run the rejoin protocol before anything else.
    just_restarted: bool,
    /// `MYRMICS_TRACE_TASK`, read once at construction (it used to be an
    /// environment syscall on every single grant).
    trace_task: Option<u64>,
    // --- reusable scratch; per-scheduler so the steady state allocates
    // --- nothing on the event path.
    /// Pool of ready-action buffers for [`SchedLogic::reeval`] (a pool,
    /// not a single buffer, because re-evaluation recurses through
    /// quiescence propagation).
    ready_pool: Vec<Vec<ReadyAction>>,
    /// Argument-owner scratch for delegation checks.
    owners_scratch: Vec<usize>,
    /// Packing subtree-walk buffers.
    pack_scratch: PackScratch,
    /// Remote subregion roots from the last pack walk.
    pack_remote: Vec<crate::ids::RegionId>,
}

impl SchedLogic {
    pub fn new(idx: usize, core: CoreId, hier: &HierarchyMap, cfg: &PlatformConfig) -> Self {
        SchedLogic {
            idx,
            core,
            next_req: 1,
            placer: Placer::new(&cfg.policy, hier, idx, cfg.seed),
            ready: ReadyQ::new(),
            steal_victim: None,
            steal_retries: 0,
            last_reported: 0,
            last_pong: vec![0; hier.children[idx].len()],
            generation: 0,
            just_restarted: false,
            trace_task: std::env::var("MYRMICS_TRACE_TASK")
                .ok()
                .and_then(|t| t.parse::<u64>().ok()),
            ready_pool: Vec::new(),
            owners_scratch: Vec::new(),
            pack_scratch: PackScratch::default(),
            pack_remote: Vec::new(),
        }
    }

    /// Placement state (load estimates, policy) — read-only view for
    /// diagnostics and the load-drift regression tests.
    pub fn placer(&self) -> &Placer {
        &self.placer
    }

    /// Current ready-queue depth (diagnostics/tests).
    pub fn ready_depth(&self) -> usize {
        self.ready.len()
    }

    /// A `StealReq` is outstanding (oracle: must be false at quiescence).
    pub fn steal_in_flight(&self) -> bool {
        self.steal_victim.is_some()
    }

    /// Incarnation number (0 = never crashed; oracles/tests).
    pub fn generation(&self) -> u32 {
        self.generation
    }

    /// Seeded-corruption hook for the oracle self-tests: mutable access
    /// to the placement books.
    #[cfg(test)]
    pub fn placer_mut(&mut self) -> &mut Placer {
        &mut self.placer
    }

    /// Seeded-corruption hook for the oracle self-tests: leak a task into
    /// the ready queue after the run has drained.
    #[cfg(test)]
    pub fn ready_inject(&mut self, task: TaskId) {
        self.ready.push_back(task);
    }

    fn fresh_req(&mut self) -> ReqId {
        let r = ReqId((self.idx as u64) << 48 | self.next_req);
        self.next_req += 1;
        r
    }

    /// Send `msg` towards `to`, forwarding along the tree; handle locally
    /// if `to` is this core. Forwarded messages carry their destination in
    /// the delivery event, so no envelope allocation happens per hop.
    fn send_routed(&mut self, ctx: &mut Ctx<'_>, to: CoreId, msg: Msg) {
        if to == self.core {
            self.handle(ctx, self.core, msg);
            return;
        }
        let next = ctx.world.hier.route_next(self.idx, to);
        ctx.send_via(next, to, msg);
    }

    fn sched_core(&self, ctx: &Ctx<'_>, idx: usize) -> CoreId {
        ctx.world.hier.sched_core(idx)
    }

    // =================================================== spawn + delegation

    fn on_spawn(
        &mut self,
        ctx: &mut Ctx<'_>,
        req: ReqId,
        origin: CoreId,
        parent: Option<TaskId>,
        desc: TaskDesc,
    ) {
        // The parent task's responsible scheduler handles the spawn.
        if let Some(p) = parent {
            let resp = ctx.world.tasks.get(p).resp;
            if resp != self.idx {
                let to = self.sched_core(ctx, resp);
                self.send_routed(ctx, to, Msg::SpawnReq { req, origin, parent, desc });
                return;
            }
        }
        ctx.charge(ctx.sim.cost.sc_spawn_handle);
        let now = ctx.now();
        let task = ctx.world.tasks.create(desc, parent, self.idx, now);
        ctx.world.gstats.tasks_spawned += 1;
        // Traffic books ride the same exactly-once site as the global
        // spawn counter. `job` is inherited from the parent entry, so a
        // non-traffic run (job == None everywhere) never takes the branch.
        if let Some(j) = ctx.world.tasks.get(task).job {
            if let Some(tr) = ctx.world.traffic.as_mut() {
                tr.on_task_spawned(j);
            }
        }
        // sys_spawn is a synchronous RPC, and the ack doubles as the
        // race-closing rendezvous: it is sent only after every argument
        // traversal has settled (see Msg::DepDescend::settle).
        self.adopt_task(ctx, task, req, origin);
    }

    /// Take responsibility for a task: delegate further down if a single
    /// child subtree owns every argument, else run dependency analysis.
    fn adopt_task(&mut self, ctx: &mut Ctx<'_>, task: TaskId, req: ReqId, origin: CoreId) {
        ctx.world.tasks.get_mut(task).resp = self.idx;
        let desc = ctx.world.tasks.get(task).desc.clone();
        self.owners_scratch.clear();
        for (_, a) in desc.dep_args() {
            ctx.charge(ctx.sim.cost.sc_dep_locate);
            self.owners_scratch.push(ctx.world.mem.owner(a.node.unwrap()));
        }
        if !self.owners_scratch.is_empty() {
            if let Some(child) = ctx.world.hier.child_covering(self.idx, &self.owners_scratch) {
                // Never delegate into a dead subtree: the re-adopted
                // mailbox would bounce the Delegate straight back here
                // and the covering check would pick the same child again,
                // forever. Keeping responsibility here is always correct
                // (the dep protocol runs fine above the owners).
                if !self.placer.child_is_dead(child) {
                    ctx.world.tasks.get_mut(task).resp = child;
                    let to = self.sched_core(ctx, child);
                    self.send_routed(ctx, to, Msg::Delegate { task, req, origin });
                    return;
                }
            }
        }
        self.start_dep_analysis(ctx, task, req, origin);
    }

    /// One argument traversal settled; ack the spawner once all have.
    fn on_settled(&mut self, ctx: &mut Ctx<'_>, req: ReqId) {
        let done = {
            let Some(entry) = ctx.world.journal.spawns.get_mut(&req) else { return };
            entry.1 -= 1;
            entry.1 == 0
        };
        if done {
            let (origin, _) = ctx.world.journal.spawns.remove(&req).unwrap();
            self.send_routed(ctx, origin, Msg::SpawnAck { req });
        }
    }

    /// Settle one traversal: locally if the rendezvous lives here, else by
    /// message to the spawn-handling scheduler.
    fn settle(&mut self, ctx: &mut Ctx<'_>, settle: Option<(CoreId, ReqId)>) {
        let Some((core, req)) = settle else { return };
        if core == self.core {
            self.on_settled(ctx, req);
        } else {
            self.send_routed(ctx, core, Msg::DepSettled { req });
        }
    }

    // ==================================================== dependency engine

    fn start_dep_analysis(&mut self, ctx: &mut Ctx<'_>, task: TaskId, req: ReqId, origin: CoreId) {
        let deps_pending = ctx.world.tasks.get(task).deps_pending;
        if deps_pending == 0 {
            self.send_routed(ctx, origin, Msg::SpawnAck { req });
            self.task_ready(ctx, task);
            return;
        }
        ctx.world.journal.spawns.insert(req, (origin, deps_pending));
        let settle = Some((self.core, req));
        let (desc, parent) = {
            let entry = ctx.world.tasks.get(task);
            (entry.desc.clone(), entry.parent.expect("spawned task has a parent"))
        };
        let parent_desc = ctx.world.tasks.get(parent).desc.clone();
        for (i, a) in desc.dep_args() {
            let target = a.node.unwrap();
            let mode = a.access();
            // Locate the target and discover the path by following parent
            // pointers up to the parent task's argument (paper V-D).
            let anchor =
                crate::dep::analysis::find_anchor(&parent_desc.args, &ctx.world.mem, target, mode)
                    .unwrap_or_else(|| {
                        panic!(
                            "task {task} arg {i} ({target}) is not covered by its parent's footprint"
                        )
                    });
            let path_len = ctx.world.mem.path_len(anchor, target).unwrap_or(1);
            ctx.charge(
                ctx.sim.cost.sc_dep_locate + ctx.sim.cost.sc_dep_path_step * path_len as u64,
            );
            let owner = ctx.world.mem.owner(anchor);
            if owner == self.idx {
                self.descend(ctx, task, i, mode, target, anchor, false, settle);
            } else {
                ctx.world.gstats.dep_boundary_msgs += 1;
                let to = self.sched_core(ctx, owner);
                self.send_routed(
                    ctx,
                    to,
                    Msg::DepDescend {
                        task,
                        arg: i,
                        mode,
                        target,
                        cur: anchor,
                        entered: false,
                        settle,
                    },
                );
            }
        }
    }

    /// Downward traversal from `at` towards `target` (paper Fig 5a). Each
    /// hop is a cached-depth `next_hop` query — no path vectors.
    #[allow(clippy::too_many_arguments)]
    fn descend(
        &mut self,
        ctx: &mut Ctx<'_>,
        task: TaskId,
        arg: usize,
        mode: Access,
        target: NodeId,
        mut at: NodeId,
        mut entered: bool,
        settle: Option<(CoreId, ReqId)>,
    ) {
        loop {
            ctx.charge(ctx.sim.cost.sc_dep_path_step);
            let w = &mut *ctx.world;
            let node = w.dep.node_mut(at, &w.mem);
            // With recovery enabled a re-adopting parent legitimately
            // serves traversal steps on nodes owned by its dead child
            // (ownership is cost attribution; the state is shared).
            debug_assert!(
                node.owner == self.idx || w.cfg.recovery.enabled,
                "descend on foreign node {at}"
            );
            if entered {
                node.note_arrival(mode);
            }
            if at == target {
                let tasks = &w.tasks;
                let node = w.dep.node_mut(at, &w.mem);
                node.enqueue(task, arg, mode, target, &|a, t| tasks.is_ancestor(a, t));
                ctx.charge(ctx.sim.cost.sc_dep_enqueue);
                self.settle(ctx, settle);
                self.reeval(ctx, at);
                return;
            }
            let next = w.mem.next_hop(at, target).expect("target below current node");
            let tasks = &w.tasks;
            let can_pass = node.can_pass(task, mode, &|a, t| tasks.is_ancestor(a, t));
            if can_pass {
                let node = w.dep.node_mut(at, &w.mem);
                node.note_descent(next, mode);
                let next_owner = w.mem.owner(next);
                if next_owner == self.idx {
                    at = next;
                    entered = true;
                    continue;
                }
                ctx.world.gstats.dep_boundary_msgs += 1;
                let to = self.sched_core(ctx, next_owner);
                self.send_routed(
                    ctx,
                    to,
                    Msg::DepDescend { task, arg, mode, target, cur: next, entered: true, settle },
                );
                return;
            }
            // Blocked: enqueue here; the traversal resumes when the queue
            // ahead drains (paper: "the process stops and child() is
            // enqueued at the end of the local queue instead").
            let tasks = &w.tasks;
            let node = w.dep.node_mut(at, &w.mem);
            node.enqueue(task, arg, mode, target, &|a, t| tasks.is_ancestor(a, t));
            ctx.charge(ctx.sim.cost.sc_dep_enqueue);
            self.settle(ctx, settle);
            return;
        }
    }

    /// Re-evaluate a node after any state change: grant/resume entries,
    /// satisfy waiters, propagate quiescence.
    fn reeval(&mut self, ctx: &mut Ctx<'_>, at: NodeId) {
        // Pooled buffer: re-evaluation can recurse (quiescence reports
        // re-evaluate the parent node), so each nesting level takes its
        // own buffer; the pool caps out at the max nesting depth.
        let mut actions = self.ready_pool.pop().unwrap_or_default();
        actions.clear();
        {
            let w = &mut *ctx.world;
            if let Some(node) = w.dep.get_mut(at) {
                let tasks = &w.tasks;
                node.collect_ready_into(&|a, t| tasks.is_ancestor(a, t), &mut actions);
            }
        }
        for act in actions.drain(..) {
            match act {
                ReadyAction::Grant { task, arg } => {
                    ctx.charge(ctx.sim.cost.sc_grant);
                    let now = ctx.now();
                    if let Some(node) = ctx.world.dep.get_mut(at) {
                        node.last_grant_at = now;
                    }
                    let resp = ctx.world.tasks.get(task).resp;
                    if resp == self.idx {
                        self.on_arg_granted(ctx, task, arg);
                    } else {
                        let to = self.sched_core(ctx, resp);
                        self.send_routed(ctx, to, Msg::DepGranted { task, arg });
                    }
                }
                ReadyAction::Resume { task, arg, mode, target } => {
                    // The instance moves below this node.
                    let w = &mut *ctx.world;
                    let next = w.mem.next_hop(at, target).expect("resume path");
                    let node = w.dep.node_mut(at, &w.mem);
                    node.note_descent(next, mode);
                    let next_owner = w.mem.owner(next);
                    if next_owner == self.idx {
                        self.descend(ctx, task, arg, mode, target, next, true, None);
                    } else {
                        ctx.world.gstats.dep_boundary_msgs += 1;
                        let to = self.sched_core(ctx, next_owner);
                        self.send_routed(
                            ctx,
                            to,
                            Msg::DepDescend {
                                task,
                                arg,
                                mode,
                                target,
                                cur: next,
                                entered: true,
                                settle: None,
                            },
                        );
                    }
                }
            }
        }
        self.ready_pool.push(actions);
        // Waiters (sys_wait): scan in order, releasing satisfied ones.
        // The node state a wait depends on (queue, counters) is not
        // touched by `wait_node_ok`, so releasing in place preserves the
        // same release order as a snapshot-then-release scan.
        let mut wi = 0;
        loop {
            let Some(node) = ctx.world.dep.get_mut(at) else { return };
            if wi >= node.waiters.len() {
                break;
            }
            let (t, m) = node.waiters[wi];
            if node.wait_satisfied(t, m) {
                node.waiters.remove(wi);
                self.wait_node_ok(ctx, t, at);
            } else {
                wi += 1;
            }
        }
        // Quiescence propagation with the parent-counter race protocol.
        self.maybe_quiesce(ctx, at);
    }

    fn maybe_quiesce(&mut self, ctx: &mut Ctx<'_>, at: NodeId) {
        let (parent, pr, pw, dying) = {
            let Some(node) = ctx.world.dep.get_mut(at) else { return };
            // Per-mode quiescence channels: report each mode whose
            // activity drained and whose arrival count changed since the
            // last report for that mode.
            let mut pr = None;
            let mut pw = None;
            if node.read_quiescent() && node.last_quiesce_r != Some(node.pr_recv) {
                node.last_quiesce_r = Some(node.pr_recv);
                pr = Some(node.pr_recv);
            }
            if node.write_quiescent() && node.last_quiesce_w != Some(node.pw_recv) {
                node.last_quiesce_w = Some(node.pw_recv);
                pw = Some(node.pw_recv);
            }
            if pr.is_none() && pw.is_none() {
                return;
            }
            (node.parent, pr, pw, node.dying)
        };
        if let Some(p) = parent {
            if ctx.world.dep.contains(p) {
                ctx.charge(ctx.sim.cost.sc_quiesce);
                let powner = ctx.world.dep.get(p).unwrap().owner;
                if powner == self.idx {
                    self.on_quiesce(ctx, p, at, pr, pw);
                } else {
                    ctx.world.gstats.dep_boundary_msgs += 1;
                    let to = self.sched_core(ctx, powner);
                    self.send_routed(ctx, to, Msg::QuiesceUp { child: at, parent: p, pr, pw });
                }
            }
        }
        if dying {
            let remove = ctx
                .world
                .dep
                .get(at)
                .map(|n| n.waiters.is_empty() && n.is_quiescent())
                .unwrap_or(false);
            if remove {
                ctx.world.dep.remove(at);
            }
        }
    }

    fn on_quiesce(
        &mut self,
        ctx: &mut Ctx<'_>,
        parent: NodeId,
        child: NodeId,
        pr: Option<u64>,
        pw: Option<u64>,
    ) {
        ctx.charge(ctx.sim.cost.sc_quiesce);
        let matched = match ctx.world.dep.get_mut(parent) {
            Some(node) => node.apply_quiesce(child, pr, pw),
            None => false,
        };
        if matched {
            self.reeval(ctx, parent);
        }
    }

    fn on_arg_granted(&mut self, ctx: &mut Ctx<'_>, task: TaskId, arg: usize) {
        if self.trace_task == Some(task.0) {
            eprintln!(
                "[{}] t{} arg {} granted ({:?})",
                ctx.now(),
                task.0,
                arg,
                ctx.world.tasks.get(task).desc.args[arg].node
            );
        }
        let entry = ctx.world.tasks.get_mut(task);
        debug_assert!(entry.deps_pending > 0);
        entry.deps_pending -= 1;
        if entry.deps_pending == 0 {
            self.task_ready(ctx, task);
        }
    }

    // ============================================================== packing

    fn task_ready(&mut self, ctx: &mut Ctx<'_>, task: TaskId) {
        let now = ctx.now();
        {
            let entry = ctx.world.tasks.get_mut(task);
            entry.state = TaskState::Packing;
            entry.ready_at = now;
        }
        let desc = ctx.world.tasks.get(task).desc.clone();
        // Accumulate into the entry's own (empty) pack vector: re-packing
        // after the task retires would reuse its capacity, and the final
        // move into the entry is free.
        let mut acc: Vec<ProducerRange> = std::mem::take(&mut ctx.world.tasks.get_mut(task).pack);
        acc.clear();
        let mut outstanding = 0usize;
        let req = self.fresh_req();
        for (_, a) in desc.dep_args() {
            if a.is_notransfer() || a.flags & crate::task::descriptor::TYPE_IN_ARG == 0 {
                // NOTRANSFER (paper V-A) and write-only arguments move no
                // data to the consumer: nothing to pack.
                continue;
            }
            let node = a.node.unwrap();
            if ctx.world.mem.owner(node) == self.idx {
                let before = acc.len();
                self.pack_remote.clear();
                ctx.world.mem.collect_pack_into(
                    node,
                    &mut self.pack_scratch,
                    &mut acc,
                    &mut self.pack_remote,
                );
                ctx.charge(
                    ctx.sim.cost.sc_pack_base
                        + ctx.sim.cost.sc_pack_per_range * (acc.len() - before) as u64,
                );
                outstanding += self.send_pack_reqs(ctx, req);
            } else {
                outstanding += 1;
                let owner = ctx.world.mem.owner(node);
                let to = self.sched_core(ctx, owner);
                self.send_routed(ctx, to, Msg::PackReq { req, node, reply_to: self.core });
            }
        }
        if outstanding == 0 {
            ctx.world.tasks.get_mut(task).pack = acc;
            self.enqueue_ready(ctx, task);
        } else {
            ctx.world
                .journal
                .packs
                .insert(req, PackPending { task: Some(task), reply: None, outstanding, acc });
        }
    }

    fn on_pack_req(&mut self, ctx: &mut Ctx<'_>, req: ReqId, node: NodeId, reply_to: CoreId) {
        // The ranges leave this core inside a PackResp message (or wait in
        // a pending aggregation), so they need an owned vector; the walk
        // itself runs over reusable scratch.
        let mut ranges: Vec<ProducerRange> = Vec::new();
        self.pack_remote.clear();
        ctx.world.mem.collect_pack_into(
            node,
            &mut self.pack_scratch,
            &mut ranges,
            &mut self.pack_remote,
        );
        ctx.charge(
            ctx.sim.cost.sc_pack_base + ctx.sim.cost.sc_pack_per_range * ranges.len() as u64,
        );
        if self.pack_remote.is_empty() {
            self.send_routed(ctx, reply_to, Msg::PackResp { req, ranges });
            return;
        }
        let nested = self.fresh_req();
        let outstanding = self.pack_remote.len();
        ctx.world.journal.packs.insert(
            nested,
            PackPending { task: None, reply: Some((req, reply_to)), outstanding, acc: ranges },
        );
        let sent = self.send_pack_reqs(ctx, nested);
        debug_assert_eq!(sent, outstanding);
    }

    /// Forward a `PackReq` tagged `req` to the owner of every remote
    /// subregion root the last pack walk gathered into `pack_remote`.
    /// Returns how many were sent. (The list is `mem::take`n so it stays
    /// unborrowed across `send_routed`, then put back to keep its
    /// capacity.)
    fn send_pack_reqs(&mut self, ctx: &mut Ctx<'_>, req: ReqId) -> usize {
        let remote = std::mem::take(&mut self.pack_remote);
        for &r in &remote {
            let owner = ctx.world.mem.owner(NodeId::Region(r));
            let to = self.sched_core(ctx, owner);
            self.send_routed(
                ctx,
                to,
                Msg::PackReq { req, node: NodeId::Region(r), reply_to: self.core },
            );
        }
        let n = remote.len();
        self.pack_remote = remote;
        n
    }

    fn on_pack_resp(&mut self, ctx: &mut Ctx<'_>, req: ReqId, ranges: Vec<ProducerRange>) {
        let finished = {
            let Some(p) = ctx.world.journal.packs.get_mut(&req) else { return };
            p.acc.extend(ranges);
            p.outstanding -= 1;
            p.outstanding == 0
        };
        if !finished {
            return;
        }
        let p = ctx.world.journal.packs.remove(&req).unwrap();
        if let Some(task) = p.task {
            ctx.world.tasks.get_mut(task).pack = p.acc;
            self.enqueue_ready(ctx, task);
        } else if let Some((orig, reply_to)) = p.reply {
            self.send_routed(ctx, reply_to, Msg::PackResp { req: orig, ranges: p.acc });
        }
    }

    // ========================================== ready queue + work stealing

    /// A packed, dependency-free task enters this scheduler's ready queue.
    /// Dispatch is "pop + place + send" (`pump`), so queued tasks remain
    /// migratable until the moment they are placed.
    fn enqueue_ready(&mut self, ctx: &mut Ctx<'_>, task: TaskId) {
        {
            let entry = ctx.world.tasks.get_mut(task);
            entry.state = TaskState::Queued;
            entry.queued_at = self.idx;
        }
        self.ready.push_back(task);
        let depth = self.ready.len() as u64;
        if depth > ctx.world.gstats.ready_queue_hwm {
            ctx.world.gstats.ready_queue_hwm = depth;
        }
        self.pump(ctx);
    }

    /// Pop + place ready tasks. With stealing disabled this always drains
    /// the queue immediately (identical behavior — and byte-identical
    /// event schedule — to the pre-ReadyQ dispatch path). With stealing
    /// enabled, dispatch throttles once every placement target is at
    /// capacity: the surplus stays here, visible in upstream load reports
    /// and stealable by the parent. Re-pumped on every load decay
    /// (completions, forwarded `TaskDone` hops) and load report.
    fn pump(&mut self, ctx: &mut Ctx<'_>) {
        while !self.ready.is_empty() {
            if self.placer.steal_cfg().enabled
                && !self.placer.has_headroom(&ctx.world.hier, self.idx)
            {
                break;
            }
            let task = self.ready.pop_front().expect("non-empty ready queue");
            // Stale-lease check: the table, not the queue, is the source
            // of truth. A crash re-adoption may have re-issued this task
            // elsewhere (its `queued_at` moved); dispatching the local
            // leftover would run it twice. Never taken in a crash-free
            // run, so the pre-recovery schedule is untouched.
            {
                let entry = ctx.world.tasks.get(task);
                if entry.state != TaskState::Queued || entry.queued_at != self.idx {
                    ctx.world.gstats.crash_dups_dropped += 1;
                    continue;
                }
            }
            self.place(ctx, task);
        }
    }

    /// Idle-driven steal trigger: when one child subtree's load estimate
    /// is 0 while a sibling's is at/above the threshold, ask the victim
    /// (chosen by the configured [`VictimPolicy`]) for up to `batch`
    /// queued-ready tasks. One request in flight at a time.
    ///
    /// [`VictimPolicy`]: crate::sched::policy::VictimPolicy
    fn maybe_steal(&mut self, ctx: &mut Ctx<'_>) {
        if !self.placer.steal_cfg().enabled || self.steal_victim.is_some() {
            return;
        }
        let Some(victim) = self.placer.choose_victim(&ctx.world.hier, self.idx) else {
            return;
        };
        self.steal_victim = Some(victim);
        ctx.world.gstats.steal_reqs += 1;
        let batch = self.placer.steal_cfg().batch;
        let to = self.sched_core(ctx, victim);
        self.send_routed(ctx, to, Msg::StealReq { batch });
    }

    /// Victim side: surrender up to `batch` tasks from the *back* of the
    /// ready queue (the work this scheduler would reach last), or refuse
    /// if everything is already committed to workers/subtrees.
    fn on_steal_req(&mut self, ctx: &mut Ctx<'_>, from: CoreId, batch: u32) {
        ctx.charge(ctx.sim.cost.sc_steal_handle);
        // A StealReq whose sender is one of this scheduler's own children
        // is its own in-flight request, surfaced from a re-adopted dead
        // mailbox (the drain rewrites the sender to the dead core).
        // `declare_dead` already answered it with the synthesized deny —
        // swallow it. "Serving" it instead would reply towards *this*
        // scheduler's parent: at the root that parent does not exist, and
        // at a mid level the reply would corrupt the grandparent's latch.
        if ctx
            .world
            .hier
            .sched_idx(from)
            .is_some_and(|s| ctx.world.hier.parent[s] == Some(self.idx))
        {
            assert!(
                ctx.world.cfg.recovery.enabled,
                "StealReq from own child outside crash recovery"
            );
            ctx.world.gstats.crash_dups_dropped += 1;
            return;
        }
        // Otherwise a StealReq only ever comes from the parent scheduler.
        let parent = ctx.world.hier.parent[self.idx].expect("stolen-from scheduler has a parent");
        let reply_to = self.sched_core(ctx, parent);
        // Fault injection: deny regardless of queue depth, exercising the
        // thief's deny path and deny-retry backoff under load.
        if ctx.chaos_force_deny() {
            self.send_routed(ctx, reply_to, Msg::StealDeny);
            return;
        }
        let mut tasks = Vec::new();
        while (tasks.len() as u32) < batch {
            let Some(t) = self.ready.pop_back() else { break };
            // Same stale-lease check as `pump`: never surrender a queue
            // entry the table no longer maps to this scheduler.
            {
                let entry = ctx.world.tasks.get(t);
                if entry.state != TaskState::Queued || entry.queued_at != self.idx {
                    ctx.world.gstats.crash_dups_dropped += 1;
                    continue;
                }
            }
            ctx.charge(ctx.sim.cost.sc_steal_per_task);
            tasks.push(t);
        }
        if tasks.is_empty() {
            self.send_routed(ctx, reply_to, Msg::StealDeny);
            return;
        }
        self.send_routed(ctx, reply_to, Msg::StealGrant { tasks });
        // The queue shrank: refresh the parent's authoritative view (the
        // grant already carried the eager decay; threshold-gated reports
        // then land decay-then-overwrite like every other refresh).
        self.report_up(ctx);
    }

    /// Deny-retry backoff: with `StealCfg::retry_backoff > 0`, a denied
    /// thief re-arms the steal trigger after a capped exponential delay
    /// instead of going quiet until the next natural trigger (a load
    /// report or completion hop). The default backoff of 0 disables the
    /// path entirely — no timer, no counter movement — keeping the
    /// pre-retry event schedule byte-identical.
    fn retry_after_deny(&mut self, ctx: &mut Ctx<'_>) {
        let cfg = self.placer.steal_cfg();
        if cfg.retry_backoff == 0 {
            return;
        }
        if self.steal_retries >= cfg.retry_max {
            // Budget exhausted: go quiet; the next grant resets the count.
            return;
        }
        self.steal_retries += 1;
        let shift = (self.steal_retries - 1).min(10);
        let delay = cfg.retry_backoff.saturating_mul(1u64 << shift);
        ctx.after(delay, TimerKind::Custom(STEAL_RETRY_TIMER));
    }

    /// Thief side: account the migration (decay the victim's estimate,
    /// charge the destination) and re-place every stolen task towards the
    /// idle side of this scheduler's subtree.
    fn on_steal_grant(&mut self, ctx: &mut Ctx<'_>, tasks: Vec<TaskId>) {
        let Some(victim) = self.steal_victim.take() else {
            // Only one way the latch can be empty: the victim granted,
            // then died before the (possibly chaos-delayed) grant landed,
            // and `declare_dead` already synthesized the deny and
            // re-issued every lease in this batch (they were all still
            // `Queued` at the victim when it was declared). Late
            // duplicate — outside recovery it is a protocol bug.
            assert!(
                ctx.world.cfg.recovery.enabled,
                "grant without an outstanding StealReq"
            );
            ctx.world.gstats.crash_dups_dropped += tasks.len() as u64;
            return;
        };
        self.steal_retries = 0;
        ctx.world.gstats.steal_grants += 1;
        ctx.world.gstats.tasks_stolen += tasks.len() as u64;
        self.placer.victim_stolen(victim, tasks.len() as u64);
        for task in tasks {
            self.place_stolen(ctx, task, victim);
        }
        // The victim decay may have opened headroom for this scheduler's
        // own held-back ready tasks — dispatch them (FIFO, so older local
        // work is not overtaken further by the freshly routed steals).
        self.pump(ctx);
        // Re-placement bumped the idle slot(s), so the trigger condition
        // re-evaluates against fresh estimates: still-imbalanced trees may
        // immediately pull another batch, balanced ones stop.
        self.maybe_steal(ctx);
    }

    /// Re-place one stolen task: charge the re-pack (its descriptor and
    /// range list re-marshal towards the new subtree) plus a scoring pass,
    /// then send it down the least-loaded child other than the victim.
    /// The receiver runs the normal queue/place path from there.
    fn place_stolen(&mut self, ctx: &mut Ctx<'_>, task: TaskId, victim: usize) {
        let (ranges, epoch) = {
            let entry = ctx.world.tasks.get(task);
            (entry.pack.len() as u64, entry.epoch)
        };
        ctx.charge(ctx.sim.cost.sc_pack_base + ctx.sim.cost.sc_pack_per_range * ranges);
        let (dest, scored) = self.placer.steal_dest(&ctx.world.hier, self.idx, victim);
        ctx.charge(ctx.sim.cost.sc_score_base + ctx.sim.cost.sc_score_per_child * scored);
        ctx.world.tasks.get_mut(task).state = TaskState::Placing;
        let to = self.sched_core(ctx, dest);
        self.send_routed(ctx, to, Msg::ScheduleDown { task, epoch });
    }

    // ============================================================ placement

    /// Hierarchical placement descent (paper V-E): the configured policy
    /// picks a child subtree, or a worker at leaf level, and the task is
    /// forwarded/dispatched accordingly. The task's pack list is borrowed
    /// via `mem::take` (and restored); candidate scoring, eager load
    /// bookkeeping and any policy randomness live in [`Placer`].
    fn place(&mut self, ctx: &mut Ctx<'_>, task: TaskId) {
        ctx.world.tasks.get_mut(task).state = TaskState::Placing;
        let pack = std::mem::take(&mut ctx.world.tasks.get_mut(task).pack);
        if !ctx.world.hier.children[self.idx].is_empty() {
            let (chosen, scored) = self.placer.choose_child(&ctx.world.hier, self.idx, &pack);
            ctx.charge(
                ctx.sim.cost.sc_score_base + ctx.sim.cost.sc_score_per_child * scored,
            );
            ctx.world.tasks.get_mut(task).pack = pack;
            let epoch = ctx.world.tasks.get(task).epoch;
            let to = self.sched_core(ctx, chosen);
            self.send_routed(ctx, to, Msg::ScheduleDown { task, epoch });
            return;
        }
        // Leaf: pick a worker.
        assert!(
            !ctx.world.hier.leaf_workers[self.idx].is_empty(),
            "leaf scheduler {} has no workers",
            self.idx
        );
        let (w, scored) = self.placer.choose_worker(&ctx.world.hier, self.idx, &pack);
        ctx.charge(ctx.sim.cost.sc_score_base + ctx.sim.cost.sc_score_per_child * scored);
        {
            let entry = ctx.world.tasks.get_mut(task);
            entry.worker = Some(w);
            entry.state = TaskState::Dispatched;
            entry.pack = pack;
        }
        // New last producer for write arguments (paper V-E).
        let desc = ctx.world.tasks.get(task).desc.clone();
        for (_, a) in desc.dep_args() {
            if a.access() == Access::Write && !a.is_notransfer() {
                let node = a.node.unwrap();
                ctx.world.mem.set_producer(node, w);
                let owner = ctx.world.mem.owner(node);
                if owner != self.idx {
                    let to = self.sched_core(ctx, owner);
                    self.send_routed(ctx, to, Msg::ProducerUpdate { node, worker: w });
                }
            }
        }
        ctx.charge(ctx.sim.cost.sc_dispatch);
        self.send_routed(ctx, w, Msg::Dispatch { task });
    }

    // ============================================================ completion

    fn on_task_done(&mut self, ctx: &mut Ctx<'_>, task: TaskId) {
        // Exactly-once completion: a `TaskDone` for a task already
        // recorded `Done` is a late duplicate that surfaced from a dead
        // scheduler's drained mailbox. The table is the source of truth —
        // drop it before any forwarding or accounting.
        if ctx.world.tasks.get(task).state == TaskState::Done {
            ctx.world.gstats.crash_dups_dropped += 1;
            return;
        }
        let resp = ctx.world.tasks.get(task).resp;
        if resp != self.idx {
            // Leaf on the worker's path: refresh the local load estimate,
            // then forward to the responsible scheduler. The forward goes
            // out *before* the load report so upstream schedulers apply
            // their eager-estimate decay first and the authoritative
            // report (which already reflects this completion) lands last —
            // decay-then-overwrite never double-counts.
            // After a crash re-adoption this first-hop role can fall to
            // the dead leaf's *parent*, whose tracker has no slot for the
            // adopted worker — attribute only workers actually attached
            // here (`child_done` on the resp path covers the rest).
            let known_worker = ctx.world.tasks.get(task).worker.filter(|&w| {
                ctx.world.hier.is_leaf(self.idx) && ctx.world.hier.leaf_of_worker(w) == self.idx
            });
            if let Some(w) = known_worker {
                self.placer.worker_done(w);
            }
            let to = self.sched_core(ctx, resp);
            self.send_routed(ctx, to, Msg::TaskDone { task });
            if known_worker.is_some() {
                // The decay may have opened headroom for a held task.
                self.pump(ctx);
                self.report_up(ctx);
            }
            return;
        }
        ctx.charge(ctx.sim.cost.sc_task_done);
        let now = ctx.now();
        {
            let entry = ctx.world.tasks.get_mut(task);
            entry.state = TaskState::Done;
            entry.done_at = now;
        }
        // Undo the eager load estimate from `place()`: at a leaf the unit
        // went to the worker itself; at an inner scheduler it went to the
        // child subtree the task descended into. (The decay mirrors the
        // worker-level refresh — previously inner schedulers leaked their
        // eager increments until the next child load report, so estimates
        // drifted upward whenever reports were throttled.) A stolen task
        // may have run on a worker *outside* this scheduler's subtree
        // (migration above a delegated-to leaf): then there is nothing to
        // decay here — this scheduler never placed it. `child_done`
        // already no-ops via `child_towards`; the leaf case needs the
        // explicit attachment check.
        if let Some(w) = ctx.world.tasks.get(task).worker {
            if ctx.world.hier.is_leaf(self.idx) {
                if ctx.world.hier.leaf_of_worker(w) == self.idx {
                    self.placer.worker_done(w);
                }
            } else {
                self.placer.child_done(&ctx.world.hier, self.idx, w);
            }
        }
        ctx.world.gstats.tasks_completed += 1;
        // Traffic books: same exactly-once site as the completion counter
        // (the dedup above covers crash-recovery duplicates too).
        if let Some(j) = ctx.world.tasks.get(task).job {
            if let Some(tr) = ctx.world.traffic.as_mut() {
                tr.on_task_completed(j, now);
            }
        }
        let desc = ctx.world.tasks.get(task).desc.clone();
        for (i, a) in desc.dep_args() {
            let node = a.node.unwrap();
            let owner = match ctx.world.dep.get(node) {
                Some(n) => n.owner,
                None => continue, // region freed while the task ran
            };
            if owner == self.idx {
                self.on_pop_entry(ctx, node, task, i);
            } else {
                let to = self.sched_core(ctx, owner);
                self.send_routed(ctx, to, Msg::PopEntry { node, task, arg: i });
            }
        }
        // Quiescence: under traffic, counts matching between jobs (or
        // while deferred jobs await their retry timers) must not end the
        // run — the gate additionally requires every arrival fired and
        // every admitted job drained. `traffic == None` keeps the
        // original single-job gate bit-for-bit.
        if ctx.world.gstats.tasks_completed == ctx.world.gstats.tasks_spawned
            && ctx.world.traffic.as_ref().map_or(true, |t| t.all_done())
        {
            ctx.world.done = true;
        }
        // The decay may have opened headroom (dispatch a held task) or
        // idled a child subtree (trigger a steal). No-ops when stealing
        // is disabled: the queue is empty and maybe_steal returns early.
        self.pump(ctx);
        self.maybe_steal(ctx);
    }

    // ==================================================== traffic admission

    /// A job's arrival timer fired (pre-pushed at build time from the
    /// open-loop schedule): first admission attempt, at this entry
    /// scheduler. The phase/entry guards make a duplicate firing a no-op:
    /// crash recovery re-arms job timers (the engine drops timers that
    /// fire inside a down window), and after a *spurious* declaration
    /// both the original timer and the adopter's re-arm can fire — the
    /// first one to process wins, deterministically.
    fn on_job_arrival(&mut self, ctx: &mut Ctx<'_>, j: JobId) {
        match ctx.world.traffic.as_mut() {
            Some(tr) if tr.job(j).phase == JobPhase::Scheduled && tr.job(j).entry == self.idx => {
                tr.note_arrived(j);
            }
            _ => return,
        }
        self.try_admit(ctx, j);
    }

    /// A deferred job's backoff timer fired: re-run admission against
    /// current (drained-since) state. Same duplicate-firing guards as
    /// [`Scheduler::on_job_arrival`].
    fn on_job_retry(&mut self, ctx: &mut Ctx<'_>, j: JobId) {
        match ctx.world.traffic.as_ref() {
            Some(tr) if tr.job(j).phase == JobPhase::Deferred && tr.job(j).entry == self.idx => {}
            _ => return,
        }
        self.try_admit(ctx, j);
    }

    /// Decentralized admission. The decision consults only state local to
    /// this scheduler — its own load books via the [`Placer`] seam and the
    /// tenant's live-job count — never the hierarchy root, so admission
    /// scales with the number of top-level subtrees. Admit injects the
    /// job's root task pre-granted on a fresh per-job region *pinned to
    /// this scheduler* (ownership discipline: admission mutates nothing
    /// another scheduler owns); defer re-arms a retry timer with capped
    /// exponential backoff, so a job is never dropped — load drains as
    /// running tasks finish and a later retry must eventually pass.
    fn try_admit(&mut self, ctx: &mut Ctx<'_>, j: JobId) {
        let (shape, main_fn, live) = match ctx.world.traffic.as_ref() {
            Some(tr) => {
                let b = tr.job(j);
                (b.shape, tr.main_fn, tr.tenant_live(b.tenant))
            }
            None => return,
        };
        // The decision reads the same books a load report would.
        ctx.charge(ctx.sim.cost.sc_load_report);
        if !self.placer.admit_job(&ctx.world.cfg.traffic, live) {
            let delay = ctx.world.traffic.as_mut().unwrap().note_deferred(j);
            ctx.after(delay, TimerKind::Custom(traffic::retry_tag(j)));
            return;
        }
        // Inject: mirror the boot main-task path (create + pre-grant on a
        // fresh region + straight to packing). The region is empty, so the
        // pre-grant is trivially race-free, and it is owned here, so the
        // whole admission is one local event.
        ctx.charge(ctx.sim.cost.sc_ralloc + ctx.sim.cost.sc_spawn_handle + ctx.sim.cost.sc_grant);
        let now = ctx.now();
        let region = ctx.world.mem.ralloc_pinned(RegionId::ROOT, self.idx);
        let desc = TaskDesc::new(
            main_fn,
            vec![
                TaskArg::region_inout(region),
                TaskArg::val(shape.tasks as u64),
                TaskArg::val(shape.task_cycles),
                TaskArg::val(shape.fanout as u64),
                TaskArg::val(shape.hot_pct as u64),
            ],
        );
        let task = ctx.world.tasks.create(desc, None, self.idx, now);
        ctx.world.tasks.get_mut(task).job = Some(j);
        ctx.world.gstats.tasks_spawned += 1;
        ctx.world.traffic.as_mut().unwrap().note_admitted(j, task, now);
        {
            let mem = &ctx.world.mem;
            let node = ctx.world.dep.node_mut(NodeId::Region(region), mem);
            node.enqueue_granted(task, 0, Access::Write);
        }
        ctx.world.tasks.get_mut(task).deps_pending = 0;
        self.task_ready(ctx, task);
    }

    fn on_pop_entry(&mut self, ctx: &mut Ctx<'_>, node: NodeId, task: TaskId, arg: usize) {
        let popped = match ctx.world.dep.get_mut(node) {
            Some(n) => n.pop_task(task, arg),
            None => false,
        };
        if popped {
            ctx.charge(ctx.sim.cost.sc_dep_dequeue);
            self.reeval(ctx, node);
        }
    }

    // ============================================================== sys_wait

    fn on_wait_req(
        &mut self,
        ctx: &mut Ctx<'_>,
        task: TaskId,
        origin: CoreId,
        nodes: Vec<(NodeId, Access)>,
    ) {
        let resp = ctx.world.tasks.get(task).resp;
        if resp != self.idx {
            let to = self.sched_core(ctx, resp);
            self.send_routed(ctx, to, Msg::WaitReq { task, origin, nodes });
            return;
        }
        ctx.world.tasks.get_mut(task).state = TaskState::Waiting;
        if nodes.is_empty() {
            self.send_routed(ctx, origin, Msg::WaitGranted { task });
            return;
        }
        ctx.world.journal.waits.insert(task, nodes.len());
        for (node, mode) in nodes {
            let owner = match ctx.world.dep.get(node) {
                Some(n) => n.owner,
                None => ctx.world.mem.owner(node),
            };
            if owner == self.idx {
                self.register_wait(ctx, task, node, mode);
            } else {
                let to = self.sched_core(ctx, owner);
                self.send_routed(ctx, to, Msg::RegisterWait { task, node, mode });
            }
        }
    }

    fn register_wait(&mut self, ctx: &mut Ctx<'_>, task: TaskId, node: NodeId, mode: Access) {
        let satisfied = {
            let w = &mut *ctx.world;
            let n = w.dep.node_mut(node, &w.mem);
            if n.wait_satisfied(task, mode) {
                true
            } else {
                n.waiters.push((task, mode));
                false
            }
        };
        if satisfied {
            self.wait_node_ok(ctx, task, node);
        }
    }

    fn wait_node_ok(&mut self, ctx: &mut Ctx<'_>, task: TaskId, node: NodeId) {
        let resp = ctx.world.tasks.get(task).resp;
        if resp != self.idx {
            let to = self.sched_core(ctx, resp);
            self.send_routed(ctx, to, Msg::WaitNodeOk { task, node });
            return;
        }
        let drained = {
            let Some(left) = ctx.world.journal.waits.get_mut(&task) else { return };
            *left -= 1;
            *left == 0
        };
        if drained {
            ctx.world.journal.waits.remove(&task);
            let worker = ctx.world.tasks.get(task).worker.expect("waiting task has a worker");
            ctx.world.tasks.get_mut(task).state = TaskState::Running;
            self.send_routed(ctx, worker, Msg::WaitGranted { task });
        }
    }

    // ======================================================= memory service

    fn on_mem_req(
        &mut self,
        ctx: &mut Ctx<'_>,
        req: ReqId,
        origin: CoreId,
        owner: CoreId,
        op: MemOpKind,
    ) {
        if owner != self.core && !self.serving_for(ctx, owner) {
            self.send_routed(ctx, owner, Msg::MemReq { req, origin, owner, op });
            return;
        }
        let c = &ctx.sim.cost;
        let cost = match op {
            MemOpKind::Alloc => c.sc_alloc,
            MemOpKind::Balloc { n } => c.sc_alloc + c.sc_balloc_per_obj * n as u64,
            MemOpKind::Ralloc => c.sc_ralloc,
            MemOpKind::Free => c.sc_free,
            MemOpKind::Rfree { nodes } => c.sc_free + c.sc_rfree_per_node * nodes as u64,
            MemOpKind::Realloc => c.sc_alloc + c.sc_free,
        };
        ctx.charge(cost);
        self.send_routed(ctx, origin, Msg::MemResp { req });
    }

    // ========================================================= load reports

    fn on_load_report(&mut self, ctx: &mut Ctx<'_>, from: CoreId, load: u64) {
        ctx.charge(ctx.sim.cost.sc_load_report);
        match ctx.world.hier.sched_idx(from) {
            Some(s) => {
                // Stale pre-crash traffic from a child declared dead
                // since: scoring it would resurrect the book the
                // declaration just zeroed. (A restarted child's fresh
                // report rides the same link *behind* its Rejoin, so it
                // always lands on a live mark.)
                if ctx.world.hier.parent[s] == Some(self.idx) && self.placer.child_is_dead(s) {
                    ctx.world.gstats.crash_dups_dropped += 1;
                    return;
                }
                self.placer.child_report(s, load)
            }
            None => {
                // A re-adopted orphan worker reports here during an
                // outage, but the (non-leaf) adopter keeps no worker
                // book — orphans only drain in-flight work until their
                // leaf rejoins, so the report carries no decision.
                if !ctx.world.hier.is_leaf(self.idx)
                    || ctx.world.hier.leaf_of_worker(from) != self.idx
                {
                    return;
                }
                self.placer.worker_report(from, load)
            }
        }
        // Fresh estimates may reveal headroom or an idle/loaded imbalance.
        // Pump first: dispatching from the queue keeps total+queue
        // constant, so the upstream report below is unaffected by order.
        self.pump(ctx);
        self.maybe_steal(ctx);
        self.report_up(ctx);
    }

    /// Re-aggregate and report upstream when the load changed by at least
    /// the configured threshold (paper V-C). The aggregate is the
    /// tracker's incrementally maintained total — O(1), no table scan —
    /// plus the depth of this scheduler's own ready queue: held-back
    /// ready tasks are load this subtree owns, and without the term a
    /// holding scheduler under-reports exactly the surplus the rebalance
    /// protocol exists to detect. (With stealing disabled the queue is
    /// always empty here, so the reported value is unchanged.)
    fn report_up(&mut self, ctx: &mut Ctx<'_>) {
        let my_load = self.placer.total() + self.ready.len() as u64;
        let thr = ctx.world.cfg.load_report_threshold;
        if my_load.abs_diff(self.last_reported) >= thr {
            if let Some(p) = ctx.world.hier.parent[self.idx] {
                self.last_reported = my_load;
                let to = self.sched_core(ctx, p);
                ctx.send(to, Msg::LoadReport { from: self.core, load: my_load });
            }
        }
    }

    // ======================================================= crash recovery

    /// Is `core` a scheduler child of mine that I currently serve for
    /// (declared dead, mailbox re-adopted)? Requests addressed to it by
    /// core id (`MemReq`) are handled here instead of re-forwarded — the
    /// redirect would bounce them back forever.
    fn serving_for(&self, ctx: &Ctx<'_>, core: CoreId) -> bool {
        ctx.world.cfg.recovery.enabled
            && ctx.world.hier.sched_idx(core).is_some_and(|s| {
                ctx.world.hier.parent[s] == Some(self.idx) && self.placer.child_is_dead(s)
            })
    }

    /// Arm the next heartbeat tick. Gated on the recovery switch, on
    /// having scheduler children to probe, and on the run still being
    /// live — once `done` is set the chain stops, or teardown would idle
    /// behind a timer nobody needs.
    fn maybe_arm_heartbeat(&mut self, ctx: &mut Ctx<'_>) {
        let rc = ctx.world.cfg.recovery;
        if rc.enabled && !ctx.world.hier.children[self.idx].is_empty() && !ctx.world.done {
            ctx.after(rc.heartbeat_period, TimerKind::Custom(HEARTBEAT_TIMER));
        }
    }

    /// One heartbeat tick: probe every live scheduler child, declare the
    /// ones whose last `Pong` is older than the timeout, re-arm.
    fn on_heartbeat(&mut self, ctx: &mut Ctx<'_>) {
        if ctx.world.done {
            return;
        }
        let timeout = ctx.world.cfg.recovery.heartbeat_timeout;
        let now = ctx.now();
        // Child slots are contiguous (slot i = base + i), so the probe
        // loop borrows nothing and allocates nothing.
        let n = ctx.world.hier.children[self.idx].len();
        let Some(&base) = ctx.world.hier.children[self.idx].first() else { return };
        for slot in 0..n {
            if self.placer.loads.child_dead(slot) {
                continue;
            }
            if now.saturating_sub(self.last_pong[slot]) > timeout {
                self.declare_dead(ctx, base + slot);
            } else {
                ctx.world.gstats.heartbeats += 1;
                ctx.charge(ctx.sim.cost.sc_load_report);
                let to = self.sched_core(ctx, base + slot);
                self.send_routed(ctx, to, Msg::Ping);
            }
        }
        self.maybe_arm_heartbeat(ctx);
    }

    /// Liveness probe from the parent — answer with a `Pong`. The probe
    /// may also be *our own*: a `Ping` sent to a child declared dead
    /// since bounces off its re-adopted mailbox back to us (sender
    /// rewritten to the dead core) and must be swallowed, not ponged.
    fn on_ping(&mut self, ctx: &mut Ctx<'_>, from: CoreId) {
        ctx.charge(ctx.sim.cost.sc_load_report);
        if let Some(s) = ctx.world.hier.sched_idx(from) {
            if ctx.world.hier.parent[s] == Some(self.idx) && self.placer.child_is_dead(s) {
                return;
            }
        }
        self.send_routed(ctx, from, Msg::Pong);
    }

    /// `Pong` from a scheduler child: refresh its liveness stamp.
    fn on_pong(&mut self, ctx: &mut Ctx<'_>, from: CoreId) {
        ctx.charge(ctx.sim.cost.sc_load_report);
        if let Some(s) = ctx.world.hier.sched_idx(from) {
            if ctx.world.hier.parent[s] == Some(self.idx) {
                self.last_pong[self.placer.loads.child_slot(s)] = ctx.now();
            }
        }
    }

    /// A scheduler child missed its heartbeat deadline: take its subtree
    /// over. The parent (a) adopts the dead core's mailbox so in-flight
    /// traffic drains here instead of blackholing, (b) drops the child
    /// from every placement/steal decision, (c) releases a steal latch
    /// the victim can no longer answer, (d) re-attaches the orphaned
    /// workers to itself, and (e) re-issues the tasks stranded in the
    /// dead scheduler's volatile ready queue towards surviving siblings.
    ///
    /// Exactly-once contract: the durable task table is the source of
    /// truth. Only tasks still `Queued` *and* leased to the dead child
    /// (`queued_at`) are re-issued, each under a bumped epoch; anything
    /// further along (Placing/Dispatched/Running) completes through the
    /// re-adopted mailbox and the adopted workers. Stale queue entries
    /// and stale `ScheduleDown`s are dropped by the lease/epoch checks at
    /// dispatch time, so a spurious declaration (a slow-but-alive child)
    /// costs capacity, never correctness.
    fn declare_dead(&mut self, ctx: &mut Ctx<'_>, child: usize) {
        let dead_core = ctx.world.hier.sched_core(child);
        ctx.world.gstats.re_adoptions += 1;
        ctx.charge(ctx.sim.cost.sc_score_base);
        self.placer.mark_child_dead(child);
        ctx.adopt_mailbox(dead_core, self.core);
        // An outstanding StealReq to the dead child can never be
        // answered — synthesize the deny so the one-request latch is
        // released and deny-retry backoff keeps this thief live.
        if self.steal_victim == Some(child) {
            self.steal_victim = None;
            ctx.world.gstats.steal_denies += 1;
            ctx.world.gstats.crash_denies_synth += 1;
            self.retry_after_deny(ctx);
        }
        for i in 0..ctx.world.hier.leaf_workers[child].len() {
            let w = ctx.world.hier.leaf_workers[child][i];
            ctx.charge(ctx.sim.cost.sc_dispatch);
            self.send_routed(ctx, w, Msg::Adopt { leaf: self.core });
        }
        // Recovery scan (off the hot path — at most one outage per run,
        // so the allocation is fine): responsibility for the dead child's
        // tasks moves here; stranded `Queued` leases are re-issued.
        let mut orphans = Vec::new();
        for e in ctx.world.tasks.iter_mut() {
            if e.resp == child {
                e.resp = self.idx;
            }
            if e.state == TaskState::Queued && e.queued_at == child {
                e.epoch += 1;
                orphans.push(e.id);
            }
        }
        ctx.world.gstats.tasks_reissued += orphans.len() as u64;
        for t in orphans {
            ctx.charge(ctx.sim.cost.sc_steal_per_task);
            self.enqueue_ready(ctx, t);
        }
        // Traffic takeover: timers that fire at a dead core are dropped
        // by the engine, so the dead child's not-yet-admitted jobs move
        // here — entry reassigned, arrival/retry timers re-armed at this
        // scheduler. Already-live jobs need nothing: their tasks drain
        // through the task-table recovery above. If the declaration was
        // spurious the original timers may still fire at the (alive)
        // child, where the entry guard drops them.
        if let Some(tr) = ctx.world.traffic.as_mut() {
            let now = ctx.now();
            let backoff = tr.retry_backoff;
            let mut rearm: Vec<(Cycles, u64)> = Vec::new();
            for (i, b) in tr.jobs.iter_mut().enumerate() {
                if b.entry != child {
                    continue;
                }
                let j = JobId(i as u32);
                match b.phase {
                    JobPhase::Scheduled => {
                        b.entry = self.idx;
                        let delay = b.submit_at.saturating_sub(now).max(1);
                        rearm.push((delay, traffic::arrive_tag(j)));
                    }
                    JobPhase::Deferred => {
                        b.entry = self.idx;
                        rearm.push((backoff, traffic::retry_tag(j)));
                    }
                    JobPhase::Live | JobPhase::Done => {}
                }
            }
            for (delay, tag) in rearm {
                ctx.charge(ctx.sim.cost.sc_load_report);
                ctx.after(delay, TimerKind::Custom(tag));
            }
        }
    }

    /// Restart transition, scheduler side: the engine wiped the volatile
    /// state (`on_crash_restart`), then the restart `Boot` lands here.
    /// Rebuild the load books from zero, reclaim whatever the durable
    /// task table still leases to this scheduler (a restart that beats
    /// the parent's timeout means nothing was ever re-issued), and
    /// announce the fresh incarnation so the parent clears the redirect
    /// and hands the workers back.
    fn rejoin(&mut self, ctx: &mut Ctx<'_>) {
        self.just_restarted = false;
        self.placer.reset_loads(&ctx.world.hier, self.idx);
        let mut mine = Vec::new();
        for e in ctx.world.tasks.iter() {
            if e.state == TaskState::Queued && e.queued_at == self.idx {
                mine.push(e.id);
            }
        }
        for t in mine {
            ctx.charge(ctx.sim.cost.sc_steal_per_task);
            self.enqueue_ready(ctx, t);
        }
        // Job timers that fired during the down window died with the old
        // incarnation: re-arm every entry job of ours still waiting. A
        // surviving original timer (fire time past the restart) makes a
        // duplicate, which the phase guard drops.
        if let Some(tr) = ctx.world.traffic.as_ref() {
            let now = ctx.now();
            let mut rearm: Vec<(Cycles, u64)> = Vec::new();
            for (i, b) in tr.jobs.iter().enumerate() {
                if b.entry != self.idx {
                    continue;
                }
                let j = JobId(i as u32);
                match b.phase {
                    JobPhase::Scheduled => {
                        let delay = b.submit_at.saturating_sub(now).max(1);
                        rearm.push((delay, traffic::arrive_tag(j)));
                    }
                    JobPhase::Deferred => rearm.push((tr.retry_backoff, traffic::retry_tag(j))),
                    JobPhase::Live | JobPhase::Done => {}
                }
            }
            for (delay, tag) in rearm {
                ctx.charge(ctx.sim.cost.sc_load_report);
                ctx.after(delay, TimerKind::Custom(tag));
            }
        }
        if let Some(p) = ctx.world.hier.parent[self.idx] {
            ctx.charge(ctx.sim.cost.sc_load_report);
            let to = self.sched_core(ctx, p);
            self.send_routed(ctx, to, Msg::Rejoin { from: self.core });
            // Unconditional report: the parent's book for this child was
            // zeroed at declaration (or is stale pre-crash). Same-link
            // FIFO lands it after the Rejoin, i.e. on a live mark.
            let load = self.placer.total() + self.ready.len() as u64;
            self.last_reported = load;
            self.send_routed(ctx, to, Msg::LoadReport { from: self.core, load });
        }
        self.pump(ctx);
    }

    /// A restarted child announced itself: clear the death mark and the
    /// mailbox redirect, hand its workers back, and refresh liveness so
    /// the next heartbeat tick does not instantly re-declare it.
    fn on_rejoin(&mut self, ctx: &mut Ctx<'_>, child_core: CoreId) {
        ctx.charge(ctx.sim.cost.sc_load_report);
        let Some(s) = ctx.world.hier.sched_idx(child_core) else { return };
        if ctx.world.hier.parent[s] != Some(self.idx) {
            return;
        }
        self.last_pong[self.placer.loads.child_slot(s)] = ctx.now();
        if self.placer.child_is_dead(s) {
            ctx.restore_mailbox(child_core);
            self.placer.mark_child_alive(s);
            ctx.world.gstats.re_adoptions += 1;
            for i in 0..ctx.world.hier.leaf_workers[s].len() {
                let w = ctx.world.hier.leaf_workers[s][i];
                ctx.charge(ctx.sim.cost.sc_dispatch);
                self.send_routed(ctx, w, Msg::Adopt { leaf: child_core });
            }
        }
    }

    // ============================================================= dispatch

    pub fn handle(&mut self, ctx: &mut Ctx<'_>, from: CoreId, msg: Msg) {
        match msg {
            Msg::SpawnReq { req, origin, parent, desc } => {
                self.on_spawn(ctx, req, origin, parent, desc)
            }
            Msg::Delegate { task, req, origin } => self.adopt_task(ctx, task, req, origin),
            Msg::DepDescend { task, arg, mode, target, cur, entered, settle } => {
                self.descend(ctx, task, arg, mode, target, cur, entered, settle)
            }
            Msg::DepSettled { req } => self.on_settled(ctx, req),
            Msg::DepGranted { task, arg } => self.on_arg_granted(ctx, task, arg),
            Msg::PopEntry { node, task, arg } => self.on_pop_entry(ctx, node, task, arg),
            Msg::QuiesceUp { child, parent, pr, pw } => {
                ctx.world.gstats.dep_boundary_msgs += 1;
                self.on_quiesce(ctx, parent, child, pr, pw)
            }
            Msg::PackReq { req, node, reply_to } => self.on_pack_req(ctx, req, node, reply_to),
            Msg::PackResp { req, ranges } => self.on_pack_resp(ctx, req, ranges),
            Msg::ScheduleDown { task, epoch } => {
                // Epoch dedup (exactly-once): a descent that surfaced from
                // a dead scheduler's drained mailbox may already have been
                // re-issued under a bumped epoch by the re-adopting parent
                // — the older incarnation loses.
                if epoch < ctx.world.tasks.get(task).epoch {
                    ctx.world.gstats.crash_dups_dropped += 1;
                } else {
                    self.enqueue_ready(ctx, task)
                }
            }
            Msg::StealReq { batch } => self.on_steal_req(ctx, from, batch),
            Msg::StealGrant { tasks } => self.on_steal_grant(ctx, tasks),
            Msg::StealDeny => {
                if self.steal_victim.take().is_none() {
                    // The victim refused, then died before the reply
                    // landed: `declare_dead` already synthesized this
                    // deny (and counted it). Counting the late duplicate
                    // would break `reqs == grants + denies`.
                    assert!(
                        ctx.world.cfg.recovery.enabled,
                        "deny without an outstanding StealReq"
                    );
                    ctx.world.gstats.crash_dups_dropped += 1;
                } else {
                    ctx.world.gstats.steal_denies += 1;
                    self.retry_after_deny(ctx);
                }
            }
            Msg::ProducerUpdate { .. } => {
                // Functional update was applied eagerly; charge bookkeeping.
                ctx.charge(ctx.sim.cost.sc_load_report);
            }
            Msg::TaskDone { task } => self.on_task_done(ctx, task),
            Msg::MemReq { req, origin, owner, op } => self.on_mem_req(ctx, req, origin, owner, op),
            Msg::WaitReq { task, origin, nodes } => self.on_wait_req(ctx, task, origin, nodes),
            Msg::RegisterWait { task, node, mode } => self.register_wait(ctx, task, node, mode),
            Msg::WaitNodeOk { task, node } => self.wait_node_ok(ctx, task, node),
            Msg::LoadReport { from, load } => self.on_load_report(ctx, from, load),
            Msg::Ping => self.on_ping(ctx, from),
            Msg::Pong => self.on_pong(ctx, from),
            Msg::Rejoin { from: child } => self.on_rejoin(ctx, child),
            other => panic!("scheduler {} got unexpected message {}", self.idx, other.tag()),
        }
    }
}

impl CoreLogic for SchedLogic {
    fn as_any(&self) -> Option<&dyn std::any::Any> {
        Some(self)
    }

    fn as_any_mut(&mut self) -> Option<&mut dyn std::any::Any> {
        Some(self)
    }

    fn on_event(&mut self, ctx: &mut Ctx<'_>, ev: Event) {
        // Fault injection: a bounded stall defers this scheduler's
        // processing (0 — and no RNG draw — when chaos is inactive).
        let stall = ctx.chaos_stall();
        if stall > 0 {
            ctx.charge(stall);
        }
        match ev {
            Event::Boot => {
                // Recovery off: inert, exactly as before (no Boot is even
                // seeded). Recovery on: the t=0 seed Boot starts the
                // heartbeat chain on probing (non-leaf) schedulers; a
                // restart Boot first runs the rejoin protocol.
                if self.just_restarted {
                    self.rejoin(ctx);
                }
                self.maybe_arm_heartbeat(ctx);
            }
            Event::Msg { from, dst, msg } => {
                if dst == self.core {
                    self.handle(ctx, from, msg);
                } else {
                    // Intermediate tree hop: forward towards the final
                    // destination. The payload moves — no envelope, no
                    // allocation.
                    //
                    // A forwarded TaskDone travels from the worker's leaf
                    // towards the responsible scheduler — normally the
                    // exact reverse of the ScheduleDown descent — so this
                    // scheduler eagerly bumped the child subtree the task
                    // went into and must decay it here, or mid-level
                    // estimates leak until the next child load report. A
                    // *migrated* task's completion may instead pass hops
                    // whose subtree never held it (it runs outside its
                    // responsible scheduler's subtree); `child_done`
                    // attributes by the worker it actually ran on and
                    // no-ops via `child_towards` everywhere else.
                    let mut was_task_done = false;
                    if let Msg::TaskDone { task } = &msg {
                        was_task_done = true;
                        if let Some(w) = ctx.world.tasks.get(*task).worker {
                            self.placer.child_done(&ctx.world.hier, self.idx, w);
                        }
                    }
                    let next = ctx.world.hier.route_next(self.idx, dst);
                    ctx.send_via(next, dst, msg);
                    if was_task_done {
                        // The forward-hop decay above may have opened
                        // headroom or idled a child (no-op with stealing
                        // disabled).
                        self.pump(ctx);
                        self.maybe_steal(ctx);
                    }
                }
            }
            Event::Timer(TimerKind::Custom(STEAL_RETRY_TIMER)) => {
                // Deny-retry backoff expired: re-evaluate the steal
                // trigger against current estimates (no-op if a request
                // is already in flight or no victim qualifies).
                self.maybe_steal(ctx);
            }
            Event::Timer(TimerKind::Custom(HEARTBEAT_TIMER)) => self.on_heartbeat(ctx),
            // Remaining custom tags: traffic job timers (kind nibble in
            // the top bits — never collides with the sub-2^32 legacy tags
            // matched above). Non-traffic runs arm no such timer.
            Event::Timer(TimerKind::Custom(tag)) => match traffic::decode_tag(tag) {
                Some(JobTimer::Arrive(j)) => self.on_job_arrival(ctx, j),
                Some(JobTimer::Retry(j)) => self.on_job_retry(ctx, j),
                None => {}
            },
            Event::DmaDone { .. } | Event::Timer(_) | Event::Wake => {}
        }
    }

    fn on_crash_restart(&mut self) {
        // The volatile scheduling plane is lost: ready queue, load books
        // (rebuilt in `rejoin` from fresh reports), the steal latch and
        // backoff, the report-threshold anchor, liveness stamps.
        // `next_req` deliberately survives (journaled — see [`Journal`]):
        // resetting it would mint ReqIds colliding with pre-crash journal
        // entries. The task table and dep/memory state are `World`-level
        // and durable by construction.
        self.generation += 1;
        self.just_restarted = true;
        self.ready = ReadyQ::new();
        self.steal_victim = None;
        self.steal_retries = 0;
        self.last_reported = 0;
        for p in &mut self.last_pong {
            *p = 0;
        }
    }
}

//! Generational slot arena: dense, index-addressed storage for hot runtime
//! state.
//!
//! The simulator's per-event path looks up dependency nodes and task
//! entries millions of times per second; backing them with hash maps puts
//! a hash + probe on every grant/re-evaluation step. A [`SlotArena`] keeps
//! entries in fixed-size chunks so a lookup is a bounds check and two
//! array indexes, freed slots are recycled through a free list (no steady-
//! state allocation), and each slot carries a *generation* so a stale
//! handle held across a free/reuse cycle is detected instead of silently
//! aliasing the new occupant.
//!
//! Storage is *address-stable*: entries live in `CHUNK`-sized boxed
//! blocks that are never moved or reallocated once created, and the
//! outer chunk table is pre-reserved to its maximum size so growth never
//! relocates it either. The threaded sharded executor relies on this —
//! a shard may read a task entry created by another shard in an earlier
//! lookahead window (the conservative barrier provides the
//! happens-before edge) while the owning shard keeps appending; with a
//! single flat `Vec` that append could reallocate the backing store out
//! from under the reader.

/// Handle into a [`SlotArena`]: slot index + the generation it was
/// allocated under. `SlotId::NONE` is the canonical "no slot" sentinel
/// (useful for dense side tables that map external ids to slots).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct SlotId {
    pub idx: u32,
    pub gen: u32,
}

impl SlotId {
    pub const NONE: SlotId = SlotId { idx: u32::MAX, gen: u32::MAX };

    #[inline]
    pub fn is_none(self) -> bool {
        self.idx == u32::MAX
    }
}

struct Slot<T> {
    gen: u32,
    val: Option<T>,
}

/// Slots per chunk. A power of two so index decomposition is a shift and
/// a mask on the hot path.
const CHUNK_BITS: usize = 12;
const CHUNK: usize = 1 << CHUNK_BITS;
/// Upper bound on chunks (16.7M slots). The outer table is reserved to
/// this up front so pushing a new chunk never reallocates it.
const MAX_CHUNKS: usize = 4096;

fn new_chunk<T>() -> Box<[Slot<T>]> {
    (0..CHUNK).map(|_| Slot { gen: 0, val: None }).collect::<Vec<_>>().into_boxed_slice()
}

/// A generational slot arena. Insertion reuses the most recently freed
/// slot (LIFO, cache-warm); while nothing is ever removed, slot indices
/// are handed out densely in insertion order (0, 1, 2, ...), which lets
/// insert-only users (the task table) treat the slot index itself as the
/// external id.
pub struct SlotArena<T> {
    chunks: Vec<Box<[Slot<T>]>>,
    /// Dense high-water mark: total slots ever allocated (live + free);
    /// also the next dense index.
    used: usize,
    free: Vec<u32>,
    live: usize,
}

impl<T> Default for SlotArena<T> {
    fn default() -> Self {
        SlotArena { chunks: Vec::with_capacity(MAX_CHUNKS), used: 0, free: Vec::new(), live: 0 }
    }
}

impl<T> SlotArena<T> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        let mut a = Self::default();
        for _ in 0..cap.div_ceil(CHUNK).min(MAX_CHUNKS) {
            a.chunks.push(new_chunk());
        }
        a
    }

    #[inline]
    fn slot(&self, idx: usize) -> Option<&Slot<T>> {
        if idx < self.used {
            Some(&self.chunks[idx >> CHUNK_BITS][idx & (CHUNK - 1)])
        } else {
            None
        }
    }

    #[inline]
    fn slot_mut(&mut self, idx: usize) -> Option<&mut Slot<T>> {
        if idx < self.used {
            Some(&mut self.chunks[idx >> CHUNK_BITS][idx & (CHUNK - 1)])
        } else {
            None
        }
    }

    /// Number of live entries.
    #[inline]
    pub fn len(&self) -> usize {
        self.live
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Total slots ever allocated (live + free). For insert-only arenas
    /// this equals `len()` and is the next dense index.
    #[inline]
    pub fn capacity_used(&self) -> usize {
        self.used
    }

    pub fn insert(&mut self, val: T) -> SlotId {
        self.live += 1;
        if let Some(idx) = self.free.pop() {
            let slot = self.slot_mut(idx as usize).expect("free-listed slot exists");
            debug_assert!(slot.val.is_none());
            slot.val = Some(val);
            SlotId { idx, gen: slot.gen }
        } else {
            if self.used == self.chunks.len() * CHUNK {
                assert!(self.chunks.len() < MAX_CHUNKS, "SlotArena chunk table exhausted");
                self.chunks.push(new_chunk());
            }
            let idx = self.used;
            self.used += 1;
            let slot = &mut self.chunks[idx >> CHUNK_BITS][idx & (CHUNK - 1)];
            slot.val = Some(val);
            SlotId { idx: idx as u32, gen: slot.gen }
        }
    }

    #[inline]
    pub fn get(&self, id: SlotId) -> Option<&T> {
        match self.slot(id.idx as usize) {
            Some(s) if s.gen == id.gen => s.val.as_ref(),
            _ => None,
        }
    }

    #[inline]
    pub fn get_mut(&mut self, id: SlotId) -> Option<&mut T> {
        match self.slot_mut(id.idx as usize) {
            Some(s) if s.gen == id.gen => s.val.as_mut(),
            _ => None,
        }
    }

    /// Index-only access for insert-only arenas where the dense index is
    /// the external id (generations are all zero in that regime).
    #[inline]
    pub fn get_dense(&self, idx: usize) -> Option<&T> {
        self.slot(idx).and_then(|s| s.val.as_ref())
    }

    #[inline]
    pub fn get_dense_mut(&mut self, idx: usize) -> Option<&mut T> {
        self.slot_mut(idx).and_then(|s| s.val.as_mut())
    }

    /// Free the slot, bumping its generation so outstanding handles go
    /// stale. Returns the value if the handle was live.
    pub fn remove(&mut self, id: SlotId) -> Option<T> {
        let slot = self.slot_mut(id.idx as usize)?;
        if slot.gen != id.gen || slot.val.is_none() {
            return None;
        }
        let val = slot.val.take();
        slot.gen = slot.gen.wrapping_add(1);
        self.free.push(id.idx);
        self.live -= 1;
        val
    }

    /// Iterate live entries in slot order.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.chunks.iter().flat_map(|c| c.iter()).take(self.used).filter_map(|s| s.val.as_ref())
    }

    /// Mutable iteration over live entries in slot order.
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut T> {
        let used = self.used;
        self.chunks
            .iter_mut()
            .flat_map(|c| c.iter_mut())
            .take(used)
            .filter_map(|s| s.val.as_mut())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_only_is_dense() {
        let mut a = SlotArena::new();
        for i in 0..100u32 {
            let id = a.insert(i);
            assert_eq!(id.idx, i);
            assert_eq!(id.gen, 0);
        }
        assert_eq!(a.len(), 100);
        assert_eq!(a.get_dense(42), Some(&42));
        assert_eq!(a.capacity_used(), 100);
    }

    #[test]
    fn remove_recycles_lifo_and_bumps_generation() {
        let mut a = SlotArena::new();
        let x = a.insert("x");
        let y = a.insert("y");
        assert_eq!(a.remove(x), Some("x"));
        assert_eq!(a.len(), 1);
        // Stale handle is rejected.
        assert_eq!(a.get(x), None);
        assert_eq!(a.remove(x), None);
        // Reuse the freed slot with a new generation.
        let z = a.insert("z");
        assert_eq!(z.idx, x.idx);
        assert_eq!(z.gen, x.gen + 1);
        assert_eq!(a.get(z), Some(&"z"));
        assert_eq!(a.get(y), Some(&"y"));
        assert_eq!(a.len(), 2);
    }

    #[test]
    fn get_mut_and_iter() {
        let mut a = SlotArena::new();
        let ids: Vec<SlotId> = (0..5).map(|i| a.insert(i)).collect();
        a.remove(ids[2]);
        *a.get_mut(ids[4]).unwrap() = 40;
        let live: Vec<i32> = a.iter().copied().collect();
        assert_eq!(live, vec![0, 1, 3, 40]);
    }

    #[test]
    fn none_sentinel() {
        assert!(SlotId::NONE.is_none());
        let mut a: SlotArena<u8> = SlotArena::new();
        assert_eq!(a.get(SlotId::NONE), None);
        let id = a.insert(1);
        assert!(!id.is_none());
    }

    #[test]
    fn growth_crosses_chunk_boundaries() {
        let mut a = SlotArena::new();
        let n = CHUNK + 7;
        let ids: Vec<SlotId> = (0..n).map(|i| a.insert(i)).collect();
        assert_eq!(a.len(), n);
        assert_eq!(a.capacity_used(), n);
        assert_eq!(ids[CHUNK].idx as usize, CHUNK);
        assert_eq!(a.get_dense(CHUNK - 1), Some(&(CHUNK - 1)));
        assert_eq!(a.get_dense(CHUNK), Some(&CHUNK));
        // Remove across the boundary and reuse LIFO.
        assert_eq!(a.remove(ids[CHUNK + 1]), Some(CHUNK + 1));
        let z = a.insert(999);
        assert_eq!(z.idx, ids[CHUNK + 1].idx);
        assert_eq!(z.gen, 1);
        assert_eq!(a.iter().count(), n);
        assert_eq!(a.capacity_used(), n);
    }

    #[test]
    fn with_capacity_preallocates_without_affecting_density() {
        let mut a: SlotArena<usize> = SlotArena::with_capacity(3 * CHUNK);
        assert_eq!(a.capacity_used(), 0);
        for i in 0..10 {
            assert_eq!(a.insert(i).idx as usize, i);
        }
        assert_eq!(a.len(), 10);
    }
}

//! Per-region slab allocator.
//!
//! "We use a new slab pool to build each local region when it is created.
//! Packing region objects in dedicated slabs helps to isolate them from
//! other regions and to enable communication on slab-based quantities ...
//! The underlying slab allocator manages the dynamic allocation and
//! freeing of memory objects of any size organized in packed groups of
//! same-sized objects. We tune the slab allocator to the size of the 64-B
//! cache lines" (paper V-C).
//!
//! Every region owns a [`SlabPool`]. Objects are rounded up to a multiple
//! of the cache line and packed into 4-KB slabs of the same size class;
//! objects larger than a slab take a run of contiguous slabs. Keeping a
//! region's objects packed is what later makes packing produce few,
//! large, coalesced ranges (paper V-E).

use std::collections::BTreeMap;

use crate::memory::addr::{GlobalPages, PagePool, CACHE_LINE, SLAB_BYTES};

/// One 4-KB slab serving a single size class.
#[derive(Clone, Debug)]
struct Slab {
    base: u64,
    /// Rounded object size this slab serves.
    class: u64,
    /// Occupancy bitmap; slot `i` covers `base + i*class`.
    used: u64,
    n_slots: u32,
}

impl Slab {
    fn new(base: u64, class: u64) -> Self {
        let n_slots = (SLAB_BYTES / class).min(64) as u32;
        Slab { base, class, used: 0, n_slots }
    }

    fn full(&self) -> bool {
        self.used.count_ones() == self.n_slots
    }

    fn empty(&self) -> bool {
        self.used == 0
    }

    fn alloc(&mut self) -> Option<u64> {
        for i in 0..self.n_slots {
            if self.used & (1 << i) == 0 {
                self.used |= 1 << i;
                return Some(self.base + i as u64 * self.class);
            }
        }
        None
    }

    fn free(&mut self, addr: u64) -> bool {
        if addr < self.base || addr >= self.base + SLAB_BYTES {
            return false;
        }
        let off = addr - self.base;
        if off % self.class != 0 {
            return false;
        }
        let i = off / self.class;
        if i >= self.n_slots as u64 || self.used & (1 << i) == 0 {
            return false;
        }
        self.used &= !(1 << i);
        true
    }
}

/// A region's allocator: slabs grouped by size class plus big multi-slab
/// allocations.
#[derive(Clone, Debug, Default)]
pub struct SlabPool {
    /// slab base -> slab, for address-based free.
    slabs: BTreeMap<u64, Slab>,
    /// size class -> bases of slabs with free slots.
    open: BTreeMap<u64, Vec<u64>>,
    /// Large allocations: base -> (bytes, slab run length).
    big: BTreeMap<u64, (u64, u64)>,
    pub allocated_bytes: u64,
    pub requested_bytes: u64,
}

/// Round a request up to the cache-line multiple (the slab size class).
pub fn size_class(size: u64) -> u64 {
    size.max(1).div_ceil(CACHE_LINE) * CACHE_LINE
}

impl SlabPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate `size` bytes. Returns the address. `pool`/`global` supply
    /// fresh slabs when needed.
    pub fn alloc(&mut self, size: u64, pool: &mut PagePool, global: &mut GlobalPages) -> u64 {
        let class = size_class(size);
        self.requested_bytes += size;
        self.allocated_bytes += class;
        if class > SLAB_BYTES {
            // Multi-slab allocation: a contiguous run from the page pool.
            let n = class.div_ceil(SLAB_BYTES);
            let base = pool.take_contiguous(n, global);
            self.big.insert(base, (class, n));
            return base;
        }
        if let Some(bases) = self.open.get_mut(&class) {
            while let Some(&b) = bases.last() {
                let slab = self.slabs.get_mut(&b).expect("open slab missing");
                if let Some(addr) = slab.alloc() {
                    if slab.full() {
                        bases.pop();
                    }
                    return addr;
                }
                bases.pop();
            }
        }
        let (base, _) = pool.take_slab(global);
        let mut slab = Slab::new(base, class);
        let addr = slab.alloc().expect("fresh slab must have a slot");
        let full = slab.full();
        self.slabs.insert(base, slab);
        if !full {
            self.open.entry(class).or_default().push(base);
        }
        addr
    }

    /// Free the allocation at `addr`. Empty slabs return to the page pool
    /// (the paper's watermark-based slab trading between regions).
    /// Returns false if the address was not live.
    pub fn free(&mut self, addr: u64, pool: &mut PagePool) -> bool {
        if let Some((class, n)) = self.big.remove(&addr) {
            self.allocated_bytes -= class;
            for i in 0..n {
                pool.give_slab(addr + i * SLAB_BYTES);
            }
            return true;
        }
        let slab_base = addr - addr % SLAB_BYTES;
        let Some(slab) = self.slabs.get_mut(&slab_base) else { return false };
        let class = slab.class;
        if !slab.free(addr) {
            return false;
        }
        self.allocated_bytes -= class;
        if slab.empty() {
            self.slabs.remove(&slab_base);
            if let Some(open) = self.open.get_mut(&class) {
                open.retain(|&b| b != slab_base);
            }
            pool.give_slab(slab_base);
        } else if let Some(open) = self.open.get_mut(&class) {
            if !open.contains(&slab_base) {
                open.push(slab_base);
            }
        } else {
            self.open.entry(class).or_default().push(slab_base);
        }
        true
    }

    /// Release every slab back to the page pool (region destruction).
    pub fn release_all(&mut self, pool: &mut PagePool) {
        for (&base, _) in std::mem::take(&mut self.slabs).iter() {
            pool.give_slab(base);
        }
        for (&base, &(_, n)) in std::mem::take(&mut self.big).iter() {
            for i in 0..n {
                pool.give_slab(base + i * SLAB_BYTES);
            }
        }
        self.open.clear();
        self.allocated_bytes = 0;
    }

    /// Bytes held in slabs vs bytes actually allocated — the external
    /// fragmentation the paper trades for locality.
    pub fn fragmentation(&self) -> f64 {
        let held =
            self.slabs.len() as u64 * SLAB_BYTES + self.big.values().map(|&(c, _)| c).sum::<u64>();
        if held == 0 {
            0.0
        } else {
            1.0 - self.allocated_bytes as f64 / held as f64
        }
    }

    pub fn n_slabs(&self) -> usize {
        self.slabs.len() + self.big.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup() -> (SlabPool, PagePool, GlobalPages) {
        (SlabPool::new(), PagePool::default(), GlobalPages::new())
    }

    #[test]
    fn size_classes_are_line_multiples() {
        assert_eq!(size_class(1), 64);
        assert_eq!(size_class(64), 64);
        assert_eq!(size_class(65), 128);
        assert_eq!(size_class(4096), 4096);
        assert_eq!(size_class(0), 64);
    }

    #[test]
    fn same_class_objects_pack_into_one_slab() {
        let (mut s, mut p, mut g) = setup();
        let addrs: Vec<u64> = (0..64).map(|_| s.alloc(64, &mut p, &mut g)).collect();
        // 64 * 64B = 4096: exactly one slab.
        assert_eq!(s.n_slabs(), 1);
        // All addresses distinct and contiguous within the slab.
        let base = addrs.iter().copied().min().unwrap();
        let mut sorted = addrs.clone();
        sorted.sort_unstable();
        for (i, a) in sorted.iter().enumerate() {
            assert_eq!(*a, base + i as u64 * 64);
        }
        // 65th allocation opens a second slab.
        s.alloc(64, &mut p, &mut g);
        assert_eq!(s.n_slabs(), 2);
    }

    #[test]
    fn free_and_reuse() {
        let (mut s, mut p, mut g) = setup();
        let a = s.alloc(100, &mut p, &mut g);
        assert!(s.free(a, &mut p));
        assert!(!s.free(a, &mut p), "double free must fail");
        let b = s.alloc(100, &mut p, &mut g);
        assert_eq!(a, b, "freed slot should be reused");
    }

    #[test]
    fn big_objects_span_slabs() {
        let (mut s, mut p, mut g) = setup();
        let a = s.alloc(10_000, &mut p, &mut g);
        assert_eq!(a % SLAB_BYTES, 0);
        assert!(s.free(a, &mut p));
        assert_eq!(s.allocated_bytes, 0);
    }

    #[test]
    fn empty_slab_returns_to_pool() {
        let (mut s, mut p, mut g) = setup();
        let a = s.alloc(64, &mut p, &mut g);
        let free_before = p.free_slab_count();
        s.free(a, &mut p);
        assert_eq!(p.free_slab_count(), free_before + 1);
        assert_eq!(s.n_slabs(), 0);
    }

    #[test]
    fn fragmentation_accounting() {
        let (mut s, mut p, mut g) = setup();
        assert_eq!(s.fragmentation(), 0.0);
        s.alloc(64, &mut p, &mut g);
        // One 64-B object holds a whole 4-KB slab: high fragmentation.
        assert!(s.fragmentation() > 0.9);
        for _ in 0..63 {
            s.alloc(64, &mut p, &mut g);
        }
        assert_eq!(s.fragmentation(), 0.0);
    }

    #[test]
    fn release_all_returns_everything() {
        let (mut s, mut p, mut g) = setup();
        for i in 0..100 {
            s.alloc(64 + (i % 5) * 64, &mut p, &mut g);
        }
        let n = s.n_slabs();
        assert!(n > 0);
        let before = p.free_slab_count();
        s.release_all(&mut p);
        assert!(p.free_slab_count() >= before + n);
        assert_eq!(s.n_slabs(), 0);
        assert_eq!(s.allocated_bytes, 0);
    }

    #[test]
    fn mixed_classes_do_not_collide() {
        let (mut s, mut p, mut g) = setup();
        let mut addrs = Vec::new();
        for i in 0..200u64 {
            let sz = 1 + (i * 37) % 300;
            addrs.push((s.alloc(sz, &mut p, &mut g), size_class(sz)));
        }
        // No two allocations overlap.
        addrs.sort_unstable();
        for w in addrs.windows(2) {
            assert!(w[0].0 + w[0].1 <= w[1].0, "overlap between {:?} and {:?}", w[0], w[1]);
        }
    }
}

//! Region-based memory management over a global address space.
pub mod addr;
pub mod region;
pub mod slab;
pub mod store;
pub mod trie;

//! Compressed binary trie over `u64` keys.
//!
//! "Schedulers use tries to track which region IDs and address ranges
//! belong to which children schedulers" (paper V-C). This is that
//! structure: a path-compressed radix tree with O(word) lookup,
//! insert and remove, plus a predecessor query used to resolve interior
//! addresses to the object that contains them.

/// A path-compressed binary trie mapping `u64` keys to values.
#[derive(Clone, Debug)]
pub struct Trie<V> {
    root: Option<Box<Node<V>>>,
    len: usize,
}

#[derive(Clone, Debug)]
enum Node<V> {
    Leaf {
        key: u64,
        val: V,
    },
    /// Inner node: all keys below share `prefix` in the bits above `bit`;
    /// `bit` is the discriminating bit index (0 = LSB).
    Inner {
        prefix: u64,
        bit: u32,
        left: Box<Node<V>>,
        right: Box<Node<V>>,
    },
}

fn mask_above(bit: u32) -> u64 {
    // Bits strictly above `bit`.
    if bit >= 63 {
        0
    } else {
        !0u64 << (bit + 1)
    }
}

impl<V> Node<V> {
    fn any_key(&self) -> u64 {
        match self {
            Node::Leaf { key, .. } => *key,
            Node::Inner { prefix, .. } => *prefix,
        }
    }
}

impl<V> Default for Trie<V> {
    fn default() -> Self {
        Trie { root: None, len: 0 }
    }
}

impl<V> Trie<V> {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn insert(&mut self, key: u64, val: V) -> Option<V> {
        match self.root.take() {
            None => {
                self.root = Some(Box::new(Node::Leaf { key, val }));
                self.len += 1;
                None
            }
            Some(node) => {
                let (node, old) = Self::insert_at(node, key, val);
                self.root = Some(node);
                if old.is_none() {
                    self.len += 1;
                }
                old
            }
        }
    }

    fn insert_at(node: Box<Node<V>>, key: u64, val: V) -> (Box<Node<V>>, Option<V>) {
        // Representative key to compare prefixes with.
        let rep = node.any_key();
        let diff = rep ^ key;
        match *node {
            Node::Leaf { key: k, val: v } => {
                if k == key {
                    return (Box::new(Node::Leaf { key, val }), Some(v));
                }
                let bit = 63 - diff.leading_zeros();
                let old_leaf = Box::new(Node::Leaf { key: k, val: v });
                let new_leaf = Box::new(Node::Leaf { key, val });
                let (left, right) =
                    if key >> bit & 1 == 0 { (new_leaf, old_leaf) } else { (old_leaf, new_leaf) };
                let prefix = key & mask_above(bit);
                (Box::new(Node::Inner { prefix, bit, left, right }), None)
            }
            Node::Inner { prefix, bit, left, right } => {
                let above = diff & mask_above(bit);
                if above != 0 {
                    // Key diverges above this node: split here.
                    let sbit = 63 - above.leading_zeros();
                    let this = Box::new(Node::Inner { prefix, bit, left, right });
                    let new_leaf = Box::new(Node::Leaf { key, val });
                    let new_prefix = key & mask_above(sbit);
                    let (l, r) =
                        if key >> sbit & 1 == 0 { (new_leaf, this) } else { (this, new_leaf) };
                    return (
                        Box::new(Node::Inner { prefix: new_prefix, bit: sbit, left: l, right: r }),
                        None,
                    );
                }
                if key >> bit & 1 == 1 {
                    let (r, old) = Self::insert_at(right, key, val);
                    (Box::new(Node::Inner { prefix, bit, left, right: r }), old)
                } else {
                    let (l, old) = Self::insert_at(left, key, val);
                    (Box::new(Node::Inner { prefix, bit, left: l, right }), old)
                }
            }
        }
    }

    pub fn get(&self, key: u64) -> Option<&V> {
        let mut cur = self.root.as_deref()?;
        loop {
            match cur {
                Node::Leaf { key: k, val } => return if *k == key { Some(val) } else { None },
                Node::Inner { bit, left, right, .. } => {
                    cur = if key >> *bit & 1 == 1 { right } else { left };
                }
            }
        }
    }

    pub fn contains(&self, key: u64) -> bool {
        self.get(key).is_some()
    }

    pub fn remove(&mut self, key: u64) -> Option<V> {
        let root = self.root.take()?;
        let (node, removed) = Self::remove_at(root, key);
        self.root = node;
        if removed.is_some() {
            self.len -= 1;
        }
        removed
    }

    fn remove_at(node: Box<Node<V>>, key: u64) -> (Option<Box<Node<V>>>, Option<V>) {
        match *node {
            Node::Leaf { key: k, val } => {
                if k == key {
                    (None, Some(val))
                } else {
                    (Some(Box::new(Node::Leaf { key: k, val })), None)
                }
            }
            Node::Inner { prefix, bit, left, right } => {
                if key >> bit & 1 == 1 {
                    let (r, removed) = Self::remove_at(right, key);
                    match r {
                        Some(r) => {
                            (Some(Box::new(Node::Inner { prefix, bit, left, right: r })), removed)
                        }
                        None => (Some(left), removed),
                    }
                } else {
                    let (l, removed) = Self::remove_at(left, key);
                    match l {
                        Some(l) => {
                            (Some(Box::new(Node::Inner { prefix, bit, left: l, right })), removed)
                        }
                        None => (Some(right), removed),
                    }
                }
            }
        }
    }

    /// Greatest key `<= x` (predecessor query), with its value.
    pub fn floor(&self, x: u64) -> Option<(u64, &V)> {
        fn max_leaf<V>(mut n: &Node<V>) -> (u64, &V) {
            loop {
                match n {
                    Node::Leaf { key, val } => return (*key, val),
                    Node::Inner { right, .. } => n = right,
                }
            }
        }
        fn go<V>(n: &Node<V>, x: u64) -> Option<(u64, &V)> {
            match n {
                Node::Leaf { key, val } => (*key <= x).then_some((*key, val)),
                Node::Inner { prefix, bit, left, right } => {
                    // If the subtree's shared prefix diverges from x above
                    // the discriminating bit, the whole subtree is either
                    // entirely below or entirely above x.
                    let m = mask_above(*bit);
                    if prefix & m != x & m {
                        return if prefix & m < x & m { Some(max_leaf(n)) } else { None };
                    }
                    if x >> *bit & 1 == 1 {
                        // Try right side first; everything in left is smaller.
                        go(right, x).or_else(|| Some(max_leaf(left)))
                    } else {
                        go(left, x)
                    }
                }
            }
        }
        let root = self.root.as_deref()?;
        go(root, x)
    }

    /// In-order iteration (ascending key order).
    pub fn iter(&self) -> Vec<(u64, &V)> {
        let mut out = Vec::with_capacity(self.len);
        fn walk<'a, V>(n: &'a Node<V>, out: &mut Vec<(u64, &'a V)>) {
            match n {
                Node::Leaf { key, val } => out.push((*key, val)),
                Node::Inner { left, right, .. } => {
                    walk(left, out);
                    walk(right, out);
                }
            }
        }
        if let Some(r) = &self.root {
            walk(r, &mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut t = Trie::new();
        for k in [5u64, 1, 9, 1 << 40, 0, 77, u64::MAX] {
            assert_eq!(t.insert(k, k.wrapping_mul(2)), None);
        }
        assert_eq!(t.len(), 7);
        for k in [5u64, 1, 9, 1 << 40, 0, 77, u64::MAX] {
            assert_eq!(t.get(k), Some(&k.wrapping_mul(2)));
        }
        assert_eq!(t.get(6), None);
        assert_eq!(t.remove(9), Some(18));
        let _ = &t;
        assert_eq!(t.get(9), None);
        assert_eq!(t.len(), 6);
        assert_eq!(t.remove(9), None);
    }

    #[test]
    fn insert_overwrites() {
        let mut t = Trie::new();
        assert_eq!(t.insert(3, "a"), None);
        assert_eq!(t.insert(3, "b"), Some("a"));
        assert_eq!(t.len(), 1);
        assert_eq!(t.get(3), Some(&"b"));
    }

    #[test]
    fn floor_queries() {
        let mut t = Trie::new();
        for k in [10u64, 20, 30, 1000] {
            t.insert(k, k);
        }
        assert_eq!(t.floor(5), None);
        assert_eq!(t.floor(10).map(|(k, _)| k), Some(10));
        assert_eq!(t.floor(15).map(|(k, _)| k), Some(10));
        assert_eq!(t.floor(29).map(|(k, _)| k), Some(20));
        assert_eq!(t.floor(999).map(|(k, _)| k), Some(30));
        assert_eq!(t.floor(u64::MAX).map(|(k, _)| k), Some(1000));
    }

    #[test]
    fn iter_is_sorted() {
        let mut t = Trie::new();
        let keys = [9u64, 2, 7, 4, 100, 55, 3];
        for k in keys {
            t.insert(k, ());
        }
        let got: Vec<u64> = t.iter().into_iter().map(|(k, _)| k).collect();
        let mut want = keys.to_vec();
        want.sort_unstable();
        assert_eq!(got, want);
    }

    #[test]
    fn dense_random_behaviour_matches_btreemap() {
        use std::collections::BTreeMap;
        let mut t = Trie::new();
        let mut m = BTreeMap::new();
        let mut rng = crate::sim::rng::Rng::new(99);
        for _ in 0..2000 {
            let k = rng.below(512);
            match rng.below(3) {
                0 => {
                    assert_eq!(t.insert(k, k), m.insert(k, k));
                }
                1 => {
                    assert_eq!(t.remove(k), m.remove(&k));
                }
                _ => {
                    assert_eq!(t.get(k), m.get(&k));
                    let q = rng.below(600);
                    let want = m.range(..=q).next_back().map(|(k, v)| (*k, v));
                    assert_eq!(t.floor(q), want);
                }
            }
            assert_eq!(t.len(), m.len());
        }
    }
}

//! Global address space: 1-MB page trading between schedulers.
//!
//! "The allocator uses a slab size of 4 KB as the basic unit inside a
//! scheduler ... and a 1-MB page size as the basic unit which schedulers
//! trade free address ranges to implement a global address space"
//! (paper V-C).
//!
//! The top-level scheduler logically owns the whole address space; child
//! schedulers request pages from their parent when their local free-slab
//! pool drains below the low watermark, and return pages above the high
//! watermark. The *functional* side lives here; the message cost of a page
//! request is charged by the memory API replay (see `api::ctx`).

pub const PAGE_BYTES: u64 = 1 << 20;
pub const SLAB_BYTES: u64 = 4096;
pub const CACHE_LINE: u64 = 64;
pub const SLABS_PER_PAGE: u64 = PAGE_BYTES / SLAB_BYTES;

/// Hands out fresh 1-MB pages from the global address space. The space
/// starts at a non-zero base so that address 0 stays an invalid pointer.
#[derive(Clone, Debug)]
pub struct GlobalPages {
    next: u64,
}

impl Default for GlobalPages {
    fn default() -> Self {
        GlobalPages { next: PAGE_BYTES }
    }
}

impl GlobalPages {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate one fresh page; returns its base address.
    pub fn take_page(&mut self) -> u64 {
        let base = self.next;
        self.next += PAGE_BYTES;
        base
    }

    /// Total address space handed out so far.
    pub fn handed_out(&self) -> u64 {
        self.next - PAGE_BYTES
    }
}

/// Per-scheduler pool of free 4-KB slabs, refilled a page at a time.
#[derive(Clone, Debug, Default)]
pub struct PagePool {
    free_slabs: Vec<u64>,
    /// Pages this scheduler has requested from its parent (statistics /
    /// fragmentation accounting).
    pub pages_held: u64,
    /// Number of times this pool had to go to the parent for a page —
    /// each one models a scheduler->parent round trip.
    pub page_requests: u64,
}

impl PagePool {
    /// Take one free slab, pulling a fresh page from the global allocator
    /// if the pool is empty. Returns (slab base, had_to_request_page).
    pub fn take_slab(&mut self, global: &mut GlobalPages) -> (u64, bool) {
        if let Some(s) = self.free_slabs.pop() {
            return (s, false);
        }
        let page = global.take_page();
        self.pages_held += 1;
        self.page_requests += 1;
        // Carve the page into slabs; keep them in descending address order
        // so allocation proceeds from the page base upwards.
        for i in (1..SLABS_PER_PAGE).rev() {
            self.free_slabs.push(page + i * SLAB_BYTES);
        }
        (page, true)
    }

    /// Return a slab to the pool (region freed or watermark trading).
    pub fn give_slab(&mut self, base: u64) {
        debug_assert_eq!(base % SLAB_BYTES, 0);
        self.free_slabs.push(base);
    }

    /// Take `n` *contiguous* slabs (multi-slab allocations). Prefers a run
    /// from the free pool; falls back to fresh pages (which are contiguous
    /// by construction). Returns the base address of the run.
    pub fn take_contiguous(&mut self, n: u64, global: &mut GlobalPages) -> u64 {
        debug_assert!(n >= 1);
        // Scan the free pool for an existing run.
        self.free_slabs.sort_unstable();
        let mut run_start = 0usize;
        for i in 0..self.free_slabs.len() {
            if i > run_start && self.free_slabs[i] != self.free_slabs[i - 1] + SLAB_BYTES {
                run_start = i;
            }
            if (i - run_start + 1) as u64 == n {
                let base = self.free_slabs[run_start];
                self.free_slabs.drain(run_start..=i);
                return base;
            }
        }
        // No run available: take fresh, consecutive pages.
        let pages = n.div_ceil(SLABS_PER_PAGE);
        let base = global.take_page();
        for p in 1..pages {
            let next = global.take_page();
            debug_assert_eq!(next, base + p * PAGE_BYTES, "global pages are sequential");
        }
        self.pages_held += pages;
        self.page_requests += 1;
        // Return the tail of the last page to the pool.
        for i in n..pages * SLABS_PER_PAGE {
            self.free_slabs.push(base + i * SLAB_BYTES);
        }
        base
    }

    pub fn free_slab_count(&self) -> usize {
        self.free_slabs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pages_are_disjoint_and_aligned() {
        let mut g = GlobalPages::new();
        let a = g.take_page();
        let b = g.take_page();
        assert_eq!(a % PAGE_BYTES, 0);
        assert_eq!(b, a + PAGE_BYTES);
        assert!(a > 0, "address 0 must stay invalid");
        assert_eq!(g.handed_out(), 2 * PAGE_BYTES);
    }

    #[test]
    fn pool_refills_from_global() {
        let mut g = GlobalPages::new();
        let mut p = PagePool::default();
        let (s0, requested) = p.take_slab(&mut g);
        assert!(requested);
        assert_eq!(p.page_requests, 1);
        assert_eq!(s0 % SLAB_BYTES, 0);
        // The rest of the page is now pooled: 255 more slabs, no request.
        for i in 1..SLABS_PER_PAGE {
            let (s, req) = p.take_slab(&mut g);
            assert!(!req, "slab {i} should come from the pool");
            assert_eq!(s % SLAB_BYTES, 0);
        }
        // Page exhausted: next take requests again.
        let (_, req) = p.take_slab(&mut g);
        assert!(req);
        assert_eq!(p.page_requests, 2);
        assert_eq!(p.pages_held, 2);
    }

    #[test]
    fn returned_slabs_are_reused() {
        let mut g = GlobalPages::new();
        let mut p = PagePool::default();
        let (s, _) = p.take_slab(&mut g);
        let before = p.free_slab_count();
        p.give_slab(s);
        assert_eq!(p.free_slab_count(), before + 1);
        let (s2, req) = p.take_slab(&mut g);
        assert!(!req);
        assert_eq!(s2, s);
    }

    #[test]
    fn slabs_within_page_ascend() {
        let mut g = GlobalPages::new();
        let mut p = PagePool::default();
        let (first, _) = p.take_slab(&mut g);
        let (second, _) = p.take_slab(&mut g);
        assert_eq!(second, first + SLAB_BYTES);
    }
}

//! Regions, objects, and the distributed ownership map.
//!
//! Myrmics regions are dynamic, growable pools of memory containing
//! objects and subregions (paper II). Metadata for each region lives on
//! exactly one scheduler (its *owner*); owners are assigned on creation
//! from the user's level hint ("vertical" placement) plus load balancing
//! ("horizontal": the child scheduler with the lowest region load wins,
//! paper V-C) and never migrate.
//!
//! The functional state is kept here in one place; ownership is respected
//! by the scheduler logic, which only touches nodes it owns and crosses
//! boundaries with explicit NoC messages (see `sched::scheduler`).

use std::collections::BTreeMap;

use crate::fxmap::FxHashMap;

use crate::ids::{CoreId, NodeId, ObjectId, RegionId};
use crate::memory::addr::{GlobalPages, PagePool};
use crate::memory::slab::{size_class, SlabPool};
use crate::memory::trie::Trie;
use crate::noc::msg::ProducerRange;
use crate::sched::hierarchy::HierarchyMap;

#[derive(Debug)]
pub struct Region {
    pub id: RegionId,
    pub parent: Option<RegionId>,
    pub children: Vec<RegionId>,
    pub objects: Vec<ObjectId>,
    /// Owning scheduler index.
    pub owner: usize,
    pub level_hint: i32,
    /// Depth in the region tree (root = 0). Cached at creation so the
    /// dependency traversal can compute next-hop/path-length queries in
    /// O(depth) without building path vectors.
    pub depth: u32,
    pub pool: SlabPool,
}

#[derive(Clone, Debug)]
pub struct Object {
    pub id: ObjectId,
    pub region: RegionId,
    pub addr: u64,
    pub size: u64,
    /// The worker core that last had write access (paper V-E: "the last
    /// worker core which had write access to a specific address range").
    pub last_producer: Option<CoreId>,
}

/// The global-address-space memory manager.
pub struct Memory {
    regions: FxHashMap<RegionId, Region>,
    objects: FxHashMap<ObjectId, Object>,
    next_rid: u64,
    next_oid: u64,
    pub global_pages: GlobalPages,
    /// Per-scheduler page pools.
    pub pools: Vec<PagePool>,
    /// Regions owned per scheduler (the load-balance criterion).
    pub region_load: Vec<u64>,
    /// Region-id routing trie (rid -> owner scheduler index).
    pub rid_owner: Trie<usize>,
    /// Address -> object map for pack/locate (base address keyed).
    addr_map: BTreeMap<u64, ObjectId>,
    /// Reusable DFS stack for the iterative subtree walks
    /// ([`Memory::set_producer`]); avoids per-call allocation.
    walk_stack: Vec<RegionId>,
}

impl Memory {
    /// Create the memory manager with the root region owned by the
    /// top-level scheduler.
    pub fn new(n_scheds: usize) -> Self {
        let mut m = Memory {
            regions: FxHashMap::default(),
            objects: FxHashMap::default(),
            next_rid: 1,
            next_oid: 1,
            global_pages: GlobalPages::new(),
            pools: (0..n_scheds).map(|_| PagePool::default()).collect(),
            region_load: vec![0; n_scheds],
            rid_owner: Trie::new(),
            addr_map: BTreeMap::new(),
            walk_stack: Vec::new(),
        };
        m.regions.insert(
            RegionId::ROOT,
            Region {
                id: RegionId::ROOT,
                parent: None,
                children: Vec::new(),
                objects: Vec::new(),
                owner: 0,
                level_hint: 0,
                depth: 0,
                pool: SlabPool::new(),
            },
        );
        m.rid_owner.insert(0, 0);
        m.region_load[0] += 1;
        m
    }

    pub fn region(&self, r: RegionId) -> &Region {
        self.regions.get(&r).unwrap_or_else(|| panic!("no region {r}"))
    }

    pub fn region_mut(&mut self, r: RegionId) -> &mut Region {
        self.regions.get_mut(&r).unwrap_or_else(|| panic!("no region {r}"))
    }

    pub fn object(&self, o: ObjectId) -> &Object {
        self.objects.get(&o).unwrap_or_else(|| panic!("no object {o}"))
    }

    pub fn object_mut(&mut self, o: ObjectId) -> &mut Object {
        self.objects.get_mut(&o).unwrap_or_else(|| panic!("no object {o}"))
    }

    pub fn exists(&self, n: NodeId) -> bool {
        match n {
            NodeId::Region(r) => self.regions.contains_key(&r),
            NodeId::Object(o) => self.objects.contains_key(&o),
        }
    }

    /// Owning scheduler index of a node.
    pub fn owner(&self, n: NodeId) -> usize {
        match n {
            NodeId::Region(r) => self.region(r).owner,
            NodeId::Object(o) => self.region(self.object(o).region).owner,
        }
    }

    /// `sys_ralloc`: create a region under `parent` with a level hint.
    /// Owner: start from the parent region's owner and descend while the
    /// hint asks for a deeper level, picking the least-loaded child.
    pub fn ralloc(&mut self, parent: RegionId, lvl: i32, hier: &HierarchyMap) -> RegionId {
        let powner = self.region(parent).owner;
        let mut owner = powner;
        while (hier.level_of[owner] as i32) < lvl && !hier.children[owner].is_empty() {
            owner = hier.children[owner]
                .iter()
                .copied()
                .min_by_key(|&c| (self.region_load[c], c))
                .unwrap();
        }
        let id = RegionId(self.next_rid);
        self.next_rid += 1;
        let depth = self.region(parent).depth + 1;
        self.regions.insert(
            id,
            Region {
                id,
                parent: Some(parent),
                children: Vec::new(),
                objects: Vec::new(),
                owner,
                level_hint: lvl,
                depth,
                pool: SlabPool::new(),
            },
        );
        self.region_mut(parent).children.push(id);
        self.region_load[owner] += 1;
        self.rid_owner.insert(id.0, owner);
        id
    }

    /// Create a region under `parent` with an explicitly pinned owner —
    /// the traffic layer's per-job root regions: a job admitted at an
    /// entry scheduler keeps its root region (and thus its dependency
    /// anchor) local to that scheduler, so admission never takes a
    /// cross-owner hop. Bypasses the level-hint descent but maintains
    /// every ownership structure `ralloc` does.
    pub fn ralloc_pinned(&mut self, parent: RegionId, owner: usize) -> RegionId {
        assert!(owner < self.pools.len(), "pinned owner out of range");
        let id = RegionId(self.next_rid);
        self.next_rid += 1;
        let depth = self.region(parent).depth + 1;
        self.regions.insert(
            id,
            Region {
                id,
                parent: Some(parent),
                children: Vec::new(),
                objects: Vec::new(),
                owner,
                level_hint: 0,
                depth,
                pool: SlabPool::new(),
            },
        );
        self.region_mut(parent).children.push(id);
        self.region_load[owner] += 1;
        self.rid_owner.insert(id.0, owner);
        id
    }

    /// `sys_alloc`: allocate `size` bytes in region `r`.
    pub fn alloc(&mut self, size: u64, r: RegionId) -> ObjectId {
        let owner = self.region(r).owner;
        let id = ObjectId(self.next_oid);
        self.next_oid += 1;
        let region = self.regions.get_mut(&r).expect("alloc in dead region");
        let addr = region.pool.alloc(size, &mut self.pools[owner], &mut self.global_pages);
        region.objects.push(id);
        self.objects.insert(id, Object { id, region: r, addr, size, last_producer: None });
        self.addr_map.insert(addr, id);
        id
    }

    /// `sys_balloc`: bulk-allocate `n` same-sized objects (packed).
    pub fn balloc(&mut self, size: u64, r: RegionId, n: usize) -> Vec<ObjectId> {
        (0..n).map(|_| self.alloc(size, r)).collect()
    }

    /// `sys_free`.
    pub fn free(&mut self, o: ObjectId) -> bool {
        let Some(obj) = self.objects.remove(&o) else { return false };
        self.addr_map.remove(&obj.addr);
        let owner = self.region(obj.region).owner;
        let region = self.regions.get_mut(&obj.region).expect("object region missing");
        region.objects.retain(|&x| x != o);
        region.pool.free(obj.addr, &mut self.pools[owner]);
        true
    }

    /// `sys_realloc`: move/resize an object, possibly to a new region.
    pub fn realloc(&mut self, o: ObjectId, new_size: u64, new_r: RegionId) -> u64 {
        let (old_region, old_addr, producer) = {
            let obj = self.object(o);
            (obj.region, obj.addr, obj.last_producer)
        };
        let old_owner = self.region(old_region).owner;
        self.addr_map.remove(&old_addr);
        let reg = self.regions.get_mut(&old_region).expect("realloc old region");
        reg.pool.free(old_addr, &mut self.pools[old_owner]);
        reg.objects.retain(|&x| x != o);

        let new_owner = self.region(new_r).owner;
        let reg = self.regions.get_mut(&new_r).expect("realloc new region");
        let addr = reg.pool.alloc(new_size, &mut self.pools[new_owner], &mut self.global_pages);
        reg.objects.push(o);
        self.objects
            .insert(o, Object { id: o, region: new_r, addr, size: new_size, last_producer: producer });
        self.addr_map.insert(addr, o);
        addr
    }

    /// `sys_rfree`: recursively destroy a region, its objects and children.
    /// Returns every node that was destroyed (so dependency metadata can be
    /// torn down too).
    pub fn rfree(&mut self, r: RegionId) -> Vec<NodeId> {
        assert_ne!(r, RegionId::ROOT, "cannot free the root region");
        let mut destroyed = Vec::new();
        self.rfree_rec(r, &mut destroyed);
        if let Some(parent) = self.regions.get(&r).and_then(|x| x.parent) {
            let _ = parent;
        }
        destroyed
    }

    fn rfree_rec(&mut self, r: RegionId, out: &mut Vec<NodeId>) {
        let Some(mut region) = self.regions.remove(&r) else { return };
        // Unlink from parent.
        if let Some(p) = region.parent {
            if let Some(parent) = self.regions.get_mut(&p) {
                parent.children.retain(|&c| c != r);
            }
        }
        for c in region.children.clone() {
            self.rfree_rec(c, out);
        }
        for o in region.objects.clone() {
            if let Some(obj) = self.objects.remove(&o) {
                self.addr_map.remove(&obj.addr);
                out.push(NodeId::Object(o));
            }
        }
        region.pool.release_all(&mut self.pools[region.owner]);
        self.region_load[region.owner] = self.region_load[region.owner].saturating_sub(1);
        self.rid_owner.remove(r.0);
        out.push(NodeId::Region(r));
    }

    /// Region an object belongs to; a region maps to itself.
    pub fn region_of(&self, n: NodeId) -> RegionId {
        match n {
            NodeId::Region(r) => r,
            NodeId::Object(o) => self.object(o).region,
        }
    }

    /// The parent node in the region tree (an object's parent is its
    /// region; a region's parent is its parent region).
    pub fn parent_of(&self, n: NodeId) -> Option<NodeId> {
        match n {
            NodeId::Object(o) => Some(NodeId::Region(self.object(o).region)),
            NodeId::Region(r) => self.region(r).parent.map(NodeId::Region),
        }
    }

    /// Depth of a node in the region/object tree (root region = 0; an
    /// object sits one level below its region). Cached, O(1).
    #[inline]
    pub fn depth_of(&self, n: NodeId) -> u32 {
        match n {
            NodeId::Region(r) => self.region(r).depth,
            NodeId::Object(o) => self.region(self.object(o).region).depth + 1,
        }
    }

    /// The immediate child of `anchor` on the path down to `target`
    /// (`target` itself when it is a direct child). `None` when `anchor`
    /// is not a strict ancestor of `target`. O(depth), allocation-free —
    /// this is the traversal step the dependency engine takes per hop,
    /// replacing the `path_down` vector it used to build per hop.
    pub fn next_hop(&self, anchor: NodeId, target: NodeId) -> Option<NodeId> {
        if anchor == target {
            return None;
        }
        let da = self.depth_of(anchor);
        let dt = self.depth_of(target);
        if dt <= da {
            return None;
        }
        let mut cur = target;
        for _ in 0..(dt - da - 1) {
            cur = self.parent_of(cur)?;
        }
        (self.parent_of(cur) == Some(anchor)).then_some(cur)
    }

    /// Number of nodes on the inclusive chain `[anchor, ..., target]`
    /// (1 when `anchor == target`); `None` if `anchor` is not an
    /// ancestor-or-self of `target`. Depth arithmetic only — used for
    /// traversal cost accounting without materializing the path.
    pub fn path_len(&self, anchor: NodeId, target: NodeId) -> Option<usize> {
        if anchor == target {
            return Some(1);
        }
        let da = self.depth_of(anchor);
        let dt = self.depth_of(target);
        if dt <= da {
            return None;
        }
        // Verify ancestry by walking up target's chain to anchor's level.
        let mut cur = target;
        for _ in 0..(dt - da) {
            cur = self.parent_of(cur)?;
        }
        (cur == anchor).then_some((dt - da + 1) as usize)
    }

    /// Chain `[anchor, ..., target]` walking region parents up from
    /// `target`; `None` if `anchor` is not an ancestor-or-self of `target`.
    /// Allocates a path vector per call — exactly the hot-path shape PR 1
    /// removed — so it is compiled only into test builds as a reference
    /// oracle for [`Memory::next_hop`] / [`Memory::path_len`]. Production
    /// code cannot link against it, which keeps the per-hop path builder
    /// from being silently reintroduced.
    #[cfg(test)]
    pub fn path_down(&self, anchor: NodeId, target: NodeId) -> Option<Vec<NodeId>> {
        let mut chain = vec![target];
        let mut cur = target;
        while cur != anchor {
            cur = self.parent_of(cur)?;
            chain.push(cur);
        }
        chain.reverse();
        Some(chain)
    }

    /// Record `worker` as last producer of every object under `n`.
    /// Iterative preorder walk over a reusable stack — no recursion, no
    /// per-call `children`/`objects` clones.
    pub fn set_producer(&mut self, n: NodeId, worker: CoreId) {
        match n {
            NodeId::Object(o) => self.object_mut(o).last_producer = Some(worker),
            NodeId::Region(r0) => {
                let mut stack = std::mem::take(&mut self.walk_stack);
                stack.clear();
                stack.push(r0);
                while let Some(r) = stack.pop() {
                    let reg = self.regions.get(&r).expect("set_producer on dead region");
                    for &o in &reg.objects {
                        // Disjoint field borrows: `reg` holds `self.regions`,
                        // the objects live in `self.objects`.
                        self.objects
                            .get_mut(&o)
                            .unwrap_or_else(|| panic!("no object {o}"))
                            .last_producer = Some(worker);
                    }
                    for &k in reg.children.iter().rev() {
                        stack.push(k);
                    }
                }
                self.walk_stack = stack;
            }
        }
    }

    /// Pack the portion of `n`'s subtree owned by `n`'s owner: returns the
    /// coalesced local ranges plus the roots of subregions owned by other
    /// schedulers (each continues as a remote PackReq).
    ///
    /// Allocating convenience wrapper around [`Memory::collect_pack_into`]
    /// (tests and cold paths).
    pub fn collect_pack(&self, n: NodeId) -> (Vec<ProducerRange>, Vec<RegionId>) {
        let mut scratch = PackScratch::default();
        let mut out = Vec::new();
        let mut remote = Vec::new();
        self.collect_pack_into(n, &mut scratch, &mut out, &mut remote);
        (out, remote)
    }

    /// Scratch-buffer variant of [`Memory::collect_pack`]: appends the
    /// coalesced local ranges to `out` and the remote subregion roots to
    /// `remote` (neither is cleared — callers accumulate across several
    /// arguments). `scratch` is reused between calls so the steady state
    /// performs no allocation.
    pub fn collect_pack_into(
        &self,
        n: NodeId,
        scratch: &mut PackScratch,
        out: &mut Vec<ProducerRange>,
        remote: &mut Vec<RegionId>,
    ) {
        scratch.raw.clear();
        match n {
            NodeId::Object(o) => {
                let obj = self.object(o);
                scratch.raw.push((obj.addr, size_class(obj.size), obj.last_producer));
            }
            NodeId::Region(r0) => {
                let owner = self.region(r0).owner;
                scratch.stack.clear();
                scratch.stack.push(r0);
                // Explicit preorder DFS; the owner check happens when a
                // region is *visited*, so remote subregions are recorded in
                // the same encounter order as the recursive version (which
                // keeps the remote-PackReq message schedule identical).
                while let Some(r) = scratch.stack.pop() {
                    let reg = self.region(r);
                    if reg.owner != owner {
                        remote.push(r);
                        continue;
                    }
                    for &o in &reg.objects {
                        let obj = self.object(o);
                        scratch.raw.push((obj.addr, size_class(obj.size), obj.last_producer));
                    }
                    for &c in reg.children.iter().rev() {
                        scratch.stack.push(c);
                    }
                }
            }
        }
        coalesce_into(&mut scratch.raw, out);
    }

    /// Number of live regions (including the root).
    pub fn n_regions(&self) -> usize {
        self.regions.len()
    }

    pub fn n_objects(&self) -> usize {
        self.objects.len()
    }

    /// Object whose allocation contains `addr`, if any.
    pub fn object_at(&self, addr: u64) -> Option<ObjectId> {
        let (_, &oid) = self.addr_map.range(..=addr).next_back()?;
        let obj = self.object(oid);
        (addr < obj.addr + size_class(obj.size)).then_some(oid)
    }

    /// Total bytes of a node's subtree (object sizes, class-rounded).
    pub fn footprint(&self, n: NodeId) -> u64 {
        match n {
            NodeId::Object(o) => size_class(self.object(o).size),
            NodeId::Region(r) => {
                let reg = self.region(r);
                reg.objects.iter().map(|&o| size_class(self.object(o).size)).sum::<u64>()
                    + reg.children.iter().map(|&c| self.footprint(NodeId::Region(c))).sum::<u64>()
            }
        }
    }
}

/// Reusable buffers for [`Memory::collect_pack_into`]: the raw
/// (addr, bytes, producer) triples gathered from a subtree and the DFS
/// stack that walks it. One per scheduler core keeps the pack path
/// allocation-free after warm-up.
#[derive(Default)]
pub struct PackScratch {
    raw: Vec<(u64, u64, Option<CoreId>)>,
    stack: Vec<RegionId>,
}

/// Merge adjacent ranges with the same producer (sorted by address),
/// appending to `out`. The append-only contract lets a caller accumulate
/// several arguments' packs into one list without intermediate vectors;
/// coalescing never merges across calls (each call starts a fresh run).
fn coalesce_into(raw: &mut [(u64, u64, Option<CoreId>)], out: &mut Vec<ProducerRange>) {
    raw.sort_unstable_by_key(|&(a, _, _)| a);
    let start = out.len();
    for &(addr, bytes, prod) in raw.iter() {
        let Some(p) = prod else { continue }; // never-produced: no transfer source
        if out.len() > start {
            let last = out.last_mut().expect("non-empty run");
            if last.producer == p && last.addr + last.bytes == addr {
                last.bytes += bytes;
                continue;
            }
        }
        out.push(ProducerRange { producer: p, addr, bytes });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HierarchySpec;

    fn hier2() -> HierarchyMap {
        HierarchyMap::build(32, &HierarchySpec::two_level(2))
    }

    #[test]
    fn ralloc_assigns_owner_by_level_and_load() {
        let h = hier2();
        let mut m = Memory::new(h.n_scheds);
        // Level 0: stays at the top scheduler.
        let top_r = m.ralloc(RegionId::ROOT, 0, &h);
        assert_eq!(m.region(top_r).owner, 0);
        // Level 1: descends to the least-loaded leaf (index 1 first).
        let r1 = m.ralloc(RegionId::ROOT, 1, &h);
        assert_eq!(m.region(r1).owner, 1);
        // Next level-1 region balances to the other leaf.
        let r2 = m.ralloc(RegionId::ROOT, 1, &h);
        assert_eq!(m.region(r2).owner, 2);
        // Routing trie agrees.
        assert_eq!(m.rid_owner.get(r1.0), Some(&1));
        assert_eq!(m.rid_owner.get(r2.0), Some(&2));
    }

    #[test]
    fn ralloc_pinned_bypasses_the_descent() {
        let h = hier2();
        let mut m = Memory::new(h.n_scheds);
        // Pin to scheduler 2 even though the load-balanced descent would
        // pick scheduler 1 first.
        let r = m.ralloc_pinned(RegionId::ROOT, 2);
        assert_eq!(m.region(r).owner, 2);
        assert_eq!(m.rid_owner.get(r.0), Some(&2));
        assert!(m.region(RegionId::ROOT).children.contains(&r));
        assert_eq!(m.depth_of(NodeId::Region(r)), 1);
        // Ownership load is booked exactly like ralloc, so rfree's
        // decrement stays balanced.
        let load = m.region_load[2];
        m.rfree(r);
        assert_eq!(m.region_load[2], load - 1);
    }

    #[test]
    fn objects_live_in_their_region() {
        let h = hier2();
        let mut m = Memory::new(h.n_scheds);
        let r = m.ralloc(RegionId::ROOT, 1, &h);
        let o = m.alloc(256, r);
        assert_eq!(m.object(o).region, r);
        assert_eq!(m.owner(NodeId::Object(o)), m.region(r).owner);
        assert_eq!(m.object_at(m.object(o).addr), Some(o));
        assert_eq!(m.object_at(m.object(o).addr + 100), Some(o));
    }

    #[test]
    fn balloc_packs_contiguously() {
        let h = hier2();
        let mut m = Memory::new(h.n_scheds);
        let r = m.ralloc(RegionId::ROOT, 1, &h);
        let objs = m.balloc(64, r, 32);
        let addrs: Vec<u64> = objs.iter().map(|&o| m.object(o).addr).collect();
        for w in addrs.windows(2) {
            assert_eq!(w[1], w[0] + 64, "bulk objects should pack into the slab");
        }
    }

    #[test]
    fn path_down_and_parents() {
        let h = hier2();
        let mut m = Memory::new(h.n_scheds);
        let a = m.ralloc(RegionId::ROOT, 0, &h);
        let b = m.ralloc(a, 1, &h);
        let o = m.alloc(64, b);
        let path = m
            .path_down(NodeId::Region(a), NodeId::Object(o))
            .expect("a is an ancestor of o");
        assert_eq!(path, vec![NodeId::Region(a), NodeId::Region(b), NodeId::Object(o)]);
        // Non-ancestor anchor.
        let c = m.ralloc(RegionId::ROOT, 0, &h);
        assert!(m.path_down(NodeId::Region(c), NodeId::Object(o)).is_none());
    }

    #[test]
    fn next_hop_mirrors_path_down() {
        let h = hier2();
        let mut m = Memory::new(h.n_scheds);
        let a = m.ralloc(RegionId::ROOT, 0, &h);
        let b = m.ralloc(a, 1, &h);
        let o = m.alloc(64, b);
        // Depths are cached on creation.
        assert_eq!(m.depth_of(NodeId::Region(RegionId::ROOT)), 0);
        assert_eq!(m.depth_of(NodeId::Region(a)), 1);
        assert_eq!(m.depth_of(NodeId::Region(b)), 2);
        assert_eq!(m.depth_of(NodeId::Object(o)), 3);
        // Hop-by-hop agrees with the full path.
        let path = m.path_down(NodeId::Region(a), NodeId::Object(o)).unwrap();
        let mut walked = vec![NodeId::Region(a)];
        let mut at = NodeId::Region(a);
        while at != NodeId::Object(o) {
            at = m.next_hop(at, NodeId::Object(o)).expect("descends");
            walked.push(at);
        }
        assert_eq!(walked, path);
        assert_eq!(m.path_len(NodeId::Region(a), NodeId::Object(o)), Some(path.len()));
    }

    #[test]
    fn next_hop_edge_cases() {
        let h = hier2();
        let mut m = Memory::new(h.n_scheds);
        let a = m.ralloc(RegionId::ROOT, 0, &h);
        let b = m.ralloc(a, 1, &h);
        let o = m.alloc(64, b);
        // anchor == target: no hop to take.
        assert_eq!(m.next_hop(NodeId::Region(a), NodeId::Region(a)), None);
        assert_eq!(m.path_len(NodeId::Region(a), NodeId::Region(a)), Some(1));
        // Object leaf directly below the anchor region.
        assert_eq!(m.next_hop(NodeId::Region(b), NodeId::Object(o)), Some(NodeId::Object(o)));
        // Cross-owner boundary (a owned by top, b forced deeper): the
        // structural query is owner-agnostic.
        assert_ne!(m.region(a).owner, m.region(b).owner);
        assert_eq!(m.next_hop(NodeId::Region(a), NodeId::Object(o)), Some(NodeId::Region(b)));
        // Non-ancestor anchor: no path.
        let c = m.ralloc(RegionId::ROOT, 0, &h);
        assert_eq!(m.next_hop(NodeId::Region(c), NodeId::Object(o)), None);
        assert_eq!(m.path_len(NodeId::Region(c), NodeId::Object(o)), None);
        // Sibling at equal depth: depth guard rejects immediately.
        assert_eq!(m.next_hop(NodeId::Region(c), NodeId::Region(a)), None);
        // Anchor below target (inverted direction): rejected.
        assert_eq!(m.next_hop(NodeId::Object(o), NodeId::Region(a)), None);
    }

    #[test]
    fn rfree_destroys_subtree() {
        let h = hier2();
        let mut m = Memory::new(h.n_scheds);
        let a = m.ralloc(RegionId::ROOT, 0, &h);
        let b = m.ralloc(a, 1, &h);
        let o1 = m.alloc(64, a);
        let o2 = m.alloc(64, b);
        let destroyed = m.rfree(a);
        assert_eq!(destroyed.len(), 4); // o1, o2, b, a
        assert!(destroyed.contains(&NodeId::Object(o1)));
        assert!(destroyed.contains(&NodeId::Object(o2)));
        assert!(destroyed.contains(&NodeId::Region(b)));
        assert!(!m.exists(NodeId::Region(a)));
        assert!(!m.exists(NodeId::Object(o2)));
        assert!(!m.region(RegionId::ROOT).children.contains(&a));
    }

    #[test]
    fn pack_coalesces_by_producer() {
        let h = hier2();
        let mut m = Memory::new(h.n_scheds);
        let r = m.ralloc(RegionId::ROOT, 1, &h);
        let objs = m.balloc(64, r, 8);
        // First 4 produced by worker c10, next 4 by c11.
        for &o in &objs[..4] {
            m.object_mut(o).last_producer = Some(CoreId(10));
        }
        for &o in &objs[4..] {
            m.object_mut(o).last_producer = Some(CoreId(11));
        }
        let (ranges, remote) = m.collect_pack(NodeId::Region(r));
        assert!(remote.is_empty());
        assert_eq!(ranges.len(), 2);
        assert_eq!(ranges[0].bytes, 256);
        assert_eq!(ranges[0].producer, CoreId(10));
        assert_eq!(ranges[1].bytes, 256);
        assert_eq!(ranges[1].producer, CoreId(11));
    }

    #[test]
    fn pack_reports_remote_subregions() {
        let h = hier2();
        let mut m = Memory::new(h.n_scheds);
        // Parent owned by top (level 0); child forced to a leaf (level 1).
        let a = m.ralloc(RegionId::ROOT, 0, &h);
        let b = m.ralloc(a, 1, &h);
        assert_ne!(m.region(a).owner, m.region(b).owner);
        m.alloc(64, a);
        let (_, remote) = m.collect_pack(NodeId::Region(a));
        assert_eq!(remote, vec![b]);
    }

    #[test]
    fn set_producer_recurses() {
        let h = hier2();
        let mut m = Memory::new(h.n_scheds);
        let a = m.ralloc(RegionId::ROOT, 0, &h);
        let b = m.ralloc(a, 1, &h);
        let o1 = m.alloc(64, a);
        let o2 = m.alloc(64, b);
        m.set_producer(NodeId::Region(a), CoreId(42));
        assert_eq!(m.object(o1).last_producer, Some(CoreId(42)));
        assert_eq!(m.object(o2).last_producer, Some(CoreId(42)));
    }

    #[test]
    fn footprint_rounds_to_class() {
        let h = hier2();
        let mut m = Memory::new(h.n_scheds);
        let r = m.ralloc(RegionId::ROOT, 0, &h);
        m.alloc(100, r); // class 128
        m.alloc(64, r);
        assert_eq!(m.footprint(NodeId::Region(r)), 192);
    }

    #[test]
    fn never_produced_ranges_do_not_transfer() {
        let h = hier2();
        let mut m = Memory::new(h.n_scheds);
        let r = m.ralloc(RegionId::ROOT, 0, &h);
        m.alloc(64, r);
        let (ranges, _) = m.collect_pack(NodeId::Region(r));
        assert!(ranges.is_empty(), "unproduced data needs no DMA source");
    }

    #[test]
    fn realloc_moves_object() {
        let h = hier2();
        let mut m = Memory::new(h.n_scheds);
        let r1 = m.ralloc(RegionId::ROOT, 1, &h);
        let r2 = m.ralloc(RegionId::ROOT, 1, &h);
        let o = m.alloc(64, r1);
        m.object_mut(o).last_producer = Some(CoreId(9));
        let new_addr = m.realloc(o, 256, r2);
        let obj = m.object(o);
        assert_eq!(obj.region, r2);
        assert_eq!(obj.addr, new_addr);
        assert_eq!(obj.size, 256);
        assert_eq!(obj.last_producer, Some(CoreId(9)), "producer survives realloc");
        assert!(m.region(r2).objects.contains(&o));
        assert!(!m.region(r1).objects.contains(&o));
    }
}

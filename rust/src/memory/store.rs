//! Backing byte store for real-compute objects.
//!
//! The simulator models where data *lives* (last producers, DMA volumes)
//! separately from what data *is*. When a benchmark runs in `Real` compute
//! mode, task bodies read and write actual bytes here and the L1/L2 PJRT
//! kernels operate on them; in `Modeled` mode the store stays empty.

use std::collections::HashMap;

use crate::ids::ObjectId;

#[derive(Default, Debug)]
pub struct DataStore {
    bytes: HashMap<ObjectId, Vec<u8>>,
}

impl DataStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put(&mut self, o: ObjectId, data: Vec<u8>) {
        self.bytes.insert(o, data);
    }

    pub fn get(&self, o: ObjectId) -> Option<&[u8]> {
        self.bytes.get(&o).map(|v| v.as_slice())
    }

    pub fn get_mut(&mut self, o: ObjectId) -> Option<&mut Vec<u8>> {
        self.bytes.get_mut(&o)
    }

    pub fn remove(&mut self, o: ObjectId) {
        self.bytes.remove(&o);
    }

    pub fn put_f32(&mut self, o: ObjectId, data: &[f32]) {
        let mut v = Vec::with_capacity(data.len() * 4);
        for x in data {
            v.extend_from_slice(&x.to_le_bytes());
        }
        self.bytes.insert(o, v);
    }

    pub fn get_f32(&self, o: ObjectId) -> Option<Vec<f32>> {
        let b = self.bytes.get(&o)?;
        Some(b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    pub fn put_u32(&mut self, o: ObjectId, data: &[u32]) {
        let mut v = Vec::with_capacity(data.len() * 4);
        for x in data {
            v.extend_from_slice(&x.to_le_bytes());
        }
        self.bytes.insert(o, v);
    }

    pub fn get_u32(&self, o: ObjectId) -> Option<Vec<u32>> {
        let b = self.bytes.get(&o)?;
        Some(b.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    pub fn total_bytes(&self) -> usize {
        self.bytes.values().map(|v| v.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let mut s = DataStore::new();
        let data = vec![1.5f32, -2.25, 0.0, f32::MAX];
        s.put_f32(ObjectId(1), &data);
        assert_eq!(s.get_f32(ObjectId(1)), Some(data));
        assert_eq!(s.get_f32(ObjectId(2)), None);
    }

    #[test]
    fn u32_roundtrip() {
        let mut s = DataStore::new();
        s.put_u32(ObjectId(3), &[7, 0, u32::MAX]);
        assert_eq!(s.get_u32(ObjectId(3)), Some(vec![7, 0, u32::MAX]));
    }

    #[test]
    fn raw_bytes_and_remove() {
        let mut s = DataStore::new();
        s.put(ObjectId(1), vec![1, 2, 3]);
        assert_eq!(s.get(ObjectId(1)), Some(&[1u8, 2, 3][..]));
        assert_eq!(s.total_bytes(), 3);
        s.remove(ObjectId(1));
        assert!(s.is_empty());
    }
}

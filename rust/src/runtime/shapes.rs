//! Fixed shapes of the AOT-compiled kernels.
//!
//! AOT lowering freezes shapes at compile time (`python/compile/aot.py`
//! lowers each kernel for exactly these). Task bodies fall back to the
//! pure-rust path when their runtime shape differs.

/// Jacobi band kernel input: (rows + 2 halo, n) f32.
pub const JACOBI_IN: (usize, usize) = (10, 32);
/// Matmul tile kernel: (M, K) x (K, N) + (M, N) accumulator.
pub const MATMUL_TILE: (usize, usize, usize) = (16, 16, 16);
/// K-means assign kernel: points per task x 3 dims, K clusters.
pub const KMEANS_POINTS: usize = 256;
pub const KMEANS_K: usize = 4;
/// Bitonic merge kernel: two sorted runs of this length.
pub const BITONIC_RUN: usize = 256;

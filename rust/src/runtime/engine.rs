//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! Layer-2 (JAX) and Layer-1 (Pallas) live in `python/compile/` and run
//! once at build time (`make artifacts`), emitting HLO **text** into
//! `artifacts/`. This module is the only bridge: it compiles each artifact
//! on the PJRT CPU client and executes it from task bodies when the
//! platform runs in `Real` compute mode. Python is never on the request
//! path.
//!
//! HLO text (not a serialized `HloModuleProto`) is the interchange format:
//! jax >= 0.5 emits protos with 64-bit instruction ids that the pinned
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// A named, compiled kernel cache over the PJRT CPU client.
pub struct KernelEngine {
    client: xla::PjRtClient,
    dir: PathBuf,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl KernelEngine {
    /// Create the engine over `dir` (usually `artifacts/`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(KernelEngine { client, dir: dir.as_ref().to_path_buf(), exes: HashMap::new() })
    }

    /// Default artifacts directory: `$MYRMICS_ARTIFACTS` or `./artifacts`.
    pub fn artifacts_dir() -> PathBuf {
        std::env::var_os("MYRMICS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    /// Does the artifact for `name` exist on disk?
    pub fn available(&self, name: &str) -> bool {
        self.dir.join(format!("{name}.hlo.txt")).exists()
    }

    fn ensure(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile kernel '{name}'"))?;
            self.exes.insert(name.to_string(), exe);
        }
        Ok(self.exes.get(name).unwrap())
    }

    /// Execute kernel `name` on f32 inputs (`(data, shape)` pairs); returns
    /// every output as a flat f32 vector. The python side lowers every
    /// kernel with `return_tuple=True`, so outputs arrive as a tuple.
    pub fn run_f32(&mut self, name: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let exe = self.ensure(name)?;
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .with_context(|| format!("reshape input for '{name}' to {shape:?}"))?;
            lits.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("execute kernel '{name}'"))?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }

    /// Number of compiled (cached) kernels.
    pub fn n_compiled(&self) -> usize {
        self.exes.len()
    }
}

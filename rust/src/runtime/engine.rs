//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! Layer-2 (JAX) and Layer-1 (Pallas) live in `python/compile/` and run
//! once at build time (`make artifacts`), emitting HLO **text** into
//! `artifacts/`. This module is the only bridge: it compiles each artifact
//! on the PJRT CPU client and executes it from task bodies when the
//! platform runs in `Real` compute mode. Python is never on the request
//! path.
//!
//! HLO text (not a serialized `HloModuleProto`) is the interchange format:
//! jax >= 0.5 emits protos with 64-bit instruction ids that the pinned
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! The real bridge needs the vendored `xla`/`anyhow` crates, which only
//! exist in the full build environment — it is gated behind the custom
//! `--cfg pjrt` flag (`RUSTFLAGS="--cfg pjrt"` after adding the vendored
//! dependencies to the manifest; a cargo feature would advertise a
//! build that cannot resolve without them). The default build ships an
//! API-identical stub whose `load` fails, so `Real` compute mode is
//! simply unavailable and every simulation path (the crate's actual
//! subject) builds and tests hermetically.

use std::fmt;
use std::path::PathBuf;

/// Error from the kernel bridge (stub: always "feature disabled").
#[derive(Debug)]
pub struct KernelError(pub String);

impl fmt::Display for KernelError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "kernel engine: {}", self.0)
    }
}

impl std::error::Error for KernelError {}

pub type Result<T> = std::result::Result<T, KernelError>;

/// Default artifacts directory: `$MYRMICS_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("MYRMICS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(pjrt)]
mod real {
    use super::{artifacts_dir as shared_artifacts_dir, KernelError, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    /// A named, compiled kernel cache over the PJRT CPU client.
    pub struct KernelEngine {
        client: xla::PjRtClient,
        dir: PathBuf,
        exes: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    fn wrap<T, E: std::fmt::Display>(r: std::result::Result<T, E>, what: &str) -> Result<T> {
        r.map_err(|e| KernelError(format!("{what}: {e}")))
    }

    impl KernelEngine {
        /// Create the engine over `dir` (usually `artifacts/`).
        pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
            let client = wrap(xla::PjRtClient::cpu(), "create PJRT CPU client")?;
            Ok(KernelEngine { client, dir: dir.as_ref().to_path_buf(), exes: HashMap::new() })
        }

        pub fn artifacts_dir() -> PathBuf {
            shared_artifacts_dir()
        }

        /// Does the artifact for `name` exist on disk?
        pub fn available(&self, name: &str) -> bool {
            self.dir.join(format!("{name}.hlo.txt")).exists()
        }

        fn ensure(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.exes.contains_key(name) {
                let path = self.dir.join(format!("{name}.hlo.txt"));
                let proto = wrap(
                    xla::HloModuleProto::from_text_file(&path),
                    "parse HLO text",
                )?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = wrap(self.client.compile(&comp), "compile kernel")?;
                self.exes.insert(name.to_string(), exe);
            }
            Ok(self.exes.get(name).unwrap())
        }

        /// Execute kernel `name` on f32 inputs (`(data, shape)` pairs);
        /// returns every output as a flat f32 vector. The python side
        /// lowers every kernel with `return_tuple=True`, so outputs arrive
        /// as a tuple.
        pub fn run_f32(
            &mut self,
            name: &str,
            inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<Vec<f32>>> {
            let exe = self.ensure(name)?;
            let mut lits = Vec::with_capacity(inputs.len());
            for (data, shape) in inputs {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                let lit = wrap(xla::Literal::vec1(data).reshape(&dims), "reshape input")?;
                lits.push(lit);
            }
            let result = wrap(exe.execute::<xla::Literal>(&lits), "execute kernel")?[0][0]
                .to_literal_sync()
                .map_err(|e| KernelError(format!("sync result: {e}")))?;
            let parts = wrap(result.to_tuple(), "untuple result")?;
            let mut out = Vec::with_capacity(parts.len());
            for p in parts {
                out.push(wrap(p.to_vec::<f32>(), "read output")?);
            }
            Ok(out)
        }

        /// Number of compiled (cached) kernels.
        pub fn n_compiled(&self) -> usize {
            self.exes.len()
        }
    }
}

#[cfg(pjrt)]
pub use real::KernelEngine;

#[cfg(not(pjrt))]
mod stub {
    use super::{artifacts_dir as shared_artifacts_dir, KernelError, Result};
    use std::path::{Path, PathBuf};
    // `Path` is the `load` parameter bound; `PathBuf` the artifacts dir.

    /// API-identical stand-in for the PJRT bridge. `load` always fails, so
    /// `World::kernels` stays `None` and every task body takes its
    /// pure-rust fallback path; simulation behavior is unaffected.
    pub struct KernelEngine {}

    impl KernelEngine {
        pub fn load(_dir: impl AsRef<Path>) -> Result<Self> {
            Err(KernelError(
                "built without `--cfg pjrt` (vendored xla/anyhow not present)".into(),
            ))
        }

        pub fn artifacts_dir() -> PathBuf {
            shared_artifacts_dir()
        }

        pub fn available(&self, _name: &str) -> bool {
            false
        }

        pub fn run_f32(
            &mut self,
            _name: &str,
            _inputs: &[(&[f32], &[usize])],
        ) -> Result<Vec<Vec<f32>>> {
            Err(KernelError("built without `--cfg pjrt`".into()))
        }

        pub fn n_compiled(&self) -> usize {
            0
        }
    }
}

#[cfg(not(pjrt))]
pub use stub::KernelEngine;

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn artifacts_dir_honors_env() {
        // Default (no env): ./artifacts. (Avoid mutating the process env
        // in tests — other tests run concurrently.)
        if std::env::var_os("MYRMICS_ARTIFACTS").is_none() {
            assert_eq!(artifacts_dir(), Path::new("artifacts"));
        }
        assert_eq!(KernelEngine::artifacts_dir(), artifacts_dir());
    }

    #[cfg(not(pjrt))]
    #[test]
    fn stub_engine_declines_gracefully() {
        let err = KernelEngine::load("artifacts").err().expect("stub must not load");
        assert!(err.to_string().contains("pjrt"));
    }
}

//! PJRT bridge for AOT-compiled JAX/Pallas kernels.
pub mod engine;
pub mod shapes;

/// Smoke check used by tests/examples: can we bring up the PJRT client?
#[cfg(pjrt)]
pub fn smoke() -> engine::Result<String> {
    let client = xla::PjRtClient::cpu()
        .map_err(|e| engine::KernelError(format!("create PJRT CPU client: {e}")))?;
    Ok(client.platform_name())
}

/// Stub smoke check: the PJRT client is unavailable without `--cfg pjrt`
/// (vendored xla dependency).
#[cfg(not(pjrt))]
pub fn smoke() -> engine::Result<String> {
    Err(engine::KernelError("built without `--cfg pjrt`".into()))
}

//! PJRT bridge for AOT-compiled JAX/Pallas kernels.
pub mod engine;
pub mod shapes;

/// Smoke check used by tests/examples: can we bring up the PJRT client?
pub fn smoke() -> anyhow::Result<String> {
    let client = xla::PjRtClient::cpu()?;
    Ok(client.platform_name())
}

//! Platform, hierarchy, policy and cost-model configuration.
//!
//! The cost model mirrors the published latencies of the 520-core Formic
//! prototype (paper III and [17, 18]):
//!
//! * a full DMA operation can be started in 24 CPU clock cycles,
//! * a core-to-core round-trip message costs 38 (nearest) to 131 (farthest)
//!   clock cycles,
//! * messages are processed back-to-back in 450-750 cycles,
//! * ARM Cortex-A9 runtime cores are 7-8x faster than the MicroBlaze
//!   worker cores (Fig 7a),
//!
//! plus per-runtime-operation costs calibrated so the Fig 7a intrinsic
//! overhead microbenchmark reproduces the paper's headline numbers:
//! ~16.2 K cycles to spawn and ~13.3 K cycles to execute an empty task on
//! the heterogeneous configuration, and ~37.4 K cycles to spawn on the
//! MicroBlaze-only configuration (see `experiments::fig7` and the
//! calibration test in `apps::synthetic`).

use crate::ids::Cycles;
use crate::sim::chaos::FaultPlan;

/// Which flavour of CPU a simulated core models. Affects only the charge
/// rate: all costs in [`CostModel`] are expressed in MicroBlaze cycles and
/// divided by `arm_speedup` when charged on a Cortex-A9.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum CoreKind {
    /// Slow, in-order, throughput-optimized core (runs application tasks).
    MicroBlaze,
    /// Fast, out-of-order, latency-optimized core (runs the runtime).
    CortexA9,
}

/// Which placement policy drives the hierarchical scheduling descent
/// (paper V-E). Dispatched as an enum in `sched::policy` so the placement
/// path stays allocation-free and branch-predictable.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PolicyKind {
    /// The paper's policy: blend a locality score `L` with a load-balance
    /// score `B` as `T = p*L + (100-p)*B` (VI-D).
    LocalityBalance,
    /// Ignore scores entirely; rotate through candidates in index order.
    RoundRobin,
    /// Randomized power-of-two-choices: sample two distinct candidates
    /// with the run's deterministic RNG and take the lighter-loaded one.
    PowerOfTwoChoices,
}

/// How a scheduler picks the sibling subtree to steal queued-ready tasks
/// from when one of its children idles (see `sched::policy::VictimPolicy`).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum VictimKind {
    /// Deterministic: the most loaded eligible child (ties to the lowest
    /// index). The default — draws no random numbers.
    MaxLoad,
    /// Uniform among eligible children, drawn from the per-scheduler RNG
    /// derived from the run seed (never host entropy).
    Random,
}

/// Idle-driven work-stealing configuration. **Off by default**: with
/// `enabled == false` every ready task is dispatched in the same handler
/// that queued it, no steal message ever exists, and the event schedule is
/// byte-identical to the pre-stealing scheduler (the determinism
/// fingerprints pin this). With it on, runs are still bit-deterministic
/// from [`PlatformConfig::seed`] (`tests/steal_determinism.rs`).
#[derive(Clone, Copy, Debug)]
pub struct StealCfg {
    pub enabled: bool,
    /// A child subtree is steal-eligible when its load estimate is at
    /// least this (and some sibling sits at exactly 0).
    pub threshold: u64,
    /// Maximum queued-ready tasks migrated per `StealGrant`.
    pub batch: u32,
    pub victim: VictimKind,
    /// Deny-retry backoff base, cycles. **0 (the default) disables
    /// retry** and keeps the protocol byte-identical to the pre-retry
    /// scheduler: a denied thief goes quiet until the next natural
    /// trigger. When > 0, a denied thief re-arms its steal trigger after
    /// `retry_backoff << min(attempt - 1, 10)` cycles (capped exponential
    /// backoff), so an idle subtree can't stall behind one unlucky deny.
    pub retry_backoff: u64,
    /// Maximum consecutive denied retries before going quiet.
    pub retry_max: u32,
}

impl StealCfg {
    /// Stealing enabled with the default threshold/batch/victim policy.
    pub fn on() -> Self {
        StealCfg { enabled: true, ..Self::default() }
    }

    /// Stealing enabled with the seeded randomized victim policy.
    pub fn random_victim() -> Self {
        StealCfg { enabled: true, victim: VictimKind::Random, ..Self::default() }
    }

    /// Deny-retry configured (builder-style); `backoff == 0` keeps the
    /// retry path disabled.
    pub fn with_retry(mut self, backoff: u64, max: u32) -> Self {
        self.retry_backoff = backoff;
        self.retry_max = max;
        self
    }
}

impl Default for StealCfg {
    fn default() -> Self {
        StealCfg {
            enabled: false,
            threshold: 4,
            batch: 2,
            victim: VictimKind::MaxLoad,
            retry_backoff: 0,
            retry_max: 3,
        }
    }
}

/// How an entry scheduler decides whether to admit an arriving traffic
/// job (see `sim::traffic` and `sched::policy::Placer::admit_job`).
/// Decisions are taken *per top-level subtree* with local state only —
/// admission is decentralized, never funneled through the hierarchy root.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum AdmissionKind {
    /// Every arrival is admitted immediately.
    AdmitAll,
    /// A tenant may have at most `TrafficCfg::tenant_cap` live jobs;
    /// arrivals beyond the cap are deferred with backoff.
    TenantCap,
    /// Load-threshold backpressure: defer while the entry scheduler's
    /// aggregate load estimate is at or above
    /// `TrafficCfg::load_threshold`.
    LoadThreshold,
}

impl AdmissionKind {
    /// Stable policy name used in sweep reports and JSON output.
    pub fn name(&self) -> &'static str {
        match self {
            AdmissionKind::AdmitAll => "admit-all",
            AdmissionKind::TenantCap => "tenant-cap",
            AdmissionKind::LoadThreshold => "load-threshold",
        }
    }
}

/// Multi-tenant traffic configuration (`sim::traffic`). **Off by
/// default**: with `enabled == false` no `TrafficState` is installed, no
/// arrival timer is ever pushed, the scheduler's quiescence gate is
/// unchanged, and every single-job fingerprint stays byte-identical to
/// the pre-traffic engine (the config tests below pin that no
/// constructor flips it). With it on, the whole arrival schedule is
/// drawn from [`PlatformConfig::seed`] at build time, so runs stay
/// bit-deterministic and shard-count invariant.
#[derive(Clone, Debug)]
pub struct TrafficCfg {
    pub enabled: bool,
    /// Total jobs in the open-loop arrival schedule.
    pub jobs: u32,
    /// Tenant count; per-job tenants are drawn weighted by
    /// `tenant_weights` (uniform when the table is empty).
    pub tenants: u32,
    /// Per-tenant draw weights (the "tenant table"). Empty = uniform;
    /// otherwise must have exactly `tenants` entries.
    pub tenant_weights: Vec<u64>,
    /// Mean inter-arrival gap, cycles (uniform jitter in
    /// `[mean/2, 3*mean/2]`).
    pub mean_gap: Cycles,
    pub admission: AdmissionKind,
    /// `TenantCap`: max live jobs per tenant (>= 1 enforced at the seam).
    pub tenant_cap: u32,
    /// `LoadThreshold`: defer while the entry subtree's load estimate is
    /// at or above this.
    pub load_threshold: u64,
    /// Deferred-retry backoff base, cycles (capped exponential).
    pub retry_backoff: Cycles,
}

impl TrafficCfg {
    /// Traffic disabled; runs are byte-identical to the pre-traffic
    /// engine.
    pub fn off() -> Self {
        TrafficCfg {
            enabled: false,
            jobs: 0,
            tenants: 1,
            tenant_weights: Vec::new(),
            mean_gap: 0,
            admission: AdmissionKind::AdmitAll,
            tenant_cap: 0,
            load_threshold: 0,
            retry_backoff: 0,
        }
    }

    /// Traffic enabled with `jobs` arrivals over `tenants` tenants and
    /// the default knobs.
    pub fn on(jobs: u32, tenants: u32) -> Self {
        TrafficCfg {
            enabled: true,
            jobs: jobs.max(1),
            tenants: tenants.max(1),
            tenant_weights: Vec::new(),
            mean_gap: 2_000_000,
            admission: AdmissionKind::AdmitAll,
            tenant_cap: 2,
            load_threshold: 24,
            retry_backoff: 500_000,
        }
    }

    /// Admission policy configured (builder-style).
    pub fn with_admission(mut self, kind: AdmissionKind) -> Self {
        self.admission = kind;
        self
    }
}

impl Default for TrafficCfg {
    fn default() -> Self {
        Self::off()
    }
}

/// Crash-recovery configuration (heartbeat detection + hierarchical
/// re-adoption, see `rust/docs/fuzzing.md` "Crash & recovery"). **Off by
/// default**: with `enabled == false` no heartbeat timer is ever armed, no
/// `Ping`/`Pong` message exists, crash knobs in the fault plan are ignored,
/// and the event schedule stays byte-identical to the pre-recovery engine
/// (pinned by the untouched fingerprints in `tests/determinism.rs` and
/// `tests/steal_determinism.rs`). With it on, runs are still
/// bit-deterministic from `(seed, plan)` (`tests/crash_determinism.rs`).
#[derive(Clone, Copy, Debug)]
pub struct RecoveryCfg {
    pub enabled: bool,
    /// Heartbeat period, cycles: every non-leaf scheduler pings each
    /// scheduler child this often while the run is live.
    pub heartbeat_period: Cycles,
    /// A child is declared dead when no `Pong` arrived within this many
    /// cycles. Must comfortably exceed `heartbeat_period` plus worst-case
    /// wire latency and chaos stalls, or healthy children get buried.
    pub heartbeat_timeout: Cycles,
}

impl RecoveryCfg {
    /// Recovery disabled; runs are byte-identical to the pre-recovery
    /// engine.
    pub fn off() -> Self {
        RecoveryCfg { enabled: false, heartbeat_period: 0, heartbeat_timeout: 0 }
    }

    /// Recovery enabled with the default heartbeat cadence.
    pub fn on() -> Self {
        RecoveryCfg {
            enabled: true,
            heartbeat_period: 50_000,
            heartbeat_timeout: 250_000,
        }
    }
}

impl Default for RecoveryCfg {
    fn default() -> Self {
        Self::off()
    }
}

/// Sharded-engine configuration (conservative PDES over the scheduler
/// hierarchy, see `rust/docs/sim-engine.md` "Sharded engine"). **`shards
/// == 1` by default**: the engine takes the exact legacy single-wheel
/// code path — no partition is computed, no mailbox exists, and every
/// pre-sharding determinism fingerprint stays byte-identical. With
/// `shards > 1` the run is still bit-identical to `shards == 1`: shards
/// exchange cross-shard events through mailboxes merged in the global
/// `(t, seq)` order under a lookahead window derived from the minimum
/// cross-shard NoC link latency.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardCfg {
    /// Requested shard count. Clamped at build time to the number of
    /// top-level scheduler subtrees (a shard must own at least one whole
    /// subtree; flat hierarchies always run with one shard).
    pub shards: usize,
    /// Host threads stepping the shards. `1` (the default) keeps the
    /// sequential merge loop — byte-identical to every pre-threading
    /// run. With `threads > 1` eligible workloads (see
    /// `World::par_safe`) step shards on real host threads between the
    /// conservative barriers; the engine clamps `threads` to the
    /// effective shard count, and every fingerprint stays bit-identical
    /// across thread counts.
    pub threads: usize,
    /// Override the derived conservative lookahead (cycles). `None` (the
    /// default) derives it from the cost model: the minimum one-way wire
    /// latency over all cross-shard tree links. Lowering it below the
    /// true minimum would be unsound; the engine clamps to >= 1.
    pub lookahead_override: Option<Cycles>,
}

impl ShardCfg {
    /// Single-shard: the legacy engine path, byte-identical to HEAD.
    pub fn off() -> Self {
        ShardCfg { shards: 1, threads: 1, lookahead_override: None }
    }

    /// Sharded engine with `n` shards and the derived lookahead.
    pub fn with_shards(n: usize) -> Self {
        ShardCfg { shards: n.max(1), threads: 1, lookahead_override: None }
    }

    /// Sharded engine with `n` shards stepped by `t` host threads.
    pub fn with_threads(n: usize, t: usize) -> Self {
        ShardCfg { shards: n.max(1), threads: t.clamp(1, n.max(1)), lookahead_override: None }
    }

    /// Shard/thread counts from the `MYRMICS_SHARDS` / `MYRMICS_THREADS`
    /// environment variables (CI runs the whole suite under
    /// `MYRMICS_SHARDS=4` and a second lane adds `MYRMICS_THREADS=4`);
    /// unset, empty or unparsable values mean 1 (the legacy path).
    /// Threads are clamped to the shard count — a thread can only step
    /// whole shards.
    pub fn from_env() -> Self {
        let parse = |var: &str| -> usize {
            match std::env::var(var) {
                Ok(v) => match v.trim().parse::<usize>() {
                    Ok(n) if n >= 1 => n,
                    _ => 1,
                },
                Err(_) => 1,
            }
        };
        Self::with_threads(parse("MYRMICS_SHARDS"), parse("MYRMICS_THREADS"))
    }
}

impl Default for ShardCfg {
    fn default() -> Self {
        Self::off()
    }
}

/// Placement-policy configuration: a tagged policy [`kind`](PolicyCfg::kind)
/// plus its parameters. Only [`PolicyKind::LocalityBalance`] reads
/// `p_locality`; randomized policies derive their RNG from
/// [`PlatformConfig::seed`], never from host entropy.
#[derive(Clone, Copy, Debug)]
pub struct PolicyCfg {
    pub kind: PolicyKind,
    /// Percentage weight for the locality score (0..=100). The paper finds
    /// a good trade-off at 0.1-0.3 locality weight, i.e. `p` in 10..30.
    pub p_locality: u32,
    /// Idle-driven work stealing (off by default).
    pub steal: StealCfg,
}

impl PolicyCfg {
    /// The paper policy with an explicit locality weight.
    pub fn locality_balance(p_locality: u32) -> Self {
        PolicyCfg { kind: PolicyKind::LocalityBalance, p_locality, ..Self::default() }
    }

    /// Same policy with work stealing configured (builder-style).
    pub fn with_steal(mut self, steal: StealCfg) -> Self {
        self.steal = steal;
        self
    }

    pub fn round_robin() -> Self {
        PolicyCfg { kind: PolicyKind::RoundRobin, ..Self::default() }
    }

    pub fn power_of_two() -> Self {
        PolicyCfg { kind: PolicyKind::PowerOfTwoChoices, ..Self::default() }
    }

    /// Stable policy name used in sweep reports and JSON output.
    pub fn name(&self) -> &'static str {
        match self.kind {
            PolicyKind::LocalityBalance => "locality-balance",
            PolicyKind::RoundRobin => "round-robin",
            PolicyKind::PowerOfTwoChoices => "p2c",
        }
    }
}

impl Default for PolicyCfg {
    fn default() -> Self {
        // Paper VI-D: "a good trade-off ... lies in the range of assigning
        // a 0.7-0.9 load-balance weight and a 0.3-0.1 locality weight".
        PolicyCfg {
            kind: PolicyKind::LocalityBalance,
            p_locality: 10,
            steal: StealCfg::default(),
        }
    }
}

/// Cycle costs for every modeled operation. All values are MicroBlaze
/// cycles; scheduler-side costs are divided by [`CostModel::arm_speedup`]
/// when the scheduler runs on a Cortex-A9.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Cortex-A9 over MicroBlaze speed ratio ("approximately a 7-8x
    /// difference on running time", Fig 7a discussion).
    pub arm_speedup: f64,

    // --- NoC: messages -------------------------------------------------
    /// One-way wire latency: `base + per_hop * hops` cycles. Calibrated to
    /// the 38..131-cycle round-trip range over the 3D mesh.
    pub msg_lat_base: Cycles,
    pub msg_lat_per_hop: Cycles,
    /// Cost charged on the *sender* core to push a message into the
    /// receiver's per-peer buffer (one-way hardware DMA primitive).
    pub msg_send: Cycles,
    /// Cost charged on the *receiver* to pull + dispatch a message:
    /// `min + (max-min) * hops/max_hops` — "processed back-to-back in the
    /// order of 450-750 clock cycles, depending on core distance and
    /// buffer availability".
    pub msg_proc_min: Cycles,
    pub msg_proc_max: Cycles,
    /// Fixed control-message size in bytes (64 B = one cache line).
    pub msg_bytes: u64,

    // --- NoC: DMA -------------------------------------------------------
    /// "A full DMA operation can be started in 24 CPU clock cycles."
    pub dma_start: Cycles,
    /// Payload bytes moved per cycle once a transfer is streaming.
    pub dma_bytes_per_cycle: u64,
    /// Extra latency per mesh hop for the first byte of a transfer.
    pub dma_per_hop: Cycles,

    // --- Worker-side runtime costs (charged on the worker core) ---------
    /// `sys_spawn` marshalling on the worker (argument tables, API entry).
    pub wk_spawn_call: Cycles,
    /// Other memory-API calls from a task (`sys_alloc` and friends).
    pub wk_api_call: Cycles,
    /// Handling an incoming task-dispatch message (queue the descriptor).
    pub wk_dispatch_handle: Cycles,
    /// Per-task setup before the body runs: unpack args, order the DMA
    /// group for remote ranges.
    pub wk_task_setup: Cycles,
    /// Per-task teardown after the body returns (completion message prep).
    pub wk_task_teardown: Cycles,
    /// Worker-side cost to process any other incoming message (acks, DMA
    /// completions).
    pub wk_msg_proc: Cycles,

    // --- Scheduler-side runtime costs (MB cycles; /arm_speedup on A9) ---
    /// Unmarshal a spawn request + create the task descriptor.
    pub sc_spawn_handle: Cycles,
    /// Locate one argument's dependency node (trie lookups).
    pub sc_dep_locate: Cycles,
    /// Walk one region level during path discovery / downward traversal.
    pub sc_dep_path_step: Cycles,
    /// Enqueue one argument on a dependency queue (incl. counter updates).
    pub sc_dep_enqueue: Cycles,
    /// Dequeue/pop one argument at task completion.
    pub sc_dep_dequeue: Cycles,
    /// Grant bookkeeping when an argument reaches the queue head.
    pub sc_grant: Cycles,
    /// Quiescence propagation step (child-counter decrement, parent
    /// counter check).
    pub sc_quiesce: Cycles,
    /// Packing: fixed part + per coalesced address range.
    pub sc_pack_base: Cycles,
    pub sc_pack_per_range: Cycles,
    /// Hierarchical scheduling decision: fixed part + per candidate child.
    pub sc_score_base: Cycles,
    pub sc_score_per_child: Cycles,
    /// Dispatch a ready task towards a worker.
    pub sc_dispatch: Cycles,
    /// Handle a task-completion message.
    pub sc_task_done: Cycles,
    /// Memory-management services.
    pub sc_alloc: Cycles,
    pub sc_balloc_per_obj: Cycles,
    pub sc_ralloc: Cycles,
    pub sc_free: Cycles,
    pub sc_rfree_per_node: Cycles,
    /// Handle an upstream load report.
    pub sc_load_report: Cycles,
    /// Work stealing: fixed cost to service a `StealReq` at the victim.
    pub sc_steal_handle: Cycles,
    /// Work stealing: per migrated task (unlink + descriptor re-marshal)
    /// at the victim; the thief additionally pays normal re-pack and
    /// scoring charges when it re-places the stolen task.
    pub sc_steal_per_task: Cycles,

    // --- Mini-MPI baseline costs (charged on MicroBlaze ranks) ----------
    /// Software send/receive overhead per MPI message (the paper uses "a
    /// lightweight MPI library implementation").
    pub mpi_send_overhead: Cycles,
    pub mpi_recv_overhead: Cycles,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            arm_speedup: 7.5,

            // Round trip = 2*(base + per_hop*hops): 38 cycles at 1 hop,
            // ~122 cycles at the 21-hop far corner of the 8x8x8 mesh.
            msg_lat_base: 17,
            msg_lat_per_hop: 2,
            msg_send: 400,
            msg_proc_min: 450,
            msg_proc_max: 750,
            msg_bytes: 64,

            dma_start: 24,
            dma_bytes_per_cycle: 8,
            dma_per_hop: 2,

            // Calibrated: worker-side spawn ~12.9 K cycles, so that
            // hetero spawn = wk + sched/7.5 + wire = 16.2 K and MB-only
            // spawn = wk + sched = 37.4 K (Fig 7a / Fig 12a).
            wk_spawn_call: 11_700,
            wk_api_call: 3_000,
            wk_dispatch_handle: 2_000,
            wk_task_setup: 4_000,
            wk_task_teardown: 3_500,
            wk_msg_proc: 500,

            // Scheduler-side spawn chain ~24.4 K MB cycles (see above).
            sc_spawn_handle: 9_000,
            sc_dep_locate: 3_000,
            sc_dep_path_step: 1_200,
            sc_dep_enqueue: 2_500,
            sc_dep_dequeue: 2_000,
            sc_grant: 1_500,
            sc_quiesce: 800,
            sc_pack_base: 2_500,
            sc_pack_per_range: 300,
            sc_score_base: 2_500,
            sc_score_per_child: 250,
            sc_dispatch: 1_500,
            sc_task_done: 4_000,
            sc_alloc: 2_500,
            sc_balloc_per_obj: 400,
            sc_ralloc: 3_500,
            sc_free: 1_800,
            sc_rfree_per_node: 600,
            sc_load_report: 300,
            sc_steal_handle: 1_200,
            sc_steal_per_task: 400,

            mpi_send_overhead: 500,
            mpi_recv_overhead: 450,
        }
    }
}

impl CostModel {
    /// Charge `mb_cycles` worth of MicroBlaze work on a core of `kind`.
    pub fn charge_on(&self, kind: CoreKind, mb_cycles: Cycles) -> Cycles {
        match kind {
            CoreKind::MicroBlaze => mb_cycles,
            CoreKind::CortexA9 => {
                ((mb_cycles as f64 / self.arm_speedup).round() as Cycles).max(1)
            }
        }
    }

    /// Receiver-side message processing cost for a given hop distance.
    pub fn msg_proc(&self, hops: u32, max_hops: u32) -> Cycles {
        let span = self.msg_proc_max.saturating_sub(self.msg_proc_min);
        self.msg_proc_min + span * hops as Cycles / (max_hops.max(1) as Cycles)
    }

    /// One-way wire latency for a message over `hops` mesh hops.
    pub fn msg_latency(&self, hops: u32) -> Cycles {
        self.msg_lat_base + self.msg_lat_per_hop * hops as Cycles
    }

    /// Wire time for a DMA transfer of `bytes` over `hops` mesh hops.
    pub fn dma_time(&self, bytes: u64, hops: u32) -> Cycles {
        self.dma_start
            + self.dma_per_hop * hops as Cycles
            + bytes.div_ceil(self.dma_bytes_per_cycle.max(1))
    }
}

/// Shape of the scheduler tree (paper IV-b, Fig 3a).
///
/// `scheds_per_level[0]` is always 1 (the single top-level scheduler);
/// each subsequent entry is the total number of schedulers at that level.
/// Workers hang evenly under the lowest level. A single-entry vec is the
/// "flat" single-scheduler configuration used as the paper's baseline.
#[derive(Clone, Debug)]
pub struct HierarchySpec {
    pub scheds_per_level: Vec<usize>,
}

impl HierarchySpec {
    /// Flat scheduling: one scheduler for every worker.
    pub fn flat() -> Self {
        HierarchySpec { scheds_per_level: vec![1] }
    }

    /// The paper's two-level configuration: 1 top-level scheduler plus `l`
    /// leaf schedulers ("L=2 for 32 workers, L=4 for 64 workers and L=7
    /// for 128, 256 or 512 workers", Fig 8 caption).
    pub fn two_level(l: usize) -> Self {
        assert!(l >= 1);
        HierarchySpec { scheds_per_level: vec![1, l] }
    }

    /// Paper Fig 8 leaf-scheduler count for a worker count.
    pub fn paper_leaves(workers: usize) -> usize {
        match workers {
            0..=31 => 1,
            32..=63 => 2,
            64..=127 => 4,
            _ => 7,
        }
    }

    /// Multi-level hierarchy with a fixed scheduler fanout, as in the
    /// deeper-hierarchies experiment (paper VI-E, fanout 6).
    pub fn multi_level(levels: usize, fanout: usize) -> Self {
        assert!(levels >= 1 && fanout >= 1);
        let mut v = Vec::with_capacity(levels);
        let mut n = 1;
        for _ in 0..levels {
            v.push(n);
            n *= fanout;
        }
        HierarchySpec { scheds_per_level: v }
    }

    pub fn n_levels(&self) -> usize {
        self.scheds_per_level.len()
    }

    pub fn n_schedulers(&self) -> usize {
        self.scheds_per_level.iter().sum()
    }
}

/// Everything needed to instantiate a simulated platform.
#[derive(Clone, Debug)]
pub struct PlatformConfig {
    /// Number of worker cores (MicroBlaze).
    pub n_workers: usize,
    /// Scheduler tree shape.
    pub hierarchy: HierarchySpec,
    /// If true, scheduler cores are Cortex-A9 (the paper's heterogeneous
    /// setup); if false they are MicroBlaze (paper VI-E homogeneous setup).
    pub hetero: bool,
    pub cost: CostModel,
    pub policy: PolicyCfg,
    /// Per-peer software message buffer capacity (credit-flow system).
    pub channel_capacity: usize,
    /// A worker/scheduler reports load upstream when its load changed by
    /// at least this much since the last report.
    pub load_report_threshold: u64,
    /// Deterministic seed for all randomized decisions in the run.
    pub seed: u64,
    /// Deterministic fault injection ([`crate::sim::chaos`]). Disabled by
    /// default ([`FaultPlan::none`]): runs stay byte-identical to the
    /// pre-chaos engine.
    pub chaos: FaultPlan,
    /// Crash detection + recovery protocol ([`RecoveryCfg`]). Disabled by
    /// default; crash faults in the plan only fire when this is on.
    pub recovery: RecoveryCfg,
    /// Sharded-engine configuration ([`ShardCfg`]). Defaults to the
    /// `MYRMICS_SHARDS` environment variable (1 when unset): the whole
    /// test suite can be re-run against the sharded engine without
    /// touching a single constructor call.
    pub shard: ShardCfg,
    /// Multi-tenant traffic layer ([`TrafficCfg`]). Disabled by default;
    /// single-job runs never see an arrival timer or an admission branch.
    pub traffic: TrafficCfg,
}

impl PlatformConfig {
    pub fn new(n_workers: usize, hierarchy: HierarchySpec) -> Self {
        PlatformConfig {
            n_workers,
            hierarchy,
            hetero: true,
            cost: CostModel::default(),
            policy: PolicyCfg::default(),
            channel_capacity: 8,
            load_report_threshold: 1,
            seed: 0xB5EED,
            chaos: FaultPlan::none(),
            recovery: RecoveryCfg::off(),
            shard: ShardCfg::from_env(),
            traffic: TrafficCfg::off(),
        }
    }

    /// Paper-style heterogeneous config: flat (single scheduler).
    pub fn flat(n_workers: usize) -> Self {
        Self::new(n_workers, HierarchySpec::flat())
    }

    /// Paper-style heterogeneous config: 1 top + paper leaf count.
    pub fn hierarchical(n_workers: usize) -> Self {
        let leaves = HierarchySpec::paper_leaves(n_workers);
        if leaves <= 1 {
            // With <=31 workers the paper's table degenerates to flat.
            Self::new(n_workers, HierarchySpec::flat())
        } else {
            Self::new(n_workers, HierarchySpec::two_level(leaves))
        }
    }

    pub fn n_schedulers(&self) -> usize {
        self.hierarchy.n_schedulers()
    }

    pub fn n_cores(&self) -> usize {
        self.n_workers + self.n_schedulers()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_round_trip_matches_prototype_range() {
        let c = CostModel::default();
        // Nearest core: 1 hop.
        assert_eq!(2 * c.msg_latency(1), 38);
        // Farthest corner of an 8x8x8 mesh: 21 hops; the prototype quotes
        // 131 cycles - accept the modeled value within ~15%.
        let far = 2 * c.msg_latency(21);
        assert!((110..=140).contains(&far), "far round trip {far}");
    }

    #[test]
    fn msg_proc_range() {
        let c = CostModel::default();
        assert_eq!(c.msg_proc(0, 21), 450);
        assert_eq!(c.msg_proc(21, 21), 750);
        let mid = c.msg_proc(10, 21);
        assert!((450..750).contains(&mid));
    }

    #[test]
    fn dma_cost_has_fixed_start() {
        let c = CostModel::default();
        assert_eq!(c.dma_time(0, 0), 24);
        assert!(c.dma_time(4096, 4) > c.dma_time(4096, 0));
        // 8 bytes/cycle streaming.
        assert_eq!(c.dma_time(64, 0), 24 + 8);
    }

    #[test]
    fn arm_charges_less() {
        let c = CostModel::default();
        assert_eq!(c.charge_on(CoreKind::MicroBlaze, 7500), 7500);
        assert_eq!(c.charge_on(CoreKind::CortexA9, 7500), 1000);
        // Never rounds to zero.
        assert_eq!(c.charge_on(CoreKind::CortexA9, 1), 1);
    }

    #[test]
    fn hierarchy_shapes() {
        assert_eq!(HierarchySpec::flat().n_schedulers(), 1);
        assert_eq!(HierarchySpec::two_level(7).n_schedulers(), 8);
        let h = HierarchySpec::multi_level(3, 6);
        assert_eq!(h.scheds_per_level, vec![1, 6, 36]);
        assert_eq!(h.n_levels(), 3);
    }

    #[test]
    fn paper_leaf_table() {
        assert_eq!(HierarchySpec::paper_leaves(16), 1);
        assert_eq!(HierarchySpec::paper_leaves(32), 2);
        assert_eq!(HierarchySpec::paper_leaves(64), 4);
        assert_eq!(HierarchySpec::paper_leaves(128), 7);
        assert_eq!(HierarchySpec::paper_leaves(512), 7);
    }

    #[test]
    fn policy_cfg_defaults_and_names() {
        let d = PolicyCfg::default();
        assert_eq!(d.kind, PolicyKind::LocalityBalance);
        assert_eq!(d.p_locality, 10);
        assert_eq!(d.name(), "locality-balance");
        assert_eq!(PolicyCfg::locality_balance(30).p_locality, 30);
        assert_eq!(PolicyCfg::round_robin().name(), "round-robin");
        assert_eq!(PolicyCfg::power_of_two().name(), "p2c");
        // Randomized/rotating policies keep the default blend parameter so
        // switching back to LocalityBalance is a one-field change.
        assert_eq!(PolicyCfg::round_robin().p_locality, 10);
    }

    #[test]
    fn stealing_is_off_by_default_everywhere() {
        // The off-by-default guarantee is what keeps every pre-stealing
        // determinism fingerprint byte-identical: no constructor may flip
        // it implicitly.
        assert!(!PolicyCfg::default().steal.enabled);
        assert!(!PolicyCfg::locality_balance(30).steal.enabled);
        assert!(!PolicyCfg::round_robin().steal.enabled);
        assert!(!PolicyCfg::power_of_two().steal.enabled);
        assert!(!PlatformConfig::hierarchical(64).policy.steal.enabled);
    }

    #[test]
    fn steal_cfg_constructors() {
        let on = StealCfg::on();
        assert!(on.enabled);
        assert_eq!(on.victim, VictimKind::MaxLoad);
        assert!(on.threshold >= 1);
        assert!(on.batch >= 1);
        // Deny-retry is off by default (backoff 0 = pre-retry protocol).
        assert_eq!(on.retry_backoff, 0);
        let rnd = StealCfg::random_victim();
        assert!(rnd.enabled);
        assert_eq!(rnd.victim, VictimKind::Random);
        assert_eq!(rnd.retry_backoff, 0);
        let retry = StealCfg::on().with_retry(10_000, 5);
        assert_eq!(retry.retry_backoff, 10_000);
        assert_eq!(retry.retry_max, 5);
        let p = PolicyCfg::default().with_steal(on);
        assert!(p.steal.enabled);
        assert_eq!(p.kind, PolicyKind::LocalityBalance);
    }

    #[test]
    fn fault_injection_is_off_by_default_everywhere() {
        // Same byte-identity contract as stealing: no constructor may
        // install a fault plan implicitly.
        assert!(!PlatformConfig::new(4, HierarchySpec::flat()).chaos.enabled);
        assert!(!PlatformConfig::flat(8).chaos.enabled);
        assert!(!PlatformConfig::hierarchical(64).chaos.enabled);
        assert_eq!(PlatformConfig::flat(8).chaos, FaultPlan::none());
    }

    #[test]
    fn recovery_is_off_by_default_everywhere() {
        // Same byte-identity contract as stealing and chaos: no
        // constructor may arm heartbeats implicitly.
        assert!(!RecoveryCfg::default().enabled);
        assert!(!PlatformConfig::new(4, HierarchySpec::flat()).recovery.enabled);
        assert!(!PlatformConfig::flat(8).recovery.enabled);
        assert!(!PlatformConfig::hierarchical(64).recovery.enabled);
        let on = RecoveryCfg::on();
        assert!(on.enabled);
        assert!(on.heartbeat_timeout > on.heartbeat_period);
        assert!(on.heartbeat_period > 0);
    }

    #[test]
    fn sharding_defaults_follow_the_env() {
        // Same byte-identity contract as stealing/chaos/recovery: the
        // plain default is the legacy single-shard path. The constructor
        // funnel additionally honours MYRMICS_SHARDS so CI can re-run the
        // whole suite sharded — assert against from_env() rather than a
        // literal so this test is green in both CI lanes.
        assert_eq!(ShardCfg::default(), ShardCfg::off());
        assert_eq!(ShardCfg::off().shards, 1);
        assert_eq!(ShardCfg::off().threads, 1);
        assert!(ShardCfg::off().lookahead_override.is_none());
        assert_eq!(ShardCfg::with_shards(0).shards, 1);
        assert_eq!(ShardCfg::with_shards(4).shards, 4);
        assert_eq!(ShardCfg::with_shards(4).threads, 1);
        // Threads clamp to the shard count: a thread steps whole shards.
        assert_eq!(ShardCfg::with_threads(4, 2).threads, 2);
        assert_eq!(ShardCfg::with_threads(2, 8).threads, 2);
        assert_eq!(ShardCfg::with_threads(0, 0).threads, 1);
        let want = ShardCfg::from_env();
        assert_eq!(PlatformConfig::new(4, HierarchySpec::flat()).shard, want);
        assert_eq!(PlatformConfig::flat(8).shard, want);
        assert_eq!(PlatformConfig::hierarchical(64).shard, want);
    }

    #[test]
    fn traffic_is_off_by_default_everywhere() {
        // Same byte-identity contract as stealing/chaos/recovery/shards:
        // no constructor may install an arrival schedule implicitly.
        assert!(!TrafficCfg::default().enabled);
        assert!(!PlatformConfig::new(4, HierarchySpec::flat()).traffic.enabled);
        assert!(!PlatformConfig::flat(8).traffic.enabled);
        assert!(!PlatformConfig::hierarchical(64).traffic.enabled);
        assert_eq!(TrafficCfg::off().jobs, 0);
    }

    #[test]
    fn traffic_cfg_constructors() {
        let t = TrafficCfg::on(24, 3);
        assert!(t.enabled);
        assert_eq!(t.jobs, 24);
        assert_eq!(t.tenants, 3);
        assert!(t.tenant_weights.is_empty(), "uniform tenant table by default");
        assert!(t.mean_gap > 0);
        assert!(t.retry_backoff > 0);
        assert_eq!(t.admission, AdmissionKind::AdmitAll);
        let t = t.with_admission(AdmissionKind::TenantCap);
        assert_eq!(t.admission, AdmissionKind::TenantCap);
        assert!(t.tenant_cap >= 1);
        // Degenerate requests clamp to usable values.
        let z = TrafficCfg::on(0, 0);
        assert_eq!(z.jobs, 1);
        assert_eq!(z.tenants, 1);
        // Stable report names.
        assert_eq!(AdmissionKind::AdmitAll.name(), "admit-all");
        assert_eq!(AdmissionKind::TenantCap.name(), "tenant-cap");
        assert_eq!(AdmissionKind::LoadThreshold.name(), "load-threshold");
    }

    #[test]
    fn platform_core_counts() {
        let p = PlatformConfig::hierarchical(128);
        assert_eq!(p.n_schedulers(), 8);
        assert_eq!(p.n_cores(), 136);
        let f = PlatformConfig::flat(512);
        assert_eq!(f.n_cores(), 513);
    }
}

//! Metrics and report generation.
pub mod metrics;
pub mod tenants;

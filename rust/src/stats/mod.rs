//! Metrics and report generation.
pub mod metrics;

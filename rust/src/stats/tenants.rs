//! Per-tenant traffic accounting: job-latency percentiles and Jain's
//! fairness index over the finished [`TrafficState`] books.
//!
//! Everything here is integer/deterministic except the Jain index, which
//! is a pure report-side f64 over final counters — it never feeds back
//! into the simulation, so the engine's bit-identical replay contract is
//! untouched.

use crate::ids::Cycles;
use crate::sim::traffic::{JobPhase, TrafficState};

/// The `q`-th percentile (0..=100) of `xs` by the nearest-rank method on
/// a sorted copy. Deterministic: integer rank arithmetic only. Returns 0
/// for an empty slice.
pub fn percentile(xs: &[Cycles], q: u32) -> Cycles {
    if xs.is_empty() {
        return 0;
    }
    let mut v = xs.to_vec();
    v.sort_unstable();
    // Nearest-rank: ceil(q/100 * n), 1-based; q=0 maps to the minimum.
    let n = v.len() as u64;
    let rank = (q as u64 * n).div_ceil(100).max(1);
    v[(rank - 1) as usize]
}

/// Jain's fairness index over per-tenant allocations:
/// `(sum x)^2 / (n * sum x^2)`. 1.0 = perfectly fair, 1/n = one tenant
/// monopolizes. Empty or all-zero input reports 1.0 (nothing was unfairly
/// shared).
pub fn jain(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq == 0.0 {
        return 1.0;
    }
    (sum * sum) / (xs.len() as f64 * sq)
}

/// One tenant's aggregate over a finished traffic run.
#[derive(Clone, Debug)]
pub struct TenantSummary {
    pub tenant: u32,
    pub jobs: u32,
    pub finished: u32,
    pub deferrals: u64,
    pub p50_latency: Cycles,
    pub p99_latency: Cycles,
    /// Total task-cycles of work this tenant's finished jobs carried —
    /// the "allocation" the fairness index is computed over.
    pub service_cycles: u64,
}

/// Whole-run traffic report: per-tenant summaries plus the cross-tenant
/// fairness index.
#[derive(Clone, Debug)]
pub struct TrafficReport {
    pub tenants: Vec<TenantSummary>,
    pub p50_latency: Cycles,
    pub p99_latency: Cycles,
    /// Jain index over per-tenant service cycles, weighted by submitted
    /// jobs (each tenant's service normalized by its offered load, so a
    /// heavy tenant isn't counted as "unfairly favored" for receiving
    /// the service it asked for).
    pub jain_index: f64,
    pub total_deferrals: u64,
    pub admitted: u32,
}

/// Summarize a finished run's books. Tolerates unfinished jobs (they are
/// excluded from latency/service aggregates) so the report is also usable
/// on truncated runs.
pub fn tenant_report(tr: &TrafficState) -> TrafficReport {
    let mut tenants = Vec::with_capacity(tr.tenants.len());
    let mut all_lat: Vec<Cycles> = Vec::with_capacity(tr.jobs.len());
    for (i, tb) in tr.tenants.iter().enumerate() {
        let mut lat: Vec<Cycles> = Vec::new();
        let mut service = 0u64;
        for j in &tr.jobs {
            if j.tenant as usize != i || j.phase != JobPhase::Done {
                continue;
            }
            lat.push(j.latency());
            service += j.shape.tasks as u64 * j.shape.task_cycles;
        }
        all_lat.extend_from_slice(&lat);
        tenants.push(TenantSummary {
            tenant: i as u32,
            jobs: tb.submitted,
            finished: tb.finished,
            deferrals: tb.deferrals,
            p50_latency: percentile(&lat, 50),
            p99_latency: percentile(&lat, 99),
            service_cycles: service,
        });
    }
    let shares: Vec<f64> = tenants
        .iter()
        .filter(|t| t.jobs > 0)
        .map(|t| t.service_cycles as f64 / t.jobs as f64)
        .collect();
    TrafficReport {
        p50_latency: percentile(&all_lat, 50),
        p99_latency: percentile(&all_lat, 99),
        jain_index: jain(&shares),
        total_deferrals: tr.total_deferrals,
        admitted: tr.admitted,
        tenants,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<Cycles> = (1..=100).collect();
        assert_eq!(percentile(&xs, 50), 50);
        assert_eq!(percentile(&xs, 99), 99);
        assert_eq!(percentile(&xs, 100), 100);
        assert_eq!(percentile(&xs, 0), 1);
        assert_eq!(percentile(&[7], 99), 7);
        assert_eq!(percentile(&[], 50), 0);
        // Unsorted input is handled (sorted copy).
        assert_eq!(percentile(&[30, 10, 20], 50), 20);
    }

    #[test]
    fn jain_bounds() {
        assert_eq!(jain(&[]), 1.0);
        assert_eq!(jain(&[0.0, 0.0]), 1.0);
        assert!((jain(&[5.0, 5.0, 5.0, 5.0]) - 1.0).abs() < 1e-12, "equal shares are fair");
        let mono = jain(&[10.0, 0.0, 0.0, 0.0]);
        assert!((mono - 0.25).abs() < 1e-12, "monopoly hits 1/n: {mono}");
        let mid = jain(&[4.0, 1.0]);
        assert!(mid > 0.5 && mid < 1.0);
    }

    #[test]
    fn report_aggregates_only_finished_jobs() {
        use crate::config::{HierarchySpec, TrafficCfg};
        use crate::ids::{JobId, TaskId};
        use crate::sched::hierarchy::HierarchyMap;
        use crate::sim::traffic::{JobShape, JobTemplate, TrafficState};
        let h = HierarchyMap::build(16, &HierarchySpec::two_level(4));
        let tpl = [JobTemplate {
            name: "t",
            shape: JobShape { tasks: 4, task_cycles: 1000, fanout: 2, hot_pct: 0 },
        }];
        let mut tr = TrafficState::generate(&TrafficCfg::on(3, 2), 9, &h, 0, &tpl);
        // Finish job 0 only (root task alone).
        tr.note_arrived(JobId(0));
        tr.note_admitted(JobId(0), TaskId(1), tr.jobs[0].submit_at + 10);
        assert!(tr.on_task_completed(JobId(0), tr.jobs[0].submit_at + 500));
        let rep = tenant_report(&tr);
        assert_eq!(rep.admitted, 1);
        assert_eq!(rep.p50_latency, 500);
        assert_eq!(rep.p99_latency, 500);
        let finished: u32 = rep.tenants.iter().map(|t| t.finished).sum();
        assert_eq!(finished, 1);
        let jobs: u32 = rep.tenants.iter().map(|t| t.jobs).sum();
        assert_eq!(jobs, 3, "submissions counted even when unfinished");
        assert!(rep.jain_index > 0.0 && rep.jain_index <= 1.0);
    }
}

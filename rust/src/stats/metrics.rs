//! Per-core and platform-wide counters.
//!
//! These drive the paper's qualitative figures: the time breakdown of
//! Fig 9 (task vs runtime vs idle time per core) and the traffic analysis
//! of Fig 10 (message and DMA volumes per core).

use crate::ids::Cycles;

/// What a core was doing while busy. `Idle` is never charged; it is
/// derived as `total - task - runtime` at reporting time.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BusyKind {
    /// Executing application task code.
    Task,
    /// Executing runtime code (message handling, dependency analysis,
    /// scheduling, memory management, API overhead on workers).
    Runtime,
}

/// Counters for a single simulated core.
#[derive(Clone, Default, Debug)]
pub struct CoreStats {
    pub busy_task: Cycles,
    pub busy_runtime: Cycles,
    /// Control messages sent / received (count and bytes).
    pub msgs_sent: u64,
    pub msgs_recv: u64,
    pub msg_bytes_sent: u64,
    pub msg_bytes_recv: u64,
    /// DMA payload bytes pulled into this core / pushed out of it.
    pub dma_bytes_in: u64,
    pub dma_bytes_out: u64,
    /// Number of application tasks this core executed (workers only).
    pub tasks_run: u64,
    /// Number of cycles the core spent stalled on channel credits.
    pub credit_stall: Cycles,
}

impl CoreStats {
    pub fn busy(&self) -> Cycles {
        self.busy_task + self.busy_runtime
    }

    /// Fraction of `total` spent on application tasks (0..=1).
    pub fn task_frac(&self, total: Cycles) -> f64 {
        if total == 0 {
            0.0
        } else {
            self.busy_task as f64 / total as f64
        }
    }

    /// Fraction of `total` spent on runtime work (0..=1).
    pub fn runtime_frac(&self, total: Cycles) -> f64 {
        if total == 0 {
            0.0
        } else {
            self.busy_runtime as f64 / total as f64
        }
    }

    /// Fraction of `total` spent idle (0..=1).
    pub fn idle_frac(&self, total: Cycles) -> f64 {
        (1.0 - self.task_frac(total) - self.runtime_frac(total)).max(0.0)
    }
}

/// Platform-wide counters.
#[derive(Clone, Default, Debug)]
pub struct GlobalStats {
    pub tasks_spawned: u64,
    pub tasks_completed: u64,
    pub events_processed: u64,
    pub msgs_total: u64,
    pub dma_transfers: u64,
    pub regions_created: u64,
    pub objects_created: u64,
    /// Dependency-analysis boundary crossings (inter-scheduler messages
    /// caused by region-tree traversal).
    pub dep_boundary_msgs: u64,
    // --- work-stealing protocol (all zero when stealing is disabled) ---
    /// `StealReq` messages initiated by idle-detecting schedulers.
    pub steal_reqs: u64,
    /// Requests answered with a `StealGrant` (>= 1 migrated task).
    pub steal_grants: u64,
    /// Requests refused (`StealDeny`: victim's ready queue was empty).
    pub steal_denies: u64,
    /// Queued-ready tasks migrated between sibling subtrees.
    pub tasks_stolen: u64,
    /// Deepest any scheduler's ready queue ever got. With stealing
    /// disabled the queue drains within the handler that fills it, so
    /// this never exceeds 1.
    pub ready_queue_hwm: u64,
    // --- crash & recovery (all zero when recovery is disabled) ---
    /// Scheduler crashes that actually fired (0 or 1 per run today).
    pub crashes: u64,
    /// Crashed schedulers that restarted and rejoined the tree.
    pub restarts: u64,
    /// Dead subtrees re-adopted by their parent after a missed-heartbeat
    /// detection (worker uplinks redirected, orphans re-placed).
    pub re_adoptions: u64,
    /// Orphaned tasks re-issued toward surviving siblings. Exactly-once:
    /// only tasks whose table state shows no dispatch and no recorded
    /// completion are ever re-issued.
    pub tasks_reissued: u64,
    /// Stale messages dropped by the generation/epoch dedup rule (late
    /// `ScheduleDown` with an old epoch, duplicate `TaskDone` for a task
    /// already recorded `Done`).
    pub crash_dups_dropped: u64,
    /// `StealDeny`s synthesized by a parent on re-adoption for a
    /// `StealReq` that was in flight to the crashed child (keeps
    /// `steal_reqs == steal_grants + steal_denies` and un-leaks the
    /// one-req-in-flight latch).
    pub crash_denies_synth: u64,
    /// Heartbeat `Ping` probes sent by parent schedulers.
    pub heartbeats: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_sum_to_one() {
        let s = CoreStats { busy_task: 600, busy_runtime: 150, ..Default::default() };
        let total = 1000;
        let sum = s.task_frac(total) + s.runtime_frac(total) + s.idle_frac(total);
        assert!((sum - 1.0).abs() < 1e-9);
        assert!((s.idle_frac(total) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn zero_total_is_safe() {
        let s = CoreStats::default();
        assert_eq!(s.task_frac(0), 0.0);
        assert_eq!(s.idle_frac(0), 1.0);
    }

    #[test]
    fn idle_clamps_at_zero() {
        // Overcommitted core (busy > wall) must not report negative idle.
        let s = CoreStats { busy_task: 900, busy_runtime: 400, ..Default::default() };
        assert_eq!(s.idle_frac(1000), 0.0);
    }
}

//! Per-core and platform-wide counters.
//!
//! These drive the paper's qualitative figures: the time breakdown of
//! Fig 9 (task vs runtime vs idle time per core) and the traffic analysis
//! of Fig 10 (message and DMA volumes per core).

use crate::ids::Cycles;
use std::cell::Cell;
use std::ops::{Deref, DerefMut};

/// What a core was doing while busy. `Idle` is never charged; it is
/// derived as `total - task - runtime` at reporting time.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BusyKind {
    /// Executing application task code.
    Task,
    /// Executing runtime code (message handling, dependency analysis,
    /// scheduling, memory management, API overhead on workers).
    Runtime,
}

/// Counters for a single simulated core.
#[derive(Clone, Default, Debug)]
pub struct CoreStats {
    pub busy_task: Cycles,
    pub busy_runtime: Cycles,
    /// Control messages sent / received (count and bytes).
    pub msgs_sent: u64,
    pub msgs_recv: u64,
    pub msg_bytes_sent: u64,
    pub msg_bytes_recv: u64,
    /// DMA payload bytes pulled into this core / pushed out of it.
    pub dma_bytes_in: u64,
    pub dma_bytes_out: u64,
    /// Number of application tasks this core executed (workers only).
    pub tasks_run: u64,
    /// Number of cycles the core spent stalled on channel credits.
    pub credit_stall: Cycles,
}

impl CoreStats {
    pub fn busy(&self) -> Cycles {
        self.busy_task + self.busy_runtime
    }

    /// Fraction of `total` spent on application tasks (0..=1).
    pub fn task_frac(&self, total: Cycles) -> f64 {
        if total == 0 {
            0.0
        } else {
            self.busy_task as f64 / total as f64
        }
    }

    /// Fraction of `total` spent on runtime work (0..=1).
    pub fn runtime_frac(&self, total: Cycles) -> f64 {
        if total == 0 {
            0.0
        } else {
            self.busy_runtime as f64 / total as f64
        }
    }

    /// Fraction of `total` spent idle (0..=1).
    pub fn idle_frac(&self, total: Cycles) -> f64 {
        (1.0 - self.task_frac(total) - self.runtime_frac(total)).max(0.0)
    }
}

/// Platform-wide counters.
#[derive(Clone, Default, Debug, PartialEq, Eq)]
pub struct GlobalStats {
    pub tasks_spawned: u64,
    pub tasks_completed: u64,
    pub events_processed: u64,
    pub msgs_total: u64,
    pub dma_transfers: u64,
    pub regions_created: u64,
    pub objects_created: u64,
    /// Dependency-analysis boundary crossings (inter-scheduler messages
    /// caused by region-tree traversal).
    pub dep_boundary_msgs: u64,
    // --- work-stealing protocol (all zero when stealing is disabled) ---
    /// `StealReq` messages initiated by idle-detecting schedulers.
    pub steal_reqs: u64,
    /// Requests answered with a `StealGrant` (>= 1 migrated task).
    pub steal_grants: u64,
    /// Requests refused (`StealDeny`: victim's ready queue was empty).
    pub steal_denies: u64,
    /// Queued-ready tasks migrated between sibling subtrees.
    pub tasks_stolen: u64,
    /// Deepest any scheduler's ready queue ever got. With stealing
    /// disabled the queue drains within the handler that fills it, so
    /// this never exceeds 1.
    pub ready_queue_hwm: u64,
    // --- crash & recovery (all zero when recovery is disabled) ---
    /// Scheduler crashes that actually fired (0 or 1 per run today).
    pub crashes: u64,
    /// Crashed schedulers that restarted and rejoined the tree.
    pub restarts: u64,
    /// Dead subtrees re-adopted by their parent after a missed-heartbeat
    /// detection (worker uplinks redirected, orphans re-placed).
    pub re_adoptions: u64,
    /// Orphaned tasks re-issued toward surviving siblings. Exactly-once:
    /// only tasks whose table state shows no dispatch and no recorded
    /// completion are ever re-issued.
    pub tasks_reissued: u64,
    /// Stale messages dropped by the generation/epoch dedup rule (late
    /// `ScheduleDown` with an old epoch, duplicate `TaskDone` for a task
    /// already recorded `Done`).
    pub crash_dups_dropped: u64,
    /// `StealDeny`s synthesized by a parent on re-adoption for a
    /// `StealReq` that was in flight to the crashed child (keeps
    /// `steal_reqs == steal_grants + steal_denies` and un-leaks the
    /// one-req-in-flight latch).
    pub crash_denies_synth: u64,
    /// Heartbeat `Ping` probes sent by parent schedulers.
    pub heartbeats: u64,
}

impl GlobalStats {
    /// Fold `other` into `self`. Every counter is a sum except
    /// `ready_queue_hwm`, whose semantics are "max ever observed".
    /// Keep this in sync with the field list above — a counter missing
    /// here silently under-reports in threaded runs (the facade unit
    /// test below catches drift for every field it exercises).
    pub fn merge_from(&mut self, o: &GlobalStats) {
        self.tasks_spawned += o.tasks_spawned;
        self.tasks_completed += o.tasks_completed;
        self.events_processed += o.events_processed;
        self.msgs_total += o.msgs_total;
        self.dma_transfers += o.dma_transfers;
        self.regions_created += o.regions_created;
        self.objects_created += o.objects_created;
        self.dep_boundary_msgs += o.dep_boundary_msgs;
        self.steal_reqs += o.steal_reqs;
        self.steal_grants += o.steal_grants;
        self.steal_denies += o.steal_denies;
        self.tasks_stolen += o.tasks_stolen;
        self.ready_queue_hwm = self.ready_queue_hwm.max(o.ready_queue_hwm);
        self.crashes += o.crashes;
        self.restarts += o.restarts;
        self.re_adoptions += o.re_adoptions;
        self.tasks_reissued += o.tasks_reissued;
        self.crash_dups_dropped += o.crash_dups_dropped;
        self.crash_denies_synth += o.crash_denies_synth;
        self.heartbeats += o.heartbeats;
    }
}

/// Per-shard slice of the `World`'s global state: the accumulator a
/// shard's worker thread charges while stepping its shard inside a
/// lookahead window. Truly global state (journal, traffic books, the
/// data store) stays behind the cross-shard message seam; counters are
/// the one piece every handler touches, so they get a shard-local slot
/// reduced at the conservative barrier / at quiescence instead of
/// threads contending one struct.
#[derive(Clone, Default, Debug)]
pub struct WorldShard {
    pub gstats: GlobalStats,
}

thread_local! {
    /// Which `WorldShard` slot this thread's counter traffic routes to.
    /// `usize::MAX` (every thread's initial state, and the main thread
    /// always) means the legacy `main` struct — so sequential runs never
    /// take the slot path and stay byte-identical.
    static STAT_SLOT: Cell<usize> = const { Cell::new(usize::MAX) };
}

/// Facade over [`GlobalStats`] that routes counter traffic to a
/// per-shard [`WorldShard`] slot when (and only when) the calling thread
/// has bound one. `Deref`/`DerefMut` keep every existing
/// `world.gstats.field` read/write source-compatible: on the main thread
/// (and in any sequential run) they resolve to the legacy `main` struct.
/// A worker thread stepping shard `k` binds slot `k` for the duration of
/// the window; the threaded executor reduces the slots back into `main`
/// (sums; max for the high-water mark) at quiescence, so post-run
/// observers always see the merged totals.
#[derive(Clone, Default, Debug)]
pub struct GStats {
    main: GlobalStats,
    shards: Vec<WorldShard>,
}

impl GStats {
    /// Ensure `n` per-shard slots exist (idempotent; only grows).
    pub fn install_shards(&mut self, n: usize) {
        if self.shards.len() < n {
            self.shards.resize_with(n, WorldShard::default);
        }
    }

    /// Bind the calling thread's counter traffic to shard slot `k`.
    pub fn set_slot(k: usize) {
        STAT_SLOT.with(|c| c.set(k));
    }

    /// Unbind the calling thread (back to the legacy `main` struct).
    pub fn clear_slot() {
        STAT_SLOT.with(|c| c.set(usize::MAX));
    }

    /// Direct access to a shard slot (barrier-time snapshot/restore).
    pub fn slot(&self, k: usize) -> &GlobalStats {
        &self.shards[k].gstats
    }

    pub fn slot_mut(&mut self, k: usize) -> &mut GlobalStats {
        &mut self.shards[k].gstats
    }

    /// Merged totals without mutating the accumulators.
    pub fn totals(&self) -> GlobalStats {
        let mut t = self.main.clone();
        for s in &self.shards {
            t.merge_from(&s.gstats);
        }
        t
    }

    /// Fold every shard slot into `main` and reset the slots. Called at
    /// quiescence by the threaded executor; afterwards plain `Deref`
    /// reads (main thread) see the merged totals.
    pub fn reduce(&mut self) {
        for s in &mut self.shards {
            let part = std::mem::take(&mut s.gstats);
            self.main.merge_from(&part);
        }
    }
}

impl Deref for GStats {
    type Target = GlobalStats;

    #[inline]
    fn deref(&self) -> &GlobalStats {
        let s = STAT_SLOT.with(|c| c.get());
        if s == usize::MAX || s >= self.shards.len() {
            &self.main
        } else {
            &self.shards[s].gstats
        }
    }
}

impl DerefMut for GStats {
    #[inline]
    fn deref_mut(&mut self) -> &mut GlobalStats {
        let s = STAT_SLOT.with(|c| c.get());
        if s == usize::MAX || s >= self.shards.len() {
            &mut self.main
        } else {
            &mut self.shards[s].gstats
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_sum_to_one() {
        let s = CoreStats { busy_task: 600, busy_runtime: 150, ..Default::default() };
        let total = 1000;
        let sum = s.task_frac(total) + s.runtime_frac(total) + s.idle_frac(total);
        assert!((sum - 1.0).abs() < 1e-9);
        assert!((s.idle_frac(total) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn zero_total_is_safe() {
        let s = CoreStats::default();
        assert_eq!(s.task_frac(0), 0.0);
        assert_eq!(s.idle_frac(0), 1.0);
    }

    #[test]
    fn idle_clamps_at_zero() {
        // Overcommitted core (busy > wall) must not report negative idle.
        let s = CoreStats { busy_task: 900, busy_runtime: 400, ..Default::default() };
        assert_eq!(s.idle_frac(1000), 0.0);
    }

    #[test]
    fn sharded_reduce_matches_legacy_totals() {
        // The satellite pin: accumulating the same charges through
        // per-shard slots and reducing must equal the legacy
        // single-struct accumulation, field for field (sums everywhere,
        // max for ready_queue_hwm).
        let mut legacy = GlobalStats::default();
        let mut g = GStats::default();
        g.install_shards(3);
        for i in 0..300u64 {
            let k = (i % 3) as usize;
            GStats::set_slot(k);
            g.tasks_spawned += 1;
            g.tasks_completed += 1;
            g.events_processed += i;
            g.msgs_total += 2;
            g.dma_transfers += (i % 2 == 0) as u64;
            g.dep_boundary_msgs += (i % 5 == 0) as u64;
            g.steal_reqs += 1;
            g.steal_grants += (i % 4 == 0) as u64;
            g.steal_denies += (i % 4 != 0) as u64;
            g.tasks_stolen += (i % 4 == 0) as u64;
            g.ready_queue_hwm = g.ready_queue_hwm.max(i % 17);
            g.heartbeats += 1;
            GStats::clear_slot();
            legacy.tasks_spawned += 1;
            legacy.tasks_completed += 1;
            legacy.events_processed += i;
            legacy.msgs_total += 2;
            legacy.dma_transfers += (i % 2 == 0) as u64;
            legacy.dep_boundary_msgs += (i % 5 == 0) as u64;
            legacy.steal_reqs += 1;
            legacy.steal_grants += (i % 4 == 0) as u64;
            legacy.steal_denies += (i % 4 != 0) as u64;
            legacy.tasks_stolen += (i % 4 == 0) as u64;
            legacy.ready_queue_hwm = legacy.ready_queue_hwm.max(i % 17);
            legacy.heartbeats += 1;
        }
        // Main-thread (unbound) traffic lands in the legacy struct.
        g.regions_created += 7;
        legacy.regions_created += 7;
        assert_eq!(g.totals(), legacy);
        // Before the reduce, plain reads see only the main-thread part.
        assert_eq!(g.tasks_spawned, 0);
        g.reduce();
        assert_eq!(*g, legacy);
        // Reduce is idempotent: slots were drained.
        g.reduce();
        assert_eq!(*g, legacy);
    }

    #[test]
    fn unbound_threads_use_the_main_struct() {
        let mut g = GStats::default();
        g.install_shards(2);
        g.tasks_spawned += 5;
        assert_eq!(g.tasks_spawned, 5);
        assert_eq!(g.slot(0).tasks_spawned, 0);
        assert_eq!(g.slot(1).tasks_spawned, 0);
    }
}

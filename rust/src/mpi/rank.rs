//! Mini-MPI: SPMD ranks over the same NoC simulation.
//!
//! The baseline of paper VI: "a lightweight MPI library implementation
//! which runs on an emulated architecture of a single-chip manycore CPU
//! with a very efficient network-on-chip". Each rank executes a
//! pre-generated program of compute, point-to-point and collective
//! operations. Payloads move as DMA transfers; collectives use the
//! platform's hardware-assisted mechanisms (the prototype does an
//! all-worker barrier in 459 cycles) plus logarithmic tree software costs.

use std::collections::{HashMap, VecDeque};

use crate::ids::{CoreId, Cycles};
use crate::noc::msg::Msg;
use crate::sim::engine::{CoreLogic, Ctx};
use crate::sim::event::{Event, TimerKind};

/// One step of a rank's program.
#[derive(Clone, Debug)]
pub enum MpiOp {
    Compute(Cycles),
    /// Non-blocking buffered send (the benchmarks double-buffer and
    /// overlap communication, paper VI-B).
    Send { to: usize, tag: u64, bytes: u64 },
    /// Blocking receive matched by (source, tag).
    Recv { from: usize, tag: u64, bytes: u64 },
    Barrier,
    /// Broadcast `bytes` from `root` (tree latency; everyone blocks).
    Bcast { root: usize, bytes: u64 },
    /// Reduce `bytes` to `root`.
    Reduce { root: usize, bytes: u64 },
    /// Allreduce = reduce + broadcast.
    Allreduce { bytes: u64 },
}

/// Shared collective rendezvous state (lives in `World.mpi`).
#[derive(Default)]
pub struct MpiShared {
    /// collective sequence number -> (#arrived, blocked cores).
    colls: HashMap<u64, (usize, Vec<CoreId>)>,
    pub n_ranks: usize,
    pub finished: usize,
}

impl MpiShared {
    pub fn new(n_ranks: usize) -> Self {
        MpiShared { n_ranks, ..Default::default() }
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Blocked {
    No,
    Recv { from: usize, tag: u64 },
    Coll,
}

pub struct MpiRank {
    pub rank: usize,
    core: CoreId,
    rank_cores: Vec<CoreId>,
    prog: Vec<MpiOp>,
    pc: usize,
    /// Arrived messages: (src rank, tag) -> payload sizes in order.
    mailbox: HashMap<(usize, u64), VecDeque<u64>>,
    blocked: Blocked,
    /// Collective sequence counter (identical across ranks: SPMD).
    coll_seq: u64,
}

impl MpiRank {
    pub fn new(rank: usize, rank_cores: Vec<CoreId>, prog: Vec<MpiOp>) -> Self {
        let core = rank_cores[rank];
        MpiRank { rank, core, rank_cores, prog, pc: 0, mailbox: HashMap::new(), blocked: Blocked::No, coll_seq: 0 }
    }

    fn n_ranks(&self) -> usize {
        self.rank_cores.len()
    }

    /// Tree depth for collectives.
    fn levels(&self) -> u64 {
        let n = self.n_ranks().max(2) as u64;
        64 - (n - 1).leading_zeros() as u64
    }

    /// Software + wire cost of a collective, charged per rank at release.
    fn coll_cost(&self, ctx: &Ctx<'_>, bytes: u64) -> Cycles {
        if bytes == 0 {
            // Barrier: hardware-assisted; 459 cycles for 512 cores, scaled
            // by tree depth.
            return 51 * self.levels();
        }
        let per_level = ctx.sim.cost.mpi_recv_overhead + ctx.sim.cost.dma_time(bytes, 4);
        per_level * self.levels()
    }

    fn step(&mut self, ctx: &mut Ctx<'_>) {
        while self.pc < self.prog.len() {
            let op = self.prog[self.pc].clone();
            match op {
                MpiOp::Compute(c) => {
                    ctx.charge_task(c);
                    self.pc += 1;
                }
                MpiOp::Send { to, tag, bytes } => {
                    ctx.charge(ctx.sim.cost.mpi_send_overhead);
                    let dst = self.rank_cores[to];
                    let hops = ctx.hops_to(dst);
                    let dt = ctx.sim.cost.dma_time(bytes, hops);
                    ctx.sim.stats[self.core.idx()].dma_bytes_out += bytes;
                    ctx.sim.stats[dst.idx()].dma_bytes_in += bytes;
                    ctx.world.gstats.dma_transfers += 1;
                    let at = ctx.now() + dt;
                    let src_core = self.core;
                    ctx.sim.push(at, dst, Event::Msg {
                        from: src_core,
                        dst,
                        msg: Msg::MpiSend { src: src_core, tag, bytes },
                    });
                    self.pc += 1;
                }
                MpiOp::Recv { from, tag, bytes: _ } => {
                    let key = (from, tag);
                    if let Some(q) = self.mailbox.get_mut(&key) {
                        if let Some(_bytes) = q.pop_front() {
                            if q.is_empty() {
                                self.mailbox.remove(&key);
                            }
                            ctx.charge(ctx.sim.cost.mpi_recv_overhead);
                            self.pc += 1;
                            continue;
                        }
                    }
                    self.blocked = Blocked::Recv { from, tag };
                    return;
                }
                MpiOp::Barrier => {
                    if self.enter_coll(ctx, 0) {
                        return;
                    }
                }
                MpiOp::Bcast { root: _, bytes } | MpiOp::Reduce { root: _, bytes } => {
                    if self.enter_coll(ctx, bytes) {
                        return;
                    }
                }
                MpiOp::Allreduce { bytes } => {
                    if self.enter_coll(ctx, 2 * bytes) {
                        return;
                    }
                }
            }
        }
        if self.blocked == Blocked::No && self.pc == self.prog.len() {
            self.pc += 1; // only count once
            let all_done = {
                let mpi = ctx.world.mpi.as_mut().expect("mpi shared state");
                mpi.finished += 1;
                mpi.finished == mpi.n_ranks
            };
            if all_done {
                ctx.world.done = true;
            }
        }
    }

    /// Returns true if this rank blocked (collective not yet complete).
    fn enter_coll(&mut self, ctx: &mut Ctx<'_>, bytes: u64) -> bool {
        let seq = self.coll_seq;
        self.coll_seq += 1;
        let cost = self.coll_cost(ctx, bytes);
        let n = self.n_ranks();
        let released = {
            let mpi = ctx.world.mpi.as_mut().expect("mpi shared state");
            let entry = mpi.colls.entry(seq).or_insert((0, Vec::new()));
            entry.0 += 1;
            if entry.0 == n {
                let waiters = std::mem::take(&mut entry.1);
                mpi.colls.remove(&seq);
                Some(waiters)
            } else {
                entry.1.push(self.core);
                None
            }
        };
        self.pc += 1; // resume *after* the collective either way
        match released {
            Some(waiters) => {
                // Last arrival releases everyone after the collective cost.
                ctx.charge(cost);
                let at = ctx.now();
                for w in waiters {
                    ctx.sim.push(at, w, Event::Timer(TimerKind::MpiStep));
                }
                false
            }
            None => {
                self.blocked = Blocked::Coll;
                true
            }
        }
    }
}

impl CoreLogic for MpiRank {
    fn on_event(&mut self, ctx: &mut Ctx<'_>, ev: Event) {
        match ev {
            Event::Boot => self.step(ctx),
            Event::Timer(TimerKind::MpiStep) => {
                // Collective released.
                debug_assert_eq!(self.blocked, Blocked::Coll);
                self.blocked = Blocked::No;
                self.step(ctx);
            }
            Event::Msg { from, dst, msg: Msg::MpiSend { src, tag, bytes } } => {
                debug_assert_eq!(from, src);
                debug_assert_eq!(dst, self.core, "MPI send delivered to the wrong rank core");
                let src_rank = self.rank_cores.iter().position(|&c| c == src).expect("rank core");
                self.mailbox.entry((src_rank, tag)).or_default().push_back(bytes);
                if self.blocked == (Blocked::Recv { from: src_rank, tag }) {
                    self.blocked = Blocked::No;
                    // The pending Recv at pc will now match.
                    self.step(ctx);
                }
            }
            _ => {}
        }
    }
}

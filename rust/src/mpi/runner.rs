//! Build and run a mini-MPI simulation from per-rank programs.

use crate::config::{CoreKind, PlatformConfig};
use crate::ids::{CoreId, Cycles};
use crate::mpi::rank::{MpiOp, MpiRank, MpiShared};
use crate::noc::topology::Topology;
use crate::platform::World;
use crate::sim::engine::{Engine, SimState};
use crate::task::registry::Registry;

/// Assemble (but do not run) an MPI simulation from per-rank programs.
/// Ranks map to consecutive MicroBlaze cores on the mesh (matching the
/// hand placement of paper VI-B). Boot events are queued; the caller runs
/// the engine — the split lets the bench harness time only the event loop.
pub fn build_mpi(programs: Vec<Vec<MpiOp>>, cfg: &PlatformConfig) -> Engine {
    let n = programs.len();
    assert!(n >= 1);
    let kinds = vec![CoreKind::MicroBlaze; n];
    let sim = SimState::new(kinds, Topology::new(n), cfg.cost.clone(), cfg.channel_capacity);
    let mut world_cfg = cfg.clone();
    world_cfg.n_workers = n;
    let mut world = World::new(world_cfg);
    world.mpi = Some(MpiShared::new(n));
    let mut eng = Engine::new(sim, world, Registry::new());
    let rank_cores: Vec<CoreId> = (0..n).map(|i| CoreId(i as u32)).collect();
    for (i, prog) in programs.into_iter().enumerate() {
        eng.set_logic(rank_cores[i], Box::new(MpiRank::new(i, rank_cores.clone(), prog)));
    }
    eng.boot();
    eng
}

/// Run `programs` (one per rank) to completion. Returns the finished
/// engine (final time in `eng.sim.now`).
pub fn run_mpi(programs: Vec<Vec<MpiOp>>, cfg: &PlatformConfig) -> Engine {
    let mut eng = build_mpi(programs, cfg);
    eng.run(Some(1 << 44));
    eng.sim.now = eng.sim.horizon();
    eng
}

/// Total wall time of an MPI run.
pub fn mpi_time(programs: Vec<Vec<MpiOp>>, cfg: &PlatformConfig) -> Cycles {
    run_mpi(programs, cfg).sim.now
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PlatformConfig {
        PlatformConfig::flat(1)
    }

    #[test]
    fn compute_only_ranks_run_in_parallel() {
        let progs = vec![vec![MpiOp::Compute(1_000_000)]; 8];
        let t = mpi_time(progs, &cfg());
        assert!(t < 1_100_000, "8 parallel ranks should take ~1M cycles, got {t}");
    }

    #[test]
    fn send_recv_pairs_match() {
        // Ring: each rank sends to the right, receives from the left.
        let n = 4;
        let progs: Vec<Vec<MpiOp>> = (0..n)
            .map(|r| {
                vec![
                    MpiOp::Send { to: (r + 1) % n, tag: 7, bytes: 4096 },
                    MpiOp::Recv { from: (r + n - 1) % n, tag: 7, bytes: 4096 },
                    MpiOp::Compute(1000),
                ]
            })
            .collect();
        let eng = run_mpi(progs, &cfg());
        assert!(eng.world.done, "all ranks must finish");
        assert_eq!(eng.sim.stats[0].dma_bytes_out, 4096);
        assert_eq!(eng.sim.stats[0].dma_bytes_in, 4096);
    }

    #[test]
    fn recv_blocks_until_send() {
        // Rank 1 computes a long time before sending; rank 0's recv must
        // stretch its completion time.
        let progs = vec![
            vec![MpiOp::Recv { from: 1, tag: 0, bytes: 64 }],
            vec![MpiOp::Compute(5_000_000), MpiOp::Send { to: 0, tag: 0, bytes: 64 }],
        ];
        let t = mpi_time(progs, &cfg());
        assert!(t >= 5_000_000);
    }

    #[test]
    fn barrier_synchronizes() {
        // Rank 0 is slow before the barrier; everyone leaves after it.
        let progs = vec![
            vec![MpiOp::Compute(2_000_000), MpiOp::Barrier, MpiOp::Compute(100)],
            vec![MpiOp::Barrier, MpiOp::Compute(100)],
            vec![MpiOp::Barrier, MpiOp::Compute(100)],
        ];
        let eng = run_mpi(progs, &cfg());
        assert!(eng.world.done);
        assert!(eng.sim.now >= 2_000_000);
    }

    #[test]
    fn allreduce_completes() {
        let progs = vec![vec![MpiOp::Allreduce { bytes: 256 }, MpiOp::Compute(10)]; 16];
        let eng = run_mpi(progs, &cfg());
        assert!(eng.world.done);
    }

    #[test]
    fn out_of_order_tags_match_correctly() {
        // Rank 1 sends tag 5 then tag 6; rank 0 receives 6 then 5.
        let progs = vec![
            vec![
                MpiOp::Recv { from: 1, tag: 6, bytes: 64 },
                MpiOp::Recv { from: 1, tag: 5, bytes: 64 },
            ],
            vec![
                MpiOp::Send { to: 0, tag: 5, bytes: 64 },
                MpiOp::Send { to: 0, tag: 6, bytes: 64 },
            ],
        ];
        let eng = run_mpi(progs, &cfg());
        assert!(eng.world.done, "tag matching must not deadlock");
    }
}

//! Mini-MPI baseline runtime on the same NoC simulation (paper VI-B).
pub mod rank;
pub mod runner;

//! Shared compute-cost models for the six benchmarks (paper VI-B).
//!
//! Both runtimes (Myrmics and the MPI baseline) charge the *same* cycle
//! cost for the same piece of work, so the scaling comparison isolates
//! runtime overhead — exactly the paper's methodology ("For each data
//! point, a Myrmics worker and an MPI core perform the same amount of
//! computation").
//!
//! Constants are MicroBlaze cycles per element-operation, set so that the
//! paper's minimum task sizes (~1 M cycles) correspond to sensible
//! per-task data chunks.

use crate::ids::Cycles;

/// Jacobi: 4 neighbour loads + adds + multiply + store per cell.
pub const JACOBI_PER_CELL: Cycles = 14;

/// Raytracing: average cycles per pixel (scene-dependent; see
/// [`raytrace_line_cycles`] for the per-line variation).
pub const RAY_PER_PIXEL: Cycles = 420;

/// Bitonic: compare-exchange cycles per element per pass.
pub const BITONIC_PER_ELEM: Cycles = 26;

/// K-Means: cycles per (point, cluster) distance evaluation.
pub const KMEANS_PER_POINT_CLUSTER: Cycles = 9;

/// Matrix multiplication: cycles per multiply-accumulate.
pub const MATMUL_PER_MAC: Cycles = 8;

/// Barnes-Hut: cycles per body-node interaction.
pub const BH_PER_INTERACTION: Cycles = 32;

pub fn jacobi_cycles(rows: u64, cols: u64) -> Cycles {
    rows * cols * JACOBI_PER_CELL
}

/// Per-line raytracing cost: the paper notes "some picture lines will be
/// in the path of more scene objects than others", so cost varies
/// deterministically with the line index (a smooth pseudo-scene profile).
pub fn raytrace_line_cycles(line: u64, width: u64, n_lines: u64) -> Cycles {
    // Scene density peaks mid-frame; +/-40% variation.
    let x = line as f64 / n_lines.max(1) as f64;
    let density = 1.0 + 0.4 * (std::f64::consts::PI * x).sin() - 0.2;
    (width as f64 * RAY_PER_PIXEL as f64 * density) as Cycles
}

/// Local sort of `n` elements (n log n).
pub fn sort_cycles(n: u64) -> Cycles {
    let logn = 64 - n.max(2).leading_zeros() as u64;
    n * logn * BITONIC_PER_ELEM
}

/// One bitonic merge pass over `n` local elements.
pub fn merge_cycles(n: u64) -> Cycles {
    n * BITONIC_PER_ELEM
}

pub fn kmeans_assign_cycles(points: u64, clusters: u64) -> Cycles {
    points * clusters * KMEANS_PER_POINT_CLUSTER
}

/// Block matmul: multiply (m x k) by (k x n).
pub fn matmul_cycles(m: u64, k: u64, n: u64) -> Cycles {
    m * k * n * MATMUL_PER_MAC
}

/// Barnes-Hut octree build over `n` local bodies.
pub fn bh_build_cycles(n: u64) -> Cycles {
    let logn = 64 - n.max(2).leading_zeros() as u64;
    n * logn * 18
}

/// Barnes-Hut force evaluation: `n` bodies against a tree of `m` bodies
/// (theta-pruned to log m interactions per body).
pub fn bh_force_cycles(n: u64, m: u64) -> Cycles {
    let logm = 64 - m.max(2).leading_zeros() as u64;
    n * logm * BH_PER_INTERACTION
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jacobi_scales_linearly() {
        assert_eq!(jacobi_cycles(10, 10), 1400);
        assert_eq!(jacobi_cycles(20, 10), 2 * jacobi_cycles(10, 10));
    }

    #[test]
    fn raytrace_varies_but_stays_positive() {
        let w = 512;
        let n = 64;
        let costs: Vec<Cycles> = (0..n).map(|l| raytrace_line_cycles(l, w, n)).collect();
        assert!(costs.iter().all(|&c| c > 0));
        let min = *costs.iter().min().unwrap() as f64;
        let max = *costs.iter().max().unwrap() as f64;
        assert!(max / min > 1.2, "per-line variation should be visible");
        // Mid-frame lines are the most expensive.
        assert!(costs[n as usize / 2] > costs[0]);
    }

    #[test]
    fn sort_beats_merge() {
        assert!(sort_cycles(1 << 12) > merge_cycles(1 << 12));
    }

    #[test]
    fn million_cycle_tasks_are_reachable() {
        // The paper uses 1 M-cycle minimum tasks; check the models can
        // express them with reasonable data sizes.
        assert!(jacobi_cycles(100, 715) > 1_000_000);
        assert!(kmeans_assign_cycles(7000, 16) > 1_000_000);
        assert!(matmul_cycles(50, 50, 50) == 1_000_000);
        assert!(raytrace_line_cycles(32, 2500, 64) > 800_000);
    }
}

//! Skewed-spawn synthetic workload: the adversary work stealing exists
//! for.
//!
//! One parent task creates `groups` region subtrees — pushed to leaf-level
//! owners, so each group delegates to a distinct scheduler subtree — and
//! then spawns independent compute tasks with a configurable *hot-spot
//! fraction* aimed at group 0. Static placement (paper V-E) must follow
//! the delegation: every hot task lands in the hot group's subtree and
//! queues behind its few workers while the sibling subtrees idle. With
//! stealing enabled (`StealCfg`), the schedulers above the hot leaf pull
//! queued-ready tasks back out and re-place them towards the idle
//! siblings, which is exactly the makespan gap the `steal` experiment
//! measures.
//!
//! The MPI baseline hand-balances the same total work statically — the
//! "hand-tuned MPI" bar the paper compares runtime scheduling against.

use std::any::Any;

use crate::api::args::ObjArg;
use crate::api::ctx::TaskCtx;
use crate::apps::workload_api::{
    app_state, check_task_counts, groups_for, Scaling, Workload,
};
use crate::ids::RegionId;
use crate::mpi::rank::MpiOp;
use crate::platform::World;
use crate::task::registry::{Registry, TaskRef};

/// Deep enough to sink group regions to leaf-level owners on any tree the
/// experiments build (levels are 0-indexed from the top; real trees stop
/// descending at their leaves).
const LEAF_LEVEL: i32 = 8;

#[derive(Clone, Debug)]
pub struct SkewParams {
    /// Independent compute tasks spawned by main.
    pub tasks: usize,
    pub task_cycles: u64,
    /// Percentage (0..=100) of tasks spawned into the hot group (group 0);
    /// the remainder round-robins over the other groups.
    pub hot_pct: u32,
    /// Region subtrees (>= 1). Group 0 is the hot spot.
    pub groups: usize,
}

impl SkewParams {
    /// How many of `tasks` hit the hot group.
    pub fn hot_tasks(&self) -> usize {
        self.tasks * self.hot_pct as usize / 100
    }
}

/// Register the task bodies; returns the main task's handle.
fn register_tasks(reg: &mut Registry) -> TaskRef {
    let work = reg.register("skew_work", |ctx: &mut TaskCtx<'_>| {
        let (_obj, cycles): (ObjArg, u64) = ctx.args();
        ctx.compute(cycles);
    });
    reg.register("skew_main", move |ctx: &mut TaskCtx<'_>| {
        let p = ctx.world.app_ref::<SkewParams>().clone();
        let groups = p.groups.max(1);
        let mut regions = Vec::with_capacity(groups);
        for _ in 0..groups {
            regions.push(ctx.ralloc(RegionId::ROOT, LEAF_LEVEL));
        }
        let hot = p.hot_tasks();
        for i in 0..p.tasks {
            let g = if i < hot || groups == 1 {
                0
            } else {
                // Cold remainder round-robins over groups 1..groups.
                1 + (i - hot) % (groups - 1)
            };
            let o = ctx.alloc(64, regions[g]);
            ctx.spawn_task(work).obj_inout(o).val(p.task_cycles).submit();
        }
    })
}

/// Build the Myrmics skew workload. Returns (registry, main task).
pub fn myrmics() -> (Registry, TaskRef) {
    let mut reg = Registry::new();
    let main = register_tasks(&mut reg);
    (reg, main)
}

/// MPI baseline: the hand-tuned programmer statically balances the same
/// `tasks * task_cycles` total work across ranks — skew is a scheduling
/// problem, not an algorithmic one, so the static decomposition is flat.
pub fn mpi_programs(p: &SkewParams, ranks: usize) -> Vec<Vec<MpiOp>> {
    (0..ranks)
        .map(|r| {
            let t0 = r * p.tasks / ranks;
            let t1 = (r + 1) * p.tasks / ranks;
            vec![MpiOp::Compute((t1 - t0) as u64 * p.task_cycles), MpiOp::Barrier]
        })
        .collect()
}

/// The skewed-spawn [`Workload`].
pub struct Skew;

fn sized(workers: usize, scaling: Scaling, groups: usize) -> SkewParams {
    // VI-B-style decomposition: 2 tasks per worker. Strong scaling fixes
    // the total work; weak scaling fixes the per-task size at the ~1 M
    // minimum.
    let tasks = (2 * workers).max(16);
    let task_cycles = match scaling {
        Scaling::Strong => ((1u64 << 31) / tasks as u64).max(1_000_000),
        Scaling::Weak => 1_000_000,
    };
    SkewParams { tasks, task_cycles, hot_pct: 85, groups }
}

impl Workload for Skew {
    fn name(&self) -> &'static str {
        "skew"
    }

    /// The adversary: a hard hot-spot fraction, as in the steal sweep.
    fn job_shape(&self, scale: u32) -> crate::sim::traffic::JobShape {
        let s = scale.max(1);
        crate::sim::traffic::JobShape {
            tasks: 16 * s,
            task_cycles: 1_000_000,
            fanout: 4,
            hot_pct: 85,
        }
    }

    fn register(&self, reg: &mut Registry) -> TaskRef {
        register_tasks(reg)
    }

    fn params_for(&self, workers: usize, scaling: Scaling) -> Box<dyn Any> {
        Box::new(sized(workers, scaling, groups_for(workers)))
    }

    fn mpi_programs(&self, ranks: usize, scaling: Scaling) -> Vec<Vec<MpiOp>> {
        mpi_programs(&sized(ranks, scaling, 1), ranks)
    }

    fn verify(&self, world: &World) -> Result<(), String> {
        let p = app_state::<SkewParams>(world)?;
        // Task-count formula: main + one work task per decomposition unit.
        check_task_counts(world, 1 + p.tasks as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HierarchySpec, PlatformConfig};
    use crate::mpi::runner::mpi_time;
    use crate::platform::Platform;

    fn params() -> SkewParams {
        SkewParams { tasks: 40, task_cycles: 200_000, hot_pct: 90, groups: 4 }
    }

    fn build(cfg: PlatformConfig, p: SkewParams) -> Platform {
        let (reg, main) = myrmics();
        Platform::build_with(cfg, reg, main, move |w| {
            w.app = Some(Box::new(p));
        })
    }

    #[test]
    fn completes_and_counts_match_the_formula() {
        let p = params();
        let mut plat = build(PlatformConfig::new(16, HierarchySpec::two_level(4)), p.clone());
        let t = plat.run(Some(1 << 44));
        assert!(t > 0);
        assert_eq!(plat.world().gstats.tasks_spawned, 1 + p.tasks as u64);
        Skew.verify(plat.world()).expect("verify must pass");
    }

    #[test]
    fn hot_fraction_formula() {
        assert_eq!(params().hot_tasks(), 36);
        let p = SkewParams { hot_pct: 100, ..params() };
        assert_eq!(p.hot_tasks(), 40);
        let p = SkewParams { hot_pct: 0, ..params() };
        assert_eq!(p.hot_tasks(), 0);
    }

    /// Static placement must follow the delegation: without stealing, the
    /// hot group's leaf subtree executes (at least) the hot share of the
    /// work — which is the imbalance the steal experiment then removes.
    #[test]
    fn skew_concentrates_work_without_stealing() {
        let p = params();
        let mut plat = build(PlatformConfig::new(16, HierarchySpec::two_level(4)), p.clone());
        plat.run(Some(1 << 44));
        let hier = &plat.eng.world.hier;
        // Tasks run per leaf subtree (4 workers each).
        let mut per_leaf = vec![0u64; hier.n_scheds];
        for s in 0..hier.n_scheds {
            for w in hier.leaf_workers[s].iter() {
                per_leaf[s] += plat.eng.sim.stats[w.idx()].tasks_run;
            }
        }
        let max = *per_leaf.iter().max().unwrap();
        // 36 hot tasks + main on one leaf out of 40+1 total.
        assert!(
            max >= p.hot_tasks() as u64,
            "hot leaf ran {max} tasks, expected >= {}: {per_leaf:?}",
            p.hot_tasks()
        );
    }

    #[test]
    fn mpi_baseline_is_balanced_and_finishes() {
        let p = params();
        let t1 = mpi_time(mpi_programs(&p, 1), &PlatformConfig::flat(1));
        let t8 = mpi_time(mpi_programs(&p, 8), &PlatformConfig::flat(1));
        assert!(t1 as f64 / t8 as f64 > 5.0, "static balance scales: {t1} vs {t8}");
    }
}

//! The unified `Workload` seam: every benchmark scenario behind one
//! trait, enumerated from one table.
//!
//! Before this layer, adding a workload meant editing a 150-line `match`
//! in `experiments/bench.rs` (plus its `valid_workers`/`iters`
//! duplicates) and hand-syncing spawn-site argument order with body-site
//! indices. Now a scenario is **one self-contained file** in `apps/`:
//! implement [`Workload`], add the entry to [`all_workloads`], and every
//! driver — fig8/9/11, the policy sweep, the benches, the CLI and the
//! generic smoke test — picks it up through trait dispatch. See
//! `docs/app-api.md` for a worked example.
//!
//! Sizing follows paper VI-B: strong scaling fixes the problem and
//! decomposes into 2 tasks per worker per step with >= ~1 M-cycle minimum
//! tasks at 512 workers; weak scaling fixes per-task size at the ~1 M
//! minimum and grows the problem with the worker count. Each workload's
//! `params_for` encodes its instance of that rule.

use std::any::Any;
use std::fmt;
use std::ops::Deref;

use crate::config::HierarchySpec;
use crate::mpi::rank::MpiOp;
use crate::platform::World;
use crate::sim::traffic::{JobShape, JobTemplate};
use crate::task::registry::{Registry, TaskRef};

/// Problem-sizing mode (paper VI-B).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Scaling {
    Strong,
    Weak,
}

/// One benchmark scenario, fully self-describing.
///
/// Implementations are unit structs (`pub struct Jacobi;`) living next
/// to their task bodies, so `&'static dyn Workload` references are free.
pub trait Workload {
    /// CLI/report name (e.g. `"barnes-hut"`).
    fn name(&self) -> &'static str;

    /// Worker counts this workload supports (e.g. matmul needs square
    /// grids). Default: all.
    fn valid_workers(&self, workers: usize) -> bool {
        let _ = workers;
        true
    }

    /// Register the task bodies into `reg`; returns the main task's
    /// typed handle.
    fn register(&self, reg: &mut Registry) -> TaskRef;

    /// Boxed parameter struct for a `(workers, scaling)` point, to be
    /// installed as `world.app` before boot.
    fn params_for(&self, workers: usize, scaling: Scaling) -> Box<dyn Any>;

    /// The hand-tuned MPI baseline for the same problem size.
    fn mpi_programs(&self, ranks: usize, scaling: Scaling) -> Vec<Vec<MpiOp>>;

    /// Post-run check on the finished world: structural invariants
    /// always, numeric results when the run carried real data.
    fn verify(&self, world: &World) -> Result<(), String>;

    /// This workload's instantiation as a traffic job template: the
    /// shape the generic job body (`apps::jobs`) realizes when an
    /// instance arrives as one job in a multi-tenant mix. `scale`
    /// multiplies the task count (1 = the smoke size). Overrides encode
    /// each workload's decomposition character — grain, fanout,
    /// hot-spot skew — so the arrival mix exercises heterogeneous job
    /// sizes, not seven copies of the same bag.
    fn job_shape(&self, scale: u32) -> JobShape {
        let s = scale.max(1);
        JobShape { tasks: 8 * s, task_cycles: 1_000_000, fanout: 4, hot_pct: 0 }
    }
}

/// Copyable handle to a workload: what drivers pass around and compare.
#[derive(Clone, Copy)]
pub struct WorkloadRef(pub &'static dyn Workload);

impl Deref for WorkloadRef {
    type Target = dyn Workload + 'static;
    fn deref(&self) -> &Self::Target {
        self.0
    }
}

impl PartialEq for WorkloadRef {
    fn eq(&self, other: &Self) -> bool {
        self.0.name() == other.0.name()
    }
}

impl Eq for WorkloadRef {}

impl fmt::Debug for WorkloadRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Workload({})", self.0.name())
    }
}

/// The single enumeration every driver derives its workload list from.
/// Adding a scenario = implementing [`Workload`] in its own file and
/// appending one entry here.
pub fn all_workloads() -> [WorkloadRef; 7] {
    [
        WorkloadRef(&crate::apps::jacobi::Jacobi),
        WorkloadRef(&crate::apps::raytrace::Raytrace),
        WorkloadRef(&crate::apps::bitonic::Bitonic),
        WorkloadRef(&crate::apps::kmeans::Kmeans),
        WorkloadRef(&crate::apps::matmul::Matmul),
        WorkloadRef(&crate::apps::barnes_hut::BarnesHut),
        WorkloadRef(&crate::apps::skew::Skew),
    ]
}

/// Look a workload up by its CLI name; panics on an unknown name.
pub fn workload(name: &str) -> WorkloadRef {
    all_workloads()
        .into_iter()
        .find(|w| w.name() == name)
        .unwrap_or_else(|| panic!("unknown workload {name:?}"))
}

/// Every workload in [`all_workloads`] as a traffic job template at
/// `scale` — the mix the tenants experiment feeds
/// [`TrafficState::generate`](crate::sim::traffic::TrafficState::generate).
pub fn job_templates(scale: u32) -> Vec<JobTemplate> {
    all_workloads()
        .iter()
        .map(|w| JobTemplate { name: w.name(), shape: w.job_shape(scale) })
        .collect()
}

/// Groups used by the app decompositions — the paper's leaf-scheduler
/// count, so each leaf scheduler gets its own region subtree.
pub fn groups_for(workers: usize) -> usize {
    HierarchySpec::paper_leaves(workers).max(1)
}

/// Shared verify() helper: all spawned tasks completed and the spawn
/// count matches the decomposition formula.
pub fn check_task_counts(world: &World, want_spawned: u64) -> Result<(), String> {
    let g = &world.gstats;
    if g.tasks_spawned != want_spawned {
        return Err(format!("spawned {} tasks, expected {}", g.tasks_spawned, want_spawned));
    }
    if g.tasks_completed != g.tasks_spawned {
        return Err(format!(
            "completed {} of {} spawned tasks",
            g.tasks_completed, g.tasks_spawned
        ));
    }
    Ok(())
}

/// Shared verify() helper: elementwise float comparison with an absolute
/// tolerance. Errors on length mismatch and on any out-of-tolerance (or
/// NaN) element.
pub fn check_close(got: &[f32], want: &[f32], tol: f32, label: &str) -> Result<(), String> {
    if got.len() != want.len() {
        return Err(format!("{label}: got {} elements, want {}", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let d = (g - w).abs();
        if d.is_nan() || d >= tol {
            return Err(format!("{label} {i}: got {g}, want {w}"));
        }
    }
    Ok(())
}

/// Downcast the finished world's app state, as a `Result` for verify().
pub fn app_state<T: 'static>(world: &World) -> Result<&T, String> {
    world
        .app
        .as_deref()
        .and_then(|a| a.downcast_ref::<T>())
        .ok_or_else(|| "app state missing or of the wrong type (main never ran?)".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_names_are_unique_and_stable() {
        let names: Vec<&str> = all_workloads().iter().map(|w| w.name()).collect();
        assert_eq!(
            names,
            ["jacobi", "raytrace", "bitonic", "kmeans", "matmul", "barnes-hut", "skew"]
        );
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len());
    }

    #[test]
    fn lookup_by_name_round_trips() {
        for w in all_workloads() {
            assert_eq!(workload(w.name()), w);
        }
    }

    #[test]
    fn job_templates_cover_the_table_with_distinct_shapes() {
        let t = job_templates(1);
        assert_eq!(t.len(), all_workloads().len());
        for (tpl, w) in t.iter().zip(all_workloads()) {
            assert_eq!(tpl.name, w.name());
            assert!(tpl.shape.tasks >= 1 && tpl.shape.fanout >= 1);
            assert!(tpl.shape.hot_pct <= 100);
        }
        assert!(
            t.iter().any(|x| x.shape != t[0].shape),
            "the mix must contain heterogeneous shapes"
        );
        let big = job_templates(4);
        for (a, b) in t.iter().zip(&big) {
            assert!(b.shape.tasks > a.shape.tasks, "scale grows the task count");
        }
    }

    #[test]
    fn valid_worker_filters() {
        assert!(workload("matmul").valid_workers(16));
        assert!(!workload("matmul").valid_workers(32));
        assert!(workload("bitonic").valid_workers(64));
        assert!(!workload("bitonic").valid_workers(48));
        assert!(!workload("barnes-hut").valid_workers(256));
        assert!(workload("jacobi").valid_workers(48));
    }
}

//! Barnes-Hut N-body (paper VI-B, Figs 8f/8l): irregular, pointer-based
//! parallelism over dynamically allocated region trees.
//!
//! "The application makes heavy use of dynamically allocated trees, which
//! are built and destroyed in each step ... Each computation task
//! allocates a tree for its local bodies; this tree belongs to a new
//! region, which is created for the loop repetition and destroyed when
//! the repetition ends. To compute the gravitational forces, tasks are
//! created to operate on two regions, each containing an octree of a part
//! of the 3D space."
//!
//! Per iteration, main: creates a fresh tree region per band, spawns
//! build tasks (bodies -> octree), a summary task reading *all* trees
//! (the all-to-all-flavoured phase that limits scaling), force tasks per
//! band reading the own + neighbouring trees + the summary, then
//! `sys_wait`s and frees the per-iteration regions. Exercises dynamic
//! regions, `sys_rfree` of draining subtrees, and wait/resume.

use std::any::Any;

use crate::api::args::{ObjArg, RegionArg, Rest};
use crate::api::ctx::TaskCtx;
use crate::apps::workload::{bh_build_cycles, bh_force_cycles};
use crate::apps::workload_api::{
    app_state, check_task_counts, groups_for, Scaling, Workload,
};
use crate::ids::{ObjectId, RegionId};
use crate::mpi::rank::MpiOp;
use crate::platform::World;
use crate::task::registry::{Registry, TaskRef};

#[derive(Clone, Debug)]
pub struct BhParams {
    pub bodies: usize,
    /// Spatial bands (tasks per phase).
    pub bands: usize,
    pub groups: usize,
    pub iters: usize,
}

pub struct BhState {
    pub p: BhParams,
    /// Persistent body objects, one per band.
    pub bodies: Vec<ObjectId>,
    pub band_sizes: Vec<usize>,
    pub group_regions: Vec<RegionId>,
    /// Per-iteration state: tree regions + tree objects + summary.
    pub tree_regions: Vec<RegionId>,
    pub trees: Vec<ObjectId>,
    pub summary: Option<ObjectId>,
    pub iters_done: usize,
}

fn band_group(p: &BhParams, b: usize) -> usize {
    b * p.groups / p.bands
}

/// The iteration spawner's task handles (captured by `bh_main`).
#[derive(Clone, Copy)]
struct BhTasks {
    build: TaskRef,
    summary: TaskRef,
    force: TaskRef,
}

/// Build one iteration's tasks, then `sys_wait` on everything it writes.
fn spawn_iteration(ctx: &mut TaskCtx<'_>, tasks: BhTasks) {
    let (p, bodies, band_sizes, group_regions) = {
        let st = ctx.world.app_ref::<BhState>();
        (st.p.clone(), st.bodies.clone(), st.band_sizes.clone(), st.group_regions.clone())
    };
    // Fresh per-iteration tree regions + tree objects (octree footprint
    // ~2x the bodies of the band) + the global summary object.
    let mut tree_regions = Vec::with_capacity(p.bands);
    let mut trees = Vec::with_capacity(p.bands);
    for b in 0..p.bands {
        let r = ctx.ralloc(group_regions[band_group(&p, b)], 2);
        let tree_bytes = (band_sizes[b] * 2 * 32) as u64;
        trees.push(ctx.alloc(tree_bytes, r));
        tree_regions.push(r);
    }
    let summary = ctx.alloc((p.bands * 64) as u64, RegionId::ROOT);
    {
        let st = ctx.world.app_mut::<BhState>();
        st.tree_regions = tree_regions.clone();
        st.trees = trees.clone();
        st.summary = Some(summary);
    }
    // Build tasks: bodies -> octree (tree region inout).
    for b in 0..p.bands {
        ctx.spawn_task(tasks.build)
            .obj_in(bodies[b])
            .reg_inout(tree_regions[b])
            .val(b as u64)
            .submit();
    }
    // Summary task: reads every tree (all-to-all flavour).
    let mut spawn = ctx.spawn_task(tasks.summary).obj_out(summary);
    for b in 0..p.bands {
        spawn = spawn.reg_in(tree_regions[b]);
    }
    spawn.submit();
    // Force tasks: own tree + ring neighbours + summary; update bodies.
    for b in 0..p.bands {
        let mut spawn = ctx
            .spawn_task(tasks.force)
            .obj_inout(bodies[b])
            .reg_in(tree_regions[b])
            .obj_in(summary)
            .val(b as u64);
        if p.bands > 1 {
            spawn = spawn
                .reg_in(tree_regions[(b + p.bands - 1) % p.bands])
                .reg_in(tree_regions[(b + 1) % p.bands]);
        }
        spawn.submit();
    }
    // Wait on the persistent body objects + the summary: everything the
    // iteration writes.
    let mut wait = ctx.wait_on();
    for &o in &bodies {
        wait = wait.obj_inout(o);
    }
    wait.obj_inout(summary).wait();
}

/// Register the Barnes-Hut task bodies; returns the main task's handle.
fn register_tasks(reg: &mut Registry) -> TaskRef {
    // Build octree for a band.
    let build = reg.register("bh_build", |ctx: &mut TaskCtx<'_>| {
        let (_bodies, _tree, b): (ObjArg, RegionArg, usize) = ctx.args();
        let n = ctx.world.app_ref::<BhState>().band_sizes[b] as u64;
        ctx.compute(bh_build_cycles(n));
    });

    // Summarize all trees (multipole summary).
    let summary = reg.register("bh_summary", |ctx: &mut TaskCtx<'_>| {
        let (_summary, _trees): (ObjArg, Rest<RegionArg>) = ctx.args();
        let bands = ctx.world.app_ref::<BhState>().p.bands as u64;
        ctx.compute(bands * 3_000);
    });

    // Force + integrate for a band.
    let force = reg.register("bh_force", |ctx: &mut TaskCtx<'_>| {
        let (_own, _tree, _summary, b, _neighbours): (
            ObjArg,
            RegionArg,
            ObjArg,
            usize,
            Rest<RegionArg>,
        ) = ctx.args();
        let (n, total) = {
            let st = ctx.world.app_ref::<BhState>();
            (st.band_sizes[b] as u64, st.p.bodies as u64)
        };
        ctx.compute(bh_force_cycles(n, total));
    });

    let tasks = BhTasks { build, summary, force };

    // Main — iteration loop through sys_wait phases.
    reg.register("bh_main", move |ctx: &mut TaskCtx<'_>| {
        let phase = ctx.phase() as usize;
        if phase == 0 {
            let p = ctx.world.app_ref::<BhParams>().clone();
            assert!(p.groups <= p.bands);
            let mut group_regions = Vec::new();
            for _ in 0..p.groups {
                group_regions.push(ctx.ralloc(RegionId::ROOT, 1));
            }
            let mut bodies = Vec::new();
            let mut band_sizes = Vec::new();
            for b in 0..p.bands {
                let n0 = b * p.bodies / p.bands;
                let n1 = (b + 1) * p.bodies / p.bands;
                band_sizes.push(n1 - n0);
                bodies.push(ctx.alloc(((n1 - n0) * 32) as u64, group_regions[band_group(&p, b)]));
            }
            ctx.world.app = Some(Box::new(BhState {
                p,
                bodies,
                band_sizes,
                group_regions,
                tree_regions: Vec::new(),
                trees: Vec::new(),
                summary: None,
                iters_done: 0,
            }));
        } else {
            // Previous iteration finished: tear down its trees ("destroyed
            // when the repetition ends").
            let (tree_regions, summary) = {
                let st = ctx.world.app_mut::<BhState>();
                st.iters_done += 1;
                (std::mem::take(&mut st.tree_regions), st.summary.take())
            };
            for r in tree_regions {
                ctx.rfree(r);
            }
            if let Some(s) = summary {
                ctx.free(s);
            }
        }
        let (iters_done, iters) = {
            let st = ctx.world.app_ref::<BhState>();
            (st.iters_done, st.p.iters)
        };
        if iters_done < iters {
            spawn_iteration(ctx, tasks);
        }
    })
}

/// Build the Myrmics Barnes-Hut app. Returns (registry, main task).
pub fn myrmics() -> (Registry, TaskRef) {
    let mut reg = Registry::new();
    let main = register_tasks(&mut reg);
    (reg, main)
}

/// MPI baseline: build + all-to-all body-sample exchange + force +
/// allreduce of the global summary. The quadratic message count is what
/// makes Barnes-Hut scale poorly (paper: "involves many and
/// communication-intensive steps").
pub fn mpi_programs(p: &BhParams, ranks: usize) -> Vec<Vec<MpiOp>> {
    (0..ranks)
        .map(|r| {
            let n = ((r + 1) * p.bodies / ranks - r * p.bodies / ranks) as u64;
            let sample_bytes = (n * 32 / 8).max(64);
            let mut prog = Vec::new();
            for it in 0..p.iters as u64 {
                prog.push(MpiOp::Compute(bh_build_cycles(n)));
                // All-to-all sample exchange.
                for other in 0..ranks {
                    if other != r {
                        prog.push(MpiOp::Send {
                            to: other,
                            tag: it * 1000 + r as u64,
                            bytes: sample_bytes,
                        });
                    }
                }
                for other in 0..ranks {
                    if other != r {
                        prog.push(MpiOp::Recv {
                            from: other,
                            tag: it * 1000 + other as u64,
                            bytes: sample_bytes,
                        });
                    }
                }
                prog.push(MpiOp::Compute(bh_force_cycles(n, p.bodies as u64)));
                prog.push(MpiOp::Allreduce { bytes: (ranks * 64) as u64 });
            }
            prog
        })
        .collect()
}

/// The Barnes-Hut [`Workload`] (paper VI-B sizing).
pub struct BarnesHut;

const ITERS: usize = 3;

fn sized(workers: usize, scaling: Scaling, groups: usize) -> BhParams {
    let bands = (2 * workers).max(2);
    let bodies = if scaling == Scaling::Weak { bands * 4096 } else { 1 << 20 };
    BhParams { bodies, bands, groups: groups.min(bands), iters: ITERS }
}

impl Workload for BarnesHut {
    fn name(&self) -> &'static str {
        "barnes-hut"
    }

    /// Irregular force tasks skewed towards the dense octant.
    fn job_shape(&self, scale: u32) -> crate::sim::traffic::JobShape {
        let s = scale.max(1);
        crate::sim::traffic::JobShape {
            tasks: 12 * s,
            task_cycles: 1_200_000,
            fanout: 4,
            hot_pct: 60,
        }
    }

    /// The paper stops at 128 workers "due to memory constraints".
    fn valid_workers(&self, workers: usize) -> bool {
        workers <= 128
    }

    fn register(&self, reg: &mut Registry) -> TaskRef {
        register_tasks(reg)
    }

    fn params_for(&self, workers: usize, scaling: Scaling) -> Box<dyn Any> {
        Box::new(sized(workers, scaling, groups_for(workers)))
    }

    fn mpi_programs(&self, ranks: usize, scaling: Scaling) -> Vec<Vec<MpiOp>> {
        mpi_programs(&sized(ranks, scaling, 1), ranks)
    }

    fn verify(&self, world: &World) -> Result<(), String> {
        let st = app_state::<BhState>(world)?;
        let p = &st.p;
        // main + iters * (bands builds + 1 summary + bands forces)
        check_task_counts(world, 1 + (p.iters * (2 * p.bands + 1)) as u64)?;
        // Every per-iteration tree region was freed: only the root and
        // the persistent group regions remain.
        let want_regions = 1 + p.groups;
        if world.mem.n_regions() != want_regions {
            return Err(format!(
                "per-iteration regions leaked: {} regions live, expected {}",
                world.mem.n_regions(),
                want_regions
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::platform::Platform;

    #[test]
    fn iterations_create_and_destroy_regions() {
        let (reg, main) = myrmics();
        let p = BhParams { bodies: 4000, bands: 8, groups: 2, iters: 3 };
        let mut plat = Platform::build_with(PlatformConfig::hierarchical(8), reg, main, |w| {
            w.app = Some(Box::new(p));
        });
        plat.run(Some(1 << 44));
        let w = plat.world();
        // main + iters * (bands builds + 1 summary + bands forces)
        assert_eq!(w.gstats.tasks_spawned, 1 + 3 * (8 + 1 + 8));
        assert_eq!(w.gstats.tasks_completed, w.gstats.tasks_spawned);
        // All per-iteration tree regions freed (24 would leak over 3
        // iterations otherwise): only root + the 2 group regions remain.
        assert_eq!(w.mem.n_regions(), 1 + 2);
        BarnesHut.verify(w).unwrap();
    }

    #[test]
    fn final_phase_frees_nothing_extra() {
        let (reg, main) = myrmics();
        let p = BhParams { bodies: 1000, bands: 4, groups: 2, iters: 1 };
        let mut plat = Platform::build_with(PlatformConfig::flat(4), reg, main, |w| {
            w.app = Some(Box::new(p));
        });
        plat.run(Some(1 << 44));
        let w = plat.world();
        assert_eq!(w.gstats.tasks_completed, w.gstats.tasks_spawned);
        BarnesHut.verify(w).unwrap();
    }

    #[test]
    fn mpi_bh_alltoall_limits_scaling() {
        let p = BhParams { bodies: 20_000, bands: 8, groups: 2, iters: 2 };
        let cfg = PlatformConfig::flat(1);
        let t2 = crate::mpi::runner::mpi_time(mpi_programs(&p, 2), &cfg);
        let t64 = crate::mpi::runner::mpi_time(mpi_programs(&p, 64), &cfg);
        // 32x more ranks: the quadratic all-to-all keeps the speedup well
        // below linear (the paper's "does not scale well").
        let speedup = t2 as f64 / t64 as f64;
        assert!(speedup > 2.0 && speedup < 24.0, "speedup {speedup:.2}");
    }
}

//! K-Means clustering (paper VI-B, Figs 8d/8j): reductions + broadcasts.
//!
//! 3D points are grouped into `k` clusters. Each iteration: every band
//! task assigns its points to the nearest centroid and emits partial sums;
//! a hierarchical reduction (per-group, then global) recomputes centroids.
//! "We use two kinds of regions: the objects to be clustered are divided
//! into a number of regions [and] a few regions hold the temporary buffers
//! during the reductions at the end of each loop."
//!
//! The main task drives iterations with `sys_wait` on the centroid object
//! — exercising the suspend/resume path of the API.

use std::any::Any;

use crate::api::args::{ObjArg, RegionArg, Rest};
use crate::api::ctx::TaskCtx;
use crate::apps::workload::kmeans_assign_cycles;
use crate::apps::workload_api::{
    app_state, check_close, check_task_counts, groups_for, Scaling, Workload,
};
use crate::ids::{ObjectId, RegionId};
use crate::mpi::rank::MpiOp;
use crate::platform::World;
use crate::task::registry::{Registry, TaskRef};

#[derive(Clone, Debug)]
pub struct KmParams {
    pub points: usize,
    pub k: usize,
    pub iters: usize,
    /// Assign tasks per iteration (point bands).
    pub bands: usize,
    pub groups: usize,
    pub real_data: bool,
}

pub struct KmState {
    pub p: KmParams,
    /// Point-band objects.
    pub bands: Vec<ObjectId>,
    pub band_sizes: Vec<usize>,
    /// Per-band partial buffers (k * 4 floats: sum xyz + count).
    pub partials: Vec<ObjectId>,
    /// Per-group reduced buffers.
    pub group_partials: Vec<ObjectId>,
    /// Centroid object (k * 3 floats), rewritten every iteration.
    pub centroids: ObjectId,
    /// (group regions, reduction-buffer regions), kept for re-spawning.
    pub regions: Option<(Vec<RegionId>, Vec<RegionId>)>,
}

fn band_group(p: &KmParams, b: usize) -> usize {
    b * p.groups / p.bands
}

/// Deterministic point cloud: three fuzzy blobs.
pub fn gen_points(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = crate::sim::rng::Rng::new(seed);
    let mut pts = Vec::with_capacity(n * 3);
    for i in 0..n {
        let c = (i % 3) as f32 * 10.0;
        for _ in 0..3 {
            pts.push(c + rng.f64() as f32);
        }
    }
    pts
}

/// Sequential reference: one k-means iteration (returns new centroids).
pub fn kmeans_step_reference(pts: &[f32], centroids: &[f32], k: usize) -> Vec<f32> {
    let mut sums = vec![0f64; k * 3];
    let mut counts = vec![0u64; k];
    for p in pts.chunks_exact(3) {
        let mut best = 0;
        let mut best_d = f64::MAX;
        for c in 0..k {
            let d: f64 = (0..3)
                .map(|j| (p[j] as f64 - centroids[c * 3 + j] as f64).powi(2))
                .sum();
            if d < best_d {
                best_d = d;
                best = c;
            }
        }
        for j in 0..3 {
            sums[best * 3 + j] += p[j] as f64;
        }
        counts[best] += 1;
    }
    (0..k * 3)
        .map(|i| {
            let c = i / 3;
            if counts[c] == 0 {
                centroids[i]
            } else {
                (sums[i] / counts[c] as f64) as f32
            }
        })
        .collect()
}

/// Partial (sums+counts) for one band, used by the real-data task bodies.
fn assign_partial(pts: &[f32], centroids: &[f32], k: usize) -> Vec<f32> {
    let mut out = vec![0f32; k * 4];
    for p in pts.chunks_exact(3) {
        let mut best = 0;
        let mut best_d = f32::MAX;
        for c in 0..k {
            let d: f32 = (0..3).map(|j| (p[j] - centroids[c * 3 + j]).powi(2)).sum();
            if d < best_d {
                best_d = d;
                best = c;
            }
        }
        for j in 0..3 {
            out[best * 4 + j] += p[j];
        }
        out[best * 4 + 3] += 1.0;
    }
    out
}

fn merge_partials(acc: &mut [f32], part: &[f32]) {
    for (a, p) in acc.iter_mut().zip(part) {
        *a += p;
    }
}

/// The per-iteration spawner's task handles (captured by `km_main`).
#[derive(Clone, Copy)]
struct KmTasks {
    group: TaskRef,
    group_reduce: TaskRef,
    global_reduce: TaskRef,
}

/// Register the K-Means task bodies; returns the main task's handle.
fn register_tasks(reg: &mut Registry) -> TaskRef {
    // Assign — in centroids, in band, out partial, val band_idx.
    let assign = reg.register("km_assign", |ctx: &mut TaskCtx<'_>| {
        let (cent, band, partial, b): (ObjArg, ObjArg, ObjArg, usize) = ctx.args();
        let (npts, k, real) = {
            let st = ctx.world.app_ref::<KmState>();
            (st.band_sizes[b], st.p.k, st.p.real_data)
        };
        ctx.compute(kmeans_assign_cycles(npts as u64, k as u64));
        if real {
            let pts = ctx.read_f32(band);
            let cents = ctx.read_f32(cent);
            // Kernel path when the AOT shape matches, else rust fallback
            // (results are identical; see python/tests).
            let mut part: Option<Vec<f32>> = None;
            if ctx.real_compute()
                && npts == crate::runtime::shapes::KMEANS_POINTS
                && k == crate::runtime::shapes::KMEANS_K
            {
                let kern = ctx.world.kernels.as_mut().unwrap();
                if kern.available("kmeans_assign") {
                    let res = kern
                        .run_f32("kmeans_assign", &[(&pts, &[npts, 3]), (&cents, &[k, 3])])
                        .expect("kmeans_assign kernel");
                    part = Some(res[0].clone());
                }
            }
            let part = part.unwrap_or_else(|| assign_partial(&pts, &cents, k));
            ctx.write_f32(partial, &part);
        }
    });

    // Group-reduce — val group, out group buf, in the group's partials.
    let group_reduce = reg.register("km_group_reduce", |ctx: &mut TaskCtx<'_>| {
        let (_g, out, parts): (u64, ObjArg, Rest<ObjArg>) = ctx.args();
        let (k, real) = {
            let st = ctx.world.app_ref::<KmState>();
            (st.p.k, st.p.real_data)
        };
        ctx.compute((parts.len() as u64) * (k as u64) * 40);
        if real {
            let mut acc = vec![0f32; k * 4];
            for &p in parts.iter() {
                let part = ctx.read_f32(p);
                merge_partials(&mut acc, &part);
            }
            ctx.write_f32(out, &acc);
        }
    });

    // Global reduce — inout centroids, in the group buffers.
    let global_reduce = reg.register("km_global_reduce", |ctx: &mut TaskCtx<'_>| {
        let (cent, parts): (ObjArg, Rest<ObjArg>) = ctx.args();
        let (k, real) = {
            let st = ctx.world.app_ref::<KmState>();
            (st.p.k, st.p.real_data)
        };
        ctx.compute((parts.len() as u64) * (k as u64) * 40 + 2_000);
        if real {
            let mut acc = vec![0f32; k * 4];
            for &p in parts.iter() {
                let part = ctx.read_f32(p);
                merge_partials(&mut acc, &part);
            }
            let old = ctx.read_f32(cent);
            let mut cents = vec![0f32; k * 3];
            for c in 0..k {
                let n = acc[c * 4 + 3];
                for j in 0..3 {
                    cents[c * 3 + j] =
                        if n == 0.0 { old[c * 3 + j] } else { acc[c * 4 + j] / n };
                }
            }
            ctx.write_f32(cent, &cents);
        }
    });

    // Per-iteration group driver (spawns the group's assign tasks).
    let group = reg.register("km_group", move |ctx: &mut TaskCtx<'_>| {
        let (_group_reg, g, _cent_nt, _reduce_reg): (RegionArg, usize, ObjArg, RegionArg) =
            ctx.args();
        let st = ctx.world.app_ref::<KmState>();
        let p = st.p.clone();
        let cent = st.centroids;
        let plan: Vec<(ObjectId, ObjectId, usize)> = (0..p.bands)
            .filter(|&b| band_group(&p, b) == g)
            .map(|b| (st.bands[b], st.partials[b], b))
            .collect();
        for (band, partial, b) in plan {
            ctx.spawn_task(assign)
                .obj_in(cent)
                .obj_in(band)
                .obj_out(partial)
                .val(b as u64)
                .submit();
        }
    });

    let tasks = KmTasks { group, group_reduce, global_reduce };

    // Main — setup, then per iteration: group drivers, group reduces, one
    // global reduce; sys_wait on the centroids between iterations (main
    // re-reads them to drive the next phase).
    reg.register("km_main", move |ctx: &mut TaskCtx<'_>| {
        let phase = ctx.phase() as usize;
        if phase == 0 {
            let p = ctx.world.app_ref::<KmParams>().clone();
            assert!(p.groups <= p.bands);
            let mut group_regions = Vec::new();
            let mut reduce_regions = Vec::new();
            for _ in 0..p.groups {
                group_regions.push(ctx.ralloc(RegionId::ROOT, 1));
                reduce_regions.push(ctx.ralloc(RegionId::ROOT, 1));
            }
            let mut bands = Vec::new();
            let mut partials = Vec::new();
            let mut band_sizes = Vec::new();
            for b in 0..p.bands {
                let g = band_group(&p, b);
                let n0 = b * p.points / p.bands;
                let n1 = (b + 1) * p.points / p.bands;
                band_sizes.push(n1 - n0);
                let br = ctx.ralloc(group_regions[g], 2);
                bands.push(ctx.alloc(((n1 - n0) * 12) as u64, br));
                partials.push(ctx.alloc((p.k * 16) as u64, reduce_regions[g]));
            }
            let mut group_partials = Vec::new();
            for g in 0..p.groups {
                group_partials.push(ctx.alloc((p.k * 16) as u64, reduce_regions[g]));
            }
            let centroids = ctx.alloc((p.k * 12) as u64, RegionId::ROOT);
            if p.real_data {
                let pts = gen_points(p.points, 17);
                for b in 0..p.bands {
                    let n0 = b * p.points / p.bands;
                    let n1 = (b + 1) * p.points / p.bands;
                    ctx.write_f32(bands[b], &pts[n0 * 3..n1 * 3]);
                }
                // Initial centroids: first k points.
                ctx.write_f32(centroids, &pts[..p.k * 3]);
            }
            let st = KmState {
                p: p.clone(),
                bands,
                band_sizes,
                partials,
                group_partials,
                centroids,
                regions: None,
            };
            ctx.world.app = Some(Box::new(st));
            // Stash the region handles for the spawner below.
            let regions = (group_regions, reduce_regions);
            spawn_iteration(ctx, &regions, tasks);
            ctx.world.app_mut::<KmState>().regions = Some(regions);
            let centroids = ctx.world.app_ref::<KmState>().centroids;
            ctx.wait_on().obj_inout(centroids).wait();
            return;
        }
        let iters = ctx.world.app_ref::<KmState>().p.iters;
        if phase < iters {
            let regions = ctx.world.app_ref::<KmState>().regions.clone().unwrap();
            spawn_iteration(ctx, &regions, tasks);
            let centroids = ctx.world.app_ref::<KmState>().centroids;
            ctx.wait_on().obj_inout(centroids).wait();
        }
    })
}

/// Build the Myrmics K-Means app. Returns (registry, main task).
pub fn myrmics() -> (Registry, TaskRef) {
    let mut reg = Registry::new();
    let main = register_tasks(&mut reg);
    (reg, main)
}

type Regions = (Vec<RegionId>, Vec<RegionId>);

fn spawn_iteration(ctx: &mut TaskCtx<'_>, regions: &Regions, tasks: KmTasks) {
    let (group_regions, reduce_regions) = regions;
    let (p, centroids, partials, group_partials) = {
        let st = ctx.world.app_ref::<KmState>();
        (st.p.clone(), st.centroids, st.partials.clone(), st.group_partials.clone())
    };
    // Group drivers spawn the assign tasks near their data.
    for g in 0..p.groups {
        ctx.spawn_task(tasks.group)
            .reg_inout(group_regions[g])
            .notransfer()
            .val(g as u64)
            .obj_in(centroids)
            .notransfer()
            .reg_inout(reduce_regions[g])
            .notransfer()
            .submit();
    }
    // Per-group reductions.
    for g in 0..p.groups {
        let mut spawn = ctx
            .spawn_task(tasks.group_reduce)
            .val(g as u64)
            .obj_out(group_partials[g]);
        for b in 0..p.bands {
            if band_group(&p, b) == g {
                spawn = spawn.obj_in(partials[b]);
            }
        }
        spawn.submit();
    }
    // Global reduction into the centroids.
    let mut spawn = ctx.spawn_task(tasks.global_reduce).obj_inout(centroids);
    for g in 0..p.groups {
        spawn = spawn.obj_in(group_partials[g]);
    }
    spawn.submit();
}

/// MPI baseline: assign + allreduce of (sums, counts) per iteration.
pub fn mpi_programs(p: &KmParams, ranks: usize) -> Vec<Vec<MpiOp>> {
    (0..ranks)
        .map(|r| {
            let npts = ((r + 1) * p.points / ranks - r * p.points / ranks) as u64;
            let mut prog = Vec::new();
            for _ in 0..p.iters {
                prog.push(MpiOp::Compute(kmeans_assign_cycles(npts, p.k as u64)));
                prog.push(MpiOp::Allreduce { bytes: (p.k * 16) as u64 });
            }
            prog
        })
        .collect()
}

/// The K-Means [`Workload`] (paper VI-B sizing).
pub struct Kmeans;

const ITERS: usize = 4;

fn sized(workers: usize, scaling: Scaling, groups: usize) -> KmParams {
    let bands = (2 * workers).max(2);
    let points = if scaling == Scaling::Weak { bands * 8192 } else { 1 << 23 };
    KmParams { points, k: 16, iters: ITERS, bands, groups: groups.min(bands), real_data: false }
}

impl Workload for Kmeans {
    fn name(&self) -> &'static str {
        "kmeans"
    }

    /// Map phase plus a mild reduction hot spot on the centers region.
    fn job_shape(&self, scale: u32) -> crate::sim::traffic::JobShape {
        let s = scale.max(1);
        crate::sim::traffic::JobShape {
            tasks: 10 * s,
            task_cycles: 900_000,
            fanout: 4,
            hot_pct: 30,
        }
    }

    fn register(&self, reg: &mut Registry) -> TaskRef {
        register_tasks(reg)
    }

    fn params_for(&self, workers: usize, scaling: Scaling) -> Box<dyn Any> {
        Box::new(sized(workers, scaling, groups_for(workers)))
    }

    fn mpi_programs(&self, ranks: usize, scaling: Scaling) -> Vec<Vec<MpiOp>> {
        mpi_programs(&sized(ranks, scaling, 1), ranks)
    }

    fn verify(&self, world: &World) -> Result<(), String> {
        let st = app_state::<KmState>(world)?;
        let p = &st.p;
        // main + iters * (group drivers + assigns + group reduces + 1
        // global reduce)
        check_task_counts(world, 1 + (p.iters * (2 * p.groups + p.bands + 1)) as u64)?;
        if p.real_data {
            let got = world
                .store
                .get_f32(st.centroids)
                .ok_or_else(|| "centroids never written".to_string())?;
            let pts = gen_points(p.points, 17);
            let mut want = pts[..p.k * 3].to_vec();
            for _ in 0..p.iters {
                want = kmeans_step_reference(&pts, &want, p.k);
            }
            check_close(&got, &want, 1e-3, "centroid")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::platform::Platform;

    fn params(real: bool) -> KmParams {
        KmParams { points: 600, k: 4, iters: 3, bands: 6, groups: 2, real_data: real }
    }

    #[test]
    fn completes_with_wait_phases() {
        let (reg, main) = myrmics();
        let mut plat = Platform::build_with(PlatformConfig::hierarchical(8), reg, main, |w| {
            w.app = Some(Box::new(params(false)));
        });
        plat.run(Some(1 << 44));
        let w = plat.world();
        // main + iters * (groups drivers + bands assigns + groups reduces + 1 global)
        let expect = 1 + 3 * (2 + 6 + 2 + 1);
        assert_eq!(w.gstats.tasks_spawned, expect as u64);
        assert_eq!(w.gstats.tasks_completed, w.gstats.tasks_spawned);
        Kmeans.verify(w).unwrap();
    }

    #[test]
    fn real_data_matches_sequential_reference() {
        let (reg, main) = myrmics();
        let p = params(true);
        let mut plat = Platform::build_with(PlatformConfig::flat(4), reg, main, |w| {
            w.app = Some(Box::new(p.clone()));
        });
        plat.run(Some(1 << 44));
        let st = plat.world().app_ref::<KmState>();
        let got = plat.world().store.get_f32(st.centroids).unwrap();
        // Reference: run the same iterations sequentially.
        let pts = gen_points(p.points, 17);
        let mut want = pts[..p.k * 3].to_vec();
        for _ in 0..p.iters {
            want = kmeans_step_reference(&pts, &want, p.k);
        }
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-3, "centroid {i}: got {g}, want {w}");
        }
        Kmeans.verify(plat.world()).unwrap();
    }

    #[test]
    fn mpi_kmeans_runs() {
        let p = params(false);
        let t1 = crate::mpi::runner::mpi_time(mpi_programs(&p, 1), &PlatformConfig::flat(1));
        let t4 = crate::mpi::runner::mpi_time(mpi_programs(&p, 4), &PlatformConfig::flat(1));
        assert!(t1 > t4);
    }
}

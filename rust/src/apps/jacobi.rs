//! Jacobi iteration (paper VI-B, Figs 8a/8g): nearest-neighbour stencil.
//!
//! An `n x n` table with a fixed border; each iteration replaces every
//! cell with the mean of its four neighbours. Double-buffered (A/B), as
//! the paper's "nontrivial, optimized implementations" are.
//!
//! **Myrmics decomposition.** The table is split into `bands` row bands,
//! grouped under `groups` super-regions ("we use regions to split the
//! table into groups of rows"). Interior rows live in per-band regions
//! under the group region; the halo *edge* rows live in separate per-group
//! **halo regions, one per buffer parity** (`H_g^A`, `H_g^B`). That split
//! is what keeps groups of the same iteration parallel: a group task of
//! parity X holds the X-halos of its neighbours `in` (read-compatible with
//! the neighbours' own X reads) and only its own Y-halo `inout`, so
//! cross-group readers never queue behind a region-wide write hold. A
//! per-iteration *group task* (all arguments NOTRANSFER — it only spawns)
//! spawns one *band task* per band with fine-grained object arguments;
//! iterations chain through the dependency queues in program order.
//!
//! **MPI baseline.** Classic halo exchange: each rank sends its edge rows
//! to both neighbours, receives theirs, computes its band.

use std::any::Any;

use crate::api::args::{ObjArg, OptObj, RegionArg, Rest};
use crate::api::ctx::TaskCtx;
use crate::apps::workload::jacobi_cycles;
use crate::apps::workload_api::{
    app_state, check_close, check_task_counts, groups_for, Scaling, Workload,
};
use crate::ids::{ObjectId, RegionId};
use crate::mpi::rank::MpiOp;
use crate::platform::World;
use crate::task::registry::{Registry, TaskRef};

#[derive(Clone, Debug)]
pub struct JacobiParams {
    /// Table dimension (n x n cells, f32).
    pub n: usize,
    pub iters: usize,
    /// Row bands (= band tasks per iteration).
    pub bands: usize,
    /// Super-regions (hierarchical decomposition width).
    pub groups: usize,
    /// Compute the real stencil on stored data (vs modeled cycles only).
    pub real_data: bool,
}

impl JacobiParams {
    pub fn modeled(n: usize, iters: usize, bands: usize, groups: usize) -> Self {
        JacobiParams { n, iters, bands, groups, real_data: false }
    }
}

/// Per-band objects, one set per buffer (A = even iterations' read side).
#[derive(Clone, Copy, Debug)]
pub struct BandObjs {
    pub top: ObjectId,
    pub interior: ObjectId,
    pub bot: ObjectId,
}

pub struct JacobiState {
    pub p: JacobiParams,
    /// [buffer][band]
    pub bufs: [Vec<BandObjs>; 2],
    pub group_regions: Vec<RegionId>,
    /// [parity][group]: halo regions holding the edge-row objects.
    pub halo_regions: [Vec<RegionId>; 2],
    /// rows per band (last band may be larger).
    pub rows: Vec<usize>,
}

impl JacobiState {
    fn band_group(&self, b: usize) -> usize {
        b * self.p.groups / self.p.bands
    }

    /// Bands belonging to group g (contiguous).
    fn group_bands(&self, g: usize) -> Vec<usize> {
        (0..self.p.bands).filter(|&b| self.band_group(b) == g).collect()
    }
}

/// Sequential reference for `iters` Jacobi sweeps (fixed border).
pub fn jacobi_reference(n: usize, iters: usize, init: &[f32]) -> Vec<f32> {
    let mut a = init.to_vec();
    let mut b = init.to_vec();
    for _ in 0..iters {
        for i in 1..n - 1 {
            for j in 1..n - 1 {
                b[i * n + j] =
                    0.25 * (a[(i - 1) * n + j] + a[(i + 1) * n + j] + a[i * n + j - 1] + a[i * n + j + 1]);
            }
        }
        std::mem::swap(&mut a, &mut b);
    }
    a
}

/// Deterministic initial table: border fixed at 1.0, interior 0.
pub fn jacobi_init(n: usize) -> Vec<f32> {
    let mut t = vec![0f32; n * n];
    for i in 0..n {
        t[i] = 1.0;
        t[(n - 1) * n + i] = 1.0;
        t[i * n] = 1.0;
        t[i * n + n - 1] = 1.0;
    }
    t
}

/// Register the Jacobi task bodies; returns the main task's handle.
///
/// Band-task wire layout (what the typed tuple below lowers from/to):
/// `in` X top/interior/bot, `out` Y top/interior/bot, band index, the
/// upstream neighbour's X bottom edge (SAFE 0 for band 0), and — only if
/// a downstream neighbour exists — its X top edge.
fn register_tasks(reg: &mut Registry) -> TaskRef {
    let band_task = reg.register("jacobi_band", |ctx: &mut TaskCtx<'_>| {
        let (x_top, x_int, x_bot, y_top, y_int, y_bot, b, up, dn): (
            ObjArg,
            ObjArg,
            ObjArg,
            ObjArg,
            ObjArg,
            ObjArg,
            usize,
            OptObj,
            OptObj,
        ) = ctx.args();
        let (rows, n, real) = {
            let st = ctx.world.app_ref::<JacobiState>();
            (st.rows[b], st.p.n, st.p.real_data)
        };
        ctx.compute(jacobi_cycles(rows as u64, n as u64));
        if !real {
            return;
        }
        // Assemble the local band plus halo rows, run the stencil, write Y.
        let mut rows_in: Vec<f32> = Vec::with_capacity((rows + 2) * n);
        let halo_up = match up.get() {
            Some(o) => ctx.read_f32(o),
            None => vec![0.0; n], // unused: band 0's top edge is the fixed border
        };
        rows_in.extend_from_slice(&halo_up);
        for o in [x_top, x_int, x_bot] {
            rows_in.extend(ctx.read_f32(o));
        }
        let halo_dn = match dn.get() {
            Some(o) => ctx.read_f32(o),
            None => vec![0.0; n],
        };
        rows_in.extend_from_slice(&halo_dn);
        debug_assert_eq!(rows_in.len(), (rows + 2) * n);

        let first_band = up.is_none();
        let last_band = dn.is_none();
        let mut out = vec![0f32; rows * n];
        // Kernel path (PJRT, L1 Pallas) or pure-rust fallback.
        let used_kernel = if ctx.real_compute() {
            let shape_in = [rows + 2, n];
            let k = ctx.world.kernels.as_mut().unwrap();
            if k.available("jacobi_band") && (rows + 2, n) == crate::runtime::shapes::JACOBI_IN {
                let res = k
                    .run_f32("jacobi_band", &[(&rows_in, &shape_in)])
                    .expect("jacobi_band kernel");
                out.copy_from_slice(&res[0]);
                true
            } else {
                false
            }
        } else {
            false
        };
        if !used_kernel {
            for i in 0..rows {
                for j in 0..n {
                    let g = |r: usize, c: usize| rows_in[r * n + c];
                    out[i * n + j] = 0.25 * (g(i, j) + g(i + 2, j) + g(i + 1, j.saturating_sub(1)) + g(i + 1, (j + 1).min(n - 1)));
                }
            }
        }
        // Fixed border: restore border cells from the input.
        for i in 0..rows {
            out[i * n] = rows_in[(i + 1) * n];
            out[i * n + n - 1] = rows_in[(i + 1) * n + n - 1];
            let global_first = first_band && i == 0;
            let global_last = last_band && i == rows - 1;
            if global_first || global_last {
                for j in 0..n {
                    out[i * n + j] = rows_in[(i + 1) * n + j];
                }
            }
        }
        ctx.write_f32(y_top, &out[..n]);
        ctx.write_f32(y_int, &out[n..(rows - 1) * n]);
        ctx.write_f32(y_bot, &out[(rows - 1) * n..]);
    });

    let group_task = reg.register("jacobi_group", move |ctx: &mut TaskCtx<'_>| {
        let (_group_reg, g, parity, _halo_y, _halo_x, _cross): (
            RegionArg,
            usize,
            usize,
            RegionArg,
            RegionArg,
            Rest<ObjArg>,
        ) = ctx.args();
        let (bands, n_bands) = {
            let st = ctx.world.app_ref::<JacobiState>();
            (st.group_bands(g), st.p.bands)
        };
        for b in bands {
            let (x, y, up, dn) = {
                let st = ctx.world.app_ref::<JacobiState>();
                let up = if b > 0 { Some(st.bufs[parity % 2][b - 1].bot) } else { None };
                let dn =
                    if b + 1 < n_bands { Some(st.bufs[parity % 2][b + 1].top) } else { None };
                (st.bufs[parity % 2][b], st.bufs[(parity + 1) % 2][b], up, dn)
            };
            let mut spawn = ctx
                .spawn_task(band_task)
                .obj_in(x.top)
                .obj_in(x.interior)
                .obj_in(x.bot)
                .obj_out(y.top)
                .obj_out(y.interior)
                .obj_out(y.bot)
                .val(b as u64)
                .obj_opt(up);
            if let Some(o) = dn {
                spawn = spawn.obj_in(o);
            }
            spawn.submit();
        }
    });

    reg.register("jacobi_main", move |ctx: &mut TaskCtx<'_>| {
        let p = ctx.world.app_ref::<JacobiParams>().clone();
        assert!(p.bands * 3 <= p.n, "bands too fine for n");
        assert!(p.groups <= p.bands);
        // Regions: one per group (level 1), one per band (level 2).
        let mut group_regions = Vec::with_capacity(p.groups);
        for _ in 0..p.groups {
            group_regions.push(ctx.ralloc(RegionId::ROOT, 1));
        }
        let mut halo_regions: [Vec<RegionId>; 2] = [Vec::new(), Vec::new()];
        for _g in 0..p.groups {
            halo_regions[0].push(ctx.ralloc(RegionId::ROOT, 1));
            halo_regions[1].push(ctx.ralloc(RegionId::ROOT, 1));
        }
        let mut rows_v = Vec::with_capacity(p.bands);
        let mut bufs: [Vec<BandObjs>; 2] = [Vec::new(), Vec::new()];
        for b in 0..p.bands {
            let g = b * p.groups / p.bands;
            let br = ctx.ralloc(group_regions[g], 2);
            let r0 = b * p.n / p.bands;
            let r1 = (b + 1) * p.n / p.bands;
            let rows = r1 - r0;
            rows_v.push(rows);
            let row_bytes = (p.n * 4) as u64;
            for side in 0..2 {
                let edges = ctx.balloc(row_bytes, halo_regions[side][g], 2); // top + bot
                let interior = ctx.alloc(row_bytes * (rows as u64 - 2), br);
                bufs[side].push(BandObjs { top: edges[0], interior, bot: edges[1] });
            }
        }
        let st = JacobiState {
            p: p.clone(),
            bufs,
            group_regions: group_regions.clone(),
            halo_regions,
            rows: rows_v.clone(),
        };
        // Seed real data into buffer A (side 0).
        if p.real_data {
            let init = jacobi_init(p.n);
            for b in 0..p.bands {
                let r0 = b * p.n / p.bands;
                let rows = st.rows[b];
                let band = &init[r0 * p.n..(r0 + rows) * p.n];
                let o = st.bufs[0][b];
                ctx.write_f32(o.top, &band[..p.n]);
                ctx.write_f32(o.interior, &band[p.n..(rows - 1) * p.n]);
                ctx.write_f32(o.bot, &band[(rows - 1) * p.n..]);
            }
        }
        ctx.world.app = Some(Box::new(st));
        // Spawn all iterations in program order; the dependency queues
        // chain them correctly.
        for it in 0..p.iters {
            let parity = it % 2;
            for g in 0..p.groups {
                let (halo_y, halo_x, cross_up, cross_dn) = {
                    let st = ctx.world.app_ref::<JacobiState>();
                    let gb = st.group_bands(g);
                    let cross_up = match gb.first() {
                        Some(&first) if first > 0 => Some(st.bufs[parity][first - 1].bot),
                        _ => None,
                    };
                    let cross_dn = match gb.last() {
                        Some(&last) if last + 1 < p.bands => Some(st.bufs[parity][last + 1].top),
                        _ => None,
                    };
                    (
                        st.halo_regions[(parity + 1) % 2][g],
                        st.halo_regions[parity][g],
                        cross_up,
                        cross_dn,
                    )
                };
                let mut spawn = ctx
                    .spawn_task(group_task)
                    .reg_inout(group_regions[g])
                    .notransfer()
                    .val(g as u64)
                    .val(parity as u64)
                    // Children write the Y-parity halo of this group and
                    // read the X-parity one.
                    .reg_inout(halo_y)
                    .notransfer()
                    .reg_in(halo_x)
                    .notransfer();
                // Cross-group halo edges this group's bands will read.
                if let Some(o) = cross_up {
                    spawn = spawn.obj_in(o).notransfer();
                }
                if let Some(o) = cross_dn {
                    spawn = spawn.obj_in(o).notransfer();
                }
                spawn.submit();
            }
        }
    })
}

/// Build the Myrmics Jacobi app. Returns (registry, main task).
pub fn myrmics() -> (Registry, TaskRef) {
    let mut reg = Registry::new();
    let main = register_tasks(&mut reg);
    (reg, main)
}

/// Read the final table (buffer parity depends on iteration count) from a
/// finished real-data run.
pub fn read_result(world: &crate::platform::World) -> Vec<f32> {
    let st = world.app_ref::<JacobiState>();
    let side = st.p.iters % 2;
    let n = st.p.n;
    let mut out = Vec::with_capacity(n * n);
    for b in 0..st.p.bands {
        let o = st.bufs[side][b];
        out.extend(world.store.get_f32(o.top).unwrap());
        out.extend(world.store.get_f32(o.interior).unwrap());
        out.extend(world.store.get_f32(o.bot).unwrap());
    }
    out
}

/// MPI baseline: halo exchange + compute, one rank per core.
pub fn mpi_programs(p: &JacobiParams, ranks: usize) -> Vec<Vec<MpiOp>> {
    let row_bytes = (p.n * 4) as u64;
    (0..ranks)
        .map(|r| {
            let rows = ((r + 1) * p.n / ranks - r * p.n / ranks) as u64;
            let mut prog = Vec::new();
            for it in 0..p.iters as u64 {
                if r > 0 {
                    prog.push(MpiOp::Send { to: r - 1, tag: it * 2, bytes: row_bytes });
                }
                if r + 1 < ranks {
                    prog.push(MpiOp::Send { to: r + 1, tag: it * 2 + 1, bytes: row_bytes });
                }
                if r + 1 < ranks {
                    prog.push(MpiOp::Recv { from: r + 1, tag: it * 2, bytes: row_bytes });
                }
                if r > 0 {
                    prog.push(MpiOp::Recv { from: r - 1, tag: it * 2 + 1, bytes: row_bytes });
                }
                prog.push(MpiOp::Compute(jacobi_cycles(rows, p.n as u64)));
            }
            prog
        })
        .collect()
}

/// The Jacobi [`Workload`] (paper VI-B sizing).
pub struct Jacobi;

const ITERS: usize = 6;

fn sized(workers: usize, scaling: Scaling) -> JacobiParams {
    let bands = (2 * workers).max(2);
    let n = if scaling == Scaling::Weak { bands * 10 } else { 8192.max(bands * 3) };
    JacobiParams::modeled(n, ITERS, bands, groups_for(workers).min(bands))
}

impl Workload for Jacobi {
    fn name(&self) -> &'static str {
        "jacobi"
    }

    /// Band-parallel stencil: moderate grain, one subregion per band
    /// group, no hot spot.
    fn job_shape(&self, scale: u32) -> crate::sim::traffic::JobShape {
        let s = scale.max(1);
        crate::sim::traffic::JobShape { tasks: 12 * s, task_cycles: 800_000, fanout: 4, hot_pct: 0 }
    }

    fn register(&self, reg: &mut Registry) -> TaskRef {
        register_tasks(reg)
    }

    fn params_for(&self, workers: usize, scaling: Scaling) -> Box<dyn Any> {
        Box::new(sized(workers, scaling))
    }

    fn mpi_programs(&self, ranks: usize, scaling: Scaling) -> Vec<Vec<MpiOp>> {
        let mut p = sized(ranks, scaling);
        p.groups = 1;
        mpi_programs(&p, ranks)
    }

    fn verify(&self, world: &World) -> Result<(), String> {
        let st = app_state::<JacobiState>(world)?;
        let p = &st.p;
        check_task_counts(world, 1 + (p.iters * (p.groups + p.bands)) as u64)?;
        if p.real_data {
            let got = read_result(world);
            let want = jacobi_reference(p.n, p.iters, &jacobi_init(p.n));
            check_close(&got, &want, 1e-4, "cell")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::mpi::runner::run_mpi;
    use crate::platform::Platform;

    #[test]
    fn myrmics_modeled_completes() {
        let (reg, main) = myrmics();
        let p = JacobiParams::modeled(64, 4, 8, 2);
        let mut plat = Platform::build_with(PlatformConfig::hierarchical(8), reg, main, |w| {
            w.app = Some(Box::new(p));
        });
        plat.run(Some(1 << 44));
        let w = plat.world();
        // 1 main + 4 iters * (2 groups + 8 bands)
        assert_eq!(w.gstats.tasks_spawned, 1 + 4 * (2 + 8));
        assert_eq!(w.gstats.tasks_completed, w.gstats.tasks_spawned);
    }

    #[test]
    fn myrmics_real_data_matches_reference() {
        let (reg, main) = myrmics();
        let n = 32;
        let iters = 3;
        let p = JacobiParams { n, iters, bands: 4, groups: 2, real_data: true };
        let mut plat = Platform::build_with(PlatformConfig::flat(4), reg, main, |w| {
            w.app = Some(Box::new(p));
        });
        plat.run(Some(1 << 44));
        let got = read_result(plat.world());
        let want = jacobi_reference(n, iters, &jacobi_init(n));
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-5, "cell {i}: got {g}, want {w}");
        }
    }

    #[test]
    fn bands_of_same_iteration_overlap_in_time() {
        let (reg, main) = myrmics();
        let p = JacobiParams::modeled(128, 2, 8, 2);
        let mut plat = Platform::build_with(PlatformConfig::flat(8), reg, main, |w| {
            w.app = Some(Box::new(p));
        });
        plat.run(Some(1 << 44));
        // Find band tasks (by registered name) of iteration 0 and check
        // some overlap.
        let band_fn = (0..plat.eng.registry.len())
            .find(|&i| plat.eng.registry.name(i) == "jacobi_band")
            .unwrap();
        let w = plat.world();
        let spans: Vec<(u64, u64)> = w
            .tasks
            .iter()
            .filter(|e| e.desc.func == band_fn)
            .take(8)
            .map(|e| (e.started_at, e.done_at))
            .collect();
        let overlaps = spans
            .iter()
            .enumerate()
            .any(|(i, a)| spans.iter().skip(i + 1).any(|b| a.0 < b.1 && b.0 < a.1));
        assert!(overlaps, "bands should run in parallel: {spans:?}");
    }

    #[test]
    fn mpi_jacobi_scales() {
        let p = JacobiParams::modeled(256, 4, 16, 4);
        let cfg = PlatformConfig::flat(1);
        let t1 = run_mpi(mpi_programs(&p, 1), &cfg).sim.now;
        let t8 = run_mpi(mpi_programs(&p, 8), &cfg).sim.now;
        let speedup = t1 as f64 / t8 as f64;
        assert!(speedup > 5.0, "MPI jacobi speedup on 8 ranks: {speedup:.2}");
    }
}

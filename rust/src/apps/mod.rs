//! Benchmark applications (paper VI): the six kernels/applications in
//! both Myrmics (region-decomposed, hierarchical tasks) and MPI
//! (hand-tuned message passing) variants, plus the synthetic
//! microbenchmarks, over shared compute-cost models.
pub mod barnes_hut;
pub mod bitonic;
pub mod jacobi;
pub mod jobs;
pub mod kmeans;
pub mod matmul;
pub mod raytrace;
pub mod skew;
pub mod synthetic;
pub mod workload;
pub mod workload_api;

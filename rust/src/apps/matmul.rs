//! Dense matrix multiplication (paper VI-B, Figs 8e/8k): SUMMA-style
//! phases with communication bursts.
//!
//! `n x n` matrices on a `p x p` block grid (the paper notes the algorithm
//! "depends on the number of cores being a power of 4", i.e. square
//! grids). In phase `k`, every task `(i, j)` accumulates `A[i][k] *
//! B[k][j]` into `C[i][j]` — so each `A[i][k]` / `B[k][j]` block is read
//! by a whole row/column of tasks at once: the "communication bursts" and
//! temporary hot spots the paper describes.
//!
//! **Regions**: per grid-row regions `R_i` (A and C blocks) and `T_k`
//! (B row blocks); a per-(row, phase) group task holds `R_i` inout and
//! `T_k` in (both NOTRANSFER) and spawns the row's block tasks.

use std::any::Any;

use crate::api::args::{ObjArg, RegionArg};
use crate::api::ctx::TaskCtx;
use crate::apps::workload::matmul_cycles;
use crate::apps::workload_api::{
    app_state, check_close, check_task_counts, Scaling, Workload,
};
use crate::ids::{ObjectId, RegionId};
use crate::mpi::rank::MpiOp;
use crate::platform::World;
use crate::task::registry::{Registry, TaskRef};

#[derive(Clone, Debug)]
pub struct MatmulParams {
    /// Matrix dimension; `p` must divide `n`.
    pub n: usize,
    /// Grid dimension (p x p blocks; p*p tasks per phase).
    pub p: usize,
    pub real_data: bool,
}

pub struct MmState {
    pub p: MatmulParams,
    /// Block objects, indexed [i][j].
    pub a: Vec<Vec<ObjectId>>,
    pub b: Vec<Vec<ObjectId>>,
    pub c: Vec<Vec<ObjectId>>,
    pub row_regions: Vec<RegionId>,
    pub brow_regions: Vec<RegionId>,
}

/// Deterministic test matrices.
pub fn gen_matrix(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = crate::sim::rng::Rng::new(seed);
    (0..n * n).map(|_| (rng.f64() as f32) - 0.5).collect()
}

pub fn matmul_reference(a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    let mut c = vec![0f32; n * n];
    for i in 0..n {
        for k in 0..n {
            let aik = a[i * n + k];
            for j in 0..n {
                c[i * n + j] += aik * b[k * n + j];
            }
        }
    }
    c
}

fn block_of(m: &[f32], n: usize, s: usize, bi: usize, bj: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(s * s);
    for r in 0..s {
        let base = (bi * s + r) * n + bj * s;
        out.extend_from_slice(&m[base..base + s]);
    }
    out
}

/// Register the matmul task bodies; returns the main task's handle.
fn register_tasks(reg: &mut Registry) -> TaskRef {
    // Block task — inout C_ij, in A_ik, in B_kj, val s.
    let block = reg.register("mm_block", |ctx: &mut TaskCtx<'_>| {
        let (oc, oa, ob, s): (ObjArg, ObjArg, ObjArg, usize) = ctx.args();
        let real = ctx.world.app_ref::<MmState>().p.real_data;
        ctx.compute(matmul_cycles(s as u64, s as u64, s as u64));
        if real {
            let a = ctx.read_f32(oa);
            let b = ctx.read_f32(ob);
            let mut c = ctx.read_f32(oc);
            let mut done = false;
            if ctx.real_compute() && (s, s, s) == crate::runtime::shapes::MATMUL_TILE {
                let kern = ctx.world.kernels.as_mut().unwrap();
                if kern.available("matmul_tile") {
                    let res = kern
                        .run_f32(
                            "matmul_tile",
                            &[(&a, &[s, s]), (&b, &[s, s]), (&c, &[s, s])],
                        )
                        .expect("matmul_tile kernel");
                    c.copy_from_slice(&res[0]);
                    done = true;
                }
            }
            if !done {
                for i in 0..s {
                    for k in 0..s {
                        let aik = a[i * s + k];
                        for j in 0..s {
                            c[i * s + j] += aik * b[k * s + j];
                        }
                    }
                }
            }
            ctx.write_f32(oc, &c);
        }
    });

    // Per-(row, phase) driver.
    let row_phase = reg.register("mm_row_phase", move |ctx: &mut TaskCtx<'_>| {
        let (_row_reg, _brow_reg, i, k): (RegionArg, RegionArg, usize, usize) = ctx.args();
        let st = ctx.world.app_ref::<MmState>();
        let p = st.p.p;
        let s = (st.p.n / p) as u64;
        let plan: Vec<(ObjectId, ObjectId, ObjectId)> =
            (0..p).map(|j| (st.c[i][j], st.a[i][k], st.b[k][j])).collect();
        for (c, a, b) in plan {
            ctx.spawn_task(block)
                .obj_inout(c)
                .obj_in(a)
                .obj_in(b)
                .val(s)
                .submit();
        }
    });

    reg.register("mm_main", move |ctx: &mut TaskCtx<'_>| {
        let prm = ctx.world.app_ref::<MatmulParams>().clone();
        let p = prm.p;
        assert_eq!(prm.n % p, 0);
        let s = prm.n / p;
        let blk_bytes = (s * s * 4) as u64;
        let mut row_regions = Vec::new();
        let mut brow_regions = Vec::new();
        for _ in 0..p {
            row_regions.push(ctx.ralloc(RegionId::ROOT, 1));
            brow_regions.push(ctx.ralloc(RegionId::ROOT, 1));
        }
        let mut a = vec![Vec::new(); p];
        let mut b = vec![Vec::new(); p];
        let mut c = vec![Vec::new(); p];
        for i in 0..p {
            for _j in 0..p {
                a[i].push(ctx.alloc(blk_bytes, row_regions[i]));
                c[i].push(ctx.alloc(blk_bytes, row_regions[i]));
                b[i].push(ctx.alloc(blk_bytes, brow_regions[i]));
            }
        }
        if prm.real_data {
            let am = gen_matrix(prm.n, 5);
            let bm = gen_matrix(prm.n, 6);
            let zeros = vec![0f32; s * s];
            for i in 0..p {
                for j in 0..p {
                    ctx.write_f32(a[i][j], &block_of(&am, prm.n, s, i, j));
                    ctx.write_f32(b[i][j], &block_of(&bm, prm.n, s, i, j));
                    ctx.write_f32(c[i][j], &zeros);
                }
            }
        }
        ctx.world.app = Some(Box::new(MmState {
            p: prm.clone(),
            a,
            b,
            c,
            row_regions: row_regions.clone(),
            brow_regions: brow_regions.clone(),
        }));
        for k in 0..p {
            for i in 0..p {
                ctx.spawn_task(row_phase)
                    .reg_inout(row_regions[i])
                    .notransfer()
                    .reg_in(brow_regions[k])
                    .notransfer()
                    .val(i as u64)
                    .val(k as u64)
                    .submit();
            }
        }
    })
}

/// Build the Myrmics matmul. Returns (registry, main task).
pub fn myrmics() -> (Registry, TaskRef) {
    let mut reg = Registry::new();
    let main = register_tasks(&mut reg);
    (reg, main)
}

/// Read back the result matrix from a finished real-data run.
pub fn read_result(world: &crate::platform::World) -> Vec<f32> {
    let st = world.app_ref::<MmState>();
    let p = st.p.p;
    let n = st.p.n;
    let s = n / p;
    let mut out = vec![0f32; n * n];
    for i in 0..p {
        for j in 0..p {
            let blk = world.store.get_f32(st.c[i][j]).unwrap();
            for r in 0..s {
                let base = (i * s + r) * n + j * s;
                out[base..base + s].copy_from_slice(&blk[r * s..(r + 1) * s]);
            }
        }
    }
    out
}

/// MPI baseline (SUMMA): per phase, the A/B block owners send to their
/// row/column peers; everyone computes the partial product.
pub fn mpi_programs(prm: &MatmulParams, ranks: usize) -> Vec<Vec<MpiOp>> {
    let p = (ranks as f64).sqrt().round() as usize;
    assert_eq!(p * p, ranks, "matmul needs a square (power-of-4) rank count");
    let s = (prm.n / p) as u64;
    let blk_bytes = s * s * 4;
    let rank_of = |i: usize, j: usize| i * p + j;
    (0..ranks)
        .map(|r| {
            let (i, j) = (r / p, r % p);
            let mut prog = Vec::new();
            for k in 0..p {
                // A[i][k] broadcast along row i.
                if j == k {
                    for jj in 0..p {
                        if jj != j {
                            prog.push(MpiOp::Send {
                                to: rank_of(i, jj),
                                tag: (2 * k) as u64,
                                bytes: blk_bytes,
                            });
                        }
                    }
                } else {
                    prog.push(MpiOp::Recv {
                        from: rank_of(i, k),
                        tag: (2 * k) as u64,
                        bytes: blk_bytes,
                    });
                }
                // B[k][j] broadcast along column j.
                if i == k {
                    for ii in 0..p {
                        if ii != i {
                            prog.push(MpiOp::Send {
                                to: rank_of(ii, j),
                                tag: (2 * k + 1) as u64,
                                bytes: blk_bytes,
                            });
                        }
                    }
                } else {
                    prog.push(MpiOp::Recv {
                        from: rank_of(k, j),
                        tag: (2 * k + 1) as u64,
                        bytes: blk_bytes,
                    });
                }
                prog.push(MpiOp::Compute(matmul_cycles(s, s, s)));
            }
            prog
        })
        .collect()
}

/// The matmul [`Workload`] (paper VI-B sizing).
pub struct Matmul;

fn sized(workers: usize, scaling: Scaling) -> MatmulParams {
    let p_grid = ((workers as f64).sqrt().round() as usize).max(1);
    let n = if scaling == Scaling::Weak { 64 * p_grid } else { 1024 };
    MatmulParams { n, p: p_grid, real_data: false }
}

impl Workload for Matmul {
    fn name(&self) -> &'static str {
        "matmul"
    }

    /// Coarse block products: few heavy tasks.
    fn job_shape(&self, scale: u32) -> crate::sim::traffic::JobShape {
        let s = scale.max(1);
        crate::sim::traffic::JobShape {
            tasks: 8 * s,
            task_cycles: 2_000_000,
            fanout: 4,
            hot_pct: 0,
        }
    }

    /// Square grids only (the paper: power-of-4 core counts).
    fn valid_workers(&self, workers: usize) -> bool {
        let p = (workers as f64).sqrt().round() as usize;
        p * p == workers
    }

    fn register(&self, reg: &mut Registry) -> TaskRef {
        register_tasks(reg)
    }

    fn params_for(&self, workers: usize, scaling: Scaling) -> Box<dyn Any> {
        Box::new(sized(workers, scaling))
    }

    fn mpi_programs(&self, ranks: usize, scaling: Scaling) -> Vec<Vec<MpiOp>> {
        mpi_programs(&sized(ranks, scaling), ranks)
    }

    fn verify(&self, world: &World) -> Result<(), String> {
        let st = app_state::<MmState>(world)?;
        let p = st.p.p;
        // main + p*p drivers + p^3 block tasks
        check_task_counts(world, (1 + p * p + p * p * p) as u64)?;
        if st.p.real_data {
            let got = read_result(world);
            let want =
                matmul_reference(&gen_matrix(st.p.n, 5), &gen_matrix(st.p.n, 6), st.p.n);
            check_close(&got, &want, 1e-3, "cell")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::platform::Platform;

    #[test]
    fn real_matmul_matches_reference() {
        let (reg, main) = myrmics();
        let prm = MatmulParams { n: 32, p: 4, real_data: true };
        let mut plat = Platform::build_with(PlatformConfig::hierarchical(8), reg, main, |w| {
            w.app = Some(Box::new(prm.clone()));
        });
        plat.run(Some(1 << 44));
        let w = plat.world();
        assert_eq!(w.gstats.tasks_completed, w.gstats.tasks_spawned);
        // main + p*p drivers + p^3 block tasks
        assert_eq!(w.gstats.tasks_spawned as usize, 1 + 16 + 64);
        Matmul.verify(w).unwrap();
        let got = read_result(w);
        let want = matmul_reference(&gen_matrix(32, 5), &gen_matrix(32, 6), 32);
        for (i, (g, wv)) in got.iter().zip(&want).enumerate() {
            assert!((g - wv).abs() < 1e-3, "cell {i}: got {g} want {wv}");
        }
    }

    #[test]
    fn phases_serialize_per_c_block() {
        // C[i][j] is inout in every phase: the p tasks touching it must
        // not overlap.
        let (reg, main) = myrmics();
        let prm = MatmulParams { n: 64, p: 2, real_data: false };
        let mut plat = Platform::build_with(PlatformConfig::flat(4), reg, main, |w| {
            w.app = Some(Box::new(prm));
        });
        plat.run(Some(1 << 44));
        let w = plat.world();
        assert_eq!(w.gstats.tasks_completed, w.gstats.tasks_spawned);
        Matmul.verify(w).unwrap();
    }

    #[test]
    fn mpi_matmul_square_grid() {
        let prm = MatmulParams { n: 128, p: 4, real_data: false };
        let cfg = PlatformConfig::flat(1);
        let t4 = crate::mpi::runner::mpi_time(mpi_programs(&prm, 4), &cfg);
        let t16 = crate::mpi::runner::mpi_time(mpi_programs(&prm, 16), &cfg);
        assert!(t4 > t16, "t4={t4} t16={t16}");
    }
}

//! Generic traffic job bodies: the task tree a [`JobShape`] realizes.
//!
//! Traffic jobs cannot read per-run state out of `world.app` — many jobs
//! with different shapes run concurrently — so the whole shape travels in
//! the root task's SAFE by-value arguments: `(region, tasks, task_cycles,
//! fanout, hot_pct)`. The admission path (`SchedLogic::try_admit`) builds
//! that descriptor from the job's [`JobShape`]; the body here decomposes
//! it exactly like the skew workload's main task — `fanout` subregions
//! pushed towards leaf-level owners, one 64-byte object per compute task,
//! a `hot_pct` fraction of tasks skewed into subregion 0 — so a single
//! registered function serves every template in the arrival mix.
//!
//! The boot body is deliberately empty: under traffic the platform's
//! mandatory boot main task has nothing to do, and the engine keeps
//! running past its completion because the quiescence gate also requires
//! `TrafficState::all_done`.
//!
//! [`JobShape`]: crate::sim::traffic::JobShape

use crate::api::args::{ObjArg, RegionArg};
use crate::api::ctx::TaskCtx;
use crate::task::registry::{Registry, TaskRef};

/// Deep enough to sink fanout subregions to leaf-level owners on any tree
/// the experiments build (same constant as the skew workload).
const LEAF_LEVEL: i32 = 8;

/// Handles of the registered traffic bodies.
pub struct JobRefs {
    /// The (empty) boot main task `Platform::build` requires.
    pub boot: TaskRef,
    /// The generic per-job root task; its registry index is what
    /// `TrafficState::main_fn` records for the admission path.
    pub job_main: TaskRef,
}

/// Register the traffic job bodies into `reg`.
pub fn register_jobs(reg: &mut Registry) -> JobRefs {
    let work = reg.register("job_work", |ctx: &mut TaskCtx<'_>| {
        let (_obj, cycles): (ObjArg, u64) = ctx.args();
        ctx.compute(cycles);
    });
    let job_main = reg.register("job_main", move |ctx: &mut TaskCtx<'_>| {
        let (root, tasks, task_cycles, fanout, hot_pct): (RegionArg, u64, u64, u64, u64) =
            ctx.args();
        let fanout = fanout.max(1) as usize;
        let mut regions = Vec::with_capacity(fanout);
        for _ in 0..fanout {
            regions.push(ctx.ralloc(root, LEAF_LEVEL));
        }
        let hot = (tasks * hot_pct.min(100) / 100) as usize;
        for i in 0..tasks as usize {
            let g = if i < hot || fanout == 1 {
                0
            } else {
                // Cold remainder round-robins over subregions 1..fanout.
                1 + (i - hot) % (fanout - 1)
            };
            let o = ctx.alloc(64, regions[g]);
            ctx.spawn_task(work).obj_inout(o).val(task_cycles).submit();
        }
    });
    let boot = reg.register("traffic_boot", |_ctx: &mut TaskCtx<'_>| {});
    JobRefs { boot, job_main }
}

/// Build a registry holding only the traffic bodies. Returns it plus the
/// handles a traffic run needs (boot main for `Platform::build_with`,
/// `job_main` for `TrafficState::generate`).
pub fn traffic_boot() -> (Registry, JobRefs) {
    let mut reg = Registry::new();
    let refs = register_jobs(&mut reg);
    (reg, refs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AdmissionKind, HierarchySpec, PlatformConfig, TrafficCfg};
    use crate::platform::Platform;
    use crate::sim::traffic::{JobPhase, JobShape, JobTemplate, TrafficState};

    fn templates() -> Vec<JobTemplate> {
        vec![
            JobTemplate {
                name: "small",
                shape: JobShape { tasks: 6, task_cycles: 2_000_000, fanout: 2, hot_pct: 50 },
            },
            JobTemplate {
                name: "wide",
                shape: JobShape { tasks: 12, task_cycles: 1_000_000, fanout: 4, hot_pct: 0 },
            },
        ]
    }

    fn run_traffic(cfg: PlatformConfig) -> Platform {
        let (reg, refs) = traffic_boot();
        let main_fn = refs.job_main.index();
        let tcfg = cfg.traffic.clone();
        let seed = cfg.seed;
        let mut plat = Platform::build_with(cfg, reg, refs.boot, move |w| {
            let tr = TrafficState::generate(&tcfg, seed, &w.hier, main_fn, &templates());
            w.traffic = Some(tr);
        });
        plat.run(Some(1 << 44));
        plat
    }

    #[test]
    fn traffic_run_drains_every_job() {
        let mut cfg = PlatformConfig::new(16, HierarchySpec::two_level(4));
        cfg.traffic = TrafficCfg::on(8, 2);
        let plat = run_traffic(cfg);
        let tr = plat.world().traffic.as_ref().unwrap();
        assert!(tr.all_done(), "every arrival fired and every job drained");
        assert_eq!(tr.admitted, 8);
        for j in &tr.jobs {
            assert_eq!(j.phase, JobPhase::Done);
            assert_eq!(j.spawned, j.shape.total_tasks(), "root + per-shape work tasks");
            assert_eq!(j.spawned, j.completed);
            assert!(j.finish_at > j.submit_at);
        }
        // Global counts: the empty boot main plus every job's tree.
        let total: u64 = 1 + tr.jobs.iter().map(|j| j.shape.total_tasks()).sum::<u64>();
        assert_eq!(plat.world().gstats.tasks_spawned, total);
        assert_eq!(plat.world().gstats.tasks_completed, total);
    }

    #[test]
    fn tenant_cap_defers_and_still_drains() {
        let mut cfg = PlatformConfig::new(16, HierarchySpec::two_level(4));
        cfg.traffic = TrafficCfg::on(10, 1).with_admission(AdmissionKind::TenantCap);
        cfg.traffic.tenant_cap = 1;
        // Cram arrivals well inside a job's runtime so the cap must bite.
        cfg.traffic.mean_gap = 50_000;
        let plat = run_traffic(cfg);
        let tr = plat.world().traffic.as_ref().unwrap();
        assert!(tr.all_done(), "deferred jobs are retried until admitted");
        assert_eq!(tr.admitted, 10);
        assert!(tr.total_deferrals > 0, "cap 1 with crammed arrivals must defer");
        assert!(tr.jobs.iter().any(|j| j.attempts > 1));
    }

    #[test]
    fn load_threshold_backpressure_drains() {
        let mut cfg = PlatformConfig::new(16, HierarchySpec::two_level(4));
        cfg.traffic = TrafficCfg::on(10, 2).with_admission(AdmissionKind::LoadThreshold);
        cfg.traffic.load_threshold = 2;
        cfg.traffic.mean_gap = 50_000;
        let plat = run_traffic(cfg);
        let tr = plat.world().traffic.as_ref().unwrap();
        assert!(tr.all_done());
        assert_eq!(tr.admitted, 10);
    }

    #[test]
    fn traffic_is_seed_deterministic_end_to_end() {
        let mut cfg = PlatformConfig::new(16, HierarchySpec::two_level(4));
        cfg.traffic = TrafficCfg::on(6, 2);
        let a = run_traffic(cfg.clone());
        let b = run_traffic(cfg);
        let (ta, tb) = (
            a.world().traffic.as_ref().unwrap(),
            b.world().traffic.as_ref().unwrap(),
        );
        for (x, y) in ta.jobs.iter().zip(&tb.jobs) {
            assert_eq!(x.submit_at, y.submit_at);
            assert_eq!(x.admit_at, y.admit_at);
            assert_eq!(x.finish_at, y.finish_at);
            assert_eq!(x.attempts, y.attempts);
        }
        assert_eq!(a.eng.sim.now, b.eng.sim.now);
    }
}

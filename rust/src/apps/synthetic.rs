//! Synthetic microbenchmarks (paper VI-A and VI-E).
//!
//! * [`empty_chain`] — Fig 7a: spawn N empty tasks on one shared object,
//!   measuring intrinsic per-task spawn/execute overhead.
//! * [`independent`] — Fig 7b / Fig 12a: one master spawns N independent
//!   tasks of a given size; the single scheduler is the bottleneck.
//! * [`hier_empty`] — Fig 12b: a hierarchy of small regions with empty
//!   tasks, saturating the schedulers so deeper hierarchies pay off.

use crate::api::args::{ObjArg, RegionArg};
use crate::api::ctx::TaskCtx;
use crate::ids::RegionId;
use crate::task::registry::{Registry, TaskRef};

/// Parameters read by the synthetic task bodies (installed as app state).
pub struct SynthParams {
    pub n_tasks: usize,
    pub task_cycles: u64,
    /// `hier_empty`: regions (domains) and tasks per domain.
    pub domains: usize,
    pub per_domain: usize,
    /// Level hint for domain regions.
    pub domain_level: i32,
}

impl Default for SynthParams {
    fn default() -> Self {
        SynthParams { n_tasks: 0, task_cycles: 0, domains: 0, per_domain: 0, domain_level: 1 }
    }
}

/// Fig 7a: main spawns `n_tasks` empty tasks, all `inout` on the same
/// object, from one worker through one scheduler. Returns (registry,
/// main task).
pub fn empty_chain() -> (Registry, TaskRef) {
    let mut reg = Registry::new();
    let empty = reg.register("empty", |_ctx: &mut TaskCtx<'_>| {});
    let main = reg.register("main", move |ctx: &mut TaskCtx<'_>| {
        let n = ctx.world.app_ref::<SynthParams>().n_tasks;
        let o = ctx.alloc(64, RegionId::ROOT);
        for _ in 0..n {
            ctx.spawn_task(empty).obj_inout(o).submit();
        }
    });
    (reg, main)
}

/// Fig 7b / 12a: main spawns `n_tasks` tasks, each on its own object,
/// each computing `task_cycles`.
pub fn independent() -> (Registry, TaskRef) {
    let mut reg = Registry::new();
    let work = reg.register("work", |ctx: &mut TaskCtx<'_>| {
        let (_obj, cycles): (ObjArg, u64) = ctx.args();
        ctx.compute(cycles);
    });
    let main = reg.register("main", move |ctx: &mut TaskCtx<'_>| {
        let p = ctx.world.app_ref::<SynthParams>();
        let (n, cycles) = (p.n_tasks, p.task_cycles);
        let objs = ctx.balloc(64, RegionId::ROOT, n);
        for o in objs {
            ctx.spawn_task(work).obj_inout(o).val(cycles).submit();
        }
    });
    (reg, main)
}

/// Fig 12b: a *hierarchy* of small regions mirroring the scheduler tree
/// ("creates a hierarchy of small regions and spawns empty tasks"): main
/// creates one mid-region per ~6 domains and spawns a mid task per
/// region; each mid task creates `~6` domain subregions and spawns domain
/// tasks; each domain task bulk-allocates `per_domain` objects and spawns
/// an empty task per object. The fan-out parallelizes spawning and the
/// nested regions distribute the dependency metadata across scheduler
/// levels — which is what deeper hierarchies exploit.
pub fn hier_empty() -> (Registry, TaskRef) {
    let mut reg = Registry::new();
    let empty = reg.register("empty", |ctx: &mut TaskCtx<'_>| {
        let cycles = ctx.world.app_ref::<SynthParams>().task_cycles;
        ctx.compute(cycles);
    });
    let domain = reg.register("domain", move |ctx: &mut TaskCtx<'_>| {
        let (r, k): (RegionArg, usize) = ctx.args();
        let objs = ctx.balloc(64, r, k);
        for o in objs {
            ctx.spawn_task(empty).obj_inout(o).submit();
        }
    });
    let mid = reg.register("mid", move |ctx: &mut TaskCtx<'_>| {
        let (g, n_domains): (RegionArg, usize) = ctx.args();
        let (k, lvl) = {
            let p = ctx.world.app_ref::<SynthParams>();
            (p.per_domain, p.domain_level)
        };
        for _ in 0..n_domains {
            let r = ctx.ralloc(g, lvl);
            // The domain task only spawns subtasks: NOTRANSFER saves the
            // region DMA (paper V-A's stated use case).
            ctx.spawn_task(domain)
                .reg_inout(r)
                .notransfer()
                .val(k as u64)
                .submit();
        }
    });
    let main = reg.register("main", move |ctx: &mut TaskCtx<'_>| {
        let p = ctx.world.app_ref::<SynthParams>();
        let d = p.domains;
        let mids = d.div_ceil(6).max(1);
        for m in 0..mids {
            let n_domains = (m + 1) * d / mids - m * d / mids;
            if n_domains == 0 {
                continue;
            }
            let g = ctx.ralloc(RegionId::ROOT, 1);
            ctx.spawn_task(mid)
                .reg_inout(g)
                .notransfer()
                .val(n_domains as u64)
                .submit();
        }
    });
    (reg, main)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::platform::Platform;
    use crate::task::table::TaskState;

    #[test]
    fn empty_chain_runs_to_completion() {
        let (reg, main) = empty_chain();
        let mut p = Platform::build_with(PlatformConfig::flat(1), reg, main, |w| {
            w.app = Some(Box::new(SynthParams { n_tasks: 20, ..Default::default() }));
        });
        let t = p.run(Some(1 << 40));
        let w = p.world();
        assert_eq!(w.gstats.tasks_spawned, 21, "main + 20 children");
        assert_eq!(w.gstats.tasks_completed, 21);
        assert!(w.tasks.iter().all(|e| e.state == TaskState::Done));
        assert!(t > 0);
    }

    #[test]
    fn empty_chain_serializes_on_the_object() {
        let (reg, main) = empty_chain();
        let mut p = Platform::build_with(PlatformConfig::flat(4), reg, main, |w| {
            w.app = Some(Box::new(SynthParams { n_tasks: 10, ..Default::default() }));
        });
        p.run(Some(1 << 40));
        // inout on one object: executions must not overlap.
        let mut spans: Vec<(u64, u64)> = p
            .world()
            .tasks
            .iter()
            .skip(1)
            .map(|e| (e.started_at, e.done_at))
            .collect();
        spans.sort_unstable();
        for w in spans.windows(2) {
            assert!(w[0].1 <= w[1].0, "serialized tasks overlap: {w:?}");
        }
    }

    #[test]
    fn independent_tasks_parallelize() {
        let run = |workers: usize| {
            let (reg, main) = independent();
            let mut p = Platform::build_with(PlatformConfig::flat(workers), reg, main, |w| {
                w.app = Some(Box::new(SynthParams {
                    n_tasks: 32,
                    task_cycles: 2_000_000,
                    ..Default::default()
                }));
            });
            p.run(Some(1 << 42))
        };
        let t1 = run(1);
        let t8 = run(8);
        let speedup = t1 as f64 / t8 as f64;
        assert!(speedup > 4.0, "8 workers should speed up ~32 independent tasks: {speedup:.2}x");
    }

    /// Locks the Fig 7a cost-model calibration: heterogeneous spawn
    /// ~16.2 K cycles, execute ~13.3 K; MicroBlaze-only spawn ~37.4 K.
    #[test]
    fn fig7a_calibration_within_ten_percent() {
        let measure = |hetero: bool| {
            let (reg, main) = empty_chain();
            let mut cfg = PlatformConfig::flat(1);
            cfg.hetero = hetero;
            let n = 500usize;
            let mut p = Platform::build_with(cfg, reg, main, |w| {
                w.app = Some(Box::new(SynthParams { n_tasks: n, ..Default::default() }));
            });
            let end = p.run(Some(1 << 44));
            let main_e = p.world().tasks.get(crate::ids::TaskId(0));
            let spawn = (main_e.done_at - main_e.started_at) as f64 / n as f64;
            let exec = (end - main_e.done_at) as f64 / n as f64;
            (spawn, exec)
        };
        let (spawn_h, exec_h) = measure(true);
        let (spawn_mb, _) = measure(false);
        assert!((spawn_h - 16_200.0).abs() / 16_200.0 < 0.10, "hetero spawn {spawn_h}");
        assert!((exec_h - 13_300.0).abs() / 13_300.0 < 0.10, "hetero exec {exec_h}");
        assert!((spawn_mb - 37_400.0).abs() / 37_400.0 < 0.10, "mb spawn {spawn_mb}");
    }

    #[test]
    fn hier_empty_completes_on_two_levels() {
        let (reg, main) = hier_empty();
        let mut p = Platform::build_with(PlatformConfig::hierarchical(32), reg, main, |w| {
            w.app = Some(Box::new(SynthParams {
                domains: 4,
                per_domain: 8,
                domain_level: 1,
                task_cycles: 0,
                ..Default::default()
            }));
        });
        p.run(Some(1 << 42));
        let w = p.world();
        // main + 1 mid + 4 domains + 32 empties
        assert_eq!(w.gstats.tasks_spawned, 1 + 1 + 4 + 32);
        assert_eq!(w.gstats.tasks_completed, w.gstats.tasks_spawned);
        // Delegation must have pushed domain tasks to leaf schedulers.
        let delegated = w.tasks.iter().filter(|e| e.resp != 0).count();
        assert!(delegated > 0, "no tasks were delegated to leaf schedulers");
    }
}

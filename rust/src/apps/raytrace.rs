//! Raytracing (paper VI-B, Figs 8b/8h): embarrassingly parallel.
//!
//! "A description of a scene geometry is made available to all workers.
//! Each worker renders a part of a picture frame ... We use regions to
//! split the frame into groups of pixel lines." Per-line cost varies with
//! the scene profile (`workload::raytrace_line_cycles`), which is why
//! workers are not fully busy at low core counts (paper VI-C).

use std::any::Any;

use crate::api::args::{ObjArg, RegionArg};
use crate::api::ctx::TaskCtx;
use crate::apps::workload::raytrace_line_cycles;
use crate::apps::workload_api::{
    app_state, check_task_counts, groups_for, Scaling, Workload,
};
use crate::ids::{ObjectId, RegionId};
use crate::mpi::rank::MpiOp;
use crate::platform::World;
use crate::task::registry::{Registry, TaskRef};

#[derive(Clone, Debug)]
pub struct RayParams {
    pub width: usize,
    pub height: usize,
    /// Render tasks (chunks of lines).
    pub tasks: usize,
    pub groups: usize,
    /// Scene description size in bytes (broadcast/read by everyone).
    pub scene_bytes: u64,
}

pub struct RayState {
    pub p: RayParams,
    pub scene: ObjectId,
    pub chunks: Vec<ObjectId>,
}

/// Total modeled cycles to render lines `[l0, l1)`.
pub fn chunk_cycles(p: &RayParams, l0: usize, l1: usize) -> u64 {
    (l0..l1)
        .map(|l| raytrace_line_cycles(l as u64, p.width as u64, p.height as u64))
        .sum()
}

/// Register the raytracer task bodies; returns the main task's handle.
fn register_tasks(reg: &mut Registry) -> TaskRef {
    let render = reg.register("ray_render", |ctx: &mut TaskCtx<'_>| {
        let (_scene, _chunk, c): (ObjArg, ObjArg, usize) = ctx.args();
        let p = ctx.world.app_ref::<RayState>().p.clone();
        let l0 = c * p.height / p.tasks;
        let l1 = (c + 1) * p.height / p.tasks;
        ctx.compute(chunk_cycles(&p, l0, l1));
    });

    let group = reg.register("ray_group", move |ctx: &mut TaskCtx<'_>| {
        let (_group_reg, g, _scene_nt): (RegionArg, usize, ObjArg) = ctx.args();
        let (tasks, groups, scene, chunks) = {
            let st = ctx.world.app_ref::<RayState>();
            (st.p.tasks, st.p.groups, st.scene, st.chunks.clone())
        };
        for c in 0..tasks {
            if c * groups / tasks == g {
                ctx.spawn_task(render)
                    .obj_in(scene)
                    .obj_out(chunks[c])
                    .val(c as u64)
                    .submit();
            }
        }
    });

    reg.register("ray_main", move |ctx: &mut TaskCtx<'_>| {
        let p = ctx.world.app_ref::<RayParams>().clone();
        assert!(p.groups <= p.tasks);
        // Scene lives in the root region; one frame-chunk object per task,
        // packed into per-group regions of pixel lines.
        let scene = ctx.alloc(p.scene_bytes, RegionId::ROOT);
        let mut chunks = Vec::with_capacity(p.tasks);
        let mut group_regions = Vec::with_capacity(p.groups);
        for _ in 0..p.groups {
            group_regions.push(ctx.ralloc(RegionId::ROOT, 1));
        }
        for c in 0..p.tasks {
            let g = c * p.groups / p.tasks;
            let lines = (c + 1) * p.height / p.tasks - c * p.height / p.tasks;
            chunks.push(ctx.alloc((lines * p.width * 4) as u64, group_regions[g]));
        }
        ctx.world.app = Some(Box::new(RayState { p: p.clone(), scene, chunks }));
        for g in 0..p.groups {
            ctx.spawn_task(group)
                .reg_inout(group_regions[g])
                .notransfer()
                .val(g as u64)
                .obj_in(scene)
                .notransfer()
                .submit();
        }
    })
}

/// Build the Myrmics raytracer. Returns (registry, main task).
pub fn myrmics() -> (Registry, TaskRef) {
    let mut reg = Registry::new();
    let main = register_tasks(&mut reg);
    (reg, main)
}

/// MPI baseline: broadcast the scene, render, gather to rank 0. Lines are
/// assigned round-robin (hand-tuned static balance against the scene's
/// per-line cost profile).
pub fn mpi_programs(p: &RayParams, ranks: usize) -> Vec<Vec<MpiOp>> {
    (0..ranks)
        .map(|r| {
            let lines: Vec<usize> = (r..p.height).step_by(ranks).collect();
            let line_bytes = (lines.len() * p.width * 4) as u64;
            let cycles: u64 = lines
                .iter()
                .map(|&l| {
                    crate::apps::workload::raytrace_line_cycles(
                        l as u64,
                        p.width as u64,
                        p.height as u64,
                    )
                })
                .sum();
            let mut prog = vec![
                MpiOp::Bcast { root: 0, bytes: p.scene_bytes },
                MpiOp::Compute(cycles),
            ];
            if r == 0 {
                for src in 1..ranks {
                    prog.push(MpiOp::Recv { from: src, tag: 1, bytes: line_bytes });
                }
            } else {
                prog.push(MpiOp::Send { to: 0, tag: 1, bytes: line_bytes });
            }
            prog
        })
        .collect()
}

/// The raytracing [`Workload`] (paper VI-B sizing).
pub struct Raytrace;

fn sized(workers: usize, scaling: Scaling, groups: usize) -> RayParams {
    let tasks = (2 * workers).max(2);
    let height = if scaling == Scaling::Weak { tasks * 2 } else { 2048.max(tasks * 2) };
    RayParams {
        width: 4096,
        height,
        tasks,
        groups: groups.min(tasks),
        scene_bytes: 64 * 1024,
    }
}

impl Workload for Raytrace {
    fn name(&self) -> &'static str {
        "raytrace"
    }

    /// Embarrassingly parallel tiles: many fine-grained tasks over a wide
    /// fanout.
    fn job_shape(&self, scale: u32) -> crate::sim::traffic::JobShape {
        let s = scale.max(1);
        crate::sim::traffic::JobShape { tasks: 16 * s, task_cycles: 600_000, fanout: 8, hot_pct: 0 }
    }

    fn register(&self, reg: &mut Registry) -> TaskRef {
        register_tasks(reg)
    }

    fn params_for(&self, workers: usize, scaling: Scaling) -> Box<dyn Any> {
        Box::new(sized(workers, scaling, groups_for(workers)))
    }

    fn mpi_programs(&self, ranks: usize, scaling: Scaling) -> Vec<Vec<MpiOp>> {
        mpi_programs(&sized(ranks, scaling, 1), ranks)
    }

    fn verify(&self, world: &World) -> Result<(), String> {
        let st = app_state::<RayState>(world)?;
        check_task_counts(world, 1 + (st.p.groups + st.p.tasks) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::mpi::runner::mpi_time;
    use crate::platform::Platform;

    fn params() -> RayParams {
        RayParams { width: 256, height: 64, tasks: 16, groups: 4, scene_bytes: 8192 }
    }

    #[test]
    fn myrmics_completes_and_scales() {
        let run = |workers| {
            let (reg, main) = myrmics();
            let mut plat =
                Platform::build_with(PlatformConfig::hierarchical(workers), reg, main, |w| {
                    w.app = Some(Box::new(params()));
                });
            let t = plat.run(Some(1 << 44));
            assert_eq!(plat.world().gstats.tasks_completed, 1 + 4 + 16);
            t
        };
        let t1 = run(1);
        let t8 = run(8);
        assert!(t1 as f64 / t8 as f64 > 3.0, "speedup {:.2}", t1 as f64 / t8 as f64);
    }

    #[test]
    fn mpi_scales_nearly_perfectly() {
        let p = params();
        let t1 = mpi_time(mpi_programs(&p, 1), &PlatformConfig::flat(1));
        let t8 = mpi_time(mpi_programs(&p, 8), &PlatformConfig::flat(1));
        let s = t1 as f64 / t8 as f64;
        assert!(s > 5.0, "mpi speedup {s:.2}");
    }

    #[test]
    fn line_cost_variation_creates_imbalance() {
        // With per-line cost variation, equal line counts != equal work
        // (the effect the paper reports for low core counts).
        let p = params();
        let a = chunk_cycles(&p, 0, 8);
        let b = chunk_cycles(&p, 28, 36);
        assert!((b as f64 / a as f64) > 1.1);
    }
}

//! Bitonic sort (paper VI-B, Figs 8c/8i): butterfly communication.
//!
//! `blocks` buffers of `m` elements each. After a local sort, `log2(B)`
//! stages of `(k, j)` passes merge-split partner blocks (`partner = i ^
//! 2^j`), the classic block-bitonic network — compare-exchange becomes
//! merge-split on sorted blocks.
//!
//! **Myrmics decomposition**: buffers live under per-group regions ("the
//! data to be sorted are divided into coarse regions when the algorithm
//! initializes"). Passes whose partner distance stays inside a group are
//! spawned by per-group pass tasks (hierarchical); wider passes are
//! spawned by main. This is the paper's worst-scaling benchmark: the task
//! count per pass is high and the schedulers saturate (Fig 9a).

use std::any::Any;

use crate::api::args::{ObjArg, RegionArg};
use crate::api::ctx::TaskCtx;
use crate::apps::workload::{merge_cycles, sort_cycles};
use crate::apps::workload_api::{
    app_state, check_task_counts, groups_for, Scaling, Workload,
};
use crate::ids::{ObjectId, RegionId};
use crate::mpi::rank::MpiOp;
use crate::platform::World;
use crate::task::registry::{Registry, TaskRef};

#[derive(Clone, Debug)]
pub struct BitonicParams {
    /// Number of blocks; must be a power of two.
    pub blocks: usize,
    /// Elements per block.
    pub m: usize,
    /// Groups (power of two, <= blocks).
    pub groups: usize,
    pub real_data: bool,
}

pub struct BitonicState {
    pub p: BitonicParams,
    pub bufs: Vec<ObjectId>,
    pub group_regions: Vec<RegionId>,
}

fn log2(x: usize) -> u32 {
    debug_assert!(x.is_power_of_two());
    x.trailing_zeros()
}

/// The (k, j) pass schedule after the local sort.
pub fn passes(blocks: usize) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    for k in 1..=log2(blocks) {
        for j in (0..k).rev() {
            out.push((k, j));
        }
    }
    out
}

/// Merge-split: both blocks sorted ascending; `asc` keeps the low half in
/// `a`.
fn merge_split(a: &mut Vec<u32>, b: &mut Vec<u32>, asc: bool) {
    let m = a.len();
    let mut all: Vec<u32> = a.iter().chain(b.iter()).copied().collect();
    all.sort_unstable();
    if asc {
        b.copy_from_slice(&all[m..]);
        a.copy_from_slice(&all[..m]);
    } else {
        b.copy_from_slice(&all[..m]);
        a.copy_from_slice(&all[m..]);
    }
}

/// Register the bitonic task bodies; returns the main task's handle.
/// (Bodies are registered dependencies-first so each spawner can capture
/// its children's `TaskRef`s.)
fn register_tasks(reg: &mut Registry) -> TaskRef {
    // Local sort — inout buf, val i.
    let sort = reg.register("bt_sort", |ctx: &mut TaskCtx<'_>| {
        let (buf, _i): (ObjArg, u64) = ctx.args();
        let (m, real) = {
            let st = ctx.world.app_ref::<BitonicState>();
            (st.p.m, st.p.real_data)
        };
        ctx.compute(sort_cycles(m as u64));
        if real {
            let mut v = ctx.read_u32(buf);
            v.sort_unstable();
            ctx.write_u32(buf, &v);
        }
    });

    // Merge-split pair — inout buf_lo, inout buf_hi, val asc.
    let pair = reg.register("bt_pair", |ctx: &mut TaskCtx<'_>| {
        let (lo, hi, asc): (ObjArg, ObjArg, u64) = ctx.args();
        let (m, real) = {
            let st = ctx.world.app_ref::<BitonicState>();
            (st.p.m, st.p.real_data)
        };
        ctx.compute(merge_cycles(2 * m as u64));
        if real {
            let mut a = ctx.read_u32(lo);
            let mut b = ctx.read_u32(hi);
            merge_split(&mut a, &mut b, asc != 0);
            ctx.write_u32(lo, &a);
            ctx.write_u32(hi, &b);
        }
    });

    // Per-group pass driver — spawns the group's intra-group pairs.
    let pass = reg.register("bt_pass", move |ctx: &mut TaskCtx<'_>| {
        let (_group_reg, g, k, j): (RegionArg, usize, u64, u64) = ctx.args();
        let (k, j) = (k as u32, j as u32);
        let (blocks, groups, bufs) = {
            let st = ctx.world.app_ref::<BitonicState>();
            (st.p.blocks, st.p.groups, st.bufs.clone())
        };
        let gs = blocks / groups;
        for i in (g * gs)..((g + 1) * gs) {
            let partner = i ^ (1 << j);
            if partner > i {
                let asc = (i >> k) & 1 == 0;
                ctx.spawn_task(pair)
                    .obj_inout(bufs[i])
                    .obj_inout(bufs[partner])
                    .val(asc as u64)
                    .submit();
            }
        }
    });

    // Per-group local-sort driver.
    let sortgrp = reg.register("bt_sortgrp", move |ctx: &mut TaskCtx<'_>| {
        let (_group_reg, g): (RegionArg, usize) = ctx.args();
        let (blocks, groups, bufs) = {
            let st = ctx.world.app_ref::<BitonicState>();
            (st.p.blocks, st.p.groups, st.bufs.clone())
        };
        let gs = blocks / groups;
        for i in (g * gs)..((g + 1) * gs) {
            ctx.spawn_task(sort).obj_inout(bufs[i]).val(i as u64).submit();
        }
    });

    reg.register("bt_main", move |ctx: &mut TaskCtx<'_>| {
        let p = ctx.world.app_ref::<BitonicParams>().clone();
        assert!(p.blocks.is_power_of_two() && p.groups.is_power_of_two());
        assert!(p.groups <= p.blocks);
        let mut group_regions = Vec::new();
        let mut bufs = Vec::new();
        for _ in 0..p.groups {
            group_regions.push(ctx.ralloc(RegionId::ROOT, 1));
        }
        let gs = p.blocks / p.groups;
        for i in 0..p.blocks {
            bufs.push(ctx.alloc((p.m * 4) as u64, group_regions[i / gs]));
        }
        if p.real_data {
            let mut rng = crate::sim::rng::Rng::new(42);
            for &o in &bufs {
                let data: Vec<u32> = (0..p.m).map(|_| rng.next_u64() as u32).collect();
                ctx.write_u32(o, &data);
            }
        }
        ctx.world.app =
            Some(Box::new(BitonicState { p: p.clone(), bufs: bufs.clone(), group_regions: group_regions.clone() }));
        // Local sorts, via per-group drivers (hierarchical spawn).
        for (g, &gr) in group_regions.iter().enumerate() {
            ctx.spawn_task(sortgrp)
                .reg_inout(gr)
                .notransfer()
                .val(g as u64)
                .submit();
        }
        // Merge passes.
        for (k, j) in passes(p.blocks) {
            if (1usize << j) < gs {
                // Intra-group: delegate to per-group pass drivers.
                for (g, &gr) in group_regions.iter().enumerate() {
                    ctx.spawn_task(pass)
                        .reg_inout(gr)
                        .notransfer()
                        .val(g as u64)
                        .val(k as u64)
                        .val(j as u64)
                        .submit();
                }
            } else {
                // Cross-group pairs: spawned flat from main.
                for i in 0..p.blocks {
                    let partner = i ^ (1usize << j);
                    if partner > i {
                        let asc = (i >> k) & 1 == 0;
                        ctx.spawn_task(pair)
                            .obj_inout(bufs[i])
                            .obj_inout(bufs[partner])
                            .val(asc as u64)
                            .submit();
                    }
                }
            }
        }
    })
}

/// Build the Myrmics bitonic sort. Returns (registry, main task).
pub fn myrmics() -> (Registry, TaskRef) {
    let mut reg = Registry::new();
    let main = register_tasks(&mut reg);
    (reg, main)
}

/// Gather the fully sorted sequence from a finished real-data run.
pub fn read_result(world: &crate::platform::World) -> Vec<u32> {
    let st = world.app_ref::<BitonicState>();
    let mut out = Vec::new();
    for &o in &st.bufs {
        out.extend(world.store.get_u32(o).unwrap());
    }
    out
}

/// MPI baseline: local sort, then pairwise exchange + merge per pass.
pub fn mpi_programs(p: &BitonicParams, ranks: usize) -> Vec<Vec<MpiOp>> {
    assert!(ranks.is_power_of_two());
    let m = (p.blocks * p.m / ranks) as u64; // elements per rank
    let bytes = m * 4;
    (0..ranks)
        .map(|r| {
            let mut prog = vec![MpiOp::Compute(sort_cycles(m))];
            for (tag, (_k, j)) in passes(ranks).into_iter().enumerate() {
                let partner = r ^ (1usize << j);
                prog.push(MpiOp::Send { to: partner, tag: tag as u64, bytes });
                prog.push(MpiOp::Recv { from: partner, tag: tag as u64, bytes });
                prog.push(MpiOp::Compute(merge_cycles(2 * m)));
            }
            prog
        })
        .collect()
}

/// The bitonic-sort [`Workload`] (paper VI-B sizing).
pub struct Bitonic;

fn sized(workers: usize, scaling: Scaling, groups: usize) -> BitonicParams {
    let blocks = (2 * workers).next_power_of_two();
    let m = if scaling == Scaling::Weak { 4096 } else { (1usize << 22) / blocks };
    BitonicParams {
        blocks,
        m: m.max(64),
        groups: groups.next_power_of_two().min(blocks),
        real_data: false,
    }
}

impl Workload for Bitonic {
    fn name(&self) -> &'static str {
        "bitonic"
    }

    /// Merge-stage chunks: small tasks over a binary split.
    fn job_shape(&self, scale: u32) -> crate::sim::traffic::JobShape {
        let s = scale.max(1);
        crate::sim::traffic::JobShape { tasks: 16 * s, task_cycles: 500_000, fanout: 2, hot_pct: 0 }
    }

    fn valid_workers(&self, workers: usize) -> bool {
        workers.is_power_of_two()
    }

    fn register(&self, reg: &mut Registry) -> TaskRef {
        register_tasks(reg)
    }

    fn params_for(&self, workers: usize, scaling: Scaling) -> Box<dyn Any> {
        Box::new(sized(workers, scaling, groups_for(workers)))
    }

    fn mpi_programs(&self, ranks: usize, scaling: Scaling) -> Vec<Vec<MpiOp>> {
        mpi_programs(&sized(ranks, scaling, 1), ranks)
    }

    fn verify(&self, world: &World) -> Result<(), String> {
        let st = app_state::<BitonicState>(world)?;
        let p = &st.p;
        let gs = p.blocks / p.groups;
        // main + sort drivers + sorts, then per pass: either (drivers +
        // intra pairs) or the flat cross-group pairs — blocks/2 pairs
        // either way.
        let mut want = (1 + p.groups + p.blocks) as u64;
        for (_k, j) in passes(p.blocks) {
            if (1usize << j) < gs {
                want += (p.groups + p.blocks / 2) as u64;
            } else {
                want += (p.blocks / 2) as u64;
            }
        }
        check_task_counts(world, want)?;
        if p.real_data {
            let out = read_result(world);
            if out.windows(2).any(|w| w[0] > w[1]) {
                return Err("sequence not sorted".into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PlatformConfig;
    use crate::platform::Platform;

    #[test]
    fn pass_schedule_is_log_squared() {
        assert_eq!(passes(2).len(), 1);
        assert_eq!(passes(8).len(), 6); // 1 + 2 + 3
        assert_eq!(passes(16).len(), 10);
    }

    #[test]
    fn real_sort_is_correct() {
        let (reg, main) = myrmics();
        let p = BitonicParams { blocks: 8, m: 64, groups: 2, real_data: true };
        let mut plat = Platform::build_with(PlatformConfig::hierarchical(8), reg, main, |w| {
            w.app = Some(Box::new(p));
        });
        plat.run(Some(1 << 44));
        let w = plat.world();
        assert_eq!(w.gstats.tasks_completed, w.gstats.tasks_spawned);
        Bitonic.verify(w).unwrap();
        let out = read_result(w);
        assert_eq!(out.len(), 512);
        for win in out.windows(2) {
            assert!(win[0] <= win[1], "sequence not sorted");
        }
    }

    #[test]
    fn modeled_run_completes_flat() {
        let (reg, main) = myrmics();
        let p = BitonicParams { blocks: 16, m: 128, groups: 4, real_data: false };
        let mut plat = Platform::build_with(PlatformConfig::flat(16), reg, main, |w| {
            w.app = Some(Box::new(p));
        });
        plat.run(Some(1 << 44));
        let w = plat.world();
        assert_eq!(w.gstats.tasks_completed, w.gstats.tasks_spawned);
        Bitonic.verify(w).unwrap();
    }

    #[test]
    fn mpi_bitonic_completes_and_scales_modestly() {
        let p = BitonicParams { blocks: 16, m: 4096, groups: 4, real_data: false };
        let cfg = PlatformConfig::flat(1);
        let t1 = crate::mpi::runner::mpi_time(mpi_programs(&p, 1), &cfg);
        let t8 = crate::mpi::runner::mpi_time(mpi_programs(&p, 8), &cfg);
        assert!(t1 as f64 / t8 as f64 > 2.0);
    }

    #[test]
    fn merge_split_partitions() {
        let mut a = vec![1, 4, 9, 12];
        let mut b = vec![2, 3, 10, 11];
        merge_split(&mut a, &mut b, true);
        assert_eq!(a, vec![1, 2, 3, 4]);
        assert_eq!(b, vec![9, 10, 11, 12]);
        merge_split(&mut a, &mut b, false);
        assert_eq!(a, vec![9, 10, 11, 12]);
        assert_eq!(b, vec![1, 2, 3, 4]);
    }
}

//! Determinism regression gate for the zero-allocation hot-path refactor.
//!
//! The simulator must be a pure function of its configuration: final
//! virtual time and every global counter must replay bit-identically.
//! These tests pin fig7-style runs (the workloads the hotpath bench
//! drives) so any refactor of the dependency traversal, packing, routing
//! or scheduler state that changes the event schedule — even by one
//! message reordering — fails loudly instead of silently shifting the
//! numbers every later perf PR is judged against.
//!
//! Limitation: run-to-run replay catches nondeterminism, not behavior
//! drift *across* refactors (a deterministic schedule change shifts both
//! runs identically). The PR-1 build container has no Rust toolchain, so
//! seed golden values could not be captured; first session with cargo:
//! run these, record each Fingerprint as a `const` golden, and assert
//! against it so later refactors are held to bit-identical schedules.

use myrmics::apps::jobs::traffic_boot;
use myrmics::apps::synthetic::{empty_chain, hier_empty, independent, SynthParams};
use myrmics::apps::workload_api::job_templates;
use myrmics::apps::jacobi;
use myrmics::config::{
    HierarchySpec, PlatformConfig, RecoveryCfg, ShardCfg, TrafficCfg,
};
use myrmics::mpi::runner::run_mpi;
use myrmics::platform::Platform;
use myrmics::sim::chaos::FaultPlan;
use myrmics::sim::traffic::TrafficState;

/// Everything that must replay bit-identically.
#[derive(PartialEq, Eq, Debug)]
struct Fingerprint {
    final_time: u64,
    events: u64,
    msgs: u64,
    tasks_spawned: u64,
    tasks_completed: u64,
    dep_boundary_msgs: u64,
    dma_transfers: u64,
}

fn run_independent(workers: usize, n_tasks: usize) -> Fingerprint {
    let (reg, main) = independent();
    let mut plat = Platform::build_with(PlatformConfig::hierarchical(workers), reg, main, |w| {
        w.app = Some(Box::new(SynthParams {
            n_tasks,
            task_cycles: 100_000,
            ..Default::default()
        }));
    });
    let t = plat.run(Some(1 << 44));
    let g = &plat.world().gstats;
    Fingerprint {
        final_time: t,
        events: g.events_processed,
        msgs: g.msgs_total,
        tasks_spawned: g.tasks_spawned,
        tasks_completed: g.tasks_completed,
        dep_boundary_msgs: g.dep_boundary_msgs,
        dma_transfers: g.dma_transfers,
    }
}

fn run_empty_chain(n_tasks: usize) -> Fingerprint {
    let (reg, main) = empty_chain();
    let mut plat = Platform::build_with(PlatformConfig::flat(1), reg, main, |w| {
        w.app = Some(Box::new(SynthParams { n_tasks, ..Default::default() }));
    });
    let t = plat.run(Some(1 << 44));
    let g = &plat.world().gstats;
    Fingerprint {
        final_time: t,
        events: g.events_processed,
        msgs: g.msgs_total,
        tasks_spawned: g.tasks_spawned,
        tasks_completed: g.tasks_completed,
        dep_boundary_msgs: g.dep_boundary_msgs,
        dma_transfers: g.dma_transfers,
    }
}

/// fig7b shape (independent tasks over a hierarchy): two runs must agree
/// on the final cycle count and every global counter, and the run must
/// actually complete all its tasks.
#[test]
fn fig7_independent_replays_bit_identically() {
    let a = run_independent(16, 64);
    let b = run_independent(16, 64);
    assert_eq!(a, b, "fig7-style run must replay bit-identically");
    assert_eq!(a.tasks_spawned, 65, "main + 64 children");
    assert_eq!(a.tasks_completed, 65);
    assert!(a.final_time > 0);
    assert!(a.events > 0);
}

/// fig7a shape (serialized empty tasks, one worker): the pure runtime-
/// overhead path must also replay bit-identically.
#[test]
fn fig7_empty_chain_replays_bit_identically() {
    let a = run_empty_chain(200);
    let b = run_empty_chain(200);
    assert_eq!(a, b);
    assert_eq!(a.tasks_completed, 201);
}

/// Larger hierarchy: more schedulers, more tree routing, more boundary
/// crossings — the paths the routing/arena refactor touches hardest.
#[test]
fn fig7_wide_hierarchy_replays_bit_identically() {
    let a = run_independent(64, 256);
    let b = run_independent(64, 256);
    assert_eq!(a, b);
    assert_eq!(a.tasks_completed, 257);
}

/// The MPI baseline rides the same event core (timing wheel, wake-marker
/// deferrals, DMA-delivered payloads without credit channels): its runs
/// must replay bit-identically too.
#[test]
fn mpi_baseline_replays_bit_identically() {
    let run = || {
        let p = jacobi::JacobiParams::modeled(1024, 3, 32, 1);
        let eng = run_mpi(jacobi::mpi_programs(&p, 16), &PlatformConfig::flat(1));
        assert!(eng.world.done, "all ranks must finish");
        let g = &eng.world.gstats;
        (eng.sim.now, g.events_processed, g.msgs_total, g.dma_transfers)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "MPI baseline must replay bit-identically");
    assert!(a.0 > 0);
    assert!(a.3 > 0, "jacobi ranks exchange halos over DMA");
}

/// Nested-region workload (fig12b shape): regions distributed across
/// scheduler owners, so the traversal genuinely crosses ownership
/// boundaries and the quiescence/parent-counter protocol runs.
#[test]
fn hier_empty_replays_bit_identically() {
    let run = || {
        let (reg, main) = hier_empty();
        // 64 workers => 1 top + 4 leaf schedulers, so level-1 regions land
        // on leaf owners and traversals cross ownership boundaries.
        let mut plat =
            Platform::build_with(PlatformConfig::hierarchical(64), reg, main, |w| {
                w.app = Some(Box::new(SynthParams {
                    domains: 8,
                    per_domain: 4,
                    task_cycles: 10_000,
                    ..Default::default()
                }));
            });
        let t = plat.run(Some(1 << 44));
        let g = &plat.world().gstats;
        (
            t,
            g.events_processed,
            g.msgs_total,
            g.tasks_spawned,
            g.tasks_completed,
            g.dep_boundary_msgs,
        )
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
    assert_eq!(a.3, a.4, "all spawned tasks complete");
    assert!(a.5 > 0, "nested regions must exercise cross-owner traversal");
}

// ---------------------------------------------------------------------------
// Sharded engine: the conservative-sync merge must reproduce the exact
// single-queue schedule, so the fingerprint is pinned to be *identical*
// across shard counts — not merely self-consistent per count.
// ---------------------------------------------------------------------------

fn run_with_shards(cfg_base: PlatformConfig, shards: usize) -> Fingerprint {
    let (reg, main) = independent();
    let mut cfg = cfg_base;
    cfg.shard = ShardCfg::with_shards(shards);
    let mut plat = Platform::build_with(cfg, reg, main, |w| {
        w.app = Some(Box::new(SynthParams {
            n_tasks: 256,
            task_cycles: 100_000,
            ..Default::default()
        }));
    });
    let t = plat.run(Some(1 << 44));
    let g = &plat.world().gstats;
    Fingerprint {
        final_time: t,
        events: g.events_processed,
        msgs: g.msgs_total,
        tasks_spawned: g.tasks_spawned,
        tasks_completed: g.tasks_completed,
        dep_boundary_msgs: g.dep_boundary_msgs,
        dma_transfers: g.dma_transfers,
    }
}

/// fig7-independent over the paper's two-level 64-worker tree (4 leaf
/// subtrees): shards=1 (the exact legacy path) must equal shards=2 and
/// shards=4 bit-for-bit.
#[test]
fn fig7_independent_fingerprint_is_shard_count_invariant() {
    let one = run_with_shards(PlatformConfig::hierarchical(64), 1);
    let two = run_with_shards(PlatformConfig::hierarchical(64), 2);
    let four = run_with_shards(PlatformConfig::hierarchical(64), 4);
    assert_eq!(one, two, "shards=2 must replay the legacy schedule");
    assert_eq!(one, four, "shards=4 must replay the legacy schedule");
    assert_eq!(one.tasks_completed, 257);
}

/// Deeper tree: a 3-level hierarchy (1-3-9 schedulers) keeps whole
/// top-level subtrees per shard, so the partition is coarser and the
/// cross-shard links are only the root's three child edges.
#[test]
fn three_level_hierarchy_fingerprint_is_shard_count_invariant() {
    let base = || PlatformConfig::new(64, HierarchySpec::multi_level(3, 3));
    let one = run_with_shards(base(), 1);
    let two = run_with_shards(base(), 2);
    let four = run_with_shards(base(), 4); // clamps to the 3 subtrees
    assert_eq!(one, two);
    assert_eq!(one, four);
    assert_eq!(one.tasks_spawned, one.tasks_completed);
}

/// Satellite pin for the sharded `horizon()` max-reduce: a sharded run
/// must still drain past `world.done` to true quiescence, with the final
/// time covering every shard's busy horizon.
#[test]
fn sharded_run_to_quiescence_drains_past_done() {
    let (reg, main) = independent();
    let mut cfg = PlatformConfig::hierarchical(64);
    cfg.shard = ShardCfg::with_shards(4);
    let mut plat = Platform::build_with(cfg, reg, main, |w| {
        w.app = Some(Box::new(SynthParams {
            n_tasks: 64,
            task_cycles: 100_000,
            ..Default::default()
        }));
    });
    let t = plat.run_to_quiescence(Some(1 << 44));
    assert!(plat.world().done, "workload must complete");
    assert!(plat.eng.sim.queue_is_empty(), "every wheel, held slot and mailbox drained");
    assert_eq!(t, plat.eng.sim.horizon(), "final time covers the per-shard max-reduce");
    assert!(plat.eng.sim.shard_windows() > 0, "run actually used the sharded engine");
}

// ---------------------------------------------------------------------------
// Thread-parallel sharded engine: real host threads stepping the shards
// between conservative barriers must reproduce the exact sequential-merge
// schedule — the fingerprint is pinned to be *identical* across thread
// counts (and, chaos off, across shard counts too). `threads=1` is the
// sequential merge itself, so `one` below is the already-pinned baseline.
// ---------------------------------------------------------------------------

fn run_with_shards_threads(
    cfg_base: PlatformConfig,
    shards: usize,
    threads: usize,
    chaos: FaultPlan,
) -> Fingerprint {
    let (reg, main) = independent();
    let mut cfg = cfg_base;
    cfg.shard = ShardCfg::with_threads(shards, threads);
    cfg.chaos = chaos;
    let mut plat = Platform::build_with(cfg, reg, main, |w| {
        // Synthetic fig7: all spawns come from the main task's scheduler
        // subtree — the single-spawner contract holds.
        w.par_safe = true;
        w.app = Some(Box::new(SynthParams {
            n_tasks: 256,
            task_cycles: 100_000,
            ..Default::default()
        }));
    });
    let t = plat.run(Some(1 << 44));
    let g = &plat.world().gstats;
    Fingerprint {
        final_time: t,
        events: g.events_processed,
        msgs: g.msgs_total,
        tasks_spawned: g.tasks_spawned,
        tasks_completed: g.tasks_completed,
        dep_boundary_msgs: g.dep_boundary_msgs,
        dma_transfers: g.dma_transfers,
    }
}

/// fig7-independent, 4 shards: threads 1/2/4 must produce bit-identical
/// fingerprints, and (chaos off) all of them must equal the unsharded
/// legacy schedule.
#[test]
fn fig7_independent_fingerprint_is_thread_count_invariant() {
    let legacy = run_with_shards(PlatformConfig::hierarchical(64), 1);
    let one = run_with_shards_threads(PlatformConfig::hierarchical(64), 4, 1, FaultPlan::none());
    let two = run_with_shards_threads(PlatformConfig::hierarchical(64), 4, 2, FaultPlan::none());
    let four = run_with_shards_threads(PlatformConfig::hierarchical(64), 4, 4, FaultPlan::none());
    assert_eq!(one, legacy, "threads=1 is the sequential merge");
    assert_eq!(two, one, "threads=2 must replay the sequential schedule");
    assert_eq!(four, one, "threads=4 must replay the sequential schedule");
    assert_eq!(one.tasks_completed, 257);
}

/// Deeper tree (1-3-9 schedulers, 3 shards): the barrier walk must
/// reassign canonical order identically with an odd shard count and a
/// thread count that does not divide it.
#[test]
fn three_level_hierarchy_fingerprint_is_thread_count_invariant() {
    let base = || PlatformConfig::new(64, HierarchySpec::multi_level(3, 3));
    let legacy = run_with_shards(base(), 1);
    let one = run_with_shards_threads(base(), 4, 1, FaultPlan::none());
    let two = run_with_shards_threads(base(), 4, 2, FaultPlan::none());
    let four = run_with_shards_threads(base(), 4, 4, FaultPlan::none());
    assert_eq!(one, legacy);
    assert_eq!(two, one);
    assert_eq!(four, one);
    assert_eq!(one.tasks_spawned, one.tasks_completed);
}

/// Chaos on (jitter + stalls + starvation, no crash): every draw comes
/// from a per-shard lane keyed by (run seed, plan seed, shard id), so the
/// RNG schedule is a function of shard-local execution order alone and
/// the fingerprint must still be thread-count invariant at a fixed shard
/// count. (Lanes make chaos runs shard-count *dependent* by design —
/// the pin here is threads, not shards.)
#[test]
fn chaos_fingerprint_is_thread_count_invariant() {
    let plan = FaultPlan {
        enabled: true,
        plan_seed: 11,
        jitter_pct: 30,
        jitter_max: 5_000,
        starve_pct: 20,
        stall_pct: 25,
        stall_max: 2_000,
        ..FaultPlan::none()
    };
    let one = run_with_shards_threads(PlatformConfig::hierarchical(64), 4, 1, plan.clone());
    let two = run_with_shards_threads(PlatformConfig::hierarchical(64), 4, 2, plan.clone());
    let four = run_with_shards_threads(PlatformConfig::hierarchical(64), 4, 4, plan);
    assert_eq!(two, one, "chaos draws must come off per-shard lanes");
    assert_eq!(four, one);
    assert_eq!(one.tasks_completed, 257, "chaos must not lose tasks");
}

/// A crashing (recovery-enabled) configuration is outside the threaded
/// executor's eligibility gate: requesting threads must be a no-op — the
/// run falls back to the sequential merge and replays bit-identically.
#[test]
fn crash_runs_fall_back_to_sequential_merge() {
    let plan = FaultPlan {
        enabled: true,
        plan_seed: 7,
        crash_pct: 100,
        crash_max: 50_000,
        crash_down: 600_000,
        ..FaultPlan::none()
    };
    let run = |threads: usize| {
        let mut cfg = PlatformConfig::hierarchical(64);
        cfg.recovery = RecoveryCfg::on();
        cfg.chaos = plan.clone();
        cfg.shard = ShardCfg::with_threads(4, threads);
        let (reg, main) = independent();
        let mut plat = Platform::build_with(cfg, reg, main, |w| {
            w.par_safe = true; // the *gate*, not the workload, must refuse
            w.app = Some(Box::new(SynthParams {
                n_tasks: 64,
                task_cycles: 100_000,
                ..Default::default()
            }));
        });
        let t = plat.run_to_quiescence(Some(1 << 44));
        let g = &plat.world().gstats;
        (t, g.events_processed, g.msgs_total, g.tasks_completed, g.crashes, g.restarts)
    };
    let seq = run(1);
    let thr = run(4);
    assert_eq!(thr, seq, "ineligible configs must take the sequential path");
    assert!(seq.4 > 0, "the crash plan must actually fire");
}

/// Multi-tenant traffic mutates cross-shard books outside the message
/// seam, so it is gated out too: threads requested, sequential schedule
/// delivered.
#[test]
fn traffic_runs_fall_back_to_sequential_merge() {
    let run = |threads: usize| {
        let traffic = TrafficCfg::on(8, 2);
        let mut cfg = PlatformConfig::hierarchical(64);
        cfg.traffic = traffic.clone();
        cfg.shard = ShardCfg::with_threads(4, threads);
        let seed = cfg.seed;
        let (reg, refs) = traffic_boot();
        let main_fn = refs.job_main.index();
        let mut plat = Platform::build_with(cfg, reg, refs.boot, move |w| {
            w.par_safe = true;
            let tr =
                TrafficState::generate(&traffic, seed, &w.hier, main_fn, &job_templates(1));
            w.traffic = Some(tr);
        });
        let t = plat.run(Some(1 << 44));
        let g = &plat.world().gstats;
        let tr = plat.world().traffic.as_ref().expect("traffic installed");
        assert!(tr.all_done());
        (t, g.events_processed, g.msgs_total, g.tasks_completed, tr.admitted)
    };
    let seq = run(1);
    let thr = run(4);
    assert_eq!(thr, seq);
}

/// Threaded quiescence: the windowed executor must drain every wheel past
/// `world.done`, agree with the per-shard busy-horizon max-reduce, and
/// still conclude completion from the reduced per-thread counters.
#[test]
fn threaded_run_to_quiescence_drains_past_done() {
    let (reg, main) = independent();
    let mut cfg = PlatformConfig::hierarchical(64);
    cfg.shard = ShardCfg::with_threads(4, 4);
    let mut plat = Platform::build_with(cfg, reg, main, |w| {
        w.par_safe = true;
        w.app = Some(Box::new(SynthParams {
            n_tasks: 64,
            task_cycles: 100_000,
            ..Default::default()
        }));
    });
    let t = plat.run_to_quiescence(Some(1 << 44));
    assert!(plat.world().done, "workload must complete");
    assert!(plat.eng.sim.queue_is_empty(), "every wheel and held slot drained");
    assert_eq!(t, plat.eng.sim.horizon(), "final time covers the per-shard max-reduce");
    assert!(plat.eng.sim.shard_windows() > 0, "run actually used the windowed executor");
}

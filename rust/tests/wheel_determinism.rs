//! Differential determinism gate for the timing-wheel event queue.
//!
//! The wheel (`sim::wheel::EventQ`) replaced the engine's global
//! `BinaryHeap<Queued>`; the determinism contract (docs/sim-engine.md)
//! says its pop order must be *exactly* the heap's `(t, seq)` total order
//! — same-tick ties by sequence number, wake markers merged by their own
//! consumed sequence numbers, far-future events surfacing in order after
//! the lazy epoch refill. These properties drive both structures with the
//! same randomized streams (engine-shaped: pushes never precede the last
//! popped time) and assert identical pop sequences.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use myrmics::ids::CoreId;
use myrmics::sim::event::Event;
use myrmics::sim::wheel::{EventQ, Popped};
use myrmics::testutil::prop::{check, Gen};

/// (t, seq, is_wake, core) — the full pop-order key plus payload identity.
type Key = (u64, u64, bool, u32);

/// Time deltas skewed over every wheel regime: same tick, level-0/1/2
/// distances, and past-the-span far-heap jumps (the wheel span is 2^24).
fn delta(g: &mut Gen) -> u64 {
    match g.usize_in(0, 4) {
        0 => 0,
        1 => g.u64_in(1, 255),
        2 => g.u64_in(256, (1 << 16) - 1),
        3 => g.u64_in(1 << 16, (1 << 24) - 1),
        _ => g.u64_in(1 << 24, 1 << 27),
    }
}

fn pop_key(p: Popped) -> Key {
    match p {
        Popped::Ev(q) => (q.t, q.seq, false, q.core.0),
        Popped::Wake { t, seq, core } => (t, seq, true, core.0),
    }
}

/// Drive wheel + reference with a random interleaving of pushes and pops,
/// then drain both; every pop must match the reference exactly.
fn run_stream(g: &mut Gen, wake_ratio: u64) {
    let mut q = EventQ::new();
    let mut reference: BinaryHeap<Reverse<Key>> = BinaryHeap::new();
    let mut seq = 0u64;
    let mut now = 0u64;
    let ops = g.usize_in(20, 300);
    for _ in 0..ops {
        if reference.is_empty() || g.usize_in(0, 2) > 0 {
            for _ in 0..g.usize_in(1, 6) {
                let t = now + delta(g);
                let core = CoreId(g.u64_in(0, 15) as u32);
                if wake_ratio > 0 && g.u64_in(1, wake_ratio) == 1 {
                    q.push_wake(t, seq, core);
                    reference.push(Reverse((t, seq, true, core.0)));
                } else {
                    q.push(t, seq, core, Event::Boot);
                    reference.push(Reverse((t, seq, false, core.0)));
                }
                seq += 1;
            }
        } else {
            let Reverse(expect) = reference.pop().expect("reference non-empty");
            let got = pop_key(q.pop().expect("wheel must match reference occupancy"));
            assert_eq!(got, expect, "pop order diverged from the reference heap");
            now = got.0;
        }
    }
    while let Some(Reverse(expect)) = reference.pop() {
        let got = pop_key(q.pop().expect("wheel must drain with the reference"));
        assert_eq!(got, expect, "drain order diverged from the reference heap");
        now = got.0;
    }
    assert!(q.pop().is_none(), "wheel must be empty when the reference is");
    assert!(q.is_empty());
    let _ = now;
}

#[test]
fn wheel_matches_reference_heap() {
    check("wheel vs reference heap", 96, |g| run_stream(g, 0));
}

#[test]
fn wheel_matches_reference_heap_with_wakes() {
    // Roughly 1 in 4 entries is a wake marker: exercises the side-heap
    // merge and the bounded cursor advance around pending wakes.
    check("wheel vs reference heap (wakes)", 96, |g| run_stream(g, 4));
}

#[test]
fn same_tick_bursts_preserve_seq_order() {
    // Heavy tie pressure: many events on few distinct ticks, including
    // ticks that start out above level 0 and must cascade in order.
    check("same-tick burst ordering", 64, |g| {
        let mut q = EventQ::new();
        let mut reference: BinaryHeap<Reverse<Key>> = BinaryHeap::new();
        let ticks: Vec<u64> = (0..g.u64_in(1, 4))
            .map(|_| g.u64_in(0, 1 << 25))
            .collect();
        for seq in 0..g.u64_in(8, 64) {
            let t = *g.pick(&ticks);
            q.push(t, seq, CoreId(0), Event::Boot);
            reference.push(Reverse((t, seq, false, 0)));
        }
        while let Some(Reverse(expect)) = reference.pop() {
            assert_eq!(pop_key(q.pop().expect("wheel drains")), expect);
        }
        assert!(q.pop().is_none());
    });
}

#[test]
fn far_future_refill_preserves_order_across_epochs() {
    // Streams biased to far-heap jumps: every pop crosses epochs often,
    // exercising the lazy refill repeatedly.
    check("epoch refill ordering", 64, |g| {
        let mut q = EventQ::new();
        let mut reference: BinaryHeap<Reverse<Key>> = BinaryHeap::new();
        let mut now = 0u64;
        let mut seq = 0u64;
        for _ in 0..g.usize_in(4, 12) {
            for _ in 0..g.usize_in(1, 8) {
                // Mostly-far pushes plus a few near ones.
                let t = if g.bool() {
                    now + g.u64_in(1 << 24, 1 << 28)
                } else {
                    now + g.u64_in(0, 1000)
                };
                q.push(t, seq, CoreId(0), Event::Boot);
                reference.push(Reverse((t, seq, false, 0)));
                seq += 1;
            }
            let Reverse(expect) = reference.pop().expect("pushed above");
            let got = pop_key(q.pop().expect("wheel matches"));
            assert_eq!(got, expect);
            now = got.0;
        }
        let _ = now;
        while let Some(Reverse(expect)) = reference.pop() {
            assert_eq!(pop_key(q.pop().expect("wheel drains")), expect);
        }
    });
}

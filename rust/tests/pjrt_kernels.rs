//! Integration: AOT artifacts (L1 Pallas via L2 JAX) loaded and executed
//! through the PJRT runtime, checked against the rust-side references.
//!
//! Requires `make artifacts`; tests skip (with a notice) when the
//! artifacts directory is absent so `cargo test` works standalone.

use myrmics::apps::jacobi::{jacobi_init, jacobi_reference, myrmics as jacobi_app, read_result, JacobiParams};
use myrmics::apps::kmeans::{gen_points, kmeans_step_reference};
use myrmics::config::PlatformConfig;
use myrmics::platform::Platform;
use myrmics::runtime::engine::KernelEngine;
use myrmics::runtime::shapes;

fn engine() -> Option<KernelEngine> {
    if cfg!(not(pjrt)) {
        // Stub build: `load` always fails, regardless of on-disk artifacts.
        eprintln!("SKIP: built without `--cfg pjrt` (PJRT bridge stubbed)");
        return None;
    }
    let dir = KernelEngine::artifacts_dir();
    if !dir.join("jacobi_band.hlo.txt").exists() {
        eprintln!("SKIP: no artifacts in {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(KernelEngine::load(dir).expect("PJRT client"))
}

#[test]
fn jacobi_kernel_matches_rust_stencil() {
    let Some(mut k) = engine() else { return };
    let (rows2, n) = shapes::JACOBI_IN;
    let x: Vec<f32> = (0..rows2 * n).map(|i| ((i * 37) % 101) as f32 / 10.0).collect();
    let out = k.run_f32("jacobi_band", &[(&x, &[rows2, n])]).expect("run");
    assert_eq!(out.len(), 1);
    let got = &out[0];
    assert_eq!(got.len(), (rows2 - 2) * n);
    // Rust reference with the same clamped-edge semantics.
    for i in 0..rows2 - 2 {
        for j in 0..n {
            let g = |r: usize, c: usize| x[r * n + c];
            let want = 0.25
                * (g(i, j)
                    + g(i + 2, j)
                    + g(i + 1, j.saturating_sub(1))
                    + g(i + 1, (j + 1).min(n - 1)));
            let gotv = got[i * n + j];
            assert!((gotv - want).abs() < 1e-5, "({i},{j}): {gotv} vs {want}");
        }
    }
}

#[test]
fn matmul_kernel_accumulates() {
    let Some(mut k) = engine() else { return };
    let (m, kk, n) = shapes::MATMUL_TILE;
    let a: Vec<f32> = (0..m * kk).map(|i| (i % 7) as f32 - 3.0).collect();
    let b: Vec<f32> = (0..kk * n).map(|i| (i % 5) as f32 - 2.0).collect();
    let c: Vec<f32> = (0..m * n).map(|i| i as f32 * 0.1).collect();
    let out = k
        .run_f32("matmul_tile", &[(&a, &[m, kk]), (&b, &[kk, n]), (&c, &[m, n])])
        .expect("run");
    let got = &out[0];
    for i in 0..m {
        for j in 0..n {
            let mut want = c[i * n + j];
            for x in 0..kk {
                want += a[i * kk + x] * b[x * n + j];
            }
            assert!((got[i * n + j] - want).abs() < 1e-3);
        }
    }
}

#[test]
fn kmeans_kernel_counts_all_points() {
    let Some(mut k) = engine() else { return };
    let p = shapes::KMEANS_POINTS;
    let kc = shapes::KMEANS_K;
    let pts = gen_points(p, 3);
    let cents: Vec<f32> = pts[..kc * 3].to_vec();
    let out = k
        .run_f32("kmeans_assign", &[(&pts, &[p, 3]), (&cents, &[kc, 3])])
        .expect("run");
    let got = &out[0];
    assert_eq!(got.len(), kc * 4);
    let total: f32 = (0..kc).map(|c| got[c * 4 + 3]).sum();
    assert_eq!(total as usize, p, "every point assigned exactly once");
}

#[test]
fn fused_x2_artifact_runs() {
    let Some(mut k) = engine() else { return };
    if !k.available("jacobi_band_x2") {
        return;
    }
    let (rows2, n) = shapes::JACOBI_IN;
    let rows4 = rows2 + 2;
    let x: Vec<f32> = (0..rows4 * n).map(|i| (i % 13) as f32).collect();
    let out = k.run_f32("jacobi_band_x2", &[(&x, &[rows4, n])]).expect("run");
    assert_eq!(out[0].len(), (rows4 - 4) * n);
}

/// The headline e2e check: the full three-layer stack composes. The
/// simulated 520-core platform runs the Jacobi benchmark with task bodies
/// executing the AOT Pallas kernel through PJRT, and the distributed
/// result matches the sequential reference.
#[test]
fn e2e_jacobi_through_pjrt_matches_reference() {
    let Some(k) = engine() else { return };
    let (reg, main) = jacobi_app();
    // bands=4 over n=32 -> rows=8 -> kernel shape (10, 32) == JACOBI_IN.
    let p = JacobiParams { n: 32, iters: 4, bands: 4, groups: 2, real_data: true };
    let mut plat = Platform::build_with(PlatformConfig::hierarchical(8), reg, main, |w| {
        w.app = Some(Box::new(p));
        w.kernels = Some(k);
    });
    plat.run(Some(1 << 44));
    let w = plat.world();
    assert_eq!(w.gstats.tasks_completed, w.gstats.tasks_spawned);
    assert!(
        w.kernels.as_ref().unwrap().n_compiled() >= 1,
        "the PJRT kernel path must actually be exercised"
    );
    let got = read_result(w);
    let want = jacobi_reference(32, 4, &jacobi_init(32));
    for (i, (g, wv)) in got.iter().zip(&want).enumerate() {
        assert!((g - wv).abs() < 1e-4, "cell {i}: {g} vs {wv}");
    }
}

#[test]
fn e2e_kmeans_through_pjrt_matches_reference() {
    let Some(k) = engine() else { return };
    let (reg, main) = myrmics::apps::kmeans::myrmics();
    // 1024 points over 4 bands -> 256 points/band == KMEANS_POINTS, k=4.
    let p = myrmics::apps::kmeans::KmParams {
        points: 1024,
        k: 4,
        iters: 3,
        bands: 4,
        groups: 2,
        real_data: true,
    };
    let mut plat = Platform::build_with(PlatformConfig::hierarchical(8), reg, main, |w| {
        w.app = Some(Box::new(p));
        w.kernels = Some(k);
    });
    plat.run(Some(1 << 44));
    let w = plat.world();
    assert!(w.kernels.as_ref().unwrap().n_compiled() >= 1);
    let st = w.app_ref::<myrmics::apps::kmeans::KmState>();
    let got = w.store.get_f32(st.centroids).unwrap();
    let pts = gen_points(1024, 17);
    let mut want = pts[..4 * 3].to_vec();
    for _ in 0..3 {
        want = kmeans_step_reference(&pts, &want, 4);
    }
    for (i, (g, wv)) in got.iter().zip(&want).enumerate() {
        assert!((g - wv).abs() < 1e-2, "centroid {i}: {g} vs {wv}");
    }
}

//! Determinism gate for the multi-tenant traffic subsystem.
//!
//! Two contracts, mirroring the steal/chaos/recovery layers before it:
//!
//! 1. **Off by default = byte-identical**: with `TrafficCfg::enabled ==
//!    false` (the default) no `TrafficState` exists, no arrival timer is
//!    pushed and the scheduler's quiescence gate takes the
//!    `map_or(true, ..)` fast path — that contract is pinned by the
//!    untouched replay fingerprints in `tests/determinism.rs` (including
//!    the sharded lane) plus the sanity check below.
//! 2. **On = still a pure function of the seed**: the whole arrival
//!    schedule (submit times, tenants, templates, priorities, entry
//!    schedulers) is drawn at build time from `seed ^ TRAFFIC_STREAM`,
//!    retry timers arm from deterministic attempt counters, and admission
//!    consults deterministic load books — so two runs of the same
//!    configuration must replay bit-identically, on flat and deep
//!    hierarchies alike, with every admission policy.

use myrmics::apps::jobs::traffic_boot;
use myrmics::apps::workload_api::job_templates;
use myrmics::config::{AdmissionKind, HierarchySpec, PlatformConfig, TrafficCfg};
use myrmics::platform::Platform;
use myrmics::sim::traffic::TrafficState;

/// Everything that must replay bit-identically, including the traffic
/// layer's own books.
#[derive(PartialEq, Eq, Debug)]
struct Fingerprint {
    final_time: u64,
    events: u64,
    msgs: u64,
    tasks_spawned: u64,
    tasks_completed: u64,
    admitted: u32,
    deferrals: u64,
    admit_times: Vec<u64>,
    finish_times: Vec<u64>,
}

fn run_traffic(mut cfg: PlatformConfig, traffic: TrafficCfg) -> Fingerprint {
    cfg.traffic = traffic.clone();
    let (reg, refs) = traffic_boot();
    let main_fn = refs.job_main.index();
    let seed = cfg.seed;
    let mut plat = Platform::build_with(cfg, reg, refs.boot, move |w| {
        let tr = TrafficState::generate(&traffic, seed, &w.hier, main_fn, &job_templates(1));
        w.traffic = Some(tr);
    });
    let t = plat.run(Some(1 << 44));
    let g = &plat.world().gstats;
    let tr = plat.world().traffic.as_ref().expect("traffic installed");
    assert!(tr.all_done(), "every job must drain before fingerprinting");
    Fingerprint {
        final_time: t,
        events: g.events_processed,
        msgs: g.msgs_total,
        tasks_spawned: g.tasks_spawned,
        tasks_completed: g.tasks_completed,
        admitted: tr.admitted,
        deferrals: tr.total_deferrals,
        admit_times: tr.jobs.iter().map(|j| j.admit_at).collect(),
        finish_times: tr.jobs.iter().map(|j| j.finish_at).collect(),
    }
}

/// Flat hierarchy: every job enters at the single root scheduler; the
/// run must complete and replay.
#[test]
fn traffic_flat_replays_bit_identically() {
    let run = || run_traffic(PlatformConfig::flat(8), TrafficCfg::on(8, 2));
    let a = run();
    let b = run();
    assert_eq!(a, b, "flat traffic run must replay bit-identically");
    assert_eq!(a.admitted, 8);
}

/// Three-level hierarchy: arrivals spread over the top-level subtree
/// roots, jobs delegate down their subtrees; the whole schedule —
/// including every admission decision — must replay.
#[test]
fn traffic_three_level_replays_bit_identically() {
    let cfg = || PlatformConfig::new(16, HierarchySpec::multi_level(3, 2));
    let run = || run_traffic(cfg(), TrafficCfg::on(10, 3));
    let a = run();
    let b = run();
    assert_eq!(a, b, "3-level traffic run must replay bit-identically");
    assert_eq!(a.admitted, 10);
}

/// Backpressure policies arm retry timers; the deferral chain must be as
/// deterministic as the arrivals themselves.
#[test]
fn deferred_retries_replay_bit_identically() {
    let run = || {
        let mut t = TrafficCfg::on(10, 1).with_admission(AdmissionKind::TenantCap);
        t.tenant_cap = 1;
        t.mean_gap = 50_000;
        run_traffic(PlatformConfig::new(16, HierarchySpec::two_level(4)), t)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "deferral/retry chains must replay bit-identically");
    assert!(a.deferrals > 0, "the cap must actually defer: {a:?}");
}

/// Different seeds draw different schedules (and still drain).
#[test]
fn traffic_schedule_is_a_function_of_the_seed() {
    let mut cfg = PlatformConfig::new(16, HierarchySpec::two_level(4));
    cfg.seed = 0xFEED;
    let a = run_traffic(cfg.clone(), TrafficCfg::on(8, 2));
    cfg.seed = 0xBEEF;
    let c = run_traffic(cfg, TrafficCfg::on(8, 2));
    assert_eq!(a.admitted, c.admitted, "both seeds admit everything");
    assert_ne!(
        a.finish_times, c.finish_times,
        "different seeds must draw different schedules"
    );
}

/// Traffic off is the do-nothing path: a plain single-job run neither
/// installs books nor changes its schedule. (The byte-identity of the
/// full event schedule is pinned by the untouched fingerprints in
/// `tests/determinism.rs`; this is the structural half of that contract.)
#[test]
fn traffic_off_installs_nothing() {
    use myrmics::apps::skew::{myrmics as skew_myrmics, SkewParams};
    let (reg, main) = skew_myrmics();
    let cfg = PlatformConfig::new(16, HierarchySpec::two_level(4));
    assert!(!cfg.traffic.enabled);
    let mut plat = Platform::build_with(cfg, reg, main, |w| {
        w.app = Some(Box::new(SkewParams {
            tasks: 24,
            task_cycles: 100_000,
            hot_pct: 50,
            groups: 4,
        }));
    });
    plat.run(Some(1 << 44));
    assert!(plat.world().traffic.is_none());
    assert!(plat.world().tasks.iter().all(|t| t.job.is_none()));
    assert_eq!(plat.world().gstats.tasks_completed, 25);
}

//! Round-trip pins for the typed spawn/args layer (PR 4).
//!
//! The typed builder and extractor must lower to / lift from the paper's
//! Fig-4 wire format **byte-identically**: every builder method produces
//! the exact `TaskArg {node, value, flags}` the wire constructors do
//! (including the pinned flag bit values), and the extractor reads them
//! back. Any drift here would silently change message sizes, dependency
//! analysis, and the determinism fingerprints.

use std::sync::Arc;

use myrmics::api::args::{ObjArg, OptObj, RegionArg, Rest};
use myrmics::api::ctx::{TaskCtx, TaskOp};
use myrmics::config::PlatformConfig;
use myrmics::ids::{NodeId, ObjectId, RegionId};
use myrmics::platform::World;
use myrmics::task::descriptor::{
    Access, TaskArg, TaskDesc, TYPE_IN_ARG, TYPE_NOTRANSFER_ARG, TYPE_OUT_ARG, TYPE_REGION_ARG,
    TYPE_SAFE_ARG,
};
use myrmics::task::registry::{Registry, TaskRef};

fn world() -> World {
    World::new(PlatformConfig::flat(4))
}

/// Build a ctx whose own descriptor is `args` (for extractor tests).
fn ctx_with_args(w: &mut World, args: Vec<TaskArg>) -> TaskCtx<'_> {
    let t = w.tasks.create(TaskDesc::new(0, args), None, 0, 0);
    let desc = w.tasks.get(t).desc.clone();
    TaskCtx::new(w, t, myrmics::ids::CoreId(1), 0, desc)
}

/// Run `build` against a fresh ctx and return the spawned wire descs.
fn spawned(build: impl FnOnce(&mut TaskCtx<'_>)) -> Vec<TaskDesc> {
    let mut w = world();
    let t = w.tasks.create(TaskDesc::new(0, vec![]), None, 0, 0);
    let desc = w.tasks.get(t).desc.clone();
    let mut ctx = TaskCtx::new(&mut w, t, myrmics::ids::CoreId(1), 0, desc);
    build(&mut ctx);
    ctx.into_ops()
        .into_iter()
        .filter_map(|op| match op {
            TaskOp::Spawn(d) => Some(d),
            _ => None,
        })
        .collect()
}

#[test]
fn builder_methods_match_wire_constructors_exactly() {
    let o = ObjectId(7);
    let r = RegionId(3);
    let f = TaskRef::from_index(5);
    let descs = spawned(|ctx| {
        ctx.spawn_task(f)
            .obj_in(o)
            .obj_out(o)
            .obj_inout(o)
            .reg_in(r)
            .reg_inout(r)
            .val(42)
            .obj_opt(Some(o))
            .obj_opt(None)
            .submit();
    });
    assert_eq!(descs.len(), 1);
    let want = TaskDesc::new(
        5,
        vec![
            TaskArg::obj_in(o),
            TaskArg::obj_out(o),
            TaskArg::obj_inout(o),
            TaskArg::region_in(r),
            TaskArg::region_inout(r),
            TaskArg::val(42),
            TaskArg::obj_in(o),
            TaskArg::val(0),
        ],
    );
    assert_eq!(descs[0], want);
}

#[test]
fn notransfer_sets_the_bit_on_the_last_argument_only() {
    let o = ObjectId(9);
    let r = RegionId(2);
    let descs = spawned(|ctx| {
        ctx.spawn_task(TaskRef::from_index(0))
            .reg_inout(r)
            .notransfer()
            .obj_in(o)
            .submit();
    });
    let args = &descs[0].args;
    assert_eq!(args[0], TaskArg::region_inout(r).notransfer());
    assert_eq!(args[1], TaskArg::obj_in(o));
    assert!(args[0].is_notransfer());
    assert!(!args[1].is_notransfer());
}

#[test]
fn flag_bits_are_the_paper_values() {
    // The wire bits are load-bearing: pinned here *and* via the exact
    // TaskArg each builder method emits.
    assert_eq!(TYPE_IN_ARG, 1 << 0);
    assert_eq!(TYPE_OUT_ARG, 1 << 1);
    assert_eq!(TYPE_NOTRANSFER_ARG, 1 << 2);
    assert_eq!(TYPE_SAFE_ARG, 1 << 3);
    assert_eq!(TYPE_REGION_ARG, 1 << 4);
    let o = ObjectId(1);
    let r = RegionId(1);
    assert_eq!(TaskArg::obj_in(o).flags, TYPE_IN_ARG);
    assert_eq!(TaskArg::obj_out(o).flags, TYPE_OUT_ARG);
    assert_eq!(TaskArg::obj_inout(o).flags, TYPE_IN_ARG | TYPE_OUT_ARG);
    assert_eq!(TaskArg::region_in(r).flags, TYPE_IN_ARG | TYPE_REGION_ARG);
    assert_eq!(TaskArg::region_inout(r).flags, TYPE_IN_ARG | TYPE_OUT_ARG | TYPE_REGION_ARG);
    assert_eq!(TaskArg::val(3).flags, TYPE_SAFE_ARG);
    assert_eq!(TaskArg::val(3).node, None);
    assert_eq!(TaskArg::obj_in(o).node, Some(NodeId::Object(o)));
    assert_eq!(TaskArg::region_in(r).node, Some(NodeId::Region(r)));
}

#[test]
fn builder_scratch_is_reused_across_spawns() {
    // Two spawns from one body: the second must not see the first's args.
    let descs = spawned(|ctx| {
        ctx.spawn_task(TaskRef::from_index(1)).obj_in(ObjectId(1)).val(10).submit();
        ctx.spawn_task(TaskRef::from_index(2)).val(20).submit();
    });
    assert_eq!(descs.len(), 2);
    assert_eq!(descs[0], TaskDesc::new(1, vec![TaskArg::obj_in(ObjectId(1)), TaskArg::val(10)]));
    assert_eq!(descs[1], TaskDesc::new(2, vec![TaskArg::val(20)]));
}

#[test]
fn abandoned_builder_leaks_nothing() {
    let descs = spawned(|ctx| {
        // Builder dropped without submit: nothing spawned, nothing staged.
        let _ = ctx.spawn_task(TaskRef::from_index(1)).obj_in(ObjectId(1)).val(99);
        ctx.spawn_task(TaskRef::from_index(2)).val(7).submit();
    });
    assert_eq!(descs.len(), 1);
    assert_eq!(descs[0], TaskDesc::new(2, vec![TaskArg::val(7)]));
}

#[test]
fn extractor_round_trips_what_the_builder_wrote() {
    let mut w = world();
    let args = vec![
        TaskArg::region_inout(RegionId(4)).notransfer(),
        TaskArg::obj_in(ObjectId(11)),
        TaskArg::val(1234),
        TaskArg::val(0),
        TaskArg::obj_in(ObjectId(12)),
        TaskArg::obj_in(ObjectId(13)),
    ];
    let ctx = ctx_with_args(&mut w, args);
    let (r, o, v, none, rest): (RegionArg, ObjArg, u64, OptObj, Rest<ObjArg>) = ctx.args();
    assert_eq!(r, RegionId(4));
    assert_eq!(o, ObjectId(11));
    assert_eq!(v, 1234);
    assert_eq!(none.get(), None);
    assert_eq!(rest.0, vec![ObjectId(12), ObjectId(13)]);
}

#[test]
#[cfg_attr(not(debug_assertions), ignore = "debug-only check")]
#[should_panic(expected = "wire arguments")]
fn extractor_arity_mismatch_panics_in_debug() {
    let mut w = world();
    let ctx = ctx_with_args(&mut w, vec![TaskArg::val(1), TaskArg::val(2)]);
    let _: (u64,) = ctx.args();
}

#[test]
#[cfg_attr(not(debug_assertions), ignore = "debug-only check")]
#[should_panic(expected = "not an object argument")]
fn extractor_flag_mismatch_panics_in_debug() {
    let mut w = world();
    let ctx = ctx_with_args(&mut w, vec![TaskArg::region_in(RegionId(1))]);
    let _: (ObjArg,) = ctx.args();
}

#[test]
#[cfg_attr(not(debug_assertions), ignore = "debug-only check")]
#[should_panic(expected = "not a SAFE by-value argument")]
fn extractor_val_from_object_panics_in_debug() {
    let mut w = world();
    let ctx = ctx_with_args(&mut w, vec![TaskArg::obj_in(ObjectId(1))]);
    let _: (u64,) = ctx.args();
}

#[test]
fn wait_builder_lowers_to_wire_nodes() {
    let mut w = world();
    let mut ctx = ctx_with_args(&mut w, vec![]);
    let o = ObjectId(6);
    let r = RegionId(2);
    ctx.wait_on().obj_inout(o).reg_in(r).wait();
    let ops = ctx.into_ops();
    match &ops[0] {
        TaskOp::Wait(nodes) => {
            assert_eq!(
                nodes,
                &vec![(NodeId::Object(o), Access::Write), (NodeId::Region(r), Access::Read)]
            );
        }
        other => panic!("expected Wait, got {other:?}"),
    }
}

#[test]
fn registry_returns_dense_typed_handles() {
    let mut reg = Registry::new();
    let a = reg.register("a", |_| {});
    let b = reg.register("b", |_| {});
    assert_eq!(a.index(), 0);
    assert_eq!(b.index(), 1);
    assert_ne!(a, b);
    assert_eq!(TaskRef::from_index(1), b);
    assert_eq!(reg.name(b.index()), "b");
    assert_eq!(reg.len(), 2);
    // `get` borrows — calling through the borrow works.
    let f = reg.get(a.index());
    let mut w = world();
    let t = w.tasks.create(TaskDesc::new(0, vec![]), None, 0, 0);
    let desc: Arc<TaskDesc> = w.tasks.get(t).desc.clone();
    let mut ctx = TaskCtx::new(&mut w, t, myrmics::ids::CoreId(1), 0, desc);
    f(&mut ctx);
    assert!(ctx.into_ops().is_empty());
}

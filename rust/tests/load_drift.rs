//! Regression gate for eager load-estimate accounting (paper V-C/V-E).
//!
//! `place()` bumps an eager load estimate for the chosen child subtree /
//! worker. Those bumps must be undone when tasks complete (`TaskDone`
//! decay at the responsible scheduler, worker refresh at the leaf) — not
//! only overwritten by upstream load reports. Before the policy-layer
//! refactor, an inner scheduler never decayed its child estimates, so
//! with throttled reports they drifted upward forever and placement
//! slowly starved the "loaded" subtrees.
//!
//! The test disables load reports entirely (threshold = u64::MAX): after
//! a run completes, every scheduler's estimates must have drained back to
//! exactly zero through the decay path alone — on a 2-level hierarchy the
//! decay fully mirrors the bumps (top: child slots, leaves: worker slots).

use myrmics::apps::skew::{myrmics as skew_myrmics, SkewParams};
use myrmics::apps::synthetic::{independent, SynthParams};
use myrmics::config::{HierarchySpec, PlatformConfig, StealCfg};
use myrmics::platform::Platform;
use myrmics::sched::scheduler::SchedLogic;
use myrmics::sim::engine::Engine;

/// Downcast a scheduler core's logic and return its load-estimate state
/// as (total, child_loads, worker_loads).
fn sched_loads(eng: &Engine, idx: usize) -> (u64, Vec<u64>, Vec<u64>) {
    let sched = sched_logic(eng, idx);
    let loads = &sched.placer().loads;
    (loads.total(), loads.child_loads().to_vec(), loads.worker_loads().to_vec())
}

fn sched_logic(eng: &Engine, idx: usize) -> &SchedLogic {
    let core = eng.world.hier.sched_core(idx);
    let logic = eng.logic_of(core).expect("scheduler core has logic");
    logic
        .as_any()
        .and_then(|a| a.downcast_ref::<SchedLogic>())
        .expect("scheduler core logic is SchedLogic")
}

/// Every scheduler's books must be exactly zero and every ready queue
/// drained once a run completes with load reports disabled.
fn assert_drained(eng: &Engine) {
    for s in 0..eng.world.hier.n_scheds {
        let (total, children, workers) = sched_loads(eng, s);
        assert_eq!(
            total, 0,
            "scheduler {s} leaked load estimates: total {total}, \
             children {children:?}, workers {workers:?}"
        );
        assert_eq!(sched_logic(eng, s).ready_depth(), 0, "scheduler {s} still queues tasks");
    }
}

#[test]
fn estimates_drain_to_zero_without_load_reports() {
    let (reg, main) = independent();
    let mut cfg = PlatformConfig::new(16, HierarchySpec::two_level(4));
    // No load reports ever: the decay path must balance the books alone.
    cfg.load_report_threshold = u64::MAX;
    let mut plat = Platform::build_with(cfg, reg, main, |w| {
        w.app = Some(Box::new(SynthParams {
            n_tasks: 48,
            task_cycles: 100_000,
            ..Default::default()
        }));
    });
    plat.run(Some(1 << 44));
    let g = &plat.world().gstats;
    assert_eq!(g.tasks_completed, 49, "main + 48 children must complete");

    let n_scheds = plat.eng.world.hier.n_scheds;
    for s in 0..n_scheds {
        let (total, children, workers) = sched_loads(&plat.eng, s);
        assert_eq!(
            total, 0,
            "scheduler {s} leaked load estimates: total {total}, \
             children {children:?}, workers {workers:?}"
        );
        assert!(children.iter().all(|&l| l == 0), "scheduler {s} child drift: {children:?}");
        assert!(workers.iter().all(|&l| l == 0), "scheduler {s} worker drift: {workers:?}");
    }
}

/// Three-level hierarchy, reports disabled: `TaskDone` travels worker →
/// leaf → mid → top, so the mid-level schedulers only see it as a
/// *forwarded* hop — the forward-path decay must balance their books too
/// (before the fix, mid-level estimates leaked every placement forever).
#[test]
fn estimates_drain_on_three_levels_without_reports() {
    let (reg, main) = independent();
    let mut cfg = PlatformConfig::new(16, HierarchySpec::multi_level(3, 2));
    cfg.load_report_threshold = u64::MAX;
    let mut plat = Platform::build_with(cfg, reg, main, |w| {
        w.app = Some(Box::new(SynthParams {
            n_tasks: 40,
            task_cycles: 100_000,
            ..Default::default()
        }));
    });
    plat.run(Some(1 << 44));
    assert_eq!(plat.world().gstats.tasks_completed, 41);
    for s in 0..plat.eng.world.hier.n_scheds {
        let (total, children, workers) = sched_loads(&plat.eng, s);
        assert_eq!(
            total, 0,
            "scheduler {s} leaked load estimates: total {total}, \
             children {children:?}, workers {workers:?}"
        );
    }
}

/// Same shape with reports enabled (default threshold): the combination
/// of decays and authoritative reports must also leave no residue once
/// everything has completed and the queue has quiesced.
#[test]
fn estimates_stay_bounded_with_reports() {
    let (reg, main) = independent();
    let cfg = PlatformConfig::new(16, HierarchySpec::two_level(4));
    let mut plat = Platform::build_with(cfg, reg, main, |w| {
        w.app = Some(Box::new(SynthParams {
            n_tasks: 48,
            task_cycles: 100_000,
            ..Default::default()
        }));
    });
    plat.run(Some(1 << 44));
    // In-flight load reports may still be queued when the run cuts off at
    // completion, so totals need not be exactly zero everywhere — but no
    // estimate may exceed what was ever simultaneously outstanding, and
    // the decay path must keep the top's view near-drained (the old drift
    // bug left it at ~n_tasks here).
    let (total, children, workers) = sched_loads(&plat.eng, 0);
    assert!(
        total <= 4,
        "top-level estimates did not drain: total {total}, \
         children {children:?}, workers {workers:?}"
    );
}

/// Stealing enabled, reports disabled, 2-level tree: the throttled
/// dispatch path (bump on place, decay on completion) plus any steals the
/// eager estimates trigger must still drain every book to exactly zero —
/// a stolen task decays at the victim's slot and charges the thief's
/// destination slot, and the completion decay follows the worker it
/// *actually* ran on.
#[test]
fn estimates_drain_to_zero_with_stealing_enabled() {
    let (reg, main) = independent();
    let mut cfg = PlatformConfig::new(16, HierarchySpec::two_level(4));
    cfg.load_report_threshold = u64::MAX;
    cfg.policy.steal = StealCfg::on();
    let mut plat = Platform::build_with(cfg, reg, main, |w| {
        w.app = Some(Box::new(SynthParams {
            n_tasks: 48,
            task_cycles: 100_000,
            ..Default::default()
        }));
    });
    plat.run(Some(1 << 44));
    assert_eq!(plat.world().gstats.tasks_completed, 49, "main + 48 children must complete");
    assert_drained(&plat.eng);
}

/// Same contract on a 3-level hierarchy: mid-level schedulers see stolen
/// tasks only as forwarded `TaskDone` hops, and their books must still
/// balance through the forward-path decay.
#[test]
fn estimates_drain_on_three_levels_with_stealing_enabled() {
    let (reg, main) = independent();
    let mut cfg = PlatformConfig::new(16, HierarchySpec::multi_level(3, 2));
    cfg.load_report_threshold = u64::MAX;
    cfg.policy.steal = StealCfg::on();
    let mut plat = Platform::build_with(cfg, reg, main, |w| {
        w.app = Some(Box::new(SynthParams {
            n_tasks: 40,
            task_cycles: 100_000,
            ..Default::default()
        }));
    });
    plat.run(Some(1 << 44));
    assert_eq!(plat.world().gstats.tasks_completed, 41);
    assert_drained(&plat.eng);
}

/// Actual migrations (skew workload, reports on): stolen tasks must decay
/// at the victim and charge the thief — after completion no scheduler may
/// hold queued tasks, and the top's estimates must be near-drained (only
/// in-flight final reports may remain, exactly as in the report-enabled
/// baseline test above).
#[test]
fn migration_accounting_balances_under_real_steals() {
    let (reg, main) = skew_myrmics();
    let mut cfg = PlatformConfig::new(16, HierarchySpec::two_level(4));
    cfg.policy.steal = StealCfg::on();
    let mut plat = Platform::build_with(cfg, reg, main, |w| {
        w.app = Some(Box::new(SkewParams {
            tasks: 64,
            task_cycles: 200_000,
            hot_pct: 90,
            groups: 4,
        }));
    });
    plat.run(Some(1 << 44));
    let g = &plat.world().gstats;
    assert_eq!(g.tasks_completed, 65);
    assert!(g.tasks_stolen > 0, "the skewed run must actually migrate tasks");
    for s in 0..plat.eng.world.hier.n_scheds {
        assert_eq!(
            sched_logic(&plat.eng, s).ready_depth(),
            0,
            "scheduler {s} finished with queued tasks"
        );
        let (total, children, workers) = sched_loads(&plat.eng, s);
        assert!(
            total <= 4,
            "scheduler {s} books did not balance after migration: total {total}, \
             children {children:?}, workers {workers:?}"
        );
    }
}

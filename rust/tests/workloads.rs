//! Generic all-workloads smoke test (PR 4).
//!
//! Replaces the per-app copy-pasted "it completes" integration tests:
//! every entry of `all_workloads()` — present and future — is run at a
//! tiny size through the real `run_myrmics` driver on both hierarchies,
//! must complete every task it spawned, and must pass its own
//! `verify()`. A workload that is added to the table but broken (or
//! registered wrong) fails this test; one that is *not* added to the
//! table fails the enumeration pins below. CI runs this file as a named
//! step.

use myrmics::apps::workload_api::{all_workloads, Scaling};
use myrmics::experiments::bench::{run_mpi_bench, run_myrmics};

/// 4 workers is valid for every workload (square grid for matmul,
/// power of two for bitonic, <= 128 for barnes-hut).
const SMOKE_WORKERS: usize = 4;

#[test]
fn every_workload_completes_and_verifies_on_both_hierarchies() {
    for w in all_workloads() {
        assert!(
            w.valid_workers(SMOKE_WORKERS),
            "{}: smoke worker count must be valid",
            w.name()
        );
        for hier in [false, true] {
            let (t, eng) = run_myrmics(w, SMOKE_WORKERS, Scaling::Weak, hier, None);
            assert!(t > 0, "{} (hier={hier}): no virtual time elapsed", w.name());
            let g = &eng.world.gstats;
            assert!(g.tasks_spawned > 1, "{} (hier={hier}): nothing spawned", w.name());
            assert_eq!(
                g.tasks_completed,
                g.tasks_spawned,
                "{} (hier={hier}): stalled",
                w.name()
            );
            w.verify(&eng.world)
                .unwrap_or_else(|e| panic!("{} (hier={hier}) verify failed: {e}", w.name()));
        }
    }
}

#[test]
fn every_workload_has_an_mpi_baseline() {
    for w in all_workloads() {
        let (t, eng) = run_mpi_bench(w, SMOKE_WORKERS, Scaling::Weak);
        assert!(t > 0, "{}: MPI baseline ran no virtual time", w.name());
        assert!(eng.world.done, "{}: MPI ranks never finished", w.name());
    }
}

#[test]
fn table_is_complete() {
    // The six paper benchmarks must all be enumerable — a workload
    // silently dropped from the table is a broken build, not a quieter
    // figure.
    let names: Vec<&str> = all_workloads().iter().map(|w| w.name()).collect();
    for want in ["jacobi", "raytrace", "bitonic", "kmeans", "matmul", "barnes-hut"] {
        assert!(names.contains(&want), "workload {want} missing from all_workloads()");
    }
}

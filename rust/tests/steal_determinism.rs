//! Determinism gate for the work-stealing subsystem.
//!
//! Two contracts, both load-bearing:
//!
//! 1. **Off by default = byte-identical**: with `StealCfg::enabled ==
//!    false` (the default), the ReadyQ refactor must reproduce the
//!    pre-stealing event schedule exactly — that contract is pinned by
//!    the untouched replay fingerprints in `tests/determinism.rs` /
//!    `tests/wheel_determinism.rs` (push + pop happen in the same
//!    handler, no message, cost or ordering difference exists).
//! 2. **On = still a pure function of the seed**: with stealing enabled,
//!    every steal decision derives from deterministic load estimates and
//!    (for the randomized victim policy) the per-scheduler RNG seeded
//!    from `PlatformConfig::seed` — so two runs of the same configuration
//!    must replay bit-identically, on flat and deep hierarchies alike.

use myrmics::apps::skew::{myrmics as skew_myrmics, SkewParams};
use myrmics::apps::synthetic::{independent, SynthParams};
use myrmics::config::{HierarchySpec, PlatformConfig, StealCfg};
use myrmics::platform::Platform;

/// Everything that must replay bit-identically, including the steal
/// protocol's own counters.
#[derive(PartialEq, Eq, Debug)]
struct Fingerprint {
    final_time: u64,
    events: u64,
    msgs: u64,
    tasks_spawned: u64,
    tasks_completed: u64,
    dep_boundary_msgs: u64,
    steal_reqs: u64,
    steal_grants: u64,
    steal_denies: u64,
    tasks_stolen: u64,
    ready_hwm: u64,
}

fn run_skew(mut cfg: PlatformConfig, steal: StealCfg, tasks: usize) -> Fingerprint {
    cfg.policy.steal = steal;
    let (reg, main) = skew_myrmics();
    let mut plat = Platform::build_with(cfg, reg, main, move |w| {
        w.app = Some(Box::new(SkewParams {
            tasks,
            task_cycles: 200_000,
            hot_pct: 90,
            groups: 4,
        }));
    });
    let t = plat.run(Some(1 << 44));
    let g = &plat.world().gstats;
    Fingerprint {
        final_time: t,
        events: g.events_processed,
        msgs: g.msgs_total,
        tasks_spawned: g.tasks_spawned,
        tasks_completed: g.tasks_completed,
        dep_boundary_msgs: g.dep_boundary_msgs,
        steal_reqs: g.steal_reqs,
        steal_grants: g.steal_grants,
        steal_denies: g.steal_denies,
        tasks_stolen: g.tasks_stolen,
        ready_hwm: g.ready_queue_hwm,
    }
}

/// Flat hierarchy: a single scheduler has no sibling to steal between —
/// the protocol must stay silent, the run must still complete and replay.
#[test]
fn steal_enabled_flat_replays_bit_identically() {
    let run = || run_skew(PlatformConfig::flat(4), StealCfg::on(), 32);
    let a = run();
    let b = run();
    assert_eq!(a, b, "flat steal-enabled run must replay bit-identically");
    assert_eq!(a.tasks_completed, 33, "main + 32 work tasks");
    assert_eq!(a.steal_reqs, 0, "no siblings, no steals");
}

/// Two-level tree under heavy skew: steals must actually fire, and the
/// whole schedule — including every steal decision — must replay.
#[test]
fn steal_enabled_two_level_replays_bit_identically() {
    let cfg = || PlatformConfig::new(16, HierarchySpec::two_level(4));
    let run = || run_skew(cfg(), StealCfg::on(), 64);
    let a = run();
    let b = run();
    assert_eq!(a, b, "steal-enabled run must replay bit-identically");
    assert_eq!(a.tasks_completed, 65);
    assert!(a.tasks_stolen > 0, "the skewed run must migrate tasks: {a:?}");
    assert!(a.ready_hwm > 1, "held-back ready tasks must show in the queue depth");
}

/// Three-level hierarchy: steals happen at inner levels too (a mid
/// scheduler rebalancing its leaf children); replay must still pin.
#[test]
fn steal_enabled_three_level_replays_bit_identically() {
    let cfg = || PlatformConfig::new(16, HierarchySpec::multi_level(3, 2));
    let run = || run_skew(cfg(), StealCfg::on(), 64);
    let a = run();
    let b = run();
    assert_eq!(a, b, "3-level steal-enabled run must replay bit-identically");
    assert_eq!(a.tasks_completed, 65);
    assert!(a.tasks_stolen > 0, "hierarchical steals must fire: {a:?}");
}

/// The randomized victim policy draws only from per-scheduler RNGs
/// derived from the run seed: same seed = same schedule, different seed
/// may differ (and at minimum never panics or stalls).
#[test]
fn random_victim_policy_is_seed_deterministic() {
    let mut base = PlatformConfig::new(16, HierarchySpec::two_level(4));
    base.seed = 0xFEED;
    let run = |cfg: PlatformConfig| run_skew(cfg, StealCfg::random_victim(), 64);
    let a = run(base.clone());
    let b = run(base.clone());
    assert_eq!(a, b, "random-victim runs must replay from the seed");
    assert_eq!(a.tasks_completed, 65);
    let mut other = base;
    other.seed = 0xBEEF;
    let c = run(other);
    assert_eq!(c.tasks_completed, 65, "different seed must still complete");
}

/// Independent (non-skewed) workload with stealing enabled: the
/// throttled-dispatch path replays too, not just the skew shape.
#[test]
fn steal_enabled_independent_replays_bit_identically() {
    let run = || {
        let mut cfg = PlatformConfig::new(16, HierarchySpec::two_level(4));
        cfg.policy.steal = StealCfg::on();
        let (reg, main) = independent();
        let mut plat = Platform::build_with(cfg, reg, main, |w| {
            w.app = Some(Box::new(SynthParams {
                n_tasks: 48,
                task_cycles: 100_000,
                ..Default::default()
            }));
        });
        let t = plat.run(Some(1 << 44));
        let g = &plat.world().gstats;
        (t, g.events_processed, g.msgs_total, g.tasks_completed, g.ready_queue_hwm)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
    assert_eq!(a.3, 49);
}

//! Determinism and liveness gate for scheduler crash-and-restart.
//!
//! Three contracts, all load-bearing:
//!
//! 1. **Recovery off = byte-identical**: with `RecoveryCfg::off()` (the
//!    default) no heartbeat is armed, no crash is installed and no extra
//!    RNG draw happens — the pre-crash event schedule is pinned by the
//!    untouched replay fingerprints in `tests/determinism.rs` /
//!    `tests/steal_determinism.rs`. Here we additionally pin that a
//!    plan's crash knobs are inert while recovery is off.
//! 2. **Crashed runs replay**: the crash schedule is a pure function of
//!    `(run seed, plan seed)`, the outage window and every recovery step
//!    (death declaration, mailbox adoption, orphan re-issue, rejoin) run
//!    on virtual time only — so two runs of a crashing configuration
//!    must produce bit-identical fingerprints, including the recovery
//!    counters themselves.
//! 3. **Exactly-once completion**: with a leaf scheduler lost mid-run,
//!    every workload still reaches quiescence with `tasks_completed ==
//!    tasks_spawned` and every PR-6 oracle green — no lost task, no
//!    double execution (duplicates land in `crash_dups_dropped`, never
//!    in the task table).

use myrmics::apps::skew::{myrmics as skew_myrmics, SkewParams};
use myrmics::apps::synthetic::{empty_chain, hier_empty, independent, SynthParams};
use myrmics::config::{HierarchySpec, PlatformConfig, RecoveryCfg, StealCfg};
use myrmics::platform::Platform;
use myrmics::sim::chaos::FaultPlan;
use myrmics::testutil::oracles;

/// Everything that must replay bit-identically, recovery counters
/// included.
#[derive(PartialEq, Eq, Debug)]
struct Fingerprint {
    final_time: u64,
    events: u64,
    msgs: u64,
    tasks_spawned: u64,
    tasks_completed: u64,
    steal_reqs: u64,
    steal_grants: u64,
    steal_denies: u64,
    tasks_stolen: u64,
    crashes: u64,
    restarts: u64,
    re_adoptions: u64,
    tasks_reissued: u64,
    crash_dups_dropped: u64,
    heartbeats: u64,
}

/// A plan whose only perturbation is the scheduler crash: every rate
/// knob is zero, so any schedule difference against a crash-free run is
/// the outage and the recovery protocol, nothing else.
fn crash_plan(perm_pct: u32) -> FaultPlan {
    FaultPlan {
        enabled: true,
        plan_seed: 7,
        crash_pct: 100,
        crash_max: 50_000,
        crash_down: 600_000,
        crash_perm_pct: perm_pct,
        ..FaultPlan::none()
    }
}

struct Outcome {
    fp: Fingerprint,
    done: bool,
    violations: Vec<String>,
}

/// Build, drain to quiescence and check oracles on the skew workload.
fn run_skew(hier: HierarchySpec, recovery: RecoveryCfg, chaos: FaultPlan) -> Outcome {
    let mut cfg = PlatformConfig::new(16, hier);
    cfg.policy.steal = StealCfg::on().with_retry(10_000, 8);
    cfg.recovery = recovery;
    cfg.chaos = chaos;
    let (reg, main) = skew_myrmics();
    let mut plat = Platform::build_with(cfg, reg, main, |w| {
        w.app = Some(Box::new(SkewParams {
            tasks: 64,
            task_cycles: 200_000,
            hot_pct: 90,
            groups: 4,
        }));
    });
    let t = plat.run_to_quiescence(Some(1 << 44));
    finish(t, plat)
}

fn finish(t: u64, plat: Platform) -> Outcome {
    let violations = oracles::check_all(&plat.eng, false);
    let g = &plat.eng.world.gstats;
    Outcome {
        fp: Fingerprint {
            final_time: t,
            events: g.events_processed,
            msgs: g.msgs_total,
            tasks_spawned: g.tasks_spawned,
            tasks_completed: g.tasks_completed,
            steal_reqs: g.steal_reqs,
            steal_grants: g.steal_grants,
            steal_denies: g.steal_denies,
            tasks_stolen: g.tasks_stolen,
            crashes: g.crashes,
            restarts: g.restarts,
            re_adoptions: g.re_adoptions,
            tasks_reissued: g.tasks_reissued,
            crash_dups_dropped: g.crash_dups_dropped,
            heartbeats: g.heartbeats,
        },
        done: plat.eng.world.done,
        violations,
    }
}

/// Two-level tree, leaf scheduler lost and restarted mid-run: the run
/// completes exactly once, every oracle holds, and the whole thing —
/// outage, re-adoption, re-issue, rejoin — replays bit-identically.
#[test]
fn crashed_run_replays_bit_identically_two_level() {
    let run = || run_skew(HierarchySpec::two_level(4), RecoveryCfg::on(), crash_plan(0));
    let a = run();
    let b = run();
    assert_eq!(a.fp, b.fp, "crashed run must replay bit-identically");
    assert!(a.done, "crashed run must still complete");
    assert!(a.violations.is_empty(), "oracles: {:?}", a.violations);
    assert_eq!(a.fp.crashes, 1, "the forced crash must fire: {:?}", a.fp);
    assert_eq!(a.fp.restarts, 1, "the victim must restart: {:?}", a.fp);
    assert_eq!(a.fp.tasks_completed, a.fp.tasks_spawned, "exactly-once: {:?}", a.fp);
    assert!(a.fp.heartbeats > 0, "the liveness probe must have run: {:?}", a.fp);
}

/// Three-level tree: death is declared by a mid scheduler, re-placement
/// happens inside its subtree, and the schedule still replays.
#[test]
fn crashed_run_replays_bit_identically_three_level() {
    let run = || run_skew(HierarchySpec::multi_level(3, 2), RecoveryCfg::on(), crash_plan(0));
    let a = run();
    let b = run();
    assert_eq!(a.fp, b.fp, "3-level crashed run must replay bit-identically");
    assert!(a.done && a.violations.is_empty(), "oracles: {:?}", a.violations);
    assert_eq!(a.fp.crashes, 1, "{:?}", a.fp);
    assert_eq!(a.fp.tasks_completed, a.fp.tasks_spawned, "{:?}", a.fp);
}

/// Flat tree: a single scheduler has no eligible victim (nobody could
/// adopt its orphans), so the forced-crash plan must install nothing —
/// the run completes crash-free and replays.
#[test]
fn flat_tree_has_no_eligible_victim() {
    let run = || run_skew(HierarchySpec::flat(), RecoveryCfg::on(), crash_plan(0));
    let a = run();
    let b = run();
    assert_eq!(a.fp, b.fp);
    assert!(a.done && a.violations.is_empty(), "oracles: {:?}", a.violations);
    assert_eq!(a.fp.crashes, 0, "no eligible victim on a flat tree: {:?}", a.fp);
    assert_eq!(a.fp.restarts, 0, "{:?}", a.fp);
    assert_eq!(a.fp.tasks_reissued, 0, "{:?}", a.fp);
}

/// Recovery off (the default): the plan's crash knobs are dead weight —
/// the fingerprint is byte-identical to the same plan with the crash
/// knobs zeroed, and no recovery counter moves.
#[test]
fn recovery_off_makes_crash_knobs_inert() {
    let with_knobs = run_skew(HierarchySpec::two_level(4), RecoveryCfg::off(), crash_plan(0));
    let without = run_skew(
        HierarchySpec::two_level(4),
        RecoveryCfg::off(),
        FaultPlan { crash_pct: 0, ..crash_plan(0) },
    );
    assert_eq!(
        with_knobs.fp, without.fp,
        "crash knobs must be byte-inert while recovery is off"
    );
    assert_eq!(with_knobs.fp.crashes, 0);
    assert_eq!(with_knobs.fp.heartbeats, 0, "no probe without recovery: {:?}", with_knobs.fp);
    assert!(with_knobs.done && with_knobs.violations.is_empty());
}

/// Permanent death (`up_at = None`): the victim never rejoins, its
/// workers stay adopted by the parent and the siblings absorb the
/// re-issued orphans — the run still quiesces exactly once and replays.
#[test]
fn permanent_death_still_completes_exactly_once() {
    let run = || run_skew(HierarchySpec::two_level(4), RecoveryCfg::on(), crash_plan(100));
    let a = run();
    let b = run();
    assert_eq!(a.fp, b.fp, "permanent-death run must replay bit-identically");
    assert!(a.done, "permanent death must not wedge the run");
    assert!(a.violations.is_empty(), "oracles: {:?}", a.violations);
    assert_eq!(a.fp.crashes, 1, "{:?}", a.fp);
    assert_eq!(a.fp.restarts, 0, "permanent death never restarts: {:?}", a.fp);
    assert_eq!(a.fp.re_adoptions, 1, "the parent must adopt the subtree: {:?}", a.fp);
    assert_eq!(a.fp.tasks_completed, a.fp.tasks_spawned, "exactly-once: {:?}", a.fp);
}

/// Every workload shape survives losing a leaf scheduler mid-run: full
/// quiescence, oracles green, `completed == spawned` (exactly-once), on
/// the two-level tree with a crash early in the run.
#[test]
fn all_workloads_quiesce_through_a_leaf_crash() {
    let shapes: &[&str] = &["chain", "independent", "skew-90", "hier-empty"];
    for &shape in shapes {
        let mut cfg = PlatformConfig::new(16, HierarchySpec::two_level(4));
        cfg.policy.steal = StealCfg::on().with_retry(10_000, 8);
        cfg.recovery = RecoveryCfg::on();
        cfg.chaos = crash_plan(0);
        let mut plat = match shape {
            "chain" => {
                let (reg, main) = empty_chain();
                Platform::build_with(cfg, reg, main, |w| {
                    w.app = Some(Box::new(SynthParams {
                        n_tasks: 60,
                        task_cycles: 20_000,
                        ..Default::default()
                    }));
                })
            }
            "independent" => {
                let (reg, main) = independent();
                Platform::build_with(cfg, reg, main, |w| {
                    w.app = Some(Box::new(SynthParams {
                        n_tasks: 48,
                        task_cycles: 100_000,
                        ..Default::default()
                    }));
                })
            }
            "skew-90" => {
                let (reg, main) = skew_myrmics();
                Platform::build_with(cfg, reg, main, |w| {
                    w.app = Some(Box::new(SkewParams {
                        tasks: 48,
                        task_cycles: 200_000,
                        hot_pct: 90,
                        groups: 4,
                    }));
                })
            }
            _ => {
                let (reg, main) = hier_empty();
                Platform::build_with(cfg, reg, main, |w| {
                    w.app = Some(Box::new(SynthParams {
                        domains: 4,
                        per_domain: 8,
                        task_cycles: 100_000,
                        domain_level: 2,
                        ..Default::default()
                    }));
                })
            }
        };
        let t = plat.run_to_quiescence(Some(1 << 44));
        let o = finish(t, plat);
        assert!(o.done, "{shape}: crashed run must reach quiescence");
        assert!(o.violations.is_empty(), "{shape}: oracles: {:?}", o.violations);
        assert_eq!(o.fp.crashes, 1, "{shape}: the crash must fire: {:?}", o.fp);
        assert_eq!(
            o.fp.tasks_completed, o.fp.tasks_spawned,
            "{shape}: exactly-once completion: {:?}",
            o.fp
        );
    }
}

//! Cross-module integration: dependency semantics and serial equivalence
//! of full platform runs, plus property-style sweeps over random task
//! graphs (mini-prop harness; proptest is not vendored).

use myrmics::api::args::{ObjArg, Rest};
use myrmics::config::{HierarchySpec, PlatformConfig};
use myrmics::ids::RegionId;
use myrmics::platform::Platform;
use myrmics::task::registry::Registry;
use myrmics::testutil::prop;

/// A chain of inout tasks on one object must observe strict increments
/// (serial equivalence of the dependency queues).
#[test]
fn counter_chain_is_serialized() {
    for workers in [1usize, 4, 16] {
        let mut reg = Registry::new();
        let inc = reg.register("inc", |ctx| {
            let (o,): (ObjArg,) = ctx.args();
            let v = ctx.read_u32(o)[0];
            ctx.compute(50_000);
            ctx.write_u32(o, &[v + 1]);
        });
        let main = reg.register("main", move |ctx| {
            let o = ctx.alloc(64, RegionId::ROOT);
            ctx.write_u32(o, &[0]);
            for _ in 0..40 {
                ctx.spawn_task(inc).obj_inout(o).submit();
            }
        });
        let mut p = Platform::build(PlatformConfig::hierarchical(workers), reg, main);
        p.run(Some(1 << 44));
        let w = p.world();
        assert_eq!(w.gstats.tasks_completed, 41);
        // Find the object (first allocated).
        let final_v = w.store.get_u32(myrmics::ids::ObjectId(1)).unwrap()[0];
        assert_eq!(final_v, 40, "lost increments with {workers} workers");
    }
}

/// Readers between writers see the latest write; concurrent readers don't
/// serialize against each other.
#[test]
fn readers_see_latest_write_and_overlap() {
    let mut reg = Registry::new();
    let write = reg.register("write", |ctx| {
        let (o, v): (ObjArg, u64) = ctx.args();
        ctx.compute(100_000);
        ctx.write_u32(o, &[v as u32]);
    });
    let read = reg.register("read", |ctx| {
        let (o, expect): (ObjArg, u64) = ctx.args();
        ctx.compute(400_000);
        assert_eq!(ctx.read_u32(o)[0], expect as u32, "reader saw a stale value");
    });
    let main = reg.register("main", move |ctx| {
        let o = ctx.alloc(64, RegionId::ROOT);
        ctx.write_u32(o, &[0]);
        for round in 1..=4u64 {
            ctx.spawn_task(write).obj_inout(o).val(round).submit();
            for _ in 0..6 {
                ctx.spawn_task(read).obj_in(o).val(round).submit();
            }
        }
    });
    let mut p = Platform::build(PlatformConfig::hierarchical(8), reg, main);
    p.run(Some(1 << 44));
    let w = p.world();
    assert_eq!(w.gstats.tasks_completed, 1 + 4 * 7);
    // Readers of the same round must overlap somewhere (read concurrency).
    let readers: Vec<(u64, u64)> = w
        .tasks
        .iter()
        .filter(|e| e.desc.func == read.index())
        .take(6)
        .map(|e| (e.started_at, e.done_at))
        .collect();
    let overlaps = readers
        .iter()
        .enumerate()
        .any(|(i, a)| readers.iter().skip(i + 1).any(|b| a.0 < b.1 && b.0 < a.1));
    assert!(overlaps, "concurrent readers never overlapped: {readers:?}");
}

/// Random nested-region task graphs: writers into random subregions with
/// a final whole-region reader; the reader must observe every write.
#[test]
fn prop_random_region_graphs_are_deterministic_and_complete() {
    prop::check("random region graphs", 12, |g| {
        let depth = g.usize_in(1, 3);
        let fanout = g.usize_in(1, 3);
        let writers = g.usize_in(3, 12);
        let workers = *g.pick(&[2usize, 5, 9]);
        let seed_tag = g.u64_in(0, 1 << 30);

        let mut reg = Registry::new();
        let write = reg.register("w", |ctx| {
            let (o, v): (ObjArg, u64) = ctx.args();
            ctx.compute(60_000);
            ctx.write_u32(o, &[v as u32]);
        });
        let check = reg.register("check", |ctx| {
            ctx.compute(10_000);
            let (_tag, objs): (u64, Rest<ObjArg>) = ctx.args();
            for (i, &o) in objs.iter().enumerate() {
                assert_eq!(ctx.read_u32(o)[0], i as u32 + 1, "missing write");
            }
        });
        let main = reg.register("main", move |ctx| {
            // Build a random region tree.
            let mut regions = vec![ctx.ralloc(RegionId::ROOT, 1)];
            for _ in 0..depth {
                let mut next = Vec::new();
                for &r in regions.clone().iter() {
                    for _ in 0..fanout {
                        next.push(ctx.ralloc(r, 2));
                    }
                }
                regions = next;
            }
            // One object per writer in a pseudo-random region; everything
            // is under the first lvl-1 region's ancestors, so anchor via
            // the whole root.
            let mut objs = Vec::new();
            for i in 0..writers {
                let r = regions[(seed_tag as usize + i * 7) % regions.len()];
                let o = ctx.alloc(64, r);
                objs.push(o);
                ctx.spawn_task(write).obj_out(o).val(i as u64 + 1).submit();
            }
            // Reader over every object, ordered after all writers. The
            // leading SAFE tag keeps the wire layout of the original test.
            let mut spawn = ctx.spawn_task(check).val(0);
            for &o in &objs {
                spawn = spawn.obj_in(o);
            }
            spawn.submit();
        });
        let _ = (write, check);
        let mut p = Platform::build(PlatformConfig::hierarchical(workers), reg, main);
        p.run(Some(1 << 44));
        let w = p.world();
        assert_eq!(
            w.gstats.tasks_completed,
            w.gstats.tasks_spawned,
            "deadlock/livelock in random graph (seed {:#x})",
            g.seed
        );
    });
}

/// Deterministic replay: identical seeds give identical virtual times and
/// message counts.
#[test]
fn prop_runs_are_deterministic() {
    prop::check("determinism", 6, |g| {
        let workers = g.usize_in(2, 24);
        let tasks = g.usize_in(4, 40);
        let run = || {
            let (reg, main) = myrmics::apps::synthetic::independent();
            let mut p =
                Platform::build_with(PlatformConfig::hierarchical(workers), reg, main, |w| {
                    w.app = Some(Box::new(myrmics::apps::synthetic::SynthParams {
                        n_tasks: tasks,
                        task_cycles: 200_000,
                        ..Default::default()
                    }));
                });
            let t = p.run(Some(1 << 44));
            (t, p.world().gstats.msgs_total, p.world().gstats.events_processed)
        };
        assert_eq!(run(), run());
    });
}

/// Failure injection: a worker that dies (stops processing) must stall
/// the run rather than corrupt it — completed counts stay consistent.
#[test]
fn dead_worker_stalls_but_never_corrupts() {
    let (reg, main) = myrmics::apps::synthetic::independent();
    let mut p = Platform::build_with(PlatformConfig::flat(4), reg, main, |w| {
        w.app = Some(Box::new(myrmics::apps::synthetic::SynthParams {
            n_tasks: 16,
            task_cycles: 100_000,
            ..Default::default()
        }));
    });
    // Kill worker core 2 by making it permanently busy.
    p.eng.sim.metas[2].busy_until = u64::MAX / 2;
    p.run(Some(200_000_000));
    let w = p.world();
    assert!(w.gstats.tasks_completed < w.gstats.tasks_spawned, "the dead worker's tasks stall");
    assert!(w.gstats.tasks_completed >= 1);
    assert_eq!(w.tasks.n_done() as u64, w.gstats.tasks_completed);
}

/// Deep hierarchies (4 and 5 scheduler levels) still produce correct runs
/// (paper VI-E validates correctness at 4-5 levels).
#[test]
fn four_and_five_level_hierarchies_are_correct() {
    for levels in [4usize, 5] {
        let spec = HierarchySpec::multi_level(levels, 2);
        let cfg = PlatformConfig::new(2usize.pow(levels as u32), spec);
        let (reg, main) = myrmics::apps::synthetic::hier_empty();
        let domains = cfg.n_workers / 2;
        let mut p = Platform::build_with(cfg, reg, main, move |w| {
            w.app = Some(Box::new(myrmics::apps::synthetic::SynthParams {
                domains,
                per_domain: 3,
                domain_level: levels as i32 - 1,
                ..Default::default()
            }));
        });
        p.run(Some(1 << 46));
        let w = p.world();
        assert_eq!(
            w.gstats.tasks_completed, w.gstats.tasks_spawned,
            "{levels}-level hierarchy stalled"
        );
    }
}
